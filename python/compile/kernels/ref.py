"""Pure-jnp / numpy oracles for the Pallas kernels.

Each kernel has a reference here computing the same *specification* in
plain array ops — the pytest suite asserts bit-exact (integer kernels)
or allclose (float pipelines) agreement.
"""

from __future__ import annotations

import numpy as np

SLOPE_FRAC = 13


def lut_interp_ref(x_raw, table, lo_raw, index_shift, q_in=8, q_out=8):
    """Integer reference for ``lut_interp`` (same math as luts.LutTable)."""
    x = np.asarray(x_raw, dtype=np.int32)
    sections = table.shape[0]
    off = np.maximum(x - lo_raw, 0)
    sec = np.minimum(off >> index_shift, sections - 1)
    w = table[sec, 0].astype(np.int64)
    b = table[sec, 1].astype(np.int64)
    prod = (w * x.astype(np.int64)) >> (SLOPE_FRAC + q_in - q_out)
    return np.clip(prod + b, -32768, 32767).astype(np.int16)


def salu_gemv_ref(w, x, bias, frac_bits=8):
    """Integer reference for ``salu_gemv``: exact 64-bit accumulation,
    arithmetic shift, saturation."""
    acc = w.astype(np.int64) @ x.astype(np.int64)
    acc = np.clip(acc, -(2**31), 2**31 - 1)  # S-ALU 32-bit registers
    y = (acc.astype(np.int64) >> frac_bits) + bias.astype(np.int64)
    return np.clip(y, -32768, 32767).astype(np.int16)


def softmax_lut_ref(scores, exp_table, rec_table, exp_lo, exp_shift, rec_lo, rec_shift):
    """Integer reference for ``softmax_lut`` — mirrors the rust
    FunctionalGpt::softmax_q213 pipeline."""
    s = np.asarray(scores, dtype=np.int32)
    m = int(s.max())
    shifted = np.maximum(s - m, -32768)
    exps = lut_interp_ref(
        shifted.astype(np.int16), exp_table, exp_lo, exp_shift, q_in=8, q_out=13
    ).astype(np.int64)
    exps = np.clip(exps, 0, 32767)
    total = max(int(exps.sum()), 1)

    # Range reduction to [1, 2) in Q2.13.
    k = total.bit_length() - 1 - 13
    mant = total >> k if k >= 0 else total << -k
    m_q8 = np.int16(mant >> 5)
    recip = int(
        lut_interp_ref(np.array([m_q8]), rec_table, rec_lo, rec_shift, q_in=8, q_out=13)[0]
    )

    prod = exps * recip
    if k >= 0:
        out = prod >> (13 + k)
    else:
        out = (prod >> 13) << (-k)
    return np.clip(out, 0, 32767).astype(np.int16)


def softmax_float_ref(scores_q8):
    """Float softmax of dequantized Q8.8 scores (accuracy yardstick)."""
    x = np.asarray(scores_q8, dtype=np.float64) / 256.0
    e = np.exp(x - x.max())
    return e / e.sum()
