"""L1 Pallas kernel: LUT-based softmax (§3.2.1 dataflow).

max-subtract (S-ALU max op) → LUT exp in Q2.13 → reduce-sum (C-ALU adder
tree) → LUT reciprocal with power-of-two range reduction (bank-level
unit's bit-position decode) → scale. Bit-exact with
``FunctionalGpt::softmax_q213`` in the rust functional simulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SLOPE_FRAC = 13
EXP_Q_OUT = 13  # Q2.13
RECIP_Q_OUT = 13  # Q2.13


def _softmax_kernel(
    s_ref,
    exp_ref,
    rec_ref,
    o_ref,
    *,
    exp_lo_raw,
    exp_shift,
    rec_lo_raw,
    rec_shift,
    exp_sections,
    rec_sections,
):
    scores = s_ref[...].astype(jnp.int32)
    # S-ALU max op.
    m = jnp.max(scores)
    shifted = jnp.maximum(scores - m, -32768)

    # LUT exp: Q8.8 in → Q2.13 out.
    off = jnp.maximum(shifted - exp_lo_raw, 0)
    sec = jnp.minimum(off >> exp_shift, exp_sections - 1)
    w = exp_ref[...][sec, 0].astype(jnp.int32)
    b = exp_ref[...][sec, 1].astype(jnp.int32)
    exps = jnp.clip(((w * shifted) >> (SLOPE_FRAC + 8 - EXP_Q_OUT)) + b, 0, 32767)

    # C-ALU reduce-sum (Q2.13, 32-bit).
    total = jnp.maximum(jnp.sum(exps), 1)

    # Range reduction: total = mant · 2^k with mant ∈ [1, 2) Q2.13.
    # floor(log2) is exact here (total < 2^26 fits f32's mantissa).
    e = jnp.floor(jnp.log2(total.astype(jnp.float32))).astype(jnp.int32)
    k = e - 13
    mant = jnp.where(k >= 0, total >> jnp.maximum(k, 0), total << jnp.maximum(-k, 0))
    m_q8 = (mant >> 5).astype(jnp.int32)  # Q2.13 → Q8.8 table input
    roff = jnp.maximum(m_q8 - rec_lo_raw, 0)
    rsec = jnp.minimum(roff >> rec_shift, rec_sections - 1)
    rw = rec_ref[...][rsec, 0].astype(jnp.int32)
    rb = rec_ref[...][rsec, 1].astype(jnp.int32)
    recip = ((rw * m_q8) >> (SLOPE_FRAC + 8 - RECIP_Q_OUT)) + rb  # Q2.13

    # Scale: s_i = (exp_i × recip) >> (13 + k), matching the rust model's
    # k ≥ 0 / k < 0 branches exactly.
    # exps ≤ 2^15 and recip ≤ 2^14, so the product fits int32.
    prod = exps * recip
    pos = prod >> jnp.maximum(13 + k, 13)
    neg = (prod >> 13) << jnp.maximum(-k, 0)
    out = jnp.where(k >= 0, pos, neg)
    o_ref[...] = jnp.clip(out, 0, 32767).astype(jnp.int16)


@functools.partial(
    jax.jit,
    static_argnames=("exp_lo_raw", "exp_shift", "rec_lo_raw", "rec_shift"),
)
def softmax_lut(scores, exp_table, rec_table, *, exp_lo_raw, exp_shift, rec_lo_raw, rec_shift):
    """Softmax over int16 Q8.8 ``scores`` → int16 Q2.13 weights."""
    n = scores.shape[0]
    kernel = functools.partial(
        _softmax_kernel,
        exp_lo_raw=exp_lo_raw,
        exp_shift=exp_shift,
        rec_lo_raw=rec_lo_raw,
        rec_shift=rec_shift,
        exp_sections=exp_table.shape[0],
        rec_sections=rec_table.shape[0],
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int16),
        interpret=True,
    )(scores, exp_table, rec_table)


def softmax_for(exp_t, rec_t, scores):
    """Wrapper taking ``luts.LutTable`` objects."""
    return softmax_lut(
        jnp.asarray(scores, jnp.int16),
        jnp.asarray(exp_t.table_i16(), jnp.int16),
        jnp.asarray(rec_t.table_i16(), jnp.int16),
        exp_lo_raw=exp_t.lo_raw,
        exp_shift=exp_t.index_shift,
        rec_lo_raw=rec_t.lo_raw,
        rec_shift=rec_t.index_shift,
    )
