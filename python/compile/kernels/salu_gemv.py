"""L1 Pallas kernel: S-ALU-shaped fixed-point GEMV (§3.1 / Fig. 6(b)).

Grid = (row tiles = S-ALU groups, column tiles = banks). Each program
instance streams one ``(tile_rows × tile_cols)`` weight tile — the GBL
burst stream of one subarray group — MACs into an int32 register block
(the S-ALU's 16 × 32-bit registers), and the column-tile grid axis plays
the C-ALU: partial sums accumulate into the output block across banks.
The final shift-truncate + bias is the S-ALU writeback shifter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemv_kernel(w_ref, x_ref, acc_ref):
    """One (row-tile, col-tile) step: acc += W_tile · x_tile (int32)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.int32)
    x = x_ref[...].astype(jnp.int32)
    # Shared-MAC analogue: one fused reduction per register block rather
    # than 16 scalar FMAs (DESIGN.md §Hardware-Adaptation).
    acc_ref[...] += jnp.sum(w * x[None, :], axis=1)


@functools.partial(jax.jit, static_argnames=("tile_rows", "tile_cols", "frac_bits"))
def salu_gemv(w, x, bias, *, tile_rows=16, tile_cols=64, frac_bits=8):
    """y[rows] = sat16((W·x) >> frac_bits + bias).

    ``w``: int16[rows, cols] (rows % tile_rows == 0, cols % tile_cols == 0),
    ``x``: int16[cols], ``bias``: int16[rows].
    """
    rows, cols = w.shape
    assert rows % tile_rows == 0 and cols % tile_cols == 0
    acc = pl.pallas_call(
        _gemv_kernel,
        grid=(rows // tile_rows, cols // tile_cols),
        in_specs=[
            pl.BlockSpec((tile_rows, tile_cols), lambda i, j: (i, j)),
            pl.BlockSpec((tile_cols,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_rows,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.int32),
        interpret=True,
    )(w, x)
    # Writeback shifter: arithmetic shift, bias add, int16 saturation.
    y = (acc >> frac_bits) + bias.astype(jnp.int32)
    return jnp.clip(y, -32768, 32767).astype(jnp.int16)
