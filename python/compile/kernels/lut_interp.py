"""L1 Pallas kernel: LUT-based linear interpolation (§4.2, Fig. 9).

The paper's hardware insight re-thought for the TPU memory hierarchy
(DESIGN.md §Hardware-Adaptation): the LUT-embedded subarray's per-MAT
column select becomes an in-VMEM gather over a ``(sections, 2)``
slope/intercept table; the bank-level unit's bit-position decode is the
same shift-and-clamp index computation in int32 lanes; the S-ALU
multiply-add is a fused int32 multiply + arithmetic shift + add with
int16 saturation.

``interpret=True`` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SLOPE_FRAC = 13
LANES = 16


def _lut_kernel(x_ref, table_ref, o_ref, *, lo_raw, index_shift, out_shift, sections):
    """One grid step: interpolate a block of raw int16 inputs."""
    x = x_ref[...].astype(jnp.int32)
    # Bank-level unit decode: shift-and-clamp section index.
    offset = jnp.maximum(x - lo_raw, 0)
    sec = jnp.minimum(offset >> index_shift, sections - 1)
    # LUT-embedded subarray read: gather both entries per lane.
    w = table_ref[...][sec, 0].astype(jnp.int32)
    b = table_ref[...][sec, 1].astype(jnp.int32)
    # S-ALU multiply-add with the writeback shifter (arithmetic shift).
    prod = (w * x) >> out_shift
    y = prod + b
    o_ref[...] = jnp.clip(y, -32768, 32767).astype(jnp.int16)


@functools.partial(
    jax.jit, static_argnames=("lo_raw", "index_shift", "q_in", "q_out", "block")
)
def lut_interp(x_raw, table, *, lo_raw, index_shift, q_in=8, q_out=8, block=256):
    """Interpolate ``x_raw`` (int16[N], N multiple of ``block``) against
    ``table`` (int16[sections, 2] of [slope Q2.13, intercept q_out])."""
    n = x_raw.shape[0]
    sections = table.shape[0]
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    out_shift = SLOPE_FRAC + q_in - q_out
    kernel = functools.partial(
        _lut_kernel,
        lo_raw=lo_raw,
        index_shift=index_shift,
        out_shift=out_shift,
        sections=sections,
    )
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            # The whole table stays resident in VMEM for every step —
            # the LUT-embedded subarray's row stays open across chunks.
            pl.BlockSpec((sections, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int16),
        interpret=True,
    )(x_raw, table)


def lut_interp_for(table_obj, x_raw, block=256):
    """Convenience wrapper taking a ``luts.LutTable``."""
    return lut_interp(
        jnp.asarray(x_raw, jnp.int16),
        jnp.asarray(table_obj.table_i16(), jnp.int16),
        lo_raw=table_obj.lo_raw,
        index_shift=table_obj.index_shift,
        q_in=table_obj.q_in,
        q_out=table_obj.q_out,
        block=block,
    )
