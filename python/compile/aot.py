"""AOT export: lower the L2 model + L1 kernels to HLO **text** artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: the
image's xla_extension 0.5.1 rejects jax ≥ 0.5 protos with 64-bit
instruction ids, while the text parser reassigns ids (see
/opt/xla-example/README.md). Run once via ``make artifacts``; python is
never on the request path.

Artifacts written to ``--out-dir`` (default ../artifacts):
  model_decode_ref.hlo.txt   float decode step (golden model)
  model_decode_pim.hlo.txt   LUT/fixed-point decode step (SAL-PIM pipeline)
  kernel_lut_gelu.hlo.txt    standalone GELU interpolation kernel
  kernel_salu_gemv.hlo.txt   standalone S-ALU GEMV kernel
  kernel_softmax.hlo.txt     standalone LUT softmax kernel
  luts/<fn>_<sections>.txt   quantized slope/intercept tables
  manifest.txt               shapes + argument order per artifact
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import luts, model
from .kernels.lut_interp import lut_interp
from .kernels.salu_gemv import salu_gemv
from .kernels.softmax_lut import softmax_lut

CFG = model.CFG


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # True = print_large_constants: the baked synthetic weights must
    # survive the text round-trip (the default elides them as `{...}`,
    # which the rust-side parser would reject or mis-load).
    return comp.as_hlo_text(True)


def lower_decode(pim: bool):
    tok = jax.ShapeDtypeStruct((), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    kv = jax.ShapeDtypeStruct((CFG.n_layers, CFG.max_seq, CFG.d_model), jnp.float32)
    fn = functools.partial(model.decode_step, pim=pim)
    return jax.jit(fn).lower(tok, pos, kv, kv)


def lower_kernels():
    """Standalone L1 kernel artifacts (fixed shapes for the rust loader)."""
    gelu_t = luts.LutTable("gelu", 64)
    exp_t = luts.LutTable("exp", 64)
    rec_t = luts.LutTable("recip", 64)

    n = 512
    x = jax.ShapeDtypeStruct((n,), jnp.int16)
    table = jax.ShapeDtypeStruct((64, 2), jnp.int16)
    gelu = jax.jit(
        functools.partial(
            lut_interp,
            lo_raw=gelu_t.lo_raw,
            index_shift=gelu_t.index_shift,
            q_in=8,
            q_out=8,
            block=256,
        )
    ).lower(x, table)

    rows, cols = 128, 128
    gemv = jax.jit(functools.partial(salu_gemv, tile_rows=16, tile_cols=64)).lower(
        jax.ShapeDtypeStruct((rows, cols), jnp.int16),
        jax.ShapeDtypeStruct((cols,), jnp.int16),
        jax.ShapeDtypeStruct((rows,), jnp.int16),
    )

    scores = jax.ShapeDtypeStruct((CFG.max_seq,), jnp.int16)
    softmax = jax.jit(
        functools.partial(
            softmax_lut,
            exp_lo_raw=exp_t.lo_raw,
            exp_shift=exp_t.index_shift,
            rec_lo_raw=rec_t.lo_raw,
            rec_shift=rec_t.index_shift,
        )
    ).lower(scores, table, table)

    return {
        "kernel_lut_gelu": (gelu, f"x:int16[{n}] table:int16[64,2] -> int16[{n}]"),
        "kernel_salu_gemv": (
            gemv,
            f"w:int16[{rows},{cols}] x:int16[{cols}] bias:int16[{rows}] -> int16[{rows}]",
        ),
        "kernel_softmax": (
            softmax,
            f"scores:int16[{CFG.max_seq}] exp:int16[64,2] rec:int16[64,2] -> int16[{CFG.max_seq}]",
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "luts"), exist_ok=True)

    manifest = []
    kv_shape = f"f32[{CFG.n_layers},{CFG.max_seq},{CFG.d_model}]"
    sig = (
        f"token:i32[] pos:i32[] kv_k:{kv_shape} kv_v:{kv_shape} -> "
        f"(logits:f32[{CFG.vocab}], kv_k:{kv_shape}, kv_v:{kv_shape})"
    )
    for name, pim in [("model_decode_ref", False), ("model_decode_pim", True)]:
        text = to_hlo_text(lower_decode(pim))
        path = os.path.join(out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name}: {sig}")
        print(f"wrote {path} ({len(text)} chars)")

    for name, (lowered, sig_k) in lower_kernels().items():
        text = to_hlo_text(lowered)
        path = os.path.join(out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name}: {sig_k}")
        print(f"wrote {path} ({len(text)} chars)")

    for fn in luts.FUNCS:
        t = luts.LutTable(fn, 64)
        path = os.path.join(out, "luts", f"{fn}_64.txt")
        with open(path, "w") as f:
            f.write(t.to_artifact_text())
        manifest.append(f"luts/{fn}_64.txt: sections=64")

    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {out}/manifest.txt")


if __name__ == "__main__":
    main()
