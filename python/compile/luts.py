"""LUT slope/intercept table generation (python side).

Bit-identical mirror of ``rust/src/interp/lut.rs``: endpoint-fit linear
interpolation on uniform power-of-two sections, slopes stored Q2.13,
intercepts in the function's output format, inputs decoded by a pure
shift (the bank-level unit's column decoder).
"""

from __future__ import annotations

import math

import numpy as np

from .weights import quantize

SLOPE_FRAC = 13

# (lo, hi, q_in_frac, q_out_frac) per function — matches LutSubarrays::new.
FUNCS = {
    "gelu": (-8.0, 8.0, 8, 8),
    "exp": (-16.0, 0.0, 8, 13),
    "rsqrt": (0.0, 4.0, 8, 8),
    "recip": (1.0, 2.0, 8, 13),
    "tanh": (-4.0, 4.0, 8, 8),
}

RANGE_REDUCED = {"rsqrt", "recip"}


def eval_exact(func: str, x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.float64)
    if func == "gelu":
        c = math.sqrt(2.0 / math.pi)
        return 0.5 * x * (1.0 + np.tanh(c * (x + 0.044715 * x**3)))
    if func == "exp":
        return np.exp(x)
    if func == "rsqrt":
        return 1.0 / np.sqrt(x)
    if func == "recip":
        return 1.0 / x
    if func == "tanh":
        return np.tanh(x)
    raise ValueError(func)


class LutTable:
    """Quantized slope/intercept table + decode parameters."""

    def __init__(self, func: str, sections: int):
        lo, hi, q_in, q_out = FUNCS[func]
        assert sections & (sections - 1) == 0, "sections must be 2^k"
        span_raw = int(round((hi - lo) * (1 << q_in)))
        assert span_raw % sections == 0
        per_section = span_raw // sections
        assert per_section & (per_section - 1) == 0
        self.func = func
        self.sections = sections
        self.q_in = q_in
        self.q_out = q_out
        self.lo = lo
        self.hi = hi
        self.lo_raw = int(lo * (1 << q_in))
        self.index_shift = per_section.bit_length() - 1

        width = (hi - lo) / sections
        x0 = lo + np.arange(sections) * width
        x1 = x0 + width
        if func in RANGE_REDUCED:
            floor = 0.5 * min(width, 1.0)
            x0 = np.maximum(x0, floor)
            x1 = np.maximum(x1, floor)
        y0 = eval_exact(func, x0)
        y1 = eval_exact(func, x1)
        w = (y1 - y0) / width
        b = y0 - w * (lo + np.arange(sections) * width)
        self.slopes = quantize(w, SLOPE_FRAC)
        self.intercepts = quantize(b, q_out)

    def section_of(self, raw: np.ndarray) -> np.ndarray:
        offset = np.maximum(raw.astype(np.int32) - self.lo_raw, 0)
        return np.minimum(offset >> self.index_shift, self.sections - 1)

    def eval_raw(self, raw: np.ndarray) -> np.ndarray:
        """Bit-exact integer evaluation (mirrors LutTable::eval_raw)."""
        raw = np.asarray(raw, dtype=np.int16)
        s = self.section_of(raw)
        w = self.slopes[s].astype(np.int64)
        shift = SLOPE_FRAC + self.q_in - self.q_out
        prod = (w * raw.astype(np.int64)) >> shift
        y = prod + self.intercepts[s].astype(np.int64)
        return np.clip(y, -32768, 32767).astype(np.int16)

    def table_i16(self) -> np.ndarray:
        """(sections, 2) int16 [slope, intercept] — the kernel operand."""
        return np.stack([self.slopes, self.intercepts], axis=1)

    def to_artifact_text(self) -> str:
        head = (
            f"# lut {self.func} sections={self.sections} q_in={self.q_in} "
            f"q_out={self.q_out} slope_frac={SLOPE_FRAC} lo={fmt(self.lo)} hi={fmt(self.hi)}\n"
        )
        body = "".join(
            f"{int(w)} {int(b)}\n" for w, b in zip(self.slopes, self.intercepts)
        )
        return head + body


def fmt(x: float) -> str:
    """Rust's `{}` float formatting for the values we use (integers print
    without a trailing .0)."""
    return str(int(x)) if float(x).is_integer() else repr(x)
