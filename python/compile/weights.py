"""Shared synthetic-weight generation (python side).

Must stay bit-identical to ``rust/src/model/weights.rs``: SplitMix64
seeded with FNV-1a of ``"{model}/{tensor}"``, uniform floats in
``[-scale, scale]``, optional Q-format quantization with
round-half-away-from-zero (what rust's ``f64::round`` does).
"""

from __future__ import annotations

import numpy as np

MASK64 = np.uint64(0xFFFFFFFFFFFFFFFF)

WEIGHT_SCALE = 0.08
EMBED_SCALE = 0.5


def fnv1a(s: str) -> np.uint64:
    """FNV-1a 64-bit hash of a UTF-8 string."""
    h = np.uint64(0xCBF29CE484222325)
    prime = np.uint64(0x100000001B3)
    for b in s.encode("utf-8"):
        h = np.uint64(h ^ np.uint64(b))
        h = np.uint64((int(h) * int(prime)) & int(MASK64))
    return h


def splitmix64(seed: np.uint64, n: int) -> np.ndarray:
    """First ``n`` outputs of SplitMix64 from ``seed`` (uint64 array)."""
    out = np.empty(n, dtype=np.uint64)
    state = int(seed)
    for i in range(n):
        state = (state + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        z = state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        out[i] = (z ^ (z >> 31)) & 0xFFFFFFFFFFFFFFFF
    return out


def f64_unit(raw: np.ndarray) -> np.ndarray:
    """Rust's ``SplitMix64::f64_unit``: (x >> 11) / 2^53."""
    return (raw >> np.uint64(11)).astype(np.float64) / float(1 << 53)


def gen_f64(name: str, n: int, scale: float) -> np.ndarray:
    """Uniform floats in [-scale, scale] — mirrors rust ``gen_f64``."""
    raw = splitmix64(fnv1a(name), n)
    return (f64_unit(raw) * 2.0 - 1.0) * scale


def quantize(x: np.ndarray, frac_bits: int) -> np.ndarray:
    """Q-format quantization with round-half-away-from-zero + saturation
    (rust ``QFormat::quantize``)."""
    scaled = np.asarray(x, dtype=np.float64) * (1 << frac_bits)
    rounded = np.where(scaled >= 0, np.floor(scaled + 0.5), np.ceil(scaled - 0.5))
    return np.clip(rounded, -32768, 32767).astype(np.int16)


def dequantize(raw: np.ndarray, frac_bits: int) -> np.ndarray:
    return np.asarray(raw, dtype=np.float64) / (1 << frac_bits)


def gen_q(name: str, n: int, scale: float, frac_bits: int = 8) -> np.ndarray:
    return quantize(gen_f64(name, n, scale), frac_bits)


class MiniConfig:
    """GPT-2 mini — must match rust ``ModelConfig::gpt2_mini``."""

    name = "gpt2-mini"
    d_model = 128
    n_layers = 2
    n_heads = 4
    d_ff = 512
    vocab = 256
    max_seq = 128

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


def layer_params(cfg: MiniConfig, l: int, frac_bits: int = 8) -> dict:
    """One decoder layer's parameters, quantize-dequantized to the same
    grid the rust fixed-point model sees (float values on the Q8.8
    lattice)."""
    d, f, name = cfg.d_model, cfg.d_ff, cfg.name

    def t(tensor: str, n: int, scale: float = WEIGHT_SCALE) -> np.ndarray:
        return dequantize(gen_q(f"{name}/{tensor}", n, scale, frac_bits), frac_bits)

    return {
        "wq": t(f"l{l}/wq", d * d).reshape(d, d),
        "wk": t(f"l{l}/wk", d * d).reshape(d, d),
        "wv": t(f"l{l}/wv", d * d).reshape(d, d),
        "wo": t(f"l{l}/wo", d * d).reshape(d, d),
        "bq": t(f"l{l}/bq", d),
        "bk": t(f"l{l}/bk", d),
        "bv": t(f"l{l}/bv", d),
        "bo": t(f"l{l}/bo", d),
        "w1": t(f"l{l}/w1", f * d).reshape(f, d),
        "b1": t(f"l{l}/b1", f),
        "w2": t(f"l{l}/w2", d * f).reshape(d, f),
        "b2": t(f"l{l}/b2", d),
        "ln1_g": np.ones(d),
        "ln1_b": t(f"l{l}/ln1b", d),
        "ln2_g": np.ones(d),
        "ln2_b": t(f"l{l}/ln2b", d),
    }


def model_params(cfg: MiniConfig, frac_bits: int = 8) -> dict:
    d, name = cfg.d_model, cfg.name

    def t(tensor: str, n: int, scale: float) -> np.ndarray:
        return dequantize(gen_q(f"{name}/{tensor}", n, scale, frac_bits), frac_bits)

    return {
        "wte": t("wte", cfg.vocab * d, EMBED_SCALE).reshape(cfg.vocab, d),
        "wpe": t("wpe", cfg.max_seq * d, EMBED_SCALE).reshape(cfg.max_seq, d),
        "layers": [layer_params(cfg, l, frac_bits) for l in range(cfg.n_layers)],
        "lnf_g": np.ones(d),
        "lnf_b": t("lnf_b", d, WEIGHT_SCALE),
    }
