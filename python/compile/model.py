"""L2: the GPT-2 forward pass in JAX, calling the L1 Pallas kernels.

Two pipelines over the same synthetic weights (python/compile/weights.py,
bit-identical to the rust side):

* ``decode_ref`` — pure-float decode step with exact non-linearities:
  the golden model the rust runtime loads for cross-validation and the
  serving example.
* ``decode_pim`` — the SAL-PIM numerical pipeline: GELU and softmax run
  through the LUT-interpolation Pallas kernels at 16-bit fixed point
  (quantize → integer kernel → dequantize), mirroring what the in-memory
  S-ALUs + LUT-embedded subarrays compute.

Both are AOT-lowered by ``aot.py`` to HLO text; python never runs at
request time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import luts, weights
from .kernels.lut_interp import lut_interp
from .kernels.softmax_lut import softmax_lut

CFG = weights.MiniConfig()

_GELU_T = luts.LutTable("gelu", 64)
_EXP_T = luts.LutTable("exp", 64)
_REC_T = luts.LutTable("recip", 64)


def params_arrays():
    """Model parameters as a pytree of jnp arrays (f32)."""
    p = weights.model_params(CFG)
    return jax.tree_util.tree_map(lambda a: jnp.asarray(a, jnp.float32), p)


def _layernorm(x, g, b):
    mean = jnp.mean(x)
    var = jnp.mean((x - mean) ** 2)
    return (x - mean) / jnp.sqrt(var + 1e-5) * g + b


def _gelu_exact(x):
    c = np.sqrt(2.0 / np.pi).astype(np.float32)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _gelu_pim(x):
    """GELU via the LUT-interpolation kernel at Q8.8."""
    raw = jnp.clip(jnp.round(x * 256.0), -32768, 32767).astype(jnp.int16)
    table = jnp.asarray(_GELU_T.table_i16(), jnp.int16)
    y = lut_interp(
        raw,
        table,
        lo_raw=_GELU_T.lo_raw,
        index_shift=_GELU_T.index_shift,
        q_in=8,
        q_out=8,
        block=x.shape[0],
    )
    return y.astype(jnp.float32) / 256.0


def _softmax_exact(scores, mask):
    s = jnp.where(mask, scores, -jnp.inf)
    return jax.nn.softmax(s)


def _softmax_pim(scores, mask):
    """Softmax via the LUT kernel at fixed point (masked lanes → −128,
    which the exp table maps to ~0)."""
    s = jnp.where(mask, scores, -128.0)
    raw = jnp.clip(jnp.round(s * 256.0), -32768, 32767).astype(jnp.int16)
    w = softmax_lut(
        raw,
        jnp.asarray(_EXP_T.table_i16(), jnp.int16),
        jnp.asarray(_REC_T.table_i16(), jnp.int16),
        exp_lo_raw=_EXP_T.lo_raw,
        exp_shift=_EXP_T.index_shift,
        rec_lo_raw=_REC_T.lo_raw,
        rec_shift=_REC_T.index_shift,
    )
    return w.astype(jnp.float32) / 8192.0  # Q2.13


def _decode(params, token, pos, kv_k, kv_v, *, pim: bool):
    """One decode step.

    token: int32 scalar; pos: int32 scalar (0-based);
    kv_k/kv_v: f32[n_layers, max_seq, d_model] caches.
    Returns (logits f32[vocab], new_kv_k, new_kv_v).
    """
    d = CFG.d_model
    dh = CFG.d_head
    gelu = _gelu_pim if pim else _gelu_exact
    softmax = _softmax_pim if pim else _softmax_exact

    x = params["wte"][token] + params["wpe"][pos]
    positions = jnp.arange(CFG.max_seq)
    mask = positions <= pos

    for l, lw in enumerate(params["layers"]):
        h = _layernorm(x, lw["ln1_g"], lw["ln1_b"])
        q = lw["wq"] @ h + lw["bq"]
        k = lw["wk"] @ h + lw["bk"]
        v = lw["wv"] @ h + lw["bv"]
        kv_k = kv_k.at[l, pos].set(k)
        kv_v = kv_v.at[l, pos].set(v)

        attn = jnp.zeros(d, jnp.float32)
        for head in range(CFG.n_heads):
            sl = slice(head * dh, (head + 1) * dh)
            scores = kv_k[l, :, sl] @ q[sl] / np.sqrt(dh).astype(np.float32)
            wgt = softmax(scores, mask)
            attn = attn.at[sl].set(wgt @ kv_v[l, :, sl])
        x = x + lw["wo"] @ attn + lw["bo"]

        h = _layernorm(x, lw["ln2_g"], lw["ln2_b"])
        ff = gelu(lw["w1"] @ h + lw["b1"])
        x = x + lw["w2"] @ ff + lw["b2"]

    h = _layernorm(x, params["lnf_g"], params["lnf_b"])
    logits = params["wte"] @ h  # tied LM head
    return logits, kv_k, kv_v


@functools.partial(jax.jit, static_argnames=("pim",))
def decode_step(token, pos, kv_k, kv_v, *, pim=False):
    """Jitted decode step with parameters baked as constants (the HLO
    artifact is self-contained; rust passes only token/pos/KV)."""
    return _decode(params_arrays(), token, pos, kv_k, kv_v, pim=pim)


def decode_ref(token, pos, kv_k, kv_v):
    return decode_step(token, pos, kv_k, kv_v, pim=False)


def decode_pim(token, pos, kv_k, kv_v):
    return decode_step(token, pos, kv_k, kv_v, pim=True)


def empty_kv():
    return (
        jnp.zeros((CFG.n_layers, CFG.max_seq, CFG.d_model), jnp.float32),
        jnp.zeros((CFG.n_layers, CFG.max_seq, CFG.d_model), jnp.float32),
    )


def generate(prompt, n_out, *, pim=False):
    """Greedy generation helper (tests + artifact smoke checks)."""
    kv_k, kv_v = empty_kv()
    pos = 0
    next_tok = 0
    for t in prompt:
        logits, kv_k, kv_v = decode_step(
            jnp.int32(t), jnp.int32(pos), kv_k, kv_v, pim=pim
        )
        next_tok = int(jnp.argmax(logits))
        pos += 1
    out = []
    for _ in range(n_out):
        out.append(next_tok)
        logits, kv_k, kv_v = decode_step(
            jnp.int32(next_tok), jnp.int32(pos), kv_k, kv_v, pim=pim
        )
        next_tok = int(jnp.argmax(logits))
        pos += 1
    return out
