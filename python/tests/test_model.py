"""L2 model tests: shapes, PIM-pipeline fidelity, weight-spec parity."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import luts, model, weights


class TestWeightsSpec:
    """The shared PRNG spec must match the rust implementation."""

    def test_fnv1a_known_vectors(self):
        # Same vectors asserted in rust/src/model/weights.rs.
        assert int(weights.fnv1a("")) == 0xCBF29CE484222325
        assert int(weights.fnv1a("a")) == 0xAF63DC4C8601EC8C
        assert int(weights.fnv1a("foobar")) == 0x85944171F73967E8

    def test_splitmix_determinism(self):
        a = weights.splitmix64(np.uint64(42), 8)
        b = weights.splitmix64(np.uint64(42), 8)
        np.testing.assert_array_equal(a, b)

    def test_gen_range_and_name_dependence(self):
        a = weights.gen_f64("m/wq", 64, 0.1)
        c = weights.gen_f64("m/wk", 64, 0.1)
        assert np.abs(a).max() <= 0.1
        assert not np.array_equal(a, c)

    def test_quantize_matches_rust_rounding(self):
        # round-half-away-from-zero, like rust f64::round.
        assert weights.quantize(np.array([0.5 / 256]), 8)[0] == 1
        assert weights.quantize(np.array([-0.5 / 256]), 8)[0] == -1
        assert weights.quantize(np.array([1e9]), 8)[0] == 32767


class TestLutTables:
    def test_artifact_text_shape(self):
        t = luts.LutTable("exp", 32)
        text = t.to_artifact_text()
        assert text.startswith("# lut exp sections=32")
        assert len(text.splitlines()) == 33

    @pytest.mark.parametrize("func", list(luts.FUNCS))
    def test_decode_covers_range(self, func):
        t = luts.LutTable(func, 64)
        assert t.section_of(np.array([-32768]))[0] == 0
        assert t.section_of(np.array([32767]))[0] == 63


class TestModel:
    def test_decode_shapes(self):
        kv_k, kv_v = model.empty_kv()
        logits, k2, v2 = model.decode_ref(jnp.int32(3), jnp.int32(0), kv_k, kv_v)
        assert logits.shape == (model.CFG.vocab,)
        assert k2.shape == kv_k.shape

    def test_kv_cache_updated_at_position(self):
        kv_k, kv_v = model.empty_kv()
        _, k2, v2 = model.decode_ref(jnp.int32(3), jnp.int32(5), kv_k, kv_v)
        assert float(jnp.abs(k2[0, 5]).sum()) > 0
        assert float(jnp.abs(k2[0, 6]).sum()) == 0

    def test_pim_pipeline_tracks_ref(self):
        kv_k, kv_v = model.empty_kv()
        lr, _, _ = model.decode_ref(jnp.int32(5), jnp.int32(0), kv_k, kv_v)
        lp, _, _ = model.decode_pim(jnp.int32(5), jnp.int32(0), kv_k, kv_v)
        lr, lp = np.asarray(lr), np.asarray(lp)
        corr = np.corrcoef(lr, lp)[0, 1]
        assert corr > 0.999, f"pim/ref corr {corr}"
        assert lr.argmax() == lp.argmax()

    def test_generation_deterministic(self):
        a = model.generate([1, 2], 4)
        b = model.generate([1, 2], 4)
        assert a == b and len(a) == 4

    def test_pim_generation_mostly_agrees(self):
        # The §4.1 accuracy-proxy at the artifact level: greedy decode
        # through the LUT pipeline agrees with float on most steps.
        a = model.generate([7, 3, 1], 6, pim=False)
        b = model.generate([7, 3, 1], 6, pim=True)
        agree = sum(x == y for x, y in zip(a, b)) / len(a)
        assert agree >= 0.8, f"{a} vs {b}"
