"""Kernel-vs-reference correctness: the CORE L1 signal.

Integer kernels must match their numpy references **bit-exactly**;
hypothesis sweeps shapes and value ranges.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import luts
from compile.kernels import ref
from compile.kernels.lut_interp import lut_interp_for
from compile.kernels.salu_gemv import salu_gemv
from compile.kernels.softmax_lut import softmax_for

import jax.numpy as jnp

FUNCS = list(luts.FUNCS)


@pytest.fixture(scope="module")
def tables():
    return {f: luts.LutTable(f, 64) for f in FUNCS}


class TestLutInterp:
    @pytest.mark.parametrize("func", FUNCS)
    def test_kernel_matches_ref_bit_exact(self, tables, func):
        t = tables[func]
        rs = np.random.RandomState(42)
        x = rs.randint(-32768, 32768, size=512).astype(np.int16)
        got = np.asarray(lut_interp_for(t, x, block=256))
        want = ref.lut_interp_ref(
            x, t.table_i16(), t.lo_raw, t.index_shift, q_in=t.q_in, q_out=t.q_out
        )
        np.testing.assert_array_equal(got, want)

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        blocks=st.integers(1, 8),
        block=st.sampled_from([16, 64, 256]),
    )
    def test_shape_sweep_gelu(self, seed, blocks, block):
        t = luts.LutTable("gelu", 64)
        rs = np.random.RandomState(seed)
        x = rs.randint(-8000, 8000, size=blocks * block).astype(np.int16)
        got = np.asarray(lut_interp_for(t, x, block=block))
        want = t.eval_raw(x)
        np.testing.assert_array_equal(got, want)

    def test_gelu_accuracy_vs_float(self, tables):
        t = tables["gelu"]
        xs = np.linspace(-7.9, 7.9, 800)
        raw = luts.quantize(xs, 8)
        got = np.asarray(lut_interp_for(t, np.pad(raw, (0, 1024 - len(raw))), block=256))
        got = got[: len(raw)].astype(np.float64) / 256.0
        want = luts.eval_exact("gelu", raw.astype(np.float64) / 256.0)
        assert np.abs(got - want).max() < 0.03

    @pytest.mark.parametrize("sections", [16, 32, 64, 128])
    def test_more_sections_reduce_error(self, sections):
        t = luts.LutTable("tanh", sections)
        xs = np.linspace(-3.9, 3.9, 512)
        raw = luts.quantize(xs, 8)
        got = np.asarray(lut_interp_for(t, raw, block=512)).astype(np.float64) / 256.0
        err = np.abs(got - np.tanh(raw / 256.0)).max()
        # Fig. 4 claim: ≥32 sections keep error at the quantization floor.
        bound = 0.15 if sections == 16 else 0.04
        assert err < bound, f"{sections} sections: err {err}"


class TestSaluGemv:
    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        rows_t=st.integers(1, 8),
        cols_t=st.integers(1, 4),
    )
    def test_matches_ref_bit_exact(self, seed, rows_t, cols_t):
        rs = np.random.RandomState(seed)
        rows, cols = 16 * rows_t, 64 * cols_t
        w = rs.randint(-400, 400, size=(rows, cols)).astype(np.int16)
        x = rs.randint(-400, 400, size=cols).astype(np.int16)
        b = rs.randint(-200, 200, size=rows).astype(np.int16)
        got = np.asarray(salu_gemv(jnp.asarray(w), jnp.asarray(x), jnp.asarray(b)))
        want = ref.salu_gemv_ref(w, x, b)
        np.testing.assert_array_equal(got, want)

    def test_gemv_tracks_float(self):
        rs = np.random.RandomState(7)
        w = rs.uniform(-0.08, 0.08, size=(64, 128))
        x = rs.uniform(-2, 2, size=128)
        wq, xq = luts.quantize(w, 8), luts.quantize(x, 8)
        bq = np.zeros(64, np.int16)
        got = np.asarray(salu_gemv(jnp.asarray(wq), jnp.asarray(xq), jnp.asarray(bq)))
        want = (wq.astype(np.float64) / 256) @ (xq.astype(np.float64) / 256)
        assert np.abs(got / 256.0 - want).max() < 0.01

    def test_writeback_saturates_to_int16(self):
        # The 32-bit accumulator must not overflow (|acc| < 2^31 is a
        # kernel precondition guaranteed by Q8.8 operand ranges — see
        # rust QFormat::dot_raw); the int16 *writeback* does saturate.
        w = np.full((16, 64), 2000, np.int16)
        x = np.full(64, 2000, np.int16)   # acc = 64·4e6 = 2.56e8 < 2^31
        b = np.zeros(16, np.int16)
        got = np.asarray(salu_gemv(jnp.asarray(w), jnp.asarray(x), jnp.asarray(b)))
        want = ref.salu_gemv_ref(w, x, b)
        np.testing.assert_array_equal(got, want)
        assert (got == 32767).all()  # (2.56e8 >> 8) exceeds int16


class TestSoftmaxLut:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([4, 16, 64, 128]))
    def test_matches_ref_bit_exact(self, tables, seed, n):
        rs = np.random.RandomState(seed)
        s = rs.randint(-3000, 2000, size=n).astype(np.int16)
        e, r = tables["exp"], tables["recip"]
        got = np.asarray(softmax_for(e, r, s))
        want = ref.softmax_lut_ref(
            s, e.table_i16(), r.table_i16(), e.lo_raw, e.index_shift, r.lo_raw, r.index_shift
        )
        np.testing.assert_array_equal(got, want)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_close_to_float_softmax(self, tables, seed):
        rs = np.random.RandomState(seed)
        s = rs.randint(-1500, 1500, size=64).astype(np.int16)
        got = np.asarray(softmax_for(tables["exp"], tables["recip"], s)) / 8192.0
        want = ref.softmax_float_ref(s)
        assert np.abs(got - want).max() < 0.01
        assert abs(got.sum() - 1.0) < 0.05

    def test_uniform_scores_uniform_weights(self, tables):
        s = np.zeros(16, np.int16)
        got = np.asarray(softmax_for(tables["exp"], tables["recip"], s)) / 8192.0
        np.testing.assert_allclose(got, np.full(16, 1 / 16), atol=0.01)
