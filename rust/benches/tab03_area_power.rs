//! Table 3 — area and power of the SAL-PIM logic units (paper: 4.81 %
//! area overhead vs conventional HBM2, 9.04 % of the power budget when
//! every S-ALU runs MACs simultaneously).

use sal_pim::config::SimConfig;
use sal_pim::energy::{AreaModel, EnergyParams};
use sal_pim::report::Table;

fn main() {
    let cfg = SimConfig::paper();
    let a = AreaModel::new(&cfg);
    let p = EnergyParams::paper();

    let mut t = Table::new(
        "Table 3 — area & power of SAL-PIM units",
        &["unit", "area/unit (µm²)", "count/ch", "area/ch (mm²)", "power/unit (mW)"],
    );
    t.row(&[
        "S-ALU".into(),
        format!("{:.0}", a.units.salu_um2),
        a.salus_per_channel.to_string(),
        format!("{:.2}", a.salu_area_mm2()),
        format!("{:.3}", p.salu_w * 1e3),
    ]);
    t.row(&[
        "Bank-level unit".into(),
        format!("{:.0}", a.units.bank_unit_um2),
        a.bank_units_per_channel.to_string(),
        format!("{:.2}", a.bank_unit_area_mm2()),
        format!("{:.3}", p.bank_unit_w * 1e3),
    ]);
    t.row(&[
        "C-ALU".into(),
        format!("{:.0}", a.units.calu_um2),
        a.calus_per_channel.to_string(),
        format!("{:.2}", a.calu_area_mm2()),
        format!("{:.3}", p.calu_w * 1e3),
    ]);
    t.print();

    let overhead = a.overhead_fraction() * 100.0;
    println!("area overhead: {overhead:.2}% (paper 4.81%, threshold 25%)");
    assert!((overhead - 4.81).abs() < 0.2);

    // All-S-ALU-active logic power vs the 60 W budget (§5.2's 9.04 %).
    let channels = cfg.hbm.channels() as f64;
    let logic_w = channels
        * (a.salus_per_channel as f64 * p.salu_w
            + a.bank_units_per_channel as f64 * p.bank_unit_w
            + p.calu_w);
    let frac = logic_w / p.power_budget_w * 100.0;
    println!("peak logic power: {logic_w:.2} W = {frac:.2}% of budget (paper 9.04%)");
    assert!((frac - 9.04).abs() < 1.5, "logic fraction {frac}");
    println!("tab03 OK");
}
