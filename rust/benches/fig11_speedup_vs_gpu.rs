//! Fig. 11 — end-to-end speedup of SAL-PIM over the GPU for text
//! generation by input and output size (paper: max 4.72×, avg 1.83×;
//! speedup grows with output size and shrinks with input size).

use sal_pim::baseline::GpuModel;
use sal_pim::config::SimConfig;
use sal_pim::mapper::GenerationSim;
use sal_pim::report::{fmt_x, Table};

fn main() {
    let cfg = SimConfig::paper();
    let gpu = GpuModel::titan_rtx();
    let mut sim = GenerationSim::new(&cfg);
    let outs = [1usize, 4, 16, 32, 64, 128, 256];
    let ins = [32usize, 64, 128];

    let mut t = Table::new(
        "Fig. 11 — SAL-PIM speedup vs GPU (P_Sub=4)",
        &["in\\out", "1", "4", "16", "32", "64", "128", "256"],
    );
    let mut all = Vec::new();
    let mut grid = vec![vec![0.0f64; outs.len()]; ins.len()];
    for (i, &n_in) in ins.iter().enumerate() {
        let mut row = vec![n_in.to_string()];
        for (j, &n_out) in outs.iter().enumerate() {
            let pim = sim.generate(n_in, n_out).seconds(cfg.timing.tck_ns);
            let g = gpu.generation_time(&cfg.model, n_in, n_out);
            let s = g / pim;
            grid[i][j] = s;
            all.push(s);
            row.push(fmt_x(s));
        }
        t.row(&row);
    }
    t.print();

    let max = all.iter().cloned().fold(0.0f64, f64::max);
    let avg = all.iter().sum::<f64>() / all.len() as f64;
    println!("measured: max {} avg {}", fmt_x(max), fmt_x(avg));
    println!("paper:    max 4.72× avg 1.83×");

    // Shape assertions from the paper's discussion of Fig. 11:
    // (a) larger outputs → larger speedup (same input size);
    for (i, _) in ins.iter().enumerate() {
        assert!(
            grid[i][outs.len() - 1] > grid[i][0],
            "speedup must grow with output size (in={})",
            ins[i]
        );
    }
    // (b) larger inputs → smaller speedup (same output size);
    for (j, _) in outs.iter().enumerate().skip(2) {
        assert!(
            grid[0][j] > grid[2][j],
            "speedup must shrink with input size (out={})",
            outs[j]
        );
    }
    // (c) SAL-PIM wins overall (avg > 1) and by single-digit factors.
    assert!(avg > 1.0 && max < 25.0, "avg {avg} max {max}");
    println!("fig11 OK");
}
