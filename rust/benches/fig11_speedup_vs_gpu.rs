//! Fig. 11 — end-to-end speedup of SAL-PIM over the GPU for text
//! generation by input and output size (paper: max 4.72×, avg 1.83×;
//! speedup grows with output size and shrinks with input size).
//!
//! Runs the declarative `Scenario::Sweep` through the scenario `Runner`
//! (the same path as `sal-pim sweep`), asserts the paper's shape claims
//! on the structured outcome, and records it to `BENCH_fig11.json`.

use sal_pim::scenario::{sink, Runner, Scenario, SweepParams};
use std::path::Path;

fn main() {
    let params = SweepParams::default();
    let (ins, outs) = (params.ins.clone(), params.outs.clone());
    let scenario = Scenario::Sweep(params);
    let outcome = Runner::new().run(&scenario).expect("sweep scenario runs");

    print!("{}", sink::render_text(&outcome));

    let speedups = outcome.column_f64("speedup");
    assert_eq!(speedups.len(), ins.len() * outs.len());
    let grid: Vec<&[f64]> = speedups.chunks(outs.len()).collect();

    // Shape assertions from the paper's discussion of Fig. 11:
    // (a) larger outputs → larger speedup (same input size);
    for (i, row) in grid.iter().enumerate() {
        assert!(
            row[outs.len() - 1] > row[0],
            "speedup must grow with output size (in={})",
            ins[i]
        );
    }
    // (b) larger inputs → smaller speedup (same output size);
    for j in 2..outs.len() {
        assert!(
            grid[0][j] > grid[ins.len() - 1][j],
            "speedup must shrink with input size (out={})",
            outs[j]
        );
    }
    // (c) SAL-PIM wins overall (avg > 1) and by single-digit factors.
    let avg = outcome.metric_f64("avg_speedup").expect("avg metric");
    let max = outcome.metric_f64("max_speedup").expect("max metric");
    assert!(avg > 1.0 && max < 25.0, "avg {avg} max {max}");

    let path = sink::write_bench_file(Path::new("."), scenario.bench_tag(), &[&outcome])
        .expect("write BENCH_fig11.json");
    println!("wrote {}", path.display());
    println!("fig11 OK");
}
