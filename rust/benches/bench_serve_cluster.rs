//! Cluster serving bench: continuous batching vs sequential service, and
//! multi-device scaling at saturating load — the numbers behind the
//! EXPERIMENTS.md "serving" section.
//!
//! Asserts the acceptance bars:
//! * continuous batching on one device beats sequential FCFS on the same
//!   16-request mix (strictly higher tok/s over makespan);
//! * a 4-device cluster scales ≥ 2.5× over one device at saturating load.

use sal_pim::config::SimConfig;
use sal_pim::coordinator::Coordinator;
use sal_pim::report::{fmt_pct, fmt_time, fmt_x, Table};
use sal_pim::serve::sweep::{latency_vs_load, SweepConfig};
use sal_pim::serve::workload::{requests_from_items, ArrivalPattern};
use sal_pim::serve::{BackendKind, Cluster, DeviceEngine, Routing, ServeMetrics};
use sal_pim::testutil::RequestMix;

fn main() {
    let cfg = SimConfig::paper();

    // ---- (a) Continuous batching vs sequential on one device. ----
    let items = RequestMix::paper(42).take(16);
    let reqs = requests_from_items(&items, ArrivalPattern::AtOnce, 8);

    let mut coord = Coordinator::new(&cfg);
    for r in reqs.clone() {
        coord.submit_request(r);
    }
    let seq = ServeMetrics::from_completions(&coord.run());

    let mut eng = DeviceEngine::new(&cfg, 8);
    for r in reqs.clone() {
        eng.submit(r);
    }
    let bat = ServeMetrics::from_completions(&eng.run());
    let rep = eng.report();

    let mut t = Table::new(
        "continuous batching vs sequential (1 device, 16-request mix at t=0)",
        &["engine", "tok/s", "makespan", "p50 lat", "p95 lat", "p95 TTFT"],
    );
    for (name, m) in [("sequential fcfs", &seq), ("continuous batch×8", &bat)] {
        t.row(&[
            name.into(),
            format!("{:.1}", m.throughput_tok_s),
            fmt_time(m.makespan_s),
            fmt_time(m.p50_latency_s),
            fmt_time(m.p95_latency_s),
            fmt_time(m.p95_ttft_s),
        ]);
    }
    t.print();
    println!(
        "batching gain: {} | kv peak util {} | max batch {} | decode steps {}",
        fmt_x(bat.throughput_tok_s / seq.throughput_tok_s),
        fmt_pct(rep.kv_peak_utilization),
        rep.max_batch_seen,
        rep.decode_steps
    );
    assert_eq!(seq.total_tokens, bat.total_tokens, "token conservation");
    assert!(
        bat.throughput_tok_s > seq.throughput_tok_s,
        "continuous batching must beat sequential FCFS"
    );

    // ---- (b) Cluster scaling at saturating load. ----
    let items = RequestMix::paper(7).take(64);
    let sat = requests_from_items(&items, ArrivalPattern::AtOnce, 8);
    let mut t = Table::new(
        "cluster scaling (batch 8/device, 64-request mix at t=0, round-robin)",
        &["devices", "tok/s", "makespan", "scaling"],
    );
    let mut base = 0.0;
    let mut last = 0.0;
    for devices in [1usize, 2, 4] {
        let mut cluster = Cluster::new(&cfg, devices, 8, Routing::RoundRobin);
        for r in sat.clone() {
            cluster.submit(r);
        }
        let m = ServeMetrics::from_completions(&cluster.run());
        if devices == 1 {
            base = m.throughput_tok_s;
        }
        last = m.throughput_tok_s;
        t.row(&[
            devices.to_string(),
            format!("{:.1}", m.throughput_tok_s),
            fmt_time(m.makespan_s),
            fmt_x(m.throughput_tok_s / base),
        ]);
    }
    t.print();
    let scaling = last / base;
    assert!(
        scaling >= 2.5,
        "4-device scaling {scaling:.2}× < 2.5× at saturating load"
    );

    // ---- (c) Latency vs offered load (Poisson, 4-device cluster). ----
    let sc = SweepConfig::default();
    let loads = [50.0, 200.0, 1000.0];
    let pts = latency_vs_load(&cfg, &sc, &loads);
    let mut t = Table::new(
        "latency vs offered load (4 devices × batch 8, 64 Poisson requests)",
        &["offered req/s", "tok/s", "p50 lat", "p95 lat", "p95 TTFT", "rejected"],
    );
    for p in &pts {
        t.row(&[
            format!("{:.0}", p.offered_rps),
            format!("{:.1}", p.metrics.throughput_tok_s),
            fmt_time(p.metrics.p50_latency_s),
            fmt_time(p.metrics.p95_latency_s),
            fmt_time(p.metrics.p95_ttft_s),
            p.rejected.to_string(),
        ]);
    }
    t.print();

    // ---- (d) Execution backends on the shared mix (batch 8, t=0). ----
    let items = RequestMix::paper(42).take(16);
    let reqs = requests_from_items(&items, ArrivalPattern::AtOnce, 8);
    let mut t = Table::new(
        "execution backends (1 device × batch 8, 16-request mix at t=0)",
        &["backend", "prefill", "tok/s", "makespan", "p95 TTFT"],
    );
    let mut spans: Vec<(BackendKind, f64)> = Vec::new();
    for (kind, chunk) in [
        (BackendKind::SalPim, None),
        (BackendKind::Gpu, None),
        (BackendKind::BankLevel, None),
        (BackendKind::Hetero, None),
        (BackendKind::Hetero, Some(32usize)),
    ] {
        let mut eng = DeviceEngine::with_backend(kind.build(&cfg), 8).with_prefill_chunk(chunk);
        for r in reqs.clone() {
            eng.submit(r);
        }
        let name = eng.backend_name();
        let m = ServeMetrics::from_completions(&eng.run());
        t.row(&[
            name,
            match chunk {
                Some(c) => format!("chunk {c}"),
                None => "inline".to_string(),
            },
            format!("{:.1}", m.throughput_tok_s),
            fmt_time(m.makespan_s),
            fmt_time(m.p95_ttft_s),
        ]);
        if chunk.is_none() {
            spans.push((kind, m.makespan_s));
        }
    }
    t.print();
    let span = |k: BackendKind| {
        spans
            .iter()
            .find(|(kind, _)| *kind == k)
            .map(|(_, s)| *s)
            .expect("backend measured")
    };
    println!(
        "makespan speedup vs GPU backend: sal-pim {} | hetero {}",
        fmt_x(span(BackendKind::Gpu) / span(BackendKind::SalPim)),
        fmt_x(span(BackendKind::Gpu) / span(BackendKind::Hetero))
    );
    assert!(
        span(BackendKind::SalPim) < span(BackendKind::Gpu),
        "PIM decode must beat the GPU roofline on the decode-bound mix"
    );
    println!("serve cluster bench OK");
}
