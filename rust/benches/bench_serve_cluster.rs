//! Cluster serving bench: continuous batching vs sequential service,
//! multi-device scaling at saturating load, the latency-vs-load curve
//! and the execution-backend comparison — the numbers behind the
//! EXPERIMENTS.md "serving" section.
//!
//! Every section runs declarative `Scenario::Serve` descriptions through
//! the scenario `Runner` (the same path as `sal-pim serve`), asserts the
//! acceptance bars on the structured outcomes, and accumulates all of
//! them into `BENCH_serve.json`:
//! * continuous batching on one device beats sequential FCFS on the same
//!   16-request mix (strictly higher tok/s over makespan);
//! * a 4-device cluster scales ≥ 2.5× over one device at saturating load;
//! * PIM decode beats the GPU roofline backend on the decode-bound mix.

use sal_pim::scenario::{sink, EngineKind, Outcome, Runner, Scenario, ServeParams};
use sal_pim::serve::{BackendKind, KvPolicy};
use std::path::Path;

fn run(params: ServeParams) -> Outcome {
    Runner::new()
        .run(&Scenario::Serve(params))
        .expect("serve scenario runs")
}

fn main() {
    let runner_tag = Scenario::Serve(ServeParams::default()).bench_tag();
    let mut recorded: Vec<Outcome> = Vec::new();

    // ---- (a) Continuous batching vs sequential on one device. ----
    let mix16 = ServeParams::default().with_workload(16, 42).with_at_once(true);
    let seq = run(mix16.clone());
    let bat = run(mix16.clone().with_engine(EngineKind::Batch));
    for o in [&seq, &bat] {
        print!("{}", sink::render_text(o));
        println!();
    }
    let (seq_tok, bat_tok) = (
        seq.metric_f64("throughput").unwrap(),
        bat.metric_f64("throughput").unwrap(),
    );
    println!(
        "batching gain: {:.2}x | kv peak util {:.1}% | max batch {} | decode steps {}\n",
        bat_tok / seq_tok,
        bat.metric_f64("kv_peak_utilization").unwrap() * 100.0,
        bat.metric_f64("max_batch_seen").unwrap(),
        bat.metric_f64("decode_steps").unwrap()
    );
    assert_eq!(
        seq.metric_f64("total_tokens"),
        bat.metric_f64("total_tokens"),
        "token conservation"
    );
    assert!(
        bat_tok > seq_tok,
        "continuous batching must beat sequential FCFS"
    );
    recorded.push(seq);
    recorded.push(bat);

    // ---- (b) Cluster scaling at saturating load. ----
    let mut base = 0.0;
    let mut last = 0.0;
    for devices in [1usize, 2, 4] {
        let outcome = run(
            ServeParams::default()
                .with_engine(EngineKind::Cluster)
                .with_workload(64, 7)
                .with_cluster(devices, 8)
                .with_at_once(true),
        );
        let tok = outcome.metric_f64("throughput").unwrap();
        if devices == 1 {
            base = tok;
        }
        last = tok;
        println!(
            "cluster {} device(s): {:.1} tok/s ({:.2}x)",
            devices,
            tok,
            tok / base
        );
        recorded.push(outcome);
    }
    let scaling = last / base;
    println!();
    assert!(
        scaling >= 2.5,
        "4-device scaling {scaling:.2}× < 2.5× at saturating load"
    );

    // ---- (c) Latency vs offered load (Poisson, 4-device cluster). ----
    let sweep = run(
        ServeParams::default()
            .with_workload(64, 42)
            .with_cluster(4, 8)
            .with_sweep(vec![50.0, 200.0, 1000.0]),
    );
    print!("{}", sink::render_text(&sweep));
    println!();
    let p95 = sweep.column_f64("p95_latency");
    assert!(
        p95.last().unwrap() >= p95.first().unwrap(),
        "saturation must not *improve* tail latency: {p95:?}"
    );
    recorded.push(sweep);

    // ---- (d) Execution backends on the shared mix (batch 8, t=0). ----
    let mut spans: Vec<(BackendKind, f64)> = Vec::new();
    for (kind, chunk) in [
        (BackendKind::SalPim, None),
        (BackendKind::Gpu, None),
        (BackendKind::BankLevel, None),
        (BackendKind::Hetero, None),
        (BackendKind::Hetero, Some(32usize)),
    ] {
        let outcome = run(
            ServeParams::default()
                .with_engine(EngineKind::Batch)
                .with_workload(16, 42)
                .with_at_once(true)
                .with_backend(kind)
                .with_prefill_chunk(chunk),
        );
        println!(
            "backend {:>9} prefill {:>8}: {:>7.1} tok/s | makespan {:.3} s | p95 TTFT {:.3} s",
            kind.name(),
            match chunk {
                Some(c) => format!("chunk {c}"),
                None => "inline".to_string(),
            },
            outcome.metric_f64("throughput").unwrap(),
            outcome.metric_f64("makespan").unwrap(),
            outcome.metric_f64("p95_ttft").unwrap()
        );
        if chunk.is_none() {
            spans.push((kind, outcome.metric_f64("makespan").unwrap()));
        }
        recorded.push(outcome);
    }
    let span = |k: BackendKind| {
        spans
            .iter()
            .find(|(kind, _)| *kind == k)
            .map(|(_, s)| *s)
            .expect("backend measured")
    };
    println!(
        "makespan speedup vs GPU backend: sal-pim {:.2}x | hetero {:.2}x",
        span(BackendKind::Gpu) / span(BackendKind::SalPim),
        span(BackendKind::Gpu) / span(BackendKind::Hetero)
    );
    assert!(
        span(BackendKind::SalPim) < span(BackendKind::Gpu),
        "PIM decode must beat the GPU roofline on the decode-bound mix"
    );

    // ---- (e) Paged vs whole-window KV at equal capacity, overload. ----
    // A KV region two orders of magnitude below the device's (64
    // subarrays ≈ a handful of whole windows) under a saturating
    // open-loop rate: whole-window reservation caps the decode batch at
    // the windows that fit, the paged allocator admits by resident
    // tokens instead.
    let mut kv_outcomes: Vec<(KvPolicy, Outcome)> = Vec::new();
    for policy in [KvPolicy::Whole, KvPolicy::Paged] {
        let outcome = run(
            ServeParams::default()
                .with_engine(EngineKind::Cluster)
                .with_workload(48, 23)
                .with_cluster(2, 16)
                .with_kv_policy(policy)
                .with_kv_units(Some(64))
                .with_rate(Some(2000.0), None),
        );
        println!(
            "kv {:>5}: {:>7.1} tok/s | mean batch {:>5.2} | preempt {} (recompute {} tok) | reuse {} ({} tok)",
            policy.name(),
            outcome.metric_f64("throughput").unwrap(),
            outcome.metric_f64("mean_decode_batch").unwrap(),
            outcome.metric_f64("preemptions").unwrap(),
            outcome.metric_f64("recompute_tokens").unwrap(),
            outcome.metric_f64("reuse_hits").unwrap(),
            outcome.metric_f64("reuse_tokens").unwrap(),
        );
        kv_outcomes.push((policy, outcome));
    }
    let metric = |p: KvPolicy, name: &str| {
        kv_outcomes
            .iter()
            .find(|(k, _)| *k == p)
            .and_then(|(_, o)| o.metric_f64(name))
            .expect("kv policy measured")
    };
    assert_eq!(
        metric(KvPolicy::Whole, "total_tokens"),
        metric(KvPolicy::Paged, "total_tokens"),
        "token conservation across KV policies"
    );
    assert!(
        metric(KvPolicy::Paged, "mean_decode_batch")
            > metric(KvPolicy::Whole, "mean_decode_batch"),
        "paged mean decode batch {} !> whole {} at equal HBM capacity",
        metric(KvPolicy::Paged, "mean_decode_batch"),
        metric(KvPolicy::Whole, "mean_decode_batch")
    );
    println!();
    recorded.extend(kv_outcomes.into_iter().map(|(_, o)| o));

    // ---- Record the whole trajectory. ----
    let refs: Vec<(&str, &Outcome)> = recorded.iter().map(|o| (runner_tag, o)).collect();
    let paths = sink::write_bench_files(Path::new("."), &refs).expect("write BENCH_serve.json");
    for p in &paths {
        println!("wrote {}", p.display());
    }
    println!("serve cluster bench OK");
}
