//! §5.4 ablation — subarray-level parallelism matters more for larger
//! models ("the latest transformer-decoder-based generative model has a
//! longer vector length of up to 12,288. Therefore, acceleration through
//! subarray-level parallelism is required for a higher performance
//! increase for the large-size model").
//!
//! Sweeps GPT-2 medium → XL → a GPT-3-like d=12288 layer shape and
//! measures the P_Sub=4 / P_Sub=1 decode speedup.

use sal_pim::config::{ModelConfig, SimConfig};
use sal_pim::mapper::GenerationSim;
use sal_pim::report::{fmt_time, fmt_x, Table};

fn gpt3_like() -> ModelConfig {
    ModelConfig {
        name: "gpt3-like-layer".to_string(),
        d_model: 12288,
        n_layers: 4, // a slice of the 96-layer model (timing per layer scales linearly)
        n_heads: 96,
        d_ff: 49152,
        vocab: 50257,
        max_seq: 2048,
        param_bytes: 2,
    }
}

fn main() {
    let models = [
        ModelConfig::gpt2_medium(),
        ModelConfig::gpt2_xl(),
        gpt3_like(),
    ];
    let mut t = Table::new(
        "§5.4 ablation — P_Sub benefit by model scale (decode @ kv=128)",
        &["model", "d_model", "P_Sub=1", "P_Sub=4", "speedup"],
    );
    let mut speedups = Vec::new();
    for m in &models {
        let t1 = {
            let cfg = SimConfig::paper().with_p_sub(1).with_model(m.clone());
            GenerationSim::new(&cfg).decode_token(128)
        };
        let t4 = {
            let cfg = SimConfig::paper().with_model(m.clone());
            GenerationSim::new(&cfg).decode_token(128)
        };
        let s = t1.cycles as f64 / t4.cycles as f64;
        speedups.push(s);
        t.row(&[
            m.name.clone(),
            m.d_model.to_string(),
            fmt_time(t1.seconds(1.0)),
            fmt_time(t4.seconds(1.0)),
            fmt_x(s),
        ]);
    }
    t.print();
    assert!(
        speedups.windows(2).all(|w| w[1] > w[0]),
        "P_Sub benefit must grow with model size: {speedups:?}"
    );
    println!(
        "P_Sub=4 benefit grows {} → {} with model scale — the §5.4 claim.",
        fmt_x(speedups[0]),
        fmt_x(*speedups.last().unwrap())
    );
    println!("ablation_model_scale OK");
}
