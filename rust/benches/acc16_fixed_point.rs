//! §4.1 — 16-bit fixed-point accuracy (paper: ≈2.8 % accuracy drop on
//! lambada with GPT-2 medium). Our proxy: top-1 next-token agreement
//! between the bit-exact fixed-point pipeline (LUT nonlinears, Q8.8
//! MACs) and the float model on a synthetic last-token-prediction set —
//! the disagreement rate plays the accuracy-drop role.

use sal_pim::config::SimConfig;
use sal_pim::model::functional::top1_agreement;
use sal_pim::report::Table;

fn main() {
    let cfg = SimConfig::mini();
    // 12 prompts × 8 tokens of deterministic synthetic "text".
    let prompts: Vec<Vec<usize>> = (0..12)
        .map(|i| (0..8).map(|j| (i * 53 + j * 17 + 3) % 256).collect())
        .collect();
    let agreement = top1_agreement(&cfg, &prompts);
    let drop = (1.0 - agreement) * 100.0;

    let mut t = Table::new(
        "§4.1 — 16-bit fixed-point accuracy proxy",
        &["metric", "measured", "paper"],
    );
    t.row(&[
        "top-1 agreement".into(),
        format!("{:.1}%", agreement * 100.0),
        "—".into(),
    ]);
    t.row(&[
        "accuracy drop".into(),
        format!("{drop:.1}%"),
        "≈2.8% (lambada)".into(),
    ]);
    t.print();

    assert!(
        agreement > 0.85,
        "fixed-point pipeline diverges too much: {agreement}"
    );
    println!("acc16 OK (drop {drop:.1}% — same ballpark as the paper's 2.8%)");
}
