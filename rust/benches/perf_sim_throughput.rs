//! §Perf — simulator performance and the scheduling optimizations.
//!
//! Measures (a) wall-clock simulation throughput (simulated cycles per
//! host second) and (b) the effect of the SALP row-prefetch optimization
//! on simulated time — before/after numbers recorded in EXPERIMENTS.md
//! §Perf.

use sal_pim::config::SimConfig;
use sal_pim::mapper::GenerationSim;
use sal_pim::report::{fmt_time, fmt_x, Table};
use std::time::Instant;

fn main() {
    let cfg = SimConfig::paper();

    // (a) Simulator wall-clock throughput on a fresh decode iteration.
    let wall = Instant::now();
    let mut sim = GenerationSim::new(&cfg);
    let st = sim.decode_token(128);
    let host = wall.elapsed().as_secs_f64();
    println!(
        "simulated {} cycles in {} → {:.1} Msim-cycles/s (one decode iteration)",
        st.cycles,
        fmt_time(host),
        st.cycles as f64 / host / 1e6
    );

    // Cached-path throughput over a full generation sweep.
    let wall = Instant::now();
    let r = sim.generate(32, 256);
    let host = wall.elapsed().as_secs_f64();
    println!(
        "full generation (in=32,out=256): {} simulated in {} host time",
        fmt_time(r.seconds(cfg.timing.tck_ns)),
        fmt_time(host)
    );

    // (b) Conservative vs prefetch scheduling (the §Perf L3 knob).
    let mut t = Table::new(
        "§Perf — SALP row-prefetch scheduling",
        &["schedule", "decode @kv=128", "generation(32,64)"],
    );
    let mut times = Vec::new();
    for (name, prefetch) in [("conservative", false), ("prefetch", true)] {
        let mut s = GenerationSim::new(&cfg);
        s.set_prefetch(prefetch);
        let d = s.decode_token(128).seconds(cfg.timing.tck_ns);
        let g = s.generate(32, 64).seconds(cfg.timing.tck_ns);
        times.push((d, g));
        t.row(&[name.into(), fmt_time(d), fmt_time(g)]);
    }
    t.print();
    let gain = times[0].1 / times[1].1;
    println!("prefetch end-to-end gain: {}", fmt_x(gain));
    assert!(gain > 1.0, "prefetch must not slow the device down");
    println!("perf bench OK");
}
