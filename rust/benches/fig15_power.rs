//! Fig. 15 — power consumption by subarray-level parallelism over a
//! 32-token generation (paper: P_Sub ∈ {1,2} well under the 60 W HBM2
//! budget; P_Sub=4 exceeds it — by 24 % in the paper; our simulator's
//! higher achieved bandwidth pushes it somewhat further).
//!
//! Runs `Scenario::Power` through the scenario `Runner` (the same path
//! as `sal-pim power`), asserts the budget claims on the structured
//! outcome, and records it to `BENCH_fig15.json`.

use sal_pim::scenario::{sink, PowerParams, Runner, Scenario};
use std::path::Path;

fn main() {
    let scenario = Scenario::Power(PowerParams::default());
    let outcome = Runner::new().run(&scenario).expect("power scenario runs");

    print!("{}", sink::render_text(&outcome));

    let fracs = outcome.column_f64("budget_fraction");
    assert_eq!(fracs.len(), 3, "P_Sub ∈ {{1,2,4}} rows");
    println!(
        "paper: P_Sub=4 exceeds the 60 W budget by 24% | measured: {:.0}% over",
        (fracs[2] - 1.0) * 100.0
    );
    assert!(fracs[0] < 1.0, "P_Sub=1 must stay in budget: {}", fracs[0]);
    assert!(fracs[2] > 1.0, "P_Sub=4 must exceed budget: {}", fracs[2]);
    assert!(fracs[0] < fracs[1] && fracs[1] < fracs[2]);

    let path = sink::write_bench_file(Path::new("."), scenario.bench_tag(), &[&outcome])
        .expect("write BENCH_fig15.json");
    println!("wrote {}", path.display());
    println!("fig15 OK");
}
