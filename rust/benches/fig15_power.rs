//! Fig. 15 — power consumption by subarray-level parallelism over a
//! 32-token generation (paper: P_Sub ∈ {1,2} well under the 60 W HBM2
//! budget; P_Sub=4 exceeds it — by 24 % in the paper; our simulator's
//! higher achieved bandwidth pushes it somewhat further).

use sal_pim::config::SimConfig;
use sal_pim::energy::{EnergyParams, PowerReport};
use sal_pim::mapper::GenerationSim;
use sal_pim::report::Table;

fn main() {
    let params = EnergyParams::paper();
    let mut t = Table::new(
        "Fig. 15 — power by P_Sub (32-token generation, GPT-2 medium)",
        &["P_Sub", "ACT W", "move W", "logic W", "refresh W", "total W", "vs budget"],
    );
    let mut fracs = Vec::new();
    for &p in &[1usize, 2, 4] {
        let cfg = SimConfig::paper().with_p_sub(p);
        let mut sim = GenerationSim::new(&cfg);
        let r = sim.generate(32, 32);
        let rep = PowerReport::from_stats(&cfg, &params, &r.total());
        let s = rep.seconds;
        fracs.push(rep.budget_fraction());
        t.row(&[
            p.to_string(),
            format!("{:.1}", rep.act_j / s),
            format!("{:.1}", rep.movement_j / s),
            format!("{:.1}", rep.logic_j / s),
            format!("{:.1}", rep.refresh_j / s),
            format!("{:.1}", rep.avg_power_w()),
            format!("{:.0}%", rep.budget_fraction() * 100.0),
        ]);
    }
    t.print();

    println!(
        "paper: P_Sub=4 exceeds the 60 W budget by 24% | measured: {:.0}% over",
        (fracs[2] - 1.0) * 100.0
    );
    assert!(fracs[0] < 1.0, "P_Sub=1 must stay in budget: {}", fracs[0]);
    assert!(fracs[2] > 1.0, "P_Sub=4 must exceed budget: {}", fracs[2]);
    assert!(fracs[0] < fracs[1] && fracs[1] < fracs[2]);
    println!("fig15 OK");
}
