//! Fig. 3 — breakdown of GPU execution time for GPT-2 medium
//! (paper: MHA 50.26 %, FFN 29.36 %, non-linear 23.45 % of those
//! categories' sum — the attention path is small-kernel-bound at
//! batch 1).

use sal_pim::baseline::GpuModel;
use sal_pim::config::ModelConfig;
use sal_pim::report::Table;

fn main() {
    let gpu = GpuModel::titan_rtx();
    let m = ModelConfig::gpt2_medium();
    let b = gpu.decode_breakdown(&m, 700);
    let sum = b.mha + b.ffn + b.nonlinear;
    let rows = [
        ("MHA", b.mha / sum * 100.0, 50.26),
        ("FFN", b.ffn / sum * 100.0, 29.36),
        ("non-linear", b.nonlinear / sum * 100.0, 23.45),
    ];
    let mut t = Table::new(
        "Fig. 3 — GPU decode-time breakdown",
        &["phase", "measured %", "paper %"],
    );
    for (name, got, paper) in rows {
        t.row(&[
            name.to_string(),
            format!("{got:.2}"),
            format!("{paper:.2}"),
        ]);
        assert!(
            (got - paper).abs() < 10.0,
            "{name}: {got:.1}% vs paper {paper:.1}%"
        );
    }
    t.print();
    println!("fig03 OK (each phase within 10 points of the paper)");
}
