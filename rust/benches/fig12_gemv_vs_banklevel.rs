//! Fig. 12 — SAL-PIM GEMV speedup over bank-level PIM (Newton-style) by
//! vector size (paper: min 1.75× for small vectors, approaching the 4×
//! bandwidth gain for large ones; GPT-2 medium's d=1024 sits at the
//! small end).

use sal_pim::baseline::BankLevelPim;
use sal_pim::config::SimConfig;
use sal_pim::mapper::map_gemv;
use sal_pim::pim::PimEngine;
use sal_pim::report::{fmt_x, Table};
use sal_pim::stats::Phase;

fn main() {
    let cfg = SimConfig::paper();
    let bank = BankLevelPim::new(&cfg);
    let sizes = [1024usize, 2048, 4096, 8192, 16384];

    let mut t = Table::new(
        "Fig. 12 — GEMV speedup vs bank-level PIM",
        &["vector", "SAL-PIM cyc", "bank-level cyc", "speedup"],
    );
    let mut speedups = Vec::new();
    for &n in &sizes {
        let mut e = PimEngine::new(&cfg);
        let sal = e
            .execute(&map_gemv(&cfg, n, n, Phase::Ffn))
            .unwrap()
            .cycles;
        let bl = bank.gemv_cycles(n, n);
        let s = bl as f64 / sal as f64;
        speedups.push(s);
        t.row(&[
            n.to_string(),
            sal.to_string(),
            bl.to_string(),
            fmt_x(s),
        ]);
    }
    t.print();

    // Paper shape: speedup grows with vector size toward the 4×
    // bandwidth gain, smallest at the smallest vectors.
    assert!(
        speedups.windows(2).all(|w| w[1] >= w[0] * 0.98),
        "speedup must be (weakly) increasing: {speedups:?}"
    );
    assert!(speedups[0] > 1.2, "min speedup {}", speedups[0]);
    assert!(
        *speedups.last().unwrap() < 4.5,
        "cannot beat the 4× bandwidth gain: {}",
        speedups.last().unwrap()
    );
    println!(
        "measured: {} → {} | paper: 1.75× → ≈4×",
        fmt_x(speedups[0]),
        fmt_x(*speedups.last().unwrap())
    );
    println!("fig12 OK");
}
