//! Fig. 14 — execution time and average bandwidth by subarray-level
//! parallelism on text generation (paper: 2.11× speedup and ≈2× average
//! bandwidth going from P_Sub=1 to P_Sub=4).

use sal_pim::config::SimConfig;
use sal_pim::mapper::GenerationSim;
use sal_pim::report::{fmt_bw, fmt_time, fmt_x, Table};

fn main() {
    let (n_in, n_out) = (32usize, 64usize);
    let mut t = Table::new(
        "Fig. 14 — execution time & avg bandwidth by P_Sub (in=32, out=64)",
        &["P_Sub", "exec time", "avg bandwidth", "speedup vs P_Sub=1"],
    );
    let mut times = Vec::new();
    let mut bws = Vec::new();
    for &p in &[1usize, 2, 4] {
        let cfg = SimConfig::paper().with_p_sub(p);
        let mut sim = GenerationSim::new(&cfg);
        let r = sim.generate(n_in, n_out);
        let secs = r.seconds(cfg.timing.tck_ns);
        let bw = r.total().avg_internal_bandwidth(cfg.timing.tck_ns)
            * cfg.hbm.pseudo_channels() as f64;
        times.push(secs);
        bws.push(bw);
        t.row(&[
            p.to_string(),
            fmt_time(secs),
            fmt_bw(bw),
            fmt_x(times[0] / secs),
        ]);
    }
    t.print();

    let speedup = times[0] / times[2];
    let bw_ratio = bws[2] / bws[0];
    println!("P_Sub 1→4: speedup {} (paper 2.11×), bandwidth {} (paper ≈2×)",
        fmt_x(speedup), fmt_x(bw_ratio));
    assert!(speedup > 1.7 && speedup < 3.2, "speedup {speedup}");
    assert!(bw_ratio > 1.7 && bw_ratio < 3.5, "bw ratio {bw_ratio}");
    // Monotone scaling.
    assert!(times[0] > times[1] && times[1] > times[2]);
    println!("fig14 OK");
}
