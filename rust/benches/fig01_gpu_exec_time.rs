//! Fig. 1 — GPU execution time for GPT-2 medium text generation by
//! input and output size.
//!
//! Paper shape: total time grows linearly with output size; input size
//! has little impact (the GPU batches input tokens efficiently).

use sal_pim::baseline::GpuModel;
use sal_pim::config::ModelConfig;
use sal_pim::report::{fmt_time, Table};

fn main() {
    let gpu = GpuModel::titan_rtx();
    let m = ModelConfig::gpt2_medium();
    let mut t = Table::new(
        "Fig. 1 — GPU (Titan RTX + FasterTransformer model) execution time",
        &["in\\out", "1", "16", "64", "128", "256"],
    );
    for &n_in in &[32usize, 64, 128] {
        let mut row = vec![n_in.to_string()];
        for &n_out in &[1usize, 16, 64, 128, 256] {
            row.push(fmt_time(gpu.generation_time(&m, n_in, n_out)));
        }
        t.row(&row);
    }
    t.print();

    // Shape checks mirrored from the paper's description of Fig. 1.
    let out_ratio =
        gpu.generation_time(&m, 32, 256) / gpu.generation_time(&m, 32, 64);
    let in_ratio =
        gpu.generation_time(&m, 128, 64) / gpu.generation_time(&m, 32, 64);
    println!("output 64→256 scaling: {out_ratio:.2}× (paper: ~linear, ≈4×)");
    println!("input 32→128 scaling:  {in_ratio:.2}× (paper: 'little impact')");
    assert!(out_ratio > 3.0 && out_ratio < 5.0);
    assert!(in_ratio < 1.3);
    println!("fig01 OK");
}
