//! Fig. 4 / §2.3 — LUT-based linear-interpolation accuracy by section
//! count (paper: accuracy is kept once sections > 32).

use sal_pim::interp::{accuracy_report, min_sections_for, NonLinFn};
use sal_pim::model::fixedpoint::Q8_8;
use sal_pim::report::Table;

fn main() {
    let sections = [8usize, 16, 32, 64, 128, 256];
    let rows = accuracy_report(&sections, Q8_8, Q8_8);
    let mut t = Table::new(
        "Fig. 4 — interpolation max abs error (rel. for rsqrt/recip)",
        &["function", "8", "16", "32", "64", "128", "256"],
    );
    for f in NonLinFn::ALL {
        let mut row = vec![f.name().to_string()];
        for &s in &sections {
            let r = rows
                .iter()
                .find(|r| r.func == f && r.sections == s)
                .unwrap();
            row.push(format!("{:.4}", r.max_err));
        }
        t.row(&row);
    }
    t.print();

    // The paper's claim: ≥32 sections keep task accuracy. Our criterion:
    // every function's error at 32+ sections is within a few 16-bit
    // quantization steps.
    for f in NonLinFn::ALL {
        let r32 = rows.iter().find(|r| r.func == f && r.sections == 32).unwrap();
        assert!(r32.max_err < 0.09, "{f:?} at 32 sections: {}", r32.max_err);
        let min = min_sections_for(f, 0.09, 256, Q8_8, Q8_8).unwrap();
        println!("{:>6}: ≤0.09 error from {min} sections", f.name());
        assert!(min <= 32);
    }
    println!("fig04 OK (paper: no accuracy drop at >32 sections)");
}
