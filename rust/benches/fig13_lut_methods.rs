//! Fig. 13 — LUT-operation execution time: LUT-embedded subarray vs the
//! two fallback methods (Scan = read the whole table per register-full;
//! Select = per-element decode+fetch). Paper: 3.57× over the best
//! alternative at vector size 16,384; Scan is the worst.

use sal_pim::config::SimConfig;
use sal_pim::pim::{LutMethod, MacroOp, PimEngine};
use sal_pim::report::{fmt_x, Table};
use sal_pim::stats::Phase;

fn run(cfg: &SimConfig, n_elems: usize, method: LutMethod) -> u64 {
    let per_bank = n_elems.div_ceil(cfg.parallelism.p_ba) as u64;
    let mut e = PimEngine::new(cfg);
    e.execute(&[MacroOp::LutSweep {
        elems_per_bank: per_bank,
        method,
        sections: cfg.lut.sections,
        phase: Phase::NonLinear,
    }])
    .unwrap()
    .cycles
}

fn main() {
    let cfg = SimConfig::paper();
    let sizes = [1024usize, 4096, 16384];
    let mut t = Table::new(
        "Fig. 13 — LUT operation execution time (cycles)",
        &["vector", "LUT-embedded", "Select", "Scan", "best-alt / embedded"],
    );
    let mut last_ratio = 0.0;
    for &n in &sizes {
        let emb = run(&cfg, n, LutMethod::Embedded);
        let sel = run(&cfg, n, LutMethod::Select);
        let scan = run(&cfg, n, LutMethod::Scan);
        assert!(emb < sel && sel < scan, "ranking broken at n={n}");
        let ratio = sel.min(scan) as f64 / emb as f64;
        last_ratio = ratio;
        t.row(&[
            n.to_string(),
            emb.to_string(),
            sel.to_string(),
            scan.to_string(),
            fmt_x(ratio),
        ]);
    }
    t.print();
    println!(
        "measured speedup at 16,384: {} | paper: 3.57× (same ranking, our\n\
         Select pays two serialized LUT fetches per element so the gap is larger)",
        fmt_x(last_ratio)
    );
    assert!(last_ratio > 3.0, "embedded must win by >3× at 16k");
    println!("fig13 OK");
}
