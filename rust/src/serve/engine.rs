//! Continuous-batching serving engine for one simulated device.
//!
//! The sequential [`crate::coordinator::Coordinator`] runs each request to
//! completion before touching the next. This engine instead keeps a batch
//! of in-flight generations and walks simulated time event by event:
//!
//! * at every token boundary, waiting requests (policy-ordered) are
//!   admitted while a batch slot **and** a KV reservation are available;
//! * one batched decode step then produces one token for every active
//!   request, charged via [`ExecutionBackend::decode_step_s`] — on
//!   SAL-PIM the shared weight stream is paid once per step and the
//!   per-request KV/attention work accumulates, which is exactly why
//!   batching wins on a weight-streaming PIM;
//! * completions release their KV lease, freeing admission slots.
//!
//! The engine is generic over [`ExecutionBackend`], so the same
//! scheduler serves SAL-PIM, the GPU roofline, bank-level PIM, or a
//! heterogeneous GPU-prefill + PIM-decode device — the backend only
//! answers "how long does this prefill / batched step take" and "how
//! much KV fits".
//!
//! **Prefill scheduling.** By default a request's whole summarization is
//! charged inline at admission, stalling the decode batch (the legacy
//! behaviour). With [`DeviceEngine::with_prefill_chunk`] the prefill is
//! split into token chunks interleaved at token boundaries: every
//! still-prefilling request advances one chunk per boundary, then the
//! decode step runs over the requests already generating. Chunk `i`
//! covering tokens `[a, b)` is charged `prefill_s(b) − prefill_s(a)`,
//! which telescopes to the unchunked total — chunking reorders time, it
//! never changes the simulated token count. A completion's `prefill_s`
//! is the wall-clock span from admission to its first token (identical
//! to the service time when unchunked).
//!
//! **KV policy.** Under the historical whole-window policy
//! ([`KvPolicy::Whole`]) admission reserves the full prompt + output
//! window, so requests never interact once admitted. Under the paged
//! policy ([`KvPolicy::Paged`]) admission reserves only the prompt (plus
//! the first token) and the lease grows block-by-block at token
//! boundaries; when the pool runs dry and `--evict lru` is in force, the
//! engine preempts the *youngest* active decoding request — the one that
//! wastes the least recompute work; its idle session blocks were already
//! evicted LRU-first by the allocator — drops its blocks, and parks it on
//! a readmit queue. On readmission the preempted request's KV (prompt +
//! tokens generated so far) is *recomputed* through the backend's prefill
//! model, so simulated time stays conserved: preemption trades block
//! capacity for recompute time, it never teleports work. Generated-token
//! counts are untouched by preemption — `tokens_simulated` is bit-for-bit
//! identical with and without it. Completed paged requests park their
//! blocks as *session residency*, so a session-affinity-routed follow-up
//! request skips re-prefilling the shared prefix (a reuse hit).
//!
//! **Run-loop cores.** [`DeviceEngine::run`] dispatches on
//! [`EngineCore`]: the default *event* core schedules the boundary from
//! a completion min-heap (keyed by the earliest decode step a request
//! can finish at) plus memoized admission/readmission state and a
//! seq → batch-slot index, so a boundary with nothing to retire or
//! admit costs O(log n) instead of walking every request; the *legacy*
//! core is the historical O(n)-scan loop, kept as a transition escape
//! hatch and as the reference the `engine_equivalence` property suite
//! compares against. Both cores execute identical float operations in
//! an identical order, so completions, reports and trace streams are
//! bit-for-bit equal.
//!
//! Requests whose KV window can never fit the device are rejected rather
//! than wedging the queue.

use super::backend::{DeviceCapacity, ExecutionBackend, SalPimBackend};
use super::fabric::{Fabric, FabricParams, SharedFabric};
use super::kv_cache::{EvictPolicy, KvPolicy, KvPool, PoolLease, PrefixCacheMode};
use super::metrics::ServeMetrics;
use super::policy::Policy;
use super::types::{Completion, Request, SloClass};
use crate::config::SimConfig;
use crate::trace::{PhaseProfile, TraceEventKind, TraceHandle};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};
use std::time::Instant;

/// A request currently holding a batch slot.
struct ActiveReq {
    req: Request,
    /// Clock when the request left the queue (prefill start).
    admit_s: f64,
    /// Prompt tokens already summarized (== prompt_len once decoding).
    /// Starts at the session-reused prefix under the paged policy.
    prefill_done: usize,
    /// Clock when the request entered the decode batch.
    decode_start_s: f64,
    /// Tokens produced so far (the completed prefill emits the first).
    produced: usize,
    lease: PoolLease,
    /// Admission sequence number — preemption victims are the youngest.
    seq: u64,
    /// A freshly readmitted request is shielded from being preempted
    /// again until it has produced at least one token past its
    /// recompute: without this, a tight pool can cycle
    /// readmit → full recompute charge → immediate re-preemption,
    /// inflating the clock with zero progress.
    shielded: bool,
}

impl ActiveReq {
    /// Still in the (chunked) summarization stage.
    fn prefilling(&self) -> bool {
        self.prefill_done < self.req.prompt_len
    }

    /// KV length the next decode step runs at.
    fn next_kv(&self) -> usize {
        self.req.prompt_len + self.produced
    }

    fn finished(&self, max_seq: usize) -> bool {
        !self.prefilling()
            && (self.produced >= self.req.max_new_tokens || self.next_kv() >= max_seq)
    }

    /// Participates in the next batched decode step.
    fn decoding(&self, max_seq: usize) -> bool {
        !self.prefilling() && !self.finished(max_seq)
    }
}

/// Earliest global decode step at which `a` can satisfy
/// [`ActiveReq::finished`]. `produced` advances at most one per decode
/// step, so a request is never finished before its due step — the
/// event core's completion heap pops exactly on time (a block-stalled
/// request pops early and is re-armed at the corrected step).
fn due_step(decode_steps: u64, a: &ActiveReq, max_seq: usize) -> u64 {
    let target = a
        .req
        .max_new_tokens
        .min(max_seq.saturating_sub(a.req.prompt_len));
    decode_steps + target.saturating_sub(a.produced) as u64
}

/// Incremental cost of summarizing prompt tokens `[from, to)` on a
/// backend — the chunked-prefill charging rule every scheduler shares.
/// Monotone `prefill_s` makes the chunks telescope to the unchunked
/// total; the phase router ([`crate::serve::sched`]) prices its chunks
/// through this same function so static and dynamic runs charge
/// prefill identically.
pub(crate) fn prefill_increment_s(
    backend: &mut dyn ExecutionBackend,
    from: usize,
    to: usize,
) -> f64 {
    if from == 0 {
        backend.prefill_s(to)
    } else {
        (backend.prefill_s(to) - backend.prefill_s(from)).max(0.0)
    }
}

/// Push onto the active set, keeping the event core's seq → slot index
/// coherent (`fast` = event core; the legacy core skips the index).
fn track_push(
    active: &mut Vec<ActiveReq>,
    slot_of: &mut HashMap<u64, usize>,
    fast: bool,
    a: ActiveReq,
) {
    if fast {
        slot_of.insert(a.seq, active.len());
    }
    active.push(a);
}

/// `swap_remove` from the active set, keeping the seq → slot index
/// coherent: the displaced tail element (if any) moves into slot `i`.
fn track_swap_remove(
    active: &mut Vec<ActiveReq>,
    slot_of: &mut HashMap<u64, usize>,
    fast: bool,
    i: usize,
) -> ActiveReq {
    let a = active.swap_remove(i);
    if fast {
        slot_of.remove(&a.seq);
        if let Some(moved) = active.get(i) {
            slot_of.insert(moved.seq, i);
        }
    }
    a
}

/// A preempted request waiting to re-enter the batch. Its latency
/// anchors survive preemption so the completion's queue/prefill/decode
/// partition still tiles `[arrival, finish]` exactly.
struct Preempted {
    req: Request,
    admit_s: f64,
    decode_start_s: f64,
    produced: usize,
}

/// Post-run accounting beyond the per-request completions.
#[derive(Debug, Clone)]
pub struct EngineReport {
    /// Requests whose KV window can never fit the device.
    pub rejected: usize,
    /// High-water KV-region utilization.
    pub kv_peak_utilization: f64,
    /// Largest decode batch observed.
    pub max_batch_seen: usize,
    /// Batched decode steps executed.
    pub decode_steps: u64,
    /// Mean decode-batch size over all steps (the amortization lever).
    pub mean_decode_batch: f64,
    /// Active requests preempted under paged KV pressure.
    pub preemptions: usize,
    /// Tokens re-prefilled on readmission after preemption.
    pub recompute_tokens: usize,
    /// Admissions that reclaimed a session-resident KV prefix.
    pub reuse_hits: usize,
    /// Prompt tokens whose prefill was skipped via session reuse.
    pub reuse_tokens: usize,
    /// Admissions that reused a radix prefix-tree chain (cross-session;
    /// 0 outside `--prefix-cache radix`).
    pub prefix_hits: usize,
    /// Prompt tokens whose prefill the radix tree skipped (disjoint
    /// from `reuse_tokens`, which counts session-residency reuse).
    pub prefix_reused_tokens: usize,
    /// Prefix-tree nodes evicted under pressure.
    pub prefix_nodes_evicted: usize,
    /// Preempted KV states spilled to the host buffer (`--evict swap`).
    pub swap_outs: usize,
    /// Readmissions that restored KV from the host buffer instead of
    /// recomputing it (the fabric read was cheaper).
    pub swap_ins: usize,
    /// Bytes moved over the fabric by swap-outs plus swap-ins.
    pub swapped_bytes: u64,
    /// Wall-clock self-profile of the engine's run loop (always on).
    pub profile: PhaseProfile,
    /// True when a wall-clock deadline stopped the run early.
    pub truncated: bool,
}

/// Which implementation [`DeviceEngine::run`] uses to advance simulated
/// time (`--engine-core`).
///
/// Both cores execute the same token-boundary sequence — identical
/// float operations in an identical order — so completions, reports and
/// trace streams are **bit-for-bit identical** (pinned by the
/// `engine_equivalence` property suite). The event core replaces the
/// legacy per-boundary O(n) scans with an indexed discrete-event
/// schedule: a completion min-heap keyed by the earliest decode step a
/// request can finish at, memoized admission/readmission while the pool
/// provably cannot accept (the failed probes are side-effect-free), a
/// seq → batch-slot index for the growth loop, and a skipped growth
/// phase for whole-window pools.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineCore {
    /// Discrete-event scheduling (the default).
    #[default]
    Event,
    /// The historical token-boundary scan loop — a transition escape
    /// hatch, and the reference the equivalence tests compare against.
    Legacy,
}

impl EngineCore {
    /// Parse a `--engine-core` / suite-file token.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "event" => Some(EngineCore::Event),
            "legacy" => Some(EngineCore::Legacy),
            _ => None,
        }
    }

    /// Canonical CLI / suite-file token.
    pub fn name(&self) -> &'static str {
        match self {
            EngineCore::Event => "event",
            EngineCore::Legacy => "legacy",
        }
    }
}

/// One device running continuous batching over an [`ExecutionBackend`].
pub struct DeviceEngine {
    backend: Box<dyn ExecutionBackend>,
    capacity: DeviceCapacity,
    kv: KvPool,
    pub policy: Policy,
    /// Batch slots (concurrent generations the command scheduler
    /// interleaves across subarray groups).
    pub max_batch: usize,
    /// Index reported in completions (set by the cluster).
    pub device_index: usize,
    /// Prefill chunk size in tokens; `None` charges whole prefills
    /// inline at admission (the legacy decode-stalling behaviour).
    pub prefill_chunk: Option<usize>,
    /// Run-loop core [`DeviceEngine::run`] executes (`--engine-core`;
    /// the cluster assigns it fleet-wide).
    pub core: EngineCore,
    kv_policy: KvPolicy,
    evict: EvictPolicy,
    prefix_cache: PrefixCacheMode,
    kv_block: Option<usize>,
    kv_units: Option<usize>,
    pending: Vec<Request>,
    /// Running total of pending work in tokens, maintained by
    /// [`DeviceEngine::submit`] so least-loaded routing is O(1) instead
    /// of a queue scan per placement.
    queued_tokens: usize,
    /// Per-boundary scratch reused across steps (and runs) so the hot
    /// loop never allocates: stalled seqs, grow order, decode
    /// participants and their KV lengths.
    scratch_stalled: Vec<u64>,
    scratch_order: Vec<u64>,
    scratch_parts: Vec<usize>,
    scratch_kv_lens: Vec<usize>,
    clock_s: f64,
    rejected: Vec<Request>,
    readmit: VecDeque<Preempted>,
    max_batch_seen: usize,
    decode_steps: u64,
    decode_batch_sum: u64,
    preemptions: usize,
    recompute_tokens: usize,
    /// Host link for swap-to-host traffic (`--evict swap`) and the KV
    /// handoff of migrated requests. Shared with the cluster's fabric
    /// when set; a private default-PCIe link is created on first use
    /// otherwise.
    fabric: Option<SharedFabric>,
    /// Request id → tokens whose KV payload sits in the host buffer
    /// (spilled at preemption under `EvictPolicy::Swap`).
    swapped: HashMap<u64, usize>,
    /// Requests submitted via [`DeviceEngine::submit_prefilled`]: their
    /// prefill already ran elsewhere and their KV arrives by fabric
    /// migration, so admission charges no prefill.
    prefilled: HashSet<u64>,
    swap_outs: usize,
    swap_ins: usize,
    swapped_bytes: u64,
    /// Lifecycle-event sink; `None` (the default) records nothing.
    trace: Option<TraceHandle>,
    profile: PhaseProfile,
    /// Wall-clock deadline: the run loop stops cleanly (truncated) at
    /// the first token boundary past it.
    deadline: Option<Instant>,
    truncated: bool,
}

impl DeviceEngine {
    /// A SAL-PIM device (the historical constructor).
    pub fn new(cfg: &SimConfig, max_batch: usize) -> Self {
        Self::with_backend(Box::new(SalPimBackend::new(cfg)), max_batch)
    }

    /// A device over any execution backend.
    pub fn with_backend(backend: Box<dyn ExecutionBackend>, max_batch: usize) -> Self {
        assert!(max_batch >= 1);
        let capacity = backend.capacity();
        let kv_policy = KvPolicy::Whole;
        let evict = EvictPolicy::Lru;
        let prefix_cache = PrefixCacheMode::Session;
        DeviceEngine {
            backend,
            capacity,
            kv: KvPool::for_capacity(&capacity, kv_policy, evict, prefix_cache, None, None),
            policy: Policy::Fcfs,
            max_batch,
            device_index: 0,
            prefill_chunk: None,
            core: EngineCore::Event,
            kv_policy,
            evict,
            prefix_cache,
            kv_block: None,
            kv_units: None,
            pending: Vec::new(),
            queued_tokens: 0,
            scratch_stalled: Vec::new(),
            scratch_order: Vec::new(),
            scratch_parts: Vec::new(),
            scratch_kv_lens: Vec::new(),
            clock_s: 0.0,
            rejected: Vec::new(),
            readmit: VecDeque::new(),
            max_batch_seen: 0,
            decode_steps: 0,
            decode_batch_sum: 0,
            preemptions: 0,
            recompute_tokens: 0,
            fabric: None,
            swapped: HashMap::new(),
            prefilled: HashSet::new(),
            swap_outs: 0,
            swap_ins: 0,
            swapped_bytes: 0,
            trace: None,
            profile: PhaseProfile::default(),
            deadline: None,
            truncated: false,
        }
    }

    pub fn with_policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Select the run-loop core (`--engine-core`): [`EngineCore::Event`]
    /// is the default, [`EngineCore::Legacy`] the escape hatch.
    pub fn with_core(mut self, core: EngineCore) -> Self {
        self.core = core;
        self
    }

    fn rebuild_pool(&mut self) {
        self.kv = KvPool::for_capacity(
            &self.capacity,
            self.kv_policy,
            self.evict,
            self.prefix_cache,
            self.kv_block,
            self.kv_units,
        );
        if let Some(t) = &self.trace {
            self.kv.set_trace(t.clone());
        }
    }

    /// Attach a lifecycle-event sink; the paged KV pool shares it so
    /// eviction / reuse events land in the same stream.
    pub fn set_trace(&mut self, trace: TraceHandle) {
        self.kv.set_trace(trace.clone());
        self.trace = Some(trace);
    }

    /// Stop the run loop cleanly once this wall-clock deadline passes
    /// (the scenario layer's `budget_s`); the run is marked truncated.
    pub fn set_deadline(&mut self, deadline: Instant) {
        self.deadline = Some(deadline);
    }

    /// True when a deadline stopped a run early.
    pub fn truncated(&self) -> bool {
        self.truncated
    }

    /// Wall-clock self-profile accumulated by [`DeviceEngine::run`].
    pub fn profile(&self) -> PhaseProfile {
        self.profile
    }

    /// Switch the KV allocation discipline (`--kv-policy`).
    pub fn with_kv_policy(mut self, policy: KvPolicy) -> Self {
        self.kv_policy = policy;
        self.rebuild_pool();
        self
    }

    /// Set what the paged pool may reclaim under pressure (`--evict`).
    pub fn with_evict(mut self, evict: EvictPolicy) -> Self {
        self.evict = evict;
        self.rebuild_pool();
        self
    }

    /// Select the cross-session sharing discipline (`--prefix-cache`):
    /// [`PrefixCacheMode::Radix`] lets requests carrying a prefix path
    /// share tree-node-owned blocks across sessions.
    pub fn with_prefix_cache(mut self, mode: PrefixCacheMode) -> Self {
        self.prefix_cache = mode;
        self.rebuild_pool();
        self
    }

    /// Override the paged block size in tokens (`--kv-block`).
    pub fn with_kv_block(mut self, tokens: usize) -> Self {
        assert!(tokens >= 1, "a KV block holds at least one token");
        self.kv_block = Some(tokens);
        self.rebuild_pool();
        self
    }

    /// Shrink the KV region to `units` allocation units — subarrays on
    /// PIM (what-if experiments / admission pressure). Both KV policies
    /// see the same byte budget, so paged-vs-whole comparisons run at
    /// equal HBM capacity.
    pub fn with_kv_subarrays(mut self, units: usize) -> Self {
        self.kv_units = Some(units);
        self.rebuild_pool();
        self
    }

    /// Apply the full KV knob set in place (used by [`super::Cluster`]).
    pub(crate) fn apply_kv(
        &mut self,
        policy: KvPolicy,
        evict: EvictPolicy,
        prefix: PrefixCacheMode,
        block: Option<usize>,
        units: Option<usize>,
    ) {
        self.kv_policy = policy;
        self.evict = evict;
        self.prefix_cache = prefix;
        self.kv_block = block;
        if units.is_some() {
            self.kv_units = units;
        }
        self.rebuild_pool();
    }

    /// Interleave prefills in `chunk`-token pieces at token boundaries
    /// instead of stalling the decode batch; `None` restores the inline
    /// behaviour.
    pub fn with_prefill_chunk(mut self, chunk: Option<usize>) -> Self {
        if let Some(c) = chunk {
            assert!(c >= 1, "prefill chunk must be at least one token");
        }
        self.prefill_chunk = chunk;
        self
    }

    /// The backend's display name.
    pub fn backend_name(&self) -> String {
        self.backend.name()
    }

    /// The device's capacity card (KV geometry, max sequence).
    pub fn capacity(&self) -> DeviceCapacity {
        self.capacity
    }

    /// The KV allocation discipline in force.
    pub fn kv_policy(&self) -> KvPolicy {
        self.kv_policy
    }

    pub fn submit(&mut self, req: Request) {
        self.queued_tokens += req.kv_tokens();
        self.pending.push(req);
    }

    /// Submit a request whose prefill already ran elsewhere and whose
    /// KV arrives by fabric migration (disaggregated serving): admission
    /// allocates KV coverage for the migrated state but charges no
    /// prefill — the request enters the decode batch with its first
    /// token already produced by the prefill pool.
    pub fn submit_prefilled(&mut self, req: Request) {
        self.prefilled.insert(req.id);
        self.submit(req);
    }

    /// Attach a host link shared with other engines (the cluster's
    /// fabric), so swap-to-host traffic contends with KV migrations.
    pub fn set_fabric(&mut self, fabric: SharedFabric) {
        self.fabric = Some(fabric);
    }

    /// Attach a private host link with the given parameters.
    pub fn with_fabric(mut self, params: FabricParams) -> Self {
        self.fabric = Some(Fabric::shared(params));
        self
    }

    /// Charge a host-link transfer at the current clock, creating the
    /// default PCIe link on first use if none was attached.
    fn fabric_transfer(&mut self, bytes: usize) -> f64 {
        let fab = self
            .fabric
            .get_or_insert_with(|| Fabric::shared(FabricParams::pcie()));
        fab.borrow_mut().transfer(self.clock_s, bytes)
    }

    /// Cost of a host-link transfer at the current clock *without*
    /// committing it (the swap-vs-recompute probe).
    fn fabric_peek(&mut self, bytes: usize) -> f64 {
        let fab = self
            .fabric
            .get_or_insert_with(|| Fabric::shared(FabricParams::pcie()));
        let dt = fab.borrow().peek_transfer_s(self.clock_s, bytes);
        dt
    }

    /// Estimated outstanding work in tokens (for least-loaded routing).
    /// Maintained incrementally by [`DeviceEngine::submit`], so routing
    /// a request is O(1) instead of a pending-queue scan.
    pub fn queued_tokens(&self) -> usize {
        self.queued_tokens
    }

    /// Tokens of `session`'s KV currently parked for reuse on this
    /// device (0 under the whole-window policy).
    pub fn session_resident_tokens(&self, session: u64) -> usize {
        self.kv.session_resident_tokens(session)
    }

    /// Incremental cost of summarizing prompt tokens `[from, to)`.
    fn prefill_increment_s(&mut self, from: usize, to: usize) -> f64 {
        prefill_increment_s(self.backend.as_mut(), from, to)
    }

    /// Emit a trace event stamped at the current clock (no-op when
    /// untraced), keeping the shared handle's time in sync for nested
    /// emitters (the paged KV pool).
    fn temit(&self, kind: TraceEventKind) {
        if let Some(t) = &self.trace {
            t.set_time(self.clock_s);
            t.emit(kind);
        }
    }

    /// Sync the shared handle's sim-time stamp to the engine clock
    /// before calling into the KV pool (which emits at that stamp).
    fn tsync(&self) {
        if let Some(t) = &self.trace {
            t.set_time(self.clock_s);
        }
    }

    /// Attribute the KV-handoff share of a prefill charge (hetero
    /// backends only; the handoff is linear in tokens, so per-chunk
    /// shares are exact).
    fn temit_handoff(&self, id: u64, tokens: usize) {
        if tokens == 0 || self.trace.is_none() {
            return;
        }
        if let Some(dt) = self.backend.kv_handoff_s_for(tokens) {
            self.temit(TraceEventKind::KvHandoff {
                id,
                tokens,
                dt_s: dt,
            });
        }
    }

    /// Drain the queue with continuous batching; returns completions in
    /// finish order.
    ///
    /// Dispatches on [`EngineCore`]. Both cores run the *same* boundary
    /// sequence (arrivals → readmission → admission → chunked prefill →
    /// KV growth/preemption → batched decode → retirement) with
    /// identical float operations in an identical order; the event core
    /// (`fast`) additionally skips phases it can prove are no-ops —
    /// retirement via the completion heap, admission/readmission via
    /// the blocked memos, growth for whole-window pools — and resolves
    /// the growth loop's seq lookups through the slot index.
    pub fn run(&mut self) -> Vec<Completion> {
        let run_start = Instant::now();
        let fast = self.core == EngineCore::Event;
        let mut incoming = std::mem::take(&mut self.pending);
        self.queued_tokens = 0;
        incoming.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let mut incoming = incoming.into_iter().peekable();
        let mut waiting: Vec<Request> = Vec::new();
        let mut active: Vec<ActiveReq> = Vec::new();
        let mut completions: Vec<Completion> = Vec::new();
        let max_seq = self.capacity.max_seq;
        let mut admit_seq: u64 = 0;

        // Per-boundary scratch, reused across boundaries and runs (the
        // buffers live on the engine); taken into locals so `&mut self`
        // method calls stay legal inside the loop.
        let mut stalled = std::mem::take(&mut self.scratch_stalled);
        let mut order = std::mem::take(&mut self.scratch_order);
        let mut parts = std::mem::take(&mut self.scratch_parts);
        let mut kv_lens = std::mem::take(&mut self.scratch_kv_lens);

        // Event-core state. `slot_of` maps an admission seq to its slot
        // in `active` (coherent across every push / swap_remove);
        // `finish_heap` holds (earliest decode step the request can
        // finish at, seq), so the common nothing-retires boundary costs
        // one peek instead of an O(n) scan. The blocked memos record
        // that the last `try_admit` / `try_readmit` failed — both are
        // side-effect-free on failure and deterministic, so the phase
        // stays skippable until freed capacity (retire/preempt) or a
        // changed waiting set (arrival) invalidates the memo.
        let mut slot_of: HashMap<u64, usize> = HashMap::new();
        let mut finish_heap: BinaryHeap<Reverse<(u64, u64)>> = BinaryHeap::new();
        let mut admit_blocked = false;
        let mut readmit_blocked = false;
        // Live prefilling count: lets the event core skip the chunk
        // advance entirely while nothing is summarizing.
        let mut prefilling = 0usize;

        loop {
            // A wall-clock budget (scenario `budget_s`) stops the run
            // cleanly at a token boundary instead of hanging CI.
            if let Some(d) = self.deadline {
                if Instant::now() >= d {
                    self.truncated = true;
                    break;
                }
            }
            let t_arrive = Instant::now();
            // Pull everything that has arrived by the current clock.
            while let Some(r) = incoming.peek() {
                if r.arrival_s <= self.clock_s {
                    let r = incoming.next().unwrap();
                    if let Some(t) = &self.trace {
                        t.emit_at(
                            r.arrival_s,
                            TraceEventKind::Arrival {
                                id: r.id,
                                session: r.session,
                            },
                        );
                    }
                    waiting.push(r);
                    admit_blocked = false;
                } else {
                    break;
                }
            }
            // Idle device: jump to the next arrival or stop.
            if active.is_empty() && waiting.is_empty() && self.readmit.is_empty() {
                match incoming.next() {
                    Some(r) => {
                        self.clock_s = self.clock_s.max(r.arrival_s);
                        if let Some(t) = &self.trace {
                            t.emit_at(
                                r.arrival_s,
                                TraceEventKind::Arrival {
                                    id: r.id,
                                    session: r.session,
                                },
                            );
                        }
                        waiting.push(r);
                        admit_blocked = false;
                        self.profile.admission_s += t_arrive.elapsed().as_secs_f64();
                        continue;
                    }
                    None => {
                        self.profile.admission_s += t_arrive.elapsed().as_secs_f64();
                        break;
                    }
                }
            }
            self.profile.admission_s += t_arrive.elapsed().as_secs_f64();

            // Readmit preempted requests first (FIFO — the longest-waiting
            // victim re-enters first). The dropped KV (prompt + tokens
            // generated so far) is *recomputed* through the backend's
            // prefill model, so the preemption's cost is paid in simulated
            // time, not hand-waved away.
            let t_readmit = Instant::now();
            if !(fast && readmit_blocked) {
                while active.len() < self.max_batch {
                    let Some(front) = self.readmit.front() else {
                        break;
                    };
                    let rebuilt = front.req.prompt_len + front.produced;
                    self.tsync();
                    match self
                        .kv
                        .try_readmit(front.req.id, front.req.session, rebuilt + 1)
                    {
                        Some(lease) => {
                            let p = self.readmit.pop_front().unwrap();
                            // Restore the dropped KV: recompute it through
                            // the backend's prefill model, or — when the
                            // blocks were swapped to the host buffer — read
                            // them back over the fabric if that is cheaper.
                            // The decision compares the two cost signatures
                            // at this clock (fabric contention included);
                            // ties go to recompute, deterministically.
                            let recompute_dt = self.prefill_increment_s(0, rebuilt);
                            let swap = match self.swapped.get(&p.req.id).copied() {
                                Some(tokens) => {
                                    let bytes = tokens * self.capacity.kv_bytes_per_token;
                                    let dt = self.fabric_peek(bytes);
                                    (dt < recompute_dt).then_some((dt, bytes, tokens))
                                }
                                None => None,
                            };
                            self.swapped.remove(&p.req.id);
                            let (dt, recomputed) = match swap {
                                Some((_, bytes, tokens)) => {
                                    let dt = self.fabric_transfer(bytes);
                                    self.swap_ins += 1;
                                    self.swapped_bytes += bytes as u64;
                                    self.clock_s += dt;
                                    self.temit(TraceEventKind::SwapIn {
                                        id: p.req.id,
                                        tokens,
                                        dt_s: dt,
                                    });
                                    (dt, 0)
                                }
                                None => {
                                    self.clock_s += recompute_dt;
                                    self.recompute_tokens += rebuilt;
                                    (recompute_dt, rebuilt)
                                }
                            };
                            admit_seq += 1;
                            self.temit(TraceEventKind::Readmit {
                                id: p.req.id,
                                recompute_tokens: recomputed,
                                dt_s: dt,
                            });
                            if recomputed > 0 {
                                self.temit_handoff(p.req.id, rebuilt);
                            }
                            let a = ActiveReq {
                                prefill_done: p.req.prompt_len,
                                req: p.req,
                                admit_s: p.admit_s,
                                decode_start_s: p.decode_start_s,
                                produced: p.produced,
                                lease,
                                seq: admit_seq,
                                shielded: true,
                            };
                            if fast {
                                finish_heap.push(Reverse((
                                    due_step(self.decode_steps, &a, max_seq),
                                    a.seq,
                                )));
                            }
                            track_push(&mut active, &mut slot_of, fast, a);
                        }
                        // The FIFO front stays the front and the failed
                        // probe is pure: skippable until capacity frees.
                        None => {
                            readmit_blocked = true;
                            break;
                        }
                    }
                }
            }
            self.profile.readmit_s += t_readmit.elapsed().as_secs_f64();

            // Token-boundary admission: policy-ordered while a batch slot
            // and a KV reservation are both available.
            let t_admit = Instant::now();
            if !(fast && admit_blocked) {
                while active.len() < self.max_batch && !waiting.is_empty() {
                    let idx = self.policy.pick(&waiting);
                    let window = waiting[idx]
                        .kv_tokens()
                        .max(waiting[idx].prompt_len + 1);
                    if !self.kv.fits_ever(window) {
                        let req = waiting.swap_remove(idx);
                        self.prefilled.remove(&req.id);
                        self.rejected.push(req);
                        continue;
                    }
                    let id = waiting[idx].id;
                    let session = waiting[idx].session;
                    let prompt_len = waiting[idx].prompt_len;
                    let migrated = self.prefilled.contains(&id);
                    self.tsync();
                    let grant = if migrated {
                        // Migrated KV *is* the request's state: no session
                        // reuse, just coverage for prompt + first token.
                        self.kv
                            .try_admit_migrated(id, session, prompt_len, window)
                            .map(|lease| (lease, 0))
                    } else {
                        self.kv
                            .try_admit(id, session, prompt_len, window, &waiting[idx].prefix)
                    };
                    match grant {
                        Some((lease, reused)) => {
                            let req = waiting.swap_remove(idx);
                            if migrated {
                                self.prefilled.remove(&id);
                            }
                            let admit_s = self.clock_s;
                            admit_seq += 1;
                            self.temit(TraceEventKind::Admit {
                                id,
                                session,
                                reused_tokens: reused,
                            });
                            let mut a = ActiveReq {
                                req,
                                admit_s,
                                // A session-reused prefix skips its prefill.
                                prefill_done: reused,
                                decode_start_s: admit_s,
                                produced: 0,
                                lease,
                                seq: admit_seq,
                                shielded: false,
                            };
                            if migrated {
                                // The prefill pool already summarized the
                                // prompt and produced the first token; the
                                // migrated KV lands with zero local charge
                                // (the migration itself was charged on the
                                // fabric by the cluster).
                                a.prefill_done = a.req.prompt_len;
                                // Not counted in `profile.sim_tokens`: the
                                // prefill pool simulated (and counted) it.
                                a.produced = 1;
                            } else if self.prefill_chunk.is_none() {
                                // The (rest of the) summarization charged inline.
                                let dt = self.prefill_increment_s(reused, a.req.prompt_len);
                                self.clock_s += dt;
                                a.prefill_done = a.req.prompt_len;
                                a.decode_start_s = self.clock_s;
                                a.produced = 1;
                                self.profile.sim_tokens += 1;
                                self.temit(TraceEventKind::PrefillChunk {
                                    id,
                                    from: reused,
                                    to: prompt_len,
                                    dt_s: dt,
                                });
                                self.temit_handoff(id, prompt_len - reused);
                            } else if !a.prefilling() {
                                // Degenerate empty prompt: nothing to chunk,
                                // the first token is immediate.
                                a.produced = 1;
                                self.profile.sim_tokens += 1;
                            }
                            if fast {
                                if a.prefilling() {
                                    prefilling += 1;
                                } else {
                                    finish_heap.push(Reverse((
                                        due_step(self.decode_steps, &a, max_seq),
                                        a.seq,
                                    )));
                                }
                            }
                            track_push(&mut active, &mut slot_of, fast, a);
                        }
                        // KV region full right now: wait for a completion.
                        // The failed probe is pure and the policy pick is
                        // deterministic over an unchanged waiting set, so
                        // the whole phase is skippable until then.
                        None => {
                            admit_blocked = true;
                            break;
                        }
                    }
                }
            }
            self.max_batch_seen = self.max_batch_seen.max(active.len());

            // Advance one prefill chunk per still-prefilling request
            // (the device time-shares chunks at token boundaries). The
            // event core skips the walk while nothing is summarizing.
            // Under the priority policy, interactive requests' chunks
            // run before batch requests' chunks (prefill-chunk
            // priority: their first token lands earlier at the same
            // total simulated cost); otherwise a single pass preserves
            // the historical slot order bit-for-bit.
            if let Some(chunk) = self.prefill_chunk {
                if !fast || prefilling > 0 {
                    let passes: &[Option<SloClass>] = if self.policy == Policy::Priority {
                        &[Some(SloClass::Interactive), Some(SloClass::Batch)]
                    } else {
                        &[None]
                    };
                    for pass in passes {
                    for a in active.iter_mut() {
                        if let Some(class) = pass {
                            if a.req.slo != *class {
                                continue;
                            }
                        }
                        if !a.prefilling() {
                            continue;
                        }
                        let from = a.prefill_done;
                        let to = (from + chunk).min(a.req.prompt_len);
                        let dt = self.prefill_increment_s(from, to);
                        self.clock_s += dt;
                        a.prefill_done = to;
                        self.temit(TraceEventKind::PrefillChunk {
                            id: a.req.id,
                            from,
                            to,
                            dt_s: dt,
                        });
                        self.temit_handoff(a.req.id, to - from);
                        if !a.prefilling() {
                            // Summarization complete: emits the first token.
                            a.decode_start_s = self.clock_s;
                            a.produced = 1;
                            self.profile.sim_tokens += 1;
                            if fast {
                                prefilling -= 1;
                                finish_heap.push(Reverse((
                                    due_step(self.decode_steps, a, max_seq),
                                    a.seq,
                                )));
                            }
                        }
                    }
                    }
                }
            }
            self.profile.admission_s += t_admit.elapsed().as_secs_f64();

            // Grow every decoding lease to cover the KV the next step
            // writes. Oldest-first, so a pool shortfall preempts only
            // *strictly younger* requests — the oldest always progresses,
            // which rules out livelock. A request with no younger victim
            // stalls one boundary and keeps its blocks.
            let t_grow = Instant::now();
            let mut preempt_elapsed = 0.0f64;
            // The clock does not advance while growing, so one stamp
            // sync covers every pool call in the loop.
            self.tsync();
            stalled.clear();
            // Whole-window pools reserve up front: every `ensure` is a
            // provable no-op, so the event core skips the walk outright.
            if !fast || self.kv.needs_growth() {
                order.clear();
                order.extend(active.iter().filter(|a| a.decoding(max_seq)).map(|a| a.seq));
                order.sort_unstable();
                'grow: for &seq in &order {
                    loop {
                        // A seq vanishes from `active` only by being
                        // preempted earlier in this very phase.
                        let i = if fast {
                            match slot_of.get(&seq) {
                                Some(&i) => i,
                                None => continue 'grow,
                            }
                        } else {
                            match active.iter().position(|a| a.seq == seq) {
                                Some(i) => i,
                                None => continue 'grow,
                            }
                        };
                        let need = active[i].next_kv() + 1;
                        if self.kv.ensure(&mut active[i].lease, need) {
                            continue 'grow;
                        }
                        if !self.kv.preemption_allowed() {
                            stalled.push(seq);
                            continue 'grow;
                        }
                        // Youngest strictly-younger decoding request;
                        // shielded (just-readmitted) requests are spared so
                        // their recompute charge buys at least one token.
                        let victim = active
                            .iter()
                            .enumerate()
                            .filter(|(_, a)| a.seq > seq && a.decoding(max_seq) && !a.shielded)
                            .max_by_key(|(_, a)| a.seq)
                            .map(|(j, _)| j);
                        match victim {
                            Some(j) => {
                                let t_preempt = Instant::now();
                                let v = track_swap_remove(&mut active, &mut slot_of, fast, j);
                                self.kv.free(v.lease);
                                self.preemptions += 1;
                                self.temit(TraceEventKind::Preempt { id: v.req.id });
                                if self.kv.swap_enabled() {
                                    // Spill the dropped KV payload to the
                                    // host buffer: an asynchronous DMA
                                    // charged to the link (it contends with
                                    // other fabric traffic), not to the
                                    // engine clock. Readmission may read it
                                    // back instead of recomputing.
                                    let tokens = v.req.prompt_len + v.produced;
                                    let bytes =
                                        tokens * self.capacity.kv_bytes_per_token;
                                    let dt = self.fabric_transfer(bytes);
                                    self.swap_outs += 1;
                                    self.swapped_bytes += bytes as u64;
                                    self.swapped.insert(v.req.id, tokens);
                                    if let Some(t) = &self.trace {
                                        t.emit_at(
                                            self.clock_s + dt,
                                            TraceEventKind::SwapOut {
                                                id: v.req.id,
                                                tokens,
                                                dt_s: dt,
                                            },
                                        );
                                    }
                                }
                                self.readmit.push_back(Preempted {
                                    req: v.req,
                                    admit_s: v.admit_s,
                                    decode_start_s: v.decode_start_s,
                                    produced: v.produced,
                                });
                                // Freed blocks invalidate both memos.
                                admit_blocked = false;
                                readmit_blocked = false;
                                preempt_elapsed += t_preempt.elapsed().as_secs_f64();
                                // Retry the grow with the freed blocks.
                            }
                            None => {
                                stalled.push(seq);
                                continue 'grow;
                            }
                        }
                    }
                }
            }
            self.profile.preempt_s += preempt_elapsed;
            self.profile.growth_s +=
                (t_grow.elapsed().as_secs_f64() - preempt_elapsed).max(0.0);

            // One batched decode step over every request that still
            // decodes (past prefill, not finished, KV below the window,
            // not stalled on blocks).
            let t_decode = Instant::now();
            parts.clear();
            parts.extend(
                active
                    .iter()
                    .enumerate()
                    .filter(|(_, a)| a.decoding(max_seq) && !stalled.contains(&a.seq))
                    .map(|(i, _)| i),
            );
            if !parts.is_empty() {
                kv_lens.clear();
                kv_lens.extend(parts.iter().map(|&i| active[i].next_kv()));
                let dt = self.backend.decode_step_s(&kv_lens);
                self.clock_s += dt;
                self.decode_steps += 1;
                self.decode_batch_sum += kv_lens.len() as u64;
                self.profile.sim_tokens += parts.len() as u64;
                self.temit(TraceEventKind::DecodeStep {
                    batch: parts.len(),
                    dt_s: dt,
                });
                for &i in &parts {
                    active[i].produced += 1;
                    // One token produced: the readmission paid for itself.
                    active[i].shielded = false;
                }
            }
            self.profile.decode_s += t_decode.elapsed().as_secs_f64();

            // Retire finished requests, freeing their KV slots (paged
            // pools park the blocks as session residency for reuse).
            // The event core consults the completion heap first: when
            // nothing is due at this decode step the scan is skipped
            // entirely; when something is due, the legacy scan runs
            // verbatim so the completion order stays bit-identical.
            let mut any_due = !fast;
            if fast {
                while let Some(&Reverse((due, seq))) = finish_heap.peek() {
                    if due > self.decode_steps {
                        break;
                    }
                    finish_heap.pop();
                    // Preempted seqs leave stale entries; drop them (the
                    // readmission pushed a fresh entry under a new seq).
                    let Some(&i) = slot_of.get(&seq) else {
                        continue;
                    };
                    if active[i].finished(max_seq) {
                        any_due = true;
                    } else {
                        // Block-stalled past its due step: re-arm at the
                        // corrected earliest-finish step.
                        finish_heap.push(Reverse((
                            due_step(self.decode_steps, &active[i], max_seq),
                            seq,
                        )));
                    }
                }
            }
            if !any_due {
                continue;
            }
            let mut i = 0;
            while i < active.len() {
                if active[i].finished(max_seq) {
                    let a = track_swap_remove(&mut active, &mut slot_of, fast, i);
                    // Released capacity invalidates both blocked memos.
                    admit_blocked = false;
                    readmit_blocked = false;
                    self.temit(TraceEventKind::Complete {
                        id: a.req.id,
                        tokens_simulated: a.produced,
                    });
                    completions.push(Completion {
                        id: a.req.id,
                        prompt_len: a.req.prompt_len,
                        // Reported budget, mirroring the sequential path
                        // (max_seq truncation stops the clock, not the
                        // reported count)…
                        tokens_out: a.req.max_new_tokens,
                        // …while the simulated count is exact and must
                        // match the sequential path per request.
                        tokens_simulated: a.produced,
                        queue_s: a.admit_s - a.req.arrival_s,
                        // Wall span from admission to the first token;
                        // equals the prefill service time when unchunked.
                        prefill_s: a.decode_start_s - a.admit_s,
                        decode_s: self.clock_s - a.decode_start_s,
                        finish_s: self.clock_s,
                        device: self.device_index,
                        slo: a.req.slo,
                    });
                    self.kv.release(a.lease);
                } else {
                    i += 1;
                }
            }
        }
        // Park the scratch buffers for the next run.
        self.scratch_stalled = stalled;
        self.scratch_order = order;
        self.scratch_parts = parts;
        self.scratch_kv_lens = kv_lens;
        self.profile.wall_s += run_start.elapsed().as_secs_f64();
        completions
    }

    /// Metrics helper over a completed run.
    pub fn metrics(done: &[Completion]) -> ServeMetrics {
        ServeMetrics::from_completions(done)
    }

    pub fn report(&self) -> EngineReport {
        EngineReport {
            rejected: self.rejected.len(),
            kv_peak_utilization: self.kv.peak_utilization(),
            max_batch_seen: self.max_batch_seen,
            decode_steps: self.decode_steps,
            mean_decode_batch: if self.decode_steps == 0 {
                0.0
            } else {
                self.decode_batch_sum as f64 / self.decode_steps as f64
            },
            preemptions: self.preemptions,
            recompute_tokens: self.recompute_tokens,
            reuse_hits: self.kv.reuse_hits(),
            reuse_tokens: self.kv.reuse_tokens(),
            prefix_hits: self.kv.prefix_hits(),
            prefix_reused_tokens: self.kv.prefix_reused_tokens(),
            prefix_nodes_evicted: self.kv.prefix_nodes_evicted(),
            swap_outs: self.swap_outs,
            swap_ins: self.swap_ins,
            swapped_bytes: self.swapped_bytes,
            profile: self.profile,
            truncated: self.truncated,
        }
    }

    /// Requests rejected because their KV window can never fit.
    pub fn rejected(&self) -> &[Request] {
        &self.rejected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::backend::BackendKind;
    use crate::serve::kv_cache::KvCacheManager;

    fn req(id: u64, prompt: usize, out: usize, at: f64) -> Request {
        Request {
            id,
            prompt_len: prompt,
            max_new_tokens: out,
            arrival_s: at,
            session: id,
            slo: SloClass::Batch,
            prefix: Vec::new(),
        }
    }

    #[test]
    fn single_request_matches_sequential_shape() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::new(&cfg, 4);
        e.submit(req(0, 32, 8, 0.0));
        let done = e.run();
        assert_eq!(done.len(), 1);
        let c = &done[0];
        assert_eq!(c.tokens_out, 8);
        assert_eq!(c.queue_s, 0.0);
        assert!(c.prefill_s > 0.0 && c.decode_s > 0.0);
        let r = e.report();
        assert_eq!(r.rejected, 0);
        assert_eq!(r.max_batch_seen, 1);
        assert_eq!(r.decode_steps, 7, "n_out-1 decode iterations");
        assert_eq!(r.preemptions, 0);
        assert!((r.mean_decode_batch - 1.0).abs() < 1e-12);
    }

    #[test]
    fn batch_overlaps_requests() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::new(&cfg, 4);
        for i in 0..4 {
            e.submit(req(i, 32, 8, 0.0));
        }
        let done = e.run();
        assert_eq!(done.len(), 4);
        assert_eq!(e.report().max_batch_seen, 4);
        // All requests share decode steps, so the batch finishes well
        // before 4× a single request's span.
        let m = ServeMetrics::from_completions(&done);
        let mut single = DeviceEngine::new(&cfg, 1);
        single.submit(req(0, 32, 8, 0.0));
        let one = ServeMetrics::from_completions(&single.run());
        assert!(m.makespan_s < 4.0 * one.makespan_s);
    }

    #[test]
    fn kv_pressure_blocks_then_frees() {
        let cfg = SimConfig::paper();
        // Room for roughly one request's window at a time.
        let per_sub = cfg.hbm.subarray_bytes() / cfg.model.kv_bytes_per_token();
        let subs_for_one = (40usize).div_ceil(per_sub);
        let mut e = DeviceEngine::new(&cfg, 8).with_kv_subarrays(subs_for_one);
        for i in 0..3 {
            e.submit(req(i, 32, 8, 0.0));
        }
        let done = e.run();
        assert_eq!(done.len(), 3, "all served once slots free");
        assert_eq!(e.report().max_batch_seen, 1, "KV cap serializes");
        assert!(e.report().kv_peak_utilization > 0.0);
    }

    #[test]
    fn impossible_request_is_rejected_not_wedged() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::new(&cfg, 2).with_kv_subarrays(1);
        let cap = KvCacheManager::with_kv_subarrays(&cfg, 1).capacity_tokens();
        e.submit(req(0, cap + 64, 64, 0.0)); // can never fit
        e.submit(req(1, 2, 2, 0.0));
        let done = e.run();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(e.report().rejected, 1);
    }

    #[test]
    fn chunked_prefill_conserves_tokens() {
        // Chunking reorders time; the simulated token counts per request
        // are identical to the inline-prefill run.
        let cfg = SimConfig::paper();
        let run = |chunk: Option<usize>| -> Vec<(u64, usize)> {
            let mut e = DeviceEngine::new(&cfg, 4).with_prefill_chunk(chunk);
            e.submit(req(0, 96, 8, 0.0));
            e.submit(req(1, 32, 16, 0.0));
            e.submit(req(2, 48, 4, 0.0));
            let mut out: Vec<(u64, usize)> =
                e.run().iter().map(|c| (c.id, c.tokens_simulated)).collect();
            out.sort();
            out
        };
        assert_eq!(run(None), run(Some(16)));
        assert_eq!(run(None), run(Some(7)), "odd chunk sizes too");
    }

    #[test]
    fn gpu_backend_serves_the_same_queue() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::with_backend(BackendKind::Gpu.build(&cfg), 4);
        assert_eq!(e.backend_name(), "gpu");
        for i in 0..3 {
            e.submit(req(i, 32, 8, 0.0));
        }
        let done = e.run();
        assert_eq!(done.len(), 3);
        assert_eq!(e.report().rejected, 0);
    }

    #[test]
    fn paged_policy_serves_the_same_queue_with_more_concurrency() {
        // Same tiny region as `kv_pressure_blocks_then_frees`: whole
        // serializes (one window at a time), paged overlaps requests
        // because only resident tokens hold blocks.
        let cfg = SimConfig::paper();
        let per_sub = cfg.hbm.subarray_bytes() / cfg.model.kv_bytes_per_token();
        let subs_for_one = (40usize).div_ceil(per_sub);
        let run = |policy: KvPolicy| {
            let mut e = DeviceEngine::new(&cfg, 8)
                .with_kv_policy(policy)
                .with_kv_subarrays(2 * subs_for_one);
            for i in 0..4 {
                e.submit(req(i, 16, 24, 0.0));
            }
            let mut done: Vec<(u64, usize)> =
                e.run().iter().map(|c| (c.id, c.tokens_simulated)).collect();
            done.sort();
            (done, e.report())
        };
        let (whole_done, whole_rep) = run(KvPolicy::Whole);
        let (paged_done, paged_rep) = run(KvPolicy::Paged);
        assert_eq!(whole_done, paged_done, "token conservation across policies");
        assert!(
            paged_rep.mean_decode_batch > whole_rep.mean_decode_batch,
            "paged {} !> whole {}",
            paged_rep.mean_decode_batch,
            whole_rep.mean_decode_batch
        );
    }

    #[test]
    fn preemption_recomputes_and_conserves_tokens() {
        // A region too small for every window forces preemption under
        // paged+lru; every request still simulates its full budget.
        let cfg = SimConfig::paper();
        let per_sub = cfg.hbm.subarray_bytes() / cfg.model.kv_bytes_per_token();
        let subs = (3 * 40usize).div_ceil(per_sub);
        let mut e = DeviceEngine::new(&cfg, 8)
            .with_kv_policy(KvPolicy::Paged)
            .with_kv_subarrays(subs);
        for i in 0..6 {
            e.submit(req(i, 8, 32, 0.0));
        }
        let done = e.run();
        assert_eq!(done.len(), 6, "everything served despite preemptions");
        for c in &done {
            assert_eq!(c.tokens_simulated, 32, "request {} lost tokens", c.id);
        }
        let rep = e.report();
        assert!(rep.preemptions > 0, "pressure must force preemption");
        assert!(rep.recompute_tokens > 0, "recompute must be charged");
    }

    #[test]
    fn session_reuse_skips_the_shared_prefix() {
        // Two requests of one session, arriving far apart: the second
        // reclaims the first's resident blocks and skips most of its
        // prefill, so its TTFT shrinks vs a cold session.
        let cfg = SimConfig::paper();
        let run = |same_session: bool| {
            let mut e = DeviceEngine::new(&cfg, 4).with_kv_policy(KvPolicy::Paged);
            let mut a = req(0, 64, 8, 0.0);
            let mut b = req(1, 64, 8, 1.0);
            a.session = 1;
            b.session = if same_session { 1 } else { 2 };
            e.submit(a);
            e.submit(b);
            let done = e.run();
            let second = done.iter().find(|c| c.id == 1).unwrap().clone();
            (second.ttft_s(), e.report())
        };
        let (cold_ttft, cold_rep) = run(false);
        let (warm_ttft, warm_rep) = run(true);
        assert_eq!(cold_rep.reuse_hits, 0);
        assert_eq!(warm_rep.reuse_hits, 1);
        assert!(warm_rep.reuse_tokens > 0);
        assert!(
            warm_ttft < cold_ttft,
            "reused prefix must shrink TTFT: warm {warm_ttft} !< cold {cold_ttft}"
        );
    }

    #[test]
    fn engine_core_tokens_round_trip() {
        for core in [EngineCore::Event, EngineCore::Legacy] {
            assert_eq!(EngineCore::parse(core.name()), Some(core));
        }
        assert_eq!(EngineCore::parse("turbo"), None);
        assert_eq!(EngineCore::default(), EngineCore::Event);
    }

    #[test]
    fn queued_tokens_is_maintained_incrementally() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::new(&cfg, 4);
        let a = req(0, 32, 8, 0.0);
        let b = req(1, 16, 4, 0.0);
        let want = a.kv_tokens() + b.kv_tokens();
        e.submit(a);
        e.submit(b);
        assert_eq!(e.queued_tokens(), want);
        e.run();
        assert_eq!(e.queued_tokens(), 0, "run drains the queue");
    }

    #[test]
    fn legacy_core_matches_event_core_bit_for_bit_under_preemption() {
        // The full random matrix lives in tests/engine_equivalence.rs;
        // this is the smoke-sized pin with the preemption + readmit
        // machinery (the hardest phases to keep bit-identical) engaged.
        let cfg = SimConfig::paper();
        let per_sub = cfg.hbm.subarray_bytes() / cfg.model.kv_bytes_per_token();
        let subs = (3 * 40usize).div_ceil(per_sub);
        let run = |core: EngineCore| {
            let mut e = DeviceEngine::new(&cfg, 8)
                .with_core(core)
                .with_kv_policy(KvPolicy::Paged)
                .with_kv_subarrays(subs);
            for i in 0..6 {
                e.submit(req(i, 8, 32, 0.0));
            }
            let done = e.run();
            let rep = e.report();
            (
                done,
                rep.preemptions,
                rep.decode_steps,
                rep.max_batch_seen,
                rep.recompute_tokens,
            )
        };
        let (ev, ev_p, ev_s, ev_b, ev_r) = run(EngineCore::Event);
        let (lg, lg_p, lg_s, lg_b, lg_r) = run(EngineCore::Legacy);
        assert!(ev_p > 0, "pressure must force preemption in this pin");
        assert_eq!(ev_p, lg_p);
        assert_eq!(ev_s, lg_s);
        assert_eq!(ev_b, lg_b);
        assert_eq!(ev_r, lg_r);
        assert_eq!(ev.len(), lg.len());
        for (a, b) in ev.iter().zip(&lg) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens_simulated, b.tokens_simulated);
            assert_eq!(a.queue_s.to_bits(), b.queue_s.to_bits());
            assert_eq!(a.prefill_s.to_bits(), b.prefill_s.to_bits());
            assert_eq!(a.decode_s.to_bits(), b.decode_s.to_bits());
            assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
        }
    }

    #[test]
    fn radix_prefix_cache_beats_session_reuse_across_sessions() {
        // Ten distinct sessions share a system prompt. Session
        // residency cannot help (each session is cold); the radix tree
        // prefills the shared prefix once and reuses it nine times.
        use crate::serve::types::PrefixSeg;
        let cfg = SimConfig::paper();
        let run = |mode: PrefixCacheMode| {
            let mut e = DeviceEngine::new(&cfg, 4)
                .with_kv_policy(KvPolicy::Paged)
                .with_prefix_cache(mode);
            for i in 0..10u64 {
                let mut r = req(i, 96, 4, i as f64 * 0.5);
                r.session = 100 + i;
                r.prefix = vec![PrefixSeg { id: 1, tokens: 64 }];
                e.submit(r);
            }
            let done = e.run();
            assert_eq!(done.len(), 10);
            (done, e.report())
        };
        let (sess_done, sess_rep) = run(PrefixCacheMode::Session);
        let (radix_done, radix_rep) = run(PrefixCacheMode::Radix);
        assert_eq!(sess_rep.prefix_hits, 0);
        assert_eq!(radix_rep.prefix_hits, 9, "nine warm admissions");
        assert_eq!(radix_rep.prefix_reused_tokens, 9 * 64);
        // Token conservation: reuse skips *prefill work*, never output.
        for (a, b) in sess_done.iter().zip(&radix_done) {
            assert_eq!(a.tokens_simulated, b.tokens_simulated);
        }
        // And the skipped prefill shows up as wall-clock time.
        let span = |d: &[Completion]| {
            d.iter().map(|c| c.finish_s).fold(0.0f64, f64::max)
        };
        assert!(
            span(&radix_done) < span(&sess_done),
            "radix {} !< session {}",
            span(&radix_done),
            span(&sess_done)
        );
    }

    #[test]
    fn priority_policy_cuts_interactive_ttft() {
        // A burst of batch work arrives just before an interactive
        // request; under FCFS the interactive one waits its turn, under
        // the priority policy it jumps the queue.
        let cfg = SimConfig::paper();
        let run = |policy: Policy| {
            let mut e = DeviceEngine::new(&cfg, 1).with_policy(policy);
            for i in 0..4u64 {
                e.submit(req(i, 64, 16, 0.0));
            }
            let mut hot = req(9, 32, 8, 0.01);
            hot.slo = SloClass::Interactive;
            e.submit(hot);
            let done = e.run();
            done.iter().find(|c| c.id == 9).unwrap().ttft_s()
        };
        let fcfs = run(Policy::Fcfs);
        let prio = run(Policy::Priority);
        assert!(prio < fcfs, "priority {prio} !< fcfs {fcfs}");
    }

    #[test]
    fn evict_none_preallocates_and_never_preempts() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::new(&cfg, 8)
            .with_kv_policy(KvPolicy::Paged)
            .with_evict(EvictPolicy::None)
            .with_kv_subarrays(16);
        for i in 0..4 {
            e.submit(req(i, 16, 16, 0.0));
        }
        let done = e.run();
        assert_eq!(done.len(), 4);
        assert_eq!(e.report().preemptions, 0);
    }
}
