//! Continuous-batching serving engine for one simulated SAL-PIM device.
//!
//! The sequential [`crate::coordinator::Coordinator`] runs each request to
//! completion before touching the next. This engine instead keeps a batch
//! of in-flight generations and walks simulated time event by event:
//!
//! * at every token boundary, waiting requests (policy-ordered) are
//!   admitted while a batch slot **and** a KV reservation are available —
//!   admission charges the request's summarization (prefill) inline;
//! * one batched decode step then produces one token for every active
//!   request, charged via
//!   [`crate::mapper::GenerationSim::decode_batch_step`]: the shared
//!   weight stream is paid once per step, the per-request KV/attention
//!   work accumulates — which is exactly why batching wins on a
//!   weight-streaming PIM;
//! * completions release their KV lease, freeing admission slots.
//!
//! Requests whose KV window can never fit the device are rejected rather
//! than wedging the queue (the device has no eviction path).

use super::kv_cache::{KvCacheManager, KvLease};
use super::metrics::ServeMetrics;
use super::policy::Policy;
use super::types::{Completion, Request};
use crate::config::SimConfig;
use crate::mapper::GenerationSim;

/// A request currently holding a batch slot.
struct ActiveReq {
    req: Request,
    /// Clock when the request left the queue (prefill start).
    admit_s: f64,
    prefill_s: f64,
    /// Clock when the request entered the decode batch.
    decode_start_s: f64,
    /// Tokens produced so far (the prefill emits the first).
    produced: usize,
    lease: KvLease,
}

impl ActiveReq {
    /// KV length the next decode step runs at.
    fn next_kv(&self) -> usize {
        self.req.prompt_len + self.produced
    }

    fn finished(&self, max_seq: usize) -> bool {
        self.produced >= self.req.max_new_tokens || self.next_kv() >= max_seq
    }
}

/// Post-run accounting beyond the per-request completions.
#[derive(Debug, Clone)]
pub struct EngineReport {
    /// Requests whose KV window can never fit the device.
    pub rejected: usize,
    /// High-water KV-region utilization.
    pub kv_peak_utilization: f64,
    /// Largest decode batch observed.
    pub max_batch_seen: usize,
    /// Batched decode steps executed.
    pub decode_steps: u64,
}

/// One device running continuous batching.
pub struct DeviceEngine {
    pub cfg: SimConfig,
    sim: GenerationSim,
    kv: KvCacheManager,
    pub policy: Policy,
    /// Batch slots (concurrent generations the command scheduler
    /// interleaves across subarray groups).
    pub max_batch: usize,
    /// Index reported in completions (set by the cluster).
    pub device_index: usize,
    pending: Vec<Request>,
    clock_s: f64,
    rejected: Vec<Request>,
    max_batch_seen: usize,
    decode_steps: u64,
}

impl DeviceEngine {
    pub fn new(cfg: &SimConfig, max_batch: usize) -> Self {
        assert!(max_batch >= 1);
        DeviceEngine {
            cfg: cfg.clone(),
            sim: GenerationSim::new(cfg),
            kv: KvCacheManager::for_device(cfg),
            policy: Policy::Fcfs,
            max_batch,
            device_index: 0,
            pending: Vec::new(),
            clock_s: 0.0,
            rejected: Vec::new(),
            max_batch_seen: 0,
            decode_steps: 0,
        }
    }

    pub fn with_policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Shrink the KV region (what-if experiments / admission pressure).
    pub fn with_kv_subarrays(mut self, kv_subarrays: usize) -> Self {
        self.kv = KvCacheManager::with_kv_subarrays(&self.cfg, kv_subarrays);
        self
    }

    pub fn submit(&mut self, req: Request) {
        self.pending.push(req);
    }

    /// Estimated outstanding work in tokens (for least-loaded routing).
    pub fn queued_tokens(&self) -> usize {
        self.pending.iter().map(|r| r.kv_tokens()).sum()
    }

    fn prefill_time(&mut self, prompt_len: usize) -> f64 {
        let st = self.sim.prefill(prompt_len);
        st.seconds(self.cfg.timing.tck_ns)
    }

    /// Drain the queue with continuous batching; returns completions in
    /// finish order.
    pub fn run(&mut self) -> Vec<Completion> {
        let mut incoming = std::mem::take(&mut self.pending);
        incoming.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let mut incoming = incoming.into_iter().peekable();
        let mut waiting: Vec<Request> = Vec::new();
        let mut active: Vec<ActiveReq> = Vec::new();
        let mut completions: Vec<Completion> = Vec::new();
        let max_seq = self.cfg.model.max_seq;

        loop {
            // Pull everything that has arrived by the current clock.
            while let Some(r) = incoming.peek() {
                if r.arrival_s <= self.clock_s {
                    waiting.push(incoming.next().unwrap());
                } else {
                    break;
                }
            }
            // Idle device: jump to the next arrival or stop.
            if active.is_empty() && waiting.is_empty() {
                match incoming.next() {
                    Some(r) => {
                        self.clock_s = self.clock_s.max(r.arrival_s);
                        waiting.push(r);
                        continue;
                    }
                    None => break,
                }
            }

            // Token-boundary admission: policy-ordered while a batch slot
            // and a KV reservation are both available.
            while active.len() < self.max_batch && !waiting.is_empty() {
                let idx = self.policy.pick(&waiting);
                let tokens = waiting[idx].kv_tokens();
                if !self.kv.fits_ever(tokens) {
                    let req = waiting.swap_remove(idx);
                    self.rejected.push(req);
                    continue;
                }
                let id = waiting[idx].id;
                match self.kv.try_admit(id, tokens) {
                    Some(lease) => {
                        let req = waiting.swap_remove(idx);
                        let admit_s = self.clock_s;
                        let prefill_s = self.prefill_time(req.prompt_len);
                        self.clock_s += prefill_s;
                        active.push(ActiveReq {
                            req,
                            admit_s,
                            prefill_s,
                            decode_start_s: self.clock_s,
                            produced: 1,
                            lease,
                        });
                    }
                    // KV region full right now: wait for a completion.
                    None => break,
                }
            }
            self.max_batch_seen = self.max_batch_seen.max(active.len());

            // One batched decode step over every request that still
            // decodes (not finished, KV below the model window).
            let kv_lens: Vec<usize> = active
                .iter()
                .filter(|a| !a.finished(max_seq))
                .map(|a| a.next_kv())
                .collect();
            if !kv_lens.is_empty() {
                let st = self.sim.decode_batch_step(&kv_lens);
                self.clock_s += self.cfg.timing.cycles_to_sec(st.cycles);
                self.decode_steps += 1;
                for a in active.iter_mut() {
                    if !a.finished(max_seq) {
                        a.produced += 1;
                    }
                }
            }

            // Retire finished requests, freeing their KV slots.
            let mut i = 0;
            while i < active.len() {
                if active[i].finished(max_seq) {
                    let a = active.swap_remove(i);
                    completions.push(Completion {
                        id: a.req.id,
                        prompt_len: a.req.prompt_len,
                        // Reported budget, mirroring the sequential path
                        // (max_seq truncation stops the clock, not the
                        // reported count)…
                        tokens_out: a.req.max_new_tokens,
                        // …while the simulated count is exact and must
                        // match the sequential path per request.
                        tokens_simulated: a.produced,
                        queue_s: a.admit_s - a.req.arrival_s,
                        prefill_s: a.prefill_s,
                        decode_s: self.clock_s - a.decode_start_s,
                        finish_s: self.clock_s,
                        device: self.device_index,
                    });
                    self.kv.release(a.lease);
                } else {
                    i += 1;
                }
            }
        }
        completions
    }

    /// Metrics helper over a completed run.
    pub fn metrics(done: &[Completion]) -> ServeMetrics {
        ServeMetrics::from_completions(done)
    }

    pub fn report(&self) -> EngineReport {
        EngineReport {
            rejected: self.rejected.len(),
            kv_peak_utilization: self.kv.peak_utilization(),
            max_batch_seen: self.max_batch_seen,
            decode_steps: self.decode_steps,
        }
    }

    /// Requests rejected because their KV window can never fit.
    pub fn rejected(&self) -> &[Request] {
        &self.rejected
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, out: usize, at: f64) -> Request {
        Request {
            id,
            prompt_len: prompt,
            max_new_tokens: out,
            arrival_s: at,
            session: id,
        }
    }

    #[test]
    fn single_request_matches_sequential_shape() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::new(&cfg, 4);
        e.submit(req(0, 32, 8, 0.0));
        let done = e.run();
        assert_eq!(done.len(), 1);
        let c = &done[0];
        assert_eq!(c.tokens_out, 8);
        assert_eq!(c.queue_s, 0.0);
        assert!(c.prefill_s > 0.0 && c.decode_s > 0.0);
        let r = e.report();
        assert_eq!(r.rejected, 0);
        assert_eq!(r.max_batch_seen, 1);
        assert_eq!(r.decode_steps, 7, "n_out-1 decode iterations");
    }

    #[test]
    fn batch_overlaps_requests() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::new(&cfg, 4);
        for i in 0..4 {
            e.submit(req(i, 32, 8, 0.0));
        }
        let done = e.run();
        assert_eq!(done.len(), 4);
        assert_eq!(e.report().max_batch_seen, 4);
        // All requests share decode steps, so the batch finishes well
        // before 4× a single request's span.
        let m = ServeMetrics::from_completions(&done);
        let mut single = DeviceEngine::new(&cfg, 1);
        single.submit(req(0, 32, 8, 0.0));
        let one = ServeMetrics::from_completions(&single.run());
        assert!(m.makespan_s < 4.0 * one.makespan_s);
    }

    #[test]
    fn kv_pressure_blocks_then_frees() {
        let cfg = SimConfig::paper();
        // Room for roughly one request's window at a time.
        let per_sub = cfg.hbm.subarray_bytes() / cfg.model.kv_bytes_per_token();
        let subs_for_one = (40usize).div_ceil(per_sub);
        let mut e = DeviceEngine::new(&cfg, 8).with_kv_subarrays(subs_for_one);
        for i in 0..3 {
            e.submit(req(i, 32, 8, 0.0));
        }
        let done = e.run();
        assert_eq!(done.len(), 3, "all served once slots free");
        assert_eq!(e.report().max_batch_seen, 1, "KV cap serializes");
        assert!(e.report().kv_peak_utilization > 0.0);
    }

    #[test]
    fn impossible_request_is_rejected_not_wedged() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::new(&cfg, 2).with_kv_subarrays(1);
        let cap = KvCacheManager::with_kv_subarrays(&cfg, 1).capacity_tokens();
        e.submit(req(0, cap + 64, 64, 0.0)); // can never fit
        e.submit(req(1, 2, 2, 0.0));
        let done = e.run();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(e.report().rejected, 1);
    }
}
