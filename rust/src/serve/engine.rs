//! Continuous-batching serving engine for one simulated device.
//!
//! The sequential [`crate::coordinator::Coordinator`] runs each request to
//! completion before touching the next. This engine instead keeps a batch
//! of in-flight generations and walks simulated time event by event:
//!
//! * at every token boundary, waiting requests (policy-ordered) are
//!   admitted while a batch slot **and** a KV reservation are available;
//! * one batched decode step then produces one token for every active
//!   request, charged via [`ExecutionBackend::decode_step_s`] — on
//!   SAL-PIM the shared weight stream is paid once per step and the
//!   per-request KV/attention work accumulates, which is exactly why
//!   batching wins on a weight-streaming PIM;
//! * completions release their KV lease, freeing admission slots.
//!
//! The engine is generic over [`ExecutionBackend`], so the same
//! scheduler serves SAL-PIM, the GPU roofline, bank-level PIM, or a
//! heterogeneous GPU-prefill + PIM-decode device — the backend only
//! answers "how long does this prefill / batched step take" and "how
//! much KV fits".
//!
//! **Prefill scheduling.** By default a request's whole summarization is
//! charged inline at admission, stalling the decode batch (the legacy
//! behaviour). With [`DeviceEngine::with_prefill_chunk`] the prefill is
//! split into token chunks interleaved at token boundaries: every
//! still-prefilling request advances one chunk per boundary, then the
//! decode step runs over the requests already generating. Chunk `i`
//! covering tokens `[a, b)` is charged `prefill_s(b) − prefill_s(a)`,
//! which telescopes to the unchunked total — chunking reorders time, it
//! never changes the simulated token count. A completion's `prefill_s`
//! is the wall-clock span from admission to its first token (identical
//! to the service time when unchunked).
//!
//! Requests whose KV window can never fit the device are rejected rather
//! than wedging the queue (the device has no eviction path).

use super::backend::{DeviceCapacity, ExecutionBackend, SalPimBackend};
use super::kv_cache::{KvCacheManager, KvLease};
use super::metrics::ServeMetrics;
use super::policy::Policy;
use super::types::{Completion, Request};
use crate::config::SimConfig;

/// A request currently holding a batch slot.
struct ActiveReq {
    req: Request,
    /// Clock when the request left the queue (prefill start).
    admit_s: f64,
    /// Prompt tokens already summarized (== prompt_len once decoding).
    prefill_done: usize,
    /// Clock when the request entered the decode batch.
    decode_start_s: f64,
    /// Tokens produced so far (the completed prefill emits the first).
    produced: usize,
    lease: KvLease,
}

impl ActiveReq {
    /// Still in the (chunked) summarization stage.
    fn prefilling(&self) -> bool {
        self.prefill_done < self.req.prompt_len
    }

    /// KV length the next decode step runs at.
    fn next_kv(&self) -> usize {
        self.req.prompt_len + self.produced
    }

    fn finished(&self, max_seq: usize) -> bool {
        !self.prefilling()
            && (self.produced >= self.req.max_new_tokens || self.next_kv() >= max_seq)
    }

    /// Participates in the next batched decode step.
    fn decoding(&self, max_seq: usize) -> bool {
        !self.prefilling() && !self.finished(max_seq)
    }
}

/// Post-run accounting beyond the per-request completions.
#[derive(Debug, Clone)]
pub struct EngineReport {
    /// Requests whose KV window can never fit the device.
    pub rejected: usize,
    /// High-water KV-region utilization.
    pub kv_peak_utilization: f64,
    /// Largest decode batch observed.
    pub max_batch_seen: usize,
    /// Batched decode steps executed.
    pub decode_steps: u64,
}

/// One device running continuous batching over an [`ExecutionBackend`].
pub struct DeviceEngine {
    backend: Box<dyn ExecutionBackend>,
    capacity: DeviceCapacity,
    kv: KvCacheManager,
    pub policy: Policy,
    /// Batch slots (concurrent generations the command scheduler
    /// interleaves across subarray groups).
    pub max_batch: usize,
    /// Index reported in completions (set by the cluster).
    pub device_index: usize,
    /// Prefill chunk size in tokens; `None` charges whole prefills
    /// inline at admission (the legacy decode-stalling behaviour).
    pub prefill_chunk: Option<usize>,
    pending: Vec<Request>,
    clock_s: f64,
    rejected: Vec<Request>,
    max_batch_seen: usize,
    decode_steps: u64,
}

impl DeviceEngine {
    /// A SAL-PIM device (the historical constructor).
    pub fn new(cfg: &SimConfig, max_batch: usize) -> Self {
        Self::with_backend(Box::new(SalPimBackend::new(cfg)), max_batch)
    }

    /// A device over any execution backend.
    pub fn with_backend(backend: Box<dyn ExecutionBackend>, max_batch: usize) -> Self {
        assert!(max_batch >= 1);
        let capacity = backend.capacity();
        DeviceEngine {
            backend,
            capacity,
            kv: KvCacheManager::from_capacity(&capacity),
            policy: Policy::Fcfs,
            max_batch,
            device_index: 0,
            prefill_chunk: None,
            pending: Vec::new(),
            clock_s: 0.0,
            rejected: Vec::new(),
            max_batch_seen: 0,
            decode_steps: 0,
        }
    }

    pub fn with_policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Shrink the KV region to `units` allocation units — subarrays on
    /// PIM (what-if experiments / admission pressure).
    pub fn with_kv_subarrays(mut self, units: usize) -> Self {
        self.kv = KvCacheManager::from_capacity_units(&self.capacity, units);
        self
    }

    /// Interleave prefills in `chunk`-token pieces at token boundaries
    /// instead of stalling the decode batch; `None` restores the inline
    /// behaviour.
    pub fn with_prefill_chunk(mut self, chunk: Option<usize>) -> Self {
        if let Some(c) = chunk {
            assert!(c >= 1, "prefill chunk must be at least one token");
        }
        self.prefill_chunk = chunk;
        self
    }

    /// The backend's display name.
    pub fn backend_name(&self) -> String {
        self.backend.name()
    }

    pub fn submit(&mut self, req: Request) {
        self.pending.push(req);
    }

    /// Estimated outstanding work in tokens (for least-loaded routing).
    pub fn queued_tokens(&self) -> usize {
        self.pending.iter().map(|r| r.kv_tokens()).sum()
    }

    /// Incremental cost of summarizing prompt tokens `[from, to)`.
    fn prefill_increment_s(&mut self, from: usize, to: usize) -> f64 {
        if from == 0 {
            self.backend.prefill_s(to)
        } else {
            (self.backend.prefill_s(to) - self.backend.prefill_s(from)).max(0.0)
        }
    }

    /// Drain the queue with continuous batching; returns completions in
    /// finish order.
    pub fn run(&mut self) -> Vec<Completion> {
        let mut incoming = std::mem::take(&mut self.pending);
        incoming.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let mut incoming = incoming.into_iter().peekable();
        let mut waiting: Vec<Request> = Vec::new();
        let mut active: Vec<ActiveReq> = Vec::new();
        let mut completions: Vec<Completion> = Vec::new();
        let max_seq = self.capacity.max_seq;

        loop {
            // Pull everything that has arrived by the current clock.
            while let Some(r) = incoming.peek() {
                if r.arrival_s <= self.clock_s {
                    waiting.push(incoming.next().unwrap());
                } else {
                    break;
                }
            }
            // Idle device: jump to the next arrival or stop.
            if active.is_empty() && waiting.is_empty() {
                match incoming.next() {
                    Some(r) => {
                        self.clock_s = self.clock_s.max(r.arrival_s);
                        waiting.push(r);
                        continue;
                    }
                    None => break,
                }
            }

            // Token-boundary admission: policy-ordered while a batch slot
            // and a KV reservation are both available.
            while active.len() < self.max_batch && !waiting.is_empty() {
                let idx = self.policy.pick(&waiting);
                let tokens = waiting[idx].kv_tokens();
                if !self.kv.fits_ever(tokens) {
                    let req = waiting.swap_remove(idx);
                    self.rejected.push(req);
                    continue;
                }
                let id = waiting[idx].id;
                match self.kv.try_admit(id, tokens) {
                    Some(lease) => {
                        let req = waiting.swap_remove(idx);
                        let admit_s = self.clock_s;
                        let mut a = ActiveReq {
                            req,
                            admit_s,
                            prefill_done: 0,
                            decode_start_s: admit_s,
                            produced: 0,
                            lease,
                        };
                        if self.prefill_chunk.is_none() {
                            // Whole summarization charged inline.
                            let dt = self.prefill_increment_s(0, a.req.prompt_len);
                            self.clock_s += dt;
                            a.prefill_done = a.req.prompt_len;
                            a.decode_start_s = self.clock_s;
                            a.produced = 1;
                        } else if !a.prefilling() {
                            // Degenerate empty prompt: nothing to chunk,
                            // the first token is immediate.
                            a.produced = 1;
                        }
                        active.push(a);
                    }
                    // KV region full right now: wait for a completion.
                    None => break,
                }
            }
            self.max_batch_seen = self.max_batch_seen.max(active.len());

            // Advance one prefill chunk per still-prefilling request
            // (the device time-shares chunks at token boundaries).
            if let Some(chunk) = self.prefill_chunk {
                for a in active.iter_mut() {
                    if !a.prefilling() {
                        continue;
                    }
                    let from = a.prefill_done;
                    let to = (from + chunk).min(a.req.prompt_len);
                    let dt = self.prefill_increment_s(from, to);
                    self.clock_s += dt;
                    a.prefill_done = to;
                    if !a.prefilling() {
                        // Summarization complete: emits the first token.
                        a.decode_start_s = self.clock_s;
                        a.produced = 1;
                    }
                }
            }

            // One batched decode step over every request that still
            // decodes (past prefill, not finished, KV below the window).
            let kv_lens: Vec<usize> = active
                .iter()
                .filter(|a| a.decoding(max_seq))
                .map(|a| a.next_kv())
                .collect();
            if !kv_lens.is_empty() {
                let dt = self.backend.decode_step_s(&kv_lens);
                self.clock_s += dt;
                self.decode_steps += 1;
                for a in active.iter_mut() {
                    if a.decoding(max_seq) {
                        a.produced += 1;
                    }
                }
            }

            // Retire finished requests, freeing their KV slots.
            let mut i = 0;
            while i < active.len() {
                if active[i].finished(max_seq) {
                    let a = active.swap_remove(i);
                    completions.push(Completion {
                        id: a.req.id,
                        prompt_len: a.req.prompt_len,
                        // Reported budget, mirroring the sequential path
                        // (max_seq truncation stops the clock, not the
                        // reported count)…
                        tokens_out: a.req.max_new_tokens,
                        // …while the simulated count is exact and must
                        // match the sequential path per request.
                        tokens_simulated: a.produced,
                        queue_s: a.admit_s - a.req.arrival_s,
                        // Wall span from admission to the first token;
                        // equals the prefill service time when unchunked.
                        prefill_s: a.decode_start_s - a.admit_s,
                        decode_s: self.clock_s - a.decode_start_s,
                        finish_s: self.clock_s,
                        device: self.device_index,
                    });
                    self.kv.release(a.lease);
                } else {
                    i += 1;
                }
            }
        }
        completions
    }

    /// Metrics helper over a completed run.
    pub fn metrics(done: &[Completion]) -> ServeMetrics {
        ServeMetrics::from_completions(done)
    }

    pub fn report(&self) -> EngineReport {
        EngineReport {
            rejected: self.rejected.len(),
            kv_peak_utilization: self.kv.peak_utilization(),
            max_batch_seen: self.max_batch_seen,
            decode_steps: self.decode_steps,
        }
    }

    /// Requests rejected because their KV window can never fit.
    pub fn rejected(&self) -> &[Request] {
        &self.rejected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::backend::BackendKind;

    fn req(id: u64, prompt: usize, out: usize, at: f64) -> Request {
        Request {
            id,
            prompt_len: prompt,
            max_new_tokens: out,
            arrival_s: at,
            session: id,
        }
    }

    #[test]
    fn single_request_matches_sequential_shape() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::new(&cfg, 4);
        e.submit(req(0, 32, 8, 0.0));
        let done = e.run();
        assert_eq!(done.len(), 1);
        let c = &done[0];
        assert_eq!(c.tokens_out, 8);
        assert_eq!(c.queue_s, 0.0);
        assert!(c.prefill_s > 0.0 && c.decode_s > 0.0);
        let r = e.report();
        assert_eq!(r.rejected, 0);
        assert_eq!(r.max_batch_seen, 1);
        assert_eq!(r.decode_steps, 7, "n_out-1 decode iterations");
    }

    #[test]
    fn batch_overlaps_requests() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::new(&cfg, 4);
        for i in 0..4 {
            e.submit(req(i, 32, 8, 0.0));
        }
        let done = e.run();
        assert_eq!(done.len(), 4);
        assert_eq!(e.report().max_batch_seen, 4);
        // All requests share decode steps, so the batch finishes well
        // before 4× a single request's span.
        let m = ServeMetrics::from_completions(&done);
        let mut single = DeviceEngine::new(&cfg, 1);
        single.submit(req(0, 32, 8, 0.0));
        let one = ServeMetrics::from_completions(&single.run());
        assert!(m.makespan_s < 4.0 * one.makespan_s);
    }

    #[test]
    fn kv_pressure_blocks_then_frees() {
        let cfg = SimConfig::paper();
        // Room for roughly one request's window at a time.
        let per_sub = cfg.hbm.subarray_bytes() / cfg.model.kv_bytes_per_token();
        let subs_for_one = (40usize).div_ceil(per_sub);
        let mut e = DeviceEngine::new(&cfg, 8).with_kv_subarrays(subs_for_one);
        for i in 0..3 {
            e.submit(req(i, 32, 8, 0.0));
        }
        let done = e.run();
        assert_eq!(done.len(), 3, "all served once slots free");
        assert_eq!(e.report().max_batch_seen, 1, "KV cap serializes");
        assert!(e.report().kv_peak_utilization > 0.0);
    }

    #[test]
    fn impossible_request_is_rejected_not_wedged() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::new(&cfg, 2).with_kv_subarrays(1);
        let cap = KvCacheManager::with_kv_subarrays(&cfg, 1).capacity_tokens();
        e.submit(req(0, cap + 64, 64, 0.0)); // can never fit
        e.submit(req(1, 2, 2, 0.0));
        let done = e.run();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 1);
        assert_eq!(e.report().rejected, 1);
    }

    #[test]
    fn chunked_prefill_conserves_tokens() {
        // Chunking reorders time; the simulated token counts per request
        // are identical to the inline-prefill run.
        let cfg = SimConfig::paper();
        let run = |chunk: Option<usize>| -> Vec<(u64, usize)> {
            let mut e = DeviceEngine::new(&cfg, 4).with_prefill_chunk(chunk);
            e.submit(req(0, 96, 8, 0.0));
            e.submit(req(1, 32, 16, 0.0));
            e.submit(req(2, 48, 4, 0.0));
            let mut out: Vec<(u64, usize)> =
                e.run().iter().map(|c| (c.id, c.tokens_simulated)).collect();
            out.sort();
            out
        };
        assert_eq!(run(None), run(Some(16)));
        assert_eq!(run(None), run(Some(7)), "odd chunk sizes too");
    }

    #[test]
    fn gpu_backend_serves_the_same_queue() {
        let cfg = SimConfig::paper();
        let mut e = DeviceEngine::with_backend(BackendKind::Gpu.build(&cfg), 4);
        assert_eq!(e.backend_name(), "gpu");
        for i in 0..3 {
            e.submit(req(i, 32, 8, 0.0));
        }
        let done = e.run();
        assert_eq!(done.len(), 3);
        assert_eq!(e.report().rejected, 0);
    }
}
