//! Workload generation for serving experiments.
//!
//! Two layers live here:
//!
//! * the historical open-loop layer — [`ArrivalPattern`] plus
//!   [`requests_from_items`] / [`generate`] / [`generate_small`] — kept
//!   bit-identical (seeded tests pin it) because every pre-production
//!   scenario and the bench trajectory replay it exactly;
//! * [`WorkloadSpec`], the typed production workload description:
//!   arrival process × session model × length distribution × SLO mix.
//!   The legacy `--rate/--burst/--at-once/--sessions` flags desugar to
//!   a spec via [`WorkloadSpec::from_legacy`] and generate the same
//!   requests bit-for-bit (pinned by test), so the old flags are pure
//!   aliases.
//!
//! A spec renders to / parses from a compact string
//! (`poisson:8,multiturn=3:2,prefix=512:16:128,interactive=0.25`) with
//! an exact round-trip — the same string is the `--workload` CLI value
//! and the `workload` TOML key, so suite files round-trip by
//! construction.
//!
//! The multi-turn generator is *open-loop*: turn t+1 arrives an
//! exponential think-time after turn t's **arrival**, not its
//! completion (a closed loop would couple the workload to scheduler
//! quality and break replayability across engines). Prompts grow
//! turn-over-turn (previous context + previous output + the new user
//! message), and every session's first turn carries the shared-prefix
//! path (root system prompt + its group's template) that the radix
//! prefix cache deduplicates across sessions.

use super::types::{PrefixSeg, Request, SloClass};
use crate::testutil::{MixItem, RequestMix, SplitMix64};

/// How request arrivals are spaced.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// Everything at t = 0 (the saturating / closed-batch case).
    AtOnce,
    /// The historical serving mix: each gap is `jitter × scale_s`.
    Jittered { scale_s: f64 },
    /// Open-loop Poisson arrivals at `rate_rps` requests/second
    /// (exponential gaps drawn from the mix's jitter stream).
    Poisson { rate_rps: f64 },
    /// Bursts of `burst` simultaneous requests, burst starts Poisson at
    /// `rate_rps` requests/second overall.
    Bursty { rate_rps: f64, burst: usize },
}

impl ArrivalPattern {
    /// Human-readable label for tables.
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalPattern::AtOnce => "at-once",
            ArrivalPattern::Jittered { .. } => "jittered",
            ArrivalPattern::Poisson { .. } => "poisson",
            ArrivalPattern::Bursty { .. } => "bursty",
        }
    }

    /// Canonical spec-string token (`at-once`, `jittered:0.05`,
    /// `poisson:8`, `bursty:8:4`).
    fn render(&self) -> String {
        match self {
            ArrivalPattern::AtOnce => "at-once".to_string(),
            ArrivalPattern::Jittered { scale_s } => format!("jittered:{scale_s}"),
            ArrivalPattern::Poisson { rate_rps } => format!("poisson:{rate_rps}"),
            ArrivalPattern::Bursty { rate_rps, burst } => format!("bursty:{rate_rps}:{burst}"),
        }
    }

    fn parse(tok: &str) -> Result<ArrivalPattern, String> {
        let mut parts = tok.split(':');
        let head = parts.next().unwrap_or("");
        let rest: Vec<&str> = parts.collect();
        let bad = || format!("bad arrival token `{tok}` (at-once|jittered:S|poisson:R|bursty:R:B)");
        match (head, rest.len()) {
            ("at-once", 0) => Ok(ArrivalPattern::AtOnce),
            ("jittered", 1) => Ok(ArrivalPattern::Jittered {
                scale_s: rest[0].parse().map_err(|_| bad())?,
            }),
            ("poisson", 1) => {
                let rate_rps: f64 = rest[0].parse().map_err(|_| bad())?;
                if rate_rps <= 0.0 {
                    return Err(format!("arrival rate must be positive, got {rate_rps}"));
                }
                Ok(ArrivalPattern::Poisson { rate_rps })
            }
            ("bursty", 2) => {
                let rate_rps: f64 = rest[0].parse().map_err(|_| bad())?;
                if rate_rps <= 0.0 {
                    return Err(format!("arrival rate must be positive, got {rate_rps}"));
                }
                Ok(ArrivalPattern::Bursty {
                    rate_rps,
                    burst: rest[1].parse().map_err(|_| bad())?,
                })
            }
            _ => Err(bad()),
        }
    }
}

/// Inverse-CDF exponential gap from a uniform [0,1) draw.
fn exp_gap(u: f64, rate_rps: f64) -> f64 {
    debug_assert!(rate_rps > 0.0);
    -(1.0 - u).ln() / rate_rps
}

/// Turn drawn shapes into requests with `pattern` arrivals. Sessions
/// cycle over `n_sessions` (drives session-affinity routing).
pub fn requests_from_items(
    items: &[MixItem],
    pattern: ArrivalPattern,
    n_sessions: usize,
) -> Vec<Request> {
    assert!(n_sessions >= 1);
    let mut at = 0.0f64;
    items
        .iter()
        .enumerate()
        .map(|(i, item)| {
            match pattern {
                ArrivalPattern::AtOnce => {}
                ArrivalPattern::Jittered { scale_s } => at += item.jitter * scale_s,
                ArrivalPattern::Poisson { rate_rps } => at += exp_gap(item.jitter, rate_rps),
                ArrivalPattern::Bursty { rate_rps, burst } => {
                    let burst = burst.max(1);
                    if i % burst == 0 {
                        // One gap per burst keeps the overall offered
                        // rate at `rate_rps`.
                        at += exp_gap(item.jitter, rate_rps) * burst as f64;
                    }
                }
            }
            Request {
                id: i as u64,
                prompt_len: item.prompt_len,
                max_new_tokens: item.max_new_tokens,
                arrival_s: at,
                session: (i % n_sessions) as u64,
                slo: SloClass::Batch,
                prefix: Vec::new(),
            }
        })
        .collect()
}

/// `n` paper-mix requests under `pattern` (seeded, deterministic).
pub fn generate(seed: u64, n: usize, pattern: ArrivalPattern, n_sessions: usize) -> Vec<Request> {
    let items = RequestMix::paper(seed).take(n);
    requests_from_items(&items, pattern, n_sessions)
}

/// `n` small-mix requests under `pattern` (fast tests).
pub fn generate_small(
    seed: u64,
    n: usize,
    pattern: ArrivalPattern,
    n_sessions: usize,
) -> Vec<Request> {
    let items = RequestMix::small(seed).take(n);
    requests_from_items(&items, pattern, n_sessions)
}

/// Prompt/output length distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LengthModel {
    /// The historical paper mix (prompt 16–128, output 8–128).
    Paper,
    /// The trimmed test mix (prompt 16–64, output 8–32).
    Small,
    /// Heavy-tailed (Pareto, tail index α = 1.5): most requests are
    /// near the minimum, a few are huge — the agentic/chat regime.
    /// Lengths are clamped to `cap` so one draw can't exceed a
    /// device's sequence budget.
    Heavy {
        min_prompt: usize,
        min_out: usize,
        cap: usize,
    },
}

/// Pareto(min, α=1.5) draw from a uniform, clamped to `[min, cap]`.
fn pareto(u: f64, min: usize, cap: usize) -> usize {
    let x = min as f64 * (1.0 - u).powf(-1.0 / 1.5);
    (x as usize).clamp(min, cap.max(min))
}

impl LengthModel {
    fn render(&self) -> String {
        match self {
            LengthModel::Paper => "paper".to_string(),
            LengthModel::Small => "small".to_string(),
            LengthModel::Heavy {
                min_prompt,
                min_out,
                cap,
            } => format!("heavy:{min_prompt}:{min_out}:{cap}"),
        }
    }

    fn parse(tok: &str) -> Result<LengthModel, String> {
        let parts: Vec<&str> = tok.split(':').collect();
        let bad = || format!("bad lengths token `{tok}` (paper|small|heavy:MINP:MINO:CAP)");
        match parts.as_slice() {
            ["paper"] => Ok(LengthModel::Paper),
            ["small"] => Ok(LengthModel::Small),
            ["heavy", p, o, c] => {
                let min_prompt: usize = p.parse().map_err(|_| bad())?;
                let min_out: usize = o.parse().map_err(|_| bad())?;
                let cap: usize = c.parse().map_err(|_| bad())?;
                if min_prompt == 0 || min_out == 0 || cap < min_prompt || cap < min_out {
                    return Err(format!(
                        "heavy lengths need 0 < min ≤ cap, got {min_prompt}/{min_out}/{cap}"
                    ));
                }
                Ok(LengthModel::Heavy {
                    min_prompt,
                    min_out,
                    cap,
                })
            }
            _ => Err(bad()),
        }
    }

    /// `n` shapes from this model's seeded stream. Paper/Small delegate
    /// to [`RequestMix`] so legacy equivalence holds by construction.
    fn take(&self, seed: u64, n: usize) -> Vec<MixItem> {
        match self {
            LengthModel::Paper => RequestMix::paper(seed).take(n),
            LengthModel::Small => RequestMix::small(seed).take(n),
            LengthModel::Heavy {
                min_prompt,
                min_out,
                cap,
            } => {
                // Three draws per item, mirroring RequestMix's shape.
                let mut rng = SplitMix64::new(seed);
                (0..n)
                    .map(|_| {
                        let prompt_len = pareto(rng.f64_unit(), *min_prompt, *cap);
                        let max_new_tokens = pareto(rng.f64_unit(), *min_out, *cap);
                        let jitter = rng.f64_unit();
                        MixItem {
                            prompt_len,
                            max_new_tokens,
                            jitter,
                        }
                    })
                    .collect()
            }
        }
    }
}

/// The shared-prefix tree shape: one root (the system prompt all
/// sessions share) and `groups` second-level nodes (per-tenant /
/// per-template prompts); session `s` hangs under group `s % groups`.
/// Node ids are stable: root = 1, group g = 2 + g (0 is reserved for
/// "no node").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixSpec {
    pub root_tokens: usize,
    pub groups: usize,
    pub group_tokens: usize,
}

impl PrefixSpec {
    pub const fn none() -> PrefixSpec {
        PrefixSpec {
            root_tokens: 0,
            groups: 0,
            group_tokens: 0,
        }
    }

    /// The prefix path for a session, root first.
    fn path_for(&self, session: u64) -> Vec<PrefixSeg> {
        let mut p = Vec::new();
        if self.root_tokens > 0 {
            p.push(PrefixSeg {
                id: 1,
                tokens: self.root_tokens,
            });
        }
        if self.groups > 0 && self.group_tokens > 0 {
            p.push(PrefixSeg {
                id: 2 + session % self.groups as u64,
                tokens: self.group_tokens,
            });
        }
        p
    }

    fn total_tokens(&self) -> usize {
        self.root_tokens + if self.groups > 0 { self.group_tokens } else { 0 }
    }
}

/// How requests group into sessions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SessionModel {
    /// The historical model: `n` independent single-turn requests whose
    /// session ids cycle over `n_sessions` (affinity routing only).
    Cycle { n_sessions: usize },
    /// Multi-turn chat/agent sessions: the generator's `n` counts
    /// *sessions*; each runs `Geometric(mean_turns)` turns (clamped to
    /// [`MAX_TURNS`]) spaced by exponential think-time with mean
    /// `think_s`, prompts grow with the accumulated conversation, and
    /// every turn carries the session's shared-prefix path.
    MultiTurn {
        mean_turns: f64,
        think_s: f64,
        prefix: PrefixSpec,
    },
}

/// Upper clamp on the geometric turns draw — bounds one session's
/// contribution to the workload (and the prompt growth that compounds
/// with it).
pub const MAX_TURNS: usize = 64;

/// Seed salt for the control stream (session starts, SLO coin flips,
/// turn counts) so it never collides with the length stream — the
/// length stream must stay draw-for-draw identical to the legacy path.
const CTL_SALT: u64 = 0x574B_4C44_5F43_544C; // "WKLD_CTL"

/// A complete, typed workload description: arrival process × session
/// model × length distribution × SLO mix. Replaces the scattered
/// `--rate/--burst/--at-once/--sessions` flags (which now desugar to a
/// spec via [`WorkloadSpec::from_legacy`], bit-identically).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub arrival: ArrivalPattern,
    pub sessions: SessionModel,
    pub lengths: LengthModel,
    /// Fraction of traffic in the interactive SLO class ([0, 1]; the
    /// coin is per-request under `Cycle`, per-session under
    /// `MultiTurn` — a human either is or isn't on the other end).
    pub interactive_share: f64,
}

impl Default for WorkloadSpec {
    /// The legacy default workload: jittered singles over 8 sessions
    /// (what bare `sal-pim serve` always ran).
    fn default() -> Self {
        WorkloadSpec {
            arrival: ArrivalPattern::Jittered { scale_s: 0.05 },
            sessions: SessionModel::Cycle { n_sessions: 8 },
            lengths: LengthModel::Paper,
            interactive_share: 0.0,
        }
    }
}

impl WorkloadSpec {
    pub fn at_once() -> Self {
        WorkloadSpec {
            arrival: ArrivalPattern::AtOnce,
            ..WorkloadSpec::default()
        }
    }

    pub fn poisson(rate_rps: f64) -> Self {
        WorkloadSpec {
            arrival: ArrivalPattern::Poisson { rate_rps },
            ..WorkloadSpec::default()
        }
    }

    pub fn bursty(rate_rps: f64, burst: usize) -> Self {
        WorkloadSpec {
            arrival: ArrivalPattern::Bursty { rate_rps, burst },
            ..WorkloadSpec::default()
        }
    }

    pub fn with_sessions(mut self, n_sessions: usize) -> Self {
        self.sessions = SessionModel::Cycle { n_sessions };
        self
    }

    pub fn with_multi_turn(mut self, mean_turns: f64, think_s: f64) -> Self {
        let prefix = match self.sessions {
            SessionModel::MultiTurn { prefix, .. } => prefix,
            SessionModel::Cycle { .. } => PrefixSpec::none(),
        };
        self.sessions = SessionModel::MultiTurn {
            mean_turns,
            think_s,
            prefix,
        };
        self
    }

    /// Attach a shared-prefix tree (switches to multi-turn with 1 mean
    /// turn if the session model was `Cycle`).
    pub fn with_prefix(mut self, root_tokens: usize, groups: usize, group_tokens: usize) -> Self {
        let spec = PrefixSpec {
            root_tokens,
            groups,
            group_tokens,
        };
        self.sessions = match self.sessions {
            SessionModel::MultiTurn {
                mean_turns,
                think_s,
                ..
            } => SessionModel::MultiTurn {
                mean_turns,
                think_s,
                prefix: spec,
            },
            SessionModel::Cycle { .. } => SessionModel::MultiTurn {
                mean_turns: 1.0,
                think_s: 0.0,
                prefix: spec,
            },
        };
        self
    }

    pub fn with_lengths(mut self, lengths: LengthModel) -> Self {
        self.lengths = lengths;
        self
    }

    pub fn with_interactive(mut self, share: f64) -> Self {
        self.interactive_share = share;
        self
    }

    /// Desugar the legacy flag cluster. Reproduces the historical
    /// validation exactly: `burst` without `rate` and non-positive
    /// rates are the same errors the old path raised, and the
    /// resulting spec generates bit-identical requests (pinned by
    /// test).
    pub fn from_legacy(
        at_once: bool,
        rate: Option<f64>,
        burst: Option<usize>,
        n_sessions: usize,
    ) -> Result<WorkloadSpec, String> {
        let arrival = if at_once {
            ArrivalPattern::AtOnce
        } else {
            match (rate, burst) {
                (None, None) => ArrivalPattern::Jittered { scale_s: 0.05 },
                (None, Some(_)) => {
                    return Err(
                        "`burst` needs `rate` (bursty arrivals are Poisson bursts)".to_string()
                    )
                }
                (Some(rate), burst) => {
                    if rate <= 0.0 {
                        return Err(format!("arrival rate must be positive, got {rate}"));
                    }
                    match burst {
                        Some(b) => ArrivalPattern::Bursty {
                            rate_rps: rate,
                            burst: b,
                        },
                        None => ArrivalPattern::Poisson { rate_rps: rate },
                    }
                }
            }
        };
        Ok(WorkloadSpec {
            arrival,
            sessions: SessionModel::Cycle { n_sessions },
            lengths: LengthModel::Paper,
            interactive_share: 0.0,
        })
    }

    /// Human label for run titles (the arrival process dominates).
    pub fn arrival_name(&self) -> &'static str {
        self.arrival.name()
    }

    /// Canonical spec string; [`WorkloadSpec::parse`] inverts it
    /// exactly (floats use Rust's shortest round-trip formatting).
    pub fn render(&self) -> String {
        let mut s = self.arrival.render();
        match &self.sessions {
            SessionModel::Cycle { n_sessions } => s.push_str(&format!(",sessions={n_sessions}")),
            SessionModel::MultiTurn {
                mean_turns,
                think_s,
                prefix,
            } => {
                s.push_str(&format!(",multiturn={mean_turns}:{think_s}"));
                if *prefix != PrefixSpec::none() {
                    s.push_str(&format!(
                        ",prefix={}:{}:{}",
                        prefix.root_tokens, prefix.groups, prefix.group_tokens
                    ));
                }
            }
        }
        if self.lengths != LengthModel::Paper {
            s.push_str(&format!(",lengths={}", self.lengths.render()));
        }
        if self.interactive_share != 0.0 {
            s.push_str(&format!(",interactive={}", self.interactive_share));
        }
        s
    }

    /// Parse a spec string (`ARRIVAL[,key=value]*`). Unknown keys are
    /// hard errors, mirroring the suite-file parser's strictness.
    pub fn parse(s: &str) -> Result<WorkloadSpec, String> {
        let mut toks = s.split(',');
        let arrival = ArrivalPattern::parse(toks.next().unwrap_or("").trim())?;
        let mut spec = WorkloadSpec {
            arrival,
            ..WorkloadSpec::default()
        };
        let mut sessions: Option<SessionModel> = None;
        let mut prefix: Option<PrefixSpec> = None;
        for tok in toks {
            let tok = tok.trim();
            let (key, val) = tok
                .split_once('=')
                .ok_or_else(|| format!("bad workload token `{tok}` (expected key=value)"))?;
            match key {
                "sessions" => {
                    let n: usize = val
                        .parse()
                        .map_err(|_| format!("bad sessions count `{val}`"))?;
                    if n == 0 {
                        return Err("sessions must be at least 1".to_string());
                    }
                    sessions = Some(SessionModel::Cycle { n_sessions: n });
                }
                "multiturn" => {
                    let (t, th) = val.split_once(':').ok_or_else(|| {
                        format!("bad multiturn token `{val}` (expected TURNS:THINK_S)")
                    })?;
                    let mean_turns: f64 = t
                        .parse()
                        .map_err(|_| format!("bad mean turns `{t}`"))?;
                    let think_s: f64 = th
                        .parse()
                        .map_err(|_| format!("bad think time `{th}`"))?;
                    if mean_turns < 1.0 || think_s < 0.0 {
                        return Err(format!(
                            "multiturn needs mean_turns ≥ 1 and think_s ≥ 0, got {t}:{th}"
                        ));
                    }
                    sessions = Some(SessionModel::MultiTurn {
                        mean_turns,
                        think_s,
                        prefix: PrefixSpec::none(),
                    });
                }
                "prefix" => {
                    let parts: Vec<&str> = val.split(':').collect();
                    let [r, g, t] = parts.as_slice() else {
                        return Err(format!(
                            "bad prefix token `{val}` (expected ROOT:GROUPS:TOKENS)"
                        ));
                    };
                    let p = PrefixSpec {
                        root_tokens: r.parse().map_err(|_| format!("bad prefix root `{r}`"))?,
                        groups: g.parse().map_err(|_| format!("bad prefix groups `{g}`"))?,
                        group_tokens: t
                            .parse()
                            .map_err(|_| format!("bad prefix tokens `{t}`"))?,
                    };
                    if p.group_tokens > 0 && p.groups == 0 {
                        return Err("prefix group tokens need groups ≥ 1".to_string());
                    }
                    prefix = Some(p);
                }
                "lengths" => spec.lengths = LengthModel::parse(val)?,
                "interactive" => {
                    let share: f64 = val
                        .parse()
                        .map_err(|_| format!("bad interactive share `{val}`"))?;
                    if !(0.0..=1.0).contains(&share) {
                        return Err(format!(
                            "interactive share must be in [0, 1], got {share}"
                        ));
                    }
                    spec.interactive_share = share;
                }
                _ => return Err(format!("unknown workload key `{key}`")),
            }
        }
        if let Some(s) = sessions {
            spec.sessions = s;
        }
        if let Some(p) = prefix {
            match &mut spec.sessions {
                SessionModel::MultiTurn { prefix, .. } => *prefix = p,
                SessionModel::Cycle { .. } => {
                    return Err("prefix needs the multiturn session model".to_string())
                }
            }
        }
        Ok(spec)
    }

    /// Generate the workload. Under `Cycle`, `n` counts requests (the
    /// historical meaning); under `MultiTurn`, `n` counts sessions and
    /// each contributes ≥ 1 turn. Fully determined by `(seed, n,
    /// self)`.
    pub fn generate(&self, seed: u64, n: usize) -> Vec<Request> {
        match self.sessions {
            SessionModel::Cycle { n_sessions } => {
                let items = self.lengths.take(seed, n);
                let mut reqs = requests_from_items(&items, self.arrival, n_sessions);
                // The SLO coin uses a salted side stream so a zero
                // share leaves the legacy byte stream untouched.
                if self.interactive_share > 0.0 {
                    let mut ctl = SplitMix64::new(seed ^ CTL_SALT);
                    for r in &mut reqs {
                        if ctl.f64_unit() < self.interactive_share {
                            r.slo = SloClass::Interactive;
                        }
                    }
                }
                reqs
            }
            SessionModel::MultiTurn {
                mean_turns,
                think_s,
                prefix,
            } => self.generate_multi_turn(seed, n, mean_turns, think_s, prefix),
        }
    }

    /// Session loop. Control draws per session, in order: arrival
    /// uniform, SLO uniform, turns uniform (always all three, so the
    /// stream stays aligned across arrival patterns). Lengths come
    /// from the length stream, one item per turn; the item's jitter
    /// doubles as the think-time uniform for turns ≥ 2.
    fn generate_multi_turn(
        &self,
        seed: u64,
        n_sessions: usize,
        mean_turns: f64,
        think_s: f64,
        prefix: PrefixSpec,
    ) -> Vec<Request> {
        let mut ctl = SplitMix64::new(seed ^ CTL_SALT);
        let mut out: Vec<(f64, u64, usize, Request)> = Vec::with_capacity(n_sessions * 2);
        let mut session_start = 0.0f64;
        // Control draws (session starts, SLO coins, turn counts) come
        // first so the single contiguous length stream can then be
        // taken at exactly `total_turns` items.
        let mut turns = Vec::with_capacity(n_sessions);
        let mut arrivals = Vec::with_capacity(n_sessions);
        let mut slos = Vec::with_capacity(n_sessions);
        for s in 0..n_sessions {
            let arrival_u = ctl.f64_unit();
            let slo_u = ctl.f64_unit();
            let turns_u = ctl.f64_unit();
            match self.arrival {
                ArrivalPattern::AtOnce => {}
                ArrivalPattern::Jittered { scale_s } => session_start += arrival_u * scale_s,
                ArrivalPattern::Poisson { rate_rps } => {
                    session_start += exp_gap(arrival_u, rate_rps)
                }
                ArrivalPattern::Bursty { rate_rps, burst } => {
                    let burst = burst.max(1);
                    if s % burst == 0 {
                        session_start += exp_gap(arrival_u, rate_rps) * burst as f64;
                    }
                }
            }
            arrivals.push(session_start);
            slos.push(if slo_u < self.interactive_share {
                SloClass::Interactive
            } else {
                SloClass::Batch
            });
            turns.push(geometric_turns(turns_u, mean_turns));
        }
        let total_turns: usize = turns.iter().sum();
        let item_stream = self.lengths.take(seed, total_turns);
        let mut next_item = 0usize;
        for s in 0..n_sessions {
            let path = prefix.path_for(s as u64);
            let prefix_total = prefix.total_tokens();
            let mut at = arrivals[s];
            let mut context = 0usize; // accumulated conversation tokens
            for turn in 0..turns[s] {
                let item = item_stream[next_item];
                next_item += 1;
                if turn > 0 && think_s > 0.0 {
                    at += -(1.0 - item.jitter).ln() * think_s;
                }
                let prompt_len = if turn == 0 {
                    prefix_total + item.prompt_len
                } else {
                    context + item.prompt_len
                };
                context = prompt_len + item.max_new_tokens;
                out.push((
                    at,
                    s as u64,
                    turn,
                    Request {
                        id: 0, // assigned after the arrival sort
                        prompt_len,
                        max_new_tokens: item.max_new_tokens,
                        arrival_s: at,
                        session: s as u64,
                        slo: slos[s],
                        prefix: path.clone(),
                    },
                ));
            }
        }
        // Global arrival order (ties broken by session, then turn) so
        // ids are the admission order every engine sees.
        out.sort_by(|a, b| {
            a.0.total_cmp(&b.0)
                .then(a.1.cmp(&b.1))
                .then(a.2.cmp(&b.2))
        });
        out.into_iter()
            .enumerate()
            .map(|(i, (_, _, _, mut r))| {
                r.id = i as u64;
                r
            })
            .collect()
    }
}

/// Geometric turn count with the given mean, support ≥ 1, clamped to
/// [`MAX_TURNS`].
fn geometric_turns(u: f64, mean_turns: f64) -> usize {
    if mean_turns <= 1.0 {
        return 1;
    }
    let p = 1.0 / mean_turns;
    let k = 1 + ((1.0 - u).ln() / (1.0 - p).ln()).floor() as usize;
    k.clamp(1, MAX_TURNS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_once_pins_arrivals_to_zero() {
        let reqs = generate(1, 8, ArrivalPattern::AtOnce, 4);
        assert_eq!(reqs.len(), 8);
        assert!(reqs.iter().all(|r| r.arrival_s == 0.0));
        assert_eq!(reqs[5].session, 1);
    }

    #[test]
    fn poisson_arrivals_are_monotone_and_rate_scaled() {
        let slow = generate(9, 64, ArrivalPattern::Poisson { rate_rps: 10.0 }, 1);
        let fast = generate(9, 64, ArrivalPattern::Poisson { rate_rps: 1000.0 }, 1);
        for w in slow.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        // Same uniform draws, 100× the rate → exactly 100× tighter span.
        let span_slow = slow.last().unwrap().arrival_s;
        let span_fast = fast.last().unwrap().arrival_s;
        assert!(span_slow > 0.0);
        assert!((span_slow / span_fast - 100.0).abs() < 1e-6);
    }

    #[test]
    fn bursty_groups_share_an_arrival_instant() {
        let reqs = generate(
            5,
            12,
            ArrivalPattern::Bursty {
                rate_rps: 100.0,
                burst: 4,
            },
            2,
        );
        for chunk in reqs.chunks(4) {
            assert!(chunk.iter().all(|r| r.arrival_s == chunk[0].arrival_s));
        }
        assert!(reqs[4].arrival_s > reqs[3].arrival_s);
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(3, 16, ArrivalPattern::Poisson { rate_rps: 50.0 }, 8);
        let b = generate(3, 16, ArrivalPattern::Poisson { rate_rps: 50.0 }, 8);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }

    #[test]
    fn legacy_flags_desugar_bit_identically() {
        // Every legacy flag shape must generate the exact byte stream
        // the old ArrivalPattern path produced.
        let cases: Vec<(bool, Option<f64>, Option<usize>, ArrivalPattern)> = vec![
            (true, None, None, ArrivalPattern::AtOnce),
            (true, Some(8.0), Some(4), ArrivalPattern::AtOnce),
            (false, None, None, ArrivalPattern::Jittered { scale_s: 0.05 }),
            (
                false,
                Some(25.0),
                None,
                ArrivalPattern::Poisson { rate_rps: 25.0 },
            ),
            (
                false,
                Some(25.0),
                Some(4),
                ArrivalPattern::Bursty {
                    rate_rps: 25.0,
                    burst: 4,
                },
            ),
        ];
        for (at_once, rate, burst, pattern) in cases {
            let spec = WorkloadSpec::from_legacy(at_once, rate, burst, 4).unwrap();
            let new = spec.generate(42, 24);
            let old = generate(42, 24, pattern, 4);
            assert_eq!(new.len(), old.len());
            for (a, b) in new.iter().zip(&old) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.prompt_len, b.prompt_len);
                assert_eq!(a.max_new_tokens, b.max_new_tokens);
                assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
                assert_eq!(a.session, b.session);
                assert_eq!(a.slo, SloClass::Batch);
                assert!(a.prefix.is_empty());
            }
        }
        // And the two historical error shapes survive the desugar.
        assert!(WorkloadSpec::from_legacy(false, None, Some(4), 1).is_err());
        assert!(WorkloadSpec::from_legacy(false, Some(0.0), None, 1).is_err());
    }

    #[test]
    fn spec_strings_round_trip_exactly() {
        let specs = vec![
            WorkloadSpec::default(),
            WorkloadSpec::at_once().with_sessions(64),
            WorkloadSpec::poisson(12.5).with_interactive(0.25),
            WorkloadSpec::bursty(100.0, 8)
                .with_multi_turn(3.0, 2.5)
                .with_prefix(512, 16, 128)
                .with_lengths(LengthModel::Heavy {
                    min_prompt: 32,
                    min_out: 16,
                    cap: 512,
                })
                .with_interactive(0.4),
            WorkloadSpec::poisson(8.0).with_multi_turn(4.0, 0.5),
        ];
        for s in specs {
            let rendered = s.render();
            let back = WorkloadSpec::parse(&rendered)
                .unwrap_or_else(|e| panic!("parse({rendered}) failed: {e}"));
            assert_eq!(back, s, "round-trip through `{rendered}`");
            assert_eq!(back.render(), rendered, "canonical form is a fixpoint");
        }
    }

    #[test]
    fn spec_parse_rejects_malformed_input() {
        for bad in [
            "warp-speed",
            "poisson:0",
            "poisson:-3",
            "poisson:8,interactive=1.5",
            "poisson:8,sessions=0",
            "poisson:8,prefix=512:16:128", // prefix without multiturn
            "poisson:8,multiturn=0.5:1",
            "poisson:8,lengths=heavy:0:8:64",
            "poisson:8,frobnicate=1",
            "bursty:8",
        ] {
            assert!(WorkloadSpec::parse(bad).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn multi_turn_sessions_grow_and_stay_ordered() {
        let spec = WorkloadSpec::poisson(10.0)
            .with_multi_turn(3.0, 2.0)
            .with_prefix(256, 4, 64);
        let reqs = spec.generate(7, 32);
        assert!(reqs.len() >= 32, "every session contributes ≥ 1 turn");
        // Ids are the global arrival order.
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
            assert_eq!(w[1].id, w[0].id + 1);
        }
        // Per session: arrivals strictly ordered by turn, prompts grow
        // with the accumulated conversation, class/prefix constant.
        for s in 0..32u64 {
            let turns: Vec<&Request> = reqs.iter().filter(|r| r.session == s).collect();
            assert!(!turns.is_empty());
            let mut by_arrival = turns.clone();
            by_arrival.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
            for w in by_arrival.windows(2) {
                // Next turn's prompt must contain the previous turn's
                // whole conversation.
                assert!(w[1].prompt_len > w[0].prompt_len + w[0].max_new_tokens - 1);
                assert_eq!(w[1].slo, w[0].slo);
                assert_eq!(w[1].prefix, w[0].prefix);
            }
            // First turn starts at prefix + user message.
            assert!(by_arrival[0].prompt_len > by_arrival[0].prefix_tokens());
            assert_eq!(by_arrival[0].prefix_tokens(), 256 + 64);
            assert_eq!(by_arrival[0].prefix[0], PrefixSeg { id: 1, tokens: 256 });
            assert_eq!(by_arrival[0].prefix[1].id, 2 + s % 4);
        }
    }

    #[test]
    fn multi_turn_generation_is_deterministic_and_token_conserving() {
        let spec = WorkloadSpec::poisson(20.0)
            .with_multi_turn(2.5, 1.0)
            .with_prefix(128, 8, 32)
            .with_interactive(0.3)
            .with_lengths(LengthModel::Heavy {
                min_prompt: 16,
                min_out: 8,
                cap: 256,
            });
        let a = spec.generate(11, 40);
        let b = spec.generate(11, 40);
        assert_eq!(a.len(), b.len());
        let tok = |v: &[Request]| -> usize { v.iter().map(|r| r.kv_tokens()).sum() };
        assert_eq!(tok(&a), tok(&b), "token totals are seed-determined");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.slo, y.slo);
        }
        // A different seed moves the totals (the streams are live).
        assert_ne!(tok(&a), tok(&spec.generate(12, 40)));
    }

    #[test]
    fn interactive_share_is_respected_in_expectation() {
        let spec = WorkloadSpec::at_once().with_sessions(8).with_interactive(0.5);
        let reqs = spec.generate(3, 400);
        let interactive = reqs
            .iter()
            .filter(|r| r.slo == SloClass::Interactive)
            .count();
        assert!(
            (120..=280).contains(&interactive),
            "share 0.5 of 400 drew {interactive}"
        );
        // Zero share leaves everything batch (and the request stream
        // bit-identical to legacy — checked elsewhere).
        let none = WorkloadSpec::at_once().with_sessions(8).generate(3, 50);
        assert!(none.iter().all(|r| r.slo == SloClass::Batch));
    }

    #[test]
    fn heavy_lengths_are_heavy_tailed_but_capped() {
        let spec = WorkloadSpec::at_once()
            .with_sessions(1)
            .with_lengths(LengthModel::Heavy {
                min_prompt: 32,
                min_out: 8,
                cap: 1024,
            });
        let reqs = spec.generate(5, 500);
        assert!(reqs.iter().all(|r| (32..=1024).contains(&r.prompt_len)));
        assert!(reqs.iter().all(|r| (8..=1024).contains(&r.max_new_tokens)));
        let over_4x = reqs.iter().filter(|r| r.prompt_len > 128).count();
        assert!(over_4x > 0, "a Pareto tail must produce >4× draws");
        let median_band = reqs.iter().filter(|r| r.prompt_len <= 64).count();
        assert!(
            median_band > reqs.len() / 2,
            "most draws sit near the minimum"
        );
    }

    #[test]
    fn geometric_turns_clamp_and_average() {
        assert_eq!(geometric_turns(0.999999, 1.0), 1);
        assert_eq!(geometric_turns(0.9999999999, 8.0), MAX_TURNS);
        let mut rng = SplitMix64::new(1);
        let n = 4000;
        let total: usize = (0..n).map(|_| geometric_turns(rng.f64_unit(), 3.0)).sum();
        let mean = total as f64 / n as f64;
        assert!((2.6..=3.4).contains(&mean), "mean turns ≈ 3, got {mean}");
    }
}
