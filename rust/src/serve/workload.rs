//! Open-loop workload generation for serving experiments.
//!
//! Arrival processes are derived from the same deterministic
//! [`RequestMix`] stream the example and CLI consume, so a (seed, n,
//! pattern) triple fully determines the workload — routing and batching
//! comparisons replay it exactly.

use super::types::Request;
use crate::testutil::{MixItem, RequestMix};

/// How request arrivals are spaced.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// Everything at t = 0 (the saturating / closed-batch case).
    AtOnce,
    /// The historical serving mix: each gap is `jitter × scale_s`.
    Jittered { scale_s: f64 },
    /// Open-loop Poisson arrivals at `rate_rps` requests/second
    /// (exponential gaps drawn from the mix's jitter stream).
    Poisson { rate_rps: f64 },
    /// Bursts of `burst` simultaneous requests, burst starts Poisson at
    /// `rate_rps` requests/second overall.
    Bursty { rate_rps: f64, burst: usize },
}

impl ArrivalPattern {
    /// Human-readable label for tables.
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalPattern::AtOnce => "at-once",
            ArrivalPattern::Jittered { .. } => "jittered",
            ArrivalPattern::Poisson { .. } => "poisson",
            ArrivalPattern::Bursty { .. } => "bursty",
        }
    }
}

/// Inverse-CDF exponential gap from a uniform [0,1) draw.
fn exp_gap(u: f64, rate_rps: f64) -> f64 {
    debug_assert!(rate_rps > 0.0);
    -(1.0 - u).ln() / rate_rps
}

/// Turn drawn shapes into requests with `pattern` arrivals. Sessions
/// cycle over `n_sessions` (drives session-affinity routing).
pub fn requests_from_items(
    items: &[MixItem],
    pattern: ArrivalPattern,
    n_sessions: usize,
) -> Vec<Request> {
    assert!(n_sessions >= 1);
    let mut at = 0.0f64;
    items
        .iter()
        .enumerate()
        .map(|(i, item)| {
            match pattern {
                ArrivalPattern::AtOnce => {}
                ArrivalPattern::Jittered { scale_s } => at += item.jitter * scale_s,
                ArrivalPattern::Poisson { rate_rps } => at += exp_gap(item.jitter, rate_rps),
                ArrivalPattern::Bursty { rate_rps, burst } => {
                    let burst = burst.max(1);
                    if i % burst == 0 {
                        // One gap per burst keeps the overall offered
                        // rate at `rate_rps`.
                        at += exp_gap(item.jitter, rate_rps) * burst as f64;
                    }
                }
            }
            Request {
                id: i as u64,
                prompt_len: item.prompt_len,
                max_new_tokens: item.max_new_tokens,
                arrival_s: at,
                session: (i % n_sessions) as u64,
            }
        })
        .collect()
}

/// `n` paper-mix requests under `pattern` (seeded, deterministic).
pub fn generate(seed: u64, n: usize, pattern: ArrivalPattern, n_sessions: usize) -> Vec<Request> {
    let items = RequestMix::paper(seed).take(n);
    requests_from_items(&items, pattern, n_sessions)
}

/// `n` small-mix requests under `pattern` (fast tests).
pub fn generate_small(
    seed: u64,
    n: usize,
    pattern: ArrivalPattern,
    n_sessions: usize,
) -> Vec<Request> {
    let items = RequestMix::small(seed).take(n);
    requests_from_items(&items, pattern, n_sessions)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_once_pins_arrivals_to_zero() {
        let reqs = generate(1, 8, ArrivalPattern::AtOnce, 4);
        assert_eq!(reqs.len(), 8);
        assert!(reqs.iter().all(|r| r.arrival_s == 0.0));
        assert_eq!(reqs[5].session, 1);
    }

    #[test]
    fn poisson_arrivals_are_monotone_and_rate_scaled() {
        let slow = generate(9, 64, ArrivalPattern::Poisson { rate_rps: 10.0 }, 1);
        let fast = generate(9, 64, ArrivalPattern::Poisson { rate_rps: 1000.0 }, 1);
        for w in slow.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        // Same uniform draws, 100× the rate → exactly 100× tighter span.
        let span_slow = slow.last().unwrap().arrival_s;
        let span_fast = fast.last().unwrap().arrival_s;
        assert!(span_slow > 0.0);
        assert!((span_slow / span_fast - 100.0).abs() < 1e-6);
    }

    #[test]
    fn bursty_groups_share_an_arrival_instant() {
        let reqs = generate(
            5,
            12,
            ArrivalPattern::Bursty {
                rate_rps: 100.0,
                burst: 4,
            },
            2,
        );
        for chunk in reqs.chunks(4) {
            assert!(chunk.iter().all(|r| r.arrival_s == chunk[0].arrival_s));
        }
        assert!(reqs[4].arrival_s > reqs[3].arrival_s);
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(3, 16, ArrivalPattern::Poisson { rate_rps: 50.0 }, 8);
        let b = generate(3, 16, ArrivalPattern::Poisson { rate_rps: 50.0 }, 8);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }
}
