//! Dynamic phase-aware scheduling (`--schedule`): the typed [`SchedSpec`]
//! grammar, the two-pool [`PhaseSim`] router, and the offline-optimal
//! [`oracle`] baseline.
//!
//! SAL-PIM wins on memory-bound decode but loses to the GPU roofline on
//! parallel prefill, and the right placement for a request's *next phase*
//! shifts as batch composition changes (PAPI's observation). This module
//! turns the static `--backend` choice into an online decision loop:
//!
//! * [`SchedSpec`] — the user-facing schedule grammar
//!   (`POLICY[,key=value]*`, e.g. `static:salpim`, `phase`,
//!   `phase,hysteresis=2,objective=energy,power_cap=60`), with an exact
//!   `render` ⇄ `parse` round-trip mirroring
//!   [`crate::serve::WorkloadSpec`]. The legacy `--backend` flag desugars
//!   onto `static:<backend>` via [`SchedSpec::from_legacy`].
//! * [`PhaseSim`] — a GPU-class pool and a PIM-class pool behind one
//!   router. At every token boundary the router re-decides where a
//!   request's next phase (prefill admission or decode membership) should
//!   run, scoring candidates with the backends' existing cost signatures
//!   (`prefill_s` deltas vs batched `decode_step_s` marginals) plus the
//!   modeled fabric migration cost, with a hysteresis streak so KV does
//!   not thrash across the link. The energy objective folds the Fig. 15
//!   power model in ([`crate::energy::EnergyParams`]) and supports a
//!   `power_cap_w` constraint.
//! * [`oracle`] — the offline-optimal baseline: every uniform
//!   (prefill-pool, decode-pool) placement always, plus the exhaustive
//!   per-request placement space when it is small enough to brute-force
//!   ([`ORACLE_EXHAUSTIVE_MAX`]), so runs report a [`pct_of_oracle`]
//!   figure that is ≤ 100 by construction.
//!
//! [`PhaseSim`] mirrors [`crate::serve::DeviceEngine`]'s semantics where
//! they overlap — prefill completion emits the first token, decode joins
//! at the *next* boundary, chunked prefill telescopes through the same
//! `prefill_s(to) - prefill_s(from)` charging rule — but models each pool
//! as one batched boundary clock so the oracle stays brute-forceable.

use std::cmp::Ordering;

use super::backend::{BackendKind, ExecutionBackend};
use super::engine::prefill_increment_s;
use super::fabric::{Fabric, FabricParams};
use super::policy::Policy;
use super::types::{Completion, Request};
use crate::config::SimConfig;
use crate::energy::EnergyParams;

/// Default hysteresis: a decode migration needs this many *additional*
/// consecutive boundaries where the other pool scores strictly better
/// (so `2` means three wins in a row) before KV moves.
pub const DEFAULT_HYSTERESIS: u32 = 2;

/// Device power of one GPU-class pool member (Titan RTX board power, W),
/// the GPU side of the energy objective. The PIM side comes from the
/// Fig. 15 model via [`EnergyParams::pim_device_power_w`].
pub const GPU_CLASS_POWER_W: f64 = 280.0;

/// The oracle brute-forces per-request placements only while
/// `4^n_requests` stays at or under this bound (n ≤ 5); larger traces
/// fall back to the four uniform placements.
pub const ORACLE_EXHAUSTIVE_MAX: usize = 1024;

/// Additive score penalty for migrating into a pool with no free batch
/// slot — large enough to dominate any real latency/energy score, finite
/// so a doubly-penalized comparison still orders.
const POOL_FULL_PENALTY: f64 = 1e12;

/// Additive score penalty for a candidate whose projected cluster power
/// exceeds `power_cap`. Dominates [`POOL_FULL_PENALTY`].
const CAP_PENALTY: f64 = 1e18;

/// The schedule policy head of a [`SchedSpec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedPolicy {
    /// Pin every phase of every request to one backend — exactly
    /// today's `--backend` behavior, by construction.
    Static(BackendKind),
    /// Re-decide the pool for each request's next phase at every token
    /// boundary.
    #[default]
    Phase,
}

/// What the router (and the oracle) minimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Objective {
    /// Mean request latency (queue + prefill + decode).
    #[default]
    Latency,
    /// Modeled energy (J): busy device-power × time, plus per-migration
    /// IO energy at the Fig. 15 `e_io` rate.
    Energy,
}

impl Objective {
    pub fn name(self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Energy => "energy",
        }
    }

    pub fn parse(s: &str) -> Result<Objective, String> {
        match s {
            "latency" => Ok(Objective::Latency),
            "energy" => Ok(Objective::Energy),
            _ => Err(format!(
                "unknown objective `{s}` (latency|energy){}",
                crate::cli::suggest(s, ["latency", "energy"].into_iter(), "")
            )),
        }
    }
}

/// Typed schedule specification — the `--schedule` / suite-TOML
/// `schedule =` surface.
///
/// Grammar: `POLICY[,key=value]*` where `POLICY` is `static:<backend>`
/// or `phase`, and the keys are `hysteresis` (token boundaries),
/// `objective` (`latency`|`energy`) and `power_cap` (watts; requires
/// `objective=energy`). [`SchedSpec::render`] emits the minimal string
/// (defaults elided) and [`SchedSpec::parse`] accepts it back exactly,
/// so specs round-trip bit-identically through suite files. The keys
/// parse on a `static:` head too but are inert there — a static
/// schedule never routes.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedSpec {
    pub policy: SchedPolicy,
    pub hysteresis: u32,
    pub objective: Objective,
    pub power_cap_w: Option<f64>,
}

impl Default for SchedSpec {
    fn default() -> Self {
        SchedSpec {
            policy: SchedPolicy::Phase,
            hysteresis: DEFAULT_HYSTERESIS,
            objective: Objective::Latency,
            power_cap_w: None,
        }
    }
}

impl SchedSpec {
    /// The schedule the legacy `--backend` flag desugars onto:
    /// `static:<backend>` with every knob at its default.
    pub fn from_legacy(backend: BackendKind) -> SchedSpec {
        SchedSpec {
            policy: SchedPolicy::Static(backend),
            ..SchedSpec::default()
        }
    }

    /// Render the canonical spec string (defaults elided). Exact
    /// inverse of [`SchedSpec::parse`] for every spec `parse` accepts.
    pub fn render(&self) -> String {
        let mut s = match self.policy {
            SchedPolicy::Static(b) => format!("static:{}", b.name()),
            SchedPolicy::Phase => "phase".to_string(),
        };
        if self.hysteresis != DEFAULT_HYSTERESIS {
            s.push_str(&format!(",hysteresis={}", self.hysteresis));
        }
        if self.objective != Objective::Latency {
            s.push_str(&format!(",objective={}", self.objective.name()));
        }
        if let Some(w) = self.power_cap_w {
            s.push_str(&format!(",power_cap={w}"));
        }
        s
    }

    /// Parse a spec string (see the type docs for the grammar).
    pub fn parse(s: &str) -> Result<SchedSpec, String> {
        let mut toks = s.split(',');
        let head = toks.next().unwrap_or("").trim();
        let policy = if head == "phase" {
            SchedPolicy::Phase
        } else if let Some(rest) = head.strip_prefix("static:") {
            SchedPolicy::Static(BackendKind::parse(rest.trim())?)
        } else if head == "static" {
            return Err(
                "static needs a backend: static:<salpim|gpu|banklevel|hetero>".to_string(),
            );
        } else {
            return Err(format!(
                "unknown schedule policy `{head}` (static:<backend>|phase){}",
                crate::cli::suggest(head, ["phase", "static"].into_iter(), "")
            ));
        };
        let mut spec = SchedSpec {
            policy,
            ..SchedSpec::default()
        };
        for tok in toks {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let Some((key, val)) = tok.split_once('=') else {
                return Err(format!("bad schedule token `{tok}` (expected key=value)"));
            };
            let (key, val) = (key.trim(), val.trim());
            match key {
                "hysteresis" => {
                    spec.hysteresis = val.parse().map_err(|_| {
                        format!("bad hysteresis `{val}` (whole token-boundary count)")
                    })?;
                }
                "objective" => spec.objective = Objective::parse(val)?,
                "power_cap" => {
                    let w: f64 = val
                        .parse()
                        .map_err(|_| format!("bad power_cap `{val}` (watts)"))?;
                    if !w.is_finite() || w <= 0.0 {
                        return Err(format!("power_cap must be a positive wattage, got `{val}`"));
                    }
                    spec.power_cap_w = Some(w);
                }
                _ => {
                    return Err(format!(
                        "unknown schedule key `{key}`{}",
                        crate::cli::suggest(
                            key,
                            ["hysteresis", "objective", "power_cap"].into_iter(),
                            ""
                        )
                    ));
                }
            }
        }
        if spec.power_cap_w.is_some() && spec.objective == Objective::Latency {
            return Err("power_cap needs objective=energy (the latency objective never reads \
                        modeled power — drop the cap or add objective=energy)"
                .to_string());
        }
        Ok(spec)
    }
}

/// Which pool a phase runs on. `Gpu` is pool 0 (devices
/// `0..gpu_devices`), `Pim` pool 1 (devices `gpu_devices..`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Loc {
    Gpu,
    Pim,
}

impl Loc {
    pub const BOTH: [Loc; 2] = [Loc::Gpu, Loc::Pim];

    pub fn idx(self) -> usize {
        match self {
            Loc::Gpu => 0,
            Loc::Pim => 1,
        }
    }

    pub fn other(self) -> Loc {
        match self {
            Loc::Gpu => Loc::Pim,
            Loc::Pim => Loc::Gpu,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Loc::Gpu => "gpu-pool",
            Loc::Pim => "pim-pool",
        }
    }
}

/// Shape of the two-pool cluster [`PhaseSim`] schedules over.
#[derive(Debug, Clone, Copy)]
pub struct PhaseTopology {
    /// GPU-class devices (pool 0). Must be ≥ 1.
    pub gpu_devices: usize,
    /// PIM-class devices (pool 1). Must be ≥ 1.
    pub pim_devices: usize,
    /// Batch slots per device; a pool's admission capacity is
    /// `devices × max_batch`.
    pub max_batch: usize,
    /// Host link KV migrations are charged against.
    pub fabric: FabricParams,
    /// Admission order within each pool's queue.
    pub policy: Policy,
    /// Chunked prefill (tokens per boundary); `None` = whole-prompt.
    pub prefill_chunk: Option<usize>,
}

impl PhaseTopology {
    /// A topology with PCIe fabric, FCFS admission and unchunked
    /// prefill — override fields for anything else.
    pub fn new(gpu_devices: usize, pim_devices: usize, max_batch: usize) -> Self {
        PhaseTopology {
            gpu_devices,
            pim_devices,
            max_batch,
            fabric: FabricParams::pcie(),
            policy: Policy::Fcfs,
            prefill_chunk: None,
        }
    }
}

/// Where a request is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Stage {
    NotArrived,
    Queued,
    Prefilling,
    Decoding,
    /// KV in flight over the fabric; lands at the target pool no
    /// earlier than `until_s`.
    Migrating { until_s: f64 },
    Done,
}

/// Per-request router state.
#[derive(Debug, Clone)]
struct Flight {
    req: Request,
    pool: Loc,
    stage: Stage,
    admit_s: f64,
    first_token_s: f64,
    prefill_done: usize,
    produced: usize,
    /// Consecutive boundaries where the other pool scored strictly
    /// better (the hysteresis counter).
    streak: u32,
    prefill_pool: Loc,
    decode_pool: Option<Loc>,
}

impl Flight {
    /// KV tokens currently pinned (prompt + produced tokens).
    fn kv_len(&self) -> usize {
        self.req.prompt_len + self.produced
    }
}

/// One pool: a batched boundary clock over `n_devices` identical
/// devices sharing one (memoized) cost model.
struct PoolSim {
    backend: Box<dyn ExecutionBackend>,
    n_devices: usize,
    max_batch: usize,
    clock_s: f64,
    /// Flight indices admitted to the pool (prefilling or decoding).
    resident: Vec<usize>,
    /// Flight indices routed here but not yet admitted.
    queue: Vec<usize>,
    /// Power of one busy device (W) under the energy objective.
    device_power_w: f64,
    busy_s: f64,
    /// First global device index of the pool (completion attribution).
    device_base: usize,
}

impl PoolSim {
    fn capacity(&self) -> usize {
        self.n_devices * self.max_batch
    }

    fn has_work(&self) -> bool {
        !self.resident.is_empty() || !self.queue.is_empty()
    }
}

/// The two-pool phase scheduler / oracle evaluation engine.
///
/// One instance is reusable: [`PhaseSim::run`] resets all mutable state
/// first, so the oracle can sweep hundreds of forced placements over
/// the same (memoized) backends. With [`PhaseSim::set_placement`] the
/// router is bypassed and every request's (prefill, decode) pools come
/// from the given placement — that is how the oracle and the static
/// baselines are evaluated on identical ground.
pub struct PhaseSim {
    spec: SchedSpec,
    topo: PhaseTopology,
    pools: [PoolSim; 2],
    fabric: Fabric,
    kv_bytes_per_token: usize,
    max_seq: usize,
    e_io_pj_bit: f64,
    energy_j: f64,
    migrations: u64,
    completions: Vec<Completion>,
    flights: Vec<Flight>,
    forced: Option<Vec<(Loc, Loc)>>,
}

/// What one [`PhaseSim::run`] produced.
#[derive(Debug, Clone)]
pub struct PhaseOutcome {
    /// Every finished request, sorted by (finish, id). `queue_s +
    /// prefill_s + decode_s` tiles `[arrival, finish]` exactly, like
    /// every other serving path.
    pub completions: Vec<Completion>,
    /// Decode-phase KV migrations the router ordered.
    pub router_migrations: u64,
    /// Bytes moved over the fabric by those migrations.
    pub migrated_bytes: u64,
    /// Modeled energy (J): busy device-power × time + migration IO.
    pub energy_j: f64,
    /// `energy_j / makespan_s` (0 when nothing ran).
    pub avg_power_w: f64,
    /// Latest completion time (s).
    pub makespan_s: f64,
    /// Mean total request latency (s).
    pub mean_latency_s: f64,
    /// The spec's objective value: `mean_latency_s` under `latency`,
    /// `energy_j` under `energy`. Lower is better; feeds
    /// [`pct_of_oracle`].
    pub objective: f64,
    /// Realized (prefill pool, decode pool) per request, input order.
    pub placement: Vec<(Loc, Loc)>,
}

impl PhaseSim {
    /// Build the two pools: a GPU roofline pool and a SAL-PIM pool.
    /// KV geometry (bytes/token, max seq) comes from the PIM device so
    /// migration sizes match the decode pool that holds the KV longest.
    pub fn new(cfg: &SimConfig, spec: SchedSpec, topo: PhaseTopology) -> Self {
        assert!(
            topo.gpu_devices >= 1 && topo.pim_devices >= 1,
            "phase scheduling needs both pools populated"
        );
        assert!(topo.max_batch >= 1, "max_batch must be at least 1");
        let params = EnergyParams::paper();
        let gpu_backend = BackendKind::Gpu.build(cfg);
        let pim_backend = BackendKind::SalPim.build(cfg);
        let kv_bytes_per_token = pim_backend.capacity().kv_bytes_per_token;
        let pools = [
            PoolSim {
                backend: gpu_backend,
                n_devices: topo.gpu_devices,
                max_batch: topo.max_batch,
                clock_s: 0.0,
                resident: Vec::new(),
                queue: Vec::new(),
                device_power_w: GPU_CLASS_POWER_W,
                busy_s: 0.0,
                device_base: 0,
            },
            PoolSim {
                backend: pim_backend,
                n_devices: topo.pim_devices,
                max_batch: topo.max_batch,
                clock_s: 0.0,
                resident: Vec::new(),
                queue: Vec::new(),
                device_power_w: params.pim_device_power_w(cfg),
                busy_s: 0.0,
                device_base: topo.gpu_devices,
            },
        ];
        PhaseSim {
            spec,
            topo,
            pools,
            fabric: Fabric::new(topo.fabric),
            kv_bytes_per_token,
            max_seq: cfg.model.max_seq,
            e_io_pj_bit: params.e_io_pj_bit,
            energy_j: 0.0,
            migrations: 0,
            completions: Vec::new(),
            flights: Vec::new(),
            forced: None,
        }
    }

    /// Force every request's (prefill pool, decode pool) instead of
    /// routing dynamically (`None` restores the router). Indexed by
    /// request input order; the oracle sweeps placements through this.
    pub fn set_placement(&mut self, placement: Option<Vec<(Loc, Loc)>>) {
        self.forced = placement;
    }

    fn reset(&mut self, requests: &[Request]) {
        for p in &mut self.pools {
            p.clock_s = 0.0;
            p.resident.clear();
            p.queue.clear();
            p.busy_s = 0.0;
        }
        self.fabric = Fabric::new(self.topo.fabric);
        self.energy_j = 0.0;
        self.migrations = 0;
        self.completions.clear();
        self.flights = requests
            .iter()
            .map(|r| Flight {
                req: r.clone(),
                pool: Loc::Gpu,
                stage: Stage::NotArrived,
                admit_s: 0.0,
                first_token_s: 0.0,
                prefill_done: 0,
                produced: 0,
                streak: 0,
                prefill_pool: Loc::Gpu,
                decode_pool: None,
            })
            .collect();
        if let Some(p) = &self.forced {
            assert_eq!(
                p.len(),
                requests.len(),
                "forced placement must cover every request"
            );
        }
    }

    /// Serve `requests` to completion and report the outcome. Resets
    /// all mutable state first, so repeated runs are independent (the
    /// memoized backend costs never change a value, only its price).
    pub fn run(&mut self, requests: &[Request]) -> PhaseOutcome {
        self.reset(requests);
        let mut order: Vec<usize> = (0..self.flights.len()).collect();
        order.sort_by(|&a, &b| {
            self.flights[a]
                .req
                .arrival_s
                .total_cmp(&self.flights[b].req.arrival_s)
                .then(self.flights[a].req.id.cmp(&self.flights[b].req.id))
        });
        let mut next_arr = 0usize;
        loop {
            // Earliest event wins; kind breaks time ties
            // deterministically (arrival < landing < gpu < pim step).
            let mut best: Option<(f64, u8, usize)> = None;
            if next_arr < order.len() {
                let i = order[next_arr];
                consider(&mut best, (self.flights[i].req.arrival_s, 0, i));
            }
            for (i, f) in self.flights.iter().enumerate() {
                if let Stage::Migrating { until_s } = f.stage {
                    // A landing is its own event only when the target
                    // pool is idle; busy pools absorb landings at their
                    // next boundary (step 0 of `step_pool`).
                    let p = &self.pools[f.pool.idx()];
                    if !p.has_work() {
                        consider(&mut best, (until_s.max(p.clock_s), 1, i));
                    }
                }
            }
            for (pi, p) in self.pools.iter().enumerate() {
                if p.has_work() {
                    consider(&mut best, (p.clock_s, 2 + pi as u8, pi));
                }
            }
            let Some((t, kind, payload)) = best else {
                break;
            };
            match kind {
                0 => {
                    next_arr += 1;
                    self.admit_arrival(payload, t);
                }
                1 => {
                    let pi = self.flights[payload].pool.idx();
                    self.pools[pi].clock_s = t;
                    self.flights[payload].stage = Stage::Decoding;
                    self.pools[pi].resident.push(payload);
                }
                k => self.step_pool(usize::from(k) - 2),
            }
        }
        self.outcome()
    }

    /// Route an arriving request to a pool's admission queue.
    fn admit_arrival(&mut self, i: usize, t: f64) {
        let loc = match &self.forced {
            Some(p) => p[i].0,
            None => self.route_prefill(i),
        };
        let f = &mut self.flights[i];
        f.pool = loc;
        f.prefill_pool = loc;
        f.stage = Stage::Queued;
        let p = &mut self.pools[loc.idx()];
        if !p.has_work() {
            // An idle pool's clock jumps to the arrival; a busy pool
            // admits at its next natural boundary.
            p.clock_s = p.clock_s.max(t);
        }
        p.queue.push(i);
    }

    /// One token boundary of pool `pi`: land migrations, admit, run a
    /// prefill-chunk + batched-decode step, retire, and let the router
    /// re-place what remains.
    fn step_pool(&mut self, pi: usize) {
        let t0 = self.pools[pi].clock_s;
        let loc = Loc::BOTH[pi];

        // (0) Land migrated KV whose transfer finished by this boundary.
        let landing: Vec<usize> = self
            .flights
            .iter()
            .enumerate()
            .filter(|(_, f)| {
                f.pool == loc && matches!(f.stage, Stage::Migrating { until_s } if until_s <= t0)
            })
            .map(|(i, _)| i)
            .collect();
        for i in landing {
            self.flights[i].stage = Stage::Decoding;
            self.pools[pi].resident.push(i);
        }

        // (1) Admit from the queue in policy order while slots remain.
        loop {
            let p = &self.pools[pi];
            if p.resident.len() >= p.capacity() || p.queue.is_empty() {
                break;
            }
            let waiting: Vec<Request> = p
                .queue
                .iter()
                .map(|&i| self.flights[i].req.clone())
                .collect();
            let pick = self.topo.policy.pick(&waiting);
            let i = self.pools[pi].queue.remove(pick);
            let f = &mut self.flights[i];
            f.stage = Stage::Prefilling;
            f.admit_s = t0;
            self.pools[pi].resident.push(i);
        }

        let n_dev = self.pools[pi].n_devices;

        // (2) Prefill chunks, round-robin across the pool's devices;
        // the boundary waits for the slowest device (max of sums).
        let chunk = self.topo.prefill_chunk.unwrap_or(usize::MAX).max(1);
        let prefilling: Vec<usize> = self.pools[pi]
            .resident
            .iter()
            .copied()
            .filter(|&i| self.flights[i].stage == Stage::Prefilling)
            .collect();
        let mut dev_sums = vec![0.0f64; n_dev];
        let mut finished_prefill: Vec<usize> = Vec::new();
        for (j, &i) in prefilling.iter().enumerate() {
            let (from, to, prompt) = {
                let f = &self.flights[i];
                let from = f.prefill_done;
                (
                    from,
                    from.saturating_add(chunk).min(f.req.prompt_len),
                    f.req.prompt_len,
                )
            };
            dev_sums[j % n_dev] += prefill_increment_s(self.pools[pi].backend.as_mut(), from, to);
            let f = &mut self.flights[i];
            f.prefill_done = to;
            if to == prompt {
                // Prefill completion emits the first token.
                f.produced = 1;
                finished_prefill.push(i);
            }
        }
        let prefill_time = dev_sums.iter().copied().fold(0.0f64, f64::max);

        // (3) One batched decode step over the already-decoding
        // residents, round-robin grouped per device.
        let decoding: Vec<usize> = self.pools[pi]
            .resident
            .iter()
            .copied()
            .filter(|&i| self.flights[i].stage == Stage::Decoding)
            .collect();
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n_dev];
        for (j, &i) in decoding.iter().enumerate() {
            groups[j % n_dev].push(self.flights[i].kv_len());
        }
        let mut decode_time = 0.0f64;
        for g in groups.iter().filter(|g| !g.is_empty()) {
            decode_time = decode_time.max(self.pools[pi].backend.decode_step_s(g));
        }
        for &i in &decoding {
            self.flights[i].produced += 1;
        }

        let dt = prefill_time + decode_time;
        if dt > 0.0 {
            let used = prefilling.len().max(decoding.len()).clamp(1, n_dev) as f64;
            self.energy_j += dt * self.pools[pi].device_power_w * used;
            self.pools[pi].busy_s += dt;
        }
        let t1 = t0 + dt;
        self.pools[pi].clock_s = t1;

        // (2b) First tokens land at the boundary; decode joins the
        // *next* boundary (DeviceEngine semantics).
        for &i in &finished_prefill {
            let f = &mut self.flights[i];
            f.first_token_s = t1;
            f.stage = Stage::Decoding;
        }

        // (4) Retire finished requests.
        let mut r = 0;
        while r < self.pools[pi].resident.len() {
            let i = self.pools[pi].resident[r];
            let f = &self.flights[i];
            let done = f.stage == Stage::Decoding
                && (f.produced >= f.req.max_new_tokens || f.kv_len() >= self.max_seq);
            if !done {
                r += 1;
                continue;
            }
            self.pools[pi].resident.remove(r);
            let device = self.pools[pi].device_base;
            let f = &mut self.flights[i];
            f.stage = Stage::Done;
            self.completions.push(Completion {
                id: f.req.id,
                prompt_len: f.req.prompt_len,
                // Reported budget vs exact simulated count, mirroring
                // `DeviceEngine` (max_seq truncation stops the clock,
                // not the reported count).
                tokens_out: f.req.max_new_tokens,
                tokens_simulated: f.produced,
                queue_s: f.admit_s - f.req.arrival_s,
                prefill_s: f.first_token_s - f.admit_s,
                decode_s: t1 - f.first_token_s,
                finish_s: t1,
                device,
                slo: f.req.slo,
            });
        }

        // (5a) Place the decode phase of requests that just finished
        // prefill (fresh decision, no hysteresis — this is the
        // prefill→decode handoff).
        for &i in &finished_prefill {
            if self.flights[i].stage == Stage::Done {
                continue;
            }
            let target = match &self.forced {
                Some(p) => p[i].1,
                None => self.best_decode_pool(i, t1),
            };
            self.flights[i].decode_pool = Some(target);
            if target != loc {
                self.migrate(i, t1, target);
            }
        }

        // (5b) Dynamic mode: re-score the other decoding residents;
        // migrate only after the other pool wins `hysteresis + 1`
        // boundaries in a row.
        if self.forced.is_none() && self.spec.policy == SchedPolicy::Phase {
            let rescore: Vec<usize> = self.pools[pi]
                .resident
                .iter()
                .copied()
                .filter(|&i| self.flights[i].stage == Stage::Decoding)
                .filter(|i| !finished_prefill.contains(i))
                .collect();
            for i in rescore {
                let stay = self.decode_score(i, loc, t1);
                let go = self.decode_score(i, loc.other(), t1);
                if go < stay {
                    self.flights[i].streak += 1;
                } else {
                    self.flights[i].streak = 0;
                }
                if self.flights[i].streak > self.spec.hysteresis {
                    self.flights[i].streak = 0;
                    self.flights[i].decode_pool = Some(loc.other());
                    self.migrate(i, t1, loc.other());
                }
            }
        }
    }

    /// Move a request's KV to `target`: charge the fabric, pay IO
    /// energy, and put the flight in flight until the transfer lands.
    fn migrate(&mut self, i: usize, t: f64, target: Loc) {
        let cur = self.flights[i].pool;
        let bytes = self.flights[i].kv_len() * self.kv_bytes_per_token;
        let dt = self.fabric.transfer(t, bytes);
        self.energy_j += bytes as f64 * 8.0 * self.e_io_pj_bit * 1e-12;
        self.migrations += 1;
        self.pools[cur.idx()].resident.retain(|&j| j != i);
        let f = &mut self.flights[i];
        f.pool = target;
        f.stage = Stage::Migrating { until_s: t + dt };
    }

    fn device_power_w(&self, loc: Loc) -> f64 {
        self.pools[loc.idx()].device_power_w
    }

    /// Marginal cost of adding a `kv`-length request to the decode
    /// group it would round-robin into on `loc` (excluding itself when
    /// scoring "stay").
    fn marginal_step(&mut self, loc: Loc, kv: usize, exclude: Option<usize>) -> f64 {
        let pi = loc.idx();
        let n_dev = self.pools[pi].n_devices;
        let lens: Vec<usize> = self.pools[pi]
            .resident
            .iter()
            .copied()
            .filter(|&i| Some(i) != exclude && self.flights[i].stage == Stage::Decoding)
            .map(|i| self.flights[i].kv_len())
            .collect();
        let g = lens.len() % n_dev;
        let mut group: Vec<usize> = lens
            .iter()
            .enumerate()
            .filter(|(j, _)| j % n_dev == g)
            .map(|(_, &l)| l)
            .collect();
        let base = if group.is_empty() {
            0.0
        } else {
            self.pools[pi].backend.decode_step_s(&group)
        };
        group.push(kv);
        (self.pools[pi].backend.decode_step_s(&group) - base).max(0.0)
    }

    /// Score running a request's whole life on `loc` at arrival time:
    /// prefill + estimated decode at mid-life KV, inflated by queue
    /// congestion (latency) or priced at device power (energy).
    fn prefill_score(&mut self, loc: Loc, i: usize) -> f64 {
        let (prompt, max_new) = {
            let r = &self.flights[i].req;
            (r.prompt_len, r.max_new_tokens)
        };
        let pi = loc.idx();
        let congestion = {
            let p = &self.pools[pi];
            (p.resident.len() + p.queue.len()) as f64 / p.capacity() as f64
        };
        let prefill = self.pools[pi].backend.prefill_s(prompt);
        let marginal = self.marginal_step(loc, prompt + max_new / 2, None);
        let service = prefill + marginal * max_new.saturating_sub(1) as f64;
        match self.spec.objective {
            Objective::Latency => service * (1.0 + congestion),
            Objective::Energy => {
                let mut score = service * self.device_power_w(loc);
                if self.cap_violated(loc) {
                    score += CAP_PENALTY;
                }
                score
            }
        }
    }

    fn route_prefill(&mut self, i: usize) -> Loc {
        let gpu = self.prefill_score(Loc::Gpu, i);
        let pim = self.prefill_score(Loc::Pim, i);
        // Strict win moves off the GPU pool; ties stay (deterministic).
        if pim < gpu {
            Loc::Pim
        } else {
            Loc::Gpu
        }
    }

    /// Score finishing a request's decode on `cand`: remaining tokens ×
    /// marginal step cost, plus the fabric migration price (latency) or
    /// IO energy (energy) when `cand` is not the current pool.
    fn decode_score(&mut self, i: usize, cand: Loc, t: f64) -> f64 {
        let cur = self.flights[i].pool;
        let (remaining, kv) = {
            let f = &self.flights[i];
            (f.req.max_new_tokens.saturating_sub(f.produced), f.kv_len())
        };
        let moving = cand != cur;
        let bytes = kv * self.kv_bytes_per_token;
        let mig_s = if moving {
            self.fabric.peek_transfer_s(t, bytes)
        } else {
            0.0
        };
        let exclude = if moving { None } else { Some(i) };
        let marginal = self.marginal_step(cand, kv, exclude);
        let mut full_penalty = 0.0;
        if moving {
            let p = &self.pools[cand.idx()];
            if p.resident.len() >= p.capacity() {
                full_penalty = POOL_FULL_PENALTY;
            }
        }
        match self.spec.objective {
            Objective::Latency => mig_s + remaining as f64 * marginal + full_penalty,
            Objective::Energy => {
                let mig_j = if moving {
                    bytes as f64 * 8.0 * self.e_io_pj_bit * 1e-12
                } else {
                    0.0
                };
                let mut score =
                    mig_j + remaining as f64 * marginal * self.device_power_w(cand) + full_penalty;
                if self.cap_violated(cand) {
                    score += CAP_PENALTY;
                }
                score
            }
        }
    }

    fn best_decode_pool(&mut self, i: usize, t: f64) -> Loc {
        let cur = self.flights[i].pool;
        let stay = self.decode_score(i, cur, t);
        let go = self.decode_score(i, cur.other(), t);
        // Strict win required to move: ties never migrate KV.
        if go < stay {
            cur.other()
        } else {
            cur
        }
    }

    /// Would routing one more request to `extra` push the projected
    /// cluster power (busy devices × device power) over `power_cap`?
    fn cap_violated(&self, extra: Loc) -> bool {
        let Some(cap) = self.spec.power_cap_w else {
            return false;
        };
        let mut total = 0.0;
        for (pi, p) in self.pools.iter().enumerate() {
            let mut load = p.resident.len() + p.queue.len();
            if Loc::BOTH[pi] == extra {
                load += 1;
            }
            total += load.min(p.n_devices) as f64 * p.device_power_w;
        }
        total > cap
    }

    fn outcome(&mut self) -> PhaseOutcome {
        let mut completions = std::mem::take(&mut self.completions);
        completions.sort_by(|a, b| a.finish_s.total_cmp(&b.finish_s).then(a.id.cmp(&b.id)));
        let makespan_s = completions
            .iter()
            .map(|c| c.finish_s)
            .fold(0.0f64, f64::max);
        let mean_latency_s = if completions.is_empty() {
            0.0
        } else {
            completions.iter().map(|c| c.total_latency_s()).sum::<f64>() / completions.len() as f64
        };
        let objective = match self.spec.objective {
            Objective::Latency => mean_latency_s,
            Objective::Energy => self.energy_j,
        };
        let placement = self
            .flights
            .iter()
            .map(|f| (f.prefill_pool, f.decode_pool.unwrap_or(f.prefill_pool)))
            .collect();
        PhaseOutcome {
            completions,
            router_migrations: self.migrations,
            migrated_bytes: self.fabric.migrated_bytes(),
            energy_j: self.energy_j,
            avg_power_w: if makespan_s > 0.0 {
                self.energy_j / makespan_s
            } else {
                0.0
            },
            makespan_s,
            mean_latency_s,
            objective,
            placement,
        }
    }
}

fn consider(best: &mut Option<(f64, u8, usize)>, cand: (f64, u8, usize)) {
    let replace = match best {
        None => true,
        Some(b) => match cand.0.total_cmp(&b.0) {
            Ordering::Less => true,
            Ordering::Greater => false,
            Ordering::Equal => (cand.1, cand.2) < (b.1, b.2),
        },
    };
    if replace {
        *best = Some(cand);
    }
}

/// The offline-optimal baseline's result.
#[derive(Debug, Clone, Copy)]
pub struct OracleReport {
    /// Best objective over every candidate placement evaluated (plus
    /// the `also` values folded in) — the oracle's score.
    pub objective: f64,
    /// Best objective over the four *uniform* (prefill, decode)
    /// placements: the best any static schedule could do.
    pub best_static_objective: f64,
    /// Candidate placements (and folded values) considered.
    pub candidates: usize,
    /// Whether the full `4^n` per-request placement space was searched
    /// (n small enough), or only the uniform placements.
    pub exhaustive: bool,
}

/// Offline-optimal placement search over a recorded arrival trace.
///
/// Always evaluates the four uniform placements (every request prefills
/// on pool P and decodes on pool D), brute-forces all `4^n` per-request
/// placements when that stays at or under [`ORACLE_EXHAUSTIVE_MAX`],
/// and folds the realized objectives in `also` (e.g. the dynamic
/// router's own run) into the minimum. Because the candidate set
/// contains every uniform placement *and* every `also` value,
/// [`pct_of_oracle`] is ≤ 100 for each of them by construction — the
/// oracle itself scores exactly 100.
pub fn oracle(
    cfg: &SimConfig,
    spec: &SchedSpec,
    topo: &PhaseTopology,
    requests: &[Request],
    also: &[f64],
) -> OracleReport {
    let mut sim = PhaseSim::new(cfg, spec.clone(), *topo);
    let n = requests.len();
    let uniform = [
        (Loc::Gpu, Loc::Gpu),
        (Loc::Gpu, Loc::Pim),
        (Loc::Pim, Loc::Gpu),
        (Loc::Pim, Loc::Pim),
    ];
    let mut candidates = 0usize;
    let mut best_static = f64::INFINITY;
    let mut best = f64::INFINITY;
    for (p, d) in uniform {
        sim.set_placement(Some(vec![(p, d); n]));
        let obj = sim.run(requests).objective;
        candidates += 1;
        best_static = best_static.min(obj);
        best = best.min(obj);
    }
    let exhaustive = 4usize
        .checked_pow(n as u32)
        .is_some_and(|t| t <= ORACLE_EXHAUSTIVE_MAX);
    if exhaustive {
        for mask in 0..4usize.pow(n as u32) {
            let placement: Vec<(Loc, Loc)> = (0..n)
                .map(|r| {
                    let c = (mask >> (2 * r)) & 3;
                    (
                        if c & 1 == 0 { Loc::Gpu } else { Loc::Pim },
                        if c & 2 == 0 { Loc::Gpu } else { Loc::Pim },
                    )
                })
                .collect();
            sim.set_placement(Some(placement));
            let obj = sim.run(requests).objective;
            candidates += 1;
            best = best.min(obj);
        }
    }
    for &obj in also {
        candidates += 1;
        best = best.min(obj);
    }
    OracleReport {
        objective: best,
        best_static_objective: best_static,
        candidates,
        exhaustive,
    }
}

/// `100 × oracle / achieved` for a lower-is-better objective: 100 means
/// oracle-optimal, lower means worse. Never exceeds 100 when `achieved`
/// came from a candidate the oracle folded in (see [`oracle`]); a
/// non-positive achieved objective (empty run) reports 100.
pub fn pct_of_oracle(objective: f64, oracle_objective: f64) -> f64 {
    if objective <= 0.0 {
        100.0
    } else {
        100.0 * oracle_objective / objective
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::types::SloClass;

    fn req(id: u64, prompt: usize, out: usize, at: f64) -> Request {
        Request {
            id,
            prompt_len: prompt,
            max_new_tokens: out,
            arrival_s: at,
            session: id,
            slo: SloClass::Batch,
            prefix: Vec::new(),
        }
    }

    /// Long-prompt/short-output + short-prompt/long-output mix: the
    /// workload shape where the phases genuinely disagree on placement.
    fn mixed(n: usize) -> Vec<Request> {
        (0..n as u64)
            .map(|id| {
                if id % 2 == 0 {
                    req(id, 192, 4, id as f64 * 0.005)
                } else {
                    req(id, 16, 48, id as f64 * 0.005)
                }
            })
            .collect()
    }

    #[test]
    fn spec_render_parse_round_trips() {
        let specs = [
            SchedSpec::default(),
            SchedSpec::from_legacy(BackendKind::SalPim),
            SchedSpec::from_legacy(BackendKind::Hetero),
            SchedSpec {
                policy: SchedPolicy::Phase,
                hysteresis: 0,
                objective: Objective::Latency,
                power_cap_w: None,
            },
            SchedSpec {
                policy: SchedPolicy::Phase,
                hysteresis: 5,
                objective: Objective::Energy,
                power_cap_w: Some(60.0),
            },
            SchedSpec {
                policy: SchedPolicy::Static(BackendKind::Gpu),
                hysteresis: 2,
                objective: Objective::Energy,
                power_cap_w: Some(42.5),
            },
        ];
        for spec in specs {
            let rendered = spec.render();
            let back = SchedSpec::parse(&rendered)
                .unwrap_or_else(|e| panic!("`{rendered}` failed to parse back: {e}"));
            assert_eq!(back, spec, "round-trip through `{rendered}`");
            assert_eq!(back.render(), rendered, "render is canonical");
        }
        assert_eq!(SchedSpec::default().render(), "phase");
        assert_eq!(
            SchedSpec::from_legacy(BackendKind::SalPim).render(),
            "static:salpim"
        );
        assert_eq!(
            SchedSpec::parse("phase, hysteresis=1 , objective=energy").unwrap(),
            SchedSpec {
                policy: SchedPolicy::Phase,
                hysteresis: 1,
                objective: Objective::Energy,
                power_cap_w: None,
            },
            "whitespace around tokens is tolerated"
        );
    }

    #[test]
    fn spec_parse_rejects_with_actionable_errors() {
        let bare = SchedSpec::parse("static").unwrap_err();
        assert!(bare.contains("static:<"), "{bare}");
        let typo = SchedSpec::parse("phse").unwrap_err();
        assert!(typo.contains("did you mean phase"), "{typo}");
        let backend = SchedSpec::parse("static:cuda").unwrap_err();
        assert!(backend.contains("salpim"), "{backend}");
        let key = SchedSpec::parse("phase,hysterisis=3").unwrap_err();
        assert!(key.contains("did you mean hysteresis"), "{key}");
        let objective = SchedSpec::parse("phase,objective=enery").unwrap_err();
        assert!(objective.contains("did you mean energy"), "{objective}");
        let cap = SchedSpec::parse("phase,power_cap=60").unwrap_err();
        assert!(cap.contains("objective=energy"), "{cap}");
        let neg = SchedSpec::parse("phase,objective=energy,power_cap=-5").unwrap_err();
        assert!(neg.contains("positive"), "{neg}");
        let kv = SchedSpec::parse("phase,hysteresis").unwrap_err();
        assert!(kv.contains("expected key=value"), "{kv}");
    }

    #[test]
    fn phase_run_completes_and_latency_tiles() {
        let cfg = SimConfig::paper();
        let requests = mixed(4);
        let mut sim = PhaseSim::new(&cfg, SchedSpec::default(), PhaseTopology::new(1, 1, 4));
        let out = sim.run(&requests);
        assert_eq!(out.completions.len(), requests.len());
        for c in &out.completions {
            let r = requests.iter().find(|r| r.id == c.id).unwrap();
            assert_eq!(c.tokens_simulated, r.max_new_tokens, "req {}", c.id);
            let tiled = r.arrival_s + c.queue_s + c.prefill_s + c.decode_s;
            assert!(
                (tiled - c.finish_s).abs() < 1e-9,
                "req {}: {} vs {}",
                c.id,
                tiled,
                c.finish_s
            );
            assert!(c.queue_s >= 0.0 && c.prefill_s > 0.0 && c.decode_s >= 0.0);
        }
        assert!(out.makespan_s > 0.0 && out.mean_latency_s > 0.0);
        assert!(out.energy_j > 0.0 && out.avg_power_w > 0.0);
        assert_eq!(out.placement.len(), requests.len());
    }

    #[test]
    fn tokens_conserved_across_every_placement() {
        // Scheduling may move work between pools but must never change
        // what is computed: per-request simulated tokens are identical
        // under the dynamic router and all four forced uniforms.
        let cfg = SimConfig::paper();
        let requests = mixed(3);
        let mut sim = PhaseSim::new(&cfg, SchedSpec::default(), PhaseTopology::new(1, 1, 4));
        let reference: Vec<(u64, usize)> = sim
            .run(&requests)
            .completions
            .iter()
            .map(|c| (c.id, c.tokens_simulated))
            .collect();
        for (p, d) in [
            (Loc::Gpu, Loc::Gpu),
            (Loc::Gpu, Loc::Pim),
            (Loc::Pim, Loc::Gpu),
            (Loc::Pim, Loc::Pim),
        ] {
            sim.set_placement(Some(vec![(p, d); requests.len()]));
            let mut got: Vec<(u64, usize)> = sim
                .run(&requests)
                .completions
                .iter()
                .map(|c| (c.id, c.tokens_simulated))
                .collect();
            got.sort_unstable();
            let mut want = reference.clone();
            want.sort_unstable();
            assert_eq!(got, want, "placement ({},{})", p.name(), d.name());
        }
    }

    #[test]
    fn forced_cross_pool_placement_migrates_every_request() {
        let cfg = SimConfig::paper();
        let requests = mixed(3);
        let mut sim = PhaseSim::new(&cfg, SchedSpec::default(), PhaseTopology::new(1, 1, 4));
        sim.set_placement(Some(vec![(Loc::Gpu, Loc::Pim); requests.len()]));
        let out = sim.run(&requests);
        assert_eq!(out.completions.len(), requests.len());
        assert_eq!(out.router_migrations, requests.len() as u64);
        assert!(out.migrated_bytes > 0);
        for &(p, d) in &out.placement {
            assert_eq!((p, d), (Loc::Gpu, Loc::Pim));
        }
        // All decode ran on the PIM pool, so completions carry its
        // device base.
        for c in &out.completions {
            assert_eq!(c.device, 1);
        }
    }

    #[test]
    fn oracle_scores_100_and_bounds_every_policy() {
        let cfg = SimConfig::paper();
        let requests = mixed(2); // 4^2 = 16 ≤ cap → exhaustive
        let spec = SchedSpec::default();
        let topo = PhaseTopology::new(1, 1, 4);
        let mut sim = PhaseSim::new(&cfg, spec.clone(), topo);
        let dynamic = sim.run(&requests).objective;
        let report = oracle(&cfg, &spec, &topo, &requests, &[dynamic]);
        assert!(report.exhaustive);
        assert!(report.candidates >= 4 + 16 + 1);
        assert!((pct_of_oracle(report.objective, report.objective) - 100.0).abs() < 1e-9);
        assert!(pct_of_oracle(dynamic, report.objective) <= 100.0 + 1e-9);
        for (p, d) in [
            (Loc::Gpu, Loc::Gpu),
            (Loc::Gpu, Loc::Pim),
            (Loc::Pim, Loc::Gpu),
            (Loc::Pim, Loc::Pim),
        ] {
            sim.set_placement(Some(vec![(p, d); requests.len()]));
            let obj = sim.run(&requests).objective;
            let pct = pct_of_oracle(obj, report.objective);
            assert!(pct <= 100.0 + 1e-9, "({},{}) at {pct}", p.name(), d.name());
            assert!(report.best_static_objective <= obj + 1e-12);
        }
    }

    #[test]
    fn energy_objective_reads_the_fig15_power_model() {
        let cfg = SimConfig::paper();
        let params = EnergyParams::paper();
        // The PIM pool's device power is the Fig. 15 logic + refresh
        // figure, far below the GPU's board power.
        let pim_w = params.pim_device_power_w(&cfg);
        assert!(pim_w > 0.0 && pim_w < GPU_CLASS_POWER_W, "{pim_w}");
        let spec = SchedSpec::parse("phase,objective=energy,power_cap=60").unwrap();
        let mut sim = PhaseSim::new(&cfg, spec, PhaseTopology::new(1, 1, 4));
        let energy_run = sim.run(&mixed(3));
        assert!(energy_run.objective > 0.0);
        assert!((energy_run.objective - energy_run.energy_j).abs() < 1e-12);
    }
}
