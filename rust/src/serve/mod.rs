//! The cluster serving engine (L4).
//!
//! The [`crate::coordinator`] serves one request at a time on one device —
//! faithful to the paper's evaluation, but nothing like the heavy-traffic
//! regime a deployed SAL-PIM pod faces. This module is the serving layer
//! above it:
//!
//! * [`KvCacheManager`] — maps per-request KV state onto subarray capacity
//!   derived from [`crate::config::HbmConfig`]; admission fails when the
//!   KV region is exhausted and slots free on completion;
//! * [`DeviceEngine`] — a continuous-batching scheduler over one simulated
//!   device: new requests join at token boundaries and batched decode
//!   steps are charged with the multi-subarray timing model
//!   ([`crate::mapper::GenerationSim::decode_batch_step`]);
//! * [`Cluster`] — N devices behind a router ([`Routing`]: round-robin,
//!   least-loaded, session-affinity) with per-device queues;
//! * [`workload`] — open-loop Poisson / bursty arrival generation;
//! * [`sweep`] — the latency-vs-offered-load sweep behind
//!   `sal-pim serve --sweep` and `bench_serve_cluster`.
//!
//! The request/completion/policy/metric types live here and are shared
//! with the single-device coordinator (which re-exports them), so both
//! paths consume the identical vocabulary.

mod cluster;
mod engine;
mod kv_cache;
mod metrics;
mod policy;
mod types;
pub mod sweep;
pub mod workload;

pub use cluster::{Cluster, Routing};
pub use engine::{DeviceEngine, EngineReport};
pub use kv_cache::{KvCacheManager, KvLease};
pub use metrics::{percentile, ServeMetrics};
pub use policy::{Policy, Scheduler};
pub use types::{Completion, Request};
