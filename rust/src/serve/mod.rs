//! The cluster serving engine (L4).
//!
//! The [`crate::coordinator`] serves one request at a time on one device —
//! faithful to the paper's evaluation, but nothing like the heavy-traffic
//! regime a deployed SAL-PIM pod faces. This module is the serving layer
//! above it:
//!
//! * [`backend`] — the [`ExecutionBackend`] trait plus the four device
//!   cost models (SAL-PIM, GPU roofline, bank-level PIM, heterogeneous
//!   GPU-prefill + PIM-decode); everything below schedules against the
//!   trait, never a concrete simulator;
//! * [`kv_cache`] — KV capacity management over the backend's hints
//!   (subarrays on PIM, pages on a GPU): the historical whole-window
//!   [`KvCacheManager`] and the paged [`PagedKvManager`] (fixed-size
//!   token blocks, LRU session residency, preemption + recompute), both
//!   behind the engine-facing [`KvPool`] (`--kv-policy whole|paged`,
//!   `--evict lru|none`);
//! * [`DeviceEngine`] — a continuous-batching scheduler over one
//!   simulated device: new requests join at token boundaries, batched
//!   decode steps are charged via [`ExecutionBackend::decode_step_s`],
//!   and prefills optionally interleave in token chunks
//!   ([`DeviceEngine::with_prefill_chunk`]) instead of stalling the
//!   decode batch;
//! * [`Cluster`] — N devices behind a router ([`Routing`]: round-robin,
//!   least-loaded, session-affinity) with per-device queues; devices may
//!   mix backend families ([`Cluster::from_engines`]);
//! * [`fabric`] — the modeled host interconnect (PCIe/NVLink-class
//!   [`FabricParams`]: bandwidth, base latency, fair-share contention)
//!   that prices KV handoffs, cross-device migrations and swap-to-host
//!   traffic in one place;
//! * [`DisaggregatedCluster`] — a prefill pool and a decode pool
//!   (`--engine disagg`): each request prefills on one pool, its paged
//!   KV migrates over the fabric, and decode finishes on the other
//!   pool; with `--evict swap`, preempted KV spills to a host buffer
//!   over the same fabric and readmission picks the cheaper of swap-in
//!   and recompute;
//! * [`sched`] — the typed [`SchedSpec`] schedule grammar
//!   (`--schedule "POLICY[,key=value]*"`; the legacy `--backend` flag
//!   desugars onto `static:<backend>`), the dynamic phase-aware
//!   [`PhaseSim`] router that re-places each request's next phase at
//!   every token boundary, and the offline-optimal [`oracle`] baseline
//!   behind the `pct_of_oracle` metric;
//! * [`workload`] — open-loop Poisson / bursty arrival generation;
//! * [`sweep`] — the latency-vs-offered-load sweep behind
//!   `sal-pim serve --sweep` and `bench_serve_cluster`.
//!
//! The engine, cluster and paged KV pool emit typed lifecycle events
//! into a shared [`crate::trace::TraceHandle`] when one is attached
//! ([`DeviceEngine::set_trace`] / [`Cluster::set_trace`]; off by
//! default), they accumulate a wall-clock self-profile per run
//! ([`crate::trace::PhaseProfile`]), and [`ServeMetrics`] percentiles
//! are answered from log-bucketed [`crate::trace::Histogram`]s.
//!
//! The request/completion/policy/metric types live here and are shared
//! with the single-device coordinator (which re-exports them), so both
//! paths consume the identical vocabulary.

mod cluster;
mod engine;
mod metrics;
mod policy;
mod types;
pub mod backend;
pub mod fabric;
pub mod kv_cache;
pub mod sched;
pub mod sweep;
pub mod workload;

pub use backend::{
    BackendKind, BankLevelBackend, DeviceCapacity, ExecutionBackend, GpuBackend, HeteroBackend,
    SalPimBackend,
};
pub use cluster::{Cluster, DisaggregatedCluster, Routing};
pub use engine::{DeviceEngine, EngineCore, EngineReport};
pub use fabric::{Fabric, FabricKind, FabricParams, SharedFabric};
pub use kv_cache::{
    EvictPolicy, KvCacheManager, KvLease, KvPolicy, KvPool, PagedKvManager, PrefixCacheMode,
};
pub use metrics::{percentile, ServeMetrics};
pub use policy::{Policy, Scheduler, INTERACTIVE_BOOST_S};
pub use sched::{
    oracle, pct_of_oracle, Loc, Objective, OracleReport, PhaseOutcome, PhaseSim, PhaseTopology,
    SchedPolicy, SchedSpec,
};
pub use types::{Completion, PrefixSeg, Request, SloClass};
pub use workload::{ArrivalPattern, LengthModel, PrefixSpec, SessionModel, WorkloadSpec};
