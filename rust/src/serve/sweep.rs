//! Latency-vs-offered-load sweeps over the cluster engine.
//!
//! Drives [`Cluster`] with open-loop Poisson workloads at increasing
//! offered loads and reports the classic serving curve: throughput
//! climbs with load until the devices saturate, then queueing pushes the
//! tail latencies up. Exposed as `sal-pim serve --sweep` and used by
//! `bench_serve_cluster`.

use super::backend::BackendKind;
use super::cluster::{Cluster, Routing};
use super::engine::EngineCore;
use super::kv_cache::{EvictPolicy, KvPolicy, PrefixCacheMode};
use super::metrics::ServeMetrics;
use super::policy::Policy;
use super::workload::{generate, ArrivalPattern};
use crate::config::SimConfig;

/// Sweep shape shared by the CLI and the bench.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub devices: usize,
    pub max_batch: usize,
    pub routing: Routing,
    pub policy: Policy,
    pub requests: usize,
    pub seed: u64,
    pub n_sessions: usize,
    /// Execution backend every device runs (`--backend`).
    pub backend: BackendKind,
    /// Chunked-prefill token size, `None` for inline prefill
    /// (`--prefill-chunk`).
    pub prefill_chunk: Option<usize>,
    /// KV allocation discipline every device runs (`--kv-policy`).
    pub kv_policy: KvPolicy,
    /// Paged eviction policy (`--evict`).
    pub evict: EvictPolicy,
    /// Paged block-size override in tokens (`--kv-block`).
    pub kv_block: Option<usize>,
    /// KV-region size override in allocation units (`--kv-units`).
    pub kv_units: Option<usize>,
    /// Run-loop core every device executes (`--engine-core`).
    pub core: EngineCore,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            devices: 4,
            max_batch: 8,
            routing: Routing::RoundRobin,
            policy: Policy::Fcfs,
            requests: 64,
            seed: 42,
            n_sessions: 8,
            backend: BackendKind::SalPim,
            prefill_chunk: None,
            kv_policy: KvPolicy::Whole,
            evict: EvictPolicy::Lru,
            kv_block: None,
            kv_units: None,
            core: EngineCore::default(),
        }
    }
}

/// One point on the latency-vs-load curve.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub offered_rps: f64,
    pub metrics: ServeMetrics,
    pub rejected: usize,
}

/// Run the cluster at each offered load (requests/second).
pub fn latency_vs_load(cfg: &SimConfig, sc: &SweepConfig, loads_rps: &[f64]) -> Vec<SweepPoint> {
    loads_rps
        .iter()
        .map(|&rate| {
            let reqs = generate(
                sc.seed,
                sc.requests,
                ArrivalPattern::Poisson { rate_rps: rate },
                sc.n_sessions,
            );
            let mut cluster =
                Cluster::homogeneous(cfg, sc.backend, sc.devices, sc.max_batch, sc.routing)
                    .with_policy(sc.policy)
                    .with_prefill_chunk(sc.prefill_chunk)
                    .with_kv(
                        sc.kv_policy,
                        sc.evict,
                        PrefixCacheMode::Session,
                        sc.kv_block,
                        sc.kv_units,
                    )
                    .with_core(sc.core);
            for r in reqs {
                cluster.submit(r);
            }
            let done = cluster.run();
            let mut metrics = ServeMetrics::from_completions(&done);
            metrics.absorb_reports(&cluster.per_device_reports());
            SweepPoint {
                offered_rps: rate,
                metrics,
                rejected: cluster.rejected(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hetero_backend_sweeps_end_to_end() {
        // The CLI acceptance path: `--backend hetero --sweep` (with
        // chunked prefill) must run every point to completion.
        let cfg = SimConfig::paper();
        let sc = SweepConfig {
            devices: 2,
            max_batch: 4,
            requests: 8,
            backend: BackendKind::Hetero,
            prefill_chunk: Some(32),
            ..SweepConfig::default()
        };
        let pts = latency_vs_load(&cfg, &sc, &[100.0]);
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].metrics.requests, 8);
        assert!(pts[0].metrics.throughput_tok_s > 0.0);
    }

    #[test]
    fn load_raises_tail_latency() {
        let cfg = SimConfig::paper();
        let sc = SweepConfig {
            devices: 1,
            max_batch: 4,
            requests: 16,
            ..SweepConfig::default()
        };
        let pts = latency_vs_load(&cfg, &sc, &[20.0, 20_000.0]);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].metrics.requests, 16);
        assert_eq!(pts[1].metrics.requests, 16);
        // At a crush load the queueing delay must dominate: p95 latency
        // is no better than at the gentle load.
        assert!(
            pts[1].metrics.p95_latency_s >= pts[0].metrics.p95_latency_s,
            "saturation must not *improve* tail latency: {} vs {}",
            pts[1].metrics.p95_latency_s,
            pts[0].metrics.p95_latency_s
        );
    }
}
