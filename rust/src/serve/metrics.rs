//! Serving metrics: latency percentiles, throughput, utilization.
//!
//! All aggregations are *total*: a serving loop must survive a metrics
//! window with zero completions, so [`percentile`] returns `None` on empty
//! input and [`ServeMetrics::from_completions`] yields zeroed defaults
//! instead of panicking.

use super::engine::EngineReport;
use super::types::Completion;

/// Percentile of a sample set (nearest-rank; `p` in [0, 100]).
/// Returns `None` for an empty sample set.
pub fn percentile(samples: &[f64], p: f64) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    let mut v = samples.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    Some(v[rank.min(v.len() - 1)])
}

/// Aggregated serving metrics for a batch of completions.
#[derive(Debug, Clone)]
pub struct ServeMetrics {
    pub requests: usize,
    pub total_tokens: usize,
    pub makespan_s: f64,
    pub throughput_tok_s: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub p50_ttft_s: f64,
    pub p95_ttft_s: f64,
    pub mean_queue_s: f64,
    /// Paged-KV engine counters, filled by
    /// [`ServeMetrics::absorb_reports`] (zero for completion-only
    /// aggregations and whole-window runs).
    pub preemptions: usize,
    /// Tokens re-prefilled on readmission after preemption.
    pub recompute_tokens: usize,
    /// Admissions that reclaimed a session-resident KV prefix.
    pub reuse_hits: usize,
    /// Prompt tokens whose prefill was skipped via session reuse.
    pub reuse_tokens: usize,
    /// Mean decode-batch size across devices (step-weighted).
    pub mean_decode_batch: f64,
}

impl ServeMetrics {
    /// All-zero metrics (the empty window).
    pub fn empty() -> Self {
        ServeMetrics {
            requests: 0,
            total_tokens: 0,
            makespan_s: 0.0,
            throughput_tok_s: 0.0,
            p50_latency_s: 0.0,
            p95_latency_s: 0.0,
            p50_ttft_s: 0.0,
            p95_ttft_s: 0.0,
            mean_queue_s: 0.0,
            preemptions: 0,
            recompute_tokens: 0,
            reuse_hits: 0,
            reuse_tokens: 0,
            mean_decode_batch: 0.0,
        }
    }

    pub fn from_completions(done: &[Completion]) -> Self {
        if done.is_empty() {
            return Self::empty();
        }
        let latencies: Vec<f64> = done.iter().map(|c| c.total_latency_s()).collect();
        let ttfts: Vec<f64> = done.iter().map(|c| c.ttft_s()).collect();
        let total_tokens: usize = done.iter().map(|c| c.tokens_out).sum();
        let makespan = done
            .iter()
            .map(|c| c.finish_s)
            .fold(0.0f64, f64::max);
        ServeMetrics {
            requests: done.len(),
            total_tokens,
            makespan_s: makespan,
            throughput_tok_s: if makespan > 0.0 {
                total_tokens as f64 / makespan
            } else {
                0.0
            },
            p50_latency_s: percentile(&latencies, 50.0).unwrap_or(0.0),
            p95_latency_s: percentile(&latencies, 95.0).unwrap_or(0.0),
            p50_ttft_s: percentile(&ttfts, 50.0).unwrap_or(0.0),
            p95_ttft_s: percentile(&ttfts, 95.0).unwrap_or(0.0),
            mean_queue_s: done.iter().map(|c| c.queue_s).sum::<f64>() / done.len() as f64,
            preemptions: 0,
            recompute_tokens: 0,
            reuse_hits: 0,
            reuse_tokens: 0,
            mean_decode_batch: 0.0,
        }
    }

    /// Fold per-device engine reports into the metrics: preemption /
    /// recompute / reuse counters sum across devices, the mean decode
    /// batch is weighted by each device's step count.
    pub fn absorb_reports(&mut self, reports: &[EngineReport]) {
        let mut steps = 0u64;
        let mut batch_sum = 0.0f64;
        for r in reports {
            self.preemptions += r.preemptions;
            self.recompute_tokens += r.recompute_tokens;
            self.reuse_hits += r.reuse_hits;
            self.reuse_tokens += r.reuse_tokens;
            batch_sum += r.mean_decode_batch * r.decode_steps as f64;
            steps += r.decode_steps;
        }
        if steps > 0 {
            self.mean_decode_batch = batch_sum / steps as f64;
        }
    }
}

impl std::fmt::Display for ServeMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "requests:        {}", self.requests)?;
        writeln!(f, "tokens:          {}", self.total_tokens)?;
        writeln!(f, "makespan:        {:.3} s", self.makespan_s)?;
        writeln!(f, "throughput:      {:.1} tok/s", self.throughput_tok_s)?;
        writeln!(
            f,
            "latency p50/p95: {:.1} / {:.1} ms",
            self.p50_latency_s * 1e3,
            self.p95_latency_s * 1e3
        )?;
        writeln!(
            f,
            "ttft    p50/p95: {:.1} / {:.1} ms",
            self.p50_ttft_s * 1e3,
            self.p95_ttft_s * 1e3
        )?;
        write!(f, "mean queue:      {:.1} ms", self.mean_queue_s * 1e3)?;
        if self.mean_decode_batch > 0.0 {
            write!(f, "\nmean batch:      {:.2}", self.mean_decode_batch)?;
        }
        if self.preemptions > 0 || self.reuse_hits > 0 {
            write!(
                f,
                "\npaging:          {} preempt ({} tok recompute) | {} reuse hit ({} tok)",
                self.preemptions, self.recompute_tokens, self.reuse_hits, self.reuse_tokens
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn comp(id: u64, queue: f64, prefill: f64, decode: f64, tokens: usize) -> Completion {
        Completion {
            id,
            prompt_len: 32,
            tokens_out: tokens,
            tokens_simulated: tokens,
            queue_s: queue,
            prefill_s: prefill,
            decode_s: decode,
            finish_s: queue + prefill + decode,
            device: 0,
        }
    }

    #[test]
    fn percentile_basics() {
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), Some(1.0));
        assert_eq!(percentile(&v, 50.0), Some(3.0));
        assert_eq!(percentile(&v, 100.0), Some(5.0));
    }

    #[test]
    fn percentile_of_empty_is_none() {
        assert_eq!(percentile(&[], 50.0), None);
    }

    #[test]
    fn metrics_aggregate() {
        let done = vec![
            comp(0, 0.0, 0.01, 0.1, 10),
            comp(1, 0.05, 0.01, 0.2, 20),
        ];
        let m = ServeMetrics::from_completions(&done);
        assert_eq!(m.requests, 2);
        assert_eq!(m.total_tokens, 30);
        assert!(m.throughput_tok_s > 0.0);
        assert!(m.p95_latency_s >= m.p50_latency_s);
        assert!((m.mean_queue_s - 0.025).abs() < 1e-12);
    }

    #[test]
    fn empty_window_is_survivable() {
        let m = ServeMetrics::from_completions(&[]);
        assert_eq!(m.requests, 0);
        assert_eq!(m.total_tokens, 0);
        assert_eq!(m.throughput_tok_s, 0.0);
        assert_eq!(m.p95_latency_s, 0.0);
    }

    #[test]
    fn display_renders() {
        let m = ServeMetrics::from_completions(&[comp(0, 0.0, 0.01, 0.1, 10)]);
        let s = format!("{m}");
        assert!(s.contains("throughput"));
        assert!(!s.contains("paging"), "quiet when no paging activity");
    }

    #[test]
    fn engine_reports_fold_into_the_metrics() {
        let rep = |steps: u64, batch: f64, pre: usize, reuse: usize| EngineReport {
            rejected: 0,
            kv_peak_utilization: 0.5,
            max_batch_seen: 4,
            decode_steps: steps,
            mean_decode_batch: batch,
            preemptions: pre,
            recompute_tokens: 10 * pre,
            reuse_hits: reuse,
            reuse_tokens: 5 * reuse,
        };
        let mut m = ServeMetrics::from_completions(&[comp(0, 0.0, 0.01, 0.1, 10)]);
        m.absorb_reports(&[rep(10, 4.0, 1, 2), rep(30, 2.0, 2, 0)]);
        assert_eq!(m.preemptions, 3);
        assert_eq!(m.recompute_tokens, 30);
        assert_eq!(m.reuse_hits, 2);
        assert_eq!(m.reuse_tokens, 10);
        // Step-weighted: (10*4 + 30*2) / 40 = 2.5.
        assert!((m.mean_decode_batch - 2.5).abs() < 1e-12);
        let s = format!("{m}");
        assert!(s.contains("paging"), "{s}");
        assert!(s.contains("mean batch"), "{s}");
    }
}
