//! Serving metrics: latency percentiles, throughput, utilization.
//!
//! All aggregations are *total*: a serving loop must survive a metrics
//! window with zero completions, so [`percentile`] returns `None` on empty
//! input and [`ServeMetrics::from_completions`] yields zeroed defaults
//! instead of panicking.
//!
//! Percentiles are answered from log-bucketed
//! [`crate::trace::Histogram`]s (O(1) per sample, ~1% bucket
//! resolution) rather than sorting a flat `Vec<f64>` per window. The
//! exact nearest-rank [`percentile`] stays as the reference
//! implementation; a regression test pins the two within bucket error.

use super::engine::EngineReport;
use super::types::Completion;
use crate::trace::HistogramRegistry;

/// Percentile of a sample set (nearest-rank; `p` in [0, 100]).
/// Returns `None` for an empty sample set.
///
/// **Small-sample semantics** (pinned, shared with the histogram path):
/// the answer is always an observed sample, never an interpolation —
/// `p95` of `[1, 2, 3, 4, 5]` is `5.0` (rank `round(0.95 × 4) = 4`),
/// not the linearly interpolated `4.8`. Nearest-rank biases *up* for
/// high percentiles at small `n`; at serving scale (hundreds of
/// completions per window) the two estimators converge.
pub fn percentile(samples: &[f64], p: f64) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    let mut v = samples.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    Some(v[rank.min(v.len() - 1)])
}

/// Aggregated serving metrics for a batch of completions.
#[derive(Debug, Clone)]
pub struct ServeMetrics {
    pub requests: usize,
    pub total_tokens: usize,
    pub makespan_s: f64,
    pub throughput_tok_s: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub p50_ttft_s: f64,
    pub p95_ttft_s: f64,
    pub mean_queue_s: f64,
    /// Paged-KV engine counters, filled by
    /// [`ServeMetrics::absorb_reports`] (zero for completion-only
    /// aggregations and whole-window runs).
    pub preemptions: usize,
    /// Tokens re-prefilled on readmission after preemption.
    pub recompute_tokens: usize,
    /// Admissions that reclaimed a session-resident KV prefix.
    pub reuse_hits: usize,
    /// Prompt tokens whose prefill was skipped via session reuse.
    pub reuse_tokens: usize,
    /// Admissions that reused a radix prefix-tree chain (cross-session).
    pub prefix_hits: usize,
    /// Prompt tokens whose prefill the radix tree skipped.
    pub prefix_reused_tokens: usize,
    /// Preempted KV states spilled to the host buffer (`--evict swap`).
    pub swap_outs: usize,
    /// Readmissions that restored KV over the fabric instead of
    /// recomputing it.
    pub swap_ins: usize,
    /// Bytes moved over the fabric by swap-outs plus swap-ins.
    pub swapped_bytes: u64,
    /// Mean decode-batch size across devices (step-weighted).
    pub mean_decode_batch: f64,
}

impl ServeMetrics {
    /// All-zero metrics (the empty window).
    pub fn empty() -> Self {
        ServeMetrics {
            requests: 0,
            total_tokens: 0,
            makespan_s: 0.0,
            throughput_tok_s: 0.0,
            p50_latency_s: 0.0,
            p95_latency_s: 0.0,
            p50_ttft_s: 0.0,
            p95_ttft_s: 0.0,
            mean_queue_s: 0.0,
            preemptions: 0,
            recompute_tokens: 0,
            reuse_hits: 0,
            reuse_tokens: 0,
            prefix_hits: 0,
            prefix_reused_tokens: 0,
            swap_outs: 0,
            swap_ins: 0,
            swapped_bytes: 0,
            mean_decode_batch: 0.0,
        }
    }

    pub fn from_completions(done: &[Completion]) -> Self {
        if done.is_empty() {
            return Self::empty();
        }
        // One O(1)-per-sample pass; no flat sample vectors to sort.
        let mut hist = HistogramRegistry::new();
        for c in done {
            hist.record("latency", c.total_latency_s());
            hist.record("ttft", c.ttft_s());
        }
        let total_tokens: usize = done.iter().map(|c| c.tokens_out).sum();
        let makespan = done
            .iter()
            .map(|c| c.finish_s)
            .fold(0.0f64, f64::max);
        ServeMetrics {
            requests: done.len(),
            total_tokens,
            makespan_s: makespan,
            throughput_tok_s: if makespan > 0.0 {
                total_tokens as f64 / makespan
            } else {
                0.0
            },
            p50_latency_s: hist.percentile_or_zero("latency", 50.0),
            p95_latency_s: hist.percentile_or_zero("latency", 95.0),
            p50_ttft_s: hist.percentile_or_zero("ttft", 50.0),
            p95_ttft_s: hist.percentile_or_zero("ttft", 95.0),
            mean_queue_s: done.iter().map(|c| c.queue_s).sum::<f64>() / done.len() as f64,
            preemptions: 0,
            recompute_tokens: 0,
            reuse_hits: 0,
            reuse_tokens: 0,
            prefix_hits: 0,
            prefix_reused_tokens: 0,
            swap_outs: 0,
            swap_ins: 0,
            swapped_bytes: 0,
            mean_decode_batch: 0.0,
        }
    }

    /// Fold per-device engine reports into the metrics: preemption /
    /// recompute / reuse counters sum across devices, the mean decode
    /// batch is weighted by each device's step count.
    pub fn absorb_reports(&mut self, reports: &[EngineReport]) {
        let mut steps = 0u64;
        let mut batch_sum = 0.0f64;
        for r in reports {
            self.preemptions += r.preemptions;
            self.recompute_tokens += r.recompute_tokens;
            self.reuse_hits += r.reuse_hits;
            self.reuse_tokens += r.reuse_tokens;
            self.prefix_hits += r.prefix_hits;
            self.prefix_reused_tokens += r.prefix_reused_tokens;
            self.swap_outs += r.swap_outs;
            self.swap_ins += r.swap_ins;
            self.swapped_bytes += r.swapped_bytes;
            batch_sum += r.mean_decode_batch * r.decode_steps as f64;
            steps += r.decode_steps;
        }
        if steps > 0 {
            self.mean_decode_batch = batch_sum / steps as f64;
        }
    }
}

impl std::fmt::Display for ServeMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "requests:        {}", self.requests)?;
        writeln!(f, "tokens:          {}", self.total_tokens)?;
        writeln!(f, "makespan:        {:.3} s", self.makespan_s)?;
        writeln!(f, "throughput:      {:.1} tok/s", self.throughput_tok_s)?;
        writeln!(
            f,
            "latency p50/p95: {:.1} / {:.1} ms",
            self.p50_latency_s * 1e3,
            self.p95_latency_s * 1e3
        )?;
        writeln!(
            f,
            "ttft    p50/p95: {:.1} / {:.1} ms",
            self.p50_ttft_s * 1e3,
            self.p95_ttft_s * 1e3
        )?;
        write!(f, "mean queue:      {:.1} ms", self.mean_queue_s * 1e3)?;
        if self.mean_decode_batch > 0.0 {
            write!(f, "\nmean batch:      {:.2}", self.mean_decode_batch)?;
        }
        if self.preemptions > 0 || self.reuse_hits > 0 {
            write!(
                f,
                "\npaging:          {} preempt ({} tok recompute) | {} reuse hit ({} tok)",
                self.preemptions, self.recompute_tokens, self.reuse_hits, self.reuse_tokens
            )?;
        }
        if self.prefix_hits > 0 {
            write!(
                f,
                "\nprefix cache:    {} hit ({} tok shared across sessions)",
                self.prefix_hits, self.prefix_reused_tokens
            )?;
        }
        if self.swap_outs > 0 || self.swap_ins > 0 {
            write!(
                f,
                "\nswap:            {} out / {} in ({} B over fabric)",
                self.swap_outs, self.swap_ins, self.swapped_bytes
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn comp(id: u64, queue: f64, prefill: f64, decode: f64, tokens: usize) -> Completion {
        Completion {
            id,
            prompt_len: 32,
            tokens_out: tokens,
            tokens_simulated: tokens,
            queue_s: queue,
            prefill_s: prefill,
            decode_s: decode,
            finish_s: queue + prefill + decode,
            device: 0,
            slo: crate::serve::types::SloClass::Batch,
        }
    }

    #[test]
    fn percentile_basics() {
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), Some(1.0));
        assert_eq!(percentile(&v, 50.0), Some(3.0));
        assert_eq!(percentile(&v, 100.0), Some(5.0));
    }

    #[test]
    fn percentile_of_empty_is_none() {
        assert_eq!(percentile(&[], 50.0), None);
    }

    #[test]
    fn nearest_rank_small_sample_semantics_are_pinned() {
        // Nearest-rank answers an observed sample — p95 of five values
        // is the max, NOT the interpolated 4.8. This bias is kept (and
        // shared by the histogram path): changing it would silently
        // shift every BENCH_* latency percentile.
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 95.0), Some(5.0));
        assert_eq!(percentile(&v, 80.0), Some(4.0));
        // Two samples: p50 rounds to the upper one (round(0.5) = 1).
        assert_eq!(percentile(&[10.0, 20.0], 50.0), Some(20.0));
    }

    #[test]
    fn histogram_path_tracks_the_exact_reference_percentile() {
        // The regression contract for the O(1) metrics path: on fixed
        // inputs, the histogram percentile stays within one bucket
        // (~1% relative) of the exact nearest-rank sort — and the two
        // are bit-identical at the extremes.
        let samples: Vec<f64> = (1..=257).map(|i| 0.004 * (i as f64).powf(1.3)).collect();
        let mut h = crate::trace::Histogram::new();
        for &s in &samples {
            h.record(s);
        }
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            let exact = percentile(&samples, p).unwrap();
            let approx = h.percentile(p).unwrap();
            assert!(
                (approx - exact).abs() <= 0.01 * exact,
                "p{p}: histogram {approx} drifted from exact {exact}"
            );
        }
        assert_eq!(h.percentile(0.0), percentile(&samples, 0.0));
        assert_eq!(h.percentile(100.0), percentile(&samples, 100.0));
        // And ServeMetrics (histogram-backed) mirrors the reference on
        // a small window within the same bucket error.
        let done: Vec<Completion> = (0..40)
            .map(|i| comp(i, 0.0, 0.01, 0.05 + 0.01 * i as f64, 8))
            .collect();
        let m = ServeMetrics::from_completions(&done);
        let lat: Vec<f64> = done.iter().map(|c| c.total_latency_s()).collect();
        let exact = percentile(&lat, 95.0).unwrap();
        assert!((m.p95_latency_s - exact).abs() <= 0.01 * exact);
    }

    #[test]
    fn metrics_aggregate() {
        let done = vec![
            comp(0, 0.0, 0.01, 0.1, 10),
            comp(1, 0.05, 0.01, 0.2, 20),
        ];
        let m = ServeMetrics::from_completions(&done);
        assert_eq!(m.requests, 2);
        assert_eq!(m.total_tokens, 30);
        assert!(m.throughput_tok_s > 0.0);
        assert!(m.p95_latency_s >= m.p50_latency_s);
        assert!((m.mean_queue_s - 0.025).abs() < 1e-12);
    }

    #[test]
    fn empty_window_is_survivable() {
        let m = ServeMetrics::from_completions(&[]);
        assert_eq!(m.requests, 0);
        assert_eq!(m.total_tokens, 0);
        assert_eq!(m.throughput_tok_s, 0.0);
        assert_eq!(m.p95_latency_s, 0.0);
    }

    #[test]
    fn display_renders() {
        let m = ServeMetrics::from_completions(&[comp(0, 0.0, 0.01, 0.1, 10)]);
        let s = format!("{m}");
        assert!(s.contains("throughput"));
        assert!(!s.contains("paging"), "quiet when no paging activity");
    }

    #[test]
    fn engine_reports_fold_into_the_metrics() {
        let rep = |steps: u64, batch: f64, pre: usize, reuse: usize| EngineReport {
            rejected: 0,
            kv_peak_utilization: 0.5,
            max_batch_seen: 4,
            decode_steps: steps,
            mean_decode_batch: batch,
            preemptions: pre,
            recompute_tokens: 10 * pre,
            reuse_hits: reuse,
            reuse_tokens: 5 * reuse,
            prefix_hits: reuse,
            prefix_reused_tokens: 7 * reuse,
            prefix_nodes_evicted: 0,
            swap_outs: pre,
            swap_ins: pre / 2,
            swapped_bytes: 1024 * pre as u64,
            profile: crate::trace::PhaseProfile::default(),
            truncated: false,
        };
        let mut m = ServeMetrics::from_completions(&[comp(0, 0.0, 0.01, 0.1, 10)]);
        m.absorb_reports(&[rep(10, 4.0, 1, 2), rep(30, 2.0, 2, 0)]);
        assert_eq!(m.preemptions, 3);
        assert_eq!(m.recompute_tokens, 30);
        assert_eq!(m.reuse_hits, 2);
        assert_eq!(m.reuse_tokens, 10);
        assert_eq!(m.prefix_hits, 2);
        assert_eq!(m.prefix_reused_tokens, 14);
        assert_eq!(m.swap_outs, 3);
        assert_eq!(m.swap_ins, 1);
        assert_eq!(m.swapped_bytes, 3072);
        // Step-weighted: (10*4 + 30*2) / 40 = 2.5.
        assert!((m.mean_decode_batch - 2.5).abs() < 1e-12);
        let s = format!("{m}");
        assert!(s.contains("paging"), "{s}");
        assert!(s.contains("mean batch"), "{s}");
    }
}
