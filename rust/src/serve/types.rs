//! Request / completion vocabulary shared by every serving path.
//!
//! These used to live in [`crate::coordinator`]; they moved here so the
//! single-device sequential coordinator and the cluster engine speak the
//! same types (the coordinator re-exports them for compatibility).

/// Service-level objective class of a request. Interactive traffic
/// (chat turns a human is waiting on) may jump the admission queue and
/// gets prefill-chunk priority under [`super::Policy::Priority`];
/// batch traffic (offline summarization, evals) absorbs the slack.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SloClass {
    Interactive,
    /// Throughput-oriented background work (the historical default:
    /// every pre-SLO workload is batch-class, keeping old runs
    /// bit-identical).
    #[default]
    Batch,
}

impl SloClass {
    pub fn name(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<SloClass> {
        match s {
            "interactive" => Some(SloClass::Interactive),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }
}

/// One node of a request's shared-prefix path: `id` names the prefix
/// tree node (stable across sessions — all requests carrying the same
/// id share those tokens), `tokens` is the node's own token count
/// (not cumulative). A request's full shared prefix is the sum over
/// its `prefix` path, always ≤ `prompt_len`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixSeg {
    pub id: u64,
    pub tokens: usize,
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    /// Arrival time in seconds (simulated wall clock).
    pub arrival_s: f64,
    /// Session the request belongs to (drives session-affinity routing;
    /// requests of one session share KV locality on a device).
    pub session: u64,
    /// SLO class (interactive may jump queues; batch is default).
    pub slo: SloClass,
    /// Shared-prefix path, root first (empty = no cross-session
    /// sharing). Consumed by the radix prefix cache.
    pub prefix: Vec<PrefixSeg>,
}

impl Request {
    /// KV-cache tokens the request needs reserved for its whole lifetime
    /// (prompt plus full output budget).
    pub fn kv_tokens(&self) -> usize {
        self.prompt_len + self.max_new_tokens
    }

    /// Total shared-prefix tokens (sum over the prefix path), clamped
    /// to the prompt so a malformed spec can never claim reuse beyond
    /// what the request actually prefills.
    pub fn prefix_tokens(&self) -> usize {
        let t: usize = self.prefix.iter().map(|s| s.tokens).sum();
        t.min(self.prompt_len)
    }
}

/// A finished request with its latency breakdown.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    /// Output budget of the request (what the client asked for).
    pub tokens_out: usize,
    /// Tokens whose production was actually simulated (prefill's first
    /// token + executed decode iterations; `max_seq` truncation stops
    /// the count). Scheduling must never change this — the sequential
    /// and batching engines are required to agree per request.
    pub tokens_simulated: usize,
    pub queue_s: f64,
    /// Wall-clock span from admission to the first token. Equals the
    /// summarization service time when prefill runs inline; under
    /// chunked prefill it also covers the decode steps and other
    /// requests' chunks interleaved between this request's chunks.
    pub prefill_s: f64,
    pub decode_s: f64,
    pub finish_s: f64,
    /// Index of the device that served the request (0 for single-device).
    pub device: usize,
    /// SLO class the request carried (drives per-class percentiles).
    pub slo: SloClass,
}

impl Completion {
    pub fn total_latency_s(&self) -> f64 {
        self.queue_s + self.prefill_s + self.decode_s
    }

    /// Time to first token (queue + summarization).
    pub fn ttft_s(&self) -> f64 {
        self.queue_s + self.prefill_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_tokens_is_prompt_plus_budget() {
        let r = Request {
            id: 0,
            prompt_len: 32,
            max_new_tokens: 16,
            arrival_s: 0.0,
            session: 0,
            slo: SloClass::Batch,
            prefix: Vec::new(),
        };
        assert_eq!(r.kv_tokens(), 48);
        assert_eq!(r.prefix_tokens(), 0);
    }

    #[test]
    fn prefix_tokens_sum_and_clamp_to_the_prompt() {
        let mut r = Request {
            id: 0,
            prompt_len: 32,
            max_new_tokens: 16,
            arrival_s: 0.0,
            session: 0,
            slo: SloClass::Interactive,
            prefix: vec![
                PrefixSeg { id: 1, tokens: 16 },
                PrefixSeg { id: 2, tokens: 8 },
            ],
        };
        assert_eq!(r.prefix_tokens(), 24);
        r.prefix.push(PrefixSeg { id: 3, tokens: 64 });
        assert_eq!(r.prefix_tokens(), 32, "clamped to prompt_len");
    }

    #[test]
    fn slo_class_round_trips_and_defaults_to_batch() {
        assert_eq!(SloClass::default(), SloClass::Batch);
        for c in [SloClass::Interactive, SloClass::Batch] {
            assert_eq!(SloClass::parse(c.name()), Some(c));
        }
        assert_eq!(SloClass::parse("gold"), None);
    }

    #[test]
    fn latency_composition() {
        let c = Completion {
            id: 0,
            prompt_len: 32,
            tokens_out: 8,
            tokens_simulated: 8,
            queue_s: 0.1,
            prefill_s: 0.2,
            decode_s: 0.7,
            finish_s: 1.0,
            device: 0,
            slo: SloClass::Batch,
        };
        assert!((c.total_latency_s() - 1.0).abs() < 1e-12);
        assert!((c.ttft_s() - 0.3).abs() < 1e-12);
    }
}
