//! Request / completion vocabulary shared by every serving path.
//!
//! These used to live in [`crate::coordinator`]; they moved here so the
//! single-device sequential coordinator and the cluster engine speak the
//! same types (the coordinator re-exports them for compatibility).

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    /// Arrival time in seconds (simulated wall clock).
    pub arrival_s: f64,
    /// Session the request belongs to (drives session-affinity routing;
    /// requests of one session share KV locality on a device).
    pub session: u64,
}

impl Request {
    /// KV-cache tokens the request needs reserved for its whole lifetime
    /// (prompt plus full output budget).
    pub fn kv_tokens(&self) -> usize {
        self.prompt_len + self.max_new_tokens
    }
}

/// A finished request with its latency breakdown.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    /// Output budget of the request (what the client asked for).
    pub tokens_out: usize,
    /// Tokens whose production was actually simulated (prefill's first
    /// token + executed decode iterations; `max_seq` truncation stops
    /// the count). Scheduling must never change this — the sequential
    /// and batching engines are required to agree per request.
    pub tokens_simulated: usize,
    pub queue_s: f64,
    /// Wall-clock span from admission to the first token. Equals the
    /// summarization service time when prefill runs inline; under
    /// chunked prefill it also covers the decode steps and other
    /// requests' chunks interleaved between this request's chunks.
    pub prefill_s: f64,
    pub decode_s: f64,
    pub finish_s: f64,
    /// Index of the device that served the request (0 for single-device).
    pub device: usize,
}

impl Completion {
    pub fn total_latency_s(&self) -> f64 {
        self.queue_s + self.prefill_s + self.decode_s
    }

    /// Time to first token (queue + summarization).
    pub fn ttft_s(&self) -> f64 {
        self.queue_s + self.prefill_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_tokens_is_prompt_plus_budget() {
        let r = Request {
            id: 0,
            prompt_len: 32,
            max_new_tokens: 16,
            arrival_s: 0.0,
            session: 0,
        };
        assert_eq!(r.kv_tokens(), 48);
    }

    #[test]
    fn latency_composition() {
        let c = Completion {
            id: 0,
            prompt_len: 32,
            tokens_out: 8,
            tokens_simulated: 8,
            queue_s: 0.1,
            prefill_s: 0.2,
            decode_s: 0.7,
            finish_s: 1.0,
            device: 0,
        };
        assert!((c.total_latency_s() - 1.0).abs() < 1e-12);
        assert!((c.ttft_s() - 0.3).abs() < 1e-12);
    }
}
