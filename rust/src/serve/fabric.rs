//! Host-interconnect (fabric) model for disaggregated serving.
//!
//! SAL-PIM's end-to-end story splits prefill-specialist and
//! decode-specialist device pools across a real host interconnect
//! (PIM-GPT / HPIM argue the same production shape): paged KV state
//! *moves* — prefill→decode migration, swap-to-host spill on eviction,
//! swap-in on readmission. This module is the cost model those moves
//! are charged against:
//!
//! * [`FabricParams`] — one link class: bandwidth plus a per-transfer
//!   base latency. The *uncontended* transfer cost
//!   ([`FabricParams::transfer_s`]) is `base + bytes / bandwidth`; the
//!   PCIe preset reproduces PR 2's fixed `kv_handoff_s` constant
//!   bit-for-bit (16 GB/s, zero base latency), which is what lets
//!   [`crate::serve::backend::HeteroBackend`] rebase onto this model
//!   with no numeric drift.
//! * [`Fabric`] — a *shared* link with contention state. Transfers are
//!   charged at token boundaries in simulated time: a transfer of `b`
//!   bytes at sim-time `t` counts the `n-1` transfers already in
//!   flight at `t` and pays `base + n · b / bandwidth` — concurrent
//!   transfers share the link's bandwidth, so a single transfer can
//!   only get *slower* as concurrency grows (pinned by test). The
//!   model is one-sided on purpose: a transfer's cost is fixed at its
//!   charge time from the in-flight set visible then; transfers
//!   charged later never retroactively slow it. That keeps every
//!   charge a pure function of (time, bytes, history) — deterministic
//!   and replayable — at the cost of fluid-sharing exactness.
//!
//! The serving stack is single-threaded, so the shared link is an
//! `Rc<RefCell<Fabric>>` ([`SharedFabric`]) cloned into every engine
//! that can move KV, exactly like [`crate::trace::TraceHandle`].

use std::cell::RefCell;
use std::rc::Rc;

/// One host-link class: bandwidth plus per-transfer base latency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FabricParams {
    /// Link bandwidth in bytes per second (`f64::INFINITY` for the
    /// ideal fabric — transfers then cost exactly `base_latency_s`).
    pub bandwidth_bytes_s: f64,
    /// Fixed per-transfer setup cost (DMA descriptor, doorbell) in
    /// seconds, paid once per transfer regardless of size.
    pub base_latency_s: f64,
}

impl FabricParams {
    /// PCIe-class host link: 16 GB/s, no base latency. Numerically
    /// identical to the fixed `kv_handoff_s` PR 2's hetero backend
    /// used, so rebasing onto the fabric changes no pinned result.
    pub fn pcie() -> Self {
        FabricParams {
            bandwidth_bytes_s: 16e9,
            base_latency_s: 0.0,
        }
    }

    /// NVLink-class link: ~300 GB/s with a 1 µs setup cost.
    pub fn nvlink() -> Self {
        FabricParams {
            bandwidth_bytes_s: 300e9,
            base_latency_s: 1e-6,
        }
    }

    /// The ideal fabric: infinite bandwidth, zero latency. Every
    /// transfer costs exactly `0.0`, so a disaggregated run over it
    /// must reproduce the equivalent single-pool results bit-for-bit.
    pub fn ideal() -> Self {
        FabricParams {
            bandwidth_bytes_s: f64::INFINITY,
            base_latency_s: 0.0,
        }
    }

    /// Uncontended transfer cost: `base + bytes / bandwidth`. This is
    /// the cost signature backends quote (hetero handoff, the
    /// swap-vs-recompute decision rule); the contended [`Fabric`]
    /// charge reduces to it when the link is otherwise idle.
    pub fn transfer_s(&self, bytes: usize) -> f64 {
        self.base_latency_s + bytes as f64 / self.bandwidth_bytes_s
    }
}

impl Default for FabricParams {
    fn default() -> Self {
        FabricParams::pcie()
    }
}

/// Named link classes, the `--fabric` / suite-TOML vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FabricKind {
    #[default]
    Pcie,
    Nvlink,
    Ideal,
}

impl FabricKind {
    pub const ALL: [FabricKind; 3] = [FabricKind::Pcie, FabricKind::Nvlink, FabricKind::Ideal];

    pub fn parse(tok: &str) -> Option<FabricKind> {
        match tok {
            "pcie" => Some(FabricKind::Pcie),
            "nvlink" => Some(FabricKind::Nvlink),
            "ideal" => Some(FabricKind::Ideal),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FabricKind::Pcie => "pcie",
            FabricKind::Nvlink => "nvlink",
            FabricKind::Ideal => "ideal",
        }
    }

    pub fn params(self) -> FabricParams {
        match self {
            FabricKind::Pcie => FabricParams::pcie(),
            FabricKind::Nvlink => FabricParams::nvlink(),
            FabricKind::Ideal => FabricParams::ideal(),
        }
    }
}

/// A shared host link with contention state and transfer counters.
///
/// Time never drives this struct; callers charge transfers at their
/// own simulated clock. Because a cluster runs its devices
/// sequentially, clocks can rewind between engines — the in-flight
/// ledger therefore keeps `(start, end)` intervals and counts only
/// transfers actually overlapping the charge instant, rather than
/// assuming monotone `now`.
#[derive(Debug, Clone)]
pub struct Fabric {
    params: FabricParams,
    /// `(start_s, end_s)` of every charged transfer. Bounded by the
    /// number of KV moves in a run (migrations + swaps), so it is not
    /// garbage-collected — clocks may rewind across devices.
    inflight: Vec<(f64, f64)>,
    migrated_bytes: u64,
    transfers: u64,
}

/// The cloneable handle engines share (single-threaded stack).
pub type SharedFabric = Rc<RefCell<Fabric>>;

impl Fabric {
    pub fn new(params: FabricParams) -> Self {
        Fabric {
            params,
            inflight: Vec::new(),
            migrated_bytes: 0,
            transfers: 0,
        }
    }

    /// A fresh link wrapped in the shared handle.
    pub fn shared(params: FabricParams) -> SharedFabric {
        Rc::new(RefCell::new(Fabric::new(params)))
    }

    pub fn params(&self) -> FabricParams {
        self.params
    }

    /// Transfers in flight at `now_s` (started at or before, ending
    /// strictly after — zero-width ideal transfers never occupy the
    /// link).
    pub fn concurrency_at(&self, now_s: f64) -> usize {
        self.inflight
            .iter()
            .filter(|&&(s, e)| s <= now_s && e > now_s)
            .count()
    }

    /// What a transfer of `bytes` charged at `now_s` would cost,
    /// without committing it — the swap-vs-recompute decision reads
    /// this, then commits only the cheaper option.
    pub fn peek_transfer_s(&self, now_s: f64, bytes: usize) -> f64 {
        let n = self.concurrency_at(now_s) + 1;
        self.params.base_latency_s + n as f64 * (bytes as f64 / self.params.bandwidth_bytes_s)
    }

    /// Charge a transfer of `bytes` at `now_s`: the link's bandwidth
    /// is shared evenly with every transfer in flight at the charge
    /// instant. Returns the transfer's duration and records it.
    pub fn transfer(&mut self, now_s: f64, bytes: usize) -> f64 {
        let dt = self.peek_transfer_s(now_s, bytes);
        self.inflight.push((now_s, now_s + dt));
        self.migrated_bytes += bytes as u64;
        self.transfers += 1;
        dt
    }

    /// Total bytes moved over the link so far.
    pub fn migrated_bytes(&self) -> u64 {
        self.migrated_bytes
    }

    /// Number of transfers charged so far.
    pub fn transfers(&self) -> u64 {
        self.transfers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_parse_and_name_round_trip() {
        for k in FabricKind::ALL {
            assert_eq!(FabricKind::parse(k.name()), Some(k));
        }
        assert_eq!(FabricKind::parse("infiniband"), None);
        assert_eq!(FabricKind::default(), FabricKind::Pcie);
    }

    #[test]
    fn pcie_preset_reproduces_the_legacy_handoff_constant() {
        // PR 2's hetero backend charged (tokens * kv_bytes) / 16e9 on a
        // bare constant; the pcie preset must be bit-identical so the
        // rebase moves no pinned number.
        let p = FabricParams::pcie();
        for bytes in [0usize, 1, 4096, 163_840, 7_340_032] {
            let legacy = bytes as f64 / 16e9;
            assert_eq!(p.transfer_s(bytes).to_bits(), legacy.to_bits());
        }
    }

    #[test]
    fn ideal_fabric_transfers_cost_exactly_zero() {
        let p = FabricParams::ideal();
        let mut f = Fabric::new(p);
        for bytes in [0usize, 1, 1 << 30] {
            assert_eq!(p.transfer_s(bytes), 0.0);
            assert_eq!(f.transfer(0.0, bytes), 0.0);
        }
        // Zero-width transfers never occupy the link.
        assert_eq!(f.concurrency_at(0.0), 0);
        assert_eq!(f.transfers(), 3);
    }

    #[test]
    fn contention_is_monotone_in_concurrency() {
        // The k-th concurrent transfer on a link is never faster than
        // the (k-1)-th: more sharers can only slow a transfer down.
        let bytes = 1 << 20;
        let mut prev = 0.0;
        for k in 1..=8 {
            let mut f = Fabric::new(FabricParams::pcie());
            for _ in 0..k - 1 {
                f.transfer(0.0, bytes);
            }
            let dt = f.transfer(0.0, bytes);
            assert!(
                dt >= prev,
                "transfer #{k} ({dt}) faster than #{} ({prev})",
                k - 1
            );
            assert!(dt >= FabricParams::pcie().transfer_s(bytes));
            prev = dt;
        }
    }

    #[test]
    fn link_drains_and_peek_matches_commit() {
        let mut f = Fabric::new(FabricParams::pcie());
        let bytes = 1 << 24;
        let solo = f.transfer(0.0, bytes);
        assert_eq!(solo.to_bits(), FabricParams::pcie().transfer_s(bytes).to_bits());
        // Overlapping charge pays the shared-bandwidth price...
        let peek = f.peek_transfer_s(solo / 2.0, bytes);
        assert_eq!(f.transfer(solo / 2.0, bytes).to_bits(), peek.to_bits());
        assert!(peek > solo);
        // ...but once everything ended, the link is uncontended again.
        let later = 10.0 * (solo + peek);
        assert_eq!(f.concurrency_at(later), 0);
        assert_eq!(
            f.transfer(later, bytes).to_bits(),
            FabricParams::pcie().transfer_s(bytes).to_bits()
        );
        assert_eq!(f.migrated_bytes(), 3 * bytes as u64);
    }

    #[test]
    fn nonzero_base_latency_is_paid_once_per_transfer() {
        let p = FabricParams::nvlink();
        assert_eq!(p.transfer_s(0), p.base_latency_s);
        let mut f = Fabric::new(p);
        let dt = f.transfer(0.0, 300);
        // 300 bytes at 300 GB/s is 1 ns on top of the 1 µs base.
        assert!((dt - (1e-6 + 1e-9)).abs() < 1e-18);
    }
}
