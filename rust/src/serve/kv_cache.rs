//! KV-cache capacity management for one serving device: whole-window
//! reservations and the paged block allocator.
//!
//! SAL-PIM keeps the KV cache resident in DRAM next to the weights
//! (§3.2's KV mapping streams K/V rows through the S-ALUs like weight
//! rows). A device therefore has a *hard* KV budget: whatever subarrays
//! are left after the model weights and the LUT-embedded subarrays are
//! placed. Two allocation disciplines share that budget:
//!
//! * **Whole-window** ([`KvCacheManager`], `--kv-policy whole`) — the
//!   historical model: [`KvCacheManager::try_admit`] reserves the full
//!   window (prompt + output budget) up front, so admission control is
//!   the only defence against mid-generation overflow. Simple, but every
//!   in-flight request pins KV it has not produced yet, which caps the
//!   decode batch and the shared-weight-stream amortization with it.
//! * **Paged** ([`PagedKvManager`], `--kv-policy paged`) — fixed-size
//!   blocks of [`DeviceCapacity::kv_block_tokens`] tokens (derived from
//!   the subarray row geometry: one block is one subarray's worth of
//!   rows). Blocks are allocated on demand at token boundaries, freed
//!   blocks of a finished request are parked as *session residency* so a
//!   follow-up request of the same session skips re-prefilling the
//!   shared prefix, and under pressure the allocator evicts idle session
//!   blocks in LRU order before the engine resorts to preempting an
//!   active request (recompute-on-readmit; see
//!   [`crate::serve::DeviceEngine`]).
//!
//! On top of the paged discipline sits the cross-session **radix
//! prefix cache** (`--prefix-cache radix`): requests carrying a
//! [`PrefixSeg`] path (root system prompt → group template) share
//! *tree-node-owned* blocks. Nodes own their blocks (block-aligned per
//! node — a documented idealization of sub-block sharing), live leases
//! hold references along their path, and eviction walks unreferenced
//! leaves first (references propagate rootward, so a node with zero
//! references has no live lease anywhere beneath it — eviction can
//! never free a block a live request depends on). The default
//! [`PrefixCacheMode::Session`] keeps PR 4 behavior bit-identical.
//!
//! [`KvPool`] wraps both behind the engine-facing vocabulary so the
//! scheduler is policy-agnostic.

use super::backend::DeviceCapacity;
use super::types::PrefixSeg;
use crate::config::SimConfig;
use crate::trace::{TraceEventKind, TraceHandle};
use std::collections::{BTreeMap, HashMap};

/// Subarrays left for KV on a SAL-PIM device: total subarrays minus the
/// LUT-embedded subarrays minus what the model weights occupy. Shared by
/// [`KvCacheManager::for_device`] and the SAL-PIM execution backend's
/// capacity hint so the two can never disagree.
pub fn device_kv_subarrays(cfg: &SimConfig) -> usize {
    let subarray_bytes = cfg.hbm.subarray_bytes();
    let total = cfg.hbm.total_subarrays();
    let lut = cfg.hbm.total_banks() * cfg.lut.num_lut_subarrays;
    let weight_bytes = cfg.model.total_params() * cfg.model.param_bytes;
    let weight_subarrays = weight_bytes.div_ceil(subarray_bytes);
    total.saturating_sub(lut + weight_subarrays)
}

/// Which KV allocation discipline a device runs (`--kv-policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPolicy {
    /// Reserve the full window (prompt + output budget) at admission.
    Whole,
    /// Allocate fixed-size token blocks on demand at token boundaries.
    Paged,
}

impl KvPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "whole" => Some(KvPolicy::Whole),
            "paged" => Some(KvPolicy::Paged),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KvPolicy::Whole => "whole",
            KvPolicy::Paged => "paged",
        }
    }
}

/// What the paged allocator may reclaim under pressure (`--evict`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictPolicy {
    /// Nothing beyond idle session blocks: admission reserves the whole
    /// window in blocks, so growth can never fail (no preemption path).
    None,
    /// Idle session-resident blocks go first (LRU order); if the pool is
    /// still short, the engine preempts the youngest active request and
    /// recomputes its KV on readmission.
    Lru,
    /// Like [`EvictPolicy::Lru`], but a preempted request's block
    /// payloads spill to a host buffer over the fabric, and
    /// readmission charges the *cheaper* of swapping the KV back in
    /// and recomputing it (see [`crate::serve::DeviceEngine`]).
    Swap,
}

impl EvictPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "none" => Some(EvictPolicy::None),
            // "recompute" is an alias: PR 4's preempt-and-recompute
            // discipline, spelled by what readmission costs.
            "lru" | "recompute" => Some(EvictPolicy::Lru),
            "swap" => Some(EvictPolicy::Swap),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EvictPolicy::None => "none",
            EvictPolicy::Lru => "lru",
            EvictPolicy::Swap => "swap",
        }
    }
}

/// How the paged pool shares already-computed KV across requests
/// (`--prefix-cache`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PrefixCacheMode {
    /// PR 4's per-session residency only — the default; pre-radix runs
    /// stay bit-identical.
    #[default]
    Session,
    /// Cross-session radix-tree prefix caching: tree nodes own the
    /// shared-prefix blocks, sessions hold references, eviction walks
    /// unreferenced leaves first. Session residency still covers each
    /// session's private conversation suffix.
    Radix,
}

impl PrefixCacheMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "session" => Some(PrefixCacheMode::Session),
            "radix" => Some(PrefixCacheMode::Radix),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PrefixCacheMode::Session => "session",
            PrefixCacheMode::Radix => "radix",
        }
    }
}

/// A granted whole-window KV reservation (returned by
/// [`KvCacheManager::try_admit`]; hand it back with
/// [`KvCacheManager::release`]).
#[derive(Debug)]
pub struct KvLease {
    /// Request id the lease belongs to (for diagnostics).
    pub request_id: u64,
    /// Token window reserved.
    pub tokens: usize,
    /// Whole subarrays consumed by the reservation.
    pub subarrays: usize,
}

/// Tracks the KV subarray pool of one device under whole-window
/// reservations.
#[derive(Debug)]
pub struct KvCacheManager {
    /// Bytes of K+V state per token (2 × layers × d_model × param bytes).
    kv_bytes_per_token: usize,
    /// Bytes per subarray (rows × row size).
    subarray_bytes: usize,
    /// Subarrays in the device's KV region.
    total_subarrays: usize,
    free_subarrays: usize,
    /// Live admissions (sum of leased tokens, for reporting).
    reserved_tokens: usize,
    admitted: usize,
    peak_used_subarrays: usize,
}

impl KvCacheManager {
    /// KV region derived from the device config: total subarrays minus
    /// the LUT-embedded subarrays minus what the model weights occupy
    /// (see [`device_kv_subarrays`]).
    pub fn for_device(cfg: &SimConfig) -> Self {
        Self::with_kv_subarrays(cfg, device_kv_subarrays(cfg))
    }

    /// Manager over a backend's capacity hints. "Subarray" generalizes
    /// to the backend's allocation unit (a DRAM subarray on PIM, a page
    /// on a GPU).
    pub fn from_capacity(cap: &DeviceCapacity) -> Self {
        Self::from_capacity_units(cap, cap.kv_total_units)
    }

    /// [`KvCacheManager::from_capacity`] with an overridden unit count
    /// (tests and what-if admission-pressure sweeps).
    pub fn from_capacity_units(cap: &DeviceCapacity, units: usize) -> Self {
        KvCacheManager {
            kv_bytes_per_token: cap.kv_bytes_per_token,
            subarray_bytes: cap.kv_alloc_unit_bytes,
            total_subarrays: units,
            free_subarrays: units,
            reserved_tokens: 0,
            admitted: 0,
            peak_used_subarrays: 0,
        }
    }

    /// Manager over an explicit KV-region size (tests and what-if sweeps).
    pub fn with_kv_subarrays(cfg: &SimConfig, kv_subarrays: usize) -> Self {
        KvCacheManager {
            kv_bytes_per_token: cfg.model.kv_bytes_per_token(),
            subarray_bytes: cfg.hbm.subarray_bytes(),
            total_subarrays: kv_subarrays,
            free_subarrays: kv_subarrays,
            reserved_tokens: 0,
            admitted: 0,
            peak_used_subarrays: 0,
        }
    }

    /// Whole subarrays a `tokens`-wide KV window occupies.
    pub fn subarrays_for(&self, tokens: usize) -> usize {
        (tokens * self.kv_bytes_per_token).div_ceil(self.subarray_bytes)
    }

    /// Token capacity if the region were filled by one giant request.
    pub fn capacity_tokens(&self) -> usize {
        self.total_subarrays * self.subarray_bytes / self.kv_bytes_per_token
    }

    /// Could the request ever be admitted (even on an idle device)?
    pub fn fits_ever(&self, tokens: usize) -> bool {
        self.subarrays_for(tokens) <= self.total_subarrays
    }

    /// Try to reserve a `tokens`-wide window; `None` when the region is
    /// exhausted (the caller should retry after a completion frees slots).
    pub fn try_admit(&mut self, request_id: u64, tokens: usize) -> Option<KvLease> {
        let need = self.subarrays_for(tokens);
        if need > self.free_subarrays {
            return None;
        }
        self.free_subarrays -= need;
        self.reserved_tokens += tokens;
        self.admitted += 1;
        self.peak_used_subarrays = self.peak_used_subarrays.max(self.used_subarrays());
        Some(KvLease {
            request_id,
            tokens,
            subarrays: need,
        })
    }

    /// Return a lease's subarrays to the pool.
    pub fn release(&mut self, lease: KvLease) {
        debug_assert!(self.used_subarrays() >= lease.subarrays, "double release");
        self.free_subarrays = (self.free_subarrays + lease.subarrays).min(self.total_subarrays);
        self.reserved_tokens = self.reserved_tokens.saturating_sub(lease.tokens);
        self.admitted = self.admitted.saturating_sub(1);
    }

    pub fn total_subarrays(&self) -> usize {
        self.total_subarrays
    }

    pub fn used_subarrays(&self) -> usize {
        self.total_subarrays - self.free_subarrays
    }

    /// Live admissions.
    pub fn admitted(&self) -> usize {
        self.admitted
    }

    /// Tokens currently reserved.
    pub fn reserved_tokens(&self) -> usize {
        self.reserved_tokens
    }

    /// Fraction of the KV region in use right now.
    pub fn utilization(&self) -> f64 {
        if self.total_subarrays == 0 {
            return 0.0;
        }
        self.used_subarrays() as f64 / self.total_subarrays as f64
    }

    /// High-water utilization over the manager's lifetime.
    pub fn peak_utilization(&self) -> f64 {
        if self.total_subarrays == 0 {
            return 0.0;
        }
        self.peak_used_subarrays as f64 / self.total_subarrays as f64
    }
}

/// A live paged allocation: the blocks currently backing one request's
/// KV state. Grows via [`PagedKvManager::try_grow`]; hand it back with
/// [`PagedKvManager::release_retain`] (park for session reuse) or
/// [`PagedKvManager::free`] (preemption — the KV is dropped).
#[derive(Debug)]
pub struct PagedLease {
    /// Request id the lease belongs to (for diagnostics).
    pub request_id: u64,
    /// Session whose residency the blocks join on release.
    pub session: u64,
    /// Tokens currently covered (shared prefix + private suffix).
    pub tokens: usize,
    /// Private blocks held by the lease. Excludes blocks owned by
    /// referenced prefix-tree nodes — those belong to the tree.
    pub blocks: usize,
    /// Tokens covered by the referenced prefix-tree nodes (0 outside
    /// radix mode).
    pub prefix_tokens: usize,
    /// Prefix-node ids the lease holds references on, root first.
    pub path: Vec<u64>,
}

/// Idle blocks a finished request left behind, keyed by session (the
/// session id is the map key in [`PagedKvManager::resident`]).
#[derive(Debug)]
struct SessionResidency {
    tokens: usize,
    blocks: usize,
    /// LRU stamp (monotone sequence, not wall clock — deterministic).
    last_use: u64,
}

/// One node of the cross-session prefix tree. Every node in the map is
/// populated (its blocks hold computed KV): creation and population
/// happen atomically inside the admission that first prefills the
/// node's tokens, and eviction removes the node entirely.
#[derive(Debug)]
struct PrefixNode {
    /// Tokens this node itself covers (not cumulative along the path).
    tokens: usize,
    /// Blocks the node owns (block-aligned per node).
    blocks: usize,
    /// Parent node id (0 = tree root's parent, i.e. none).
    parent: u64,
    /// Live leases whose path includes this node. References are taken
    /// along the *whole* path, so `refs == 0` implies no live lease
    /// references any descendant either.
    refs: usize,
    /// Children currently in the tree (for leaf-first eviction).
    children: usize,
    /// LRU stamp, refreshed by every admission traversing the node.
    last_use: u64,
}

/// Fixed-size-block KV allocator with LRU session residency.
///
/// Capacity accounting is in *blocks* of `block_tokens` tokens each; the
/// block byte size is `block_tokens × kv_bytes_per_token`, sized so one
/// block is one subarray's worth of K/V rows on PIM (one allocator page
/// on a GPU). The region holds `total_blocks` blocks — the same bytes as
/// the whole-window manager's subarray region, so paged-vs-whole
/// comparisons run at equal HBM capacity.
#[derive(Debug)]
pub struct PagedKvManager {
    kv_bytes_per_token: usize,
    /// Bytes per backend allocation unit (used to size the region).
    alloc_unit_bytes: usize,
    /// Allocation units backing the region (the byte budget).
    region_units: usize,
    block_tokens: usize,
    total_blocks: usize,
    free_blocks: usize,
    /// Idle session blocks, keyed by session id for O(1) residency
    /// lookups (affinity routing probes every device per arrival).
    resident: HashMap<u64, SessionResidency>,
    /// LRU index: `last_use` stamp → session. Stamps are unique and
    /// monotone, so `pop_first()` is the least-recently-used session;
    /// kept coherent with `resident` at every insert/reclaim/evict.
    lru: BTreeMap<u64, u64>,
    /// Blocks currently parked across all residencies (sum of
    /// `resident[*].blocks`, maintained incrementally).
    resident_blocks: usize,
    lru_seq: u64,
    admitted: usize,
    peak_used_blocks: usize,
    reuse_hits: usize,
    reuse_tokens: usize,
    sessions_evicted: usize,
    /// Cross-session sharing discipline (`Session` = PR 4 behavior).
    prefix_mode: PrefixCacheMode,
    /// The radix prefix tree, keyed by node id (empty outside radix
    /// mode — and then every counter below stays 0, keeping the legacy
    /// arithmetic bit-identical).
    prefix_nodes: HashMap<u64, PrefixNode>,
    /// Blocks owned by tree nodes no live lease references (the
    /// evictable share of the tree), maintained incrementally.
    unpinned_prefix_blocks: usize,
    prefix_hits: usize,
    prefix_reused_tokens: usize,
    prefix_nodes_evicted: usize,
    /// Shared lifecycle-event sink (the engine keeps its sim-time stamp
    /// fresh before calling in); `None` records nothing.
    trace: Option<TraceHandle>,
}

impl PagedKvManager {
    /// Allocator over a backend's full KV region.
    pub fn from_capacity(cap: &DeviceCapacity) -> Self {
        Self::from_capacity_units(cap, cap.kv_total_units)
    }

    /// Allocator over `units` backend allocation units (what-if pressure
    /// sweeps; equal bytes to [`KvCacheManager::from_capacity_units`]).
    pub fn from_capacity_units(cap: &DeviceCapacity, units: usize) -> Self {
        let mut mgr = PagedKvManager {
            kv_bytes_per_token: cap.kv_bytes_per_token,
            alloc_unit_bytes: cap.kv_alloc_unit_bytes,
            region_units: units,
            block_tokens: cap.kv_block_tokens.max(1),
            total_blocks: 0,
            free_blocks: 0,
            resident: HashMap::new(),
            lru: BTreeMap::new(),
            resident_blocks: 0,
            lru_seq: 0,
            admitted: 0,
            peak_used_blocks: 0,
            reuse_hits: 0,
            reuse_tokens: 0,
            sessions_evicted: 0,
            prefix_mode: PrefixCacheMode::Session,
            prefix_nodes: HashMap::new(),
            unpinned_prefix_blocks: 0,
            prefix_hits: 0,
            prefix_reused_tokens: 0,
            prefix_nodes_evicted: 0,
            trace: None,
        };
        mgr.resize_blocks();
        mgr
    }

    /// Select the cross-session sharing discipline (`--prefix-cache`).
    pub fn with_prefix_mode(mut self, mode: PrefixCacheMode) -> Self {
        self.prefix_mode = mode;
        self
    }

    /// Attach the engine's lifecycle-event sink so evictions and reuse
    /// hits land in the same stream as scheduler events.
    pub fn set_trace(&mut self, trace: TraceHandle) {
        self.trace = Some(trace);
    }

    /// Override the block size in tokens (`--kv-block`); the block count
    /// is re-derived so the region's byte budget stays fixed.
    pub fn with_block_tokens(mut self, tokens: usize) -> Self {
        assert!(tokens >= 1, "a KV block holds at least one token");
        self.block_tokens = tokens;
        self.resize_blocks();
        self
    }

    fn resize_blocks(&mut self) {
        debug_assert!(self.resident.is_empty() && self.admitted == 0);
        let region_bytes = self.region_units * self.alloc_unit_bytes;
        let block_bytes = self.block_tokens * self.kv_bytes_per_token;
        self.total_blocks = if block_bytes == 0 {
            0
        } else {
            region_bytes / block_bytes
        };
        self.free_blocks = self.total_blocks;
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    /// Blocks a `tokens`-long KV state occupies.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Token capacity if the region were filled by one giant request.
    pub fn capacity_tokens(&self) -> usize {
        self.total_blocks * self.block_tokens
    }

    /// Could a `tokens`-wide state ever be resident (idle device, every
    /// session evicted)?
    pub fn fits_ever(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.total_blocks
    }

    fn resident_blocks(&self) -> usize {
        debug_assert_eq!(
            self.resident_blocks,
            self.resident.values().map(|r| r.blocks).sum::<usize>()
        );
        self.resident_blocks
    }

    /// Tokens of `session`'s KV currently parked for reuse.
    pub fn session_resident_tokens(&self, session: u64) -> usize {
        self.resident.get(&session).map(|r| r.tokens).unwrap_or(0)
    }

    fn next_seq(&mut self) -> u64 {
        self.lru_seq += 1;
        self.lru_seq
    }

    /// Evict idle sessions (LRU first) until `need` blocks are free.
    /// Returns `false` if even a fully-evicted pool stays short.
    fn evict_idle_until(&mut self, need: usize) -> bool {
        while self.free_blocks < need {
            let Some((_, session)) = self.lru.pop_first() else {
                return false;
            };
            let r = self
                .resident
                .remove(&session)
                .expect("lru index is coherent with residency map");
            self.free_blocks += r.blocks;
            self.resident_blocks -= r.blocks;
            self.sessions_evicted += 1;
            if let Some(t) = &self.trace {
                t.emit(TraceEventKind::EvictBlocks {
                    session,
                    blocks: r.blocks,
                });
            }
        }
        true
    }

    /// Evict unreferenced prefix-tree leaves (LRU first, ties by node
    /// id for determinism) until `need` blocks are free. A node is a
    /// victim only with zero references *and* zero children — and
    /// since references are taken along whole paths, an unreferenced
    /// node has no referenced descendants: eviction can never free a
    /// block a live lease depends on.
    fn evict_prefix_until(&mut self, need: usize) -> bool {
        while self.free_blocks < need {
            let victim = self
                .prefix_nodes
                .iter()
                .filter(|(_, n)| n.refs == 0 && n.children == 0)
                .min_by_key(|(id, n)| (n.last_use, **id))
                .map(|(id, _)| *id);
            let Some(id) = victim else {
                return false;
            };
            let n = self
                .prefix_nodes
                .remove(&id)
                .expect("victim was just found in the tree");
            self.free_blocks += n.blocks;
            self.unpinned_prefix_blocks -= n.blocks;
            if let Some(p) = self.prefix_nodes.get_mut(&n.parent) {
                p.children -= 1;
            }
            self.prefix_nodes_evicted += 1;
        }
        true
    }

    /// Reclaim idle capacity — session residencies first (LRU), then
    /// unreferenced prefix leaves. With an empty tree this is exactly
    /// the historical [`PagedKvManager::evict_idle_until`].
    fn evict_until(&mut self, need: usize) -> bool {
        self.evict_idle_until(need) || self.evict_prefix_until(need)
    }

    fn add_node_ref(&mut self, id: u64) {
        let n = self
            .prefix_nodes
            .get_mut(&id)
            .expect("referenced node exists");
        n.refs += 1;
        if n.refs == 1 {
            self.unpinned_prefix_blocks -= n.blocks;
        }
    }

    fn drop_node_ref(&mut self, id: u64) {
        let n = self
            .prefix_nodes
            .get_mut(&id)
            .expect("released lease held a reference");
        n.refs -= 1;
        if n.refs == 0 {
            self.unpinned_prefix_blocks += n.blocks;
        }
    }

    fn note_peak(&mut self) {
        self.peak_used_blocks = self.peak_used_blocks.max(self.used_blocks());
    }

    /// Admit a request needing `want_tokens` of coverage. The session's
    /// parked residency (if any) is reclaimed into the lease first:
    /// `min(resident, max_reuse)` tokens count as an already-computed
    /// prefix the caller may skip prefilling (the reuse hit). Other
    /// sessions' idle blocks are evicted LRU-first if the free pool is
    /// short. `None` defers the request (active leases hold too much).
    ///
    /// Under [`PrefixCacheMode::Radix`], a request carrying a `prefix`
    /// path is admitted through the prefix tree instead: already
    /// populated path nodes count as reuse (across *any* session) and
    /// missing ones are populated by this request's prefill; the lease
    /// then covers only the private suffix and holds references along
    /// the path. The failure probe stays pure — `None` is decided
    /// before any state mutates (required by the event core's
    /// admission memoization).
    pub fn try_admit(
        &mut self,
        request_id: u64,
        session: u64,
        want_tokens: usize,
        max_reuse: usize,
        prefix: &[PrefixSeg],
    ) -> Option<(PagedLease, usize)> {
        if self.prefix_mode == PrefixCacheMode::Radix && !prefix.is_empty() {
            // Pure planning pass: what the path costs and what it frees.
            let mut prefix_alloc = 0usize; // tokens the path will cover
            let mut new_node_blocks = 0usize; // blocks for missing nodes
            let mut path_unpinned = 0usize; // evictable blocks we will pin
            for seg in prefix {
                match self.prefix_nodes.get(&seg.id) {
                    Some(n) => {
                        prefix_alloc += n.tokens;
                        if n.refs == 0 {
                            path_unpinned += n.blocks;
                        }
                    }
                    None => {
                        prefix_alloc += seg.tokens;
                        new_node_blocks += self.blocks_for(seg.tokens);
                    }
                }
            }
            let private_blocks = self.blocks_for(want_tokens.saturating_sub(prefix_alloc));
            let need_total = private_blocks + new_node_blocks;
            if need_total <= self.total_blocks {
                // Path nodes we are about to pin stop being evictable,
                // so they cannot count toward availability.
                let reclaimable = self.free_blocks
                    + self.resident_blocks()
                    + (self.unpinned_prefix_blocks - path_unpinned);
                if need_total > reclaimable {
                    return None;
                }
                return Some(self.admit_radix(
                    request_id,
                    session,
                    want_tokens,
                    max_reuse,
                    prefix,
                    private_blocks,
                ));
            }
            // Per-node block rounding made the shared path plus the
            // private suffix exceed the whole region even though the
            // unshared request fits: serve it unshared below rather
            // than defer forever.
        }
        let want_blocks = self.blocks_for(want_tokens);
        if want_blocks
            > self.free_blocks + self.resident_blocks() + self.unpinned_prefix_blocks
        {
            return None;
        }
        let mut reused = 0usize;
        if let Some(r) = self.resident.remove(&session) {
            self.lru.remove(&r.last_use);
            self.free_blocks += r.blocks;
            self.resident_blocks -= r.blocks;
            reused = r.tokens.min(max_reuse);
            if reused > 0 {
                self.reuse_hits += 1;
                self.reuse_tokens += reused;
                if let Some(t) = &self.trace {
                    t.emit(TraceEventKind::ReuseHit {
                        id: request_id,
                        session,
                        tokens: reused,
                    });
                }
            }
        }
        if !self.evict_until(want_blocks) {
            unreachable!("availability was checked above");
        }
        self.free_blocks -= want_blocks;
        self.admitted += 1;
        self.note_peak();
        Some((
            PagedLease {
                request_id,
                session,
                tokens: want_tokens,
                blocks: want_blocks,
                prefix_tokens: 0,
                path: Vec::new(),
            },
            reused,
        ))
    }

    /// Radix-mode admission: availability was already proven by
    /// [`PagedKvManager::try_admit`]'s pure planning pass.
    fn admit_radix(
        &mut self,
        request_id: u64,
        session: u64,
        want_tokens: usize,
        max_reuse: usize,
        prefix: &[PrefixSeg],
        private_blocks: usize,
    ) -> (PagedLease, usize) {
        // The reusable prefix is the *leading* chain of already
        // populated nodes (population is root-first and eviction
        // leaf-first, so the populated set along a path is always a
        // leading chain; the guard below only defends the invariant).
        let mut chain_tokens = 0usize;
        let mut prefix_alloc = 0usize;
        let mut new_node_blocks = 0usize;
        for seg in prefix {
            match self.prefix_nodes.get(&seg.id) {
                Some(n) => {
                    if chain_tokens == prefix_alloc {
                        chain_tokens += n.tokens;
                    }
                    prefix_alloc += n.tokens;
                }
                None => {
                    prefix_alloc += seg.tokens;
                    new_node_blocks += self.blocks_for(seg.tokens);
                }
            }
        }
        // Pin the existing path nodes *before* eviction runs so
        // pressure from this very admission can never take them.
        let stamp = self.next_seq();
        for seg in prefix {
            if let Some(n) = self.prefix_nodes.get_mut(&seg.id) {
                n.refs += 1;
                n.last_use = stamp;
                if n.refs == 1 {
                    let b = n.blocks;
                    self.unpinned_prefix_blocks -= b;
                }
            }
        }
        // Reclaim the session's own parked suffix (it is contiguous
        // with the shared prefix only when the whole path was already
        // populated — otherwise its KV sits beyond a gap this request
        // must re-prefill anyway, so it cannot count as reuse).
        let mut session_tokens = 0usize;
        if let Some(r) = self.resident.remove(&session) {
            self.lru.remove(&r.last_use);
            self.free_blocks += r.blocks;
            self.resident_blocks -= r.blocks;
            session_tokens = r.tokens;
        }
        if !self.evict_until(private_blocks + new_node_blocks) {
            unreachable!("availability was checked by the planning pass");
        }
        // Populate missing nodes root-first; each is born referenced.
        let mut parent = 0u64;
        for seg in prefix {
            if self.prefix_nodes.contains_key(&seg.id) {
                parent = seg.id;
                continue;
            }
            let blocks = self.blocks_for(seg.tokens);
            self.free_blocks -= blocks;
            self.prefix_nodes.insert(
                seg.id,
                PrefixNode {
                    tokens: seg.tokens,
                    blocks,
                    parent,
                    refs: 1,
                    children: 0,
                    last_use: stamp,
                },
            );
            if let Some(p) = self.prefix_nodes.get_mut(&parent) {
                p.children += 1;
            }
            parent = seg.id;
        }
        self.free_blocks -= private_blocks;
        self.admitted += 1;
        self.note_peak();
        let chain_reuse = chain_tokens.min(max_reuse);
        let mut reused = chain_reuse;
        if chain_tokens == prefix_alloc && session_tokens > 0 {
            reused = (chain_tokens + session_tokens).min(max_reuse);
        }
        if chain_reuse > 0 {
            self.prefix_hits += 1;
            self.prefix_reused_tokens += chain_reuse;
        }
        let session_part = reused - chain_reuse;
        if session_part > 0 {
            self.reuse_hits += 1;
            self.reuse_tokens += session_part;
        }
        if reused > 0 {
            if let Some(t) = &self.trace {
                t.emit(TraceEventKind::ReuseHit {
                    id: request_id,
                    session,
                    tokens: reused,
                });
            }
        }
        (
            PagedLease {
                request_id,
                session,
                tokens: want_tokens,
                blocks: private_blocks,
                prefix_tokens: prefix_alloc,
                path: prefix.iter().map(|s| s.id).collect(),
            },
            reused,
        )
    }

    /// Grow a lease to cover `want_tokens`, allocating blocks on demand
    /// (idle sessions evicted LRU-first, then unreferenced prefix
    /// leaves). Tokens covered by the lease's referenced prefix nodes
    /// never need new blocks — the tree already holds them. `false`
    /// means the engine must preempt an active request (or stall) and
    /// retry.
    pub fn try_grow(&mut self, lease: &mut PagedLease, want_tokens: usize) -> bool {
        let want_blocks = self.blocks_for(want_tokens.saturating_sub(lease.prefix_tokens));
        if want_blocks <= lease.blocks {
            lease.tokens = lease.tokens.max(want_tokens);
            return true;
        }
        let need = want_blocks - lease.blocks;
        if need > self.free_blocks + self.resident_blocks() + self.unpinned_prefix_blocks {
            return false;
        }
        if !self.evict_until(need) {
            unreachable!("availability was checked above");
        }
        self.free_blocks -= need;
        lease.blocks = want_blocks;
        lease.tokens = want_tokens;
        self.note_peak();
        true
    }

    /// Finish a request, parking its blocks as session residency so a
    /// follow-up of the same session can reuse the prefix. If the
    /// session already has parked blocks, the larger footprint wins.
    /// Prefix-path references are dropped first; only the *private*
    /// suffix (tokens beyond the referenced path) parks — the shared
    /// prefix stays with the tree.
    pub fn release_retain(&mut self, lease: PagedLease) {
        self.admitted = self.admitted.saturating_sub(1);
        for id in &lease.path {
            self.drop_node_ref(*id);
        }
        if lease.blocks == 0 {
            return;
        }
        let private_tokens = lease.tokens.saturating_sub(lease.prefix_tokens);
        let seq = self.next_seq();
        if let Some(r) = self.resident.get_mut(&lease.session) {
            if r.tokens >= private_tokens {
                self.free_blocks += lease.blocks;
            } else {
                self.free_blocks += r.blocks;
                self.resident_blocks -= r.blocks;
                self.resident_blocks += lease.blocks;
                r.tokens = private_tokens;
                r.blocks = lease.blocks;
            }
            self.lru.remove(&r.last_use);
            r.last_use = seq;
            self.lru.insert(seq, lease.session);
        } else {
            self.resident.insert(
                lease.session,
                SessionResidency {
                    tokens: private_tokens,
                    blocks: lease.blocks,
                    last_use: seq,
                },
            );
            self.resident_blocks += lease.blocks;
            self.lru.insert(seq, lease.session);
        }
    }

    /// Drop a lease without retention (preemption: the KV is lost and
    /// must be recomputed on readmission). Prefix nodes are *not* lost
    /// — only the lease's references are dropped.
    pub fn free(&mut self, lease: PagedLease) {
        self.admitted = self.admitted.saturating_sub(1);
        for id in &lease.path {
            self.drop_node_ref(*id);
        }
        self.free_blocks = (self.free_blocks + lease.blocks).min(self.total_blocks);
    }

    /// Live admissions.
    pub fn admitted(&self) -> usize {
        self.admitted
    }

    /// Blocks holding data right now (leased + parked residencies).
    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free_blocks
    }

    /// Admissions that reclaimed a session prefix.
    pub fn reuse_hits(&self) -> usize {
        self.reuse_hits
    }

    /// Prompt tokens whose prefill was skipped via session reuse.
    pub fn reuse_tokens(&self) -> usize {
        self.reuse_tokens
    }

    /// Idle session residencies evicted under pressure.
    pub fn sessions_evicted(&self) -> usize {
        self.sessions_evicted
    }

    /// Admissions that reused a populated prefix-tree chain.
    pub fn prefix_hits(&self) -> usize {
        self.prefix_hits
    }

    /// Prompt tokens whose prefill was skipped via the prefix tree
    /// (cross-session; disjoint from [`PagedKvManager::reuse_tokens`]).
    pub fn prefix_reused_tokens(&self) -> usize {
        self.prefix_reused_tokens
    }

    /// Prefix-tree nodes evicted under pressure.
    pub fn prefix_nodes_evicted(&self) -> usize {
        self.prefix_nodes_evicted
    }

    /// Nodes currently populated in the prefix tree.
    pub fn prefix_nodes_live(&self) -> usize {
        self.prefix_nodes.len()
    }

    /// Fraction of the region holding data right now.
    pub fn utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            return 0.0;
        }
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    /// High-water utilization over the manager's lifetime.
    pub fn peak_utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            return 0.0;
        }
        self.peak_used_blocks as f64 / self.total_blocks as f64
    }
}

/// A lease from either allocation discipline.
#[derive(Debug)]
pub enum PoolLease {
    Whole(KvLease),
    Paged(PagedLease),
}

/// Engine-facing KV pool: whole-window or paged, one vocabulary.
#[derive(Debug)]
pub enum KvPool {
    Whole(KvCacheManager),
    Paged {
        mgr: PagedKvManager,
        evict: EvictPolicy,
    },
}

impl KvPool {
    /// Build the pool a device engine runs: `policy` picks the
    /// discipline, `block_tokens` overrides the paged block size,
    /// `units` shrinks the region (what-if pressure; both disciplines
    /// see the same byte budget).
    pub fn for_capacity(
        cap: &DeviceCapacity,
        policy: KvPolicy,
        evict: EvictPolicy,
        prefix: PrefixCacheMode,
        block_tokens: Option<usize>,
        units: Option<usize>,
    ) -> Self {
        let units = units.unwrap_or(cap.kv_total_units);
        match policy {
            KvPolicy::Whole => KvPool::Whole(KvCacheManager::from_capacity_units(cap, units)),
            KvPolicy::Paged => {
                let mut mgr =
                    PagedKvManager::from_capacity_units(cap, units).with_prefix_mode(prefix);
                if let Some(b) = block_tokens {
                    mgr = mgr.with_block_tokens(b);
                }
                KvPool::Paged { mgr, evict }
            }
        }
    }

    pub fn policy(&self) -> KvPolicy {
        match self {
            KvPool::Whole(_) => KvPolicy::Whole,
            KvPool::Paged { .. } => KvPolicy::Paged,
        }
    }

    /// Attach a lifecycle-event sink (paged pools emit evictions and
    /// reuse hits; the whole-window pool has nothing to report).
    pub fn set_trace(&mut self, trace: TraceHandle) {
        if let KvPool::Paged { mgr, .. } = self {
            mgr.set_trace(trace);
        }
    }

    /// Could a request with this full window ever run on an idle device?
    pub fn fits_ever(&self, window_tokens: usize) -> bool {
        match self {
            KvPool::Whole(m) => m.fits_ever(window_tokens),
            KvPool::Paged { mgr, .. } => mgr.fits_ever(window_tokens),
        }
    }

    /// Admit a fresh request. Whole reserves the full window; paged
    /// reserves the prompt plus the first token (`--evict lru`) or the
    /// full window (`--evict none`, which makes growth infallible).
    /// `prefix` is the request's shared-prefix path (consumed only in
    /// radix mode). Returns the lease and the reused prefix tokens
    /// (session residency and/or radix chain).
    pub fn try_admit(
        &mut self,
        request_id: u64,
        session: u64,
        prompt_len: usize,
        window_tokens: usize,
        prefix: &[PrefixSeg],
    ) -> Option<(PoolLease, usize)> {
        match self {
            KvPool::Whole(m) => m
                .try_admit(request_id, window_tokens)
                .map(|l| (PoolLease::Whole(l), 0)),
            KvPool::Paged { mgr, evict } => {
                let want = match evict {
                    EvictPolicy::None => window_tokens.max(prompt_len + 1),
                    EvictPolicy::Lru | EvictPolicy::Swap => prompt_len + 1,
                };
                // Reuse at most prompt_len - 1 tokens: the last prompt
                // token always prefills so the first output token has a
                // nonzero cost.
                let max_reuse = prompt_len.saturating_sub(1);
                mgr.try_admit(request_id, session, want, max_reuse, prefix)
                    .map(|(l, reused)| (PoolLease::Paged(l), reused))
            }
        }
    }

    /// Admit a request whose prefill ran on another device and whose KV
    /// arrives by fabric migration: same coverage as [`KvPool::try_admit`]
    /// but **no** session-residency reuse — the migrated blocks *are* the
    /// request's state, so reclaiming a parked prefix here would skew
    /// both the reuse accounting and the migrated-byte count.
    pub fn try_admit_migrated(
        &mut self,
        request_id: u64,
        session: u64,
        prompt_len: usize,
        window_tokens: usize,
    ) -> Option<PoolLease> {
        match self {
            KvPool::Whole(m) => m.try_admit(request_id, window_tokens).map(PoolLease::Whole),
            KvPool::Paged { mgr, evict } => {
                let want = match evict {
                    EvictPolicy::None => window_tokens.max(prompt_len + 1),
                    EvictPolicy::Lru | EvictPolicy::Swap => prompt_len + 1,
                };
                mgr.try_admit(request_id, session, want, 0, &[])
                    .map(|(l, _)| PoolLease::Paged(l))
            }
        }
    }

    /// Re-admit a preempted request: allocate coverage for its rebuilt
    /// KV (`tokens`), no session reuse (its blocks were dropped).
    pub fn try_readmit(&mut self, request_id: u64, session: u64, tokens: usize) -> Option<PoolLease> {
        match self {
            KvPool::Whole(m) => m.try_admit(request_id, tokens).map(PoolLease::Whole),
            KvPool::Paged { mgr, .. } => mgr
                .try_admit(request_id, session, tokens, 0, &[])
                .map(|(l, _)| PoolLease::Paged(l)),
        }
    }

    /// Make sure the lease covers `tokens` before a decode step writes
    /// KV up to that length. Whole-window leases always do (the window
    /// was reserved up front); paged leases grow block-by-block. `false`
    /// means the engine must preempt a victim (or stall this request one
    /// boundary) and retry.
    pub fn ensure(&mut self, lease: &mut PoolLease, tokens: usize) -> bool {
        match (self, lease) {
            (KvPool::Whole(_), PoolLease::Whole(l)) => {
                debug_assert!(tokens <= l.tokens, "decode past the reserved window");
                true
            }
            (KvPool::Paged { mgr, .. }, PoolLease::Paged(l)) => mgr.try_grow(l, tokens),
            _ => unreachable!("lease/pool policy mismatch"),
        }
    }

    /// Finish a request. Paged pools park the blocks for session reuse;
    /// whole-window pools return them to the free list.
    pub fn release(&mut self, lease: PoolLease) {
        match (self, lease) {
            (KvPool::Whole(m), PoolLease::Whole(l)) => m.release(l),
            (KvPool::Paged { mgr, .. }, PoolLease::Paged(l)) => mgr.release_retain(l),
            _ => unreachable!("lease/pool policy mismatch"),
        }
    }

    /// Drop a preempted request's lease (no retention).
    pub fn free(&mut self, lease: PoolLease) {
        match (self, lease) {
            (KvPool::Whole(m), PoolLease::Whole(l)) => m.release(l),
            (KvPool::Paged { mgr, .. }, PoolLease::Paged(l)) => mgr.free(l),
            _ => unreachable!("lease/pool policy mismatch"),
        }
    }

    /// Whether leases can need per-boundary growth. Whole-window pools
    /// reserve the full window up front, so [`KvPool::ensure`] is a
    /// guaranteed no-op and the engine's event core skips the growth
    /// phase entirely. Paged pools grow block-by-block *and* track the
    /// covered token count on the lease (which feeds session-reuse
    /// accounting at release), so they must always run it.
    pub fn needs_growth(&self) -> bool {
        matches!(self, KvPool::Paged { .. })
    }

    /// Whether the engine may preempt active requests under pressure.
    pub fn preemption_allowed(&self) -> bool {
        matches!(
            self,
            KvPool::Paged {
                evict: EvictPolicy::Lru | EvictPolicy::Swap,
                ..
            }
        )
    }

    /// Whether preempted KV spills to the host buffer instead of being
    /// dropped outright (`--evict swap`).
    pub fn swap_enabled(&self) -> bool {
        matches!(
            self,
            KvPool::Paged {
                evict: EvictPolicy::Swap,
                ..
            }
        )
    }

    /// Tokens of `session`'s KV parked for reuse (0 under whole-window).
    pub fn session_resident_tokens(&self, session: u64) -> usize {
        match self {
            KvPool::Whole(_) => 0,
            KvPool::Paged { mgr, .. } => mgr.session_resident_tokens(session),
        }
    }

    pub fn reuse_hits(&self) -> usize {
        match self {
            KvPool::Whole(_) => 0,
            KvPool::Paged { mgr, .. } => mgr.reuse_hits(),
        }
    }

    pub fn reuse_tokens(&self) -> usize {
        match self {
            KvPool::Whole(_) => 0,
            KvPool::Paged { mgr, .. } => mgr.reuse_tokens(),
        }
    }

    /// Admissions that reused a radix prefix chain (0 outside radix mode).
    pub fn prefix_hits(&self) -> usize {
        match self {
            KvPool::Whole(_) => 0,
            KvPool::Paged { mgr, .. } => mgr.prefix_hits(),
        }
    }

    /// Prompt tokens whose prefill the radix tree skipped.
    pub fn prefix_reused_tokens(&self) -> usize {
        match self {
            KvPool::Whole(_) => 0,
            KvPool::Paged { mgr, .. } => mgr.prefix_reused_tokens(),
        }
    }

    /// Prefix-tree nodes evicted under pressure.
    pub fn prefix_nodes_evicted(&self) -> usize {
        match self {
            KvPool::Whole(_) => 0,
            KvPool::Paged { mgr, .. } => mgr.prefix_nodes_evicted(),
        }
    }

    pub fn utilization(&self) -> f64 {
        match self {
            KvPool::Whole(m) => m.utilization(),
            KvPool::Paged { mgr, .. } => mgr.utilization(),
        }
    }

    pub fn peak_utilization(&self) -> f64 {
        match self {
            KvPool::Whole(m) => m.peak_utilization(),
            KvPool::Paged { mgr, .. } => mgr.peak_utilization(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::backend::{ExecutionBackend, SalPimBackend};

    fn paper_capacity() -> DeviceCapacity {
        SalPimBackend::new(&SimConfig::paper()).capacity()
    }

    #[test]
    fn paper_device_has_room_for_many_contexts() {
        // GPT-2 medium: ~96 KB of KV per token; after weights + LUT
        // subarrays an 8 GB stack still holds tens of thousands of
        // tokens of KV state.
        let kv = KvCacheManager::for_device(&SimConfig::paper());
        assert!(kv.total_subarrays() > 1000, "{}", kv.total_subarrays());
        assert!(kv.capacity_tokens() > 10_000, "{}", kv.capacity_tokens());
    }

    #[test]
    fn admit_and_release_round_trip() {
        let cfg = SimConfig::paper();
        let mut kv = KvCacheManager::with_kv_subarrays(&cfg, 8);
        let cap = kv.capacity_tokens();
        assert!(cap > 0);
        let lease = kv.try_admit(1, 10).expect("small request fits");
        assert!(kv.used_subarrays() >= 1);
        assert_eq!(kv.admitted(), 1);
        assert!(kv.utilization() > 0.0);
        kv.release(lease);
        assert_eq!(kv.used_subarrays(), 0);
        assert_eq!(kv.reserved_tokens(), 0);
        assert!(kv.peak_utilization() > 0.0);
    }

    #[test]
    fn admission_fails_when_exhausted() {
        let cfg = SimConfig::paper();
        let mut kv = KvCacheManager::with_kv_subarrays(&cfg, 2);
        let per_sub = cfg.hbm.subarray_bytes() / cfg.model.kv_bytes_per_token();
        let a = kv.try_admit(1, per_sub).expect("first subarray");
        let _b = kv.try_admit(2, per_sub).expect("second subarray");
        assert!(kv.try_admit(3, 1).is_none(), "over-admission");
        kv.release(a);
        assert!(kv.try_admit(3, 1).is_some(), "slot must free on release");
    }

    #[test]
    fn fits_ever_screens_impossible_requests() {
        let cfg = SimConfig::paper();
        let kv = KvCacheManager::with_kv_subarrays(&cfg, 1);
        assert!(kv.fits_ever(1));
        assert!(!kv.fits_ever(kv.capacity_tokens() + cfg.hbm.subarray_bytes()));
    }

    #[test]
    fn capacity_constructor_matches_for_device() {
        let cfg = SimConfig::paper();
        let cap = paper_capacity();
        let a = KvCacheManager::for_device(&cfg);
        let b = KvCacheManager::from_capacity(&cap);
        assert_eq!(a.total_subarrays(), b.total_subarrays());
        assert_eq!(a.capacity_tokens(), b.capacity_tokens());
        assert_eq!(a.subarrays_for(100), b.subarrays_for(100));
        let c = KvCacheManager::from_capacity_units(&cap, 3);
        assert_eq!(c.total_subarrays(), 3);
    }

    #[test]
    fn subarray_granularity_rounds_up() {
        let cfg = SimConfig::paper();
        let kv = KvCacheManager::with_kv_subarrays(&cfg, 100);
        // One token still burns a whole subarray.
        assert_eq!(kv.subarrays_for(1), 1);
        let per_sub = cfg.hbm.subarray_bytes() / cfg.model.kv_bytes_per_token();
        assert_eq!(kv.subarrays_for(per_sub + 1), 2);
    }

    #[test]
    fn policy_tokens_parse_and_name() {
        for p in [KvPolicy::Whole, KvPolicy::Paged] {
            assert_eq!(KvPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(KvPolicy::parse("vLLM"), None);
        for e in [EvictPolicy::None, EvictPolicy::Lru, EvictPolicy::Swap] {
            assert_eq!(EvictPolicy::parse(e.name()), Some(e));
        }
        // PR 4's recompute-on-readmit discipline, by its cost name.
        assert_eq!(EvictPolicy::parse("recompute"), Some(EvictPolicy::Lru));
        assert_eq!(EvictPolicy::parse("fifo"), None);
    }

    #[test]
    fn paged_region_matches_whole_region_bytes() {
        // Equal HBM capacity: the paged region over N units holds at
        // least as many tokens as the subarray-granular whole region
        // (block packing can only round *down* less).
        let cap = paper_capacity();
        let whole = KvCacheManager::from_capacity_units(&cap, 16);
        let paged = PagedKvManager::from_capacity_units(&cap, 16);
        assert!(paged.block_tokens() >= 1);
        assert!(paged.capacity_tokens() >= whole.capacity_tokens());
        // And the byte budgets agree to within one block.
        let whole_bytes = 16 * cap.kv_alloc_unit_bytes;
        let paged_bytes =
            paged.total_blocks() * paged.block_tokens() * cap.kv_bytes_per_token;
        assert!(paged_bytes <= whole_bytes);
        assert!(whole_bytes - paged_bytes < paged.block_tokens() * cap.kv_bytes_per_token);
    }

    #[test]
    fn paged_alloc_grow_free_ledger() {
        let cap = paper_capacity();
        let mut kv = PagedKvManager::from_capacity_units(&cap, 8);
        let bt = kv.block_tokens();
        let total = kv.total_blocks();
        let (mut lease, reused) = kv.try_admit(1, 7, bt, 0, &[]).expect("one block fits");
        assert_eq!(reused, 0);
        assert_eq!(lease.blocks, 1);
        assert_eq!(kv.used_blocks(), 1);
        // Growing within the block allocates nothing.
        assert!(kv.try_grow(&mut lease, bt));
        assert_eq!(lease.blocks, 1);
        // Crossing the block boundary allocates exactly one more.
        assert!(kv.try_grow(&mut lease, bt + 1));
        assert_eq!(lease.blocks, 2);
        assert_eq!(kv.used_blocks(), 2);
        // Growth past the region fails without corrupting the ledger.
        assert!(!kv.try_grow(&mut lease, (total + 1) * bt));
        assert_eq!(lease.blocks, 2);
        kv.free(lease);
        assert_eq!(kv.used_blocks(), 0);
        assert!(kv.peak_utilization() > 0.0);
    }

    #[test]
    fn session_residency_reuses_and_evicts_lru() {
        let cap = paper_capacity();
        let mut kv = PagedKvManager::from_capacity_units(&cap, 8);
        let bt = kv.block_tokens();
        let total = kv.total_blocks();

        // Session 1 finishes a 2-block request; its blocks park.
        let (lease, _) = kv.try_admit(1, 1, 2 * bt, 0, &[]).unwrap();
        kv.release_retain(lease);
        assert_eq!(kv.session_resident_tokens(1), 2 * bt);
        assert_eq!(kv.used_blocks(), 2, "residency still holds data");

        // A follow-up of session 1 reclaims the prefix.
        let (lease, reused) = kv.try_admit(2, 1, 2 * bt + 1, 2 * bt, &[]).unwrap();
        assert_eq!(reused, 2 * bt);
        assert_eq!(kv.reuse_hits(), 1);
        assert_eq!(kv.reuse_tokens(), 2 * bt);
        assert_eq!(kv.session_resident_tokens(1), 0, "residency reclaimed");
        kv.release_retain(lease);

        // Park a second session, then demand the whole region: both idle
        // residencies are evicted (LRU first) to satisfy the allocation.
        let (lease2, _) = kv.try_admit(3, 2, bt, 0, &[]).unwrap();
        kv.release_retain(lease2);
        assert!(kv.session_resident_tokens(1) > 0);
        assert!(kv.session_resident_tokens(2) > 0);
        let (big, reused) = kv
            .try_admit(4, 9, total * bt, 0, &[])
            .expect("evicts idle sessions");
        assert_eq!(reused, 0);
        assert_eq!(kv.session_resident_tokens(1), 0);
        assert_eq!(kv.session_resident_tokens(2), 0);
        assert!(kv.sessions_evicted() >= 2);
        kv.free(big);
    }

    #[test]
    fn paged_defers_when_active_leases_hold_the_region() {
        let cap = paper_capacity();
        let mut kv = PagedKvManager::from_capacity_units(&cap, 4);
        let bt = kv.block_tokens();
        let total = kv.total_blocks();
        let (lease, _) = kv.try_admit(1, 1, total * bt, 0, &[]).unwrap();
        // Active leases are not evictable: a second admission defers.
        assert!(kv.try_admit(2, 2, bt, 0, &[]).is_none());
        kv.free(lease);
        assert!(kv.try_admit(2, 2, bt, 0, &[]).is_some());
    }

    #[test]
    fn pool_dispatches_both_policies() {
        let cap = paper_capacity();
        let mut whole = KvPool::for_capacity(
            &cap,
            KvPolicy::Whole,
            EvictPolicy::Lru,
            PrefixCacheMode::Session,
            None,
            Some(16),
        );
        let mut paged = KvPool::for_capacity(
            &cap,
            KvPolicy::Paged,
            EvictPolicy::Lru,
            PrefixCacheMode::Session,
            None,
            Some(16),
        );
        assert_eq!(whole.policy(), KvPolicy::Whole);
        assert_eq!(paged.policy(), KvPolicy::Paged);
        assert!(!whole.preemption_allowed());
        assert!(paged.preemption_allowed());

        // Whole reserves the window up front; paged only the prompt + 1.
        let (mut wl, wr) = whole.try_admit(0, 0, 16, 48, &[]).unwrap();
        let (mut pl, pr) = paged.try_admit(0, 0, 16, 48, &[]).unwrap();
        assert_eq!((wr, pr), (0, 0));
        assert!(whole.utilization() > paged.utilization());
        assert!(whole.ensure(&mut wl, 48), "window pre-reserved");
        assert!(paged.ensure(&mut pl, 48));
        whole.release(wl);
        paged.release(pl);
        assert!(paged.session_resident_tokens(0) > 0, "paged parks the session");
        assert_eq!(whole.session_resident_tokens(0), 0);
    }

    #[test]
    fn pool_evict_none_preallocates_the_window() {
        let cap = paper_capacity();
        let mut pool = KvPool::for_capacity(
            &cap,
            KvPolicy::Paged,
            EvictPolicy::None,
            PrefixCacheMode::Session,
            None,
            Some(16),
        );
        assert!(!pool.preemption_allowed());
        let (mut lease, _) = pool.try_admit(0, 0, 16, 48, &[]).unwrap();
        // Growth within the window can never fail.
        for t in 17..=48 {
            assert!(pool.ensure(&mut lease, t));
        }
        pool.free(lease);
    }

    #[test]
    fn radix_shares_prefix_across_sessions() {
        let cap = paper_capacity();
        let mut kv = PagedKvManager::from_capacity_units(&cap, 8)
            .with_prefix_mode(PrefixCacheMode::Radix);
        let bt = kv.block_tokens();
        let root = [PrefixSeg { id: 1, tokens: bt }];
        // Session 1 populates the root node (nothing to reuse yet).
        let (l1, reused) = kv.try_admit(1, 1, 2 * bt, 2 * bt - 1, &root).unwrap();
        assert_eq!(reused, 0);
        assert_eq!(l1.prefix_tokens, bt);
        assert_eq!(l1.blocks, 1, "private suffix only; the tree owns the root");
        assert_eq!(kv.prefix_nodes_live(), 1);
        // A *different* session reuses the shared root: the prefix is
        // never prefilled twice.
        let (l2, reused) = kv.try_admit(2, 2, 2 * bt, 2 * bt - 1, &root).unwrap();
        assert_eq!(reused, bt);
        assert_eq!(kv.prefix_hits(), 1);
        assert_eq!(kv.prefix_reused_tokens(), bt);
        assert_eq!(kv.reuse_tokens(), 0, "cross-session reuse is radix, not residency");
        kv.release_retain(l1);
        kv.release_retain(l2);
        // Residency parks only the private suffix; the root stays with
        // the tree.
        assert_eq!(kv.session_resident_tokens(1), bt);
        assert_eq!(kv.prefix_nodes_live(), 1);
    }

    #[test]
    fn radix_eviction_is_leaf_first_and_never_takes_referenced_nodes() {
        let cap = paper_capacity();
        let mut kv = PagedKvManager::from_capacity_units(&cap, 8)
            .with_prefix_mode(PrefixCacheMode::Radix);
        let bt = kv.block_tokens();
        let total = kv.total_blocks();
        let path = [
            PrefixSeg { id: 1, tokens: bt },
            PrefixSeg { id: 2, tokens: bt },
        ];
        let (lease, _) = kv.try_admit(1, 1, 3 * bt, 0, &path).unwrap();
        assert_eq!(kv.prefix_nodes_live(), 2);
        // The live lease pins the path: a region-sized demand defers
        // rather than freeing referenced prefix blocks.
        assert!(kv.try_admit(2, 2, total * bt, 0, &[]).is_none());
        kv.free(lease);
        // Unreferenced now. A demand one block short of the region only
        // needs one eviction — the leaf goes, the root survives.
        let (mid, _) = kv.try_admit(2, 2, (total - 1) * bt, 0, &[]).unwrap();
        assert_eq!(kv.prefix_nodes_evicted(), 1, "leaf evicted before root");
        assert_eq!(kv.prefix_nodes_live(), 1);
        kv.free(mid);
        let (back, reused) = kv.try_admit(3, 3, 2 * bt, 2 * bt - 1, &[path[0]]).unwrap();
        assert_eq!(reused, bt, "root survived leaf-first eviction");
        kv.free(back);
    }

    #[test]
    fn radix_composes_prefix_and_session_suffix_reuse() {
        let cap = paper_capacity();
        let mut kv = PagedKvManager::from_capacity_units(&cap, 8)
            .with_prefix_mode(PrefixCacheMode::Radix);
        let bt = kv.block_tokens();
        let root = [PrefixSeg { id: 1, tokens: bt }];
        // Turn 1 populates the root and parks a 2-block private suffix.
        let (l1, _) = kv.try_admit(1, 1, 3 * bt, 3 * bt - 1, &root).unwrap();
        kv.release_retain(l1);
        assert_eq!(kv.session_resident_tokens(1), 2 * bt);
        // Turn 2 of the same session reuses the radix chain *plus* its
        // own parked suffix (contiguous: the whole path was populated).
        let (l2, reused) = kv.try_admit(2, 1, 4 * bt, 4 * bt - 1, &root).unwrap();
        assert_eq!(reused, 3 * bt);
        assert_eq!(kv.prefix_reused_tokens(), bt);
        assert_eq!(kv.reuse_tokens(), 2 * bt);
        kv.release_retain(l2);
    }

    #[test]
    fn session_mode_ignores_prefix_paths() {
        // Default mode: a prefix-carrying request admits exactly like a
        // plain one (bit-compat with pre-radix behavior).
        let cap = paper_capacity();
        let mut kv = PagedKvManager::from_capacity_units(&cap, 8);
        let bt = kv.block_tokens();
        let root = [PrefixSeg { id: 1, tokens: bt }];
        let (lease, reused) = kv.try_admit(1, 1, 2 * bt, 2 * bt - 1, &root).unwrap();
        assert_eq!(reused, 0);
        assert_eq!(lease.prefix_tokens, 0);
        assert!(lease.path.is_empty());
        assert_eq!(lease.blocks, 2);
        assert_eq!(kv.prefix_nodes_live(), 0);
        kv.release_retain(lease);
        assert_eq!(kv.session_resident_tokens(1), 2 * bt);
    }

    #[test]
    fn radix_alignment_overflow_falls_back_to_unshared() {
        // A path whose per-node block rounding exceeds the region must
        // not defer forever: the request is served unshared instead.
        let cap = paper_capacity();
        let mut kv = PagedKvManager::from_capacity_units(&cap, 8)
            .with_prefix_mode(PrefixCacheMode::Radix)
            .with_block_tokens(4);
        let bt = kv.block_tokens();
        let total = kv.total_blocks();
        // The node straddles a block boundary (bt + 1 tokens → 2 blocks),
        // so sharing a region-sized request needs total + 1 blocks even
        // though the unshared request needs exactly total.
        let path = [PrefixSeg { id: 1, tokens: bt + 1 }];
        let (lease, reused) = kv
            .try_admit(1, 1, total * bt, total * bt - 1, &path)
            .expect("unshared fallback");
        assert_eq!(reused, 0);
        assert!(lease.path.is_empty(), "served unshared");
        assert_eq!(kv.prefix_nodes_live(), 0);
        kv.free(lease);
    }

    #[test]
    fn block_size_override_rescales_the_region() {
        let cap = paper_capacity();
        let small = PagedKvManager::from_capacity_units(&cap, 8);
        let coarse = PagedKvManager::from_capacity_units(&cap, 8)
            .with_block_tokens(small.block_tokens() * 2);
        assert_eq!(coarse.block_tokens(), small.block_tokens() * 2);
        assert!(coarse.total_blocks() <= small.total_blocks() / 2 + 1);
        // Byte budget is conserved across block sizes (within a block).
        let b = |m: &PagedKvManager| m.total_blocks() * m.block_tokens();
        assert!(b(&coarse) <= b(&small));
    }
}
