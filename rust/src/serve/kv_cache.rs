//! Subarray-aware KV-cache capacity accounting for one SAL-PIM device.
//!
//! SAL-PIM keeps the KV cache resident in DRAM next to the weights
//! (§3.2's KV mapping streams K/V rows through the S-ALUs like weight
//! rows). A device therefore has a *hard* KV budget: whatever subarrays
//! are left after the model weights and the LUT-embedded subarrays are
//! placed. The manager allocates that budget to requests in whole
//! subarrays — a request's K/V rows must be contiguous within a subarray
//! group for the streaming schedule to hit them with open-row accesses,
//! so capacity is consumed at subarray granularity even when a request's
//! token window fills one only partially.
//!
//! [`KvCacheManager::try_admit`] reserves the full window (prompt +
//! output budget) up front — the paper's device has no KV eviction path,
//! so admission control is the only defence against mid-generation
//! overflow. Slots free on completion via [`KvCacheManager::release`].

use super::backend::DeviceCapacity;
use crate::config::SimConfig;

/// Subarrays left for KV on a SAL-PIM device: total subarrays minus the
/// LUT-embedded subarrays minus what the model weights occupy. Shared by
/// [`KvCacheManager::for_device`] and the SAL-PIM execution backend's
/// capacity hint so the two can never disagree.
pub fn device_kv_subarrays(cfg: &SimConfig) -> usize {
    let subarray_bytes = cfg.hbm.subarray_bytes();
    let total = cfg.hbm.total_subarrays();
    let lut = cfg.hbm.total_banks() * cfg.lut.num_lut_subarrays;
    let weight_bytes = cfg.model.total_params() * cfg.model.param_bytes;
    let weight_subarrays = weight_bytes.div_ceil(subarray_bytes);
    total.saturating_sub(lut + weight_subarrays)
}

/// A granted KV reservation (returned by [`KvCacheManager::try_admit`];
/// hand it back with [`KvCacheManager::release`]).
#[derive(Debug)]
pub struct KvLease {
    /// Request id the lease belongs to (for diagnostics).
    pub request_id: u64,
    /// Token window reserved.
    pub tokens: usize,
    /// Whole subarrays consumed by the reservation.
    pub subarrays: usize,
}

/// Tracks the KV subarray pool of one device.
#[derive(Debug)]
pub struct KvCacheManager {
    /// Bytes of K+V state per token (2 × layers × d_model × param bytes).
    kv_bytes_per_token: usize,
    /// Bytes per subarray (rows × row size).
    subarray_bytes: usize,
    /// Subarrays in the device's KV region.
    total_subarrays: usize,
    free_subarrays: usize,
    /// Live admissions (sum of leased tokens, for reporting).
    reserved_tokens: usize,
    admitted: usize,
    peak_used_subarrays: usize,
}

impl KvCacheManager {
    /// KV region derived from the device config: total subarrays minus
    /// the LUT-embedded subarrays minus what the model weights occupy
    /// (see [`device_kv_subarrays`]).
    pub fn for_device(cfg: &SimConfig) -> Self {
        Self::with_kv_subarrays(cfg, device_kv_subarrays(cfg))
    }

    /// Manager over a backend's capacity hints. "Subarray" generalizes
    /// to the backend's allocation unit (a DRAM subarray on PIM, a page
    /// on a GPU).
    pub fn from_capacity(cap: &DeviceCapacity) -> Self {
        Self::from_capacity_units(cap, cap.kv_total_units)
    }

    /// [`KvCacheManager::from_capacity`] with an overridden unit count
    /// (tests and what-if admission-pressure sweeps).
    pub fn from_capacity_units(cap: &DeviceCapacity, units: usize) -> Self {
        KvCacheManager {
            kv_bytes_per_token: cap.kv_bytes_per_token,
            subarray_bytes: cap.kv_alloc_unit_bytes,
            total_subarrays: units,
            free_subarrays: units,
            reserved_tokens: 0,
            admitted: 0,
            peak_used_subarrays: 0,
        }
    }

    /// Manager over an explicit KV-region size (tests and what-if sweeps).
    pub fn with_kv_subarrays(cfg: &SimConfig, kv_subarrays: usize) -> Self {
        KvCacheManager {
            kv_bytes_per_token: cfg.model.kv_bytes_per_token(),
            subarray_bytes: cfg.hbm.subarray_bytes(),
            total_subarrays: kv_subarrays,
            free_subarrays: kv_subarrays,
            reserved_tokens: 0,
            admitted: 0,
            peak_used_subarrays: 0,
        }
    }

    /// Whole subarrays a `tokens`-wide KV window occupies.
    pub fn subarrays_for(&self, tokens: usize) -> usize {
        (tokens * self.kv_bytes_per_token).div_ceil(self.subarray_bytes)
    }

    /// Token capacity if the region were filled by one giant request.
    pub fn capacity_tokens(&self) -> usize {
        self.total_subarrays * self.subarray_bytes / self.kv_bytes_per_token
    }

    /// Could the request ever be admitted (even on an idle device)?
    pub fn fits_ever(&self, tokens: usize) -> bool {
        self.subarrays_for(tokens) <= self.total_subarrays
    }

    /// Try to reserve a `tokens`-wide window; `None` when the region is
    /// exhausted (the caller should retry after a completion frees slots).
    pub fn try_admit(&mut self, request_id: u64, tokens: usize) -> Option<KvLease> {
        let need = self.subarrays_for(tokens);
        if need > self.free_subarrays {
            return None;
        }
        self.free_subarrays -= need;
        self.reserved_tokens += tokens;
        self.admitted += 1;
        self.peak_used_subarrays = self.peak_used_subarrays.max(self.used_subarrays());
        Some(KvLease {
            request_id,
            tokens,
            subarrays: need,
        })
    }

    /// Return a lease's subarrays to the pool.
    pub fn release(&mut self, lease: KvLease) {
        debug_assert!(self.used_subarrays() >= lease.subarrays, "double release");
        self.free_subarrays = (self.free_subarrays + lease.subarrays).min(self.total_subarrays);
        self.reserved_tokens = self.reserved_tokens.saturating_sub(lease.tokens);
        self.admitted = self.admitted.saturating_sub(1);
    }

    pub fn total_subarrays(&self) -> usize {
        self.total_subarrays
    }

    pub fn used_subarrays(&self) -> usize {
        self.total_subarrays - self.free_subarrays
    }

    /// Live admissions.
    pub fn admitted(&self) -> usize {
        self.admitted
    }

    /// Tokens currently reserved.
    pub fn reserved_tokens(&self) -> usize {
        self.reserved_tokens
    }

    /// Fraction of the KV region in use right now.
    pub fn utilization(&self) -> f64 {
        if self.total_subarrays == 0 {
            return 0.0;
        }
        self.used_subarrays() as f64 / self.total_subarrays as f64
    }

    /// High-water utilization over the manager's lifetime.
    pub fn peak_utilization(&self) -> f64 {
        if self.total_subarrays == 0 {
            return 0.0;
        }
        self.peak_used_subarrays as f64 / self.total_subarrays as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_device_has_room_for_many_contexts() {
        // GPT-2 medium: ~96 KB of KV per token; after weights + LUT
        // subarrays an 8 GB stack still holds tens of thousands of
        // tokens of KV state.
        let kv = KvCacheManager::for_device(&SimConfig::paper());
        assert!(kv.total_subarrays() > 1000, "{}", kv.total_subarrays());
        assert!(kv.capacity_tokens() > 10_000, "{}", kv.capacity_tokens());
    }

    #[test]
    fn admit_and_release_round_trip() {
        let cfg = SimConfig::paper();
        let mut kv = KvCacheManager::with_kv_subarrays(&cfg, 8);
        let cap = kv.capacity_tokens();
        assert!(cap > 0);
        let lease = kv.try_admit(1, 10).expect("small request fits");
        assert!(kv.used_subarrays() >= 1);
        assert_eq!(kv.admitted(), 1);
        assert!(kv.utilization() > 0.0);
        kv.release(lease);
        assert_eq!(kv.used_subarrays(), 0);
        assert_eq!(kv.reserved_tokens(), 0);
        assert!(kv.peak_utilization() > 0.0);
    }

    #[test]
    fn admission_fails_when_exhausted() {
        let cfg = SimConfig::paper();
        let mut kv = KvCacheManager::with_kv_subarrays(&cfg, 2);
        let per_sub = cfg.hbm.subarray_bytes() / cfg.model.kv_bytes_per_token();
        let a = kv.try_admit(1, per_sub).expect("first subarray");
        let _b = kv.try_admit(2, per_sub).expect("second subarray");
        assert!(kv.try_admit(3, 1).is_none(), "over-admission");
        kv.release(a);
        assert!(kv.try_admit(3, 1).is_some(), "slot must free on release");
    }

    #[test]
    fn fits_ever_screens_impossible_requests() {
        let cfg = SimConfig::paper();
        let kv = KvCacheManager::with_kv_subarrays(&cfg, 1);
        assert!(kv.fits_ever(1));
        assert!(!kv.fits_ever(kv.capacity_tokens() + cfg.hbm.subarray_bytes()));
    }

    #[test]
    fn capacity_constructor_matches_for_device() {
        let cfg = SimConfig::paper();
        let cap = DeviceCapacity {
            kv_bytes_per_token: cfg.model.kv_bytes_per_token(),
            kv_alloc_unit_bytes: cfg.hbm.subarray_bytes(),
            kv_total_units: device_kv_subarrays(&cfg),
            max_seq: cfg.model.max_seq,
        };
        let a = KvCacheManager::for_device(&cfg);
        let b = KvCacheManager::from_capacity(&cap);
        assert_eq!(a.total_subarrays(), b.total_subarrays());
        assert_eq!(a.capacity_tokens(), b.capacity_tokens());
        assert_eq!(a.subarrays_for(100), b.subarrays_for(100));
        let c = KvCacheManager::from_capacity_units(&cap, 3);
        assert_eq!(c.total_subarrays(), 3);
    }

    #[test]
    fn subarray_granularity_rounds_up() {
        let cfg = SimConfig::paper();
        let kv = KvCacheManager::with_kv_subarrays(&cfg, 100);
        // One token still burns a whole subarray.
        assert_eq!(kv.subarrays_for(1), 1);
        let per_sub = cfg.hbm.subarray_bytes() / cfg.model.kv_bytes_per_token();
        assert_eq!(kv.subarrays_for(per_sub + 1), 2);
    }
}
