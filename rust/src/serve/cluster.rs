//! A cluster of serving devices behind a router.
//!
//! Scaling past one 8 GB stack means sharding traffic across devices
//! (each holds a full weight replica, as in PIM-GPT-style multi-device
//! serving). The cluster owns N [`DeviceEngine`]s with per-device queues
//! and routes at submit time — routing is deterministic for a fixed
//! submission order, so whole-cluster runs replay exactly under a fixed
//! workload seed.
//!
//! Devices are [`super::backend::ExecutionBackend`]-generic: a cluster
//! can be homogeneous ([`Cluster::homogeneous`] — N SAL-PIM, N GPU, …)
//! or mixed ([`Cluster::from_engines`] — e.g. a GPU tier next to PIM
//! devices), and routing stays deterministic either way.

use super::backend::BackendKind;
use super::engine::{DeviceEngine, EngineCore, EngineReport};
use super::fabric::{Fabric, FabricParams, SharedFabric};
use super::kv_cache::{EvictPolicy, KvPolicy, PrefixCacheMode};
use super::metrics::ServeMetrics;
use super::policy::Policy;
use super::types::{Completion, Request};
use crate::config::SimConfig;
use crate::trace::{PhaseProfile, TraceEvent, TraceEventKind, TraceHandle};
use std::collections::HashMap;
use std::time::Instant;

/// How requests are assigned to devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Strict rotation over devices.
    RoundRobin,
    /// Device with the least estimated queued work (tokens) at submit.
    LeastLoaded,
    /// Session block residency informs routing: a session's first
    /// request is placed on the least-loaded device, and every follow-up
    /// goes to that *home* device — where the session's paged KV blocks
    /// (and so its reuse hits) live.
    SessionAffinity,
}

impl Routing {
    pub fn name(&self) -> &'static str {
        match self {
            Routing::RoundRobin => "round-robin",
            Routing::LeastLoaded => "least-loaded",
            Routing::SessionAffinity => "session-affinity",
        }
    }
}

/// N devices + router.
pub struct Cluster {
    devices: Vec<DeviceEngine>,
    pub routing: Routing,
    rr_next: usize,
    /// Session → home device (where the session's KV blocks reside).
    session_home: HashMap<u64, usize>,
    /// Submit-time assignment trace (request id → device), for tests and
    /// routing diagnostics.
    assignments: Vec<(u64, usize)>,
    /// Shared lifecycle-event sink; [`Cluster::run`] re-stamps the
    /// device index before each device drains (devices run
    /// sequentially, so one handle serves the whole cluster).
    trace: Option<TraceHandle>,
}

impl Cluster {
    /// N SAL-PIM devices (the historical constructor).
    pub fn new(cfg: &SimConfig, n_devices: usize, max_batch: usize, routing: Routing) -> Self {
        Self::homogeneous(cfg, BackendKind::SalPim, n_devices, max_batch, routing)
    }

    /// N identical devices of one backend family.
    pub fn homogeneous(
        cfg: &SimConfig,
        kind: BackendKind,
        n_devices: usize,
        max_batch: usize,
        routing: Routing,
    ) -> Self {
        assert!(n_devices >= 1);
        Self::from_engines(
            (0..n_devices)
                .map(|_| DeviceEngine::with_backend(kind.build(cfg), max_batch))
                .collect(),
            routing,
        )
    }

    /// A cluster over pre-built (possibly heterogeneous) devices.
    /// Device indices are reassigned to the vector order.
    pub fn from_engines(mut engines: Vec<DeviceEngine>, routing: Routing) -> Self {
        assert!(!engines.is_empty(), "a cluster needs at least one device");
        for (i, d) in engines.iter_mut().enumerate() {
            d.device_index = i;
        }
        Cluster {
            devices: engines,
            routing,
            rr_next: 0,
            session_home: HashMap::new(),
            assignments: Vec::new(),
            trace: None,
        }
    }

    /// Attach a lifecycle-event sink shared by every device (the device
    /// stamp is refreshed as [`Cluster::run`] walks the devices).
    pub fn set_trace(&mut self, trace: TraceHandle) {
        for d in &mut self.devices {
            d.set_trace(trace.clone());
        }
        self.trace = Some(trace);
    }

    /// Propagate a wall-clock deadline (scenario `budget_s`) to every
    /// device; devices past it stop cleanly and report truncation.
    pub fn set_deadline(&mut self, deadline: Instant) {
        for d in &mut self.devices {
            d.set_deadline(deadline);
        }
    }

    /// True when any device's run was stopped by its deadline.
    pub fn truncated(&self) -> bool {
        self.devices.iter().any(|d| d.truncated())
    }

    /// Self-profiles of every device's run loop, merged.
    pub fn profile(&self) -> PhaseProfile {
        let mut p = PhaseProfile::default();
        for d in &self.devices {
            p.merge(&d.profile());
        }
        p
    }

    pub fn with_policy(mut self, policy: Policy) -> Self {
        for d in &mut self.devices {
            d.policy = policy;
        }
        self
    }

    /// Apply one KV configuration to every device: allocation policy,
    /// eviction policy, prefix-cache mode, paged block-size override and
    /// a KV-region size override in allocation units (see the
    /// [`DeviceEngine`] builders).
    pub fn with_kv(
        mut self,
        policy: KvPolicy,
        evict: EvictPolicy,
        prefix: PrefixCacheMode,
        block: Option<usize>,
        units: Option<usize>,
    ) -> Self {
        for d in &mut self.devices {
            d.apply_kv(policy, evict, prefix, block, units);
        }
        self
    }

    /// Pick the run-loop core for every device (see
    /// [`DeviceEngine::with_core`]); `Legacy` is the `--engine-core`
    /// escape hatch, bit-identical by construction.
    pub fn with_core(mut self, core: EngineCore) -> Self {
        for d in &mut self.devices {
            d.core = core;
        }
        self
    }

    /// Apply one prefill-chunk setting to every device (see
    /// [`DeviceEngine::with_prefill_chunk`]).
    pub fn with_prefill_chunk(mut self, chunk: Option<usize>) -> Self {
        if let Some(c) = chunk {
            assert!(c >= 1, "prefill chunk must be at least one token");
        }
        for d in &mut self.devices {
            d.prefill_chunk = chunk;
        }
        self
    }

    /// Per-device backend labels (device index order).
    pub fn backend_names(&self) -> Vec<String> {
        self.devices.iter().map(|d| d.backend_name()).collect()
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Route one request to a device queue; returns the device index.
    pub fn submit(&mut self, req: Request) -> usize {
        let n = self.devices.len();
        let dev = match self.routing {
            Routing::RoundRobin => {
                let d = self.rr_next % n;
                self.rr_next = (self.rr_next + 1) % n;
                d
            }
            Routing::LeastLoaded => {
                // Ties break toward the lowest index — deterministic.
                (0..n)
                    .min_by_key(|&i| (self.devices[i].queued_tokens(), i))
                    .unwrap()
            }
            Routing::SessionAffinity => match self.session_home.get(&req.session) {
                // Follow-ups stick to the home device, where the
                // session's resident KV blocks (paged policy) make the
                // prefix reusable without a re-prefill.
                Some(&d) => d,
                // First contact: place the session on the least-loaded
                // device (ties break toward the lowest index).
                None => {
                    let d = (0..n)
                        .min_by_key(|&i| (self.devices[i].queued_tokens(), i))
                        .unwrap();
                    self.session_home.insert(req.session, d);
                    d
                }
            },
        };
        self.assignments.push((req.id, dev));
        self.devices[dev].submit(req);
        dev
    }

    /// Run every device queue to completion; completions merged in finish
    /// order across the cluster.
    pub fn run(&mut self) -> Vec<Completion> {
        let mut all: Vec<Completion> = Vec::new();
        for d in &mut self.devices {
            if let Some(t) = &self.trace {
                t.set_device(d.device_index);
            }
            all.extend(d.run());
        }
        all.sort_by(|a, b| a.finish_s.total_cmp(&b.finish_s));
        all
    }

    /// Per-device serving metrics for the last run.
    pub fn per_device_metrics(&self, done: &[Completion]) -> Vec<ServeMetrics> {
        (0..self.devices.len())
            .map(|i| {
                let mine: Vec<Completion> =
                    done.iter().filter(|c| c.device == i).cloned().collect();
                ServeMetrics::from_completions(&mine)
            })
            .collect()
    }

    pub fn per_device_reports(&self) -> Vec<EngineReport> {
        self.devices.iter().map(|d| d.report()).collect()
    }

    /// Submit-time (request id, device) assignment trace.
    pub fn assignments(&self) -> &[(u64, usize)] {
        &self.assignments
    }

    /// Total requests rejected across devices (KV windows that can never
    /// fit).
    pub fn rejected(&self) -> usize {
        self.devices.iter().map(|d| d.rejected().len()).sum()
    }

    /// Attach one shared host link to every device, so swap-to-host
    /// traffic (`--evict swap`) from all devices contends on it.
    pub fn set_fabric(&mut self, fabric: SharedFabric) {
        for d in &mut self.devices {
            d.set_fabric(fabric.clone());
        }
    }
}

/// Disaggregated prefill/decode serving: a prefill pool, a decode pool,
/// and a modeled host fabric in between.
///
/// Each request runs its **summarization stage** on a prefill-pool
/// device (least-loaded placement, output clamped to the first token),
/// then its paged KV — prompt plus that first token — **migrates** over
/// the fabric to a decode-pool device (least-loaded at migration time,
/// the second stage of the two-stage placement), which finishes the
/// generation without re-prefilling ([`DeviceEngine::submit_prefilled`]).
/// Concurrent migrations on the link share bandwidth
/// ([`Fabric::transfer`]), so a migration burst stretches every
/// in-flight transfer.
///
/// **Accounting.** A merged [`Completion`]'s `queue_s`/`prefill_s` come
/// from the prefill stage (the first token is produced there, so TTFT is
/// unchanged by disaggregation); the migration delay, any decode-pool
/// wait, and the decode stage all land in `decode_s`. With an ideal
/// fabric (zero latency, infinite bandwidth) every added term is exactly
/// `0.0`, so completions are bit-identical to the equivalent single-pool
/// run — pinned by the `serve_disagg` suite.
///
/// **Tokens.** `tokens_simulated` is taken from the decode stage, whose
/// `produced` count includes the prefill-pool token — each token is
/// counted exactly once, so conservation versus a single-pool run holds
/// bit-for-bit.
pub struct DisaggregatedCluster {
    prefill: Vec<DeviceEngine>,
    decode: Vec<DeviceEngine>,
    fabric: SharedFabric,
    /// KV bytes per token on the decode pool (what a migration moves).
    kv_bytes_per_token: usize,
    /// Original requests by id (stage 1 runs a clamped copy).
    originals: HashMap<u64, Request>,
    /// Submit-time (request id, prefill device) assignments.
    assignments: Vec<(u64, usize)>,
    trace: Option<TraceHandle>,
}

impl DisaggregatedCluster {
    /// The canonical composition: `prefill_n` GPU devices feeding
    /// `decode_n` SAL-PIM devices over `fabric` — prefill where compute
    /// is dense, decode where memory is close.
    pub fn new(
        cfg: &SimConfig,
        prefill_n: usize,
        decode_n: usize,
        max_batch: usize,
        fabric: FabricParams,
    ) -> Self {
        Self::from_pools(
            (0..prefill_n)
                .map(|_| DeviceEngine::with_backend(BackendKind::Gpu.build(cfg), max_batch))
                .collect(),
            (0..decode_n)
                .map(|_| DeviceEngine::with_backend(BackendKind::SalPim.build(cfg), max_batch))
                .collect(),
            fabric,
        )
    }

    /// A disaggregated cluster over pre-built pools. Global device
    /// indices are assigned prefill-first (`0..P`), then decode
    /// (`P..P+D`); merged completions report decode-pool indices.
    pub fn from_pools(
        mut prefill: Vec<DeviceEngine>,
        mut decode: Vec<DeviceEngine>,
        fabric: FabricParams,
    ) -> Self {
        assert!(!prefill.is_empty(), "the prefill pool needs a device");
        assert!(!decode.is_empty(), "the decode pool needs a device");
        for (i, d) in prefill.iter_mut().enumerate() {
            d.device_index = i;
        }
        let base = prefill.len();
        let shared = Fabric::shared(fabric);
        for (i, d) in decode.iter_mut().enumerate() {
            d.device_index = base + i;
            // Swap-to-host traffic rides the same link as migrations.
            d.set_fabric(shared.clone());
        }
        let kv_bytes_per_token = decode[0].capacity().kv_bytes_per_token;
        DisaggregatedCluster {
            prefill,
            decode,
            fabric: shared,
            kv_bytes_per_token,
            originals: HashMap::new(),
            assignments: Vec::new(),
            trace: None,
        }
    }

    /// Apply a scheduling policy to every device in both pools.
    pub fn with_policy(mut self, policy: Policy) -> Self {
        for d in self.prefill.iter_mut().chain(&mut self.decode) {
            d.policy = policy;
        }
        self
    }

    /// Pick the run-loop core for every device in both pools.
    pub fn with_core(mut self, core: EngineCore) -> Self {
        for d in self.prefill.iter_mut().chain(&mut self.decode) {
            d.core = core;
        }
        self
    }

    /// Apply one KV configuration to the **decode** pool (where KV
    /// lives for the life of a generation). The prefill pool keeps the
    /// default whole-window policy: its requests hold KV only for the
    /// prompt's lifetime, so paging buys nothing there.
    pub fn with_kv(
        mut self,
        policy: KvPolicy,
        evict: EvictPolicy,
        prefix: PrefixCacheMode,
        block: Option<usize>,
        units: Option<usize>,
    ) -> Self {
        for d in &mut self.decode {
            d.apply_kv(policy, evict, prefix, block, units);
        }
        self
    }

    /// Apply one prefill-chunk setting to the prefill pool (the decode
    /// pool never prefills).
    pub fn with_prefill_chunk(mut self, chunk: Option<usize>) -> Self {
        for d in &mut self.prefill {
            d.prefill_chunk = chunk;
        }
        self
    }

    /// Attach a lifecycle-event sink. Stage streams are recorded
    /// privately and merged after the run: stage-1 `Complete` events and
    /// stage-2 `Arrival`/`Admit` events are dropped (the request arrives
    /// once and completes once), `KvMigrate` events are injected at the
    /// migration end, and the merged stream is replayed in time order —
    /// so derived span timelines still tile `[arrival, finish]` exactly.
    pub fn set_trace(&mut self, trace: TraceHandle) {
        self.trace = Some(trace);
    }

    /// Propagate a wall-clock deadline (scenario `budget_s`) to every
    /// device in both pools.
    pub fn set_deadline(&mut self, deadline: Instant) {
        for d in self.prefill.iter_mut().chain(&mut self.decode) {
            d.set_deadline(deadline);
        }
    }

    /// True when any device's run was stopped by its deadline.
    pub fn truncated(&self) -> bool {
        self.prefill
            .iter()
            .chain(&self.decode)
            .any(|d| d.truncated())
    }

    /// Self-profiles of every device's run loop, merged.
    pub fn profile(&self) -> PhaseProfile {
        let mut p = PhaseProfile::default();
        for d in self.prefill.iter().chain(&self.decode) {
            p.merge(&d.profile());
        }
        p
    }

    /// Per-device backend labels, prefill pool first.
    pub fn backend_names(&self) -> Vec<String> {
        self.prefill
            .iter()
            .chain(&self.decode)
            .map(|d| d.backend_name())
            .collect()
    }

    pub fn n_devices(&self) -> usize {
        self.prefill.len() + self.decode.len()
    }

    /// Total bytes moved by KV migrations (and swap traffic sharing the
    /// link) plus the transfer count.
    pub fn fabric_stats(&self) -> (u64, u64) {
        let f = self.fabric.borrow();
        (f.migrated_bytes(), f.transfers())
    }

    /// Route one request to a prefill-pool device (stage one of the
    /// two-stage placement: least-loaded, ties to the lowest index);
    /// returns the device index.
    pub fn submit(&mut self, req: Request) -> usize {
        let dev = (0..self.prefill.len())
            .min_by_key(|&i| (self.prefill[i].queued_tokens(), i))
            .unwrap();
        self.assignments.push((req.id, dev));
        self.originals.insert(req.id, req.clone());
        // The prefill stage produces exactly the first token; the rest
        // of the generation budget runs on the decode pool.
        let mut stage1 = req;
        stage1.max_new_tokens = 1;
        self.prefill[dev].submit(stage1);
        dev
    }

    /// Run both stages: drain the prefill pool, migrate each finished
    /// request's KV over the fabric in finish order (stage two of the
    /// placement: least-loaded decode device at migration time), drain
    /// the decode pool, and merge per-request completions. Returns
    /// completions in finish order.
    pub fn run(&mut self) -> Vec<Completion> {
        let tracing = self.trace.is_some();
        let h1 = TraceHandle::new();
        let h2 = TraceHandle::new();
        if tracing {
            for d in &mut self.prefill {
                d.set_trace(h1.clone());
            }
            for d in &mut self.decode {
                d.set_trace(h2.clone());
            }
        }

        // Stage 1: summarization on the prefill pool.
        let mut stage1: Vec<Completion> = Vec::new();
        for d in &mut self.prefill {
            if tracing {
                h1.set_device(d.device_index);
            }
            stage1.extend(d.run());
        }
        // Migrations are charged in stage-1 finish order (ties broken
        // by id), the order the KV actually becomes movable.
        stage1.sort_by(|a, b| {
            a.finish_s
                .total_cmp(&b.finish_s)
                .then(a.id.cmp(&b.id))
        });

        // Stage 2: migrate, place, decode. Each request's migration
        // delay rides along so the merge can charge it to decode_s.
        let mut migrations: Vec<TraceEvent> = Vec::new();
        let mut first: HashMap<u64, (Completion, f64)> = HashMap::new();
        for c in stage1 {
            let Some(orig) = self.originals.remove(&c.id) else {
                continue;
            };
            // Prompt KV plus the first token's entry moves.
            let tokens = c.prompt_len + 1;
            let bytes = tokens * self.kv_bytes_per_token;
            let dt = self.fabric.borrow_mut().transfer(c.finish_s, bytes);
            let arrival2 = c.finish_s + dt;
            let dev = (0..self.decode.len())
                .min_by_key(|&i| (self.decode[i].queued_tokens(), i))
                .unwrap();
            if tracing {
                migrations.push(TraceEvent {
                    t_s: arrival2,
                    device: self.decode[dev].device_index,
                    kind: TraceEventKind::KvMigrate {
                        id: c.id,
                        tokens,
                        dt_s: dt,
                    },
                });
            }
            self.decode[dev].submit_prefilled(Request {
                id: orig.id,
                prompt_len: orig.prompt_len,
                max_new_tokens: orig.max_new_tokens,
                arrival_s: arrival2,
                session: orig.session,
                slo: orig.slo,
                prefix: orig.prefix,
            });
            first.insert(c.id, (c, dt));
        }
        let mut stage2: Vec<Completion> = Vec::new();
        for d in &mut self.decode {
            if tracing {
                h2.set_device(d.device_index);
            }
            stage2.extend(d.run());
        }

        // Merge the two stages per request. With an ideal fabric every
        // term added to the stage-2 decode span is exactly 0.0, keeping
        // completions bit-identical to a single-pool run.
        let mut all: Vec<Completion> = Vec::new();
        for s2 in stage2 {
            let Some((s1, mig_dt)) = first.remove(&s2.id) else {
                continue;
            };
            all.push(Completion {
                id: s2.id,
                prompt_len: s2.prompt_len,
                tokens_out: s2.tokens_out,
                tokens_simulated: s2.tokens_simulated,
                queue_s: s1.queue_s,
                // TTFT is the prefill pool's: the first token is
                // produced there, before the migration.
                prefill_s: s1.prefill_s,
                // Stage-1 drain + migration + decode-pool wait + decode.
                decode_s: (s1.decode_s + mig_dt)
                    + (s2.queue_s + s2.prefill_s + s2.decode_s),
                finish_s: s2.finish_s,
                device: s2.device,
                slo: s2.slo,
            });
        }
        all.sort_by(|a, b| a.finish_s.total_cmp(&b.finish_s));

        if let Some(outer) = &self.trace {
            let mut merged: Vec<TraceEvent> = h1
                .take_events()
                .into_iter()
                .filter(|e| !matches!(e.kind, TraceEventKind::Complete { .. }))
                .collect();
            merged.extend(migrations);
            merged.extend(h2.take_events().into_iter().filter(|e| {
                !matches!(
                    e.kind,
                    TraceEventKind::Arrival { .. } | TraceEventKind::Admit { .. }
                )
            }));
            // Stable by time: per-device chronology survives, ties keep
            // stage order (prefill events precede their migration,
            // which precedes the decode stage).
            merged.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
            for e in merged {
                outer.set_device(e.device);
                outer.emit_at(e.t_s, e.kind);
            }
        }
        all
    }

    /// Per-device engine reports, prefill pool first.
    pub fn per_device_reports(&self) -> Vec<EngineReport> {
        self.prefill
            .iter()
            .chain(&self.decode)
            .map(|d| d.report())
            .collect()
    }

    /// Submit-time (request id, prefill device) assignment trace.
    pub fn assignments(&self) -> &[(u64, usize)] {
        &self.assignments
    }

    /// Total requests rejected across both pools.
    pub fn rejected(&self) -> usize {
        self.prefill
            .iter()
            .chain(&self.decode)
            .map(|d| d.rejected().len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, session: u64, at: f64) -> Request {
        Request {
            id,
            prompt_len: 16,
            max_new_tokens: 8,
            arrival_s: at,
            session,
            slo: crate::serve::types::SloClass::Batch,
            prefix: Vec::new(),
        }
    }

    #[test]
    fn round_robin_rotates() {
        let mut c = Cluster::new(&SimConfig::paper(), 3, 4, Routing::RoundRobin);
        let devs: Vec<usize> = (0..6).map(|i| c.submit(req(i, i, 0.0))).collect();
        assert_eq!(devs, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn session_affinity_is_sticky() {
        let mut c = Cluster::new(&SimConfig::paper(), 4, 4, Routing::SessionAffinity);
        let a = c.submit(req(0, 7, 0.0));
        let b = c.submit(req(1, 7, 0.1));
        let other = c.submit(req(2, 8, 0.2));
        assert_eq!(a, b, "same session, same device");
        assert_ne!(a, other, "a fresh session lands on a lighter device");
    }

    #[test]
    fn session_affinity_spreads_first_contacts_by_load() {
        // Four fresh sessions over two devices: first contacts alternate
        // (least-loaded placement), follow-ups stay home.
        let mut c = Cluster::new(&SimConfig::paper(), 2, 4, Routing::SessionAffinity);
        let d0 = c.submit(req(0, 100, 0.0));
        let d1 = c.submit(req(1, 101, 0.0));
        assert_ne!(d0, d1, "second session avoids the loaded device");
        let d0_again = c.submit(req(2, 100, 0.1));
        assert_eq!(d0, d0_again, "follow-up sticks to the home device");
    }

    #[test]
    fn kv_knobs_apply_to_every_device() {
        use crate::serve::kv_cache::{EvictPolicy, KvPolicy};
        let cfg = SimConfig::paper();
        let mut c = Cluster::new(&cfg, 2, 4, Routing::RoundRobin).with_kv(
            KvPolicy::Paged,
            EvictPolicy::Lru,
            PrefixCacheMode::Session,
            None,
            Some(64),
        );
        for i in 0..6 {
            c.submit(req(i, i, 0.0));
        }
        let done = c.run();
        assert_eq!(done.len(), 6);
        for rep in c.per_device_reports() {
            assert_eq!(rep.preemptions, 0, "ample region: no preemption");
        }
    }

    #[test]
    fn least_loaded_spreads_uneven_work() {
        let mut c = Cluster::new(&SimConfig::paper(), 2, 4, Routing::LeastLoaded);
        let mut big = req(0, 0, 0.0);
        big.max_new_tokens = 128;
        let d0 = c.submit(big);
        // The next two small requests should both avoid the loaded device.
        let d1 = c.submit(req(1, 1, 0.0));
        let d2 = c.submit(req(2, 2, 0.0));
        assert_ne!(d0, d1);
        assert_eq!(d1, d2, "second device stays lighter than the big job");
    }

    #[test]
    fn mixed_backend_cluster_serves_and_labels_devices() {
        let cfg = SimConfig::paper();
        let engines = vec![
            DeviceEngine::with_backend(BackendKind::SalPim.build(&cfg), 4),
            DeviceEngine::with_backend(BackendKind::Gpu.build(&cfg), 4),
        ];
        let mut c = Cluster::from_engines(engines, Routing::RoundRobin);
        assert_eq!(c.backend_names(), vec!["salpim".to_string(), "gpu".to_string()]);
        for i in 0..4 {
            c.submit(req(i, i, 0.0));
        }
        let done = c.run();
        assert_eq!(done.len(), 4);
        // Both devices took traffic.
        assert!(done.iter().any(|c| c.device == 0));
        assert!(done.iter().any(|c| c.device == 1));
    }

    #[test]
    fn cluster_cores_agree_bit_for_bit() {
        use crate::serve::kv_cache::{EvictPolicy, KvPolicy};
        let cfg = SimConfig::paper();
        let run = |core: EngineCore| {
            let mut c = Cluster::new(&cfg, 2, 4, Routing::SessionAffinity)
                .with_kv(
                    KvPolicy::Paged,
                    EvictPolicy::Lru,
                    PrefixCacheMode::Session,
                    None,
                    Some(64),
                )
                .with_core(core);
            for i in 0..8 {
                c.submit(req(i, i % 3, 0.01 * i as f64));
            }
            (c.run(), c.per_device_reports())
        };
        let (ev, ev_rep) = run(EngineCore::Event);
        let (lg, lg_rep) = run(EngineCore::Legacy);
        assert_eq!(ev.len(), lg.len());
        for (a, b) in ev.iter().zip(&lg) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.device, b.device);
            assert_eq!(a.tokens_simulated, b.tokens_simulated);
            assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
        }
        for (a, b) in ev_rep.iter().zip(&lg_rep) {
            assert_eq!(a.decode_steps, b.decode_steps);
            assert_eq!(a.preemptions, b.preemptions);
        }
    }

    #[test]
    fn disagg_serves_everything_once_and_counts_migrated_bytes() {
        let cfg = SimConfig::paper();
        let mut c = DisaggregatedCluster::new(&cfg, 2, 2, 4, FabricParams::pcie());
        for i in 0..6 {
            c.submit(req(i, i, 0.001 * i as f64));
        }
        let done = c.run();
        assert_eq!(done.len(), 6);
        let mut ids: Vec<u64> = done.iter().map(|c| c.id).collect();
        ids.sort();
        assert_eq!(ids, (0..6).collect::<Vec<u64>>());
        // Every completion reports a decode-pool device (global indices
        // 2..4) and a full token budget.
        for c in &done {
            assert!(c.device >= 2 && c.device < 4, "device {}", c.device);
            assert_eq!(c.tokens_simulated, 8);
            assert!(c.decode_s > 0.0);
        }
        let (bytes, transfers) = c.fabric_stats();
        assert_eq!(transfers, 6);
        let per_req = (16 + 1) * cfg.model.kv_bytes_per_token() as u64;
        assert_eq!(bytes, 6 * per_req);
        // Finish order is globally sorted.
        for w in done.windows(2) {
            assert!(w[0].finish_s <= w[1].finish_s);
        }
    }

    #[test]
    fn disagg_latency_partition_tiles_total_latency() {
        let cfg = SimConfig::paper();
        let mut c = DisaggregatedCluster::new(&cfg, 1, 1, 4, FabricParams::pcie());
        for i in 0..4 {
            c.submit(req(i, i, 0.002 * i as f64));
        }
        for d in c.run() {
            // queue + prefill + decode must recover [arrival, finish]:
            // the migration and decode-pool wait are inside decode_s,
            // not dropped on the floor.
            let total = d.queue_s + d.prefill_s + d.decode_s;
            let arrival = 0.002 * d.id as f64;
            assert!(
                (d.finish_s - total - arrival).abs() < 1e-9,
                "request {}: partition {total} does not span [{arrival}, {}]",
                d.id,
                d.finish_s
            );
            assert!(d.queue_s >= 0.0 && d.prefill_s > 0.0 && d.decode_s > 0.0);
        }
    }

    #[test]
    fn cluster_serves_everything_once() {
        let cfg = SimConfig::paper();
        let mut c = Cluster::new(&cfg, 2, 4, Routing::RoundRobin);
        for i in 0..6 {
            c.submit(req(i, i, 0.0));
        }
        let done = c.run();
        assert_eq!(done.len(), 6);
        let mut ids: Vec<u64> = done.iter().map(|c| c.id).collect();
        ids.sort();
        assert_eq!(ids, (0..6).collect::<Vec<u64>>());
        // Finish order is globally sorted.
        for w in done.windows(2) {
            assert!(w[0].finish_s <= w[1].finish_s);
        }
        let per = c.per_device_metrics(&done);
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].requests + per[1].requests, 6);
    }
}
