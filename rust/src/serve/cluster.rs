//! A cluster of serving devices behind a router.
//!
//! Scaling past one 8 GB stack means sharding traffic across devices
//! (each holds a full weight replica, as in PIM-GPT-style multi-device
//! serving). The cluster owns N [`DeviceEngine`]s with per-device queues
//! and routes at submit time — routing is deterministic for a fixed
//! submission order, so whole-cluster runs replay exactly under a fixed
//! workload seed.
//!
//! Devices are [`super::backend::ExecutionBackend`]-generic: a cluster
//! can be homogeneous ([`Cluster::homogeneous`] — N SAL-PIM, N GPU, …)
//! or mixed ([`Cluster::from_engines`] — e.g. a GPU tier next to PIM
//! devices), and routing stays deterministic either way.

use super::backend::BackendKind;
use super::engine::{DeviceEngine, EngineCore, EngineReport};
use super::kv_cache::{EvictPolicy, KvPolicy};
use super::metrics::ServeMetrics;
use super::policy::Policy;
use super::types::{Completion, Request};
use crate::config::SimConfig;
use crate::trace::{PhaseProfile, TraceHandle};
use std::collections::HashMap;
use std::time::Instant;

/// How requests are assigned to devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Strict rotation over devices.
    RoundRobin,
    /// Device with the least estimated queued work (tokens) at submit.
    LeastLoaded,
    /// Session block residency informs routing: a session's first
    /// request is placed on the least-loaded device, and every follow-up
    /// goes to that *home* device — where the session's paged KV blocks
    /// (and so its reuse hits) live.
    SessionAffinity,
}

impl Routing {
    pub fn name(&self) -> &'static str {
        match self {
            Routing::RoundRobin => "round-robin",
            Routing::LeastLoaded => "least-loaded",
            Routing::SessionAffinity => "session-affinity",
        }
    }
}

/// N devices + router.
pub struct Cluster {
    devices: Vec<DeviceEngine>,
    pub routing: Routing,
    rr_next: usize,
    /// Session → home device (where the session's KV blocks reside).
    session_home: HashMap<u64, usize>,
    /// Submit-time assignment trace (request id → device), for tests and
    /// routing diagnostics.
    assignments: Vec<(u64, usize)>,
    /// Shared lifecycle-event sink; [`Cluster::run`] re-stamps the
    /// device index before each device drains (devices run
    /// sequentially, so one handle serves the whole cluster).
    trace: Option<TraceHandle>,
}

impl Cluster {
    /// N SAL-PIM devices (the historical constructor).
    pub fn new(cfg: &SimConfig, n_devices: usize, max_batch: usize, routing: Routing) -> Self {
        Self::homogeneous(cfg, BackendKind::SalPim, n_devices, max_batch, routing)
    }

    /// N identical devices of one backend family.
    pub fn homogeneous(
        cfg: &SimConfig,
        kind: BackendKind,
        n_devices: usize,
        max_batch: usize,
        routing: Routing,
    ) -> Self {
        assert!(n_devices >= 1);
        Self::from_engines(
            (0..n_devices)
                .map(|_| DeviceEngine::with_backend(kind.build(cfg), max_batch))
                .collect(),
            routing,
        )
    }

    /// A cluster over pre-built (possibly heterogeneous) devices.
    /// Device indices are reassigned to the vector order.
    pub fn from_engines(mut engines: Vec<DeviceEngine>, routing: Routing) -> Self {
        assert!(!engines.is_empty(), "a cluster needs at least one device");
        for (i, d) in engines.iter_mut().enumerate() {
            d.device_index = i;
        }
        Cluster {
            devices: engines,
            routing,
            rr_next: 0,
            session_home: HashMap::new(),
            assignments: Vec::new(),
            trace: None,
        }
    }

    /// Attach a lifecycle-event sink shared by every device (the device
    /// stamp is refreshed as [`Cluster::run`] walks the devices).
    pub fn set_trace(&mut self, trace: TraceHandle) {
        for d in &mut self.devices {
            d.set_trace(trace.clone());
        }
        self.trace = Some(trace);
    }

    /// Propagate a wall-clock deadline (scenario `budget_s`) to every
    /// device; devices past it stop cleanly and report truncation.
    pub fn set_deadline(&mut self, deadline: Instant) {
        for d in &mut self.devices {
            d.set_deadline(deadline);
        }
    }

    /// True when any device's run was stopped by its deadline.
    pub fn truncated(&self) -> bool {
        self.devices.iter().any(|d| d.truncated())
    }

    /// Self-profiles of every device's run loop, merged.
    pub fn profile(&self) -> PhaseProfile {
        let mut p = PhaseProfile::default();
        for d in &self.devices {
            p.merge(&d.profile());
        }
        p
    }

    pub fn with_policy(mut self, policy: Policy) -> Self {
        for d in &mut self.devices {
            d.policy = policy;
        }
        self
    }

    /// Apply one KV configuration to every device: allocation policy,
    /// eviction policy, paged block-size override and a KV-region size
    /// override in allocation units (see the [`DeviceEngine`] builders).
    pub fn with_kv(
        mut self,
        policy: KvPolicy,
        evict: EvictPolicy,
        block: Option<usize>,
        units: Option<usize>,
    ) -> Self {
        for d in &mut self.devices {
            d.apply_kv(policy, evict, block, units);
        }
        self
    }

    /// Pick the run-loop core for every device (see
    /// [`DeviceEngine::with_core`]); `Legacy` is the `--engine-core`
    /// escape hatch, bit-identical by construction.
    pub fn with_core(mut self, core: EngineCore) -> Self {
        for d in &mut self.devices {
            d.core = core;
        }
        self
    }

    /// Apply one prefill-chunk setting to every device (see
    /// [`DeviceEngine::with_prefill_chunk`]).
    pub fn with_prefill_chunk(mut self, chunk: Option<usize>) -> Self {
        if let Some(c) = chunk {
            assert!(c >= 1, "prefill chunk must be at least one token");
        }
        for d in &mut self.devices {
            d.prefill_chunk = chunk;
        }
        self
    }

    /// Per-device backend labels (device index order).
    pub fn backend_names(&self) -> Vec<String> {
        self.devices.iter().map(|d| d.backend_name()).collect()
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Route one request to a device queue; returns the device index.
    pub fn submit(&mut self, req: Request) -> usize {
        let n = self.devices.len();
        let dev = match self.routing {
            Routing::RoundRobin => {
                let d = self.rr_next % n;
                self.rr_next = (self.rr_next + 1) % n;
                d
            }
            Routing::LeastLoaded => {
                // Ties break toward the lowest index — deterministic.
                (0..n)
                    .min_by_key(|&i| (self.devices[i].queued_tokens(), i))
                    .unwrap()
            }
            Routing::SessionAffinity => match self.session_home.get(&req.session) {
                // Follow-ups stick to the home device, where the
                // session's resident KV blocks (paged policy) make the
                // prefix reusable without a re-prefill.
                Some(&d) => d,
                // First contact: place the session on the least-loaded
                // device (ties break toward the lowest index).
                None => {
                    let d = (0..n)
                        .min_by_key(|&i| (self.devices[i].queued_tokens(), i))
                        .unwrap();
                    self.session_home.insert(req.session, d);
                    d
                }
            },
        };
        self.assignments.push((req.id, dev));
        self.devices[dev].submit(req);
        dev
    }

    /// Run every device queue to completion; completions merged in finish
    /// order across the cluster.
    pub fn run(&mut self) -> Vec<Completion> {
        let mut all: Vec<Completion> = Vec::new();
        for d in &mut self.devices {
            if let Some(t) = &self.trace {
                t.set_device(d.device_index);
            }
            all.extend(d.run());
        }
        all.sort_by(|a, b| a.finish_s.total_cmp(&b.finish_s));
        all
    }

    /// Per-device serving metrics for the last run.
    pub fn per_device_metrics(&self, done: &[Completion]) -> Vec<ServeMetrics> {
        (0..self.devices.len())
            .map(|i| {
                let mine: Vec<Completion> =
                    done.iter().filter(|c| c.device == i).cloned().collect();
                ServeMetrics::from_completions(&mine)
            })
            .collect()
    }

    pub fn per_device_reports(&self) -> Vec<EngineReport> {
        self.devices.iter().map(|d| d.report()).collect()
    }

    /// Submit-time (request id, device) assignment trace.
    pub fn assignments(&self) -> &[(u64, usize)] {
        &self.assignments
    }

    /// Total requests rejected across devices (KV windows that can never
    /// fit).
    pub fn rejected(&self) -> usize {
        self.devices.iter().map(|d| d.rejected().len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, session: u64, at: f64) -> Request {
        Request {
            id,
            prompt_len: 16,
            max_new_tokens: 8,
            arrival_s: at,
            session,
        }
    }

    #[test]
    fn round_robin_rotates() {
        let mut c = Cluster::new(&SimConfig::paper(), 3, 4, Routing::RoundRobin);
        let devs: Vec<usize> = (0..6).map(|i| c.submit(req(i, i, 0.0))).collect();
        assert_eq!(devs, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn session_affinity_is_sticky() {
        let mut c = Cluster::new(&SimConfig::paper(), 4, 4, Routing::SessionAffinity);
        let a = c.submit(req(0, 7, 0.0));
        let b = c.submit(req(1, 7, 0.1));
        let other = c.submit(req(2, 8, 0.2));
        assert_eq!(a, b, "same session, same device");
        assert_ne!(a, other, "a fresh session lands on a lighter device");
    }

    #[test]
    fn session_affinity_spreads_first_contacts_by_load() {
        // Four fresh sessions over two devices: first contacts alternate
        // (least-loaded placement), follow-ups stay home.
        let mut c = Cluster::new(&SimConfig::paper(), 2, 4, Routing::SessionAffinity);
        let d0 = c.submit(req(0, 100, 0.0));
        let d1 = c.submit(req(1, 101, 0.0));
        assert_ne!(d0, d1, "second session avoids the loaded device");
        let d0_again = c.submit(req(2, 100, 0.1));
        assert_eq!(d0, d0_again, "follow-up sticks to the home device");
    }

    #[test]
    fn kv_knobs_apply_to_every_device() {
        use crate::serve::kv_cache::{EvictPolicy, KvPolicy};
        let cfg = SimConfig::paper();
        let mut c = Cluster::new(&cfg, 2, 4, Routing::RoundRobin).with_kv(
            KvPolicy::Paged,
            EvictPolicy::Lru,
            None,
            Some(64),
        );
        for i in 0..6 {
            c.submit(req(i, i, 0.0));
        }
        let done = c.run();
        assert_eq!(done.len(), 6);
        for rep in c.per_device_reports() {
            assert_eq!(rep.preemptions, 0, "ample region: no preemption");
        }
    }

    #[test]
    fn least_loaded_spreads_uneven_work() {
        let mut c = Cluster::new(&SimConfig::paper(), 2, 4, Routing::LeastLoaded);
        let mut big = req(0, 0, 0.0);
        big.max_new_tokens = 128;
        let d0 = c.submit(big);
        // The next two small requests should both avoid the loaded device.
        let d1 = c.submit(req(1, 1, 0.0));
        let d2 = c.submit(req(2, 2, 0.0));
        assert_ne!(d0, d1);
        assert_eq!(d1, d2, "second device stays lighter than the big job");
    }

    #[test]
    fn mixed_backend_cluster_serves_and_labels_devices() {
        let cfg = SimConfig::paper();
        let engines = vec![
            DeviceEngine::with_backend(BackendKind::SalPim.build(&cfg), 4),
            DeviceEngine::with_backend(BackendKind::Gpu.build(&cfg), 4),
        ];
        let mut c = Cluster::from_engines(engines, Routing::RoundRobin);
        assert_eq!(c.backend_names(), vec!["salpim".to_string(), "gpu".to_string()]);
        for i in 0..4 {
            c.submit(req(i, i, 0.0));
        }
        let done = c.run();
        assert_eq!(done.len(), 4);
        // Both devices took traffic.
        assert!(done.iter().any(|c| c.device == 0));
        assert!(done.iter().any(|c| c.device == 1));
    }

    #[test]
    fn cluster_cores_agree_bit_for_bit() {
        use crate::serve::kv_cache::{EvictPolicy, KvPolicy};
        let cfg = SimConfig::paper();
        let run = |core: EngineCore| {
            let mut c = Cluster::new(&cfg, 2, 4, Routing::SessionAffinity)
                .with_kv(KvPolicy::Paged, EvictPolicy::Lru, None, Some(64))
                .with_core(core);
            for i in 0..8 {
                c.submit(req(i, i % 3, 0.01 * i as f64));
            }
            (c.run(), c.per_device_reports())
        };
        let (ev, ev_rep) = run(EngineCore::Event);
        let (lg, lg_rep) = run(EngineCore::Legacy);
        assert_eq!(ev.len(), lg.len());
        for (a, b) in ev.iter().zip(&lg) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.device, b.device);
            assert_eq!(a.tokens_simulated, b.tokens_simulated);
            assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
        }
        for (a, b) in ev_rep.iter().zip(&lg_rep) {
            assert_eq!(a.decode_steps, b.decode_steps);
            assert_eq!(a.preemptions, b.preemptions);
        }
    }

    #[test]
    fn cluster_serves_everything_once() {
        let cfg = SimConfig::paper();
        let mut c = Cluster::new(&cfg, 2, 4, Routing::RoundRobin);
        for i in 0..6 {
            c.submit(req(i, i, 0.0));
        }
        let done = c.run();
        assert_eq!(done.len(), 6);
        let mut ids: Vec<u64> = done.iter().map(|c| c.id).collect();
        ids.sort();
        assert_eq!(ids, (0..6).collect::<Vec<u64>>());
        // Finish order is globally sorted.
        for w in done.windows(2) {
            assert!(w[0].finish_s <= w[1].finish_s);
        }
        let per = c.per_device_metrics(&done);
        assert_eq!(per.len(), 2);
        assert_eq!(per[0].requests + per[1].requests, 6);
    }
}
