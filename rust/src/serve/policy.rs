//! Scheduling policies for serving queues (shared by the sequential
//! coordinator and the continuous-batching engine).

use super::types::Request;

/// Which waiting request runs next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// First come, first served.
    Fcfs,
    /// Shortest total work (prompt + output budget) first.
    ShortestJobFirst,
    /// Shortest prompt first (minimizes time-to-first-token variance).
    ShortestPromptFirst,
}

impl Policy {
    /// Index of the chosen request among `waiting` (non-empty).
    pub fn pick(&self, waiting: &[Request]) -> usize {
        assert!(!waiting.is_empty());
        match self {
            Policy::Fcfs => waiting
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.arrival_s.total_cmp(&b.1.arrival_s))
                .map(|(i, _)| i)
                .unwrap(),
            Policy::ShortestJobFirst => waiting
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| r.prompt_len + r.max_new_tokens)
                .map(|(i, _)| i)
                .unwrap(),
            Policy::ShortestPromptFirst => waiting
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| r.prompt_len)
                .map(|(i, _)| i)
                .unwrap(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fcfs => "fcfs",
            Policy::ShortestJobFirst => "sjf",
            Policy::ShortestPromptFirst => "spf",
        }
    }
}

/// Standalone scheduler over a waiting set (used by tests and the
/// mapping-explorer example; the serving loops embed the same logic).
#[derive(Debug)]
pub struct Scheduler {
    pub policy: Policy,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Self {
        Scheduler { policy }
    }

    /// Order a whole batch per policy (stable for ties).
    pub fn order(&self, mut reqs: Vec<Request>) -> Vec<Request> {
        match self.policy {
            Policy::Fcfs => reqs.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s)),
            Policy::ShortestJobFirst => {
                reqs.sort_by_key(|r| r.prompt_len + r.max_new_tokens)
            }
            Policy::ShortestPromptFirst => reqs.sort_by_key(|r| r.prompt_len),
        }
        reqs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, out: usize, at: f64) -> Request {
        Request {
            id,
            prompt_len: prompt,
            max_new_tokens: out,
            arrival_s: at,
            session: id,
        }
    }

    #[test]
    fn fcfs_picks_earliest() {
        let w = vec![req(0, 10, 10, 5.0), req(1, 1, 1, 1.0)];
        assert_eq!(Policy::Fcfs.pick(&w), 1);
    }

    #[test]
    fn sjf_picks_least_work() {
        let w = vec![req(0, 10, 100, 0.0), req(1, 64, 1, 0.0)];
        assert_eq!(Policy::ShortestJobFirst.pick(&w), 1);
    }

    #[test]
    fn spf_picks_shortest_prompt() {
        let w = vec![req(0, 10, 100, 0.0), req(1, 64, 1, 0.0)];
        assert_eq!(Policy::ShortestPromptFirst.pick(&w), 0);
    }

    #[test]
    fn order_is_policy_consistent() {
        let reqs = vec![req(0, 8, 100, 2.0), req(1, 4, 1, 3.0), req(2, 2, 50, 1.0)];
        let s = Scheduler::new(Policy::Fcfs);
        let ids: Vec<u64> = s.order(reqs.clone()).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 0, 1]);
        let s = Scheduler::new(Policy::ShortestJobFirst);
        let ids: Vec<u64> = s.order(reqs).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 0]);
    }
}
