//! Scheduling policies for serving queues (shared by the sequential
//! coordinator and the continuous-batching engine).

use super::types::{Request, SloClass};

/// How far an interactive request's arrival is pulled forward under
/// [`Policy::Priority`]. A *constant* boost over arrival times keeps
/// the pick pure (no clock input — required by the event core's
/// admission memoization) and starvation-free: a batch request that
/// has waited longer than the boost outranks every newer interactive
/// arrival, so nothing waits unboundedly.
pub const INTERACTIVE_BOOST_S: f64 = 5.0;

/// Which waiting request runs next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// First come, first served.
    Fcfs,
    /// Shortest total work (prompt + output budget) first.
    ShortestJobFirst,
    /// Shortest prompt first (minimizes time-to-first-token variance).
    ShortestPromptFirst,
    /// SLO-aware FCFS: interactive requests are picked as if they had
    /// arrived [`INTERACTIVE_BOOST_S`] earlier (bounded queue-jumping,
    /// so batch traffic cannot starve).
    Priority,
}

/// Arrival time after the SLO boost — the sort key for
/// [`Policy::Priority`].
fn effective_arrival(r: &Request) -> f64 {
    match r.slo {
        SloClass::Interactive => r.arrival_s - INTERACTIVE_BOOST_S,
        SloClass::Batch => r.arrival_s,
    }
}

impl Policy {
    /// Index of the chosen request among `waiting` (non-empty).
    pub fn pick(&self, waiting: &[Request]) -> usize {
        assert!(!waiting.is_empty());
        match self {
            Policy::Fcfs => waiting
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.arrival_s.total_cmp(&b.1.arrival_s))
                .map(|(i, _)| i)
                .unwrap(),
            Policy::ShortestJobFirst => waiting
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| r.prompt_len + r.max_new_tokens)
                .map(|(i, _)| i)
                .unwrap(),
            Policy::ShortestPromptFirst => waiting
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| r.prompt_len)
                .map(|(i, _)| i)
                .unwrap(),
            Policy::Priority => waiting
                .iter()
                .enumerate()
                .min_by(|a, b| effective_arrival(a.1).total_cmp(&effective_arrival(b.1)))
                .map(|(i, _)| i)
                .unwrap(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fcfs => "fcfs",
            Policy::ShortestJobFirst => "sjf",
            Policy::ShortestPromptFirst => "spf",
            Policy::Priority => "priority",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "fcfs" => Some(Policy::Fcfs),
            "sjf" => Some(Policy::ShortestJobFirst),
            "spf" => Some(Policy::ShortestPromptFirst),
            "priority" | "slo" => Some(Policy::Priority),
            _ => None,
        }
    }
}

/// Standalone scheduler over a waiting set (used by tests and the
/// mapping-explorer example; the serving loops embed the same logic).
#[derive(Debug)]
pub struct Scheduler {
    pub policy: Policy,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Self {
        Scheduler { policy }
    }

    /// Order a whole batch per policy (stable for ties).
    pub fn order(&self, mut reqs: Vec<Request>) -> Vec<Request> {
        match self.policy {
            Policy::Fcfs => reqs.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s)),
            Policy::ShortestJobFirst => {
                reqs.sort_by_key(|r| r.prompt_len + r.max_new_tokens)
            }
            Policy::ShortestPromptFirst => reqs.sort_by_key(|r| r.prompt_len),
            Policy::Priority => {
                reqs.sort_by(|a, b| effective_arrival(a).total_cmp(&effective_arrival(b)))
            }
        }
        reqs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::serve::types::SloClass;

    fn req(id: u64, prompt: usize, out: usize, at: f64) -> Request {
        Request {
            id,
            prompt_len: prompt,
            max_new_tokens: out,
            arrival_s: at,
            session: id,
            slo: SloClass::Batch,
            prefix: Vec::new(),
        }
    }

    #[test]
    fn fcfs_picks_earliest() {
        let w = vec![req(0, 10, 10, 5.0), req(1, 1, 1, 1.0)];
        assert_eq!(Policy::Fcfs.pick(&w), 1);
    }

    #[test]
    fn sjf_picks_least_work() {
        let w = vec![req(0, 10, 100, 0.0), req(1, 64, 1, 0.0)];
        assert_eq!(Policy::ShortestJobFirst.pick(&w), 1);
    }

    #[test]
    fn spf_picks_shortest_prompt() {
        let w = vec![req(0, 10, 100, 0.0), req(1, 64, 1, 0.0)];
        assert_eq!(Policy::ShortestPromptFirst.pick(&w), 0);
    }

    #[test]
    fn priority_boosts_interactive_but_not_unboundedly() {
        let mut old_batch = req(0, 8, 8, 0.0);
        old_batch.slo = SloClass::Batch;
        let mut fresh_interactive = req(1, 8, 8, 3.0);
        fresh_interactive.slo = SloClass::Interactive;
        // Interactive jumps a batch request that arrived within the
        // boost window…
        let w = vec![old_batch.clone(), fresh_interactive.clone()];
        assert_eq!(Policy::Priority.pick(&w), 1);
        // …but never one that has already waited longer than the boost
        // (starvation-freedom).
        let mut late_interactive = fresh_interactive.clone();
        late_interactive.arrival_s = INTERACTIVE_BOOST_S + 0.1;
        let w = vec![old_batch, late_interactive];
        assert_eq!(Policy::Priority.pick(&w), 0);
    }

    #[test]
    fn priority_without_interactive_traffic_is_fcfs() {
        let w = vec![req(0, 10, 10, 5.0), req(1, 1, 1, 1.0), req(2, 4, 4, 3.0)];
        assert_eq!(Policy::Priority.pick(&w), Policy::Fcfs.pick(&w));
    }

    #[test]
    fn policy_parse_round_trips() {
        for p in [
            Policy::Fcfs,
            Policy::ShortestJobFirst,
            Policy::ShortestPromptFirst,
            Policy::Priority,
        ] {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("slo"), Some(Policy::Priority));
        assert_eq!(Policy::parse("edf"), None);
    }

    #[test]
    fn order_is_policy_consistent() {
        let reqs = vec![req(0, 8, 100, 2.0), req(1, 4, 1, 3.0), req(2, 2, 50, 1.0)];
        let s = Scheduler::new(Policy::Fcfs);
        let ids: Vec<u64> = s.order(reqs.clone()).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 0, 1]);
        let s = Scheduler::new(Policy::ShortestJobFirst);
        let ids: Vec<u64> = s.order(reqs).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 0]);
    }
}
