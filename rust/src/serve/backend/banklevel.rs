//! The bank-level PIM execution backend (Newton-style, §5.4 / Fig. 12).
//!
//! Reuses the timing engine restricted to one streaming subarray per
//! bank ([`BankLevelPim::device_config`]): column reads arrive at the
//! tCCDL cadence, 1/P_Sub of SAL-PIM's rate. The crucial *serving*
//! difference from SAL-PIM is that the per-bank adder tree computes one
//! dot product at a time and has no per-request accumulator file next to
//! the subarrays — a weight row broadcast cannot be consumed by several
//! requests at once, so batched decode steps do NOT amortize: a step
//! over N requests costs the sum of N single-request iterations.
//!
//! Its DRAM also embeds no LUT subarrays, so the KV region is the whole
//! device minus the weight replica.

use super::{DeviceCapacity, ExecutionBackend};
use crate::baseline::BankLevelPim;
use crate::config::SimConfig;
use crate::mapper::GenerationSim;

/// Newton-style bank-level PIM device backend.
pub struct BankLevelBackend {
    cfg: SimConfig,
    sim: GenerationSim,
}

impl BankLevelBackend {
    /// Build from a SAL-PIM config (same HBM2 device, Table 2 timing).
    pub fn new(cfg: &SimConfig) -> Self {
        let cfg = BankLevelPim::device_config(cfg);
        BankLevelBackend {
            sim: GenerationSim::new(&cfg),
            cfg,
        }
    }
}

impl ExecutionBackend for BankLevelBackend {
    fn name(&self) -> String {
        "banklevel".to_string()
    }

    fn prefill_s(&mut self, n_tokens: usize) -> f64 {
        self.sim.prefill(n_tokens).seconds(self.cfg.timing.tck_ns)
    }

    fn decode_step_s(&mut self, kv_lens: &[usize]) -> f64 {
        assert!(!kv_lens.is_empty(), "empty decode batch");
        // No per-request accumulators: requests serialize within a step.
        let cycles: u64 = kv_lens.iter().map(|&kv| self.sim.decode_token(kv).cycles).sum();
        self.cfg.timing.cycles_to_sec(cycles)
    }

    fn capacity(&self) -> DeviceCapacity {
        let subarray_bytes = self.cfg.hbm.subarray_bytes();
        let weight_bytes = self.cfg.model.total_params() * self.cfg.model.param_bytes;
        let kv_subarrays = self
            .cfg
            .hbm
            .total_subarrays()
            .saturating_sub(weight_bytes.div_ceil(subarray_bytes));
        let kv_bytes_per_token = self.cfg.model.kv_bytes_per_token();
        DeviceCapacity {
            kv_bytes_per_token,
            kv_alloc_unit_bytes: subarray_bytes,
            kv_total_units: kv_subarrays,
            // One paged block = one subarray's rows worth of K/V state.
            kv_block_tokens: DeviceCapacity::block_tokens_for_unit(
                subarray_bytes,
                kv_bytes_per_token,
            ),
            max_seq: self.cfg.model.max_seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::backend::SalPimBackend;

    #[test]
    fn decode_does_not_amortize_across_the_batch() {
        let cfg = SimConfig::paper();
        let mut b = BankLevelBackend::new(&cfg);
        let singles: f64 = [64usize, 96].iter().map(|&kv| b.decode_step_s(&[kv])).sum();
        let batch = b.decode_step_s(&[64, 96]);
        assert!((batch - singles).abs() < 1e-15 + 1e-12 * singles);
    }

    #[test]
    fn salpim_outruns_banklevel_decode() {
        let cfg = SimConfig::paper();
        let mut bank = BankLevelBackend::new(&cfg);
        let mut sal = SalPimBackend::new(&cfg);
        let kvs = [64usize, 64, 64, 64];
        assert!(
            bank.decode_step_s(&kvs) > sal.decode_step_s(&kvs),
            "bank-level must be slower than subarray-level"
        );
    }
}
