//! Pluggable execution backends for the serving layer.
//!
//! The serving engine ([`crate::serve::DeviceEngine`]) used to own a
//! [`crate::mapper::GenerationSim`] directly, which welded the whole
//! batching/routing/sweep stack to the SAL-PIM cost model. The
//! [`ExecutionBackend`] trait decouples them: a backend answers the only
//! three questions the scheduler asks —
//!
//! 1. how long does a summarization (prefill) over `n` tokens take,
//! 2. how long does one *batched* decode step over a set of in-flight
//!    KV lengths take, and
//! 3. what KV capacity does the device expose ([`DeviceCapacity`]) —
//!
//! so every cost model in the repo becomes a servable, clusterable
//! device. The four implementations:
//!
//! * [`SalPimBackend`] — the paper's subarray-level PIM
//!   ([`crate::mapper::GenerationSim`], cycle-accurate, weight stream
//!   amortized across the batch);
//! * [`GpuBackend`] — the Titan RTX roofline
//!   ([`crate::baseline::GpuModel`]) *with batching semantics*: the
//!   weight stream is paid once per step, per-request attention
//!   accumulates;
//! * [`BankLevelBackend`] — the Newton-style bank-level PIM (one
//!   streaming subarray per bank, no per-request accumulators, so decode
//!   steps do NOT amortize across a batch);
//! * [`HeteroBackend`] — prefill on one backend, decode on another
//!   (PAPI / PIM-GPT style GPU-prefill + PIM-decode), with a
//!   configurable KV handoff cost over the host link.

mod banklevel;
mod gpu;
mod hetero;
mod salpim;

pub use banklevel::BankLevelBackend;
pub use gpu::GpuBackend;
pub use hetero::HeteroBackend;
pub use salpim::SalPimBackend;

use crate::config::SimConfig;

/// KV-capacity hints one device exposes to the serving layer's admission
/// control. Capacity is consumed in whole allocation units — subarrays
/// on a PIM device (open-row streaming wants contiguous K/V rows), pages
/// on a GPU — and, under the paged KV policy, in fixed-size blocks of
/// `kv_block_tokens` tokens each.
#[derive(Debug, Clone, Copy)]
pub struct DeviceCapacity {
    /// Bytes of K+V state one token pins for a request's lifetime.
    pub kv_bytes_per_token: usize,
    /// Bytes per allocation unit (subarray / page).
    pub kv_alloc_unit_bytes: usize,
    /// Allocation units in the device's KV region.
    pub kv_total_units: usize,
    /// Tokens per paged KV block: how many tokens of K+V state one
    /// allocation unit's rows hold (at least 1). Derived from the
    /// subarray row geometry on PIM (rows × row bytes / KV bytes per
    /// token) and the allocator page size on a GPU, via
    /// [`DeviceCapacity::block_tokens_for_unit`].
    pub kv_block_tokens: usize,
    /// Longest KV length the device's model supports.
    pub max_seq: usize,
}

impl DeviceCapacity {
    /// Tokens of K+V state one allocation unit holds — the paged block
    /// size every backend derives its `kv_block_tokens` from.
    pub fn block_tokens_for_unit(unit_bytes: usize, kv_bytes_per_token: usize) -> usize {
        (unit_bytes / kv_bytes_per_token.max(1)).max(1)
    }

    /// Token capacity if the region were filled by one giant request.
    pub fn capacity_tokens(&self) -> usize {
        self.kv_total_units * self.kv_alloc_unit_bytes / self.kv_bytes_per_token
    }
}

/// One simulated device the serving engine can schedule onto.
///
/// Methods take `&mut self` because the cost models memoize per-KV
/// simulations. All times are seconds of simulated wall clock, so
/// heterogeneous compositions and cross-backend comparisons need no
/// unit conversion.
pub trait ExecutionBackend {
    /// Human-readable backend label for tables and reports.
    fn name(&self) -> String;

    /// Service time of the summarization stage over `n_tokens` prompt
    /// tokens (emits the first output token). Must be monotone
    /// non-decreasing in `n_tokens`: chunked prefill charges chunk `i`
    /// as `prefill_s(end_i) - prefill_s(start_i)`, which telescopes to
    /// the unchunked total.
    fn prefill_s(&mut self, n_tokens: usize) -> f64;

    /// Service time of one batched decode step: every entry of
    /// `kv_lens` is one in-flight request producing its next token in
    /// the same step. A batch of one must equal the backend's
    /// single-request decode iteration.
    fn decode_step_s(&mut self, kv_lens: &[usize]) -> f64;

    /// KV capacity hints for admission control.
    fn capacity(&self) -> DeviceCapacity;

    /// The KV-handoff share of a prefill charge over `n_tokens` (the
    /// host-link transfer a heterogeneous device folds into
    /// [`ExecutionBackend::prefill_s`]). `None` — the default — means
    /// the backend has no handoff stage; tracing uses this to attribute
    /// the transfer on its own trace track.
    fn kv_handoff_s_for(&self, n_tokens: usize) -> Option<f64> {
        let _ = n_tokens;
        None
    }
}

/// The built-in backend families, as selected by `--backend`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Subarray-level PIM (the paper's device).
    SalPim,
    /// Titan RTX roofline with batched decode semantics.
    Gpu,
    /// Newton-style bank-level PIM (no batch amortization).
    BankLevel,
    /// GPU prefill + SAL-PIM decode with a PCIe-class KV handoff.
    Hetero,
}

impl BackendKind {
    pub const ALL: [BackendKind; 4] = [
        BackendKind::SalPim,
        BackendKind::Gpu,
        BackendKind::BankLevel,
        BackendKind::Hetero,
    ];

    /// Every spelling [`BackendKind::parse`] accepts (canonical names
    /// first) — the vocabulary quoted in its error and mined for
    /// did-you-mean suggestions.
    pub const ACCEPTED: [&'static str; 7] = [
        "salpim",
        "gpu",
        "banklevel",
        "hetero",
        "sal-pim",
        "pim",
        "bank-level",
    ];

    /// Parse a `--backend` / `static:<backend>` value. The error names
    /// the accepted backends and suggests the nearest one, so a typo
    /// surfaces actionably instead of `Option`-silently.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "salpim" | "sal-pim" | "pim" => Ok(BackendKind::SalPim),
            "gpu" => Ok(BackendKind::Gpu),
            "banklevel" | "bank-level" => Ok(BackendKind::BankLevel),
            "hetero" => Ok(BackendKind::Hetero),
            _ => Err(format!(
                "unknown backend `{s}` (salpim|gpu|banklevel|hetero){}",
                crate::cli::suggest(s, Self::ACCEPTED.into_iter(), "")
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::SalPim => "salpim",
            BackendKind::Gpu => "gpu",
            BackendKind::BankLevel => "banklevel",
            BackendKind::Hetero => "hetero",
        }
    }

    /// Build the backend for a device config.
    pub fn build(&self, cfg: &SimConfig) -> Box<dyn ExecutionBackend> {
        match self {
            BackendKind::SalPim => Box::new(SalPimBackend::new(cfg)),
            BackendKind::Gpu => Box::new(GpuBackend::titan_rtx(&cfg.model)),
            BackendKind::BankLevel => Box::new(BankLevelBackend::new(cfg)),
            BackendKind::Hetero => Box::new(HeteroBackend::gpu_prefill_pim_decode(cfg)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_and_names_round_trip() {
        for kind in BackendKind::ALL {
            assert_eq!(BackendKind::parse(kind.name()), Ok(kind));
        }
        assert_eq!(BackendKind::parse("pim"), Ok(BackendKind::SalPim));
        for alias in BackendKind::ACCEPTED {
            assert!(BackendKind::parse(alias).is_ok(), "{alias}");
        }
    }

    #[test]
    fn parse_errors_name_the_accepted_backends_and_suggest() {
        let err = BackendKind::parse("cuda").unwrap_err();
        assert!(err.contains("salpim|gpu|banklevel|hetero"), "{err}");
        let typo = BackendKind::parse("salpin").unwrap_err();
        assert!(typo.contains("did you mean salpim"), "{typo}");
    }

    #[test]
    fn every_kind_builds_a_live_backend() {
        let cfg = SimConfig::paper();
        for kind in BackendKind::ALL {
            let mut b = kind.build(&cfg);
            assert!(b.prefill_s(16) > 0.0, "{}", b.name());
            assert!(b.decode_step_s(&[32]) > 0.0, "{}", b.name());
            let cap = b.capacity();
            assert!(cap.kv_total_units > 0, "{}", b.name());
            assert!(cap.capacity_tokens() > 0, "{}", b.name());
            assert!(cap.kv_block_tokens >= 1, "{}", b.name());
            assert_eq!(
                cap.kv_block_tokens,
                DeviceCapacity::block_tokens_for_unit(
                    cap.kv_alloc_unit_bytes,
                    cap.kv_bytes_per_token
                ),
                "{}: block geometry must derive from the allocation unit",
                b.name()
            );
            assert_eq!(cap.max_seq, cfg.model.max_seq);
        }
    }
}
