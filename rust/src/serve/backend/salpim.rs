//! The SAL-PIM execution backend: the paper's subarray-level device
//! behind the [`ExecutionBackend`] trait.
//!
//! Thin adapter over [`GenerationSim`] — cycle-accurate prefill and
//! batched decode steps ([`GenerationSim::decode_batch_step`]: the
//! shared weight stream is paid once per step, per-request KV/attention
//! work accumulates), converted to seconds at the config's tCK. The KV
//! region is whatever subarrays remain after the model weights and the
//! LUT-embedded subarrays are placed
//! ([`crate::serve::kv_cache::device_kv_subarrays`]).

use super::{DeviceCapacity, ExecutionBackend};
use crate::config::SimConfig;
use crate::mapper::GenerationSim;
use crate::serve::kv_cache::device_kv_subarrays;

/// Subarray-level PIM device (wraps the cycle-accurate simulator).
pub struct SalPimBackend {
    cfg: SimConfig,
    sim: GenerationSim,
}

impl SalPimBackend {
    pub fn new(cfg: &SimConfig) -> Self {
        SalPimBackend {
            cfg: cfg.clone(),
            sim: GenerationSim::new(cfg),
        }
    }

    /// The device config the backend simulates.
    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }
}

impl ExecutionBackend for SalPimBackend {
    fn name(&self) -> String {
        "salpim".to_string()
    }

    fn prefill_s(&mut self, n_tokens: usize) -> f64 {
        self.sim.prefill(n_tokens).seconds(self.cfg.timing.tck_ns)
    }

    fn decode_step_s(&mut self, kv_lens: &[usize]) -> f64 {
        let st = self.sim.decode_batch_step(kv_lens);
        self.cfg.timing.cycles_to_sec(st.cycles)
    }

    fn capacity(&self) -> DeviceCapacity {
        let kv_bytes_per_token = self.cfg.model.kv_bytes_per_token();
        let subarray_bytes = self.cfg.hbm.subarray_bytes();
        DeviceCapacity {
            kv_bytes_per_token,
            kv_alloc_unit_bytes: subarray_bytes,
            kv_total_units: device_kv_subarrays(&self.cfg),
            // One paged block = one subarray's rows worth of K/V state.
            kv_block_tokens: DeviceCapacity::block_tokens_for_unit(
                subarray_bytes,
                kv_bytes_per_token,
            ),
            max_seq: self.cfg.model.max_seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_the_generation_sim_exactly() {
        let cfg = SimConfig::paper();
        let mut b = SalPimBackend::new(&cfg);
        let mut sim = GenerationSim::new(&cfg);
        let tck = cfg.timing.tck_ns;
        assert_eq!(b.prefill_s(32), sim.prefill(32).seconds(tck));
        assert_eq!(
            b.decode_step_s(&[64, 96]),
            cfg.timing.cycles_to_sec(sim.decode_batch_step(&[64, 96]).cycles)
        );
    }

    #[test]
    fn batch_of_one_degenerates_to_a_single_decode() {
        let cfg = SimConfig::paper();
        let mut b = SalPimBackend::new(&cfg);
        let mut sim = GenerationSim::new(&cfg);
        assert_eq!(
            b.decode_step_s(&[128]),
            cfg.timing.cycles_to_sec(sim.decode_token(128).cycles)
        );
    }

    #[test]
    fn capacity_mirrors_the_kv_manager() {
        let cfg = SimConfig::paper();
        let cap = SalPimBackend::new(&cfg).capacity();
        let kv = crate::serve::KvCacheManager::for_device(&cfg);
        assert_eq!(cap.kv_total_units, kv.total_subarrays());
        assert_eq!(cap.capacity_tokens(), kv.capacity_tokens());
    }
}
