//! Heterogeneous execution: prefill on one backend, decode on another.
//!
//! The paper's §6.3 future work (and PAPI / PIM-GPT as deployed
//! systems): the summarization stage is compute-dense and belongs on a
//! GPU/ASIC; token-by-token generation is memory-bound and belongs on
//! PIM. [`HeteroBackend`] composes any two [`ExecutionBackend`]s that
//! way, charging a KV handoff over the host link after prefill — the
//! prompt's K/V state is produced on the prefill device and must land in
//! the decode device's DRAM before the first decode step.
//!
//! The handoff is costed by the same [`FabricParams`] link model the
//! disaggregated cluster uses for cross-device KV migration, so
//! intra-device handoff and inter-device migration share one cost
//! signature (the default PCIe class has zero base latency, so the
//! charge is exactly the historical `bytes / 16 GB/s`).
//!
//! The handoff is linear in tokens, so chunked prefill composes cleanly:
//! each chunk's incremental cost carries its own KV bytes and the chunk
//! costs telescope to the unchunked total.

use super::{DeviceCapacity, ExecutionBackend};
use crate::config::SimConfig;
use crate::serve::fabric::FabricParams;

/// Prefill on one device, decode on another, KV handed off in between.
pub struct HeteroBackend {
    prefill: Box<dyn ExecutionBackend>,
    decode: Box<dyn ExecutionBackend>,
    /// Host-link class the KV handoff is charged at (uncontended; the
    /// handoff is part of the prefill charge on this device's clock).
    pub link: FabricParams,
}

impl HeteroBackend {
    pub fn new(
        prefill: Box<dyn ExecutionBackend>,
        decode: Box<dyn ExecutionBackend>,
        link: FabricParams,
    ) -> Self {
        assert!(
            link.bandwidth_bytes_s > 0.0,
            "handoff bandwidth must be positive"
        );
        HeteroBackend {
            prefill,
            decode,
            link,
        }
    }

    /// The canonical composition: GPU prefill + SAL-PIM decode over a
    /// PCIe-class link (what `--backend hetero` builds).
    pub fn gpu_prefill_pim_decode(cfg: &SimConfig) -> Self {
        Self::new(
            Box::new(super::GpuBackend::titan_rtx(&cfg.model)),
            Box::new(super::SalPimBackend::new(cfg)),
            FabricParams::pcie(),
        )
    }

    /// KV handoff cost for an `n`-token prompt at this link.
    fn handoff_s(&self, n_tokens: usize) -> f64 {
        self.link
            .transfer_s(n_tokens * self.decode.capacity().kv_bytes_per_token)
    }
}

impl ExecutionBackend for HeteroBackend {
    fn name(&self) -> String {
        format!("hetero({}→{})", self.prefill.name(), self.decode.name())
    }

    fn prefill_s(&mut self, n_tokens: usize) -> f64 {
        self.prefill.prefill_s(n_tokens) + self.handoff_s(n_tokens)
    }

    fn decode_step_s(&mut self, kv_lens: &[usize]) -> f64 {
        self.decode.decode_step_s(kv_lens)
    }

    /// KV lives on the decode device — that is the capacity that gates
    /// admission.
    fn capacity(&self) -> DeviceCapacity {
        self.decode.capacity()
    }

    /// The handoff share of a prefill charge, exposed so tracing can
    /// attribute the host-link transfer separately from GPU compute.
    fn kv_handoff_s_for(&self, n_tokens: usize) -> Option<f64> {
        Some(self.handoff_s(n_tokens))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::serve::backend::{GpuBackend, SalPimBackend};

    #[test]
    fn composes_prefill_decode_and_handoff_exactly() {
        let cfg = SimConfig::paper();
        let mut het = HeteroBackend::gpu_prefill_pim_decode(&cfg);
        let mut gpu = GpuBackend::titan_rtx(&cfg.model);
        let mut pim = SalPimBackend::new(&cfg);

        let n = 128;
        let handoff = FabricParams::pcie().transfer_s(n * cfg.model.kv_bytes_per_token());
        let want = gpu.prefill_s(n) + handoff;
        let got = het.prefill_s(n);
        assert!((got - want).abs() < 1e-15 + 1e-12 * want, "{got} != {want}");

        assert_eq!(het.decode_step_s(&[64, 96]), pim.decode_step_s(&[64, 96]));
        assert_eq!(het.capacity().kv_total_units, pim.capacity().kv_total_units);
    }

    #[test]
    fn handoff_scales_with_tokens_and_bandwidth() {
        let kvt = ModelConfig::gpt2_medium().kv_bytes_per_token();
        let pcie = FabricParams::pcie();
        let one = pcie.transfer_s(kvt);
        assert!(one > 0.0);
        assert!((pcie.transfer_s(10 * kvt) - 10.0 * one).abs() < 1e-12);
        let double = FabricParams {
            bandwidth_bytes_s: 2.0 * pcie.bandwidth_bytes_s,
            base_latency_s: 0.0,
        };
        assert!(double.transfer_s(kvt) < one);
    }

    #[test]
    fn hetero_prefill_beats_pim_prefill_on_long_prompts() {
        // §6.3's whole point: the GPU's parallel-input prefill plus the
        // handoff still beats PIM prefill for long prompts.
        let cfg = SimConfig::paper();
        let mut het = HeteroBackend::gpu_prefill_pim_decode(&cfg);
        let mut pim = SalPimBackend::new(&cfg);
        assert!(het.prefill_s(128) < pim.prefill_s(128));
    }

    #[test]
    fn nvlink_class_handoff_is_cheaper_than_pcie_for_large_prompts() {
        let cfg = SimConfig::paper();
        let mut pcie = HeteroBackend::new(
            Box::new(GpuBackend::titan_rtx(&cfg.model)),
            Box::new(SalPimBackend::new(&cfg)),
            FabricParams::pcie(),
        );
        let mut nv = HeteroBackend::new(
            Box::new(GpuBackend::titan_rtx(&cfg.model)),
            Box::new(SalPimBackend::new(&cfg)),
            FabricParams::nvlink(),
        );
        assert!(nv.prefill_s(512) < pcie.prefill_s(512));
    }
}
