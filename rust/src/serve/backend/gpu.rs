//! The GPU execution backend: the Titan RTX roofline baseline promoted
//! to a servable device with batching semantics.
//!
//! [`crate::baseline::GpuModel`] was a one-off per-request cost model;
//! here one batched decode step pays the *weight stream* (and the fused
//! per-layer kernels) once — every request in the batch consumes the
//! same weight tiles — while the per-request attention work (KV
//! streaming, small-kernel softmax overheads) accumulates, mirroring
//! FasterTransformer's batched decode. A batch of one reproduces
//! [`GpuModel::decode_token_time`] exactly (the decomposition lives in
//! [`GpuModel::decode_shared_time`] / [`GpuModel::decode_attention_time`],
//! so model and backend cannot drift).
//!
//! KV capacity is the card's DRAM minus one fp16 weight replica,
//! allocated in 2 MiB pages.

use super::{DeviceCapacity, ExecutionBackend};
use crate::baseline::GpuModel;
use crate::config::ModelConfig;

/// KV allocation granularity on the GPU (a CUDA-allocator-style page).
const GPU_KV_PAGE_BYTES: usize = 2 << 20;

/// GPU device backend (roofline + launch overheads, batched decode).
pub struct GpuBackend {
    model: ModelConfig,
    gpu: GpuModel,
}

impl GpuBackend {
    pub fn new(model: &ModelConfig, gpu: GpuModel) -> Self {
        GpuBackend {
            model: model.clone(),
            gpu,
        }
    }

    /// The paper's calibrated Titan RTX + FasterTransformer baseline.
    pub fn titan_rtx(model: &ModelConfig) -> Self {
        Self::new(model, GpuModel::titan_rtx())
    }

    /// The wrapped roofline model.
    pub fn model(&self) -> &GpuModel {
        &self.gpu
    }
}

impl ExecutionBackend for GpuBackend {
    fn name(&self) -> String {
        "gpu".to_string()
    }

    fn prefill_s(&mut self, n_tokens: usize) -> f64 {
        self.gpu.prefill_time(&self.model, n_tokens)
    }

    fn decode_step_s(&mut self, kv_lens: &[usize]) -> f64 {
        assert!(!kv_lens.is_empty(), "empty decode batch");
        let shared = self.gpu.decode_shared_time(&self.model);
        let per_req: f64 = kv_lens
            .iter()
            .map(|&kv| self.gpu.decode_attention_time(&self.model, kv))
            .sum();
        shared + per_req
    }

    fn capacity(&self) -> DeviceCapacity {
        let weight_bytes = self.model.total_params() * self.model.param_bytes;
        let kv_bytes = self.gpu.mem_bytes.saturating_sub(weight_bytes);
        let kv_bytes_per_token = self.model.kv_bytes_per_token();
        DeviceCapacity {
            kv_bytes_per_token,
            kv_alloc_unit_bytes: GPU_KV_PAGE_BYTES,
            kv_total_units: kv_bytes / GPU_KV_PAGE_BYTES,
            // One paged block = one allocator page worth of K/V state.
            kv_block_tokens: DeviceCapacity::block_tokens_for_unit(
                GPU_KV_PAGE_BYTES,
                kv_bytes_per_token,
            ),
            max_seq: self.model.max_seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_of_one_equals_the_roofline_decode() {
        let m = ModelConfig::gpt2_medium();
        let mut b = GpuBackend::titan_rtx(&m);
        let single = GpuModel::titan_rtx().decode_token_time(&m, 64);
        let step = b.decode_step_s(&[64]);
        assert!(
            (step - single).abs() < 1e-12 * single.max(1.0),
            "step {step} != single {single}"
        );
    }

    #[test]
    fn batched_step_amortizes_the_weight_stream() {
        let m = ModelConfig::gpt2_medium();
        let mut b = GpuBackend::titan_rtx(&m);
        let kvs = [64usize, 96, 128, 160];
        let batch = b.decode_step_s(&kvs);
        let sequential: f64 = kvs.iter().map(|&kv| b.decode_step_s(&[kv])).sum();
        let slowest = b.decode_step_s(&[160]);
        assert!(batch < sequential, "{batch} !< {sequential}");
        assert!(batch >= slowest, "{batch} < slowest member {slowest}");
    }

    #[test]
    fn titan_rtx_holds_a_large_kv_working_set() {
        // 24 GB minus ~700 MB of fp16 weights at 96 KB of KV per token:
        // well over 100k resident tokens.
        let cap = GpuBackend::titan_rtx(&ModelConfig::gpt2_medium()).capacity();
        assert!(cap.capacity_tokens() > 100_000, "{}", cap.capacity_tokens());
    }
}
