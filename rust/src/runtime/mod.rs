//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and runs
//! them from rust — the float golden model for the functional simulator
//! and the model executor behind the serving example.
//!
//! HLO *text* is the interchange format (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile`.

use std::path::{Path, PathBuf};

/// Directory holding `make artifacts` outputs.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("SALPIM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Are the AOT artifacts present (built by `make artifacts`)?
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("model_decode_ref.hlo.txt").exists()
}

/// The PJRT-backed pieces need the external `xla` crate, which is not
/// available on the offline image — they are gated behind the `pjrt`
/// feature (see Cargo.toml: enabling it requires declaring a vendored
/// `xla` path dependency there). The path helpers above stay available
/// either way so artifact-dependent tests can skip gracefully.
#[cfg(feature = "pjrt")]
mod pjrt_impl {
    #[cfg(test)]
    use super::{artifacts_available, default_artifacts_dir};
    use anyhow::{anyhow, Context, Result};
    use std::path::Path;
    #[cfg(test)]
    use std::path::PathBuf;

    /// A PJRT CPU client plus loaded executables.
    pub struct Runtime {
        pub client: xla::PjRtClient,
    }

    /// One compiled artifact.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Runtime {
        pub fn new() -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Runtime { client })
        }

        /// Load + compile one HLO-text artifact.
        pub fn load(&self, path: &Path) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
            Ok(Executable {
                exe,
                name: path
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default(),
            })
        }
    }

    impl Executable {
        /// Execute with literal inputs; returns the flattened result tuple.
        pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let out = self
                .exe
                .execute::<xla::Literal>(args)
                .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
            let lit = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?;
            // aot.py lowers with return_tuple=True.
            lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
        }
    }

    /// Build an f32 literal of the given shape.
    pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(data);
        if dims.len() == 1 {
            Ok(lit)
        } else {
            lit.reshape(dims).map_err(|e| anyhow!("reshape: {e:?}"))
        }
    }

    /// Build an i16 (S16) literal of the given shape.
    pub fn literal_i16(data: &[i16], dims: &[usize]) -> Result<xla::Literal> {
        let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::S16, dims);
        lit.copy_raw_from(data)
            .map_err(|e| anyhow!("copy_raw_from i16: {e:?}"))?;
        Ok(lit)
    }

    /// The float golden GPT model running through PJRT (decode-step artifact
    /// with KV cache threaded through rust).
    pub struct GoldenGpt {
        exe: Executable,
        n_layers: usize,
        max_seq: usize,
        d_model: usize,
        pub vocab: usize,
        kv_k: Vec<f32>,
        kv_v: Vec<f32>,
        pub pos: usize,
    }

    impl GoldenGpt {
        /// Load `model_decode_ref` (or `_pim` when `pim` is true).
        pub fn load(rt: &Runtime, dir: &Path, pim: bool) -> Result<Self> {
            let name = if pim {
                "model_decode_pim.hlo.txt"
            } else {
                "model_decode_ref.hlo.txt"
            };
            let exe = rt.load(&dir.join(name))?;
            // GPT-2 mini shapes (python/compile/weights.py::MiniConfig).
            let (n_layers, max_seq, d_model, vocab) = (2, 128, 128, 256);
            Ok(GoldenGpt {
                exe,
                n_layers,
                max_seq,
                d_model,
                vocab,
                kv_k: vec![0.0; n_layers * max_seq * d_model],
                kv_v: vec![0.0; n_layers * max_seq * d_model],
                pos: 0,
            })
        }

        pub fn reset(&mut self) {
            self.kv_k.iter_mut().for_each(|v| *v = 0.0);
            self.kv_v.iter_mut().for_each(|v| *v = 0.0);
            self.pos = 0;
        }

        /// One decode step; returns (argmax token, logits).
        pub fn decode_step(&mut self, token: usize) -> Result<(usize, Vec<f32>)> {
            anyhow::ensure!(self.pos < self.max_seq, "KV capacity exceeded");
            let dims = [
                self.n_layers as i64,
                self.max_seq as i64,
                self.d_model as i64,
            ];
            let args = vec![
                xla::Literal::scalar(token as i32),
                xla::Literal::scalar(self.pos as i32),
                literal_f32(&self.kv_k, &dims)?,
                literal_f32(&self.kv_v, &dims)?,
            ];
            let mut out = self.exe.run(&args)?;
            anyhow::ensure!(out.len() == 3, "expected 3 outputs, got {}", out.len());
            let kv_v = out.pop().unwrap();
            let kv_k = out.pop().unwrap();
            let logits_lit = out.pop().unwrap();
            let logits: Vec<f32> = logits_lit
                .to_vec()
                .map_err(|e| anyhow!("logits: {e:?}"))?;
            self.kv_k = kv_k.to_vec().map_err(|e| anyhow!("kv_k: {e:?}"))?;
            self.kv_v = kv_v.to_vec().map_err(|e| anyhow!("kv_v: {e:?}"))?;
            self.pos += 1;
            let next = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            Ok((next, logits))
        }

        /// Greedy generation (prompt then `n_out` tokens).
        pub fn generate(&mut self, prompt: &[usize], n_out: usize) -> Result<Vec<usize>> {
            self.reset();
            let mut next = 0;
            for &t in prompt {
                next = self.decode_step(t)?.0;
            }
            let mut out = Vec::with_capacity(n_out);
            for _ in 0..n_out {
                out.push(next);
                next = self.decode_step(next)?.0;
            }
            Ok(out)
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::config::SimConfig;
        use crate::interp::{LutTable, NonLinFn};
        use crate::model::fixedpoint::Q8_8;
        use crate::model::{FloatGpt, FunctionalGpt};

        fn dir() -> PathBuf {
            default_artifacts_dir()
        }

        fn need_artifacts() -> bool {
            let ok = artifacts_available(&dir());
            if !ok {
                eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            }
            ok
        }

        #[test]
        fn gelu_kernel_artifact_matches_rust_lut_bit_exact() {
            if !need_artifacts() {
                return;
            }
            let rt = Runtime::new().unwrap();
            let exe = rt.load(&dir().join("kernel_lut_gelu.hlo.txt")).unwrap();
            let table = LutTable::build(NonLinFn::Gelu, 64, Q8_8, Q8_8);
            let xs: Vec<i16> = (0..512).map(|i| (i * 37 % 16000 - 8000) as i16).collect();
            let mut tbl = Vec::with_capacity(128);
            for i in 0..64 {
                tbl.push(table.slopes[i]);
                tbl.push(table.intercepts[i]);
            }
            let args = vec![
                literal_i16(&xs, &[512]).unwrap(),
                literal_i16(&tbl, &[64, 2]).unwrap(),
            ];
            let out = exe.run(&args).unwrap();
            let got: Vec<i16> = out[0].to_vec().unwrap();
            let want: Vec<i16> = xs.iter().map(|&x| table.eval_raw(x)).collect();
            assert_eq!(got, want, "Pallas kernel ≠ rust LUT pipeline");
        }

        #[test]
        fn golden_decode_matches_float_model() {
            if !need_artifacts() {
                return;
            }
            let rt = Runtime::new().unwrap();
            let mut golden = GoldenGpt::load(&rt, &dir(), false).unwrap();
            let mut float = FloatGpt::new(&SimConfig::mini());
            for &t in &[5usize, 9, 77] {
                let (a, la) = golden.decode_step(t).unwrap();
                let (b, lb) = float.decode_step(t);
                // f32 (XLA) vs f64 (rust) — argmax and logit values agree.
                assert_eq!(a, b, "argmax mismatch at token {t}");
                let max_err = la
                    .iter()
                    .zip(&lb)
                    .map(|(x, y)| (*x as f64 - y).abs())
                    .fold(0.0f64, f64::max);
                assert!(max_err < 2e-2, "logit drift {max_err}");
            }
        }

        #[test]
        fn pim_decode_artifact_tracks_fixed_point_model() {
            if !need_artifacts() {
                return;
            }
            let rt = Runtime::new().unwrap();
            let mut pim = GoldenGpt::load(&rt, &dir(), true).unwrap();
            let mut fx = FunctionalGpt::new(&SimConfig::mini());
            let mut agree = 0;
            let toks = [3usize, 11, 42, 100];
            for &t in &toks {
                let (a, _) = pim.decode_step(t).unwrap();
                let (b, _) = fx.decode_step(t);
                agree += (a == b) as usize;
            }
            assert!(agree >= 3, "PIM artifact vs functional sim agree {agree}/4");
        }

        #[test]
        fn generation_through_pjrt_is_deterministic() {
            if !need_artifacts() {
                return;
            }
            let rt = Runtime::new().unwrap();
            let mut g = GoldenGpt::load(&rt, &dir(), false).unwrap();
            let a = g.generate(&[1, 2, 3], 4).unwrap();
            let b = g.generate(&[1, 2, 3], 4).unwrap();
            assert_eq!(a, b);
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{literal_f32, literal_i16, Executable, GoldenGpt, Runtime};
