//! Bank-level PIM baseline (Newton-style, §5.4 / Fig. 12).
//!
//! Newton integrates multipliers + an adder tree per bank: a GEMV maps
//! matrix rows across banks/channels, each bank computing complete dot
//! products — no inter-bank accumulation is needed (the paper's point in
//! §5.4: "bank-level PIM does not require the bank-level data movement").
//! The cost of that simplicity is bandwidth: one subarray streams per
//! bank, so column reads arrive at the tCCDL cadence — 1/P_Sub of
//! SAL-PIM's rate.
//!
//! The model reuses the same timing engine restricted to one subarray
//! group and drops the C-ALU merge (adder-tree results leave per bank).

use crate::config::SimConfig;
use crate::pim::{MacroOp, PimEngine};
use crate::stats::{Phase, Stats};

/// Newton-style bank-level PIM device model.
pub struct BankLevelPim {
    cfg: SimConfig,
}

impl BankLevelPim {
    /// Build from a SAL-PIM config (same HBM2 device, Table 2 timing).
    pub fn new(cfg: &SimConfig) -> Self {
        BankLevelPim {
            cfg: Self::device_config(cfg),
        }
    }

    /// The restricted device config a bank-level PIM runs: the same HBM2
    /// stack with one streaming subarray per bank (P_Sub = 1). Shared
    /// with the serving layer's `BankLevelBackend` so the GEMV baseline
    /// and the servable device agree on timing.
    pub fn device_config(cfg: &SimConfig) -> SimConfig {
        cfg.clone().with_p_sub(1)
    }

    /// GEMV macro-ops under the Newton mapping: rows → banks × channels,
    /// full rows per bank (no column split, no C-ALU accumulation), one
    /// subarray streaming per bank.
    pub fn gemv_ops(&self, rows: usize, cols: usize) -> Vec<MacroOp> {
        let p = &self.cfg.parallelism;
        let rows_per_bank = rows.div_ceil(p.p_ch * p.p_ba);
        // Per output row: cols coefficients; the in-bank adder tree
        // consumes a 16-value burst per cycle it arrives.
        let bursts_per_bank = rows_per_bank as u64 * (cols as u64).div_ceil(16);
        let cols_per_row = self.cfg.hbm.cols_per_row() as u64;
        vec![
            MacroOp::WeightStream {
                groups: 1,
                rows_per_group: bursts_per_bank.div_ceil(cols_per_row).max(1),
                cols_per_row,
                reload_every: 16,
                phase: Phase::Ffn,
            },
            // Results are written back per bank; the host gathers them
            // over the channel IO (no C-ALU on this device).
            MacroOp::Broadcast {
                bursts_per_bank: (rows_per_bank as u64).div_ceil(16).max(1),
                phase: Phase::DataMovement,
            },
        ]
    }

    /// Cycle count of one GEMV.
    pub fn gemv_cycles(&self, rows: usize, cols: usize) -> u64 {
        let mut engine = PimEngine::new(&self.cfg);
        engine
            .execute(&self.gemv_ops(rows, cols))
            .expect("bank-level gemv")
            .cycles
    }

    /// Full stats of one GEMV.
    pub fn gemv_stats(&self, rows: usize, cols: usize) -> Stats {
        let mut engine = PimEngine::new(&self.cfg);
        engine
            .execute(&self.gemv_ops(rows, cols))
            .expect("bank-level gemv")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapper::map_gemv;
    use crate::pim::PimEngine;

    fn sal_gemv_cycles(cfg: &SimConfig, n: usize) -> u64 {
        let mut e = PimEngine::new(cfg);
        e.execute(&map_gemv(cfg, n, n, Phase::Ffn)).unwrap().cycles
    }

    #[test]
    fn salpim_beats_banklevel_on_large_gemv() {
        // Fig. 12: speedup approaches the 4× bandwidth gain for large
        // vectors.
        let cfg = SimConfig::paper();
        let bank = BankLevelPim::new(&cfg);
        let n = 8192;
        let speedup = bank.gemv_cycles(n, n) as f64 / sal_gemv_cycles(&cfg, n) as f64;
        assert!(speedup > 2.5 && speedup < 4.5, "speedup {speedup}");
    }

    #[test]
    fn small_gemv_speedup_degrades() {
        // Fig. 12: minimum ≈1.75× for small vectors (accumulation
        // overhead) — the gap must shrink relative to large vectors.
        // Fig. 12's range starts at GPT-2 medium's d = 1024 ("In the
        // GPT-2 medium model, the vector length is only 1,024").
        let cfg = SimConfig::paper();
        let bank = BankLevelPim::new(&cfg);
        let small = bank.gemv_cycles(1024, 1024) as f64 / sal_gemv_cycles(&cfg, 1024) as f64;
        let large = bank.gemv_cycles(8192, 8192) as f64 / sal_gemv_cycles(&cfg, 8192) as f64;
        assert!(small < large, "small {small} !< large {large}");
        assert!(small > 1.2, "SAL-PIM must still win: {small}");
    }

    #[test]
    fn banklevel_traffic_covers_matrix() {
        let cfg = SimConfig::paper();
        let bank = BankLevelPim::new(&cfg);
        let st = bank.gemv_stats(1024, 1024);
        let device_bytes = st.internal_bytes * cfg.hbm.pseudo_channels() as u64;
        assert!(device_bytes >= 1024 * 1024 * 2);
    }
}
