//! Analytic GPU baseline: Nvidia Titan RTX running FasterTransformer.
//!
//! The paper compares against a physical Titan RTX (672 GB/s GDDR6,
//! 130 TFLOPS fp16 tensor cores) running the GPT-2 medium model. We
//! rebuild that baseline as a roofline model with per-kernel launch
//! overheads, **calibrated to the paper's own published behaviour**:
//!
//! * Fig. 1 — decode time grows linearly with output size, is nearly
//!   flat in input size, and the absolute scale makes SAL-PIM's best
//!   case (in=32, out=128) a 4.72× win;
//! * Fig. 3 — decode-time breakdown ≈ MHA 50 % / FFN 29 % / nonlinear
//!   23 % (the attention path is launch- and small-kernel-bound at
//!   batch 1, which is why MHA costs more than its weight bytes imply).
//!
//! Calibration constants are grouped in [`GpuModel::titan_rtx`] and
//! documented in DESIGN.md (substitution table).

use crate::config::ModelConfig;

/// Per-phase GPU time of one decode iteration (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct GpuBreakdown {
    pub mha: f64,
    pub ffn: f64,
    pub nonlinear: f64,
    pub other: f64,
}

impl GpuBreakdown {
    pub fn total(&self) -> f64 {
        self.mha + self.ffn + self.nonlinear + self.other
    }
}

/// Roofline + launch-overhead GPU model.
#[derive(Debug, Clone)]
pub struct GpuModel {
    /// Peak memory bandwidth (bytes/s).
    pub mem_bw: f64,
    /// Card DRAM size in bytes (bounds the KV working set when the model
    /// serves as an execution backend).
    pub mem_bytes: usize,
    /// Achieved fraction of peak bandwidth on weight-streaming GEMV.
    pub bw_eff: f64,
    /// Peak fp16 tensor throughput (FLOP/s).
    pub peak_flops: f64,
    /// Achieved fraction of peak on batched GEMM (prefill).
    pub flops_eff: f64,
    /// Fixed cost per kernel launch (s).
    pub kernel_launch: f64,
    /// Fused kernels per decoder layer in the decode path
    /// (FasterTransformer: QKV, attention, proj, 2×FFN, 2×LN + misc).
    pub kernels_per_layer: f64,
    /// Extra per-layer attention overhead at batch 1 (small-kernel and
    /// softmax inefficiency), per KV token (s).
    pub attn_per_kv_token: f64,
    /// Fixed per-layer attention overhead (s).
    pub attn_fixed: f64,
    /// Non-linear (softmax/LN/GELU) kernel cost per layer (s).
    pub nonlinear_per_layer: f64,
}

impl GpuModel {
    /// The calibrated Titan RTX + FasterTransformer baseline.
    pub fn titan_rtx() -> Self {
        GpuModel {
            mem_bw: 672e9,
            mem_bytes: 24 << 30, // 24 GB GDDR6
            bw_eff: 0.78,
            peak_flops: 130e12,
            flops_eff: 0.30,
            kernel_launch: 3.0e-6,
            kernels_per_layer: 8.0,
            attn_per_kv_token: 1.5e-8,
            attn_fixed: 11.0e-6,
            nonlinear_per_layer: 15.0e-6,
        }
    }

    /// Effective achieved bandwidth.
    pub fn eff_bw(&self) -> f64 {
        self.mem_bw * self.bw_eff
    }

    /// The KV-independent, batch-invariant part of one decode step: the
    /// full weight stream (QKV/proj, FFN, LM head) plus the fused
    /// per-layer kernels. A batched decode step pays this once — every
    /// request in the batch consumes the same weight tiles.
    pub fn decode_shared_time(&self, m: &ModelConfig) -> f64 {
        let d = m.d_model as f64;
        let layers = m.n_layers as f64;
        // Weight-streaming GEMV time per layer (memory-bound at batch 1).
        let mha_weights = 4.0 * d * d * m.param_bytes as f64;
        let ffn_weights = 2.0 * d * m.d_ff as f64 * m.param_bytes as f64;
        let launches = self.kernel_launch * self.kernels_per_layer;
        let mha_stream = layers * (mha_weights / self.eff_bw() + launches * 0.5);
        let ffn = layers * (ffn_weights / self.eff_bw() + launches * 0.25);
        let nonlinear = layers * (self.nonlinear_per_layer + launches * 0.25);
        // LM head + embedding + sampling.
        let lm_bytes = m.vocab as f64 * d * m.param_bytes as f64;
        let other = lm_bytes / self.eff_bw() + 4.0 * self.kernel_launch;
        mha_stream + ffn + nonlinear + other
    }

    /// The per-request attention work of one decode step at a KV length:
    /// K/V streaming plus the batch-1 small-kernel and softmax
    /// overheads. Accumulates across a batched step — each request's KV
    /// rows live in different memory.
    pub fn decode_attention_time(&self, m: &ModelConfig, kv_len: usize) -> f64 {
        let d = m.d_model as f64;
        let layers = m.n_layers as f64;
        let kv_bytes = 2.0 * kv_len as f64 * d * m.param_bytes as f64;
        layers
            * (kv_bytes / self.eff_bw()
                + self.attn_fixed
                + self.attn_per_kv_token * kv_len as f64)
    }

    /// Per-phase time of one decode iteration at a KV length. Built from
    /// [`GpuModel::decode_shared_time`] + [`GpuModel::decode_attention_time`]
    /// so the single-request and batched costs cannot drift.
    pub fn decode_breakdown(&self, m: &ModelConfig, kv_len: usize) -> GpuBreakdown {
        let d = m.d_model as f64;
        let layers = m.n_layers as f64;
        let mha_weights = 4.0 * d * d * m.param_bytes as f64;
        let ffn_weights = 2.0 * d * m.d_ff as f64 * m.param_bytes as f64;
        let launches = self.kernel_launch * self.kernels_per_layer;

        let mha = layers * (mha_weights / self.eff_bw() + launches * 0.5)
            + self.decode_attention_time(m, kv_len);
        let ffn = layers * (ffn_weights / self.eff_bw() + launches * 0.25);
        let nonlinear = layers * (self.nonlinear_per_layer + launches * 0.25);
        let lm_bytes = m.vocab as f64 * d * m.param_bytes as f64;
        let other = lm_bytes / self.eff_bw() + 4.0 * self.kernel_launch;
        GpuBreakdown {
            mha,
            ffn,
            nonlinear,
            other,
        }
    }

    /// One decode-iteration latency.
    pub fn decode_token_time(&self, m: &ModelConfig, kv_len: usize) -> f64 {
        self.decode_breakdown(m, kv_len).total()
    }

    /// Summarization-stage latency over `n_in` tokens (batched GEMMs:
    /// compute-bound, weights read once).
    pub fn prefill_time(&self, m: &ModelConfig, n_in: usize) -> f64 {
        let flops = m.flops_per_token(n_in / 2) as f64 * n_in as f64;
        let t_flops = flops / (self.peak_flops * self.flops_eff);
        let weight_bytes = (m.total_params() * m.param_bytes) as f64;
        let t_mem = weight_bytes / self.eff_bw();
        let launches =
            self.kernel_launch * self.kernels_per_layer * m.n_layers as f64 + 4.0 * self.kernel_launch;
        t_flops.max(t_mem) + launches + m.n_layers as f64 * self.nonlinear_per_layer
    }

    /// Full text-generation latency: prefill + `n_out − 1` decode
    /// iterations with growing KV (the first output token comes from the
    /// summarization stage, mirroring the PIM simulator's accounting).
    pub fn generation_time(&self, m: &ModelConfig, n_in: usize, n_out: usize) -> f64 {
        let mut t = self.prefill_time(m, n_in);
        for i in 1..n_out {
            let kv = n_in + i;
            if kv >= m.max_seq {
                break;
            }
            t += self.decode_token_time(m, kv);
        }
        t
    }
}

impl Default for GpuModel {
    fn default() -> Self {
        Self::titan_rtx()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn medium() -> ModelConfig {
        ModelConfig::gpt2_medium()
    }

    #[test]
    fn decode_is_memory_bound_scale() {
        // GPT-2 medium decode on Titan RTX: ≥ weights / peak BW ≈ 1.0 ms,
        // ≤ a few ms with overheads.
        let g = GpuModel::titan_rtx();
        let t = g.decode_token_time(&medium(), 64);
        assert!(t > 1.0e-3, "decode {t} s too fast (beats the memory wall)");
        assert!(t < 4.0e-3, "decode {t} s too slow");
    }

    #[test]
    fn fig1_shape_output_linear_input_flat() {
        // Fig. 1: total time grows ~linearly with output size; input
        // size has little impact.
        let g = GpuModel::titan_rtx();
        let m = medium();
        let t64 = g.generation_time(&m, 32, 64);
        let t128 = g.generation_time(&m, 32, 128);
        let ratio = t128 / t64;
        assert!(ratio > 1.7 && ratio < 2.3, "output scaling {ratio}");

        let tin32 = g.generation_time(&m, 32, 64);
        let tin128 = g.generation_time(&m, 128, 64);
        assert!(
            tin128 / tin32 < 1.25,
            "input scaling too strong: {}",
            tin128 / tin32
        );
    }

    #[test]
    fn fig3_breakdown_shape() {
        // Fig. 3: MHA ≈ 50 %, FFN ≈ 29 %, nonlinear ≈ 23 % (of the sum
        // of those categories). Accept ±8 points.
        let g = GpuModel::titan_rtx();
        let b = g.decode_breakdown(&medium(), 700);
        let sum = b.mha + b.ffn + b.nonlinear;
        let mha = b.mha / sum * 100.0;
        let ffn = b.ffn / sum * 100.0;
        let nl = b.nonlinear / sum * 100.0;
        assert!((42.0..58.0).contains(&mha), "mha {mha}%");
        assert!((21.0..37.0).contains(&ffn), "ffn {ffn}%");
        assert!((15.0..31.0).contains(&nl), "nonlinear {nl}%");
    }

    #[test]
    fn prefill_handles_batches_efficiently() {
        // Prefill of 128 tokens must cost far less than 128 decode
        // iterations (the GPU's parallel-input advantage, §2.1).
        let g = GpuModel::titan_rtx();
        let m = medium();
        let prefill = g.prefill_time(&m, 128);
        let decode128: f64 = (1..128).map(|i| g.decode_token_time(&m, i)).sum();
        assert!(prefill < decode128 / 10.0, "prefill {prefill} decode {decode128}");
    }

    #[test]
    fn shared_plus_attention_equals_the_decode_iteration() {
        // The batching decomposition must reproduce the single-request
        // roofline exactly (a batch of one is a plain decode).
        let g = GpuModel::titan_rtx();
        let m = medium();
        for kv in [1usize, 64, 700] {
            let split = g.decode_shared_time(&m) + g.decode_attention_time(&m, kv);
            let total = g.decode_token_time(&m, kv);
            assert!(
                (split - total).abs() < 1e-12 * total,
                "kv={kv}: {split} != {total}"
            );
        }
    }

    #[test]
    fn kv_growth_increases_decode_time() {
        let g = GpuModel::titan_rtx();
        let m = medium();
        assert!(g.decode_token_time(&m, 1000) > g.decode_token_time(&m, 1));
    }
}
