//! Comparison baselines.
//!
//! * [`gpu`] — the Nvidia Titan RTX + FasterTransformer analytic model,
//!   calibrated to the paper's own Fig. 1 execution-time behaviour.
//! * [`banklevel`] — the Newton-style bank-level PIM (§5.4 / Fig. 12).

pub mod banklevel;
pub mod gpu;

pub use banklevel::BankLevelPim;
pub use gpu::GpuModel;
