//! The `forall` property runner with choice-sequence shrinking.

use super::gen::Gen;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Run `prop` against `cases` random inputs. On failure, shrink the choice
/// sequence and panic with the seed + shrunk case for reproduction.
///
/// The seed is derived from the `SALPIM_TEST_SEED` env var if set, so a CI
/// failure can be replayed exactly: `SALPIM_TEST_SEED=1234 cargo test ...`.
pub fn forall(cases: usize, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let seed = std::env::var("SALPIM_TEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5A1_917);
    forall_seeded(seed, cases, prop);
}

/// [`forall`] with an explicit base seed.
pub fn forall_seeded(
    base_seed: u64,
    cases: usize,
    prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe,
) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        let outcome = catch_unwind(AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = outcome {
            let log = g.log.clone();
            let shrunk = shrink(seed, &log, &prop);
            let msg = panic_message(payload.as_ref());
            panic!(
                "property failed (seed={seed}, case={case}/{cases}):\n  {msg}\n  \
                 original draws: {log:?}\n  shrunk draws:   {shrunk:?}\n  \
                 replay with SALPIM_TEST_SEED={base_seed}"
            );
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

/// Does the property still fail when replaying `draws`?
fn fails(seed: u64, draws: &[u64], prop: &(impl Fn(&mut Gen) + std::panic::RefUnwindSafe)) -> bool {
    let mut g = Gen::replaying(seed, draws.to_vec());
    catch_unwind(AssertUnwindSafe(|| prop(&mut g))).is_err()
}

/// Greedy choice-sequence shrinking: repeatedly try halving / zeroing /
/// decrementing individual draws and truncating the tail, keeping any
/// variant that still fails. Bounded effort; returns the smallest failing
/// sequence found.
fn shrink(
    seed: u64,
    draws: &[u64],
    prop: &(impl Fn(&mut Gen) + std::panic::RefUnwindSafe),
) -> Vec<u64> {
    let mut best = draws.to_vec();
    let mut budget = 2000usize;
    let mut improved = true;
    while improved && budget > 0 {
        improved = false;
        // Try truncating the tail (later draws often unused).
        let mut t = best.clone();
        while t.len() > 1 && budget > 0 {
            t.pop();
            budget -= 1;
            if fails(seed, &t, prop) {
                best = t.clone();
                improved = true;
            } else {
                break;
            }
        }
        // Try shrinking each position.
        for i in 0..best.len() {
            if budget == 0 {
                break;
            }
            let original = best[i];
            for candidate in [0, original / 2, original.saturating_sub(1)] {
                if candidate == original {
                    continue;
                }
                let mut v = best.clone();
                v[i] = candidate;
                budget = budget.saturating_sub(1);
                if fails(seed, &v, prop) {
                    best = v;
                    improved = true;
                    break;
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(200, |g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            assert!(a + b <= 200);
        });
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            forall_seeded(1, 500, |g| {
                let x = g.usize_in(0, 1000);
                assert!(x < 900, "x too big: {x}");
            });
        }));
        let err = result.expect_err("property should fail");
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("property failed"), "{msg}");
        assert!(msg.contains("shrunk draws"), "{msg}");
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Fails iff draw >= 500; the shrunk first draw should be the
        // smallest failing value the greedy passes find (≤ original).
        let prop = |g: &mut Gen| {
            let x = g.u64_in(0, 1023);
            assert!(x < 500);
        };
        // Find a failing seed first.
        let mut seed = 0;
        let mut draws = Vec::new();
        for s in 0..100 {
            let mut g = Gen::new(s);
            if catch_unwind(AssertUnwindSafe(|| prop(&mut g))).is_err() {
                seed = s;
                draws = g.log.clone();
                break;
            }
        }
        assert!(!draws.is_empty(), "no failing seed found");
        let shrunk = shrink(seed, &draws, &prop);
        assert!(shrunk[0] % 1024 >= 500);
        assert!(shrunk[0] <= draws[0]);
    }
}
