//! Value generators for the mini property-testing harness.

use super::SplitMix64;

/// A generation context handed to property closures.
///
/// Every drawn value is recorded so the runner can replay and shrink a
/// failing case: shrinking works on the *choice sequence* (à la Hypothesis)
/// — each recorded draw is independently shrunk toward zero and the
/// property re-run with the smaller sequence.
pub struct Gen {
    rng: SplitMix64,
    /// Choice log for the current run (raw u64 draws).
    pub(crate) log: Vec<u64>,
    /// When replaying a shrunk sequence, draws come from here first.
    pub(crate) replay: Vec<u64>,
    pub(crate) replay_pos: usize,
}

impl Gen {
    pub(crate) fn new(seed: u64) -> Self {
        Gen {
            rng: SplitMix64::new(seed),
            log: Vec::new(),
            replay: Vec::new(),
            replay_pos: 0,
        }
    }

    pub(crate) fn replaying(seed: u64, replay: Vec<u64>) -> Self {
        Gen {
            rng: SplitMix64::new(seed),
            log: Vec::new(),
            replay,
            replay_pos: 0,
        }
    }

    fn draw(&mut self) -> u64 {
        let v = if self.replay_pos < self.replay.len() {
            let v = self.replay[self.replay_pos];
            self.replay_pos += 1;
            v
        } else {
            self.rng.next_u64()
        };
        self.log.push(v);
        v
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.draw() % span) as usize
    }

    /// Uniform u64 in `[lo, hi]` (inclusive).
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        let span = (hi - lo).wrapping_add(1);
        if span == 0 {
            return self.draw();
        }
        lo + self.draw() % span
    }

    /// Uniform i64 in `[lo, hi]` (inclusive).
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add((self.draw() % span) as i64)
    }

    /// Uniform i32 in `[lo, hi]` (inclusive).
    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        self.i64_in(lo as i64, hi as i64) as i32
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let unit = (self.draw() >> 11) as f64 / (1u64 << 53) as f64;
        lo + unit * (hi - lo)
    }

    /// Coin flip.
    pub fn bool(&mut self) -> bool {
        self.draw() & 1 == 1
    }

    /// One element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        let i = self.usize_in(0, items.len() - 1);
        &items[i]
    }

    /// A vector of `len ∈ [min_len, max_len]` values drawn by `f`.
    pub fn vec_of<T>(
        &mut self,
        min_len: usize,
        max_len: usize,
        mut f: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let len = self.usize_in(min_len, max_len);
        (0..len).map(|_| f(self)).collect()
    }

    /// A vector of i32 fixed-point raw values in ±`mag`.
    pub fn vec_i32(&mut self, min_len: usize, max_len: usize, mag: i32) -> Vec<i32> {
        self.vec_of(min_len, max_len, |g| g.i32_in(-mag, mag))
    }

    /// Power of two in `[lo, hi]` (both must be powers of two).
    pub fn pow2_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
        let lo_log = lo.trailing_zeros();
        let hi_log = hi.trailing_zeros();
        1 << self.u64_in(lo_log as u64, hi_log as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_are_inclusive() {
        let mut g = Gen::new(1);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let v = g.usize_in(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn i64_handles_negative_ranges() {
        let mut g = Gen::new(2);
        for _ in 0..1000 {
            let v = g.i64_in(-10, -3);
            assert!((-10..=-3).contains(&v));
        }
    }

    #[test]
    fn replay_reproduces_values() {
        let mut g = Gen::new(5);
        let a: Vec<usize> = (0..10).map(|_| g.usize_in(0, 1_000_000)).collect();
        let log = g.log.clone();
        let mut g2 = Gen::replaying(5, log);
        let b: Vec<usize> = (0..10).map(|_| g2.usize_in(0, 1_000_000)).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn pow2_in_is_power_of_two() {
        let mut g = Gen::new(9);
        for _ in 0..100 {
            let v = g.pow2_in(1, 64);
            assert!(v.is_power_of_two() && (1..=64).contains(&v));
        }
    }

    #[test]
    fn vec_of_respects_length_bounds() {
        let mut g = Gen::new(4);
        for _ in 0..100 {
            let v = g.vec_of(2, 7, |g| g.bool());
            assert!((2..=7).contains(&v.len()));
        }
    }
}
