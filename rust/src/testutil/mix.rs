//! Shared request-mix generation.
//!
//! The serving example, the CLI and the benches used to each re-draw the
//! "paper mix" (prompt 16–128, output 8–128, jittered arrivals) from their
//! own `SplitMix64` loops — keeping two consumers aligned meant fragile
//! tricks like drawing-and-discarding a value to keep RNG streams in
//! lockstep. [`RequestMix`] generates the mix once as data, so every
//! consumer (PIM coordinator, batching engine, GPU baseline) sees the
//! identical workload *by construction*.

use super::SplitMix64;

/// One drawn request shape. `jitter` is a uniform [0,1) draw consumers
/// may scale into an inter-arrival gap (or feed into an exponential).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MixItem {
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub jitter: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MixKind {
    /// The historical serving mix: prompt 16–128 (×16), output 8–128
    /// (powers of two) — what `serve_textgen` and `sal-pim serve` draw.
    Paper,
    /// A trimmed mix for tests: prompt 16–64, output 8–32. Keeps the
    /// distinct-KV working set (and so simulation time) small.
    Small,
}

/// Deterministic request-shape stream.
#[derive(Debug, Clone)]
pub struct RequestMix {
    rng: SplitMix64,
    kind: MixKind,
}

impl RequestMix {
    /// The paper serving mix; seed 42 reproduces the historical
    /// `serve_textgen` / `sal-pim serve` workload draw-for-draw.
    pub fn paper(seed: u64) -> Self {
        RequestMix {
            rng: SplitMix64::new(seed),
            kind: MixKind::Paper,
        }
    }

    /// Small mix for fast tests.
    pub fn small(seed: u64) -> Self {
        RequestMix {
            rng: SplitMix64::new(seed),
            kind: MixKind::Small,
        }
    }

    /// Draw the next request shape (three RNG draws, always).
    pub fn next_item(&mut self) -> MixItem {
        let (prompt_len, max_new_tokens) = match self.kind {
            MixKind::Paper => {
                let prompt = 16 + (self.rng.below(8) * 16) as usize;
                let out = 8usize << self.rng.below(5);
                (prompt, out)
            }
            MixKind::Small => {
                let prompt = 16 + (self.rng.below(4) * 16) as usize;
                let out = 8usize << self.rng.below(3);
                (prompt, out)
            }
        };
        let jitter = self.rng.f64_unit();
        MixItem {
            prompt_len,
            max_new_tokens,
            jitter,
        }
    }

    /// Draw `n` shapes.
    pub fn take(&mut self, n: usize) -> Vec<MixItem> {
        (0..n).map(|_| self.next_item()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_mix_matches_legacy_stream() {
        // The legacy loops drew below(8), below(5), f64_unit per request
        // from SplitMix64::new(42); the mix must reproduce that exactly.
        let mut legacy = SplitMix64::new(42);
        let mut mix = RequestMix::paper(42);
        for _ in 0..16 {
            let prompt = 16 + (legacy.below(8) * 16) as usize;
            let out = 8usize << legacy.below(5);
            let jitter = legacy.f64_unit();
            let item = mix.next_item();
            assert_eq!(item.prompt_len, prompt);
            assert_eq!(item.max_new_tokens, out);
            assert_eq!(item.jitter, jitter);
        }
    }

    #[test]
    fn mix_is_deterministic_per_seed() {
        let a = RequestMix::paper(7).take(8);
        let b = RequestMix::paper(7).take(8);
        assert_eq!(a, b);
        let c = RequestMix::paper(8).take(8);
        assert_ne!(a, c);
    }

    #[test]
    fn small_mix_stays_small() {
        let items = RequestMix::small(3).take(100);
        for i in items {
            assert!((16..=64).contains(&i.prompt_len));
            assert!((8..=32).contains(&i.max_new_tokens));
            assert!((0.0..1.0).contains(&i.jitter));
        }
    }
}
