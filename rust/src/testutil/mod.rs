//! Minimal property-based-testing harness.
//!
//! The offline build environment has no `proptest`, so this module supplies
//! the subset the test suite needs: a deterministic PRNG, value generators,
//! and a `forall` runner with integer/vector shrinking. Failures print the
//! seed and the shrunk counterexample.
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath in this
//! // environment; the same property runs in unit tests below.)
//! use sal_pim::testutil::{forall, Gen};
//! forall(100, |g| {
//!     let x = g.usize_in(0, 1000);
//!     assert!(x <= 1000);
//! });
//! ```

mod gen;
mod mix;
mod runner;

pub use gen::Gen;
pub use mix::{MixItem, RequestMix};
pub use runner::{forall, forall_seeded};

/// SplitMix64: tiny, high-quality 64-bit PRNG (public-domain algorithm).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`; `bound` must be nonzero.
    pub fn below(&mut self, bound: u64) -> u64 {
        // Rejection sampling to avoid modulo bias.
        assert!(bound > 0);
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    pub fn f64_unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_in_range() {
        let mut r = SplitMix64::new(3);
        for _ in 0..10_000 {
            let v = r.f64_unit();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = SplitMix64::new(11);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
