//! Functional model of the subarray-level ALU (§4.1, Fig. 7).
//!
//! 16 logical lanes (one per 16-bit operand in a GBL burst), 16 × 32-bit
//! accumulation registers, a writeback shifter, and four operations:
//! element-wise add, element-wise multiply, MAC, and max. The physical
//! implementation shares 8 MACs at 2× clock (§4.1) — functionally
//! invisible, so the model is 16 lanes wide.

use crate::model::fixedpoint::QFormat;

/// Number of logical lanes (operands per GBL burst).
pub const LANES: usize = 16;

/// The S-ALU operation set (table in Fig. 7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SaluOp {
    /// regs[i] = a[i] + b[i] (element-wise add).
    EwAdd,
    /// regs[i] = a[i] × b[i] (element-wise multiply, shift-truncated).
    EwMul,
    /// regs[i] += a[i] × b[i] (multiply-accumulate at 32-bit).
    Mac,
    /// regs[i] = max(regs[i], a[i]) (softmax max-subtraction support).
    Max,
}

/// One S-ALU: 16 lanes of 32-bit accumulators.
#[derive(Debug, Clone)]
pub struct Salu {
    pub regs: [i32; LANES],
    pub q: QFormat,
}

impl Salu {
    pub fn new(q: QFormat) -> Self {
        Salu {
            regs: [0; LANES],
            q,
        }
    }

    /// Clear the accumulators (start of a new output tile).
    pub fn clear(&mut self) {
        self.regs = [0; LANES];
    }

    /// Preload accumulators for max-reduction (−∞ in the raw domain).
    pub fn clear_for_max(&mut self) {
        self.regs = [i16::MIN as i32; LANES];
    }

    /// Execute one operation over a 16-lane memory operand `a` and (for
    /// two-operand ops) broadcast-or-elementwise operand `b`.
    pub fn exec(&mut self, op: SaluOp, a: &[i16; LANES], b: &[i16; LANES]) {
        match op {
            SaluOp::EwAdd => {
                for i in 0..LANES {
                    self.regs[i] = a[i] as i32 + b[i] as i32;
                }
            }
            SaluOp::EwMul => {
                for i in 0..LANES {
                    self.regs[i] = self.q.mul_raw(a[i], b[i]) >> self.q.frac_bits;
                }
            }
            SaluOp::Mac => {
                for i in 0..LANES {
                    self.regs[i] =
                        self.regs[i].saturating_add(self.q.mul_raw(a[i], b[i]));
                }
            }
            SaluOp::Max => {
                for i in 0..LANES {
                    self.regs[i] = self.regs[i].max(a[i] as i32);
                }
            }
        }
    }

    /// MAC with a broadcast scalar operand (the bank-level unit's
    /// single-data feeding method, §4.3): regs[i] += a[i] × x.
    pub fn mac_broadcast(&mut self, a: &[i16; LANES], x: i16) {
        for i in 0..LANES {
            self.regs[i] = self.regs[i].saturating_add(self.q.mul_raw(a[i], x));
        }
    }

    /// Writeback: shift-truncate the 32-bit accumulators to 16-bit
    /// (Fig. 7's right shifters + tri-state buffer onto the GBLs).
    pub fn writeback(&self) -> [i16; LANES] {
        let mut out = [0i16; LANES];
        for i in 0..LANES {
            out[i] = self.q.writeback(self.regs[i]);
        }
        out
    }

    /// Writeback without the fraction shift (for accumulations of already
    /// shifted values, e.g. element-wise results).
    pub fn writeback_raw(&self) -> [i16; LANES] {
        let mut out = [0i16; LANES];
        for i in 0..LANES {
            out[i] = self.regs[i].clamp(i16::MIN as i32, i16::MAX as i32) as i16;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixedpoint::Q8_8;

    fn arr(v: &[f64]) -> [i16; LANES] {
        let mut out = [0i16; LANES];
        for (i, &x) in v.iter().enumerate() {
            out[i] = Q8_8.quantize(x);
        }
        out
    }

    #[test]
    fn mac_accumulates_dot_product() {
        let mut s = Salu::new(Q8_8);
        // Lane 0 accumulates 1·2 + 3·4 = 14.
        s.exec(SaluOp::Mac, &arr(&[1.0]), &arr(&[2.0]));
        s.exec(SaluOp::Mac, &arr(&[3.0]), &arr(&[4.0]));
        let out = s.writeback();
        assert!((Q8_8.dequantize(out[0]) - 14.0).abs() < 0.05);
    }

    #[test]
    fn mac_broadcast_matches_elementwise_mac() {
        let mut a = Salu::new(Q8_8);
        let mut b = Salu::new(Q8_8);
        let w = arr(&[0.5, -1.0, 2.0, 0.25]);
        let x = Q8_8.quantize(1.5);
        a.mac_broadcast(&w, x);
        b.exec(SaluOp::Mac, &w, &[x; LANES]);
        assert_eq!(a.regs, b.regs);
    }

    #[test]
    fn ew_add_and_mul() {
        let mut s = Salu::new(Q8_8);
        s.exec(SaluOp::EwAdd, &arr(&[1.5]), &arr(&[2.5]));
        assert!((Q8_8.dequantize(s.writeback_raw()[0]) - 4.0).abs() < 0.01);
        s.exec(SaluOp::EwMul, &arr(&[1.5]), &arr(&[2.0]));
        assert!((Q8_8.dequantize(s.writeback_raw()[0]) - 3.0).abs() < 0.01);
    }

    #[test]
    fn max_tracks_running_maximum() {
        let mut s = Salu::new(Q8_8);
        s.clear_for_max();
        s.exec(SaluOp::Max, &arr(&[-3.0]), &[0; LANES]);
        s.exec(SaluOp::Max, &arr(&[7.0]), &[0; LANES]);
        s.exec(SaluOp::Max, &arr(&[2.0]), &[0; LANES]);
        assert!((Q8_8.dequantize(s.writeback_raw()[0]) - 7.0).abs() < 0.01);
    }

    #[test]
    fn accumulator_saturates_instead_of_wrapping() {
        let mut s = Salu::new(Q8_8);
        let big = [i16::MAX; LANES];
        for _ in 0..100_000 {
            s.exec(SaluOp::Mac, &big, &big);
        }
        assert_eq!(s.regs[0], i32::MAX);
        assert_eq!(s.writeback()[0], i16::MAX);
    }

    #[test]
    fn clear_resets_state() {
        let mut s = Salu::new(Q8_8);
        s.exec(SaluOp::Mac, &arr(&[1.0]), &arr(&[1.0]));
        s.clear();
        assert_eq!(s.regs, [0; LANES]);
    }
}
