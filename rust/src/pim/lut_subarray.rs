//! Functional model of the LUT-embedded subarray (§4.2, Fig. 8/9).
//!
//! Stores the quantized slope/intercept tables for every supported
//! non-linear function and serves the per-MAT column-select reads that
//! make one RD return 16 *different* sections' entries. The timing of the
//! Fig. 9 flow lives in [`crate::pim::engine`]; this model provides the
//! values.

use super::bank_unit::BankUnit;
use super::salu::LANES;
use crate::config::SimConfig;
use crate::interp::{LutTable, NonLinFn};
use crate::model::fixedpoint::{QFormat, Q8_8};
use std::collections::HashMap;

/// The LUT-embedded subarrays of one bank, loaded with every function's
/// table (the paper stores W in one pair of subarrays and B in another;
/// functionally they are per-function tables).
#[derive(Debug, Clone)]
pub struct LutSubarrays {
    tables: HashMap<NonLinFn, LutTable>,
    /// Sections storable per subarray row set (for sub-sel decoding).
    pub sections_per_subarray: usize,
}

impl LutSubarrays {
    /// Build tables for all functions at the configured section count,
    /// with the per-function fixed-point formats the GPT dataflow uses:
    /// GELU/tanh/rsqrt in Q8.8, softmax exp in Q2.13 (values ≤ 1 need
    /// resolution, not range) and the softmax reciprocal in Q0.15.
    pub fn new(cfg: &SimConfig) -> Self {
        use crate::model::fixedpoint::Q2_13;
        let mut tables = HashMap::new();
        for f in NonLinFn::ALL {
            let (q_in, q_out) = match f {
                // exp ≤ 1 and 1/x over [1,2) ∈ (0.5, 1] need resolution,
                // not range (recip intercepts reach ~2, so Q2.13).
                NonLinFn::Exp | NonLinFn::Recip => (Q8_8, Q2_13),
                _ => (Q8_8, Q8_8),
            };
            tables.insert(f, LutTable::build(f, cfg.lut.sections, q_in, q_out));
        }
        let capacity = cfg.hbm.row_bytes / 2;
        LutSubarrays {
            tables,
            sections_per_subarray: capacity.min(cfg.lut.sections),
        }
    }

    /// Uniform-format table set (accuracy sweeps / ablations).
    pub fn with_formats(cfg: &SimConfig, q_in: QFormat, q_out: QFormat) -> Self {
        let mut tables = HashMap::new();
        for f in NonLinFn::ALL {
            tables.insert(f, LutTable::build(f, cfg.lut.sections, q_in, q_out));
        }
        // Half the LUT subarrays hold slopes, half intercepts; each
        // function's table splits across them when it exceeds one row.
        let capacity = cfg.hbm.row_bytes / 2; // 16-bit entries per row
        LutSubarrays {
            tables,
            sections_per_subarray: capacity.min(cfg.lut.sections),
        }
    }

    pub fn table(&self, f: NonLinFn) -> &LutTable {
        &self.tables[&f]
    }

    /// One Fig. 9 sweep over a 16-lane chunk: the bank unit decodes the
    /// sections, the per-MAT column selects fetch W/B, the S-ALU computes
    /// W·x + B. Bit-exact.
    pub fn interpolate_chunk(&self, f: NonLinFn, chunk: &[i16; LANES]) -> [i16; LANES] {
        let table = self.table(f);
        let mut unit = BankUnit::new();
        unit.load(chunk);
        let sections = unit.decode_sections(table);
        let mut out = [0i16; LANES];
        for i in 0..LANES {
            // The gathered W/B entries for lane i's section, then the
            // S-ALU multiply-add — identical to LutTable::eval_raw by
            // construction (asserted in tests).
            let _ = sections[i];
            out[i] = table.eval_raw(chunk[i]);
        }
        out
    }

    /// Interpolate an arbitrary-length raw vector (chunked by 16).
    pub fn interpolate(&self, f: NonLinFn, data: &[i16]) -> Vec<i16> {
        let mut out = Vec::with_capacity(data.len());
        for chunk in data.chunks(LANES) {
            let mut buf = [0i16; LANES];
            buf[..chunk.len()].copy_from_slice(chunk);
            let res = self.interpolate_chunk(f, &buf);
            out.extend_from_slice(&res[..chunk.len()]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    fn luts() -> LutSubarrays {
        LutSubarrays::new(&SimConfig::paper())
    }

    #[test]
    fn chunk_matches_table_eval() {
        let l = luts();
        forall(100, |g| {
            let mut chunk = [0i16; LANES];
            for lane in chunk.iter_mut() {
                *lane = g.i32_in(-2048, 2047) as i16;
            }
            let out = l.interpolate_chunk(NonLinFn::Gelu, &chunk);
            for i in 0..LANES {
                assert_eq!(out[i], l.table(NonLinFn::Gelu).eval_raw(chunk[i]));
            }
        });
    }

    #[test]
    fn vector_interpolation_handles_ragged_tail() {
        let l = luts();
        let data: Vec<i16> = (0..37).map(|i| (i * 50 - 900) as i16).collect();
        let out = l.interpolate(NonLinFn::Tanh, &data);
        assert_eq!(out.len(), 37);
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(out[i], l.table(NonLinFn::Tanh).eval_raw(x));
        }
    }

    #[test]
    fn gelu_vector_accuracy() {
        let l = luts();
        let q = Q8_8;
        let xs: Vec<f64> = (-40..40).map(|i| i as f64 / 5.0).collect();
        let raw: Vec<i16> = xs.iter().map(|&x| q.quantize(x)).collect();
        let out = l.interpolate(NonLinFn::Gelu, &raw);
        for (i, &x) in xs.iter().enumerate() {
            let got = q.dequantize(out[i]);
            let want = NonLinFn::Gelu.eval_exact(x);
            assert!((got - want).abs() < 0.05, "gelu({x}) got {got} want {want}");
        }
    }

    #[test]
    fn all_functions_have_tables() {
        let l = luts();
        for f in NonLinFn::ALL {
            assert_eq!(l.table(f).sections, 64);
        }
    }

    #[test]
    fn sections_fit_one_subarray_at_paper_config() {
        let l = luts();
        assert_eq!(l.sections_per_subarray, 64);
    }
}
