//! The PIM timing engine: lowers macro-ops to DRAM command sequences on
//! the cycle-accurate controller.
//!
//! One engine simulates one pseudo-channel; the paper's mapping gives all
//! pseudo-channels identical command streams (weights are sharded evenly,
//! §3.2), so the engine's clock *is* device time.
//!
//! Two scheduling policies exist for weight streams:
//! * conservative (default): ACT → stream → PRE strictly in order, the
//!   row-transition latency is exposed;
//! * `opt_prefetch`: the next row's ACT is issued to the group's
//!   alternate subarray *while the current row streams* (SALP
//!   double-buffering), hiding tRCD — the §Perf optimization.

use super::isa::{LutMethod, MacroOp};
use crate::config::SimConfig;
use crate::dram::{ChannelController, CmdTarget, DramCmd, TimingError};
use crate::stats::{CmdKind, Stats};

/// Timing engine for one pseudo-channel.
pub struct PimEngine {
    pub cfg: SimConfig,
    pub ctl: ChannelController,
    /// Enable SALP row-prefetch double-buffering in weight streams.
    pub opt_prefetch: bool,
    /// First subarray of each S-ALU group.
    group_base: Vec<usize>,
    /// LUT-embedded subarrays holding slopes (W) and intercepts (B).
    lut_w_su: usize,
    lut_b_su: usize,
    /// Second pair of LUT subarrays (the paper provisions four; the
    /// Select fallback alternates pairs to dodge tCCDL serialization).
    lut_w2_su: usize,
    lut_b2_su: usize,
    /// Scratch subarrays for intermediate vectors (io / elementwise).
    io_su: [usize; 4],
    /// Row cursor per subarray (synthetic placement for timing runs).
    row_cursor: Vec<usize>,
    rows_per_subarray: usize,
}

impl PimEngine {
    pub fn new(cfg: &SimConfig) -> Self {
        let gs = cfg.subarrays_per_group();
        assert!(gs >= 6, "subarray group too small: {gs}");
        let n_su = cfg.hbm.subarrays_per_bank;
        let n_lut = cfg.lut.num_lut_subarrays;
        let group_base: Vec<usize> = (0..cfg.salu.max_p_sub).map(|g| g * gs).collect();
        PimEngine {
            ctl: ChannelController::new(cfg),
            opt_prefetch: false,
            group_base,
            lut_w_su: n_su - n_lut,
            lut_b_su: n_su - n_lut + 1,
            lut_w2_su: n_su - n_lut + 2.min(n_lut - 1),
            lut_b2_su: n_su - n_lut + 3.min(n_lut - 1),
            // Scratch vectors live at the top of group 0's range so they
            // never collide with the double-buffer subarrays (base, base+1).
            io_su: [gs - 1, gs - 2, gs - 3, gs - 4],
            row_cursor: vec![0; n_su],
            rows_per_subarray: cfg.hbm.rows_per_subarray,
            cfg: cfg.clone(),
        }
    }

    /// Reset timing state between measurement runs.
    pub fn reset(&mut self) {
        self.ctl.reset();
        self.row_cursor.iter_mut().for_each(|r| *r = 0);
    }

    fn next_row(&mut self, su: usize) -> usize {
        let r = self.row_cursor[su];
        self.row_cursor[su] = (r + 1) % self.rows_per_subarray;
        r
    }

    /// Execute a macro-op stream; per-op cycles are attributed to the
    /// op's phase. Returns the accumulated statistics (cycles = total
    /// elapsed pseudo-channel time including final data drain).
    pub fn execute(&mut self, ops: &[MacroOp]) -> Result<Stats, TimingError> {
        let mut stats = Stats::new();
        let setup = self.cfg.timing.pim_op_setup as i64;
        for op in ops {
            let before = self.ctl.clock;
            // FIM/AiM-style macro-command setup: the host controller
            // issues mode switches + operand descriptors per PIM op.
            self.ctl.clock += setup;
            self.exec_op(*op, &mut stats)?;
            let delta = (self.ctl.clock - before).max(0) as u64;
            stats.add_phase_cycles(op.phase(), delta);
        }
        // Drain: the last column command's data is still in flight.
        let drain = (self.cfg.timing.t_cl + self.cfg.timing.burst_cycles()) as u64;
        if let Some(op) = ops.last() {
            stats.add_phase_cycles(op.phase(), drain);
        }
        // Refresh: tRFC stolen every tREFI, amortized over the run and
        // attributed to data movement.
        let refresh = (stats.cycles as f64 * self.cfg.timing.refresh_overhead()) as u64;
        if refresh > 0 {
            self.ctl.clock += refresh as i64;
            stats.add_phase_cycles(crate::stats::Phase::DataMovement, refresh);
        }
        Ok(stats)
    }

    fn exec_op(&mut self, op: MacroOp, stats: &mut Stats) -> Result<(), TimingError> {
        match op {
            MacroOp::WeightStream {
                groups,
                rows_per_group,
                cols_per_row,
                reload_every,
                ..
            } => self.weight_stream(groups, rows_per_group, cols_per_row, reload_every, stats),
            MacroOp::LutSweep {
                elems_per_bank,
                method,
                sections,
                ..
            } => self.lut_sweep(elems_per_bank, method, sections, stats),
            MacroOp::CaluAccumulate { chunks, banks, .. } => {
                self.calu_transfer(chunks, banks, false, stats);
                Ok(())
            }
            MacroOp::CaluReduce { chunks, banks, .. } => {
                self.calu_transfer(chunks, banks, true, stats);
                Ok(())
            }
            MacroOp::Broadcast { bursts_per_bank, .. } => self.broadcast(bursts_per_bank, stats),
            MacroOp::Elementwise {
                elems_per_bank,
                n_operands,
                ..
            } => self.elementwise(elems_per_bank, n_operands, stats),
            MacroOp::ChannelReshape { bytes, .. } => {
                // One 32 B flit per cycle over the buffer-die interconnect
                // plus a fixed hop latency.
                let cycles = bytes.div_ceil(32) + 20;
                self.ctl.clock += cycles as i64;
                stats.external_bytes += bytes;
                Ok(())
            }
            MacroOp::Sync { cycles, .. } => {
                self.ctl.clock += cycles as i64;
                Ok(())
            }
        }
    }

    /// §3.1 hot loop: `groups` S-ALU groups stream weight rows in
    /// lockstep, MACs hidden under the column cadence.
    fn weight_stream(
        &mut self,
        groups: usize,
        rows_per_group: u64,
        cols_per_row: u64,
        reload_every: u64,
        stats: &mut Stats,
    ) -> Result<(), TimingError> {
        assert!(groups >= 1 && groups <= self.group_base.len());
        assert!(cols_per_row <= self.cfg.hbm.cols_per_row() as u64);
        let all = CmdTarget::AllBanks;
        // Conservative path double-buffers two subarrays per group; the
        // prefetch path triple-buffers so the prefetched ACT's target was
        // precharged two rows ago (no tRP stall on the command).
        let bufs = if self.opt_prefetch { 3 } else { 2 };
        let su_of = |engine: &Self, g: usize, r: u64| -> usize {
            engine.group_base[g] + (r % bufs) as usize
        };
        // Activate row 0 of every group.
        for g in 0..groups {
            let su = su_of(self, g, 0);
            let row = self.next_row(su);
            self.ctl.issue(
                DramCmd::Act {
                    target: all,
                    subarray: su,
                    row,
                },
                stats,
            )?;
        }
        for r in 0..rows_per_group {
            let sus: Vec<usize> = (0..groups).map(|g| su_of(self, g, r)).collect();
            if self.opt_prefetch && r + 1 < rows_per_group {
                // Issue next row's ACTs before streaming: tRCD hides
                // under the current stream (different subarray).
                for g in 0..groups {
                    let su = su_of(self, g, r + 1);
                    let row = self.next_row(su);
                    self.ctl.issue(
                        DramCmd::Act {
                            target: all,
                            subarray: su,
                            row,
                        },
                        stats,
                    )?;
                }
            }
            // Stream the row, stalling one bus slot per input-register
            // reload (the bank-level unit fetches the next 16 input
            // values from the C-ALU broadcast path).
            if reload_every == 0 || reload_every >= cols_per_row {
                self.ctl.stream_interleaved(&sus, cols_per_row, false, stats)?;
                if reload_every != 0 {
                    stats.count_cmd(CmdKind::PimOp, 1);
                    self.ctl.clock += 1;
                }
            } else {
                let mut done = 0;
                while done < cols_per_row {
                    let seg = reload_every.min(cols_per_row - done);
                    stats.count_cmd(CmdKind::PimOp, 1);
                    self.ctl.clock += 1; // register-load command slot
                    self.ctl.stream_interleaved(&sus, seg, false, stats)?;
                    done += seg;
                }
            }
            // Close the streamed row; activate the next one (conservative
            // path only — prefetch already activated it).
            for (g, &su) in sus.iter().enumerate() {
                self.ctl.issue(
                    DramCmd::Pre {
                        target: all,
                        subarray: su,
                    },
                    stats,
                )?;
                if !self.opt_prefetch && r + 1 < rows_per_group {
                    let nsu = su_of(self, g, r + 1);
                    let row = self.next_row(nsu);
                    self.ctl.issue(
                        DramCmd::Act {
                            target: all,
                            subarray: nsu,
                            row,
                        },
                        stats,
                    )?;
                }
            }
        }
        // MAC micro-ops executed: one per lane per burst.
        let bursts = groups as u64 * rows_per_group * cols_per_row;
        stats.count_cmd(CmdKind::PimOp, bursts * self.cfg.salu.lanes as u64);
        Ok(())
    }

    /// Fig. 9 LUT-embedded-subarray sweep (or the Fig. 13 fallbacks).
    fn lut_sweep(
        &mut self,
        elems_per_bank: u64,
        method: LutMethod,
        sections: usize,
        stats: &mut Stats,
    ) -> Result<(), TimingError> {
        if elems_per_bank == 0 {
            return Ok(());
        }
        let all = CmdTarget::AllBanks;
        let lanes = 16u64;
        let elems_per_row = (self.cfg.hbm.row_bytes / 2) as u64; // 16-bit elems
        let mut remaining = elems_per_bank;
        while remaining > 0 {
            let batch = remaining.min(elems_per_row);
            remaining -= batch;
            let chunks = batch.div_ceil(lanes);
            let (src, dst) = (self.io_su[0], self.io_su[1]);
            // ACT source, destination, W and B rows (Fig. 9 step 1); the
            // Select fallback additionally opens the second LUT pair.
            let mut act_list = vec![src, dst, self.lut_w_su, self.lut_b_su];
            if method == LutMethod::Select {
                for su in [self.lut_w2_su, self.lut_b2_su] {
                    if !act_list.contains(&su) {
                        act_list.push(su);
                    }
                }
            }
            for &su in &act_list {
                let row = self.next_row(su);
                self.ctl.issue(
                    DramCmd::Act {
                        target: all,
                        subarray: su,
                        row,
                    },
                    stats,
                )?;
            }
            match method {
                LutMethod::Embedded => {
                    // Per 16-element chunk: RD src / RD W / RD B / WR dst.
                    // Four distinct subarrays → the bus sustains one
                    // command per cycle with tCCDL satisfied per subarray.
                    for c in 0..chunks {
                        self.ctl.stream_interleaved(
                            &[src, self.lut_w_su, self.lut_b_su],
                            1,
                            false,
                            stats,
                        )?;
                        let _ = c;
                        self.ctl.stream_interleaved(&[dst], 1, true, stats)?;
                    }
                    stats.count_cmd(CmdKind::PimOp, chunks * lanes);
                }
                LutMethod::Select => {
                    // Decode each element and fetch its W/B individually;
                    // consecutive elements' sections land in alternating
                    // LUT subarray pairs, so reads pipeline at bus rate.
                    for _ in 0..chunks {
                        self.ctl.stream_interleaved(&[src], 1, false, stats)?;
                        for e in 0..lanes {
                            let (w, b) = if e % 2 == 0 {
                                (self.lut_w_su, self.lut_b_su)
                            } else {
                                (self.lut_w2_su, self.lut_b2_su)
                            };
                            self.ctl.stream_interleaved(&[w, b], 1, false, stats)?;
                        }
                        self.ctl.stream_interleaved(&[dst], 1, true, stats)?;
                    }
                    stats.count_cmd(CmdKind::PimOp, chunks * lanes);
                }
                LutMethod::Scan => {
                    // Stream the whole W/B region past the register for
                    // every chunk; the S-ALU compare/select of 16 lanes ×
                    // 16 scanned entries per burst is MAC-rate-bound.
                    let region_bursts = 2 * (sections as u64).div_ceil(lanes);
                    let compare_cycles_per_burst =
                        (lanes * lanes) / (2 * self.cfg.salu.macs_per_salu as u64);
                    for _ in 0..chunks {
                        self.ctl.stream_interleaved(&[src], 1, false, stats)?;
                        for _ in 0..region_bursts {
                            self.ctl.stream_interleaved(
                                &[self.lut_w_su, self.lut_b_su],
                                1,
                                false,
                                stats,
                            )?;
                            // Compute-bound select stalls the stream.
                            self.ctl.clock += compare_cycles_per_burst as i64;
                        }
                        self.ctl.stream_interleaved(&[dst], 1, true, stats)?;
                    }
                    stats.count_cmd(CmdKind::PimOp, chunks * lanes * sections as u64);
                }
            }
            // Fig. 9 step 4: precharge everything we opened.
            for &su in &act_list {
                self.ctl.issue(
                    DramCmd::Pre {
                        target: all,
                        subarray: su,
                    },
                    stats,
                )?;
            }
        }
        Ok(())
    }

    /// S-ALU-register → TSV → C-ALU transfers: `chunks` 16-lane chunks
    /// from each of `banks` banks, accumulated (or reduce-summed) by the
    /// configurable adders. Transfers ride the shared channel IO at the
    /// tCCDS cadence; the adder tree is pipelined behind it.
    fn calu_transfer(&mut self, chunks: u64, banks: usize, reduce: bool, stats: &mut Stats) {
        let t_ccds = self.cfg.timing.t_ccds as i64;
        let n = chunks * banks as u64;
        self.ctl.clock += n as i64 * t_ccds;
        if reduce {
            // Adder-tree latency + scalar broadcast command.
            self.ctl.clock += self.cfg.calu.tree_depth() as i64 + 1;
        } else {
            // Accumulator writeback latency (pipelined; pay once).
            self.ctl.clock += 1;
        }
        stats.count_cmd(CmdKind::CaluOp, n);
        stats.external_bytes += n * 32;
    }

    /// All-bank WR stream of input/intermediate data into every bank.
    fn broadcast(&mut self, bursts_per_bank: u64, stats: &mut Stats) -> Result<(), TimingError> {
        let all = CmdTarget::AllBanks;
        let cols = self.cfg.hbm.cols_per_row() as u64;
        let mut remaining = bursts_per_bank;
        while remaining > 0 {
            let batch = remaining.min(cols);
            remaining -= batch;
            let su = self.io_su[2];
            let row = self.next_row(su);
            self.ctl.issue(
                DramCmd::Act {
                    target: all,
                    subarray: su,
                    row,
                },
                stats,
            )?;
            self.ctl.stream_cols(all, su, batch, true, stats)?;
            self.ctl.issue(
                DramCmd::Pre {
                    target: all,
                    subarray: su,
                },
                stats,
            )?;
        }
        Ok(())
    }

    /// Element-wise S-ALU pass: `n_operands` reads + one write per
    /// 16-lane chunk, on distinct scratch subarrays.
    fn elementwise(
        &mut self,
        elems_per_bank: u64,
        n_operands: u32,
        stats: &mut Stats,
    ) -> Result<(), TimingError> {
        if elems_per_bank == 0 {
            return Ok(());
        }
        let all = CmdTarget::AllBanks;
        let lanes = 16u64;
        let n_ops = (n_operands as usize).clamp(1, 3);
        let elems_per_row = (self.cfg.hbm.row_bytes / 2) as u64;
        let mut remaining = elems_per_bank;
        while remaining > 0 {
            let batch = remaining.min(elems_per_row);
            remaining -= batch;
            let chunks = batch.div_ceil(lanes);
            let reads: Vec<usize> = self.io_su[..n_ops].to_vec();
            let dst = self.io_su[3];
            for &su in reads.iter().chain(std::iter::once(&dst)) {
                let row = self.next_row(su);
                self.ctl.issue(
                    DramCmd::Act {
                        target: all,
                        subarray: su,
                        row,
                    },
                    stats,
                )?;
            }
            for _ in 0..chunks {
                self.ctl.stream_interleaved(&reads, 1, false, stats)?;
                self.ctl.stream_interleaved(&[dst], 1, true, stats)?;
            }
            stats.count_cmd(CmdKind::PimOp, chunks * lanes);
            for &su in reads.iter().chain(std::iter::once(&dst)) {
                self.ctl.issue(
                    DramCmd::Pre {
                        target: all,
                        subarray: su,
                    },
                    stats,
                )?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Phase;

    fn engine() -> PimEngine {
        PimEngine::new(&SimConfig::paper())
    }

    fn ws(groups: usize, rows: u64, cols: u64) -> MacroOp {
        MacroOp::WeightStream {
            groups,
            rows_per_group: rows,
            cols_per_row: cols,
            reload_every: 0,
            phase: Phase::Ffn,
        }
    }

    #[test]
    fn weight_stream_basic_cycle_count() {
        let mut e = engine();
        let st = e.execute(&[ws(4, 2, 32)]).unwrap();
        // 4 groups × 2 rows × 32 cols = 256 bursts; bus-bound ≈ 1/cycle
        // plus ACT/PRE/tRCD overheads.
        assert!(st.cycles >= 256, "cycles {}", st.cycles);
        assert!(st.cycles < 500, "cycles {}", st.cycles);
        assert_eq!(st.commands[&CmdKind::Rd], 256 * 16);
        // 256 bursts × 16 banks × 32 B
        assert_eq!(st.internal_bytes, 256 * 16 * 32);
    }

    #[test]
    fn psub_scaling_speeds_up_streams() {
        // Same total bursts, 4 groups vs 1 group: ≈4× faster (§6.2).
        let mut e4 = engine();
        let t4 = e4.execute(&[ws(4, 8, 32)]).unwrap().cycles;
        let mut e1 = engine();
        let t1 = e1.execute(&[ws(1, 32, 32)]).unwrap().cycles;
        let ratio = t1 as f64 / t4 as f64;
        assert!(ratio > 3.0 && ratio < 4.5, "ratio {ratio}");
    }

    #[test]
    fn prefetch_hides_row_transitions() {
        let op = ws(4, 16, 32);
        let mut cons = engine();
        let t_cons = cons.execute(&[op]).unwrap().cycles;
        let mut pre = engine();
        pre.opt_prefetch = true;
        let t_pre = pre.execute(&[op]).unwrap().cycles;
        assert!(t_pre < t_cons, "prefetch {t_pre} !< conservative {t_cons}");
    }

    #[test]
    fn achieved_bandwidth_near_peak_at_psub4() {
        // A long 4-group stream should achieve ≳70 % of the 8 TB/s peak
        // even on the conservative schedule.
        let cfg = SimConfig::paper();
        let mut e = engine();
        let st = e.execute(&[ws(4, 64, 32)]).unwrap();
        // Engine simulates one pseudo-channel; scale traffic to device.
        let device_bytes = st.internal_bytes * cfg.hbm.pseudo_channels() as u64;
        let bw = device_bytes as f64 / st.seconds(cfg.timing.tck_ns);
        let peak = cfg.peak_internal_bandwidth();
        assert!(bw / peak > 0.7, "bw {:.2} TB/s vs peak {:.2} TB/s", bw / 1e12, peak / 1e12);
        assert!(bw <= peak * 1.001);
    }

    #[test]
    fn lut_methods_rank_as_fig13() {
        let n = 1024; // elements per bank
        let run = |method| {
            let mut e = engine();
            e.execute(&[MacroOp::LutSweep {
                elems_per_bank: n,
                method,
                sections: 64,
                phase: Phase::NonLinear,
            }])
            .unwrap()
            .cycles
        };
        let emb = run(LutMethod::Embedded);
        let sel = run(LutMethod::Select);
        let scan = run(LutMethod::Scan);
        assert!(emb < sel && sel < scan, "emb={emb} sel={sel} scan={scan}");
        // Fig. 13: LUT-embedded wins over the best alternative at large
        // sizes (paper: 3.57×; our Select model lands somewhat higher
        // because each element pays two serialized LUT fetches).
        let ratio = sel as f64 / emb as f64;
        assert!(ratio > 2.5 && ratio < 10.0, "select/embedded = {ratio}");
    }

    #[test]
    fn lut_sweep_counts_pim_ops() {
        let mut e = engine();
        let st = e
            .execute(&[MacroOp::LutSweep {
                elems_per_bank: 256,
                method: LutMethod::Embedded,
                sections: 64,
                phase: Phase::NonLinear,
            }])
            .unwrap();
        assert_eq!(st.commands[&CmdKind::PimOp], 256);
        assert!(st.commands[&CmdKind::Wr] > 0);
    }

    #[test]
    fn calu_costs_scale_with_chunks_and_banks() {
        let mut e = engine();
        let small = e
            .execute(&[MacroOp::CaluAccumulate {
                chunks: 4,
                banks: 16,
                phase: Phase::DataMovement,
            }])
            .unwrap()
            .cycles;
        let mut e2 = engine();
        let big = e2
            .execute(&[MacroOp::CaluAccumulate {
                chunks: 16,
                banks: 16,
                phase: Phase::DataMovement,
            }])
            .unwrap()
            .cycles;
        assert!(big > small * 2, "big={big} small={small}");
    }

    #[test]
    fn broadcast_spans_rows() {
        let mut e = engine();
        // 64 bursts = 2 rows worth of broadcast.
        let st = e
            .execute(&[MacroOp::Broadcast {
                bursts_per_bank: 64,
                phase: Phase::DataMovement,
            }])
            .unwrap();
        assert_eq!(st.commands[&CmdKind::Wr], 64 * 16);
        assert_eq!(st.commands[&CmdKind::Act], 2 * 16);
    }

    #[test]
    fn elementwise_residual_costs_two_reads() {
        let mut e1 = engine();
        let one = e1
            .execute(&[MacroOp::Elementwise {
                elems_per_bank: 512,
                n_operands: 1,
                phase: Phase::Residual,
            }])
            .unwrap()
            .cycles;
        let mut e2 = engine();
        let two = e2
            .execute(&[MacroOp::Elementwise {
                elems_per_bank: 512,
                n_operands: 2,
                phase: Phase::Residual,
            }])
            .unwrap()
            .cycles;
        assert!(two > one, "two={two} one={one}");
    }

    #[test]
    fn phase_attribution_covers_all_cycles() {
        let mut e = engine();
        let ops = [
            ws(4, 2, 32),
            MacroOp::LutSweep {
                elems_per_bank: 64,
                method: LutMethod::Embedded,
                sections: 64,
                phase: Phase::NonLinear,
            },
            MacroOp::CaluReduce {
                chunks: 1,
                banks: 16,
                phase: Phase::DataMovement,
            },
        ];
        let st = e.execute(&ops).unwrap();
        let sum: u64 = st.phase_cycles.values().sum();
        assert_eq!(sum, st.cycles);
        assert!(st.phase_cycles.contains_key(&Phase::Ffn));
        assert!(st.phase_cycles.contains_key(&Phase::NonLinear));
    }

    #[test]
    fn reload_stalls_add_bus_slots() {
        let mut a = engine();
        let no_reload = a.execute(&[ws(4, 4, 32)]).unwrap().cycles;
        let mut b = engine();
        let with_reload = b
            .execute(&[MacroOp::WeightStream {
                groups: 4,
                rows_per_group: 4,
                cols_per_row: 32,
                reload_every: 8,
                phase: Phase::Ffn,
            }])
            .unwrap()
            .cycles;
        assert!(with_reload > no_reload);
    }

    #[test]
    fn reset_allows_reuse() {
        let mut e = engine();
        let a = e.execute(&[ws(2, 2, 16)]).unwrap().cycles;
        e.reset();
        let b = e.execute(&[ws(2, 2, 16)]).unwrap().cycles;
        assert_eq!(a, b);
    }

    #[test]
    fn sync_and_reshape_advance_clock() {
        let mut e = engine();
        let st = e
            .execute(&[
                MacroOp::Sync {
                    cycles: 100,
                    phase: Phase::DataMovement,
                },
                MacroOp::ChannelReshape {
                    bytes: 2048,
                    phase: Phase::DataMovement,
                },
            ])
            .unwrap();
        assert!(st.cycles >= 100 + 64 + 20);
        assert_eq!(st.external_bytes, 2048);
    }
}
