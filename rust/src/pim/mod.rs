//! The SAL-PIM processing-in-memory layer.
//!
//! * [`isa`] — the macro-op instruction set the mapper emits and the
//!   engine executes (weight streams, LUT sweeps, C-ALU merges, …).
//! * [`engine`] — the timing engine: executes macro-op streams against the
//!   cycle-accurate [`crate::dram::ChannelController`].
//! * [`salu`] — functional model of the subarray-level ALU (§4.1).
//! * [`bank_unit`] — functional model of the bank-level unit (§4.3).
//! * [`calu`] — functional model of the channel-level ALU (§4.4).
//! * [`lut_subarray`] — the LUT-embedded subarray (§4.2) including the
//!   Fig. 13 alternative access methods (Scan / Select).

pub mod bank_unit;
pub mod calu;
pub mod engine;
pub mod isa;
pub mod lut_subarray;
pub mod salu;

pub use engine::PimEngine;
pub use isa::{LutMethod, MacroOp};
