//! Functional model of the channel-level ALU (§4.4, Fig. 10).
//!
//! Two 16-lane vector registers, two 16-bit scalar registers, and sixteen
//! configurable adders acting either as a per-lane accumulator or as an
//! adder tree (reduce-sum). Accumulation happens at 32-bit precision in
//! the model (the hardware accumulates 16-bit lanes with carry retention
//! across the two vector registers; 32-bit is the bit-growth-safe
//! equivalent the paper's register pairing provides).

use super::salu::LANES;

/// The C-ALU of one channel.
#[derive(Debug, Clone)]
pub struct Calu {
    /// Vector accumulator (the paired channel vector registers).
    pub vreg: [i32; LANES],
    /// Scalar result registers.
    pub sreg: [i32; 2],
}

impl Calu {
    pub fn new() -> Self {
        Calu {
            vreg: [0; LANES],
            sreg: [0; 2],
        }
    }

    /// Clear the vector accumulator.
    pub fn clear(&mut self) {
        self.vreg = [0; LANES];
    }

    /// Accumulator mode: add one bank's 16-lane partial into the vector
    /// register.
    pub fn accumulate(&mut self, partial: &[i32; LANES]) {
        for i in 0..LANES {
            self.vreg[i] = self.vreg[i].saturating_add(partial[i]);
        }
    }

    /// Accumulate a 16-bit lane vector (memory-sourced partials).
    pub fn accumulate_i16(&mut self, partial: &[i16; LANES]) {
        for i in 0..LANES {
            self.vreg[i] = self.vreg[i].saturating_add(partial[i] as i32);
        }
    }

    /// Adder-tree mode: reduce-sum the vector register into scalar
    /// register `which`, returning the sum.
    pub fn reduce_sum(&mut self, which: usize) -> i32 {
        // Pairwise tree, exactly as 16 adders in 4 levels would compute.
        let mut level: Vec<i64> = self.vreg.iter().map(|&v| v as i64).collect();
        while level.len() > 1 {
            level = level
                .chunks(2)
                .map(|p| p[0] + if p.len() > 1 { p[1] } else { 0 })
                .collect();
        }
        let sum = level[0].clamp(i32::MIN as i64, i32::MAX as i64) as i32;
        self.sreg[which % 2] = sum;
        sum
    }

    /// Max-reduce (used when merging per-bank maxima for softmax).
    pub fn reduce_max(&mut self, which: usize) -> i32 {
        let m = *self.vreg.iter().max().unwrap();
        self.sreg[which % 2] = m;
        m
    }

    /// Broadcast value: what gets written back to all banks.
    pub fn broadcast(&self, which: usize) -> i32 {
        self.sreg[which % 2]
    }

    /// Current vector register shifted-truncated to 16-bit lanes (the
    /// writeback to memory after accumulation, `shift` fraction bits).
    pub fn vreg_writeback(&self, shift: u32) -> [i16; LANES] {
        let mut out = [0i16; LANES];
        for i in 0..LANES {
            out[i] = (self.vreg[i] >> shift).clamp(i16::MIN as i32, i16::MAX as i32) as i16;
        }
        out
    }
}

impl Default for Calu {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn accumulate_then_reduce() {
        let mut c = Calu::new();
        c.accumulate(&[1; LANES]);
        c.accumulate(&[2; LANES]);
        assert_eq!(c.vreg[0], 3);
        assert_eq!(c.reduce_sum(0), 48);
        assert_eq!(c.broadcast(0), 48);
    }

    #[test]
    fn tree_reduce_equals_linear_sum() {
        forall(300, |g| {
            let mut c = Calu::new();
            let vals: Vec<i32> = (0..LANES).map(|_| g.i32_in(-100_000, 100_000)).collect();
            for i in 0..LANES {
                c.vreg[i] = vals[i];
            }
            let tree = c.reduce_sum(1);
            let linear: i64 = vals.iter().map(|&v| v as i64).sum();
            assert_eq!(tree as i64, linear);
        });
    }

    #[test]
    fn reduce_max_finds_maximum() {
        let mut c = Calu::new();
        c.vreg[3] = 999;
        c.vreg[9] = -5;
        assert_eq!(c.reduce_max(0), 999);
    }

    #[test]
    fn accumulate_saturates() {
        let mut c = Calu::new();
        c.accumulate(&[i32::MAX; LANES]);
        c.accumulate(&[i32::MAX; LANES]);
        assert_eq!(c.vreg[0], i32::MAX);
    }

    #[test]
    fn writeback_shifts_and_clamps() {
        let mut c = Calu::new();
        c.vreg[0] = 512;
        c.vreg[1] = i32::MAX;
        let wb = c.vreg_writeback(8);
        assert_eq!(wb[0], 2);
        assert_eq!(wb[1], i16::MAX);
    }

    #[test]
    fn scalar_registers_independent() {
        let mut c = Calu::new();
        c.vreg = [1; LANES];
        c.reduce_sum(0);
        c.vreg = [2; LANES];
        c.reduce_sum(1);
        assert_eq!(c.broadcast(0), 16);
        assert_eq!(c.broadcast(1), 32);
    }
}
