//! Functional model of the bank-level unit (§4.3, Fig. 8).
//!
//! Holds the 16 × 16-bit bank-level register, implements the two input
//! feeding methods (element-wise vs broadcast, §4.3), and the decoding
//! units that turn register data into column-select / LUT-select signals
//! for LUT-embedded subarrays.

use super::salu::LANES;
use crate::interp::LutTable;

/// How the bank-level register feeds the S-ALU MACs (§4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeedMode {
    /// Each register lane feeds its own MAC (element-wise computations
    /// and the Q×Kᵀ direction that avoids transposition).
    ElementWise,
    /// One register lane is broadcast to all MACs (GEMV accumulation).
    Broadcast(usize),
}

/// The bank-level unit: register + decoders.
#[derive(Debug, Clone)]
pub struct BankUnit {
    pub register: [i16; LANES],
}

impl BankUnit {
    pub fn new() -> Self {
        BankUnit {
            register: [0; LANES],
        }
    }

    /// Load 16 input values (from the C-ALU broadcast path or memory).
    pub fn load(&mut self, data: &[i16]) {
        for (i, &v) in data.iter().take(LANES).enumerate() {
            self.register[i] = v;
        }
        for i in data.len()..LANES {
            self.register[i] = 0;
        }
    }

    /// Produce the S-ALU's second operand under a feeding mode.
    pub fn feed(&self, mode: FeedMode) -> [i16; LANES] {
        match mode {
            FeedMode::ElementWise => self.register,
            FeedMode::Broadcast(lane) => [self.register[lane % LANES]; LANES],
        }
    }

    /// The column decoder (16 × 5-to-32 in Table 2): decode each register
    /// lane into the column-select signal for its MAT — i.e. each value's
    /// interpolation section. This is what makes 16 *different* LUT
    /// entries arrive in one RD.
    pub fn decode_sections(&self, table: &LutTable) -> [usize; LANES] {
        let mut out = [0usize; LANES];
        for i in 0..LANES {
            out[i] = table.section_of(self.register[i]);
        }
        out
    }

    /// The sub-sel decoder (16 × 1-to-2 in Table 2): which LUT-embedded
    /// subarray holds each lane's section when one row cannot store the
    /// whole table. Returns (subarray_index, section_within_subarray).
    pub fn decode_lut_select(
        &self,
        table: &LutTable,
        sections_per_subarray: usize,
    ) -> [(usize, usize); LANES] {
        let sections = self.decode_sections(table);
        let mut out = [(0usize, 0usize); LANES];
        for i in 0..LANES {
            out[i] = (
                sections[i] / sections_per_subarray,
                sections[i] % sections_per_subarray,
            );
        }
        out
    }
}

impl Default for BankUnit {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::NonLinFn;
    use crate::model::fixedpoint::Q8_8;

    #[test]
    fn load_pads_with_zero() {
        let mut u = BankUnit::new();
        u.load(&[1, 2, 3]);
        assert_eq!(u.register[0], 1);
        assert_eq!(u.register[2], 3);
        assert_eq!(u.register[3], 0);
        assert_eq!(u.register[15], 0);
    }

    #[test]
    fn broadcast_feed_replicates_lane() {
        let mut u = BankUnit::new();
        u.load(&[10, 20, 30]);
        assert_eq!(u.feed(FeedMode::Broadcast(1)), [20; LANES]);
        assert_eq!(u.feed(FeedMode::ElementWise)[2], 30);
    }

    #[test]
    fn section_decode_matches_table() {
        let t = LutTable::build(NonLinFn::Gelu, 64, Q8_8, Q8_8);
        let mut u = BankUnit::new();
        let xs: Vec<i16> = (-8..8).map(|i| Q8_8.quantize(i as f64 + 0.5)).collect();
        u.load(&xs);
        let secs = u.decode_sections(&t);
        for (i, &raw) in xs.iter().enumerate() {
            assert_eq!(secs[i], t.section_of(raw));
        }
        // Sections must be strictly increasing for increasing inputs here.
        assert!(secs.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn lut_select_splits_across_subarrays() {
        let t = LutTable::build(NonLinFn::Gelu, 64, Q8_8, Q8_8);
        let mut u = BankUnit::new();
        u.load(&[Q8_8.quantize(-7.9), Q8_8.quantize(7.9)]);
        let sel = u.decode_lut_select(&t, 32); // table split over 2 subarrays
        assert_eq!(sel[0].0, 0); // low section → first LUT subarray
        assert_eq!(sel[1].0, 1); // high section → second LUT subarray
        assert!(sel[0].1 < 32 && sel[1].1 < 32);
    }
}
