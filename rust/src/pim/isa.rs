//! The PIM macro-op instruction set.
//!
//! The mapper (§3.2 data-mapping schemes) compiles GPT operators into
//! streams of these macro-ops; the engine lowers each macro-op into
//! concrete DRAM command sequences on the cycle-accurate controller.
//! A macro-op describes *per-pseudo-channel* work — all pseudo-channels
//! execute identical streams in the paper's mapping, so one stream is
//! simulated and it represents device time.

use crate::stats::Phase;
use std::fmt;

/// How a LUT lookup is realized in DRAM (§6.1, Fig. 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LutMethod {
    /// The paper's LUT-embedded subarray: per-MAT column-select signals
    /// let one RD fetch 16 different sections' entries (Fig. 9 flow).
    Embedded,
    /// Case 1 "Scan": read the whole slope/intercept region for every
    /// register-full of data and select matches on the fly.
    Scan,
    /// Case 2 "Select": decode each element's address and fetch its
    /// slope/intercept one element at a time.
    Select,
}

impl LutMethod {
    pub fn name(&self) -> &'static str {
        match self {
            LutMethod::Embedded => "lut-embedded",
            LutMethod::Scan => "scan",
            LutMethod::Select => "select",
        }
    }
}

/// One macro-op of per-pseudo-channel PIM work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MacroOp {
    /// Multi-group weight-streaming MAC — the §3.1 GEMV/attention hot
    /// loop. Each of `groups` S-ALU subarray groups sweeps
    /// `rows_per_group` DRAM rows of `cols_per_row` GBL bursts,
    /// interleaved on the command bus, with MACs hidden under tCCDL.
    /// The bank-level register is re-loaded with fresh input every
    /// `reload_every` bursts (0 = never, e.g. attention score streams).
    WeightStream {
        groups: usize,
        rows_per_group: u64,
        cols_per_row: u64,
        reload_every: u64,
        phase: Phase,
    },
    /// LUT-based linear interpolation over `elems_per_bank` values in
    /// every bank (Fig. 9): ACT source/dest/W/B rows, then per 16-element
    /// chunk RD src / RD W / RD B / WR dst, S-ALU multiply-add hidden.
    LutSweep {
        elems_per_bank: u64,
        method: LutMethod,
        sections: usize,
        phase: Phase,
    },
    /// C-ALU accumulation of per-bank partial sums: `chunks` 16-lane
    /// chunks, each merged across `banks` banks (§4.4).
    CaluAccumulate {
        chunks: u64,
        banks: usize,
        phase: Phase,
    },
    /// C-ALU reduce-sum of per-bank 16-lane partials into one scalar
    /// (layerNorm mean/σ, softmax denominator), then scalar broadcast.
    CaluReduce {
        chunks: u64,
        banks: usize,
        phase: Phase,
    },
    /// Broadcast `bursts_per_bank` GBL bursts of input/intermediate data
    /// into every bank (all-bank WR stream).
    Broadcast {
        bursts_per_bank: u64,
        phase: Phase,
    },
    /// Element-wise S-ALU pass over `elems_per_bank` values per bank with
    /// `n_operands` memory operands (residual add = 2, scale = 1, …).
    Elementwise {
        elems_per_bank: u64,
        n_operands: u32,
        phase: Phase,
    },
    /// Move `bytes` across the buffer-die interconnect (inter-channel
    /// reshape of the MHA output, §3.2.1).
    ChannelReshape { bytes: u64, phase: Phase },
    /// Fixed-cost synchronization / command-mode switch.
    Sync { cycles: u64, phase: Phase },
}

impl MacroOp {
    pub fn phase(&self) -> Phase {
        match *self {
            MacroOp::WeightStream { phase, .. }
            | MacroOp::LutSweep { phase, .. }
            | MacroOp::CaluAccumulate { phase, .. }
            | MacroOp::CaluReduce { phase, .. }
            | MacroOp::Broadcast { phase, .. }
            | MacroOp::Elementwise { phase, .. }
            | MacroOp::ChannelReshape { phase, .. }
            | MacroOp::Sync { phase, .. } => phase,
        }
    }

    /// Total GBL bursts this op reads from memory per bank (for quick
    /// traffic estimates and mapper invariant checks).
    pub fn read_bursts_per_bank(&self) -> u64 {
        match *self {
            MacroOp::WeightStream {
                groups,
                rows_per_group,
                cols_per_row,
                ..
            } => groups as u64 * rows_per_group * cols_per_row,
            MacroOp::LutSweep {
                elems_per_bank,
                method,
                sections,
                ..
            } => {
                let chunks = elems_per_bank.div_ceil(16);
                match method {
                    LutMethod::Embedded => chunks * 3, // src + W + B
                    LutMethod::Select => chunks + 2 * elems_per_bank,
                    LutMethod::Scan => chunks * (1 + 2 * sections as u64 / 16),
                }
            }
            MacroOp::Elementwise {
                elems_per_bank,
                n_operands,
                ..
            } => elems_per_bank.div_ceil(16) * n_operands as u64,
            _ => 0,
        }
    }
}

impl fmt::Display for MacroOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            MacroOp::WeightStream {
                groups,
                rows_per_group,
                cols_per_row,
                reload_every,
                phase,
            } => write!(
                f,
                "WSTREAM g={groups} rows={rows_per_group} cols={cols_per_row} reload={reload_every} [{}]",
                phase.name()
            ),
            MacroOp::LutSweep {
                elems_per_bank,
                method,
                sections,
                phase,
            } => write!(
                f,
                "LUT {} n={elems_per_bank} sec={sections} [{}]",
                method.name(),
                phase.name()
            ),
            MacroOp::CaluAccumulate { chunks, banks, phase } => {
                write!(f, "CACC chunks={chunks} banks={banks} [{}]", phase.name())
            }
            MacroOp::CaluReduce { chunks, banks, phase } => {
                write!(f, "CRED chunks={chunks} banks={banks} [{}]", phase.name())
            }
            MacroOp::Broadcast { bursts_per_bank, phase } => {
                write!(f, "BCAST bursts={bursts_per_bank} [{}]", phase.name())
            }
            MacroOp::Elementwise {
                elems_per_bank,
                n_operands,
                phase,
            } => write!(
                f,
                "EW n={elems_per_bank} ops={n_operands} [{}]",
                phase.name()
            ),
            MacroOp::ChannelReshape { bytes, phase } => {
                write!(f, "RESHAPE bytes={bytes} [{}]", phase.name())
            }
            MacroOp::Sync { cycles, phase } => write!(f, "SYNC {cycles} [{}]", phase.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_extractable() {
        let op = MacroOp::WeightStream {
            groups: 4,
            rows_per_group: 2,
            cols_per_row: 32,
            reload_every: 16,
            phase: Phase::Ffn,
        };
        assert_eq!(op.phase(), Phase::Ffn);
    }

    #[test]
    fn read_burst_accounting() {
        let ws = MacroOp::WeightStream {
            groups: 4,
            rows_per_group: 2,
            cols_per_row: 32,
            reload_every: 0,
            phase: Phase::Mha,
        };
        assert_eq!(ws.read_bursts_per_bank(), 256);

        let lut = MacroOp::LutSweep {
            elems_per_bank: 256,
            method: LutMethod::Embedded,
            sections: 64,
            phase: Phase::NonLinear,
        };
        assert_eq!(lut.read_bursts_per_bank(), 16 * 3);

        let sel = MacroOp::LutSweep {
            elems_per_bank: 256,
            method: LutMethod::Select,
            sections: 64,
            phase: Phase::NonLinear,
        };
        assert!(sel.read_bursts_per_bank() > lut.read_bursts_per_bank());

        // Scan reads the whole table region per chunk (more than
        // Embedded) but its real cost is the compute-bound select in the
        // S-ALU, modeled by the engine, not by read traffic.
        let scan = MacroOp::LutSweep {
            elems_per_bank: 256,
            method: LutMethod::Scan,
            sections: 64,
            phase: Phase::NonLinear,
        };
        assert!(scan.read_bursts_per_bank() > lut.read_bursts_per_bank());
    }

    #[test]
    fn display_roundtrip_mentions_phase() {
        let op = MacroOp::Sync {
            cycles: 10,
            phase: Phase::DataMovement,
        };
        assert!(format!("{op}").contains("data_movement"));
    }
}
