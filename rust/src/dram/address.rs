//! Physical address decomposition.
//!
//! The mapper places weight matrices at *logical* GBL-burst granularity;
//! this module translates linear burst indices into physical
//! (pseudo-channel, bank, subarray, row, column) coordinates with the
//! interleaving order the paper's mapping schemes assume: column fastest
//! (stream within a row), then row within a subarray (stay in one
//! subarray group as long as possible), then subarray, then bank, then
//! pseudo-channel.

use crate::config::HbmConfig;

/// A fully-decoded DRAM location at GBL-burst granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhysAddr {
    pub pch: usize,
    pub bank: usize,
    pub subarray: usize,
    pub row: usize,
    pub col: usize,
}

/// Linear-index ⇄ physical-coordinate translation.
#[derive(Debug, Clone)]
pub struct AddressMapper {
    cols_per_row: usize,
    rows_per_subarray: usize,
    subarrays_per_bank: usize,
    banks_per_pch: usize,
    pseudo_channels: usize,
}

impl AddressMapper {
    pub fn new(hbm: &HbmConfig) -> Self {
        AddressMapper {
            cols_per_row: hbm.cols_per_row(),
            rows_per_subarray: hbm.rows_per_subarray,
            subarrays_per_bank: hbm.subarrays_per_bank,
            banks_per_pch: hbm.banks_per_pch,
            pseudo_channels: hbm.pseudo_channels(),
        }
    }

    /// Total addressable bursts.
    pub fn capacity(&self) -> usize {
        self.cols_per_row
            * self.rows_per_subarray
            * self.subarrays_per_bank
            * self.banks_per_pch
            * self.pseudo_channels
    }

    /// Decode a linear burst index.
    pub fn decode(&self, linear: usize) -> PhysAddr {
        assert!(linear < self.capacity(), "address {linear} out of range");
        let col = linear % self.cols_per_row;
        let r = linear / self.cols_per_row;
        let row = r % self.rows_per_subarray;
        let r = r / self.rows_per_subarray;
        let subarray = r % self.subarrays_per_bank;
        let r = r / self.subarrays_per_bank;
        let bank = r % self.banks_per_pch;
        let pch = r / self.banks_per_pch;
        PhysAddr {
            pch,
            bank,
            subarray,
            row,
            col,
        }
    }

    /// Encode physical coordinates back to the linear burst index.
    pub fn encode(&self, a: PhysAddr) -> usize {
        (((a.pch * self.banks_per_pch + a.bank) * self.subarrays_per_bank + a.subarray)
            * self.rows_per_subarray
            + a.row)
            * self.cols_per_row
            + a.col
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HbmConfig;
    use crate::testutil::forall;

    fn mapper() -> AddressMapper {
        AddressMapper::new(&HbmConfig::hbm2_8gb())
    }

    #[test]
    fn decode_zero() {
        let a = mapper().decode(0);
        assert_eq!(
            a,
            PhysAddr {
                pch: 0,
                bank: 0,
                subarray: 0,
                row: 0,
                col: 0
            }
        );
    }

    #[test]
    fn column_is_fastest_axis() {
        let m = mapper();
        let a = m.decode(0);
        let b = m.decode(1);
        assert_eq!(b.col, a.col + 1);
        assert_eq!((b.row, b.subarray, b.bank, b.pch), (a.row, a.subarray, a.bank, a.pch));
    }

    #[test]
    fn row_rolls_after_cols() {
        let m = mapper();
        let a = m.decode(m.cols_per_row);
        assert_eq!((a.col, a.row), (0, 1));
    }

    #[test]
    fn capacity_matches_device() {
        // 8 GB / 32 B bursts = 256 Mi bursts.
        assert_eq!(mapper().capacity(), (8usize << 30) / 32);
    }

    #[test]
    fn roundtrip_property() {
        let m = mapper();
        let cap = m.capacity();
        forall(500, |g| {
            let linear = g.usize_in(0, cap - 1);
            let a = m.decode(linear);
            assert_eq!(m.encode(a), linear);
            assert!(a.col < 32 && a.row < 512 && a.subarray < 64);
            assert!(a.bank < 16 && a.pch < 16);
        });
    }

    #[test]
    #[should_panic]
    fn out_of_range_panics() {
        let m = mapper();
        m.decode(m.capacity());
    }
}
