//! The pseudo-channel command scheduler / timing checker.

use super::bank::{BankState, Cycle, NEVER};
use super::command::{CmdTarget, DramCmd};
use crate::config::SimConfig;
use crate::stats::{CmdKind, Stats};
use std::collections::VecDeque;

/// Protocol violations the controller refuses to schedule around.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum TimingError {
    #[error("bank {bank} subarray {subarray}: no open row for column access")]
    RowNotOpen { bank: usize, subarray: usize },
    #[error("bank {bank} subarray {subarray}: row {open} already open, ACT of {row} needs PRE")]
    RowAlreadyOpen {
        bank: usize,
        subarray: usize,
        open: usize,
        row: usize,
    },
    #[error("bank {bank} subarray {subarray}: PRE with no open row")]
    PreClosed { bank: usize, subarray: usize },
    #[error("index out of range: bank {bank} subarray {subarray}")]
    BadIndex { bank: usize, subarray: usize },
}

/// Cycle-accurate scheduler for one HBM2 pseudo-channel.
///
/// `issue` places each command at the earliest cycle satisfying all
/// Table 2 constraints, mutating bank/subarray state. The clock only moves
/// forward; the command bus carries one command per cycle.
#[derive(Debug, Clone)]
pub struct ChannelController {
    /// Current cycle: the next cycle a command may occupy the command bus.
    pub clock: Cycle,
    pub banks: Vec<BankState>,
    /// Last column command on the shared channel IO (tCCDS domain).
    last_col_channel: Cycle,
    /// Recent ACT-command issue cycles for the tFAW rolling window.
    act_window: VecDeque<Cycle>,
    // Timing parameters (cached from config as i64 for Cycle math).
    t_rc: Cycle,
    t_rcd: Cycle,
    t_ras: Cycle,
    t_rp: Cycle,
    t_rrd: Cycle,
    t_ccds: Cycle,
    t_ccdl: Cycle,
    t_wr: Cycle,
    t_cwl: Cycle,
    t_cl: Cycle,
    t_faw: Cycle,
    burst: Cycle,
    n_banks: usize,
    n_subarrays: usize,
    gbl_bytes: u64,
}

impl ChannelController {
    pub fn new(cfg: &SimConfig) -> Self {
        let t = &cfg.timing;
        ChannelController {
            clock: 0,
            banks: (0..cfg.hbm.banks_per_pch)
                .map(|_| BankState::new(cfg.hbm.subarrays_per_bank))
                .collect(),
            last_col_channel: NEVER,
            act_window: VecDeque::with_capacity(4),
            t_rc: t.t_rc as Cycle,
            t_rcd: t.t_rcd as Cycle,
            t_ras: t.t_ras as Cycle,
            t_rp: t.t_rp as Cycle,
            t_rrd: t.t_rrd as Cycle,
            t_ccds: t.t_ccds as Cycle,
            t_ccdl: t.t_ccdl as Cycle,
            t_wr: t.t_wr as Cycle,
            t_cwl: t.t_cwl as Cycle,
            t_cl: t.t_cl as Cycle,
            t_faw: t.t_faw as Cycle,
            burst: t.burst_cycles() as Cycle,
            n_banks: cfg.hbm.banks_per_pch,
            n_subarrays: cfg.hbm.subarrays_per_bank,
            gbl_bytes: cfg.hbm.gbl_bytes_per_access() as u64,
        }
    }

    /// Reset clock and all bank state (new measurement run).
    pub fn reset(&mut self) {
        self.clock = 0;
        self.last_col_channel = NEVER;
        self.act_window.clear();
        let n_sub = self.n_subarrays;
        for b in &mut self.banks {
            *b = BankState::new(n_sub);
        }
    }

    /// Allocation-free bank range for a target (§Perf L3 iteration 2:
    /// `CmdTarget::banks` boxes an iterator; the controller hot path uses
    /// this contiguous range instead).
    fn bank_range(&self, target: CmdTarget) -> std::ops::Range<usize> {
        match target {
            CmdTarget::Bank(b) => b..b + 1,
            CmdTarget::AllBanks => 0..self.n_banks,
        }
    }

    fn check_index(&self, bank: usize, subarray: usize) -> Result<(), TimingError> {
        if bank >= self.n_banks || subarray >= self.n_subarrays {
            Err(TimingError::BadIndex { bank, subarray })
        } else {
            Ok(())
        }
    }

    /// Earliest cycle an ACT to (bank, subarray) may issue.
    fn act_ready(&self, bank: usize, subarray: usize) -> Cycle {
        let b = &self.banks[bank];
        let s = &b.subarrays[subarray];
        let mut ready = self.clock;
        ready = ready.max(s.last_act + self.t_rc); // same-subarray row cycle
        ready = ready.max(s.last_pre + self.t_rp); // precharge recovery
        ready = ready.max(b.last_act_any + self.t_rrd); // SALP inter-ACT gap
        if self.act_window.len() == 4 {
            ready = ready.max(self.act_window[0] + self.t_faw);
        }
        ready
    }

    /// Earliest cycle a column command to (bank, subarray) may issue.
    ///
    /// Two column-timing domains exist (see `bank.rs`):
    /// * **PIM all-bank mode** (`all_banks`): data flows over per-group
    ///   GBL segments into S-ALUs, so tCCDL binds per *subarray group*
    ///   and the shared channel DQ is not involved. This is the paper's
    ///   subarray-level-parallelism bandwidth model.
    /// * **Host mode** (single bank): the bank's column path and the
    ///   channel DQ are shared — classic tCCDL (same bank) + tCCDS
    ///   (channel) constraints.
    fn col_ready(&self, bank: usize, subarray: usize, all_banks: bool) -> Cycle {
        let b = &self.banks[bank];
        let s = &b.subarrays[subarray];
        let mut ready = self.clock;
        ready = ready.max(s.last_act + self.t_rcd);
        ready = ready.max(s.last_col + self.t_ccdl);
        if !all_banks {
            ready = ready.max(b.last_col + self.t_ccdl);
            ready = ready.max(self.last_col_channel + self.t_ccds);
        }
        ready
    }

    /// Earliest cycle a PRE of (bank, subarray) may issue.
    fn pre_ready(&self, bank: usize, subarray: usize) -> Cycle {
        let b = &self.banks[bank];
        let s = &b.subarrays[subarray];
        let mut ready = self.clock;
        ready = ready.max(s.last_act + self.t_ras);
        ready = ready.max(s.last_wr_data_end + self.t_wr);
        // A column command to this subarray still in flight must finish.
        ready = ready.max(s.last_col + self.t_ccdl);
        ready
    }

    /// Issue one command at the earliest legal cycle; returns that cycle.
    pub fn issue(&mut self, cmd: DramCmd, stats: &mut Stats) -> Result<Cycle, TimingError> {
        let target = cmd.target();
        let bank_list = self.bank_range(target);
        match cmd {
            DramCmd::Act { subarray, row, .. } => {
                for b in bank_list.clone() {
                    self.check_index(b, subarray)?;
                    if let Some(open) = self.banks[b].subarrays[subarray].open_row {
                        return Err(TimingError::RowAlreadyOpen {
                            bank: b,
                            subarray,
                            open,
                            row,
                        });
                    }
                }
                let at = bank_list
                    .clone()
                    .map(|b| self.act_ready(b, subarray))
                    .max()
                    .unwrap();
                for b in bank_list.clone() {
                    let bank = &mut self.banks[b];
                    bank.subarrays[subarray].open_row = Some(row);
                    bank.subarrays[subarray].last_act = at;
                    bank.last_act_any = at;
                }
                if self.act_window.len() == 4 {
                    self.act_window.pop_front();
                }
                self.act_window.push_back(at);
                stats.count_cmd(CmdKind::Act, bank_list.len() as u64);
                self.clock = at + 1;
                Ok(at)
            }
            DramCmd::Rd { subarray, .. } | DramCmd::Wr { subarray, .. } => {
                let is_wr = matches!(cmd, DramCmd::Wr { .. });
                let all_banks = matches!(target, CmdTarget::AllBanks);
                for b in bank_list.clone() {
                    self.check_index(b, subarray)?;
                    if self.banks[b].subarrays[subarray].open_row.is_none() {
                        return Err(TimingError::RowNotOpen { bank: b, subarray });
                    }
                }
                let at = bank_list
                    .clone()
                    .map(|b| self.col_ready(b, subarray, all_banks))
                    .max()
                    .unwrap();
                for b in bank_list.clone() {
                    self.banks[b].last_col = at;
                    self.banks[b].subarrays[subarray].last_col = at;
                    if is_wr {
                        self.banks[b].subarrays[subarray].last_wr_data_end =
                            at + self.t_cwl + self.burst;
                    }
                }
                if !all_banks {
                    self.last_col_channel = at;
                }
                stats.count_cmd(
                    if is_wr { CmdKind::Wr } else { CmdKind::Rd },
                    bank_list.len() as u64,
                );
                stats.internal_bytes += self.gbl_bytes * bank_list.len() as u64;
                self.clock = at + 1;
                Ok(at)
            }
            DramCmd::Pre { subarray, .. } => {
                for b in bank_list.clone() {
                    self.check_index(b, subarray)?;
                    if self.banks[b].subarrays[subarray].open_row.is_none() {
                        return Err(TimingError::PreClosed { bank: b, subarray });
                    }
                }
                let at = bank_list
                    .clone()
                    .map(|b| self.pre_ready(b, subarray))
                    .max()
                    .unwrap();
                for b in bank_list.clone() {
                    let s = &mut self.banks[b].subarrays[subarray];
                    s.open_row = None;
                    s.last_pre = at;
                }
                stats.count_cmd(CmdKind::Pre, bank_list.len() as u64);
                self.clock = at + 1;
                Ok(at)
            }
            DramCmd::PreAll { .. } => {
                let mut at = self.clock;
                let mut any = false;
                for b in bank_list.clone() {
                    for su in 0..self.n_subarrays {
                        if self.banks[b].subarrays[su].open_row.is_some() {
                            any = true;
                            at = at.max(self.pre_ready(b, su));
                        }
                    }
                }
                if !any {
                    // PREA of a fully-precharged target is a no-op command.
                    let at = self.clock;
                    self.clock = at + 1;
                    return Ok(at);
                }
                let mut n = 0;
                for b in bank_list.clone() {
                    for su in 0..self.n_subarrays {
                        let s = &mut self.banks[b].subarrays[su];
                        if s.open_row.is_some() {
                            s.open_row = None;
                            s.last_pre = at;
                            n += 1;
                        }
                    }
                }
                stats.count_cmd(CmdKind::Pre, n);
                self.clock = at + 1;
                Ok(at)
            }
        }
    }

    /// Burst fast path: `n` back-to-back same-row column commands
    /// (RD if `write` is false) to an already-open row. Produces the same
    /// final timing state as issuing them one by one (property-tested).
    /// Returns the issue cycle of the *last* command.
    pub fn stream_cols(
        &mut self,
        target: CmdTarget,
        subarray: usize,
        n: u64,
        write: bool,
        stats: &mut Stats,
    ) -> Result<Cycle, TimingError> {
        if n == 0 {
            return Ok(self.clock - 1);
        }
        let all_banks = matches!(target, CmdTarget::AllBanks);
        let bank_list: Vec<usize> = target.banks(self.n_banks).collect();
        for &b in &bank_list {
            self.check_index(b, subarray)?;
            if self.banks[b].subarrays[subarray].open_row.is_none() {
                return Err(TimingError::RowNotOpen { bank: b, subarray });
            }
        }
        let first = bank_list
            .iter()
            .map(|&b| self.col_ready(b, subarray, all_banks))
            .max()
            .unwrap();
        // Subsequent commands are gated only by tCCDL (>= tCCDS and the
        // 1-cycle command bus), so they land at first + k*tCCDL.
        let last = first + (n as Cycle - 1) * self.t_ccdl;
        for &b in &bank_list {
            self.banks[b].last_col = last;
            self.banks[b].subarrays[subarray].last_col = last;
            if write {
                self.banks[b].subarrays[subarray].last_wr_data_end =
                    last + self.t_cwl + self.burst;
            }
        }
        if !all_banks {
            self.last_col_channel = last;
        }
        stats.count_cmd(
            if write { CmdKind::Wr } else { CmdKind::Rd },
            n * bank_list.len() as u64,
        );
        stats.internal_bytes += n * self.gbl_bytes * bank_list.len() as u64;
        self.clock = last + 1;
        Ok(last)
    }

    /// Interleaved multi-group stream: `n_each` column commands to each of
    /// `subarrays` (one per active S-ALU group), issued round-robin in
    /// all-bank PIM mode. This is the §3.1 subarray-level-parallelism hot
    /// loop: with `G` groups and per-group tCCDL cadence, the command bus
    /// sustains up to `G / tCCDL` bursts per cycle per bank.
    ///
    /// Exact per-command semantics (each command individually placed at
    /// its earliest legal cycle), implemented as a tight loop without
    /// `DramCmd` construction. Returns the last issue cycle.
    pub fn stream_interleaved(
        &mut self,
        subarrays: &[usize],
        n_each: u64,
        write: bool,
        stats: &mut Stats,
    ) -> Result<Cycle, TimingError> {
        if subarrays.is_empty() || n_each == 0 {
            return Ok(self.clock - 1);
        }
        for &su in subarrays {
            for b in 0..self.n_banks {
                self.check_index(b, su)?;
                if self.banks[b].subarrays[su].open_row.is_none() {
                    return Err(TimingError::RowNotOpen { bank: b, subarray: su });
                }
            }
        }
        // Hot-loop optimization (§Perf L3): all banks share identical
        // per-subarray state in all-bank streams, so the scheduling loop
        // runs on per-subarray locals and the result is committed to the
        // bank state once at the end. Exactness vs per-command issue is
        // property-tested (tests/prop_timing.rs).
        // Stack-allocated locals (§Perf L3 iteration 3): this runs per
        // 16-element chunk in LUT sweeps, so heap allocation here shows
        // up in whole-run profiles. At most 8 concurrent streams.
        assert!(subarrays.len() <= 8, "more than 8 interleaved streams");
        let mut local_last_col = [0 as Cycle; 8];
        let mut act_floor = [0 as Cycle; 8];
        for (i, &su) in subarrays.iter().enumerate() {
            local_last_col[i] = self.banks[0].subarrays[su].last_col;
            act_floor[i] = self.banks[0].subarrays[su].last_act + self.t_rcd;
        }
        let mut clock = self.clock;
        let mut last = clock - 1;
        for _ in 0..n_each {
            for (i, _) in subarrays.iter().enumerate() {
                let at = clock
                    .max(act_floor[i])
                    .max(local_last_col[i] + self.t_ccdl);
                local_last_col[i] = at;
                clock = at + 1;
                last = at;
            }
        }
        self.clock = clock;
        for (i, &su) in subarrays.iter().enumerate() {
            let at = local_last_col[i];
            for b in 0..self.n_banks {
                self.banks[b].subarrays[su].last_col = at;
                self.banks[b].last_col = self.banks[b].last_col.max(at);
                if write {
                    self.banks[b].subarrays[su].last_wr_data_end =
                        at + self.t_cwl + self.burst;
                }
            }
        }
        let total = n_each * subarrays.len() as u64 * self.n_banks as u64;
        stats.count_cmd(if write { CmdKind::Wr } else { CmdKind::Rd }, total);
        stats.internal_bytes += total * self.gbl_bytes;
        Ok(last)
    }

    /// Cycle at which the data of a column command issued at `at` is fully
    /// transferred (read: CL + burst, write: CWL + burst).
    pub fn data_end(&self, at: Cycle, write: bool) -> Cycle {
        at + if write { self.t_cwl } else { self.t_cl } + self.burst
    }

    /// Convenience: ACT + stream + (optionally) PRE over one row.
    /// Returns the cycle the last command issued.
    pub fn row_sweep(
        &mut self,
        target: CmdTarget,
        subarray: usize,
        row: usize,
        n_cols: u64,
        write: bool,
        precharge: bool,
        stats: &mut Stats,
    ) -> Result<Cycle, TimingError> {
        self.issue(
            DramCmd::Act {
                target,
                subarray,
                row,
            },
            stats,
        )?;
        let mut last = self.stream_cols(target, subarray, n_cols, write, stats)?;
        if precharge {
            last = self.issue(DramCmd::Pre { target, subarray }, stats)?;
        }
        Ok(last)
    }

    /// Total open rows across all banks (diagnostics / invariant checks).
    pub fn open_rows(&self) -> usize {
        self.banks.iter().map(|b| b.open_count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn ctl() -> (ChannelController, Stats) {
        (ChannelController::new(&SimConfig::paper()), Stats::new())
    }

    #[test]
    fn act_rd_pre_obeys_trcd_tras() {
        let (mut c, mut st) = ctl();
        let t = DramCmd::Act {
            target: CmdTarget::Bank(0),
            subarray: 0,
            row: 10,
        };
        let act_at = c.issue(t, &mut st).unwrap();
        assert_eq!(act_at, 0);
        let rd_at = c
            .issue(
                DramCmd::Rd {
                    target: CmdTarget::Bank(0),
                    subarray: 0,
                    col: 0,
                },
                &mut st,
            )
            .unwrap();
        assert_eq!(rd_at, 16); // tRCD
        let pre_at = c
            .issue(
                DramCmd::Pre {
                    target: CmdTarget::Bank(0),
                    subarray: 0,
                },
                &mut st,
            )
            .unwrap();
        assert_eq!(pre_at, 29); // tRAS from ACT dominates
    }

    #[test]
    fn rd_without_open_row_fails() {
        let (mut c, mut st) = ctl();
        let err = c
            .issue(
                DramCmd::Rd {
                    target: CmdTarget::Bank(0),
                    subarray: 0,
                    col: 0,
                },
                &mut st,
            )
            .unwrap_err();
        assert_eq!(
            err,
            TimingError::RowNotOpen {
                bank: 0,
                subarray: 0
            }
        );
    }

    #[test]
    fn double_act_fails() {
        let (mut c, mut st) = ctl();
        let act = |row| DramCmd::Act {
            target: CmdTarget::Bank(0),
            subarray: 0,
            row,
        };
        c.issue(act(1), &mut st).unwrap();
        let err = c.issue(act(2), &mut st).unwrap_err();
        assert!(matches!(err, TimingError::RowAlreadyOpen { .. }));
    }

    #[test]
    fn same_bank_rd_cadence_is_tccdl() {
        let (mut c, mut st) = ctl();
        c.issue(
            DramCmd::Act {
                target: CmdTarget::Bank(0),
                subarray: 0,
                row: 0,
            },
            &mut st,
        )
        .unwrap();
        let rd = |col| DramCmd::Rd {
            target: CmdTarget::Bank(0),
            subarray: 0,
            col,
        };
        let a = c.issue(rd(0), &mut st).unwrap();
        let b = c.issue(rd(1), &mut st).unwrap();
        assert_eq!(b - a, 4); // tCCDL
    }

    #[test]
    fn cross_bank_rd_cadence_is_tccds() {
        let (mut c, mut st) = ctl();
        for b in 0..2 {
            c.issue(
                DramCmd::Act {
                    target: CmdTarget::Bank(b),
                    subarray: 0,
                    row: 0,
                },
                &mut st,
            )
            .unwrap();
        }
        let a = c
            .issue(
                DramCmd::Rd {
                    target: CmdTarget::Bank(0),
                    subarray: 0,
                    col: 0,
                },
                &mut st,
            )
            .unwrap();
        let b = c
            .issue(
                DramCmd::Rd {
                    target: CmdTarget::Bank(1),
                    subarray: 0,
                    col: 0,
                },
                &mut st,
            )
            .unwrap();
        assert_eq!(b - a, 2); // tCCDS, bank-interleaved
    }

    #[test]
    fn salp_allows_two_open_subarrays() {
        let (mut c, mut st) = ctl();
        let a = c
            .issue(
                DramCmd::Act {
                    target: CmdTarget::Bank(0),
                    subarray: 0,
                    row: 3,
                },
                &mut st,
            )
            .unwrap();
        let b = c
            .issue(
                DramCmd::Act {
                    target: CmdTarget::Bank(0),
                    subarray: 1,
                    row: 7,
                },
                &mut st,
            )
            .unwrap();
        assert_eq!(b - a, 2); // tRRD between subarray ACTs, not tRC
        assert_eq!(c.open_rows(), 2);
        assert!(c.banks[0].row_open(0, 3) && c.banks[0].row_open(1, 7));
    }

    #[test]
    fn same_subarray_reactivation_needs_trc() {
        let (mut c, mut st) = ctl();
        let act = |row| DramCmd::Act {
            target: CmdTarget::Bank(0),
            subarray: 0,
            row,
        };
        let a = c.issue(act(0), &mut st).unwrap();
        c.issue(
            DramCmd::Pre {
                target: CmdTarget::Bank(0),
                subarray: 0,
            },
            &mut st,
        )
        .unwrap();
        let b = c.issue(act(1), &mut st).unwrap();
        assert!(b - a >= 45, "ACT->ACT gap {} < tRC", b - a);
    }

    #[test]
    fn all_bank_act_and_stream() {
        let (mut c, mut st) = ctl();
        c.issue(
            DramCmd::Act {
                target: CmdTarget::AllBanks,
                subarray: 0,
                row: 0,
            },
            &mut st,
        )
        .unwrap();
        assert_eq!(c.open_rows(), 16);
        let last = c
            .stream_cols(CmdTarget::AllBanks, 0, 32, false, &mut st)
            .unwrap();
        // first read at tRCD=16, 31 more at tCCDL: 16 + 31*4 = 140.
        assert_eq!(last, 140);
        assert_eq!(st.commands[&crate::stats::CmdKind::Rd], 32 * 16);
        // 32 cols × 16 banks × 32 B
        assert_eq!(st.internal_bytes, 32 * 16 * 32);
    }

    #[test]
    fn stream_equals_individual_issues() {
        // The burst fast path must match the per-command path exactly.
        let (mut c1, mut st1) = ctl();
        let (mut c2, mut st2) = ctl();
        let t = CmdTarget::AllBanks;
        for c in [&mut c1, &mut c2] {
            let mut st = Stats::new();
            c.issue(
                DramCmd::Act {
                    target: t,
                    subarray: 2,
                    row: 9,
                },
                &mut st,
            )
            .unwrap();
        }
        let last1 = c1.stream_cols(t, 2, 17, false, &mut st1).unwrap();
        let mut last2 = 0;
        for col in 0..17 {
            last2 = c2
                .issue(
                    DramCmd::Rd {
                        target: t,
                        subarray: 2,
                        col,
                    },
                    &mut st2,
                )
                .unwrap();
        }
        assert_eq!(last1, last2);
        assert_eq!(st1.internal_bytes, st2.internal_bytes);
        assert_eq!(c1.banks[0].last_col, c2.banks[0].last_col);
    }

    #[test]
    fn interleaved_groups_multiply_bandwidth() {
        // 4 subarray groups streaming concurrently sustain 1 cmd/cycle
        // (tCCDL=4, G=4): the P_Sub=4 bandwidth claim.
        let (mut c, mut st) = ctl();
        let groups = [0usize, 16, 32, 48];
        for (i, &su) in groups.iter().enumerate() {
            c.issue(
                DramCmd::Act {
                    target: CmdTarget::AllBanks,
                    subarray: su,
                    row: i,
                },
                &mut st,
            )
            .unwrap();
        }
        let start = c.clock;
        let last = c.stream_interleaved(&groups, 32, false, &mut st).unwrap();
        // 128 commands at ~1/cycle once the pipeline fills.
        let span = last - start + 1;
        assert!(span <= 140, "span {span} too slow for interleaved streams");
        assert!(span >= 128, "span {span} beats the command bus");
    }

    #[test]
    fn interleaved_single_group_matches_stream_cols() {
        let (mut c1, mut st1) = ctl();
        let (mut c2, mut st2) = ctl();
        for c in [&mut c1, &mut c2] {
            let mut st = Stats::new();
            c.issue(
                DramCmd::Act {
                    target: CmdTarget::AllBanks,
                    subarray: 5,
                    row: 0,
                },
                &mut st,
            )
            .unwrap();
        }
        let a = c1.stream_interleaved(&[5], 20, false, &mut st1).unwrap();
        let b = c2
            .stream_cols(CmdTarget::AllBanks, 5, 20, false, &mut st2)
            .unwrap();
        assert_eq!(a, b);
        assert_eq!(st1.internal_bytes, st2.internal_bytes);
    }

    #[test]
    fn interleaved_equals_per_command_issue() {
        // The tight loop must match issuing individual DramCmd::Rd
        // round-robin across groups.
        let (mut c1, mut st1) = ctl();
        let (mut c2, mut st2) = ctl();
        let groups = [2usize, 10, 33];
        for c in [&mut c1, &mut c2] {
            let mut st = Stats::new();
            for (i, &su) in groups.iter().enumerate() {
                c.issue(
                    DramCmd::Act {
                        target: CmdTarget::AllBanks,
                        subarray: su,
                        row: i,
                    },
                    &mut st,
                )
                .unwrap();
            }
        }
        let a = c1.stream_interleaved(&groups, 9, false, &mut st1).unwrap();
        let mut b = 0;
        for col in 0..9 {
            for &su in &groups {
                b = c2
                    .issue(
                        DramCmd::Rd {
                            target: CmdTarget::AllBanks,
                            subarray: su,
                            col,
                        },
                        &mut st2,
                    )
                    .unwrap();
            }
        }
        assert_eq!(a, b);
        assert_eq!(st1.internal_bytes, st2.internal_bytes);
        assert_eq!(c1.clock, c2.clock);
    }

    #[test]
    fn write_then_pre_waits_twr() {
        let (mut c, mut st) = ctl();
        c.issue(
            DramCmd::Act {
                target: CmdTarget::Bank(0),
                subarray: 0,
                row: 0,
            },
            &mut st,
        )
        .unwrap();
        let wr_at = c
            .issue(
                DramCmd::Wr {
                    target: CmdTarget::Bank(0),
                    subarray: 0,
                    col: 0,
                },
                &mut st,
            )
            .unwrap();
        let pre_at = c
            .issue(
                DramCmd::Pre {
                    target: CmdTarget::Bank(0),
                    subarray: 0,
                },
                &mut st,
            )
            .unwrap();
        // PRE >= WR + tCWL + burst + tWR = wr_at + 8 + 2 + 16
        assert!(pre_at >= wr_at + 26, "pre {pre_at} wr {wr_at}");
    }

    #[test]
    fn preall_closes_everything() {
        let (mut c, mut st) = ctl();
        for su in 0..3 {
            c.issue(
                DramCmd::Act {
                    target: CmdTarget::AllBanks,
                    subarray: su,
                    row: su,
                },
                &mut st,
            )
            .unwrap();
        }
        assert_eq!(c.open_rows(), 48);
        c.issue(
            DramCmd::PreAll {
                target: CmdTarget::AllBanks,
            },
            &mut st,
        )
        .unwrap();
        assert_eq!(c.open_rows(), 0);
    }

    #[test]
    fn preall_on_idle_is_noop() {
        let (mut c, mut st) = ctl();
        let at = c
            .issue(
                DramCmd::PreAll {
                    target: CmdTarget::AllBanks,
                },
                &mut st,
            )
            .unwrap();
        assert_eq!(at, 0);
        assert!(st.commands.get(&crate::stats::CmdKind::Pre).is_none());
    }

    #[test]
    fn row_sweep_composes() {
        let (mut c, mut st) = ctl();
        let last = c
            .row_sweep(CmdTarget::AllBanks, 0, 5, 32, false, true, &mut st)
            .unwrap();
        // ACT@0, RD@16..140, PRE at >= last_col + tCCDL = 144
        assert_eq!(last, 144);
        assert_eq!(c.open_rows(), 0);
    }

    #[test]
    fn reset_restores_fresh_state() {
        let (mut c, mut st) = ctl();
        c.row_sweep(CmdTarget::AllBanks, 0, 5, 8, false, false, &mut st)
            .unwrap();
        c.reset();
        assert_eq!(c.clock, 0);
        assert_eq!(c.open_rows(), 0);
    }

    #[test]
    fn bad_index_rejected() {
        let (mut c, mut st) = ctl();
        let err = c
            .issue(
                DramCmd::Act {
                    target: CmdTarget::Bank(99),
                    subarray: 0,
                    row: 0,
                },
                &mut st,
            )
            .unwrap_err();
        assert!(matches!(err, TimingError::BadIndex { .. }));
    }
}
