//! Command-level cycle-accurate HBM2 timing model.
//!
//! This is the substrate the SAL-PIM engine ([`crate::pim`]) drives. It
//! models one HBM2 *pseudo-channel* — the unit at which PIM commands are
//! broadcast to all 16 banks in lockstep (as in FIM/AiM "all-bank mode") —
//! with per-bank and per-subarray state machines and the Table 2 timing
//! constraints. Channels run identical command streams in the paper's
//! mapping (weights are sharded so every channel does the same amount of
//! work), so device time = pseudo-channel time and the simulator only
//! steps one controller per distinct stream.
//!
//! Two execution paths produce identical timing:
//!
//! * the **per-command path** ([`ChannelController::issue`]) checks every
//!   constraint for every command — the reference semantics;
//! * the **burst fast path** ([`ChannelController::stream_row`]) advances
//!   the clock in closed form for long same-row column streams — the
//!   production path for full-model runs.
//!
//! `tests/prop_timing.rs` proves the two paths agree on random workloads.

mod address;
mod bank;
mod command;
mod controller;

pub use address::{AddressMapper, PhysAddr};
pub use bank::{BankState, SubarrayState};
pub use command::{CmdTarget, DramCmd};
pub use controller::{ChannelController, TimingError};
