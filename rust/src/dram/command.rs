//! DRAM command vocabulary.

use std::fmt;

/// Which banks a command addresses.
///
/// SAL-PIM (like FIM/AiM) issues most PIM work in *all-bank* mode: one
/// command on the pseudo-channel command bus is executed by every bank in
/// lockstep, which is what makes bank-parallel PIM scale without
/// per-bank command bandwidth.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmdTarget {
    /// A single bank (normal-memory mode or stragglers).
    Bank(usize),
    /// Every bank in the pseudo-channel simultaneously.
    AllBanks,
}

impl CmdTarget {
    /// Iterate over the concrete bank indices for `n_banks` total.
    pub fn banks(&self, n_banks: usize) -> Box<dyn Iterator<Item = usize>> {
        match *self {
            CmdTarget::Bank(b) => Box::new(std::iter::once(b)),
            CmdTarget::AllBanks => Box::new(0..n_banks),
        }
    }
}

/// One DRAM command as scheduled by the channel controller.
///
/// `subarray`-carrying commands exploit SALP (subarray-level parallelism,
/// Kim+ ISCA'12): multiple subarrays of the same bank may hold open rows
/// at once because each subarray's BLSA acts as a row cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DramCmd {
    /// Activate `row` of `subarray` in the targeted bank(s).
    Act {
        target: CmdTarget,
        subarray: usize,
        row: usize,
    },
    /// Column read from an activated subarray (one GBL burst to the
    /// S-ALU / IO). `col` indexes GBL-width units within the row.
    Rd {
        target: CmdTarget,
        subarray: usize,
        col: usize,
    },
    /// Column write into an activated subarray.
    Wr {
        target: CmdTarget,
        subarray: usize,
        col: usize,
    },
    /// Precharge one subarray's open row.
    Pre { target: CmdTarget, subarray: usize },
    /// Precharge every open subarray in the targeted bank(s).
    PreAll { target: CmdTarget },
}

impl DramCmd {
    pub fn target(&self) -> CmdTarget {
        match *self {
            DramCmd::Act { target, .. }
            | DramCmd::Rd { target, .. }
            | DramCmd::Wr { target, .. }
            | DramCmd::Pre { target, .. }
            | DramCmd::PreAll { target } => target,
        }
    }

    /// Is this a column (RD/WR) command?
    pub fn is_column(&self) -> bool {
        matches!(self, DramCmd::Rd { .. } | DramCmd::Wr { .. })
    }

    pub fn mnemonic(&self) -> &'static str {
        match self {
            DramCmd::Act { .. } => "ACT",
            DramCmd::Rd { .. } => "RD",
            DramCmd::Wr { .. } => "WR",
            DramCmd::Pre { .. } => "PRE",
            DramCmd::PreAll { .. } => "PREA",
        }
    }
}

impl fmt::Display for DramCmd {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let t = match self.target() {
            CmdTarget::Bank(b) => format!("b{b}"),
            CmdTarget::AllBanks => "b*".to_string(),
        };
        match self {
            DramCmd::Act { subarray, row, .. } => {
                write!(f, "ACT {t} s{subarray} r{row}")
            }
            DramCmd::Rd { subarray, col, .. } => write!(f, "RD  {t} s{subarray} c{col}"),
            DramCmd::Wr { subarray, col, .. } => write!(f, "WR  {t} s{subarray} c{col}"),
            DramCmd::Pre { subarray, .. } => write!(f, "PRE {t} s{subarray}"),
            DramCmd::PreAll { .. } => write!(f, "PREA {t}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_iteration() {
        let one: Vec<_> = CmdTarget::Bank(3).banks(16).collect();
        assert_eq!(one, vec![3]);
        let all: Vec<_> = CmdTarget::AllBanks.banks(4).collect();
        assert_eq!(all, vec![0, 1, 2, 3]);
    }

    #[test]
    fn classification() {
        let rd = DramCmd::Rd {
            target: CmdTarget::AllBanks,
            subarray: 0,
            col: 1,
        };
        assert!(rd.is_column());
        let act = DramCmd::Act {
            target: CmdTarget::Bank(0),
            subarray: 2,
            row: 5,
        };
        assert!(!act.is_column());
        assert_eq!(act.mnemonic(), "ACT");
    }

    #[test]
    fn display_is_compact() {
        let cmd = DramCmd::Act {
            target: CmdTarget::AllBanks,
            subarray: 7,
            row: 100,
        };
        assert_eq!(format!("{cmd}"), "ACT b* s7 r100");
    }
}
