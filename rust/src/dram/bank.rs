//! Per-bank and per-subarray timing state machines.
//!
//! SALP (Kim+ ISCA'12, the paper's ref. [23]) lets each subarray keep its
//! own row open in its bit-line sense amplifiers, so the state that
//! matters for timing lives *per subarray* (open row, last ACT/PRE
//! timestamps) plus a small amount of *per bank* state (last column
//! command, shared peripheral constraints).

/// Timestamp type: cycle at which an event happened. `NEVER` (= i64::MIN/2)
/// means "long enough ago that no constraint binds".
pub type Cycle = i64;

/// Sentinel for "no prior event".
pub const NEVER: Cycle = i64::MIN / 2;

/// Timing state of one subarray.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubarrayState {
    /// Currently open (activated) row, if any.
    pub open_row: Option<usize>,
    /// Cycle of the last ACT to this subarray.
    pub last_act: Cycle,
    /// Cycle of the last PRE to this subarray.
    pub last_pre: Cycle,
    /// Cycle of the last WR data completion (for tWR before PRE).
    pub last_wr_data_end: Cycle,
    /// Last column command streamed *by this subarray's group* in PIM
    /// mode. SAL-PIM's subarray-level parallelism means each subarray
    /// group owns its own GBL segment + S-ALU, so the tCCDL column
    /// cadence applies per group, not per bank — this is exactly the
    /// paper's P_Sub× bandwidth claim (§3.1, §6.2).
    pub last_col: Cycle,
}

impl SubarrayState {
    pub fn new() -> Self {
        SubarrayState {
            open_row: None,
            last_act: NEVER,
            last_pre: NEVER,
            last_wr_data_end: NEVER,
            last_col: NEVER,
        }
    }
}

impl Default for SubarrayState {
    fn default() -> Self {
        Self::new()
    }
}

/// Timing state of one bank (its subarrays + shared peripherals).
#[derive(Debug, Clone)]
pub struct BankState {
    pub subarrays: Vec<SubarrayState>,
    /// Last column command (RD or WR) issued to this bank — tCCDL domain.
    pub last_col: Cycle,
    /// Last ACT to *any* subarray of this bank (inter-subarray ACT gap).
    pub last_act_any: Cycle,
}

impl BankState {
    pub fn new(n_subarrays: usize) -> Self {
        BankState {
            subarrays: vec![SubarrayState::new(); n_subarrays],
            last_col: NEVER,
            last_act_any: NEVER,
        }
    }

    /// Number of currently open subarrays (SALP concurrency).
    pub fn open_count(&self) -> usize {
        self.subarrays.iter().filter(|s| s.open_row.is_some()).count()
    }

    /// Is `row` of `subarray` open?
    pub fn row_open(&self, subarray: usize, row: usize) -> bool {
        self.subarrays[subarray].open_row == Some(row)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_bank_is_idle() {
        let b = BankState::new(64);
        assert_eq!(b.open_count(), 0);
        assert!(!b.row_open(0, 0));
        assert_eq!(b.last_col, NEVER);
    }

    #[test]
    fn open_tracking() {
        let mut b = BankState::new(4);
        b.subarrays[1].open_row = Some(17);
        b.subarrays[3].open_row = Some(2);
        assert_eq!(b.open_count(), 2);
        assert!(b.row_open(1, 17));
        assert!(!b.row_open(1, 16));
    }

    #[test]
    fn never_is_far_in_past() {
        // NEVER + any realistic timing constant must not overflow and must
        // stay far below cycle 0.
        assert!(NEVER + 1_000_000 < 0);
    }
}
