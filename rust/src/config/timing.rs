//! DRAM timing parameters (Table 2 of the paper).
//!
//! All values are in cycles of `tck_ns` (1 ns at the paper's 1 GHz HBM2
//! command clock), matching the paper's "Timing parameters (ns)" row:
//! `BL = 4, tRC = 45, tRCD = 16, tRAS = 29, tCL = 16, tRRD = 2,
//! tCCDS = 2, tCCDL = 4`.

/// DRAM timing constraint set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Timing {
    /// Command-clock period in nanoseconds.
    pub tck_ns: f64,
    /// Burst length (beats per column access).
    pub bl: u64,
    /// ACT→ACT same bank/subarray (row cycle).
    pub t_rc: u64,
    /// ACT→RD/WR (row-to-column delay).
    pub t_rcd: u64,
    /// ACT→PRE minimum (row active time).
    pub t_ras: u64,
    /// RD→data (CAS latency).
    pub t_cl: u64,
    /// ACT→ACT different bank (same pch).
    pub t_rrd: u64,
    /// RD→RD different bank group (short CCD).
    pub t_ccds: u64,
    /// RD→RD same bank (long CCD) — the PIM all-bank column cadence.
    pub t_ccdl: u64,
    /// PRE→ACT (row precharge); derived as tRC − tRAS for HBM2.
    pub t_rp: u64,
    /// Write recovery (WR data end → PRE).
    pub t_wr: u64,
    /// Write latency (WR command → data).
    pub t_cwl: u64,
    /// Four-activate window (rolling limit on ACTs per pch).
    pub t_faw: u64,
    /// Average refresh interval (all-bank refresh cadence).
    pub t_refi: u64,
    /// Refresh cycle time (bank unavailable per refresh).
    pub t_rfc: u64,
    /// Per-PIM-macro-op command setup/turnaround: the host memory
    /// controller issues mode switches and operand descriptors before
    /// each in-memory operation (FIM/AiM-style macro commands).
    pub pim_op_setup: u64,
}

// `Timing` is `Copy` and all fields are plain cycle counts; constructing
// it in a const context is useful for tables of sweep configurations.
impl Timing {
    /// The paper's HBM2 timing (Table 2), tCK = 1 ns.
    pub fn hbm2() -> Self {
        Timing {
            tck_ns: 1.0,
            bl: 4,
            t_rc: 45,
            t_rcd: 16,
            t_ras: 29,
            t_cl: 16,
            t_rrd: 2,
            t_ccds: 2,
            t_ccdl: 4,
            t_rp: 16, // tRC − tRAS
            t_wr: 16,
            t_cwl: 8,
            t_faw: 16,
            t_refi: 3900,
            t_rfc: 260,
            pim_op_setup: 32,
        }
    }

    /// Fraction of time lost to refresh (tRFC every tREFI).
    pub fn refresh_overhead(&self) -> f64 {
        self.t_rfc as f64 / self.t_refi as f64
    }

    /// Data-burst duration in cycles (BL beats at DDR = BL/2 clock cycles).
    pub fn burst_cycles(&self) -> u64 {
        self.bl / 2
    }

    /// Cycles to stream `n` same-row column accesses back-to-back in
    /// all-bank PIM mode (tCCDL cadence).
    pub fn stream_cycles(&self, n: u64) -> u64 {
        n * self.t_ccdl
    }

    /// Sanity checks on the constraint set.
    pub fn validate(&self) -> Vec<String> {
        let mut problems = Vec::new();
        if self.t_ras + self.t_rp != self.t_rc {
            problems.push(format!(
                "tRAS({}) + tRP({}) != tRC({})",
                self.t_ras, self.t_rp, self.t_rc
            ));
        }
        if self.t_rcd > self.t_ras {
            problems.push(format!(
                "tRCD({}) > tRAS({}): row closes before first column",
                self.t_rcd, self.t_ras
            ));
        }
        if self.t_ccds > self.t_ccdl {
            problems.push("tCCDS > tCCDL".to_string());
        }
        if self.bl == 0 || self.bl % 2 != 0 {
            problems.push(format!("BL must be even and nonzero, got {}", self.bl));
        }
        problems
    }

    /// Convert a cycle count to nanoseconds.
    pub fn cycles_to_ns(&self, cycles: u64) -> f64 {
        cycles as f64 * self.tck_ns
    }

    /// Convert a cycle count to seconds.
    pub fn cycles_to_sec(&self, cycles: u64) -> f64 {
        self.cycles_to_ns(cycles) * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_timing_is_consistent() {
        let t = Timing::hbm2();
        assert!(t.validate().is_empty(), "{:?}", t.validate());
    }

    #[test]
    fn stream_cadence() {
        let t = Timing::hbm2();
        assert_eq!(t.stream_cycles(32), 128); // a full 1 KB row, 32 cols
        assert_eq!(t.burst_cycles(), 2);
    }

    #[test]
    fn unit_conversions() {
        let t = Timing::hbm2();
        assert_eq!(t.cycles_to_ns(45), 45.0);
        assert!((t.cycles_to_sec(1_000_000_000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn broken_timing_detected() {
        let mut t = Timing::hbm2();
        t.t_rp = 10;
        assert!(!t.validate().is_empty());
        let mut t = Timing::hbm2();
        t.bl = 3;
        assert!(!t.validate().is_empty());
    }
}
