//! HBM2 organization and PIM unit shapes (Table 2 of the paper).

/// Physical organization of the HBM2 stack hosting SAL-PIM.
///
/// The paper's device (Table 2): 4 DRAM dies + 1 buffer die; 8 channels
/// per die pair presented as 16 pseudo-channels; 16 banks per
/// pseudo-channel; 64 subarrays per bank of 512 rows each; 1 KB rows;
/// 512×512 MATs; 128-bit DQ per channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HbmConfig {
    /// DRAM dies in the stack (buffer die excluded).
    pub dram_dies: usize,
    /// Channels per die.
    pub channels_per_die: usize,
    /// Pseudo-channels per channel (HBM2 pseudo-channel mode).
    pub pch_per_channel: usize,
    /// Banks per pseudo-channel.
    pub banks_per_pch: usize,
    /// Subarrays per bank.
    pub subarrays_per_bank: usize,
    /// Rows per subarray.
    pub rows_per_subarray: usize,
    /// Row (page) size in bytes.
    pub row_bytes: usize,
    /// MAT dimension (cells per local bit-line / word-line segment).
    pub mat_dim: usize,
    /// DQ width per channel in bits.
    pub dq_bits: usize,
    /// Global bit-line width per bank in bits — the S-ALU operand width.
    /// One column access delivers `gbl_bits` to the subarray-level ALU.
    pub gbl_bits: usize,
    /// Total capacity in bytes.
    pub capacity_bytes: usize,
}

impl HbmConfig {
    /// The paper's 8 GB HBM2 stack.
    pub fn hbm2_8gb() -> Self {
        HbmConfig {
            dram_dies: 4,
            channels_per_die: 2,
            pch_per_channel: 2,
            banks_per_pch: 16,
            subarrays_per_bank: 64,
            rows_per_subarray: 512,
            row_bytes: 1024,
            mat_dim: 512,
            dq_bits: 128,
            // 256-bit GBL: 16 × 16-bit operands per column access, matching
            // the 16-lane bank-level register / 16-MAC logical S-ALU width.
            gbl_bits: 256,
            capacity_bytes: 8 << 30,
        }
    }

    /// Total independent channels in the stack.
    pub fn channels(&self) -> usize {
        self.dram_dies * self.channels_per_die
    }

    /// Total pseudo-channels — the unit of PIM command broadcast.
    pub fn pseudo_channels(&self) -> usize {
        self.channels() * self.pch_per_channel
    }

    /// Total banks in the device.
    pub fn total_banks(&self) -> usize {
        self.pseudo_channels() * self.banks_per_pch
    }

    /// Bytes delivered to an S-ALU per column access (GBL burst).
    pub fn gbl_bytes_per_access(&self) -> usize {
        self.gbl_bits / 8
    }

    /// Column accesses needed to stream one full row through the GBL.
    pub fn cols_per_row(&self) -> usize {
        self.row_bytes / self.gbl_bytes_per_access()
    }

    /// MATs per subarray (row_bytes × 8 bits / mat_dim columns each).
    pub fn mats_per_subarray(&self) -> usize {
        self.row_bytes * 8 / self.mat_dim
    }

    /// Rows per bank.
    pub fn rows_per_bank(&self) -> usize {
        self.subarrays_per_bank * self.rows_per_subarray
    }

    /// Bytes per subarray (the KV-cache allocation granule).
    pub fn subarray_bytes(&self) -> usize {
        self.rows_per_subarray * self.row_bytes
    }

    /// Total subarrays across the device.
    pub fn total_subarrays(&self) -> usize {
        self.total_banks() * self.subarrays_per_bank
    }

    /// Bytes per bank.
    pub fn bytes_per_bank(&self) -> usize {
        self.rows_per_bank() * self.row_bytes
    }
}

/// LUT-embedded subarray configuration (§4.2, Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LutConfig {
    /// Number of LUT-embedded subarrays per bank (hold slope & intercept).
    pub num_lut_subarrays: usize,
    /// Number of linear-interpolation sections per function.
    pub sections: usize,
}

impl LutConfig {
    pub fn paper() -> Self {
        LutConfig {
            num_lut_subarrays: 4,
            sections: 64,
        }
    }

    /// Rows needed to store one function's table (slope + intercept,
    /// 16-bit entries) given a row size.
    pub fn rows_per_function(&self, row_bytes: usize) -> usize {
        let table_bytes = self.sections * 2 * 2; // W and B, 2 bytes each
        table_bytes.div_ceil(row_bytes)
    }
}

/// Subarray-level ALU configuration (§4.1, Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SaluConfig {
    /// Maximum S-ALUs (subarray groups) physically present per bank.
    pub max_p_sub: usize,
    /// Physical shared MACs per S-ALU. The S-ALU is *logically* 16 lanes
    /// wide (one per 16-bit operand in a GBL burst); 8 physical MACs at
    /// 2× the column cadence service all 16 lanes (§4.1 shared-MAC).
    pub macs_per_salu: usize,
    /// Logical lanes per S-ALU = operands per GBL burst.
    pub lanes: usize,
    /// Accumulator registers per S-ALU (16 × 32-bit).
    pub regs: usize,
    /// Register width in bits (accumulation precision).
    pub reg_bits: usize,
    /// MAC clock in MHz (500 MHz = 2× the 250 MHz tCCDL column cadence).
    pub mac_clock_mhz: usize,
}

impl SaluConfig {
    pub fn paper() -> Self {
        SaluConfig {
            max_p_sub: 4,
            macs_per_salu: 8,
            lanes: 16,
            regs: 16,
            reg_bits: 32,
            mac_clock_mhz: 500,
        }
    }

    /// MAC passes needed to consume one GBL burst: `lanes / macs`
    /// (= 2 with the paper's shared-MAC arrangement, hidden under tCCDL).
    pub fn passes_per_burst(&self) -> usize {
        self.lanes.div_ceil(self.macs_per_salu)
    }
}

/// Channel-level ALU configuration (§4.4, Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CaluConfig {
    /// Channel vector registers (two of 16 × 16-bit in the paper).
    pub vector_regs: usize,
    /// Lanes per vector register.
    pub lanes: usize,
    /// Scalar registers (16-bit).
    pub scalar_regs: usize,
    /// Configurable adders (act as accumulator or adder tree).
    pub adders: usize,
}

impl CaluConfig {
    pub fn paper() -> Self {
        CaluConfig {
            vector_regs: 2,
            lanes: 16,
            scalar_regs: 2,
            adders: 16,
        }
    }

    /// Adder-tree depth for a reduce-sum over `lanes` values.
    pub fn tree_depth(&self) -> usize {
        usize::BITS as usize - (self.lanes - 1).leading_zeros() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hbm2_counts() {
        let h = HbmConfig::hbm2_8gb();
        assert_eq!(h.channels(), 8);
        assert_eq!(h.pseudo_channels(), 16);
        assert_eq!(h.total_banks(), 256);
        assert_eq!(h.cols_per_row(), 32);
        assert_eq!(h.mats_per_subarray(), 16);
        assert_eq!(h.gbl_bytes_per_access(), 32);
        assert_eq!(h.rows_per_bank(), 32768);
        assert_eq!(h.bytes_per_bank(), 32 << 20);
        assert_eq!(h.subarray_bytes(), 512 << 10);
        assert_eq!(h.total_subarrays(), 16384);
    }

    #[test]
    fn lut_table_fits_one_row() {
        // 64 sections × (W,B) × 2 B = 256 B ≤ 1 KB row.
        let l = LutConfig::paper();
        assert_eq!(l.rows_per_function(1024), 1);
    }

    #[test]
    fn shared_mac_two_passes() {
        let s = SaluConfig::paper();
        assert_eq!(s.passes_per_burst(), 2);
    }

    #[test]
    fn calu_tree_depth() {
        let c = CaluConfig::paper();
        assert_eq!(c.tree_depth(), 4);
    }
}
