//! Configuration for the SAL-PIM stack.
//!
//! [`SimConfig`] bundles everything Table 2 of the paper specifies — the
//! HBM2 organization, DRAM timing parameters, LUT-embedded-subarray setup,
//! S-ALU / bank-level-unit / C-ALU shapes — plus the transformer model
//! shapes the workloads run ([`ModelConfig`]) and the parallelism degrees
//! `(P_Ch, P_Ba, P_Sub)` the mapping schemes of §3.2 are parameterized by.
//!
//! Presets:
//! * [`SimConfig::paper`] — the exact Table 2 configuration with GPT-2
//!   medium (345 M parameters), used by every timing experiment.
//! * [`SimConfig::mini`] — a scaled-down model (GPT-2 *mini*) for
//!   functional (value-computing) runs and for cross-checking against the
//!   PJRT golden model; the memory device config is unchanged.
//!
//! Configs can also be loaded from simple `key = value` files via
//! [`parse::parse_config`] (no serde in the offline build environment).

mod hbm;
mod model;
pub mod parse;
mod timing;

pub use hbm::{CaluConfig, HbmConfig, LutConfig, SaluConfig};
pub use model::ModelConfig;
pub use timing::Timing;

/// Degrees of parallelism used by the §3.2 data-mapping schemes.
///
/// * `p_ch` — channel-level parallelism (independent weight columns/heads).
/// * `p_ba` — bank-level parallelism within a pseudo-channel (partial sums
///   merged by the C-ALU).
/// * `p_sub` — subarray-level parallelism: the number of S-ALUs (subarray
///   groups) per bank that stream weights concurrently. The paper
///   evaluates 1, 2 and 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    pub p_ch: usize,
    pub p_ba: usize,
    pub p_sub: usize,
}

impl Parallelism {
    /// Total number of S-ALUs across the device.
    pub fn total_salus(&self) -> usize {
        self.p_ch * self.p_ba * self.p_sub
    }
}

/// Complete simulator configuration (Table 2 + workload model).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// HBM2 organization (channels, banks, subarrays, row geometry).
    pub hbm: HbmConfig,
    /// DRAM timing parameters in cycles of `tck_ns`.
    pub timing: Timing,
    /// LUT-embedded subarray configuration.
    pub lut: LutConfig,
    /// Subarray-level ALU configuration.
    pub salu: SaluConfig,
    /// Channel-level ALU configuration.
    pub calu: CaluConfig,
    /// Transformer model shapes.
    pub model: ModelConfig,
    /// Active parallelism degrees for the mapper.
    pub parallelism: Parallelism,
}

impl SimConfig {
    /// The paper's Table 2 configuration with GPT-2 medium.
    pub fn paper() -> Self {
        let hbm = HbmConfig::hbm2_8gb();
        let parallelism = Parallelism {
            p_ch: hbm.pseudo_channels(),
            p_ba: hbm.banks_per_pch,
            p_sub: 4,
        };
        SimConfig {
            hbm,
            timing: Timing::hbm2(),
            lut: LutConfig::paper(),
            salu: SaluConfig::paper(),
            calu: CaluConfig::paper(),
            model: ModelConfig::gpt2_medium(),
            parallelism,
        }
    }

    /// Paper device config with a small functional-run model.
    pub fn mini() -> Self {
        let mut c = Self::paper();
        c.model = ModelConfig::gpt2_mini();
        c
    }

    /// Same as [`SimConfig::paper`] but with a different `P_Sub`
    /// (the Fig. 14 / Fig. 15 sweep).
    pub fn with_p_sub(mut self, p_sub: usize) -> Self {
        assert!(
            p_sub >= 1 && p_sub <= self.salu.max_p_sub,
            "P_Sub {} out of range 1..={}",
            p_sub,
            self.salu.max_p_sub
        );
        self.parallelism.p_sub = p_sub;
        self
    }

    /// Replace the workload model.
    pub fn with_model(mut self, model: ModelConfig) -> Self {
        self.model = model;
        self
    }

    /// Peak internal bandwidth in bytes/sec given the active `P_Sub`
    /// (§6.2: "a maximum of 8 TB/s when P_Sub is 4").
    ///
    /// Each bank-level read delivers one GBL burst (`row bytes / columns`
    /// worth = 32 B at BL=4 × 64-bit GBL) every `tCCDL` cycles, per active
    /// subarray group, per bank, per pseudo-channel.
    pub fn peak_internal_bandwidth(&self) -> f64 {
        let bytes_per_burst = self.hbm.gbl_bytes_per_access() as f64;
        let bursts_per_sec = 1.0e9 / (self.timing.t_ccdl as f64 * self.timing.tck_ns);
        bytes_per_burst
            * bursts_per_sec
            * self.parallelism.p_sub as f64
            * self.hbm.banks_per_pch as f64
            * self.hbm.pseudo_channels() as f64
    }

    /// Peak *external* (JEDEC pin) bandwidth of the unmodified HBM2 stack.
    pub fn peak_external_bandwidth(&self) -> f64 {
        // 8 channels × 128-bit DQ × 2 Gb/s/pin (1 GHz DDR).
        let channels = self.hbm.channels() as f64;
        let dq_bits = self.hbm.dq_bits as f64;
        channels * dq_bits / 8.0 * 2.0e9
    }

    /// Validate internal consistency; returns a list of human-readable
    /// problems (empty = valid).
    pub fn validate(&self) -> Vec<String> {
        let mut problems = Vec::new();
        if self.parallelism.p_sub > self.salu.max_p_sub {
            problems.push(format!(
                "P_Sub={} exceeds configured S-ALUs per bank {}",
                self.parallelism.p_sub, self.salu.max_p_sub
            ));
        }
        if self.parallelism.p_ba > self.hbm.banks_per_pch {
            problems.push(format!(
                "P_Ba={} exceeds banks per pseudo-channel {}",
                self.parallelism.p_ba, self.hbm.banks_per_pch
            ));
        }
        if self.parallelism.p_ch > self.hbm.pseudo_channels() {
            problems.push(format!(
                "P_Ch={} exceeds pseudo-channels {}",
                self.parallelism.p_ch,
                self.hbm.pseudo_channels()
            ));
        }
        if self.lut.sections == 0 || !self.lut.sections.is_power_of_two() {
            problems.push(format!(
                "LUT sections must be a power of two, got {}",
                self.lut.sections
            ));
        }
        if self.lut.num_lut_subarrays > self.hbm.subarrays_per_bank {
            problems.push(format!(
                "{} LUT subarrays exceed {} subarrays/bank",
                self.lut.num_lut_subarrays, self.hbm.subarrays_per_bank
            ));
        }
        if self.model.d_model % self.model.n_heads != 0 {
            problems.push(format!(
                "d_model {} not divisible by n_heads {}",
                self.model.d_model, self.model.n_heads
            ));
        }
        problems.extend(self.timing.validate());
        problems
    }

    /// Number of compute (non-LUT) subarrays per S-ALU group.
    ///
    /// §3.1: "if the number of S-ALU is 4 in a bank, the subarray group
    /// consists of 15 subarrays without LUT-embedded subarray".
    pub fn subarrays_per_group(&self) -> usize {
        (self.hbm.subarrays_per_bank - self.lut.num_lut_subarrays) / self.salu.max_p_sub
    }
}

impl Default for SimConfig {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_is_valid() {
        let c = SimConfig::paper();
        assert!(c.validate().is_empty(), "{:?}", c.validate());
    }

    #[test]
    fn paper_config_matches_table2() {
        let c = SimConfig::paper();
        assert_eq!(c.hbm.channels(), 8);
        assert_eq!(c.hbm.pseudo_channels(), 16);
        assert_eq!(c.hbm.banks_per_pch, 16);
        assert_eq!(c.hbm.subarrays_per_bank, 64);
        assert_eq!(c.hbm.rows_per_subarray, 512);
        assert_eq!(c.hbm.row_bytes, 1024);
        assert_eq!(c.timing.t_rc, 45);
        assert_eq!(c.timing.t_rcd, 16);
        assert_eq!(c.timing.t_ras, 29);
        assert_eq!(c.timing.t_cl, 16);
        assert_eq!(c.timing.t_rrd, 2);
        assert_eq!(c.timing.t_ccds, 2);
        assert_eq!(c.timing.t_ccdl, 4);
        assert_eq!(c.timing.bl, 4);
        assert_eq!(c.lut.num_lut_subarrays, 4);
        assert_eq!(c.lut.sections, 64);
        assert_eq!(c.salu.max_p_sub, 4);
        assert_eq!(c.salu.macs_per_salu, 8);
        assert_eq!(c.parallelism.p_sub, 4);
    }

    #[test]
    fn subarray_groups_match_paper_example() {
        // §3.1: 4 S-ALUs → groups of 15 subarrays (64 - 4 LUT = 60, /4).
        let c = SimConfig::paper();
        assert_eq!(c.subarrays_per_group(), 15);
    }

    #[test]
    fn peak_internal_bandwidth_is_8tbps_at_psub4() {
        // §6.2: "an enormous bandwidth maximum of 8 TB/s when P_Sub is 4".
        let c = SimConfig::paper();
        let tb = c.peak_internal_bandwidth() / 1e12;
        assert!((tb - 8.192).abs() < 0.3, "got {tb} TB/s");
    }

    #[test]
    fn external_bandwidth_matches_hbm2() {
        // 8ch × 128b × 2Gbps = 256 GB/s (the paper: GPU 672 GB/s is 2.63×
        // the HBM2 maximum, i.e. ≈255 GB/s).
        let c = SimConfig::paper();
        let gb = c.peak_external_bandwidth() / 1e9;
        assert!((gb - 256.0).abs() < 1.0, "got {gb} GB/s");
    }

    #[test]
    fn p_sub_sweep_validates() {
        for p in [1, 2, 4] {
            let c = SimConfig::paper().with_p_sub(p);
            assert!(c.validate().is_empty());
            assert_eq!(c.parallelism.p_sub, p);
        }
    }

    #[test]
    #[should_panic]
    fn p_sub_out_of_range_panics() {
        let _ = SimConfig::paper().with_p_sub(8);
    }

    #[test]
    fn invalid_configs_are_reported() {
        let mut c = SimConfig::paper();
        c.lut.sections = 63;
        assert!(!c.validate().is_empty());
        let mut c = SimConfig::paper();
        c.parallelism.p_ba = 1000;
        assert!(!c.validate().is_empty());
        let mut c = SimConfig::paper();
        c.model.n_heads = 7;
        assert!(!c.validate().is_empty());
    }
}
