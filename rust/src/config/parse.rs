//! Minimal `key = value` config-file parser.
//!
//! The offline build environment has no serde, so sweep/override files use
//! a flat INI-like format:
//!
//! ```text
//! # comment
//! p_sub = 4
//! model = gpt2-medium
//! lut.sections = 64
//! timing.t_ccdl = 4
//! ```
//!
//! Unknown keys are reported as errors so typos in experiment scripts fail
//! loudly instead of silently running the default configuration.

use super::{ModelConfig, SimConfig};

/// A parse failure with line context.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("line {line}: expected `key = value`, got `{text}`")]
    Syntax { line: usize, text: String },
    #[error("line {line}: unknown key `{key}`")]
    UnknownKey { line: usize, key: String },
    #[error("line {line}: bad value `{value}` for `{key}`: {why}")]
    BadValue {
        line: usize,
        key: String,
        value: String,
        why: String,
    },
    #[error("config invalid after overrides: {0:?}")]
    Invalid(Vec<String>),
}

fn parse_usize(line: usize, key: &str, value: &str) -> Result<usize, ConfigError> {
    value.parse().map_err(|e| ConfigError::BadValue {
        line,
        key: key.to_string(),
        value: value.to_string(),
        why: format!("{e}"),
    })
}

fn parse_u64(line: usize, key: &str, value: &str) -> Result<u64, ConfigError> {
    value.parse().map_err(|e| ConfigError::BadValue {
        line,
        key: key.to_string(),
        value: value.to_string(),
        why: format!("{e}"),
    })
}

/// Apply one `key = value` override to a config.
pub fn apply_override(
    cfg: &mut SimConfig,
    line: usize,
    key: &str,
    value: &str,
) -> Result<(), ConfigError> {
    match key {
        "p_sub" => cfg.parallelism.p_sub = parse_usize(line, key, value)?,
        "p_ba" => cfg.parallelism.p_ba = parse_usize(line, key, value)?,
        "p_ch" => cfg.parallelism.p_ch = parse_usize(line, key, value)?,
        "model" => {
            cfg.model = match value {
                "gpt2-medium" => ModelConfig::gpt2_medium(),
                "gpt2-xl" => ModelConfig::gpt2_xl(),
                "gpt2-mini" => ModelConfig::gpt2_mini(),
                other => {
                    return Err(ConfigError::BadValue {
                        line,
                        key: key.to_string(),
                        value: other.to_string(),
                        why: "expected gpt2-medium|gpt2-xl|gpt2-mini".to_string(),
                    })
                }
            }
        }
        "model.d_model" => cfg.model.d_model = parse_usize(line, key, value)?,
        "model.n_layers" => cfg.model.n_layers = parse_usize(line, key, value)?,
        "model.n_heads" => cfg.model.n_heads = parse_usize(line, key, value)?,
        "model.d_ff" => cfg.model.d_ff = parse_usize(line, key, value)?,
        "model.vocab" => cfg.model.vocab = parse_usize(line, key, value)?,
        "model.max_seq" => cfg.model.max_seq = parse_usize(line, key, value)?,
        "lut.sections" => cfg.lut.sections = parse_usize(line, key, value)?,
        "lut.num_lut_subarrays" => cfg.lut.num_lut_subarrays = parse_usize(line, key, value)?,
        "salu.macs_per_salu" => cfg.salu.macs_per_salu = parse_usize(line, key, value)?,
        "salu.max_p_sub" => cfg.salu.max_p_sub = parse_usize(line, key, value)?,
        "timing.t_rc" => cfg.timing.t_rc = parse_u64(line, key, value)?,
        "timing.t_rcd" => cfg.timing.t_rcd = parse_u64(line, key, value)?,
        "timing.t_ras" => cfg.timing.t_ras = parse_u64(line, key, value)?,
        "timing.t_cl" => cfg.timing.t_cl = parse_u64(line, key, value)?,
        "timing.t_rrd" => cfg.timing.t_rrd = parse_u64(line, key, value)?,
        "timing.t_ccds" => cfg.timing.t_ccds = parse_u64(line, key, value)?,
        "timing.t_ccdl" => cfg.timing.t_ccdl = parse_u64(line, key, value)?,
        "timing.t_rp" => cfg.timing.t_rp = parse_u64(line, key, value)?,
        "timing.t_faw" => cfg.timing.t_faw = parse_u64(line, key, value)?,
        "timing.t_refi" => cfg.timing.t_refi = parse_u64(line, key, value)?,
        "timing.t_rfc" => cfg.timing.t_rfc = parse_u64(line, key, value)?,
        "timing.pim_op_setup" => cfg.timing.pim_op_setup = parse_u64(line, key, value)?,
        _ => {
            return Err(ConfigError::UnknownKey {
                line,
                key: key.to_string(),
            })
        }
    }
    Ok(())
}

/// Split a config file's text into `(line, key, value)` triples without
/// applying them (comments and blanks skipped). The scenario layer stores
/// overrides in this form so one suite file can carry per-scenario config
/// deltas that are applied — and type-checked — by [`apply_overrides`].
pub fn parse_pairs(text: &str) -> Result<Vec<(usize, String, String)>, ConfigError> {
    let mut pairs = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(ConfigError::Syntax {
                line: line_no,
                text: raw.to_string(),
            });
        };
        pairs.push((line_no, key.trim().to_string(), value.trim().to_string()));
    }
    Ok(pairs)
}

/// Apply a list of `(line, key, value)` overrides and validate the result.
pub fn apply_overrides(
    mut cfg: SimConfig,
    pairs: &[(usize, String, String)],
) -> Result<SimConfig, ConfigError> {
    for (line, key, value) in pairs {
        apply_override(&mut cfg, *line, key, value)?;
    }
    let problems = cfg.validate();
    if problems.is_empty() {
        Ok(cfg)
    } else {
        Err(ConfigError::Invalid(problems))
    }
}

/// Parse a whole config file's text on top of a base config.
pub fn parse_config(base: SimConfig, text: &str) -> Result<SimConfig, ConfigError> {
    apply_overrides(base, &parse_pairs(text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_overrides() {
        let cfg = parse_config(
            SimConfig::paper(),
            "# sweep point\np_sub = 2\nlut.sections = 128\nmodel = gpt2-mini\n",
        )
        .unwrap();
        assert_eq!(cfg.parallelism.p_sub, 2);
        assert_eq!(cfg.lut.sections, 128);
        assert_eq!(cfg.model.name, "gpt2-mini");
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let cfg = parse_config(SimConfig::paper(), "\n\n# nothing\n  # more\n").unwrap();
        assert_eq!(cfg.parallelism.p_sub, 4);
    }

    #[test]
    fn unknown_key_rejected() {
        let err = parse_config(SimConfig::paper(), "p_subb = 4\n").unwrap_err();
        assert!(matches!(err, ConfigError::UnknownKey { line: 1, .. }));
    }

    #[test]
    fn bad_value_rejected() {
        let err = parse_config(SimConfig::paper(), "p_sub = four\n").unwrap_err();
        assert!(matches!(err, ConfigError::BadValue { .. }));
    }

    #[test]
    fn syntax_error_rejected() {
        let err = parse_config(SimConfig::paper(), "p_sub 4\n").unwrap_err();
        assert!(matches!(err, ConfigError::Syntax { line: 1, .. }));
    }

    #[test]
    fn invalid_combination_rejected() {
        // sections must stay a power of two.
        let err = parse_config(SimConfig::paper(), "lut.sections = 65\n").unwrap_err();
        assert!(matches!(err, ConfigError::Invalid(_)));
    }

    #[test]
    fn inline_comment_after_value() {
        let cfg = parse_config(SimConfig::paper(), "p_sub = 1 # bank-level-ish\n").unwrap();
        assert_eq!(cfg.parallelism.p_sub, 1);
    }

    #[test]
    fn parse_pairs_preserves_line_numbers() {
        let pairs = parse_pairs("# header\np_sub = 2\n\nlut.sections = 32\n").unwrap();
        assert_eq!(
            pairs,
            vec![
                (2, "p_sub".to_string(), "2".to_string()),
                (4, "lut.sections".to_string(), "32".to_string()),
            ]
        );
    }

    #[test]
    fn apply_overrides_applies_and_validates() {
        let pairs = vec![(1, "model".to_string(), "gpt2-xl".to_string())];
        let cfg = apply_overrides(SimConfig::paper(), &pairs).unwrap();
        assert_eq!(cfg.model.name, "gpt2-xl");
        // An individually-legal value that breaks cross-field validation
        // is still rejected.
        let bad = vec![(3, "p_ba".to_string(), "1000".to_string())];
        let err = apply_overrides(SimConfig::paper(), &bad).unwrap_err();
        assert!(matches!(err, ConfigError::Invalid(_)));
    }

    #[test]
    fn apply_overrides_reports_the_failing_line() {
        let pairs = vec![
            (1, "p_sub".to_string(), "2".to_string()),
            (7, "timing.t_ccdl".to_string(), "soon".to_string()),
        ];
        match apply_overrides(SimConfig::paper(), &pairs).unwrap_err() {
            ConfigError::BadValue { line, key, .. } => {
                assert_eq!(line, 7);
                assert_eq!(key, "timing.t_ccdl");
            }
            other => panic!("expected BadValue, got {other:?}"),
        }
    }

    #[test]
    fn timing_and_model_shape_overrides_cascade() {
        let cfg = parse_config(
            SimConfig::paper(),
            "timing.t_ccdl = 8\nmodel.n_layers = 12\nmodel.d_model = 768\nmodel.n_heads = 12\n",
        )
        .unwrap();
        assert_eq!(cfg.timing.t_ccdl, 8);
        assert_eq!(cfg.model.n_layers, 12);
        // Halved burst rate halves peak internal bandwidth.
        let base = SimConfig::paper().peak_internal_bandwidth();
        assert!((cfg.peak_internal_bandwidth() - base / 2.0).abs() < 1e-3);
    }
}
