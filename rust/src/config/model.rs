//! Transformer model shape configuration.
//!
//! The paper evaluates GPT-2 medium (345 M parameters, d_model = 1024,
//! 24 decoder layers). Functional (value-computing) runs use a scaled
//! GPT-2 *mini* whose shapes match the AOT-compiled JAX artifacts.

/// Shapes of a GPT-2-style decoder-only transformer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    /// Human-readable name (also selects the HLO artifact set).
    pub name: String,
    /// Hidden dimension.
    pub d_model: usize,
    /// Decoder layers.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// FFN intermediate dimension (4 × d_model for GPT-2).
    pub d_ff: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Maximum sequence length (positional table size / KV capacity).
    pub max_seq: usize,
    /// Parameter precision in bytes (2 = the paper's 16-bit fixed point).
    pub param_bytes: usize,
}

impl ModelConfig {
    /// GPT-2 medium: the paper's evaluation model.
    pub fn gpt2_medium() -> Self {
        ModelConfig {
            name: "gpt2-medium".to_string(),
            d_model: 1024,
            n_layers: 24,
            n_heads: 16,
            d_ff: 4096,
            vocab: 50257,
            max_seq: 1024,
            param_bytes: 2,
        }
    }

    /// GPT-2 XL shapes (for the "larger models" discussion in §5.4/§6.2).
    pub fn gpt2_xl() -> Self {
        ModelConfig {
            name: "gpt2-xl".to_string(),
            d_model: 1600,
            n_layers: 48,
            n_heads: 25,
            d_ff: 6400,
            vocab: 50257,
            max_seq: 1024,
            param_bytes: 2,
        }
    }

    /// Scaled-down model for functional runs and the PJRT golden-model
    /// cross-check; matches `python/compile/model.py::MINI`.
    pub fn gpt2_mini() -> Self {
        ModelConfig {
            name: "gpt2-mini".to_string(),
            d_model: 128,
            n_layers: 2,
            n_heads: 4,
            d_ff: 512,
            vocab: 256,
            max_seq: 128,
            param_bytes: 2,
        }
    }

    /// Per-head dimension.
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Parameter count of one decoder layer (weights + biases):
    /// QKV (3·d²+3d) + attn out (d²+d) + FFN (2·d·dff + dff + d)
    /// + 2 layerNorms (4d).
    pub fn params_per_layer(&self) -> usize {
        let d = self.d_model;
        let f = self.d_ff;
        4 * d * d + 4 * d + 2 * d * f + f + d + 4 * d
    }

    /// Total parameters (embedding + positional + layers + final LN).
    pub fn total_params(&self) -> usize {
        self.vocab * self.d_model
            + self.max_seq * self.d_model
            + self.n_layers * self.params_per_layer()
            + 2 * self.d_model
    }

    /// Bytes the generation stage must stream per produced token
    /// (every decoder-layer weight once + the LM head).
    pub fn bytes_per_token(&self, kv_len: usize) -> usize {
        let weights = self.n_layers * self.params_per_layer() + self.vocab * self.d_model;
        let kv = self.n_layers * 2 * kv_len * self.d_model; // K and V reads
        (weights + kv) * self.param_bytes
    }

    /// Bytes of KV-cache state one token pins for the request's lifetime
    /// (K and V vectors across every layer).
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.d_model * self.param_bytes
    }

    /// FLOPs of a single-token decode step (2 × MACs), excluding
    /// nonlinearities.
    pub fn flops_per_token(&self, kv_len: usize) -> usize {
        let d = self.d_model;
        let per_layer = 2 * (4 * d * d + 2 * d * self.d_ff) + 2 * (2 * kv_len * d);
        self.n_layers * per_layer + 2 * self.vocab * d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt2_medium_param_count() {
        // The paper says "345 million parameters".
        let m = ModelConfig::gpt2_medium();
        let p = m.total_params() as f64 / 1e6;
        assert!((330.0..360.0).contains(&p), "got {p} M");
    }

    #[test]
    fn d_head() {
        assert_eq!(ModelConfig::gpt2_medium().d_head(), 64);
        assert_eq!(ModelConfig::gpt2_mini().d_head(), 32);
    }

    #[test]
    fn bytes_per_token_is_memory_bound_scale() {
        // Decode must stream ~all weights: ≥ 2 bytes × layer params.
        let m = ModelConfig::gpt2_medium();
        let b = m.bytes_per_token(0);
        assert!(b >= m.n_layers * m.params_per_layer() * 2);
        // KV reads grow with context.
        assert!(m.bytes_per_token(1024) > m.bytes_per_token(1));
    }

    #[test]
    fn kv_bytes_per_token_matches_shapes() {
        // GPT-2 medium: 2 × 24 layers × 1024 dims × 2 B = 96 KB/token.
        assert_eq!(ModelConfig::gpt2_medium().kv_bytes_per_token(), 98304);
        assert_eq!(ModelConfig::gpt2_mini().kv_bytes_per_token(), 1024);
    }

    #[test]
    fn flops_scale_with_kv() {
        let m = ModelConfig::gpt2_mini();
        assert!(m.flops_per_token(64) > m.flops_per_token(1));
    }
}
