//! The text-generation serving coordinator (L3).
//!
//! SAL-PIM is a serving-shaped system: requests (prompt + output budget)
//! arrive, the device runs summarization then token-by-token generation.
//! The coordinator owns the request queue, the scheduling policy, the
//! device-time accounting (from the cycle-accurate simulator) and the
//! per-request latency metrics. It also implements the paper's §6.3
//! future-work policy — offloading the compute-bound summarization stage
//! to a GPU while the PIM handles generation — as a first-class option.
//!
//! This is the *sequential* single-device path: one request runs to
//! completion before the next starts. The request/completion/policy/
//! metric vocabulary lives in [`crate::serve`] (shared with the
//! continuous-batching cluster engine) and is re-exported here for
//! compatibility.

pub use crate::serve::{percentile, Completion, Policy, Request, Scheduler, ServeMetrics, SloClass};

use crate::baseline::GpuModel;
use crate::config::SimConfig;
use crate::mapper::GenerationSim;
use crate::serve::fabric::FabricParams;

/// Where the summarization stage runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefillTarget {
    /// End-to-end on PIM (the paper's evaluated system).
    Pim,
    /// §6.3 heterogeneous execution: GPU prefill + PIM decode.
    GpuOffload,
}

/// The serving coordinator: one SAL-PIM device, one queue.
pub struct Coordinator {
    pub cfg: SimConfig,
    sim: GenerationSim,
    gpu: GpuModel,
    pub policy: Policy,
    pub prefill_target: PrefillTarget,
    queue: Vec<Request>,
    next_id: u64,
}

impl Coordinator {
    pub fn new(cfg: &SimConfig) -> Self {
        Coordinator {
            cfg: cfg.clone(),
            sim: GenerationSim::new(cfg),
            gpu: GpuModel::titan_rtx(),
            policy: Policy::Fcfs,
            prefill_target: PrefillTarget::Pim,
            queue: Vec::new(),
            next_id: 0,
        }
    }

    pub fn with_policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_prefill_target(mut self, t: PrefillTarget) -> Self {
        self.prefill_target = t;
        self
    }

    /// Enqueue a request; returns its id.
    pub fn submit(&mut self, prompt_len: usize, max_new_tokens: usize, arrival_s: f64) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push(Request {
            id,
            prompt_len,
            max_new_tokens,
            arrival_s,
            session: id,
            slo: SloClass::Batch,
            prefix: Vec::new(),
        });
        id
    }

    /// Enqueue a pre-built request (shared-workload experiments).
    pub fn submit_request(&mut self, req: Request) {
        self.next_id = self.next_id.max(req.id + 1);
        self.queue.push(req);
    }

    /// Service time of one request's summarization stage.
    fn prefill_time(&mut self, prompt_len: usize) -> f64 {
        match self.prefill_target {
            PrefillTarget::Pim => {
                let st = self.sim.prefill(prompt_len);
                st.seconds(self.cfg.timing.tck_ns)
            }
            PrefillTarget::GpuOffload => {
                // GPU prefill + one KV transfer over the host link —
                // the same composition `serve`'s HeteroBackend charges.
                let gpu = self.gpu.prefill_time(&self.cfg.model, prompt_len);
                gpu + FabricParams::pcie()
                    .transfer_s(prompt_len * self.cfg.model.kv_bytes_per_token())
            }
        }
    }

    /// Decode-stage time for a request, plus the decode iterations
    /// actually simulated (`max_seq` truncation stops early).
    fn decode_time(&mut self, prompt_len: usize, n_out: usize) -> (f64, usize) {
        let mut cycles = 0u64;
        let mut iters = 0usize;
        for i in 1..n_out {
            let kv = prompt_len + i;
            if kv >= self.cfg.model.max_seq {
                break;
            }
            cycles += self.sim.decode_token(kv).cycles;
            iters += 1;
        }
        (self.cfg.timing.cycles_to_sec(cycles), iters)
    }

    /// Drain the queue, producing completions in service order.
    pub fn run(&mut self) -> Vec<Completion> {
        let mut pending = std::mem::take(&mut self.queue);
        pending.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let mut completions = Vec::with_capacity(pending.len());
        let mut device_free_at = 0.0f64;
        let mut waiting: Vec<Request> = Vec::new();
        let mut arrivals = pending.into_iter().peekable();

        loop {
            // Admit everything that has arrived by the time the device
            // frees up (or the next arrival if idle).
            if waiting.is_empty() {
                match arrivals.next() {
                    Some(r) => {
                        device_free_at = device_free_at.max(r.arrival_s);
                        waiting.push(r);
                    }
                    None => break,
                }
            }
            while let Some(r) = arrivals.peek() {
                if r.arrival_s <= device_free_at {
                    waiting.push(arrivals.next().unwrap());
                } else {
                    break;
                }
            }
            // Pick per policy.
            let idx = self.policy.pick(&waiting);
            let req = waiting.swap_remove(idx);
            let start = device_free_at.max(req.arrival_s);
            let queue_s = start - req.arrival_s;
            let prefill_s = self.prefill_time(req.prompt_len);
            let (decode_s, decode_iters) = self.decode_time(req.prompt_len, req.max_new_tokens);
            let finish = start + prefill_s + decode_s;
            device_free_at = finish;
            completions.push(Completion {
                id: req.id,
                prompt_len: req.prompt_len,
                tokens_out: req.max_new_tokens,
                // Prefill emits the first token, then the simulated
                // decode iterations.
                tokens_simulated: 1 + decode_iters,
                queue_s,
                prefill_s,
                decode_s,
                finish_s: finish,
                device: 0,
                slo: req.slo,
            });
        }
        completions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coord() -> Coordinator {
        Coordinator::new(&SimConfig::paper())
    }

    #[test]
    fn single_request_completes() {
        let mut c = coord();
        c.submit(32, 8, 0.0);
        let done = c.run();
        assert_eq!(done.len(), 1);
        let r = &done[0];
        assert_eq!(r.queue_s, 0.0);
        assert!(r.prefill_s > 0.0 && r.decode_s > 0.0);
        assert!(r.ttft_s() < r.total_latency_s());
    }

    #[test]
    fn queueing_delay_accumulates() {
        let mut c = coord();
        c.submit(32, 8, 0.0);
        c.submit(32, 8, 0.0);
        c.submit(32, 8, 0.0);
        let done = c.run();
        assert_eq!(done.len(), 3);
        assert!(done[1].queue_s > 0.0);
        assert!(done[2].queue_s > done[1].queue_s);
    }

    #[test]
    fn idle_gaps_do_not_charge_queueing() {
        let mut c = coord();
        c.submit(32, 4, 0.0);
        c.submit(32, 4, 1000.0); // arrives long after the first finishes
        let done = c.run();
        assert_eq!(done[1].queue_s, 0.0);
    }

    #[test]
    fn submitted_requests_flow_like_submitted_tuples() {
        let mut c = coord();
        c.submit_request(Request {
            id: 9,
            prompt_len: 32,
            max_new_tokens: 4,
            arrival_s: 0.0,
            session: 3,
            slo: SloClass::Batch,
            prefix: Vec::new(),
        });
        let done = c.run();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, 9);
        // Auto-ids continue past explicit ones.
        assert_eq!(c.submit(32, 4, 1.0), 10);
    }

    #[test]
    fn sjf_reorders_waiting_requests() {
        let mut c = coord().with_policy(Policy::ShortestJobFirst);
        c.submit(32, 256, 0.0); // long job first
        c.submit(32, 2, 1e-9); // short job arrives while long one queued?
        // Both present at t≈0; SJF must run the short one first among the
        // waiting set at each decision point.
        let done = c.run();
        let short = done.iter().find(|r| r.tokens_out == 2).unwrap();
        let long = done.iter().find(|r| r.tokens_out == 256).unwrap();
        // The long job was started first (it was alone), but any requests
        // waiting together get SJF ordering; with both at t≈0 the device
        // picks at t=0 from {long} only. So instead check explicit set:
        let mut c2 = coord().with_policy(Policy::ShortestJobFirst);
        c2.submit(32, 256, 0.0);
        c2.submit(32, 2, 0.0);
        let done2 = c2.run();
        assert_eq!(done2[0].tokens_out, 2, "SJF must pick the short job");
        let _ = (short, long);
    }

    #[test]
    fn gpu_offload_prefill_is_faster_for_long_prompts() {
        // §6.3: heterogeneous execution unlocks the summarization
        // bottleneck.
        let mut pim = coord();
        pim.submit(128, 4, 0.0);
        let pim_done = pim.run();

        let mut hybrid = coord().with_prefill_target(PrefillTarget::GpuOffload);
        hybrid.submit(128, 4, 0.0);
        let hy_done = hybrid.run();

        assert!(
            hy_done[0].prefill_s < pim_done[0].prefill_s,
            "hybrid {} !< pim {}",
            hy_done[0].prefill_s,
            pim_done[0].prefill_s
        );
        // Decode stays on PIM: identical.
        assert!((hy_done[0].decode_s - pim_done[0].decode_s).abs() < 1e-12);
    }
}
