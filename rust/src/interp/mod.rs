//! LUT-based linear interpolation (§2.3, §4.2, Fig. 4).
//!
//! Non-linear functions (GELU, exp, reciprocal square root, reciprocal)
//! are approximated as `f(x) ≈ W[s]·x + B[s]` where `s` is the uniform
//! section of `x` within a calibrated range. The slope/intercept tables
//! live in LUT-embedded subarrays; the bank-level unit decodes `x` into
//! column/LUT-select signals (a shift-and-clamp on the fixed-point raw
//! value), and the S-ALU performs the multiply-add.
//!
//! [`LutTable`] is the *bit-exact* model of that pipeline: tables are
//! quantized to the 16-bit formats the DRAM cells store, the index decode
//! mirrors the bank-level unit's bit-position shifter, and evaluation uses
//! the same fixed-point multiply-add as the S-ALU. The same tables are
//! exported for the Pallas kernel (`make artifacts` writes
//! `artifacts/luts/*.txt`) so L1 and L3 interpolate identically.

mod accuracy;
mod lut;

pub use accuracy::{accuracy_report, max_abs_error, mean_abs_error, min_sections_for};
pub use lut::{LutTable, NonLinFn};
