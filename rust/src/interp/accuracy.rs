//! Interpolation accuracy analysis (Fig. 4 and the §2.3 claim that ≥32
//! sections lose no task accuracy).

use super::lut::{LutTable, NonLinFn};
use crate::model::fixedpoint::QFormat;

/// Maximum absolute interpolation error of `table` against the exact
/// function, sampled at `samples` points across the evaluation range.
pub fn max_abs_error(table: &LutTable, samples: usize) -> f64 {
    sample_errors(table, samples)
        .into_iter()
        .fold(0.0f64, f64::max)
}

/// Mean absolute interpolation error.
pub fn mean_abs_error(table: &LutTable, samples: usize) -> f64 {
    let errs = sample_errors(table, samples);
    errs.iter().sum::<f64>() / errs.len() as f64
}

fn sample_errors(table: &LutTable, samples: usize) -> Vec<f64> {
    assert!(samples >= 2);
    // For range-reduced functions sample a wide positive range (multiple
    // octaves around the mantissa table) and measure *relative* error —
    // the hardware shifts the table output by the input's octave, so
    // absolute error scales with the output magnitude. Direct functions
    // use absolute error over the table range.
    let relative = table.func.range_reduced();
    let (lo, hi) = if relative {
        (0.05f64, 32.0f64)
    } else {
        (table.lo, table.hi)
    };
    (0..samples)
        .map(|i| {
            let x = lo + (hi - lo) * i as f64 / (samples - 1) as f64;
            let want = table.func.eval_exact(x);
            let err = (table.eval(x) - want).abs();
            if relative {
                err / want.abs().max(1e-12)
            } else {
                err
            }
        })
        .collect()
}

/// Smallest power-of-two section count in `[4, max_sections]` whose max
/// abs error is below `tol` (the Fig. 4 "how many sections do we need"
/// question). Returns `None` if even `max_sections` misses the tolerance.
pub fn min_sections_for(
    func: NonLinFn,
    tol: f64,
    max_sections: usize,
    q_in: QFormat,
    q_out: QFormat,
) -> Option<usize> {
    let mut sections = 4;
    while sections <= max_sections {
        let t = LutTable::build(func, sections, q_in, q_out);
        if max_abs_error(&t, 4096) < tol {
            return Some(sections);
        }
        sections *= 2;
    }
    None
}

/// One row of the Fig. 4 accuracy report.
#[derive(Debug, Clone)]
pub struct AccuracyRow {
    pub func: NonLinFn,
    pub sections: usize,
    pub max_err: f64,
    pub mean_err: f64,
}

/// Error table for every function × section count (the Fig. 4 sweep).
pub fn accuracy_report(
    section_counts: &[usize],
    q_in: QFormat,
    q_out: QFormat,
) -> Vec<AccuracyRow> {
    let mut rows = Vec::new();
    for &func in &NonLinFn::ALL {
        for &sections in section_counts {
            let t = LutTable::build(func, sections, q_in, q_out);
            rows.push(AccuracyRow {
                func,
                sections,
                max_err: max_abs_error(&t, 4096),
                mean_err: mean_abs_error(&t, 4096),
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixedpoint::Q8_8;

    #[test]
    fn error_shrinks_with_sections() {
        for func in [NonLinFn::Gelu, NonLinFn::Exp, NonLinFn::Tanh] {
            let coarse = LutTable::build(func, 8, Q8_8, Q8_8);
            let fine = LutTable::build(func, 128, Q8_8, Q8_8);
            assert!(
                max_abs_error(&fine, 2048) <= max_abs_error(&coarse, 2048),
                "{func:?}"
            );
        }
    }

    #[test]
    fn paper_claim_32_sections_suffice() {
        // §2.3: "the accuracy was kept when the number of sections was
        // larger than 32" — at 32+ sections every function's max error is
        // within a few quantization steps of the 16-bit representation.
        for func in NonLinFn::ALL {
            let t = LutTable::build(func, 32, Q8_8, Q8_8);
            let err = max_abs_error(&t, 4096);
            assert!(err < 0.09, "{func:?} err at 32 sections: {err}");
        }
    }

    #[test]
    fn min_sections_finds_crossover() {
        let s = min_sections_for(NonLinFn::Gelu, 0.05, 256, Q8_8, Q8_8);
        assert!(s.is_some());
        assert!(s.unwrap() <= 64);
    }

    #[test]
    fn min_sections_none_for_impossible_tol() {
        // Tolerance below the quantization floor can never be met.
        let s = min_sections_for(NonLinFn::Gelu, 1e-9, 64, Q8_8, Q8_8);
        assert!(s.is_none());
    }

    #[test]
    fn report_covers_all_functions() {
        let rows = accuracy_report(&[16, 64], Q8_8, Q8_8);
        assert_eq!(rows.len(), NonLinFn::ALL.len() * 2);
        assert!(rows.iter().all(|r| r.max_err >= r.mean_err));
    }
}
