//! Slope/intercept table generation and bit-exact evaluation.

use crate::model::fixedpoint::QFormat;

/// Fixed-point format of stored slopes (Q2.13: slopes of all supported
/// functions fall in (−4, 4)).
pub const SLOPE_FRAC: u32 = 13;

/// The non-linear functions SAL-PIM interpolates (§5.1: "linear
/// interpolation with 64 sections on GELU, exp, sqrt, and reciprocal").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NonLinFn {
    /// GELU activation (FFN). Direct table over [-8, 8).
    Gelu,
    /// exp(x) for x ≤ 0 (softmax after max-subtraction). Table over [-16, 0).
    Exp,
    /// 1/√x (layerNorm). Range-reduced: table over mantissa [1, 4).
    Rsqrt,
    /// 1/x (softmax normalization). Range-reduced: table over [1, 2).
    Recip,
    /// tanh(x). Direct table over [-4, 4). (Used by the GELU-exact
    /// ablation and kept for parity with MVP-style LUT units.)
    Tanh,
}

impl NonLinFn {
    pub const ALL: [NonLinFn; 5] = [
        NonLinFn::Gelu,
        NonLinFn::Exp,
        NonLinFn::Rsqrt,
        NonLinFn::Recip,
        NonLinFn::Tanh,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            NonLinFn::Gelu => "gelu",
            NonLinFn::Exp => "exp",
            NonLinFn::Rsqrt => "rsqrt",
            NonLinFn::Recip => "recip",
            NonLinFn::Tanh => "tanh",
        }
    }

    /// Ground-truth function value.
    pub fn eval_exact(&self, x: f64) -> f64 {
        match self {
            NonLinFn::Gelu => {
                // GPT-2's tanh-approximation GELU (what FasterTransformer
                // computes, and what the paper's "complex functions (tanh
                // and sqrt)" refers to).
                0.5 * x
                    * (1.0 + ((2.0 / std::f64::consts::PI).sqrt() * (x + 0.044715 * x.powi(3))).tanh())
            }
            NonLinFn::Exp => x.exp(),
            NonLinFn::Rsqrt => 1.0 / x.sqrt(),
            NonLinFn::Recip => 1.0 / x,
            NonLinFn::Tanh => x.tanh(),
        }
    }

    /// Direct-table input range `[lo, hi)`. For range-reduced functions
    /// this is the mantissa range.
    pub fn table_range(&self) -> (f64, f64) {
        match self {
            NonLinFn::Gelu => (-8.0, 8.0),
            NonLinFn::Exp => (-16.0, 0.0),
            // Mantissa lives in [1, 4); the table is decoded over [0, 4)
            // so the raw span stays a power of two (the bank-level unit's
            // shift decode requires it). Sections below 1.0 are never hit.
            NonLinFn::Rsqrt => (0.0, 4.0),
            NonLinFn::Recip => (1.0, 2.0),
            NonLinFn::Tanh => (-4.0, 4.0),
        }
    }

    /// Does evaluation range-reduce the input by a power of two first
    /// (the bank-level unit's bit-position decode, §4.3)?
    pub fn range_reduced(&self) -> bool {
        matches!(self, NonLinFn::Rsqrt | NonLinFn::Recip)
    }
}

/// A quantized slope/intercept table plus the decode parameters — the
/// exact contents of a LUT-embedded subarray for one function.
#[derive(Debug, Clone)]
pub struct LutTable {
    pub func: NonLinFn,
    pub sections: usize,
    /// Raw Q2.13 slopes, one per section.
    pub slopes: Vec<i16>,
    /// Raw intercepts in `q_out`, one per section.
    pub intercepts: Vec<i16>,
    /// Input fixed-point format.
    pub q_in: QFormat,
    /// Output fixed-point format.
    pub q_out: QFormat,
    /// Table range in input units.
    pub lo: f64,
    pub hi: f64,
    /// Right-shift that maps (raw − lo_raw) to a section index — the
    /// bank-level unit's bit-position shifter. Exact because ranges and
    /// section counts are powers of two.
    pub index_shift: u32,
    /// `lo` quantized into `q_in` raw units.
    pub lo_raw: i32,
}

impl LutTable {
    /// Build the table: endpoint-fit linear interpolation on uniform
    /// sections, quantized to the storage formats.
    ///
    /// Panics if the raw span is not `sections × 2^k` (the hardware
    /// decode needs a pure shift) — all provided ranges/section counts
    /// satisfy this.
    pub fn build(func: NonLinFn, sections: usize, q_in: QFormat, q_out: QFormat) -> Self {
        assert!(sections.is_power_of_two(), "sections must be 2^k");
        let (lo, hi) = func.table_range();
        let span_raw = ((hi - lo) * q_in.scale()) as i64;
        assert!(
            span_raw > 0 && span_raw % sections as i64 == 0,
            "range {lo}..{hi} not divisible into {sections} raw sections"
        );
        let per_section = (span_raw / sections as i64) as u64;
        assert!(
            per_section.is_power_of_two(),
            "section width {per_section} raw units is not a power of two"
        );
        let index_shift = per_section.trailing_zeros();

        let width = (hi - lo) / sections as f64;
        let q_slope = QFormat { frac_bits: SLOPE_FRAC };
        let mut slopes = Vec::with_capacity(sections);
        let mut intercepts = Vec::with_capacity(sections);
        for s in 0..sections {
            let x0 = lo + s as f64 * width;
            let x1 = x0 + width;
            // Range-reduced functions never see inputs below their
            // mantissa floor (1.0); keep unused low sections finite.
            let floor = if func.range_reduced() { 0.5 * width.min(1.0) } else { f64::NEG_INFINITY };
            let y0 = func.eval_exact(x0.max(floor));
            let y1 = func.eval_exact(x1.max(floor));
            let w = (y1 - y0) / width;
            let b = y0 - w * x0;
            slopes.push(q_slope.quantize(w));
            intercepts.push(q_out.quantize(b));
        }
        LutTable {
            func,
            sections,
            slopes,
            intercepts,
            q_in,
            q_out,
            lo,
            hi,
            index_shift,
            lo_raw: (lo * q_in.scale()) as i32,
        }
    }

    /// Decode a raw input into its section index — the column-select /
    /// LUT-select generation of the bank-level unit (clamps into range,
    /// which the paper's masking of out-of-range inputs also does).
    pub fn section_of(&self, raw: i16) -> usize {
        let offset = (raw as i32 - self.lo_raw).max(0);
        ((offset >> self.index_shift) as usize).min(self.sections - 1)
    }

    /// Bit-exact fixed-point evaluation of one element — the S-ALU
    /// multiply-add: `(W[s]·x) >> shift + B[s]`, saturated.
    pub fn eval_raw(&self, raw: i16) -> i16 {
        let s = self.section_of(raw);
        let w = self.slopes[s] as i64;
        // Product has SLOPE_FRAC + q_in.frac fractional bits; shift down
        // to q_out.frac (arithmetic shift, like the writeback shifter).
        let shift = SLOPE_FRAC + self.q_in.frac_bits - self.q_out.frac_bits;
        let prod = (w * raw as i64) >> shift;
        let y = prod + self.intercepts[s] as i64;
        y.clamp(i16::MIN as i64, i16::MAX as i64) as i16
    }

    /// Evaluate through the full pipeline in float domain:
    /// quantize → (optional range reduction) → table → dequantize.
    pub fn eval(&self, x: f64) -> f64 {
        match self.func {
            NonLinFn::Rsqrt => {
                if x <= 0.0 {
                    return self.q_out.max_value(); // hardware clamp
                }
                // x = m · 4^k with m ∈ [1,4): rsqrt(x) = rsqrt(m) · 2^−k.
                let mut m = x;
                let mut k: i32 = 0;
                while m >= 4.0 {
                    m /= 4.0;
                    k += 1;
                }
                while m < 1.0 {
                    m *= 4.0;
                    k -= 1;
                }
                let base = self.q_out.dequantize(self.eval_raw(self.q_in.quantize(m)));
                base * 2f64.powi(-k)
            }
            NonLinFn::Recip => {
                if x <= 0.0 {
                    return self.q_out.max_value();
                }
                // x = m · 2^k with m ∈ [1,2): 1/x = (1/m) · 2^−k.
                let mut m = x;
                let mut k: i32 = 0;
                while m >= 2.0 {
                    m /= 2.0;
                    k += 1;
                }
                while m < 1.0 {
                    m *= 2.0;
                    k -= 1;
                }
                let base = self.q_out.dequantize(self.eval_raw(self.q_in.quantize(m)));
                base * 2f64.powi(-k)
            }
            _ => {
                // Direct functions: clamp into table range (edge sections
                // extrapolate flat/linear exactly as the hardware decode
                // clamps the section index).
                let xc = x.clamp(self.lo, self.hi - self.q_in.epsilon());
                self.q_out.dequantize(self.eval_raw(self.q_in.quantize(xc)))
            }
        }
    }

    /// Evaluate a whole raw vector (one LUT-embedded-subarray sweep).
    pub fn eval_raw_vec(&self, raw: &[i16]) -> Vec<i16> {
        raw.iter().map(|&r| self.eval_raw(r)).collect()
    }

    /// Serialize to the artifact text format shared with the Pallas
    /// kernel (`artifacts/luts/<fn>_<sections>.txt`): header line, then
    /// one `slope intercept` raw pair per line.
    pub fn to_artifact_text(&self) -> String {
        let mut s = format!(
            "# lut {} sections={} q_in={} q_out={} slope_frac={} lo={} hi={}\n",
            self.func.name(),
            self.sections,
            self.q_in.frac_bits,
            self.q_out.frac_bits,
            SLOPE_FRAC,
            self.lo,
            self.hi
        );
        for i in 0..self.sections {
            s.push_str(&format!("{} {}\n", self.slopes[i], self.intercepts[i]));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixedpoint::{Q8_8};

    fn table(f: NonLinFn, sections: usize) -> LutTable {
        LutTable::build(f, sections, Q8_8, Q8_8)
    }

    #[test]
    fn all_functions_build_at_paper_sections() {
        for f in NonLinFn::ALL {
            let t = table(f, 64);
            assert_eq!(t.slopes.len(), 64);
            assert_eq!(t.intercepts.len(), 64);
        }
    }

    #[test]
    fn section_decode_covers_range() {
        let t = table(NonLinFn::Gelu, 64);
        assert_eq!(t.section_of(t.q_in.quantize(-8.0)), 0);
        assert_eq!(t.section_of(t.q_in.quantize(7.99)), 63);
        // Out-of-range clamps.
        assert_eq!(t.section_of(i16::MIN), 0);
        assert_eq!(t.section_of(i16::MAX), 63);
    }

    #[test]
    fn gelu_64_sections_is_accurate() {
        let t = table(NonLinFn::Gelu, 64);
        let mut max_err: f64 = 0.0;
        let mut x = -8.0;
        while x < 8.0 {
            max_err = max_err.max((t.eval(x) - NonLinFn::Gelu.eval_exact(x)).abs());
            x += 0.01;
        }
        // Two quantization steps + interpolation error.
        assert!(max_err < 0.03, "gelu max err {max_err}");
    }

    #[test]
    fn exp_table_accurate_in_softmax_range() {
        let t = table(NonLinFn::Exp, 64);
        let mut x = -16.0;
        while x < 0.0 {
            let err = (t.eval(x) - x.exp()).abs();
            assert!(err < 0.05, "exp({x}) err {err}");
            x += 0.01;
        }
    }

    #[test]
    fn rsqrt_range_reduction_tracks_exact() {
        let t = table(NonLinFn::Rsqrt, 64);
        for x in [0.01f64, 0.1, 0.5, 1.0, 2.0, 7.3, 64.0, 300.0] {
            let got = t.eval(x);
            let want = 1.0 / x.sqrt();
            let rel = (got - want).abs() / want;
            assert!(rel < 0.05, "rsqrt({x}): got {got} want {want}");
        }
    }

    #[test]
    fn recip_range_reduction_tracks_exact() {
        let t = table(NonLinFn::Recip, 64);
        for x in [0.02f64, 0.3, 1.0, 1.5, 4.0, 100.0] {
            let got = t.eval(x);
            let want = 1.0 / x;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.05, "recip({x}): got {got} want {want}");
        }
    }

    #[test]
    fn eval_raw_is_pure_integer_pipeline() {
        // Same raw input → same raw output, and matches eval() for direct
        // in-range values.
        let t = table(NonLinFn::Tanh, 64);
        let raw = t.q_in.quantize(0.7);
        assert_eq!(t.eval_raw(raw), t.eval_raw(raw));
        let via_eval = t.eval(0.7);
        let via_raw = t.q_out.dequantize(t.eval_raw(raw));
        assert_eq!(via_eval, via_raw);
    }

    #[test]
    fn artifact_text_roundtrips_shape() {
        let t = table(NonLinFn::Exp, 32);
        let text = t.to_artifact_text();
        assert!(text.starts_with("# lut exp sections=32"));
        assert_eq!(text.lines().count(), 33);
    }

    #[test]
    fn more_sections_never_hurt_much() {
        // Monotone-ish improvement: 128 sections ≤ error of 16 sections.
        let coarse = table(NonLinFn::Gelu, 16);
        let fine = table(NonLinFn::Gelu, 128);
        let err = |t: &LutTable| {
            let mut e: f64 = 0.0;
            let mut x = -8.0;
            while x < 8.0 {
                e += (t.eval(x) - NonLinFn::Gelu.eval_exact(x)).abs();
                x += 0.05;
            }
            e
        };
        assert!(err(&fine) < err(&coarse));
    }
}
