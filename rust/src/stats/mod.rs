//! Simulation statistics: cycle accounting, command counts, bandwidth and
//! per-phase execution-time breakdowns (the raw material for Fig. 3,
//! Fig. 14 and EXPERIMENTS.md).

use std::collections::BTreeMap;
use std::fmt;

/// Execution phases attributed in breakdowns (paper Fig. 3 categories).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    /// Token/positional embedding lookups & adds.
    Embedding,
    /// Multi-head attention (QKV gen, QKᵀ, softmax matmuls, output proj).
    Mha,
    /// Feed-forward network GEMVs.
    Ffn,
    /// Non-linear functions (softmax exp/recip, GELU, layerNorm rsqrt).
    NonLinear,
    /// Residual adds and misc element-wise work.
    Residual,
    /// LM head / logits.
    LmHead,
    /// Inter-level data movement (bank↔C-ALU↔broadcast).
    DataMovement,
}

impl Phase {
    pub const ALL: [Phase; 7] = [
        Phase::Embedding,
        Phase::Mha,
        Phase::Ffn,
        Phase::NonLinear,
        Phase::Residual,
        Phase::LmHead,
        Phase::DataMovement,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::Embedding => "embedding",
            Phase::Mha => "mha",
            Phase::Ffn => "ffn",
            Phase::NonLinear => "nonlinear",
            Phase::Residual => "residual",
            Phase::LmHead => "lm_head",
            Phase::DataMovement => "data_movement",
        }
    }
}

/// DRAM command kinds counted by the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum CmdKind {
    Act,
    Pre,
    Rd,
    Wr,
    /// PIM compute micro-ops executed alongside RD streams.
    PimOp,
    /// C-ALU operations (accumulate / reduce-sum / broadcast).
    CaluOp,
}

/// Aggregated counters for one simulation run.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    /// Total simulated cycles (per-channel clock, lockstep channels).
    pub cycles: u64,
    /// Cycles attributed per phase.
    pub phase_cycles: BTreeMap<Phase, u64>,
    /// Command counts per kind (summed over all banks/channels).
    pub commands: BTreeMap<CmdKind, u64>,
    /// Bytes streamed through GBLs into S-ALUs (internal traffic).
    pub internal_bytes: u64,
    /// Bytes moved over the buffer-die interconnect / to host.
    pub external_bytes: u64,
    /// Row activations (for energy).
    pub activations: u64,
    /// Simulated tokens produced (generation stage).
    pub tokens_generated: u64,
}

impl Stats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_phase_cycles(&mut self, phase: Phase, cycles: u64) {
        *self.phase_cycles.entry(phase).or_insert(0) += cycles;
        self.cycles += cycles;
    }

    pub fn count_cmd(&mut self, kind: CmdKind, n: u64) {
        *self.commands.entry(kind).or_insert(0) += n;
        if kind == CmdKind::Act {
            self.activations += n;
        }
    }

    /// Merge another run's counters into this one (e.g. per-token stats).
    pub fn merge(&mut self, other: &Stats) {
        self.cycles += other.cycles;
        for (p, c) in &other.phase_cycles {
            *self.phase_cycles.entry(*p).or_insert(0) += c;
        }
        for (k, c) in &other.commands {
            *self.commands.entry(*k).or_insert(0) += c;
        }
        self.internal_bytes += other.internal_bytes;
        self.external_bytes += other.external_bytes;
        self.activations += other.activations;
        self.tokens_generated += other.tokens_generated;
    }

    /// Wall-clock seconds at a given tCK.
    pub fn seconds(&self, tck_ns: f64) -> f64 {
        self.cycles as f64 * tck_ns * 1e-9
    }

    /// Average achieved internal bandwidth in bytes/sec.
    pub fn avg_internal_bandwidth(&self, tck_ns: f64) -> f64 {
        let s = self.seconds(tck_ns);
        if s == 0.0 {
            0.0
        } else {
            self.internal_bytes as f64 / s
        }
    }

    /// Fraction of total cycles attributed to `phase`.
    pub fn phase_fraction(&self, phase: Phase) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        *self.phase_cycles.get(&phase).unwrap_or(&0) as f64 / self.cycles as f64
    }

    /// Breakdown as (phase, fraction) sorted by descending share.
    pub fn breakdown(&self) -> Vec<(Phase, f64)> {
        let mut v: Vec<_> = Phase::ALL
            .iter()
            .map(|p| (*p, self.phase_fraction(*p)))
            .filter(|(_, f)| *f > 0.0)
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }
}

impl fmt::Display for Stats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "cycles: {}", self.cycles)?;
        writeln!(f, "tokens: {}", self.tokens_generated)?;
        writeln!(
            f,
            "internal bytes: {} ({:.1} MB)",
            self.internal_bytes,
            self.internal_bytes as f64 / 1e6
        )?;
        for (p, frac) in self.breakdown() {
            writeln!(f, "  {:>13}: {:5.2}%", p.name(), frac * 100.0)?;
        }
        for (k, c) in &self.commands {
            writeln!(f, "  {:?}: {}", k, c)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_accounting_sums() {
        let mut s = Stats::new();
        s.add_phase_cycles(Phase::Mha, 50);
        s.add_phase_cycles(Phase::Ffn, 30);
        s.add_phase_cycles(Phase::NonLinear, 20);
        assert_eq!(s.cycles, 100);
        assert!((s.phase_fraction(Phase::Mha) - 0.5).abs() < 1e-12);
        let bd = s.breakdown();
        assert_eq!(bd[0].0, Phase::Mha);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Stats::new();
        a.add_phase_cycles(Phase::Ffn, 10);
        a.count_cmd(CmdKind::Act, 3);
        a.internal_bytes = 100;
        let mut b = Stats::new();
        b.add_phase_cycles(Phase::Ffn, 5);
        b.count_cmd(CmdKind::Act, 2);
        b.internal_bytes = 50;
        a.merge(&b);
        assert_eq!(a.cycles, 15);
        assert_eq!(a.commands[&CmdKind::Act], 5);
        assert_eq!(a.activations, 5);
        assert_eq!(a.internal_bytes, 150);
    }

    #[test]
    fn bandwidth_math() {
        let mut s = Stats::new();
        s.cycles = 1_000_000_000; // 1 s at 1 GHz
        s.internal_bytes = 8_000_000_000_000; // 8 TB
        let bw = s.avg_internal_bandwidth(1.0);
        assert!((bw - 8e12).abs() / 8e12 < 1e-9);
    }

    #[test]
    fn empty_stats_safe() {
        let s = Stats::new();
        assert_eq!(s.avg_internal_bandwidth(1.0), 0.0);
        assert_eq!(s.phase_fraction(Phase::Mha), 0.0);
        assert!(s.breakdown().is_empty());
    }
}
