//! Declarative command/flag tables.
//!
//! Every `sal-pim` command declares its surface as a [`CommandSpec`]: a
//! table of [`FlagSpec`]s (name, arity, default, help). Parsing, `--help`
//! text, the README CLI section (`sal-pim help --markdown`) and
//! unknown-flag rejection are all generated from the same table, so a
//! flag exists exactly once and a typo'd flag is a hard error instead of
//! a silently-ignored no-op.

use std::fmt::Write as _;

/// Whether a flag consumes a value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arity {
    /// Bare switch; never consumes the next token.
    Switch,
    /// Always takes one value (`--flag V` or `--flag=V`).
    Value,
    /// Takes a value when one follows (`--flag V` / `--flag=V`), else
    /// acts as a switch with a documented bare-form default.
    OptionalValue,
}

/// One flag of one command.
#[derive(Debug, Clone, Copy)]
pub struct FlagSpec {
    pub name: &'static str,
    pub arity: Arity,
    /// Placeholder shown in help for value-taking flags (`N`, `FILE`…).
    pub value_name: &'static str,
    /// Default shown in help; `""` means "no default" (optional flag).
    pub default: &'static str,
    pub help: &'static str,
}

impl FlagSpec {
    const fn switch(name: &'static str, help: &'static str) -> Self {
        FlagSpec {
            name,
            arity: Arity::Switch,
            value_name: "",
            default: "",
            help,
        }
    }

    const fn value(
        name: &'static str,
        value_name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        FlagSpec {
            name,
            arity: Arity::Value,
            value_name,
            default,
            help,
        }
    }

    const fn optional_value(
        name: &'static str,
        value_name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        FlagSpec {
            name,
            arity: Arity::OptionalValue,
            value_name,
            default,
            help,
        }
    }

    /// `--name` / `--name N` as shown in usage lines.
    pub fn usage(&self) -> String {
        match self.arity {
            Arity::Switch => format!("--{}", self.name),
            Arity::Value => format!("--{} {}", self.name, self.value_name),
            Arity::OptionalValue => format!("--{} [{}]", self.name, self.value_name),
        }
    }
}

/// One positional argument of one command.
#[derive(Debug, Clone, Copy)]
pub struct PositionalSpec {
    /// Placeholder shown in usage (`BASELINE`, `NEW`…).
    pub name: &'static str,
    pub help: &'static str,
}

/// One CLI command: name, one-line summary, positional and flag tables.
#[derive(Debug, Clone)]
pub struct CommandSpec {
    pub name: &'static str,
    pub summary: &'static str,
    /// Required positional arguments, in order (most commands have none).
    pub positionals: Vec<PositionalSpec>,
    pub flags: Vec<FlagSpec>,
}

impl CommandSpec {
    pub fn flag(&self, name: &str) -> Option<&FlagSpec> {
        self.flags.iter().find(|f| f.name == name)
    }

    /// Per-command `--help` text.
    pub fn help_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "sal-pim {} — {}", self.name, self.summary);
        if !self.positionals.is_empty() {
            let args: Vec<&str> = self.positionals.iter().map(|p| p.name).collect();
            let _ = writeln!(out, "\nusage: sal-pim {} {} [flags]", self.name, args.join(" "));
            let _ = writeln!(out, "\narguments:");
            let width = self
                .positionals
                .iter()
                .map(|p| p.name.len())
                .max()
                .unwrap_or(0);
            for p in &self.positionals {
                let _ = writeln!(out, "  {:<width$}  {}", p.name, p.help, width = width);
            }
        }
        let _ = writeln!(out, "\nflags:");
        let width = self
            .flags
            .iter()
            .map(|f| f.usage().len())
            .max()
            .unwrap_or(0);
        for f in &self.flags {
            let default = if f.default.is_empty() {
                String::new()
            } else {
                format!(" (default {})", f.default)
            };
            let _ = writeln!(
                out,
                "  {:<width$}  {}{}",
                f.usage(),
                f.help,
                default,
                width = width
            );
        }
        out
    }
}

/// Flags shared by every command that resolves a simulator config.
fn config_flags() -> Vec<FlagSpec> {
    vec![
        FlagSpec::value("preset", "P", "paper", "simulator preset: paper|mini"),
        FlagSpec::value("file", "FILE", "", "key = value config override file"),
        FlagSpec::value("p-sub", "N", "", "override subarray-level parallelism P_Sub"),
    ]
}

/// Flags every command supports for machine-readable output.
fn output_flags() -> Vec<FlagSpec> {
    vec![
        FlagSpec::switch("json", "print the outcome as schema-versioned JSON"),
        FlagSpec::value(
            "out",
            "FILE",
            "",
            "also write the outcome to FILE (.json/.csv by extension)",
        ),
    ]
}

fn with_common(mut extra: Vec<FlagSpec>) -> Vec<FlagSpec> {
    let mut flags = config_flags();
    flags.append(&mut extra);
    flags.append(&mut output_flags());
    flags
}

/// The full command table — the single source of truth for the CLI.
pub fn commands() -> Vec<CommandSpec> {
    vec![
        CommandSpec {
            name: "config",
            summary: "resolve and validate a simulator configuration",
            positionals: vec![],
            flags: with_common(vec![]),
        },
        CommandSpec {
            name: "simulate",
            summary: "one end-to-end generation on SAL-PIM vs the GPU baseline",
            positionals: vec![],
            flags: with_common(vec![
                FlagSpec::value("in", "N", "32", "prompt tokens"),
                FlagSpec::value("gen", "N", "64", "generated (output) tokens"),
                FlagSpec::switch("prefetch", "enable next-row prefetch in the simulator"),
            ]),
        },
        CommandSpec {
            name: "sweep",
            summary: "the Fig. 11 speedup grid over prompt/output sizes",
            positionals: vec![],
            flags: with_common(vec![]),
        },
        CommandSpec {
            name: "breakdown",
            summary: "decode-iteration phase breakdown (Fig. 3)",
            positionals: vec![],
            flags: with_common(vec![FlagSpec::value("kv", "N", "128", "KV length of the iteration")]),
        },
        CommandSpec {
            name: "power",
            summary: "power by subarray-level parallelism (Fig. 15)",
            positionals: vec![],
            flags: with_common(vec![FlagSpec::value("gen", "N", "32", "generated tokens per run")]),
        },
        CommandSpec {
            name: "area",
            summary: "added-logic area per channel (Table 3)",
            positionals: vec![],
            flags: with_common(vec![]),
        },
        CommandSpec {
            name: "serve",
            summary: "serve a request mix on the sequential/batching/cluster/disagg engines",
            positionals: vec![],
            flags: with_common(vec![
                FlagSpec::value("requests", "N", "16", "request count"),
                FlagSpec::value(
                    "policy",
                    "P",
                    "fcfs",
                    "queue policy: fcfs|sjf|spf|priority (priority boosts interactive-SLO \
                     requests, starvation-free)",
                ),
                FlagSpec::value(
                    "workload",
                    "SPEC",
                    "",
                    "typed workload spec: ARRIVAL[,key=value]* with arrival \
                     at-once|jittered:S|poisson:R|bursty:R:B and keys sessions=N, \
                     multiturn=TURNS:THINK, prefix=ROOT:GROUPS:TOKENS, \
                     lengths=paper|small|heavy:MINP:MINO:CAP, interactive=SHARE; \
                     supersedes the legacy --at-once/--rate/--burst/--sessions aliases",
                ),
                FlagSpec::value(
                    "prefix-cache",
                    "M",
                    "session",
                    "KV prefix caching: session (per-session residency) | radix \
                     (cross-session radix-tree sharing; needs --kv-policy paged)",
                ),
                FlagSpec::value("engine", "E", "seq", "engine: seq|batch|cluster|disagg"),
                FlagSpec::value(
                    "engine-core",
                    "C",
                    "event",
                    "batching run-loop core: event (O(log n) discrete-event) | legacy \
                     (token-boundary scan; bit-identical escape hatch)",
                ),
                FlagSpec::value("devices", "N", "4", "cluster size"),
                FlagSpec::value("batch", "N", "8", "continuous-batching slots per device"),
                FlagSpec::value("route", "R", "rr", "cluster routing: rr|ll|affinity"),
                FlagSpec::value(
                    "fabric",
                    "F",
                    "pcie",
                    "host interconnect for KV migration/swap: pcie|nvlink|ideal",
                ),
                FlagSpec::value(
                    "prefill-pool",
                    "N",
                    "",
                    "disagg prefill-pool size (default: half of --devices)",
                ),
                FlagSpec::value(
                    "decode-pool",
                    "N",
                    "",
                    "disagg decode-pool size (default: remaining --devices)",
                ),
                FlagSpec::value(
                    "schedule",
                    "SPEC",
                    "",
                    "typed schedule spec: POLICY[,key=value]* with policy \
                     static:<salpim|gpu|banklevel|hetero> (one backend everywhere) | \
                     phase (re-place each request's next phase across a gpu+pim pool \
                     split at every token boundary; engine cluster, pools sized by \
                     --prefill-pool/--decode-pool) and keys hysteresis=N, \
                     objective=latency|energy, power_cap=W (needs objective=energy); \
                     supersedes the legacy --backend alias",
                ),
                FlagSpec::value(
                    "backend",
                    "B",
                    "salpim",
                    "execution backend: salpim|gpu|banklevel|hetero (legacy alias of \
                     --schedule static:<B>)",
                ),
                FlagSpec::optional_value(
                    "prefill-chunk",
                    "C",
                    "32",
                    "interleave prefill in C-token chunks instead of stalling the batch",
                ),
                FlagSpec::value(
                    "kv-policy",
                    "K",
                    "whole",
                    "KV allocation: whole (reserve the full window) | paged (block on demand)",
                ),
                FlagSpec::value(
                    "evict",
                    "E",
                    "lru",
                    "paged eviction: lru aka recompute (idle sessions first, then \
                     preempt+recompute) | swap (spill to host over the fabric, readmit \
                     by the cheaper of swap-in and recompute) | none",
                ),
                FlagSpec::value("kv-block", "N", "", "paged KV block size in tokens"),
                FlagSpec::value(
                    "kv-units",
                    "N",
                    "",
                    "shrink the KV region to N allocation units (capacity-pressure what-ifs)",
                ),
                FlagSpec::value(
                    "rate",
                    "R",
                    "",
                    "open-loop Poisson arrivals at R req/s (legacy alias of --workload)",
                ),
                FlagSpec::value(
                    "burst",
                    "B",
                    "",
                    "make Poisson arrivals bursts of B (legacy alias of --workload)",
                ),
                FlagSpec::value(
                    "sessions",
                    "N",
                    "8",
                    "cycle requests over N sessions (legacy alias of --workload)",
                ),
                FlagSpec::switch(
                    "at-once",
                    "queue every request at t = 0 (legacy alias of --workload at-once)",
                ),
                FlagSpec::switch("offload", "GPU prefill offload (seq engine only)"),
                FlagSpec::switch("sweep", "latency-vs-offered-load curve (3 loads)"),
                FlagSpec::value("seed", "S", "42", "workload seed"),
                FlagSpec::value(
                    "trace",
                    "FILE",
                    "",
                    "write a Chrome trace_event JSON of the request lifecycle to FILE \
                     (engine batch|cluster, no --sweep)",
                ),
            ]),
        },
        CommandSpec {
            name: "run",
            summary: "execute a scenario suite file and write BENCH_*.json",
            positionals: vec![],
            flags: vec![
                FlagSpec::value("scenario", "FILE", "", "scenario suite (TOML subset)"),
                FlagSpec::value("out-dir", "DIR", ".", "directory for BENCH_<tag>.json files"),
                FlagSpec::value(
                    "trace",
                    "FILE",
                    "",
                    "write a Chrome trace_event JSON for the suite's first traceable \
                     serve scenario to FILE",
                ),
                FlagSpec::switch("json", "print the outcome as schema-versioned JSON"),
                FlagSpec::value(
                    "out",
                    "FILE",
                    "",
                    "also write the whole suite as one JSON array to FILE",
                ),
            ],
        },
        CommandSpec {
            name: "compare",
            summary: "diff two BENCH_*.json files and flag metric regressions",
            positionals: vec![
                PositionalSpec {
                    name: "BASELINE",
                    help: "baseline BENCH_*.json (e.g. the previous main run's artifact)",
                },
                PositionalSpec {
                    name: "NEW",
                    help: "candidate BENCH_*.json to judge against the baseline",
                },
            ],
            flags: vec![
                FlagSpec::value(
                    "tolerance",
                    "PCT",
                    "10",
                    "allowed latency/throughput regression in percent before failing",
                ),
                FlagSpec::switch(
                    "allow-missing",
                    "report baseline metrics absent from NEW without failing \
                     (default: missing metrics fail the gate)",
                ),
                FlagSpec::switch("json", "print the outcome as schema-versioned JSON"),
                FlagSpec::value(
                    "out",
                    "FILE",
                    "",
                    "also write the outcome to FILE (.json/.csv by extension)",
                ),
            ],
        },
        CommandSpec {
            name: "help",
            summary: "print CLI help (--markdown emits the README section)",
            positionals: vec![],
            flags: vec![FlagSpec::switch(
                "markdown",
                "emit the CLI reference as Markdown (used to generate README.md)",
            )],
        },
    ]
}

/// Look up one command's spec.
pub fn find(name: &str) -> Option<CommandSpec> {
    commands().into_iter().find(|c| c.name == name)
}

/// Top-level usage text (no command / bad command).
pub fn usage() -> String {
    let mut out = String::from("usage: sal-pim <command> [flags]  (sal-pim <command> --help)\n\n");
    let cmds = commands();
    let width = cmds.iter().map(|c| c.name.len()).max().unwrap_or(0);
    for c in &cmds {
        let _ = writeln!(out, "  {:<width$}  {}", c.name, c.summary, width = width);
    }
    out
}

/// The README "CLI" section, generated from the same tables
/// (`sal-pim help --markdown`).
pub fn markdown() -> String {
    let mut out = String::from("## CLI\n");
    for c in commands() {
        if c.name == "help" {
            continue;
        }
        let _ = writeln!(out, "\n### `sal-pim {}` — {}\n", c.name, c.summary);
        for p in &c.positionals {
            let _ = writeln!(out, "* `{}` — {}", p.name, p.help);
        }
        for f in &c.flags {
            let default = if f.default.is_empty() {
                String::new()
            } else {
                format!(" (default {})", f.default)
            };
            let _ = writeln!(out, "* `{}` — {}{}", f.usage(), f.help, default);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_command_supports_json_and_out() {
        for c in commands() {
            if c.name == "help" {
                continue;
            }
            assert!(c.flag("json").is_some(), "{} lacks --json", c.name);
            assert!(c.flag("out").is_some(), "{} lacks --out", c.name);
        }
    }

    #[test]
    fn flag_names_are_unique_per_command() {
        for c in commands() {
            for (i, f) in c.flags.iter().enumerate() {
                assert!(
                    !c.flags[i + 1..].iter().any(|g| g.name == f.name),
                    "{} declares --{} twice",
                    c.name,
                    f.name
                );
            }
        }
    }

    #[test]
    fn help_text_lists_every_flag() {
        let spec = find("serve").unwrap();
        let help = spec.help_text();
        for f in &spec.flags {
            assert!(help.contains(&format!("--{}", f.name)), "missing {}", f.name);
        }
        assert!(help.contains("(default fcfs)"));
    }

    #[test]
    fn markdown_covers_every_command() {
        let md = markdown();
        for c in commands() {
            if c.name == "help" {
                continue;
            }
            assert!(md.contains(&format!("### `sal-pim {}`", c.name)));
        }
        assert!(md.contains("`--prefill-chunk [C]`"));
        assert!(md.contains("`--kv-policy K`"));
        assert!(md.contains("`--engine-core C`"));
        assert!(md.contains("`--fabric F`"));
        assert!(md.contains("`--prefill-pool N`"));
        assert!(md.contains("`--decode-pool N`"));
        assert!(md.contains("`--trace FILE`"));
        assert!(md.contains("`--workload SPEC`"));
        assert!(md.contains("`--schedule SPEC`"));
        assert!(md.contains("legacy alias of --schedule static:<B>"));
        assert!(md.contains("`--prefix-cache M`"));
        assert!(md.contains("`--sessions N`"));
        assert!(md.contains("`--allow-missing`"));
        assert!(md.contains("`BASELINE`"), "compare positionals documented");
    }

    #[test]
    fn compare_declares_two_positionals() {
        let spec = find("compare").unwrap();
        let names: Vec<&str> = spec.positionals.iter().map(|p| p.name).collect();
        assert_eq!(names, vec!["BASELINE", "NEW"]);
        let help = spec.help_text();
        assert!(help.contains("usage: sal-pim compare BASELINE NEW [flags]"), "{help}");
        assert!(help.contains("--tolerance"));
    }

    #[test]
    fn usage_names_every_command() {
        let u = usage();
        for c in commands() {
            assert!(u.contains(c.name));
        }
    }
}
