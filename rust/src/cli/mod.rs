//! Spec-driven command-line parsing (no clap in the offline environment).
//!
//! Grammar: `sal-pim <command> [--flag value] [--flag=value] [--switch]`.
//!
//! Parsing is driven by the command's declarative [`spec::CommandSpec`]
//! table: whether a flag consumes a value is declared per flag, so a bare
//! switch can never swallow a following token, and a flag the command
//! does not declare is a hard error (with a nearest-name suggestion)
//! instead of a silently-ignored typo.

pub mod spec;

use std::collections::{HashMap, HashSet};

pub use spec::{Arity, CommandSpec, FlagSpec};

/// Parsed, spec-validated arguments of one command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: HashMap<String, String>,
    switches: HashSet<String>,
    positionals: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("flag --{0} expects a value")]
    MissingValue(String),
    #[error("bad value for --{flag}: `{value}` ({why})")]
    BadValue {
        flag: String,
        value: String,
        why: String,
    },
    #[error("unknown flag --{flag} for `{command}`{suggestion}")]
    UnknownFlag {
        flag: String,
        command: String,
        suggestion: String,
    },
    #[error("--{0} is a switch and takes no value")]
    SwitchWithValue(String),
    #[error("unexpected positional argument `{0}`")]
    UnexpectedPositional(String),
    #[error("unknown command `{command}`{suggestion} — run `sal-pim help`")]
    UnknownCommand { command: String, suggestion: String },
}

/// Levenshtein distance, for "did you mean" suggestions.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur.push(sub.min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

/// ` (did you mean --x?)` when a close candidate exists. Also used by
/// the binary for unknown-command suggestions.
pub fn suggest<'a, I: Iterator<Item = &'a str>>(input: &str, candidates: I, prefix: &str) -> String {
    candidates
        .map(|c| (edit_distance(input, c), c))
        .filter(|(d, _)| *d <= 2)
        .min_by_key(|(d, _)| *d)
        .map(|(_, c)| format!(" (did you mean {prefix}{c}?)"))
        .unwrap_or_default()
}

impl Args {
    /// Parse one command's arguments (everything after the command word)
    /// against its spec. `--help` is accepted by every command.
    pub fn parse_for<I: IntoIterator<Item = String>>(
        spec: &CommandSpec,
        items: I,
    ) -> Result<Self, CliError> {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            let Some(name) = item.strip_prefix("--") else {
                // A bare token fills the next declared positional slot;
                // commands without positionals reject it as before.
                if out.positionals.len() < spec.positionals.len() {
                    out.positionals.push(item);
                    continue;
                }
                return Err(CliError::UnexpectedPositional(item));
            };
            let (name, inline) = match name.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (name, None),
            };
            if name == "help" {
                out.switches.insert("help".to_string());
                continue;
            }
            let Some(flag) = spec.flag(name) else {
                return Err(CliError::UnknownFlag {
                    flag: name.to_string(),
                    command: spec.name.to_string(),
                    suggestion: suggest(name, spec.flags.iter().map(|f| f.name), "--"),
                });
            };
            match (flag.arity, inline) {
                (Arity::Switch, Some(_)) => {
                    return Err(CliError::SwitchWithValue(name.to_string()))
                }
                (Arity::Switch, None) => {
                    out.switches.insert(name.to_string());
                }
                (_, Some(v)) => {
                    out.flags.insert(name.to_string(), v);
                }
                (Arity::Value, None) => {
                    let v = iter.next().ok_or_else(|| CliError::MissingValue(name.to_string()))?;
                    out.flags.insert(name.to_string(), v);
                }
                (Arity::OptionalValue, None) => {
                    // Takes the next token as its value unless that token
                    // is itself a flag; bare form reads as a switch.
                    if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                        let v = iter.next().unwrap();
                        out.flags.insert(name.to_string(), v);
                    } else {
                        out.switches.insert(name.to_string());
                    }
                }
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// The `i`-th positional argument, if given.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    /// All positional arguments, in order.
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// True if `--name` appeared at all (bare or with a value).
    pub fn switch(&self, name: &str) -> bool {
        self.switches.contains(name) || self.flags.contains_key(name)
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: T::Err| CliError::BadValue {
                flag: name.to_string(),
                value: v.clone(),
                why: e.to_string(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(cmd: &str, s: &str) -> Result<Args, CliError> {
        let spec = spec::find(cmd).expect("command exists");
        Args::parse_for(&spec, s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn flags_and_switches_parse() {
        let a = parse("simulate", "--in 32 --gen=64 --prefetch").unwrap();
        assert_eq!(a.flag("in"), Some("32"));
        assert_eq!(a.flag("gen"), Some("64"));
        assert!(a.switch("prefetch"));
        assert_eq!(a.get("in", 1usize).unwrap(), 32);
        assert_eq!(a.get("kv-missing-uses-default", 7usize).unwrap(), 7);
    }

    #[test]
    fn switch_never_swallows_the_next_token() {
        // The historical wart: `--prefetch 64` captured "64" as the
        // switch's value. Now the spec knows prefetch is a switch, so the
        // stray token is a hard error.
        let err = parse("simulate", "--prefetch 64").unwrap_err();
        assert!(matches!(err, CliError::UnexpectedPositional(v) if v == "64"));
        let a = parse("simulate", "--prefetch --in 16").unwrap();
        assert!(a.switch("prefetch"));
        assert_eq!(a.flag("in"), Some("16"));
    }

    #[test]
    fn unknown_flag_is_a_hard_error_with_suggestion() {
        let err = parse("serve", "--prefil-chunk 32").unwrap_err();
        match err {
            CliError::UnknownFlag {
                flag, suggestion, ..
            } => {
                assert_eq!(flag, "prefil-chunk");
                assert!(suggestion.contains("--prefill-chunk"), "{suggestion}");
            }
            other => panic!("expected UnknownFlag, got {other:?}"),
        }
        assert!(parse("simulate", "--frobnicate").is_err());
    }

    #[test]
    fn switch_with_inline_value_rejected() {
        let err = parse("simulate", "--prefetch=yes").unwrap_err();
        assert!(matches!(err, CliError::SwitchWithValue(_)));
    }

    #[test]
    fn value_flag_requires_a_value() {
        let err = parse("simulate", "--in").unwrap_err();
        assert!(matches!(err, CliError::MissingValue(f) if f == "in"));
    }

    #[test]
    fn optional_value_flag_takes_bare_and_valued_forms() {
        let a = parse("serve", "--prefill-chunk").unwrap();
        assert!(a.switch("prefill-chunk"));
        assert_eq!(a.flag("prefill-chunk"), None);
        let b = parse("serve", "--prefill-chunk 16 --sweep").unwrap();
        assert_eq!(b.flag("prefill-chunk"), Some("16"));
        let c = parse("serve", "--prefill-chunk --sweep").unwrap();
        assert!(c.switch("prefill-chunk"));
        assert!(c.switch("sweep"));
    }

    #[test]
    fn bad_value_is_reported() {
        let a = parse("simulate", "--gen abc").unwrap();
        assert!(matches!(
            a.get::<usize>("gen", 0),
            Err(CliError::BadValue { .. })
        ));
    }

    #[test]
    fn help_is_accepted_everywhere() {
        for cmd in ["config", "simulate", "serve", "run"] {
            let a = parse(cmd, "--help").unwrap();
            assert!(a.switch("help"));
        }
    }

    #[test]
    fn positionals_fill_declared_slots_in_order() {
        let a = parse("compare", "a.json b.json --tolerance 5").unwrap();
        assert_eq!(a.positional(0), Some("a.json"));
        assert_eq!(a.positional(1), Some("b.json"));
        assert_eq!(a.flag("tolerance"), Some("5"));
        // Positionals may interleave with flags.
        let b = parse("compare", "a.json --tolerance 5 b.json").unwrap();
        assert_eq!(b.positionals(), &["a.json".to_string(), "b.json".to_string()]);
        // A third bare token overflows the declared slots.
        let err = parse("compare", "a.json b.json c.json").unwrap_err();
        assert!(matches!(err, CliError::UnexpectedPositional(v) if v == "c.json"));
        // Commands without positionals reject bare tokens as before.
        assert!(matches!(
            parse("sweep", "stray").unwrap_err(),
            CliError::UnexpectedPositional(_)
        ));
    }

    #[test]
    fn suggestions_use_edit_distance() {
        assert_eq!(edit_distance("sweep", "sweep"), 0);
        assert_eq!(edit_distance("swep", "sweep"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
        let s = suggest("serv", ["serve", "simulate"].into_iter(), "");
        assert!(s.contains("serve"));
        let none = suggest("xyzzy", ["serve"].into_iter(), "");
        assert!(none.is_empty());
    }
}
