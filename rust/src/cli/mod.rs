//! Minimal command-line parsing (no clap in the offline environment).
//!
//! Grammar: `sal-pim <command> [--flag value] [--switch] [positional…]`.

use std::collections::{HashMap, HashSet};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: HashSet<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("flag --{0} expects a value")]
    MissingValue(String),
    #[error("bad value for --{flag}: `{value}` ({why})")]
    BadValue {
        flag: String,
        value: String,
        why: String,
    },
}

impl Args {
    /// Parse an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Self, CliError> {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(name) = item.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.insert(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(item);
            } else {
                out.positional.push(item);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Self, CliError> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// True if `--name` appeared at all (bare or with a value). A bare
    /// switch followed by a positional argument captures it as a value —
    /// use `--name=value`/`--name` last, or check `flag()` when the
    /// distinction matters.
    pub fn switch(&self, name: &str) -> bool {
        self.switches.contains(name) || self.flags.contains_key(name)
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e: T::Err| CliError::BadValue {
                flag: name.to_string(),
                value: v.clone(),
                why: e.to_string(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn command_flags_switches_positionals() {
        let a = parse("simulate extra1 extra2 --in 32 --out=64 --prefetch");
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.flag("in"), Some("32"));
        assert_eq!(a.flag("out"), Some("64"));
        assert!(a.switch("prefetch"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
        // A switch directly before a positional captures it as a value
        // but still reads as "present".
        let b = parse("run --prefetch pos");
        assert!(b.switch("prefetch"));
        assert_eq!(b.flag("prefetch"), Some("pos"));
    }

    #[test]
    fn typed_get_with_default() {
        let a = parse("simulate --out 128");
        assert_eq!(a.get("out", 1usize).unwrap(), 128);
        assert_eq!(a.get("in", 32usize).unwrap(), 32);
        assert!(a.get::<usize>("out", 0).is_ok());
    }

    #[test]
    fn bad_value_is_reported() {
        let a = parse("simulate --out abc");
        assert!(matches!(
            a.get::<usize>("out", 0),
            Err(CliError::BadValue { .. })
        ));
    }

    #[test]
    fn trailing_switch() {
        let a = parse("bench --quiet");
        assert!(a.switch("quiet"));
        assert_eq!(a.flag("quiet"), None);
    }
}
