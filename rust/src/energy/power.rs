//! Energy & power model (§6.2, Fig. 15).
//!
//! Energy per DRAM event follows the paper's assignments (taken from
//! O'Connor et al., "Fine-grained DRAM"): e_act = 909 pJ per activation,
//! e_pre-gsa = 1.51 pJ/bit and e_post-gsa = 1.17 pJ/bit for data moved
//! inside the die, e_io = 0.80 pJ/bit on the external interface, plus a
//! refresh allocation of 26 % of the HBM power budget and the Table 3
//! logic-unit powers.

use crate::config::SimConfig;
use crate::energy::AreaModel;
use crate::stats::Stats;

/// Energy constants (§6.2).
#[derive(Debug, Clone, Copy)]
pub struct EnergyParams {
    pub e_act_pj: f64,
    pub e_pre_gsa_pj_bit: f64,
    pub e_post_gsa_pj_bit: f64,
    pub e_io_pj_bit: f64,
    /// Fraction of the power budget consumed by refresh.
    pub refresh_fraction: f64,
    /// HBM2 stack power budget (W).
    pub power_budget_w: f64,
    /// Table 3 unit powers (W per unit, at full activity).
    pub salu_w: f64,
    pub bank_unit_w: f64,
    pub calu_w: f64,
}

impl EnergyParams {
    pub fn paper() -> Self {
        EnergyParams {
            e_act_pj: 909.0,
            e_pre_gsa_pj_bit: 1.51,
            e_post_gsa_pj_bit: 1.17,
            e_io_pj_bit: 0.80,
            refresh_fraction: 0.26,
            power_budget_w: 60.0,
            salu_w: 5.298e-3,
            bank_unit_w: 0.926e-3,
            calu_w: 2.749e-3,
        }
    }

    /// Table 3 logic power of one device (W): unit powers × unit counts
    /// × active fraction, "assuming the ALUs are always operating"
    /// (§6.2). [`PowerReport::from_stats`] charges its logic energy at
    /// exactly this rate.
    pub fn logic_power_w(&self, cfg: &SimConfig) -> f64 {
        let area = AreaModel::new(cfg);
        let channels = cfg.hbm.channels() as f64;
        let active_salus = area.salus_per_channel as f64
            * (cfg.parallelism.p_sub as f64 / cfg.salu.max_p_sub as f64);
        channels
            * (active_salus * self.salu_w
                + area.bank_units_per_channel as f64 * self.bank_unit_w
                + self.calu_w)
    }

    /// Busy power of one SAL-PIM device (W): Fig. 15's always-on
    /// components — logic plus the refresh share of the HBM budget.
    /// The data-movement terms are workload-shaped and charged per run
    /// by [`PowerReport`]; this is the steady rate the phase router's
    /// energy objective prices a busy PIM device at.
    pub fn pim_device_power_w(&self, cfg: &SimConfig) -> f64 {
        self.logic_power_w(cfg) + self.refresh_fraction * self.power_budget_w
    }
}

/// Power accounting for one simulated run.
#[derive(Debug, Clone)]
pub struct PowerReport {
    /// DRAM activation energy (J).
    pub act_j: f64,
    /// In-die data-movement energy (J).
    pub movement_j: f64,
    /// Buffer-die / IO energy (J).
    pub io_j: f64,
    /// PIM logic energy (J).
    pub logic_j: f64,
    /// Refresh energy (J).
    pub refresh_j: f64,
    /// Run duration (s).
    pub seconds: f64,
    /// Power budget (W).
    pub budget_w: f64,
}

impl PowerReport {
    /// Build from per-pseudo-channel statistics (scaled to the device).
    pub fn from_stats(cfg: &SimConfig, params: &EnergyParams, stats: &Stats) -> Self {
        let pchs = cfg.hbm.pseudo_channels() as f64;
        let seconds = stats.seconds(cfg.timing.tck_ns);
        // Stats count per-pseudo-channel work (all-bank commands already
        // count every bank they hit).
        let acts = stats.activations as f64 * pchs;
        let internal_bits = stats.internal_bytes as f64 * 8.0 * pchs;
        let external_bits = stats.external_bytes as f64 * 8.0 * pchs;

        let act_j = acts * params.e_act_pj * 1e-12;
        // Data streamed to S-ALUs crosses the cell array and the GSA
        // boundary once each.
        let movement_j =
            internal_bits * (params.e_pre_gsa_pj_bit + params.e_post_gsa_pj_bit) * 1e-12;
        let io_j = external_bits * (params.e_post_gsa_pj_bit + params.e_io_pj_bit) * 1e-12;

        // Logic: Table 3 powers × unit counts × busy time (conservative:
        // the §6.2 "assumes the ALUs are always operating").
        let logic_j = params.logic_power_w(cfg) * seconds;

        let refresh_j = params.refresh_fraction * params.power_budget_w * seconds;

        PowerReport {
            act_j,
            movement_j,
            io_j,
            logic_j,
            refresh_j,
            seconds,
            budget_w: params.power_budget_w,
        }
    }

    pub fn total_j(&self) -> f64 {
        self.act_j + self.movement_j + self.io_j + self.logic_j + self.refresh_j
    }

    /// Average power over the run (W).
    pub fn avg_power_w(&self) -> f64 {
        if self.seconds == 0.0 {
            0.0
        } else {
            self.total_j() / self.seconds
        }
    }

    /// Power relative to the budget (1.0 = at budget; Fig. 15's P_Sub=4
    /// point exceeds it).
    pub fn budget_fraction(&self) -> f64 {
        self.avg_power_w() / self.budget_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapper::GenerationSim;

    fn run_power(p_sub: usize) -> PowerReport {
        let cfg = SimConfig::paper().with_p_sub(p_sub);
        let mut sim = GenerationSim::new(&cfg);
        // Fig. 15's workload: 32 token generations.
        let r = sim.generate(32, 32);
        PowerReport::from_stats(&cfg, &EnergyParams::paper(), &r.total())
    }

    #[test]
    fn power_grows_with_p_sub() {
        // Fig. 15: more subarray parallelism ⇒ more power.
        let p1 = run_power(1).avg_power_w();
        let p2 = run_power(2).avg_power_w();
        let p4 = run_power(4).avg_power_w();
        assert!(p1 < p2 && p2 < p4, "{p1} {p2} {p4}");
    }

    #[test]
    fn psub1_under_budget_psub4_over() {
        // Fig. 15's headline: P_Sub ∈ {1,2} stay within the 60 W budget,
        // P_Sub = 4 exceeds it (paper: by 24 %; our sim's higher
        // achieved bandwidth pushes it somewhat further).
        let p1 = run_power(1);
        let p4 = run_power(4);
        assert!(p1.budget_fraction() < 1.0, "P_Sub=1 at {}", p1.budget_fraction());
        assert!(p4.budget_fraction() > 1.0, "P_Sub=4 at {}", p4.budget_fraction());
        assert!(p4.budget_fraction() < 2.2, "P_Sub=4 at {}", p4.budget_fraction());
    }

    #[test]
    fn energy_components_positive_and_refresh_constant_power() {
        let r = run_power(2);
        assert!(r.act_j > 0.0 && r.movement_j > 0.0 && r.logic_j > 0.0);
        let refresh_w = r.refresh_j / r.seconds;
        assert!((refresh_w - 0.26 * 60.0).abs() < 1e-9);
    }

    #[test]
    fn logic_energy_charges_at_the_logic_power_rate() {
        // The extracted per-device rate and the report's logic energy
        // must agree bit-for-bit (the phase router prices busy PIM
        // devices at this rate).
        let cfg = SimConfig::paper().with_p_sub(2);
        let params = EnergyParams::paper();
        let r = run_power(2);
        let expect = params.logic_power_w(&cfg) * r.seconds;
        assert_eq!(r.logic_j.to_bits(), expect.to_bits());
        let dev = params.pim_device_power_w(&cfg);
        let refresh_w = params.refresh_fraction * params.power_budget_w;
        assert!((dev - (params.logic_power_w(&cfg) + refresh_w)).abs() < 1e-12);
        assert!(dev > refresh_w);
    }

    #[test]
    fn movement_energy_dominates_at_high_bandwidth() {
        // Streaming ~4 TB/s through the die must dwarf ACT energy.
        let r = run_power(4);
        assert!(r.movement_j > r.act_j);
    }
}
