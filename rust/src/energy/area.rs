//! Area model (§5.2, Table 3).
//!
//! Unit areas come from the paper's TSMC 28-nm Design Compiler synthesis,
//! scaled ×3.6 to 20-nm DRAM technology (the paper doubles the ~1.8×
//! logic-vs-DRAM factor to be conservative). Table 3 reports the
//! *post-scaling* values; we reproduce both the raw-synthesis view and
//! the Table 3 arithmetic.

use crate::config::SimConfig;

/// Conservative 28-nm-logic → 20-nm-DRAM area scaling (§5.2).
pub const DRAM_SCALE: f64 = 3.6;

/// Unit areas in µm² (Table 3 values, already DRAM-scaled).
#[derive(Debug, Clone, Copy)]
pub struct UnitAreas {
    pub salu_um2: f64,
    pub bank_unit_um2: f64,
    pub calu_um2: f64,
    /// Conventional HBM2 area per channel (mm²).
    pub hbm2_channel_mm2: f64,
}

impl UnitAreas {
    /// The paper's Table 3 numbers.
    pub fn paper() -> Self {
        UnitAreas {
            salu_um2: 18_744.0,
            bank_unit_um2: 4_847.0,
            calu_um2: 19_126.0,
            hbm2_channel_mm2: 53.15,
        }
    }

    /// The implied pre-scaling 28-nm synthesis areas.
    pub fn raw_28nm(&self) -> (f64, f64, f64) {
        (
            self.salu_um2 / DRAM_SCALE,
            self.bank_unit_um2 / DRAM_SCALE,
            self.calu_um2 / DRAM_SCALE,
        )
    }
}

/// Whole-device area accounting.
#[derive(Debug, Clone)]
pub struct AreaModel {
    pub units: UnitAreas,
    pub salus_per_channel: usize,
    pub bank_units_per_channel: usize,
    pub calus_per_channel: usize,
}

impl AreaModel {
    /// Build for a configuration (Table 3 uses P_Sub = 4 ⇒ 64 S-ALUs per
    /// pseudo-channel pair = 128 per channel).
    pub fn new(cfg: &SimConfig) -> Self {
        let banks_per_channel = cfg.hbm.banks_per_pch * cfg.hbm.pch_per_channel;
        AreaModel {
            units: UnitAreas::paper(),
            salus_per_channel: banks_per_channel * cfg.salu.max_p_sub,
            bank_units_per_channel: banks_per_channel,
            calus_per_channel: 1,
        }
    }

    /// Area per channel added by each unit type (mm²).
    pub fn salu_area_mm2(&self) -> f64 {
        self.units.salu_um2 * self.salus_per_channel as f64 / 1e6
    }

    pub fn bank_unit_area_mm2(&self) -> f64 {
        self.units.bank_unit_um2 * self.bank_units_per_channel as f64 / 1e6
    }

    pub fn calu_area_mm2(&self) -> f64 {
        self.units.calu_um2 * self.calus_per_channel as f64 / 1e6
    }

    /// Total added area per channel (mm²).
    pub fn total_added_mm2(&self) -> f64 {
        self.salu_area_mm2() + self.bank_unit_area_mm2() + self.calu_area_mm2()
    }

    /// Area overhead vs conventional HBM2 (the paper's 4.81 %).
    pub fn overhead_fraction(&self) -> f64 {
        self.total_added_mm2() / self.units.hbm2_channel_mm2
    }

    /// The previous work's acceptability threshold (§5.2, [13]).
    pub const OVERHEAD_THRESHOLD: f64 = 0.25;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_area_per_channel() {
        let a = AreaModel::new(&SimConfig::paper());
        assert_eq!(a.salus_per_channel, 128);
        assert!((a.salu_area_mm2() - 2.40).abs() < 0.01, "{}", a.salu_area_mm2());
        assert!((a.bank_unit_area_mm2() - 0.16).abs() < 0.01);
        assert!((a.calu_area_mm2() - 0.02).abs() < 0.005);
    }

    #[test]
    fn overhead_matches_paper_4_81_percent() {
        let a = AreaModel::new(&SimConfig::paper());
        let pct = a.overhead_fraction() * 100.0;
        assert!((pct - 4.81).abs() < 0.15, "overhead {pct}%");
        assert!(a.overhead_fraction() < AreaModel::OVERHEAD_THRESHOLD);
    }

    #[test]
    fn raw_synthesis_areas_scale_back() {
        let u = UnitAreas::paper();
        let (s, b, c) = u.raw_28nm();
        assert!((s * DRAM_SCALE - u.salu_um2).abs() < 1e-6);
        assert!(b < u.bank_unit_um2 && c < u.calu_um2);
    }

    #[test]
    fn fewer_salus_reduce_overhead() {
        let mut cfg = SimConfig::paper();
        cfg.salu.max_p_sub = 1;
        let a1 = AreaModel::new(&cfg);
        let a4 = AreaModel::new(&SimConfig::paper());
        assert!(a1.overhead_fraction() < a4.overhead_fraction());
    }
}
