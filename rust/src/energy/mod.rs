//! Area and energy/power models (Table 3 & Fig. 15).

pub mod area;
pub mod power;

pub use area::AreaModel;
pub use power::{EnergyParams, PowerReport};
