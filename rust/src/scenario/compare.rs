//! BENCH-file comparison: the regression gate behind `sal-pim compare`.
//!
//! The sink layer writes schema-versioned `BENCH_<tag>.json` trajectory
//! files; this module reads two of them back (a hand-rolled JSON reader —
//! the offline build has no serde) and diffs them metric-by-metric.
//! Outcomes are paired by `(scenario, title)`, every shared numeric
//! metric becomes one diff row, and metrics with a known direction
//! (latency-like: lower is better; throughput-like: higher is better)
//! regress when they move the wrong way by more than the tolerance.
//! `sal-pim compare` renders the report as a standard [`Outcome`]
//! (`--json` / `--out` work as everywhere) and exits nonzero when any
//! regression survives — which is what the CI `bench-diff` job gates on.

use super::outcome::{Outcome, Provenance};
use super::ScenarioError;

/// A parsed JSON value (only what BENCH documents need).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

fn parse_err(pos: usize, msg: &str) -> ScenarioError {
    ScenarioError::Parse {
        line: 0,
        msg: format!("JSON byte {pos}: {msg}"),
    }
}

impl<'a> Parser<'a> {
    fn new(text: &'a str) -> Self {
        Parser {
            bytes: text.as_bytes(),
            pos: 0,
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ScenarioError> {
        match self.peek() {
            Some(b) if b == c => {
                self.pos += 1;
                Ok(())
            }
            other => Err(parse_err(
                self.pos,
                &format!("expected `{}`, found {:?}", c as char, other.map(|b| b as char)),
            )),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, ScenarioError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(parse_err(self.pos, &format!("expected `{word}`")))
        }
    }

    fn string(&mut self) -> Result<String, ScenarioError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&b) = self.bytes.get(self.pos) else {
                return Err(parse_err(self.pos, "unterminated string"));
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(&esc) = self.bytes.get(self.pos) else {
                        return Err(parse_err(self.pos, "unterminated escape"));
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| parse_err(self.pos, "bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs don't occur in our writers;
                            // map unpaired surrogates to U+FFFD.
                            out.push(char::from_u32(hex).unwrap_or('\u{FFFD}'));
                        }
                        other => {
                            return Err(parse_err(
                                self.pos,
                                &format!("bad escape `\\{}`", other as char),
                            ))
                        }
                    }
                }
                _ => {
                    // Collect the raw UTF-8 byte run verbatim.
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len()
                        && self.bytes[end] != b'"'
                        && self.bytes[end] != b'\\'
                    {
                        end += 1;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| parse_err(start, "invalid UTF-8"))?;
                    out.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ScenarioError> {
        let start = self.pos;
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| parse_err(start, "bad number"))
    }

    fn value(&mut self) -> Result<Json, ScenarioError> {
        match self.peek() {
            Some(b'{') => {
                self.pos += 1;
                let mut fields = Vec::new();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.expect(b':')?;
                    let val = self.value()?;
                    fields.push((key, val));
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => return Err(parse_err(self.pos, "expected `,` or `}`")),
                    }
                }
            }
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(parse_err(self.pos, "expected `,` or `]`")),
                    }
                }
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err(parse_err(self.pos, "unexpected end of input")),
        }
    }
}

/// Parse one JSON document (trailing whitespace tolerated).
pub fn parse_json(text: &str) -> Result<Json, ScenarioError> {
    let mut p = Parser::new(text);
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(parse_err(p.pos, "trailing garbage after the document"));
    }
    Ok(v)
}

/// One outcome's numeric metrics, flattened for comparison.
#[derive(Debug, Clone)]
pub struct OutcomeMetrics {
    pub scenario: String,
    pub title: String,
    /// `(name, value, unit)` in document order; non-numeric metric
    /// values (labels like `kv_policy`) are skipped.
    pub metrics: Vec<(String, f64, Option<String>)>,
}

/// A whole BENCH document (or a bare outcome / outcome array).
#[derive(Debug, Clone)]
pub struct BenchFile {
    /// The `bench` tag, when the document carries one.
    pub bench: Option<String>,
    pub outcomes: Vec<OutcomeMetrics>,
}

fn outcome_metrics(o: &Json) -> Result<OutcomeMetrics, ScenarioError> {
    let scenario = o
        .get("scenario")
        .and_then(Json::as_str)
        .unwrap_or("?")
        .to_string();
    let title = o
        .get("title")
        .and_then(Json::as_str)
        .unwrap_or("?")
        .to_string();
    let mut metrics = Vec::new();
    for m in o
        .get("metrics")
        .and_then(Json::as_arr)
        .ok_or_else(|| parse_err(0, "outcome has no `metrics` array"))?
    {
        let Some(name) = m.get("name").and_then(Json::as_str) else {
            continue;
        };
        let Some(value) = m.get("value").and_then(Json::as_f64) else {
            continue; // text/bool/null metrics are labels, not numbers
        };
        let unit = m
            .get("unit")
            .and_then(Json::as_str)
            .map(|u| u.to_string());
        metrics.push((name.to_string(), value, unit));
    }
    Ok(OutcomeMetrics {
        scenario,
        title,
        metrics,
    })
}

/// Read a BENCH document: `{"bench": tag, "outcomes": [...]}`, a bare
/// outcome object, or a JSON array of outcomes (the `run --out` shape).
pub fn parse_bench(text: &str) -> Result<BenchFile, ScenarioError> {
    let doc = parse_json(text)?;
    let (bench, list): (Option<String>, Vec<&Json>) = if let Some(outs) =
        doc.get("outcomes").and_then(Json::as_arr)
    {
        (
            doc.get("bench").and_then(Json::as_str).map(String::from),
            outs.iter().collect(),
        )
    } else if let Json::Arr(items) = &doc {
        (None, items.iter().collect())
    } else {
        (None, vec![&doc])
    };
    let outcomes = list
        .into_iter()
        .map(outcome_metrics)
        .collect::<Result<Vec<_>, _>>()?;
    Ok(BenchFile { bench, outcomes })
}

/// Which way a metric is allowed to move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Latency-like: growing past tolerance is a regression.
    LowerIsBetter,
    /// Throughput-like: shrinking past tolerance is a regression.
    HigherIsBetter,
    /// Counts/labels: reported, never gating.
    Informational,
}

/// Classify a metric name. Conservative on purpose: only metrics whose
/// direction is unambiguous (latency/time-like vs throughput-like) can
/// fail the gate; everything else is informational.
pub fn direction(name: &str) -> Direction {
    // Simulator self-profile phase timers (`phase_decode_s`…) contain
    // substrings like `decode` that would otherwise read as model
    // latencies; they are wall-clock diagnostics, checked first.
    if name.starts_with("phase_") {
        return Direction::Informational;
    }
    let lower_better = [
        "latency", "ttft", "queue", "makespan", "iteration", "prefill", "decode", "total",
        "gpu_baseline", "wall",
    ];
    let higher_better = ["throughput", "speedup", "decode_rate", "per_wall"];
    // Exact-name counters/diagnostics first — several contain substrings
    // like `decode` or `total` that would otherwise read as durations
    // (`mean_decode_batch` growing is the *win* paging exists for, not a
    // latency regression).
    let informational = [
        "total_tokens",
        "decode_steps",
        "mean_decode_batch",
        "preemptions",
        "recompute_tokens",
        "reuse_hits",
        "reuse_tokens",
        "rejected",
        // SLO-class populations: how the workload split, not a cost.
        // (The per-class percentiles — `interactive_p95_latency`,
        // `interactive_p50_ttft`, … — gate lower-is-better through the
        // substring rules below.)
        "interactive_requests",
        "batch_requests",
        // Radix prefix-cache counters: workload properties. The hit rate
        // is deliberately non-gating too — near-zero baselines make its
        // relative delta meaninglessly noisy.
        "prefix_hits",
        "prefix_reused_tokens",
        "prefix_nodes_evicted",
        "prefix_cache_hit_rate",
        // Fabric traffic counters: bytes moved is a property of the
        // topology under test, not a cost to minimize (an ideal fabric
        // moves the same bytes in zero time).
        "migrated_bytes",
        "fabric_transfers",
        "swap_outs",
        "swap_ins",
        "swapped_bytes",
    ];
    if informational.contains(&name) {
        return Direction::Informational;
    }
    // Phase-scheduling metrics, pinned by exact name: oracle proximity
    // gates upward, modeled energy/power gate downward, and the router's
    // migration count is a placement property (the energy term already
    // prices each migration), so it never gates.
    match name {
        "pct_of_oracle" => return Direction::HigherIsBetter,
        "energy_j" | "avg_power_w" => return Direction::LowerIsBetter,
        "router_migrations" | "best_static_pct_of_oracle" | "oracle_candidates" => {
            return Direction::Informational
        }
        _ => {}
    }
    if higher_better.iter().any(|k| name.contains(k)) {
        return Direction::HigherIsBetter;
    }
    if lower_better.iter().any(|k| name.contains(k)) {
        return Direction::LowerIsBetter;
    }
    Direction::Informational
}

/// One metric's diff between the two files.
#[derive(Debug, Clone)]
pub struct MetricDiff {
    pub title: String,
    pub metric: String,
    pub unit: Option<String>,
    pub baseline: f64,
    pub candidate: f64,
    /// Relative change `(candidate - baseline) / baseline` (0 when both
    /// are 0; ±∞ when only the baseline is 0).
    pub delta: f64,
    pub direction: Direction,
    pub regressed: bool,
}

/// The comparison result `sal-pim compare` renders and gates on.
#[derive(Debug, Clone)]
pub struct CompareReport {
    pub rows: Vec<MetricDiff>,
    /// Outcomes present in only one of the files (by scenario/title).
    pub unmatched: usize,
    /// Metrics present in the baseline but absent from the candidate,
    /// as `(outcome title, metric name, baseline value)`. A missing
    /// metric means the candidate stopped reporting something the gate
    /// was watching — fatal by default, informational only under
    /// `--allow-missing`.
    pub missing: Vec<(String, String, f64)>,
    pub regressions: usize,
    pub improvements: usize,
    pub tolerance_pct: f64,
}

/// Diff two parsed BENCH files. Outcomes pair by `(scenario, title)`
/// first-match; metrics pair by name within a paired outcome.
pub fn compare(a: &BenchFile, b: &BenchFile, tolerance_pct: f64) -> CompareReport {
    let tol = tolerance_pct / 100.0;
    let mut rows = Vec::new();
    let mut regressions = 0usize;
    let mut improvements = 0usize;
    let mut used: Vec<bool> = vec![false; b.outcomes.len()];
    let mut unmatched = 0usize;
    let mut missing: Vec<(String, String, f64)> = Vec::new();
    for oa in &a.outcomes {
        let Some(bi) = b
            .outcomes
            .iter()
            .enumerate()
            .position(|(i, ob)| !used[i] && ob.scenario == oa.scenario && ob.title == oa.title)
        else {
            unmatched += 1;
            continue;
        };
        used[bi] = true;
        let ob = &b.outcomes[bi];
        for (name, base, unit) in &oa.metrics {
            let Some((_, cand, _)) = ob.metrics.iter().find(|(n, _, _)| n == name) else {
                missing.push((oa.title.clone(), name.clone(), *base));
                continue;
            };
            let delta = if *base == 0.0 && *cand == 0.0 {
                0.0
            } else if *base == 0.0 {
                f64::INFINITY * cand.signum()
            } else {
                (cand - base) / base.abs()
            };
            let dir = direction(name);
            let regressed = match dir {
                Direction::LowerIsBetter => delta > tol,
                Direction::HigherIsBetter => delta < -tol,
                Direction::Informational => false,
            };
            let improved = match dir {
                Direction::LowerIsBetter => delta < -tol,
                Direction::HigherIsBetter => delta > tol,
                Direction::Informational => false,
            };
            regressions += usize::from(regressed);
            improvements += usize::from(improved);
            rows.push(MetricDiff {
                title: oa.title.clone(),
                metric: name.clone(),
                unit: unit.clone(),
                baseline: *base,
                candidate: *cand,
                delta,
                direction: dir,
                regressed,
            });
        }
    }
    unmatched += used.iter().filter(|u| !**u).count();
    CompareReport {
        rows,
        unmatched,
        missing,
        regressions,
        improvements,
        tolerance_pct,
    }
}

/// Render a comparison as a standard [`Outcome`] so the CLI's
/// `--json` / `--out` sinks apply unchanged.
pub fn report_outcome(report: &CompareReport, a_label: &str, b_label: &str) -> Outcome {
    let mut out = Outcome::new(
        &format!("bench diff — {a_label} → {b_label}"),
        Provenance {
            scenario: "compare".to_string(),
            preset: "-".to_string(),
            p_sub: 0,
            backend: None,
            seed: None,
            params: vec![
                ("baseline".to_string(), a_label.to_string()),
                ("candidate".to_string(), b_label.to_string()),
                ("tolerance_pct".to_string(), report.tolerance_pct.to_string()),
            ],
            truncated: false,
        },
    );
    out.columns(&[
        ("outcome", None),
        ("metric", None),
        ("baseline", None),
        ("candidate", None),
        ("delta", Some("frac")),
        ("verdict", None),
    ]);
    for r in &report.rows {
        let verdict = if r.regressed {
            "REGRESSED"
        } else {
            match r.direction {
                Direction::Informational => "info",
                _ => "ok",
            }
        };
        out.row(vec![
            r.title.clone().into(),
            r.metric.clone().into(),
            r.baseline.into(),
            r.candidate.into(),
            r.delta.into(),
            verdict.into(),
        ]);
    }
    for (title, metric, base) in &report.missing {
        out.row(vec![
            title.clone().into(),
            metric.clone().into(),
            (*base).into(),
            "-".into(),
            0.0.into(),
            "MISSING".into(),
        ]);
    }
    out.metric("compared_metrics", report.rows.len(), None);
    out.metric("regressions", report.regressions, None);
    out.metric("improvements", report.improvements, None);
    out.metric("unmatched_outcomes", report.unmatched, None);
    out.metric("missing_metrics", report.missing.len(), None);
    out.metric("tolerance", report.tolerance_pct / 100.0, Some("frac"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::sink;

    fn bench_doc(throughput: f64, p95: f64) -> String {
        let mut o = Outcome::new(
            "serve — smoke",
            Provenance {
                scenario: "serve".to_string(),
                preset: "paper".to_string(),
                p_sub: 4,
                backend: Some("salpim".to_string()),
                seed: Some(42),
                params: vec![],
                truncated: false,
            },
        );
        o.metric("throughput", throughput, Some("tok/s"));
        o.metric("p95_latency", p95, Some("s"));
        o.metric("total_tokens", 1000usize, None);
        o.metric("kv_policy", "paged", None);
        sink::bench_json("serve", &[&o])
    }

    #[test]
    fn json_parser_round_trips_sink_output() {
        let doc = bench_doc(120.5, 0.25);
        let parsed = parse_bench(&doc).unwrap();
        assert_eq!(parsed.bench.as_deref(), Some("serve"));
        assert_eq!(parsed.outcomes.len(), 1);
        let o = &parsed.outcomes[0];
        assert_eq!(o.scenario, "serve");
        // The text-valued kv_policy metric is skipped; three numerics stay.
        assert_eq!(o.metrics.len(), 3);
        assert_eq!(o.metrics[0], ("throughput".to_string(), 120.5, Some("tok/s".to_string())));
    }

    #[test]
    fn json_parser_handles_escapes_and_nesting() {
        let j = parse_json(r#"{"a": [1, -2.5e3, "x\"y\n", true, null], "b": {}}"#).unwrap();
        let arr = j.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[1].as_f64(), Some(-2500.0));
        assert_eq!(arr[2].as_str(), Some("x\"y\n"));
        assert_eq!(arr[3], Json::Bool(true));
        assert_eq!(arr[4], Json::Null);
        assert!(parse_json("{\"unterminated\": ").is_err());
        assert!(parse_json("[1, 2] trailing").is_err());
    }

    #[test]
    fn identical_files_show_no_regression() {
        let a = parse_bench(&bench_doc(100.0, 0.2)).unwrap();
        let r = compare(&a, &a, 10.0);
        assert_eq!(r.regressions, 0);
        assert_eq!(r.improvements, 0);
        assert_eq!(r.unmatched, 0);
        assert!(r.rows.iter().all(|d| d.delta == 0.0));
    }

    #[test]
    fn injected_regression_beyond_tolerance_is_flagged() {
        let base = parse_bench(&bench_doc(100.0, 0.2)).unwrap();
        // 20% throughput drop + 50% latency growth: two regressions.
        let bad = parse_bench(&bench_doc(80.0, 0.3)).unwrap();
        let r = compare(&base, &bad, 10.0);
        assert_eq!(r.regressions, 2, "{:?}", r.rows);
        // Within tolerance: clean.
        let ok = parse_bench(&bench_doc(95.0, 0.21)).unwrap();
        assert_eq!(compare(&base, &ok, 10.0).regressions, 0);
        // Improvements are counted, never gating.
        let fast = parse_bench(&bench_doc(150.0, 0.1)).unwrap();
        let r = compare(&base, &fast, 10.0);
        assert_eq!(r.regressions, 0);
        assert_eq!(r.improvements, 2);
    }

    #[test]
    fn informational_metrics_never_gate() {
        // total_tokens changing is visible but not a failure.
        let mut a = parse_bench(&bench_doc(100.0, 0.2)).unwrap();
        let b = parse_bench(&bench_doc(100.0, 0.2)).unwrap();
        a.outcomes[0].metrics[2].1 = 500.0;
        let r = compare(&a, &b, 10.0);
        assert_eq!(r.regressions, 0);
        let tok = r.rows.iter().find(|d| d.metric == "total_tokens").unwrap();
        assert_eq!(tok.direction, Direction::Informational);
        assert!(!tok.regressed);
    }

    #[test]
    fn baseline_metric_missing_from_candidate_is_reported() {
        let base = parse_bench(&bench_doc(100.0, 0.2)).unwrap();
        let mut cand = parse_bench(&bench_doc(100.0, 0.2)).unwrap();
        // Candidate stops reporting p95_latency entirely.
        cand.outcomes[0].metrics.retain(|(n, _, _)| n != "p95_latency");
        let r = compare(&base, &cand, 10.0);
        assert_eq!(r.missing.len(), 1, "{:?}", r.missing);
        assert_eq!(r.missing[0].1, "p95_latency");
        assert_eq!(r.missing[0].2, 0.2);
        // The missing metric contributes no diff row and no regression
        // of its own — gating is the caller's (CLI's) decision.
        assert_eq!(r.regressions, 0);
        assert!(r.rows.iter().all(|d| d.metric != "p95_latency"));
        // The rendered report carries both a MISSING row and the count.
        let out = report_outcome(&r, "a", "b");
        assert_eq!(out.metric_f64("missing_metrics"), Some(1.0));
        let text = sink::render_text(&out);
        assert!(text.contains("MISSING"), "{text}");
        // Extra candidate-only metrics are not "missing".
        let r = compare(&cand, &base, 10.0);
        assert!(r.missing.is_empty());
    }

    #[test]
    fn simperf_metrics_classify_by_wall_clock_direction() {
        // Self-profile throughput gates upward, wall time downward…
        assert_eq!(direction("sim_tokens_per_wall_s"), Direction::HigherIsBetter);
        assert_eq!(direction("sim_wall_s"), Direction::LowerIsBetter);
        // …while phase timers are diagnostics even when their names
        // contain duration-like substrings (`phase_decode_s`).
        assert_eq!(direction("phase_decode_s"), Direction::Informational);
        assert_eq!(direction("phase_admission_s"), Direction::Informational);
        assert_eq!(direction("phase_preempt_s"), Direction::Informational);
        assert_eq!(direction("sim_tokens"), Direction::Informational);
    }

    #[test]
    fn unmatched_outcomes_are_counted_not_fatal() {
        let a = parse_bench(&bench_doc(100.0, 0.2)).unwrap();
        let empty = BenchFile {
            bench: None,
            outcomes: vec![],
        };
        let r = compare(&a, &empty, 10.0);
        assert_eq!(r.rows.len(), 0);
        assert_eq!(r.unmatched, 1);
    }

    #[test]
    fn direction_classification_is_conservative() {
        assert_eq!(direction("p95_latency"), Direction::LowerIsBetter);
        assert_eq!(direction("p50_ttft"), Direction::LowerIsBetter);
        assert_eq!(direction("makespan"), Direction::LowerIsBetter);
        assert_eq!(direction("throughput"), Direction::HigherIsBetter);
        assert_eq!(direction("max_speedup"), Direction::HigherIsBetter);
        assert_eq!(direction("total"), Direction::LowerIsBetter);
        assert_eq!(direction("total_tokens"), Direction::Informational);
        assert_eq!(direction("requests"), Direction::Informational);
        assert_eq!(direction("kv_peak_utilization"), Direction::Informational);
        // Paging counters must never gate — `mean_decode_batch` growing
        // is the improvement the paged allocator exists to deliver.
        assert_eq!(direction("mean_decode_batch"), Direction::Informational);
        assert_eq!(direction("preemptions"), Direction::Informational);
        assert_eq!(direction("recompute_tokens"), Direction::Informational);
        assert_eq!(direction("reuse_hits"), Direction::Informational);
        // Fabric traffic is topology, not cost — never gates.
        assert_eq!(direction("migrated_bytes"), Direction::Informational);
        assert_eq!(direction("fabric_transfers"), Direction::Informational);
        assert_eq!(direction("swap_outs"), Direction::Informational);
        assert_eq!(direction("swap_ins"), Direction::Informational);
        assert_eq!(direction("swapped_bytes"), Direction::Informational);
        // …while `decode_rate` (tok/s) still gates in the right direction.
        assert_eq!(direction("decode_rate"), Direction::HigherIsBetter);
        assert_eq!(direction("decode"), Direction::LowerIsBetter);
    }

    #[test]
    fn phase_scheduling_metrics_classify_by_exact_name() {
        // Closer to the oracle is better; modeled energy/power must not
        // creep up; migration counts are placement shape, not cost.
        assert_eq!(direction("pct_of_oracle"), Direction::HigherIsBetter);
        assert_eq!(direction("energy_j"), Direction::LowerIsBetter);
        assert_eq!(direction("avg_power_w"), Direction::LowerIsBetter);
        assert_eq!(direction("router_migrations"), Direction::Informational);
        assert_eq!(
            direction("best_static_pct_of_oracle"),
            Direction::Informational
        );
        assert_eq!(direction("oracle_candidates"), Direction::Informational);
    }

    #[test]
    fn slo_class_and_prefix_cache_metrics_classify_correctly() {
        // Per-class latency percentiles gate like their global cousins.
        assert_eq!(direction("interactive_p50_latency"), Direction::LowerIsBetter);
        assert_eq!(direction("interactive_p95_latency"), Direction::LowerIsBetter);
        assert_eq!(direction("interactive_p50_ttft"), Direction::LowerIsBetter);
        assert_eq!(direction("interactive_p95_ttft"), Direction::LowerIsBetter);
        assert_eq!(direction("batch_p95_latency"), Direction::LowerIsBetter);
        // Class populations and prefix-cache counters never gate.
        assert_eq!(direction("interactive_requests"), Direction::Informational);
        assert_eq!(direction("batch_requests"), Direction::Informational);
        assert_eq!(direction("prefix_hits"), Direction::Informational);
        assert_eq!(direction("prefix_reused_tokens"), Direction::Informational);
        assert_eq!(direction("prefix_cache_hit_rate"), Direction::Informational);
    }

    #[test]
    fn report_outcome_renders_and_serializes() {
        let base = parse_bench(&bench_doc(100.0, 0.2)).unwrap();
        let bad = parse_bench(&bench_doc(80.0, 0.3)).unwrap();
        let rep = compare(&base, &bad, 10.0);
        let out = report_outcome(&rep, "BENCH_a.json", "BENCH_b.json");
        assert_eq!(out.metric_f64("regressions"), Some(2.0));
        assert_eq!(out.rows.len(), rep.rows.len());
        let text = sink::render_text(&out);
        assert!(text.contains("REGRESSED"), "{text}");
        let json = sink::to_json(&out);
        assert!(json.contains("\"scenario\": \"compare\""));
    }
}
