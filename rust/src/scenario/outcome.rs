//! Structured experiment results.
//!
//! Every scenario run yields one [`Outcome`]: named summary metrics
//! (value + unit), an optional row grid (the table body), and
//! [`Provenance`] — which config preset, `P_Sub`, backend and seed
//! produced the numbers. Outcomes are what the sinks render (text table,
//! JSON, CSV) and what `BENCH_*.json` files accumulate, so downstream
//! tooling never scrapes `println!` output.

/// Version stamp carried by every serialized outcome. Bump on any
/// field rename/removal; additions are backward compatible.
pub const SCHEMA_VERSION: u32 = 1;

/// A typed cell/metric value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Num(f64),
    Text(String),
    Bool(bool),
}

impl Value {
    /// Numeric view (ints widen; text/bool are `None`).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }
}

impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Int(v as i64)
    }
}

impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Int(v as i64)
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Text(v.to_string())
    }
}

impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Text(v)
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

/// One named summary metric.
#[derive(Debug, Clone, PartialEq)]
pub struct Metric {
    pub name: String,
    pub value: Value,
    /// Unit tag (`"s"`, `"tok/s"`, `"x"`, `"frac"`, `"W"`, `"B/s"`,
    /// `"mm2"`…); `None` for dimensionless counts/labels. Sinks use it
    /// both for display formatting and as machine-readable metadata.
    pub unit: Option<String>,
}

/// One column of the row grid.
#[derive(Debug, Clone, PartialEq)]
pub struct Column {
    pub name: String,
    pub unit: Option<String>,
}

/// Where an outcome's numbers came from.
#[derive(Debug, Clone, PartialEq)]
pub struct Provenance {
    /// Scenario kind (`"simulate"`, `"sweep"`, `"serve"`…).
    pub scenario: String,
    /// Config preset the run resolved (`"paper"` / `"mini"`).
    pub preset: String,
    /// Resolved subarray-level parallelism.
    pub p_sub: usize,
    /// Execution backend, when one applies (serve scenarios).
    pub backend: Option<String>,
    /// Workload seed, when one applies.
    pub seed: Option<u64>,
    /// The full scenario parameter set, flattened to the same
    /// `key = value` form the suite files use — enough to re-run the
    /// exact experiment.
    pub params: Vec<(String, String)>,
    /// True when a wall-clock budget (`budget_s`) stopped the run
    /// before it finished — the numbers cover a partial workload.
    pub truncated: bool,
}

/// A structured experiment result.
#[derive(Debug, Clone, PartialEq)]
pub struct Outcome {
    pub schema_version: u32,
    pub title: String,
    pub provenance: Provenance,
    pub metrics: Vec<Metric>,
    pub columns: Vec<Column>,
    pub rows: Vec<Vec<Value>>,
    /// Free-text context lines (paper reference points etc.).
    pub notes: Vec<String>,
}

impl Outcome {
    pub fn new(title: &str, provenance: Provenance) -> Self {
        Outcome {
            schema_version: SCHEMA_VERSION,
            title: title.to_string(),
            provenance,
            metrics: Vec::new(),
            columns: Vec::new(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Add a summary metric.
    pub fn metric<V: Into<Value>>(&mut self, name: &str, value: V, unit: Option<&str>) {
        self.metrics.push(Metric {
            name: name.to_string(),
            value: value.into(),
            unit: unit.map(|u| u.to_string()),
        });
    }

    /// Declare the row-grid columns as `(name, unit)` pairs.
    pub fn columns(&mut self, cols: &[(&str, Option<&str>)]) {
        self.columns = cols
            .iter()
            .map(|(n, u)| Column {
                name: n.to_string(),
                unit: u.map(|s| s.to_string()),
            })
            .collect();
    }

    /// Append one row (arity must match the declared columns).
    pub fn row(&mut self, cells: Vec<Value>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn note(&mut self, text: &str) {
        self.notes.push(text.to_string());
    }

    /// Look up a summary metric's numeric value.
    pub fn metric_f64(&self, name: &str) -> Option<f64> {
        self.metrics
            .iter()
            .find(|m| m.name == name)
            .and_then(|m| m.value.as_f64())
    }

    /// Index of a grid column by name.
    pub fn column_index(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c.name == name)
    }

    /// Numeric view of one column across all rows.
    pub fn column_f64(&self, name: &str) -> Vec<f64> {
        match self.column_index(name) {
            None => Vec::new(),
            Some(i) => self
                .rows
                .iter()
                .filter_map(|r| r[i].as_f64())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prov() -> Provenance {
        Provenance {
            scenario: "test".to_string(),
            preset: "paper".to_string(),
            p_sub: 4,
            backend: None,
            seed: Some(42),
            params: vec![("kind".to_string(), "test".to_string())],
            truncated: false,
        }
    }

    #[test]
    fn metrics_and_rows_accumulate() {
        let mut o = Outcome::new("t", prov());
        o.metric("speedup", 4.72, Some("x"));
        o.metric("requests", 16usize, None);
        o.columns(&[("in", None), ("time", Some("s"))]);
        o.row(vec![32usize.into(), 0.5.into()]);
        assert_eq!(o.schema_version, SCHEMA_VERSION);
        assert_eq!(o.metric_f64("speedup"), Some(4.72));
        assert_eq!(o.metric_f64("requests"), Some(16.0));
        assert_eq!(o.metric_f64("absent"), None);
        assert_eq!(o.column_f64("time"), vec![0.5]);
        assert_eq!(o.column_index("in"), Some(0));
    }

    #[test]
    #[should_panic]
    fn row_arity_mismatch_panics() {
        let mut o = Outcome::new("t", prov());
        o.columns(&[("a", None), ("b", None)]);
        o.row(vec![1usize.into()]);
    }

    #[test]
    fn value_coercions() {
        assert_eq!(Value::from(3usize).as_f64(), Some(3.0));
        assert_eq!(Value::from(2.5).as_f64(), Some(2.5));
        assert_eq!(Value::from("x").as_f64(), None);
        assert_eq!(Value::from(true), Value::Bool(true));
    }
}
