//! The declarative experiment API.
//!
//! A [`Scenario`] is a typed, serializable description of one experiment
//! — what every CLI command, bench and example used to hand-wire. The
//! [`Runner`] executes a scenario against the simulator stack and
//! returns a structured [`Outcome`] (metrics + rows + provenance); the
//! [`sink`] layer renders outcomes as text tables, JSON or CSV and
//! accumulates them into schema-versioned `BENCH_*.json` files.
//!
//! Scenarios round-trip through a flat TOML subset ([`file`]):
//! `sal-pim run --scenario scenarios/smoke.toml` executes a whole suite
//! from a file. New experiment surfaces should add a scenario variant
//! here instead of growing bespoke CLI plumbing.

pub mod compare;
pub mod file;
pub mod outcome;
pub mod runner;
pub mod sink;

pub use outcome::{Column, Metric, Outcome, Provenance, Value, SCHEMA_VERSION};
pub use runner::Runner;

use crate::config::parse::{apply_overrides, ConfigError};
use crate::config::SimConfig;
use crate::serve::{
    BackendKind, EngineCore, EvictPolicy, FabricKind, KvPolicy, Policy, PrefixCacheMode, Routing,
    SchedSpec, WorkloadSpec,
};

/// Scenario-layer failure.
#[derive(Debug, thiserror::Error)]
pub enum ScenarioError {
    #[error("unknown preset `{0}` (paper|mini)")]
    UnknownPreset(String),
    #[error(transparent)]
    Config(#[from] ConfigError),
    #[error("scenario file line {line}: {msg}")]
    Parse { line: usize, msg: String },
    #[error("P_Sub {p_sub} out of range 1..={max}")]
    BadPSub { p_sub: usize, max: usize },
    #[error("scenario cannot run: {0}")]
    Unsupported(String),
}

/// Which simulator configuration a scenario resolves.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigSel {
    /// Preset name: `paper` | `mini`.
    pub preset: String,
    /// Optional `P_Sub` override on top of the preset.
    pub p_sub: Option<usize>,
    /// `key = value` config overrides (the [`crate::config::parse`]
    /// vocabulary), applied after the preset.
    pub overrides: Vec<(String, String)>,
    /// Per-scenario wall-clock budget in seconds: the runner stops the
    /// scenario cleanly past it and marks the outcome truncated in
    /// provenance instead of hanging CI. `None` = unbounded.
    pub budget_s: Option<f64>,
}

impl Default for ConfigSel {
    fn default() -> Self {
        ConfigSel {
            preset: "paper".to_string(),
            p_sub: None,
            overrides: Vec::new(),
            budget_s: None,
        }
    }
}

impl ConfigSel {
    pub fn preset(name: &str) -> Self {
        ConfigSel {
            preset: name.to_string(),
            ..Self::default()
        }
    }

    pub fn with_p_sub(mut self, p_sub: usize) -> Self {
        self.p_sub = Some(p_sub);
        self
    }

    pub fn with_override(mut self, key: &str, value: &str) -> Self {
        self.overrides.push((key.to_string(), value.to_string()));
        self
    }

    /// Cap the scenario's wall-clock execution time (`budget_s` in
    /// suite files).
    pub fn with_budget_s(mut self, s: f64) -> Self {
        self.budget_s = Some(s);
        self
    }

    /// Resolve to a validated [`SimConfig`].
    pub fn resolve(&self) -> Result<SimConfig, ScenarioError> {
        let base = match self.preset.as_str() {
            "paper" => SimConfig::paper(),
            "mini" => SimConfig::mini(),
            other => return Err(ScenarioError::UnknownPreset(other.to_string())),
        };
        let pairs: Vec<(usize, String, String)> = self
            .overrides
            .iter()
            .enumerate()
            .map(|(i, (k, v))| (i + 1, k.clone(), v.clone()))
            .collect();
        let mut cfg = apply_overrides(base, &pairs)?;
        if let Some(p_sub) = self.p_sub {
            if !(1..=cfg.salu.max_p_sub).contains(&p_sub) {
                return Err(ScenarioError::BadPSub {
                    p_sub,
                    max: cfg.salu.max_p_sub,
                });
            }
            cfg = cfg.with_p_sub(p_sub);
        }
        Ok(cfg)
    }
}

/// One end-to-end generation (`sal-pim simulate`).
#[derive(Debug, Clone, PartialEq)]
pub struct SimulateParams {
    pub config: ConfigSel,
    pub n_in: usize,
    pub n_out: usize,
    pub prefetch: bool,
}

impl Default for SimulateParams {
    fn default() -> Self {
        SimulateParams {
            config: ConfigSel::default(),
            n_in: 32,
            n_out: 64,
            prefetch: false,
        }
    }
}

impl SimulateParams {
    pub fn with_config(mut self, config: ConfigSel) -> Self {
        self.config = config;
        self
    }

    pub fn with_io(mut self, n_in: usize, n_out: usize) -> Self {
        self.n_in = n_in;
        self.n_out = n_out;
        self
    }

    pub fn with_prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }
}

/// The Fig. 11 speedup grid (`sal-pim sweep`).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepParams {
    pub config: ConfigSel,
    /// Prompt sizes (grid rows).
    pub ins: Vec<usize>,
    /// Output sizes (grid columns).
    pub outs: Vec<usize>,
}

impl Default for SweepParams {
    fn default() -> Self {
        SweepParams {
            config: ConfigSel::default(),
            ins: vec![32, 64, 128],
            outs: vec![1, 4, 16, 32, 64, 128, 256],
        }
    }
}

impl SweepParams {
    pub fn with_config(mut self, config: ConfigSel) -> Self {
        self.config = config;
        self
    }

    pub fn with_grid(mut self, ins: Vec<usize>, outs: Vec<usize>) -> Self {
        self.ins = ins;
        self.outs = outs;
        self
    }
}

/// Decode-iteration phase breakdown (`sal-pim breakdown`).
#[derive(Debug, Clone, PartialEq)]
pub struct BreakdownParams {
    pub config: ConfigSel,
    /// KV length of the examined iteration.
    pub kv: usize,
}

impl Default for BreakdownParams {
    fn default() -> Self {
        BreakdownParams {
            config: ConfigSel::default(),
            kv: 128,
        }
    }
}

impl BreakdownParams {
    pub fn with_config(mut self, config: ConfigSel) -> Self {
        self.config = config;
        self
    }

    pub fn with_kv(mut self, kv: usize) -> Self {
        self.kv = kv;
        self
    }
}

/// Power by subarray-level parallelism (`sal-pim power`).
#[derive(Debug, Clone, PartialEq)]
pub struct PowerParams {
    pub config: ConfigSel,
    pub n_in: usize,
    pub n_out: usize,
    /// `P_Sub` values to sweep (rows of the Fig. 15 table).
    pub p_subs: Vec<usize>,
}

impl Default for PowerParams {
    fn default() -> Self {
        PowerParams {
            config: ConfigSel::default(),
            n_in: 32,
            n_out: 32,
            p_subs: vec![1, 2, 4],
        }
    }
}

impl PowerParams {
    pub fn with_config(mut self, config: ConfigSel) -> Self {
        self.config = config;
        self
    }

    pub fn with_io(mut self, n_in: usize, n_out: usize) -> Self {
        self.n_in = n_in;
        self.n_out = n_out;
        self
    }

    pub fn with_p_subs(mut self, p_subs: Vec<usize>) -> Self {
        self.p_subs = p_subs;
        self
    }
}

/// Added-logic area (`sal-pim area`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct AreaParams {
    pub config: ConfigSel,
}

impl AreaParams {
    pub fn with_config(mut self, config: ConfigSel) -> Self {
        self.config = config;
        self
    }
}

/// Which serving engine a [`ServeParams`] scenario drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Paper-faithful sequential coordinator.
    Seq,
    /// Continuous batching on one device.
    Batch,
    /// N batching devices behind a router.
    Cluster,
    /// Disaggregated prefill/decode pools bridged by a modeled host
    /// fabric with KV migration (`--prefill-pool` / `--decode-pool` /
    /// `--fabric`).
    Disagg,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "seq" => Some(EngineKind::Seq),
            "batch" => Some(EngineKind::Batch),
            "cluster" => Some(EngineKind::Cluster),
            "disagg" => Some(EngineKind::Disagg),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Seq => "seq",
            EngineKind::Batch => "batch",
            EngineKind::Cluster => "cluster",
            EngineKind::Disagg => "disagg",
        }
    }
}

/// A serving experiment (`sal-pim serve`): one engine, one backend, one
/// seeded workload — or the latency-vs-offered-load sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeParams {
    pub config: ConfigSel,
    pub engine: EngineKind,
    pub backend: BackendKind,
    pub policy: Policy,
    pub route: Routing,
    pub requests: usize,
    pub seed: u64,
    pub devices: usize,
    pub max_batch: usize,
    pub n_sessions: usize,
    /// Chunked-prefill token size; `None` = inline prefill.
    pub prefill_chunk: Option<usize>,
    /// KV allocation discipline (`--kv-policy whole|paged`).
    pub kv_policy: KvPolicy,
    /// Paged eviction policy (`--evict lru|none`).
    pub evict: EvictPolicy,
    /// Paged block-size override in tokens (`--kv-block`).
    pub kv_block: Option<usize>,
    /// KV-region size override in allocation units (`--kv-units`;
    /// what-if capacity-pressure experiments).
    pub kv_units: Option<usize>,
    /// Queue every request at t = 0 (saturating load).
    pub at_once: bool,
    /// Open-loop Poisson arrivals at this rate; `None` = jittered mix.
    pub rate: Option<f64>,
    /// Burst size for Poisson arrivals.
    pub burst: Option<usize>,
    /// GPU prefill offload (seq engine only).
    pub offload: bool,
    /// Latency-vs-offered-load mode: run the cluster once per load.
    pub sweep: bool,
    /// Offered loads (req/s) for sweep mode.
    pub loads: Vec<f64>,
    /// Run-loop core for the batching engines (`--engine-core
    /// event|legacy`); ignored by the sequential engine.
    pub engine_core: EngineCore,
    /// Host-fabric link class for disaggregated serving and swap-to-host
    /// eviction (`--fabric pcie|nvlink|ideal`).
    pub fabric: FabricKind,
    /// Prefill-pool size for the disagg engine (`--prefill-pool`);
    /// `None` falls back to `devices / 2` (at least 1).
    pub prefill_pool: Option<usize>,
    /// Decode-pool size for the disagg engine (`--decode-pool`);
    /// `None` falls back to the remaining devices (at least 1).
    pub decode_pool: Option<usize>,
    /// Typed workload description (`--workload` / `workload` key).
    /// `None` desugars the legacy `at_once`/`rate`/`burst`/`n_sessions`
    /// knobs through [`WorkloadSpec::from_legacy`] — bit-identical to
    /// the historical generator.
    pub workload: Option<WorkloadSpec>,
    /// Cross-session KV prefix caching mode (`--prefix-cache
    /// session|radix`; paged KV only).
    pub prefix_cache: PrefixCacheMode,
    /// Typed schedule description (`--schedule` / `schedule` key).
    /// `None` desugars the legacy `backend` choice through
    /// [`SchedSpec::from_legacy`] — `static:<backend>`, bit-identical
    /// to the historical single-backend runs. `phase` heads route
    /// dynamically through [`crate::serve::PhaseSim`].
    pub schedule: Option<SchedSpec>,
}

impl Default for ServeParams {
    fn default() -> Self {
        ServeParams {
            config: ConfigSel::default(),
            engine: EngineKind::Seq,
            backend: BackendKind::SalPim,
            policy: Policy::Fcfs,
            route: Routing::RoundRobin,
            requests: 16,
            seed: 42,
            devices: 4,
            max_batch: 8,
            n_sessions: 8,
            prefill_chunk: None,
            kv_policy: KvPolicy::Whole,
            evict: EvictPolicy::Lru,
            kv_block: None,
            kv_units: None,
            at_once: false,
            rate: None,
            burst: None,
            offload: false,
            sweep: false,
            loads: vec![50.0, 200.0, 1000.0],
            engine_core: EngineCore::default(),
            fabric: FabricKind::default(),
            prefill_pool: None,
            decode_pool: None,
            workload: None,
            prefix_cache: PrefixCacheMode::Session,
            schedule: None,
        }
    }
}

impl ServeParams {
    pub fn with_config(mut self, config: ConfigSel) -> Self {
        self.config = config;
        self
    }

    pub fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_route(mut self, route: Routing) -> Self {
        self.route = route;
        self
    }

    pub fn with_workload(mut self, requests: usize, seed: u64) -> Self {
        self.requests = requests;
        self.seed = seed;
        self
    }

    pub fn with_cluster(mut self, devices: usize, max_batch: usize) -> Self {
        self.devices = devices;
        self.max_batch = max_batch;
        self
    }

    pub fn with_prefill_chunk(mut self, chunk: Option<usize>) -> Self {
        self.prefill_chunk = chunk;
        self
    }

    pub fn with_kv_policy(mut self, policy: KvPolicy) -> Self {
        self.kv_policy = policy;
        self
    }

    pub fn with_evict(mut self, evict: EvictPolicy) -> Self {
        self.evict = evict;
        self
    }

    pub fn with_kv_block(mut self, block: Option<usize>) -> Self {
        self.kv_block = block;
        self
    }

    pub fn with_kv_units(mut self, units: Option<usize>) -> Self {
        self.kv_units = units;
        self
    }

    pub fn with_at_once(mut self, on: bool) -> Self {
        self.at_once = on;
        self
    }

    pub fn with_rate(mut self, rate: Option<f64>, burst: Option<usize>) -> Self {
        self.rate = rate;
        self.burst = burst;
        self
    }

    pub fn with_offload(mut self, on: bool) -> Self {
        self.offload = on;
        self
    }

    pub fn with_sweep(mut self, loads: Vec<f64>) -> Self {
        self.sweep = true;
        self.loads = loads;
        self
    }

    pub fn with_engine_core(mut self, core: EngineCore) -> Self {
        self.engine_core = core;
        self
    }

    pub fn with_fabric(mut self, fabric: FabricKind) -> Self {
        self.fabric = fabric;
        self
    }

    /// Attach a typed workload spec; overrides the legacy
    /// `at_once`/`rate`/`burst` knobs when set. (Named `_spec` because
    /// [`ServeParams::with_workload`] historically sets count + seed.)
    pub fn with_workload_spec(mut self, spec: WorkloadSpec) -> Self {
        self.workload = Some(spec);
        self
    }

    pub fn with_prefix_cache(mut self, mode: PrefixCacheMode) -> Self {
        self.prefix_cache = mode;
        self
    }

    /// Attach a typed schedule spec; overrides the legacy `backend`
    /// choice when set (the `--backend` flag is a documented alias for
    /// `--schedule static:<backend>`).
    pub fn with_schedule(mut self, spec: SchedSpec) -> Self {
        self.schedule = Some(spec);
        self
    }

    /// Size the disagg engine's pools explicitly (`--prefill-pool` /
    /// `--decode-pool`); unset sides derive from `devices`.
    pub fn with_pools(mut self, prefill: Option<usize>, decode: Option<usize>) -> Self {
        self.prefill_pool = prefill;
        self.decode_pool = decode;
        self
    }

    /// Resolved (prefill, decode) pool sizes: explicit values win, the
    /// rest of `devices` fills the unset side, both at least 1.
    pub fn pool_sizes(&self) -> (usize, usize) {
        let prefill = self
            .prefill_pool
            .unwrap_or_else(|| (self.devices / 2).max(1));
        let decode = self
            .decode_pool
            .unwrap_or_else(|| self.devices.saturating_sub(prefill).max(1));
        (prefill.max(1), decode.max(1))
    }
}

/// Parse a policy token (`fcfs|sjf|spf|priority`).
pub fn parse_policy(s: &str) -> Option<Policy> {
    match s {
        "fcfs" => Some(Policy::Fcfs),
        "sjf" => Some(Policy::ShortestJobFirst),
        "spf" => Some(Policy::ShortestPromptFirst),
        "priority" => Some(Policy::Priority),
        _ => None,
    }
}

/// Parse a routing token (`rr|ll|affinity`, long names accepted).
pub fn parse_route(s: &str) -> Option<Routing> {
    match s {
        "rr" | "round-robin" => Some(Routing::RoundRobin),
        "ll" | "least-loaded" => Some(Routing::LeastLoaded),
        "affinity" | "session-affinity" => Some(Routing::SessionAffinity),
        _ => None,
    }
}

/// Short routing token, the `--route` vocabulary (serialization form).
pub fn route_token(r: Routing) -> &'static str {
    match r {
        Routing::RoundRobin => "rr",
        Routing::LeastLoaded => "ll",
        Routing::SessionAffinity => "affinity",
    }
}

/// Free-form escape hatch (`kind = custom` in suite files): arbitrary
/// `param.<key> = <value>` pairs carried through the pipeline verbatim.
/// The runner resolves the config (validating it), reports numeric
/// parameter values as informational metrics and records every pair in
/// provenance — so ad-hoc experiment notes ride the same BENCH/bench-diff
/// machinery without a dedicated scenario variant.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CustomParams {
    pub config: ConfigSel,
    /// Experiment label (`label` key); names the outcome.
    pub label: String,
    /// `param.<key>` pairs, in file order.
    pub params: Vec<(String, String)>,
}

impl CustomParams {
    pub fn with_config(mut self, config: ConfigSel) -> Self {
        self.config = config;
        self
    }

    pub fn with_label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    pub fn with_param(mut self, key: &str, value: &str) -> Self {
        self.params.push((key.to_string(), value.to_string()));
        self
    }
}

/// A declarative experiment.
#[derive(Debug, Clone, PartialEq)]
pub enum Scenario {
    Simulate(SimulateParams),
    Sweep(SweepParams),
    Breakdown(BreakdownParams),
    Power(PowerParams),
    Area(AreaParams),
    Serve(ServeParams),
    Custom(CustomParams),
}

impl Scenario {
    /// Kind tag used in suite files and provenance.
    pub fn kind(&self) -> &'static str {
        match self {
            Scenario::Simulate(_) => "simulate",
            Scenario::Sweep(_) => "sweep",
            Scenario::Breakdown(_) => "breakdown",
            Scenario::Power(_) => "power",
            Scenario::Area(_) => "area",
            Scenario::Serve(_) => "serve",
            Scenario::Custom(_) => "custom",
        }
    }

    /// Tag naming the `BENCH_<tag>.json` file outcomes accumulate into
    /// (paper-figure tags where the scenario reproduces a figure).
    pub fn bench_tag(&self) -> &'static str {
        match self {
            Scenario::Simulate(_) => "simulate",
            Scenario::Sweep(_) => "fig11",
            Scenario::Breakdown(_) => "fig03",
            Scenario::Power(_) => "fig15",
            Scenario::Area(_) => "tab03",
            Scenario::Serve(_) => "serve",
            Scenario::Custom(_) => "custom",
        }
    }

    /// The scenario's config selector.
    pub fn config(&self) -> &ConfigSel {
        match self {
            Scenario::Simulate(p) => &p.config,
            Scenario::Sweep(p) => &p.config,
            Scenario::Breakdown(p) => &p.config,
            Scenario::Power(p) => &p.config,
            Scenario::Area(p) => &p.config,
            Scenario::Serve(p) => &p.config,
            Scenario::Custom(p) => &p.config,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_sel_resolves_presets_and_overrides() {
        let cfg = ConfigSel::default().resolve().unwrap();
        assert_eq!(cfg.parallelism.p_sub, 4);
        let cfg = ConfigSel::preset("mini")
            .with_p_sub(2)
            .with_override("lut.sections", "128")
            .resolve()
            .unwrap();
        assert_eq!(cfg.model.name, "gpt2-mini");
        assert_eq!(cfg.parallelism.p_sub, 2);
        assert_eq!(cfg.lut.sections, 128);
        let sel = ConfigSel::default().with_budget_s(30.0);
        assert_eq!(sel.budget_s, Some(30.0));
        assert_eq!(ConfigSel::default().budget_s, None);
    }

    #[test]
    fn config_sel_rejects_bad_inputs_without_panicking() {
        assert!(matches!(
            ConfigSel::preset("huge").resolve(),
            Err(ScenarioError::UnknownPreset(_))
        ));
        assert!(matches!(
            ConfigSel::default().with_p_sub(8).resolve(),
            Err(ScenarioError::BadPSub { p_sub: 8, max: 4 })
        ));
        assert!(matches!(
            ConfigSel::default()
                .with_override("p_subb", "4")
                .resolve(),
            Err(ScenarioError::Config(_))
        ));
    }

    #[test]
    fn builders_cover_the_cli_surface() {
        let s = ServeParams::default()
            .with_engine(EngineKind::Cluster)
            .with_backend(BackendKind::Hetero)
            .with_policy(Policy::ShortestJobFirst)
            .with_route(Routing::LeastLoaded)
            .with_workload(64, 7)
            .with_cluster(2, 4)
            .with_prefill_chunk(Some(32))
            .with_kv_policy(KvPolicy::Paged)
            .with_evict(EvictPolicy::None)
            .with_kv_block(Some(16))
            .with_kv_units(Some(64))
            .with_rate(Some(200.0), Some(4))
            .with_engine_core(EngineCore::Legacy)
            .with_fabric(FabricKind::Nvlink)
            .with_pools(Some(1), Some(3))
            .with_prefix_cache(PrefixCacheMode::Radix)
            .with_workload_spec(WorkloadSpec::parse("poisson:100,sessions=4").unwrap())
            .with_schedule(SchedSpec::parse("phase,hysteresis=1").unwrap());
        assert_eq!(s.engine, EngineKind::Cluster);
        assert_eq!(s.devices, 2);
        assert_eq!(s.rate, Some(200.0));
        assert_eq!(s.fabric, FabricKind::Nvlink);
        assert_eq!(s.pool_sizes(), (1, 3));
        assert_eq!(s.kv_policy, KvPolicy::Paged);
        assert_eq!(s.evict, EvictPolicy::None);
        assert_eq!(s.kv_block, Some(16));
        assert_eq!(s.kv_units, Some(64));
        assert_eq!(s.engine_core, EngineCore::Legacy);
        assert_eq!(s.prefix_cache, PrefixCacheMode::Radix);
        assert_eq!(
            s.workload.as_ref().unwrap().render(),
            "poisson:100,sessions=4"
        );
        assert_eq!(s.schedule.as_ref().unwrap().render(), "phase,hysteresis=1");
        assert_eq!(ServeParams::default().engine_core, EngineCore::Event);
        assert_eq!(ServeParams::default().workload, None);
        assert_eq!(ServeParams::default().schedule, None);
        assert_eq!(ServeParams::default().prefix_cache, PrefixCacheMode::Session);
        let sweep = ServeParams::default().with_sweep(vec![100.0]);
        assert!(sweep.sweep);
        assert_eq!(sweep.loads, vec![100.0]);
    }

    #[test]
    fn kind_and_tag_cover_every_variant() {
        let all = [
            Scenario::Simulate(SimulateParams::default()),
            Scenario::Sweep(SweepParams::default()),
            Scenario::Breakdown(BreakdownParams::default()),
            Scenario::Power(PowerParams::default()),
            Scenario::Area(AreaParams::default()),
            Scenario::Serve(ServeParams::default()),
            Scenario::Custom(CustomParams::default()),
        ];
        let kinds: Vec<&str> = all.iter().map(|s| s.kind()).collect();
        assert_eq!(
            kinds,
            vec!["simulate", "sweep", "breakdown", "power", "area", "serve", "custom"]
        );
        let tags: Vec<&str> = all.iter().map(|s| s.bench_tag()).collect();
        assert_eq!(
            tags,
            vec!["simulate", "fig11", "fig03", "fig15", "tab03", "serve", "custom"]
        );
        assert_eq!(all[0].config().preset, "paper");
    }

    #[test]
    fn token_parsers_round_trip() {
        for p in [
            Policy::Fcfs,
            Policy::ShortestJobFirst,
            Policy::ShortestPromptFirst,
            Policy::Priority,
        ] {
            assert_eq!(parse_policy(p.name()), Some(p));
        }
        for r in [
            Routing::RoundRobin,
            Routing::LeastLoaded,
            Routing::SessionAffinity,
        ] {
            assert_eq!(parse_route(route_token(r)), Some(r));
            assert_eq!(parse_route(r.name()), Some(r));
        }
        for e in [
            EngineKind::Seq,
            EngineKind::Batch,
            EngineKind::Cluster,
            EngineKind::Disagg,
        ] {
            assert_eq!(EngineKind::parse(e.name()), Some(e));
        }
        assert_eq!(parse_policy("lifo"), None);
        assert_eq!(parse_route("random"), None);
    }

    #[test]
    fn pool_sizes_derive_from_devices_when_unset() {
        let p = ServeParams::default().with_cluster(4, 8);
        assert_eq!(p.pool_sizes(), (2, 2));
        let p = ServeParams::default().with_cluster(1, 8);
        assert_eq!(p.pool_sizes(), (1, 1), "degenerate fleet still serves");
        let p = ServeParams::default()
            .with_cluster(6, 8)
            .with_pools(Some(2), None);
        assert_eq!(p.pool_sizes(), (2, 4));
    }
}
