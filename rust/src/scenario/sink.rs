//! Outcome rendering: text tables, JSON, CSV, `BENCH_*.json` files.
//!
//! One [`Outcome`] feeds every consumer: the CLI renders it as the
//! familiar [`crate::report::Table`] text (unit-aware cell formatting),
//! `--json` emits a schema-versioned JSON object, `--out file.csv`
//! emits the raw machine values, and the bench harness accumulates
//! outcomes into `BENCH_<tag>.json` trajectory files. Because every
//! rendering reads the same record, the JSON metrics always match the
//! text tables by construction.

use super::outcome::{Column, Metric, Outcome, Value};
use crate::report::{fmt_bw, fmt_pct, fmt_time, fmt_x, Table};
use std::fmt::Write as _;
use std::io;
use std::path::{Path, PathBuf};

/// Format a value for human tables, using the column/metric unit.
fn display_cell(value: &Value, unit: Option<&str>) -> String {
    match value {
        Value::Text(s) => s.clone(),
        Value::Bool(b) => b.to_string(),
        Value::Int(i) => i.to_string(),
        Value::Num(x) => match unit {
            Some("s") => fmt_time(*x),
            Some("x") => fmt_x(*x),
            Some("frac") => fmt_pct(*x),
            Some("B/s") => fmt_bw(*x),
            Some("tok/s") | Some("W") => format!("{x:.1}"),
            Some("mm2") => format!("{x:.2}"),
            Some("req/s") => format!("{x:.0}"),
            _ => format!("{x:.3}"),
        },
    }
}

/// Units the display formatter embeds into the cell text itself.
fn unit_embedded_in_cell(unit: &str) -> bool {
    matches!(unit, "s" | "x" | "frac" | "B/s")
}

fn header_of(col: &Column) -> String {
    match &col.unit {
        Some(u) if !unit_embedded_in_cell(u) => format!("{} ({u})", col.name),
        _ => col.name.clone(),
    }
}

/// Render the outcome's row grid as a [`Table`].
pub fn to_table(outcome: &Outcome) -> Table {
    let headers: Vec<String> = outcome.columns.iter().map(header_of).collect();
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&outcome.title, &header_refs);
    for row in &outcome.rows {
        let cells: Vec<String> = row
            .iter()
            .zip(&outcome.columns)
            .map(|(v, c)| display_cell(v, c.unit.as_deref()))
            .collect();
        t.row(&cells);
    }
    t
}

fn metric_line(m: &Metric) -> String {
    let shown = display_cell(&m.value, m.unit.as_deref());
    match &m.unit {
        Some(u) if !unit_embedded_in_cell(u) => format!("{}: {} {}", m.name, shown, u),
        _ => format!("{}: {}", m.name, shown),
    }
}

/// The full human rendering: table (if any rows), metrics, notes.
pub fn render_text(outcome: &Outcome) -> String {
    let mut out = String::new();
    if outcome.rows.is_empty() {
        let _ = writeln!(out, "## {}", outcome.title);
    } else {
        out.push_str(&to_table(outcome).render());
    }
    for m in &outcome.metrics {
        let _ = writeln!(out, "{}", metric_line(m));
    }
    for n in &outcome.notes {
        let _ = writeln!(out, "note: {n}");
    }
    out
}

/// JSON string escaping (quotes, backslashes, control characters).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn json_value(v: &Value) -> String {
    match v {
        Value::Int(i) => i.to_string(),
        Value::Num(x) if x.is_finite() => x.to_string(),
        Value::Num(_) => "null".to_string(),
        Value::Text(s) => format!("\"{}\"", json_escape(s)),
        Value::Bool(b) => b.to_string(),
    }
}

fn json_opt_str(s: &Option<String>) -> String {
    match s {
        Some(s) => format!("\"{}\"", json_escape(s)),
        None => "null".to_string(),
    }
}

/// Serialize one outcome as a JSON object (schema-versioned).
pub fn to_json(outcome: &Outcome) -> String {
    let p = &outcome.provenance;
    let params: Vec<String> = p
        .params
        .iter()
        .map(|(k, v)| format!("\"{}\": \"{}\"", json_escape(k), json_escape(v)))
        .collect();
    let metrics: Vec<String> = outcome
        .metrics
        .iter()
        .map(|m| {
            format!(
                "{{\"name\": \"{}\", \"value\": {}, \"unit\": {}}}",
                json_escape(&m.name),
                json_value(&m.value),
                json_opt_str(&m.unit)
            )
        })
        .collect();
    let columns: Vec<String> = outcome
        .columns
        .iter()
        .map(|c| {
            format!(
                "{{\"name\": \"{}\", \"unit\": {}}}",
                json_escape(&c.name),
                json_opt_str(&c.unit)
            )
        })
        .collect();
    let rows: Vec<String> = outcome
        .rows
        .iter()
        .map(|r| {
            let cells: Vec<String> = r.iter().map(json_value).collect();
            format!("[{}]", cells.join(", "))
        })
        .collect();
    let notes: Vec<String> = outcome
        .notes
        .iter()
        .map(|n| format!("\"{}\"", json_escape(n)))
        .collect();
    format!(
        "{{\"schema_version\": {}, \"scenario\": \"{}\", \"title\": \"{}\", \
         \"provenance\": {{\"preset\": \"{}\", \"p_sub\": {}, \"backend\": {}, \
         \"seed\": {}, \"truncated\": {}, \"params\": {{{}}}}}, \
         \"metrics\": [{}], \"columns\": [{}], \"rows\": [{}], \"notes\": [{}]}}",
        outcome.schema_version,
        json_escape(&p.scenario),
        json_escape(&outcome.title),
        json_escape(&p.preset),
        p.p_sub,
        json_opt_str(&p.backend),
        p.seed.map(|s| s.to_string()).unwrap_or_else(|| "null".to_string()),
        p.truncated,
        params.join(", "),
        metrics.join(", "),
        columns.join(", "),
        rows.join(", "),
        notes.join(", ")
    )
}

fn csv_cell(v: &Value) -> String {
    match v {
        Value::Text(s) if s.contains(',') || s.contains('"') => {
            format!("\"{}\"", s.replace('"', "\"\""))
        }
        Value::Text(s) => s.clone(),
        Value::Int(i) => i.to_string(),
        Value::Num(x) => x.to_string(),
        Value::Bool(b) => b.to_string(),
    }
}

/// CSV of the row grid (raw machine values, `name (unit)` headers).
/// Metric-only outcomes (no row grid) fall back to `metric,value,unit`
/// rows so `--out file.csv` never writes an empty file.
pub fn to_csv(outcome: &Outcome) -> String {
    let mut out = String::new();
    if outcome.columns.is_empty() {
        let _ = writeln!(out, "metric,value,unit");
        for m in &outcome.metrics {
            let _ = writeln!(
                out,
                "{},{},{}",
                csv_cell(&Value::Text(m.name.clone())),
                csv_cell(&m.value),
                m.unit.as_deref().unwrap_or("")
            );
        }
        return out;
    }
    let headers: Vec<String> = outcome.columns.iter().map(header_of).collect();
    let _ = writeln!(out, "{}", headers.join(","));
    for row in &outcome.rows {
        let cells: Vec<String> = row.iter().map(csv_cell).collect();
        let _ = writeln!(out, "{}", cells.join(","));
    }
    out
}

/// The `BENCH_<tag>.json` document: every outcome of one tag.
pub fn bench_json(tag: &str, outcomes: &[&Outcome]) -> String {
    let body: Vec<String> = outcomes.iter().map(|o| to_json(o)).collect();
    format!(
        "{{\"schema_version\": {}, \"bench\": \"{}\", \"outcomes\": [\n{}\n]}}\n",
        super::SCHEMA_VERSION,
        json_escape(tag),
        body.join(",\n")
    )
}

/// Write one tag's bench file into `dir`; returns its path.
pub fn write_bench_file(dir: &Path, tag: &str, outcomes: &[&Outcome]) -> io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("BENCH_{tag}.json"));
    std::fs::write(&path, bench_json(tag, outcomes))?;
    Ok(path)
}

/// Group `(tag, outcome)` pairs by tag (first-seen order) and write one
/// bench file per tag; returns the written paths.
pub fn write_bench_files(
    dir: &Path,
    tagged: &[(&str, &Outcome)],
) -> io::Result<Vec<PathBuf>> {
    let mut tags: Vec<&str> = Vec::new();
    for (tag, _) in tagged {
        if !tags.contains(tag) {
            tags.push(tag);
        }
    }
    let mut paths = Vec::new();
    for tag in tags {
        let group: Vec<&Outcome> = tagged
            .iter()
            .filter(|(t, _)| *t == tag)
            .map(|(_, o)| *o)
            .collect();
        paths.push(write_bench_file(dir, tag, &group)?);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::outcome::Provenance;

    fn sample() -> Outcome {
        let mut o = Outcome::new(
            "Fig. T — sample",
            Provenance {
                scenario: "sweep".to_string(),
                preset: "paper".to_string(),
                p_sub: 4,
                backend: Some("salpim".to_string()),
                seed: Some(42),
                params: vec![("kind".to_string(), "sweep".to_string())],
                truncated: false,
            },
        );
        o.metric("max_speedup", 4.72, Some("x"));
        o.metric("requests", 16usize, None);
        o.columns(&[
            ("in", None),
            ("time", Some("s")),
            ("speedup", Some("x")),
            ("power", Some("W")),
        ]);
        o.row(vec![32usize.into(), 0.0025.into(), 4.72.into(), 61.25.into()]);
        o.note("paper: 4.72x");
        o
    }

    #[test]
    fn table_uses_unit_aware_formatting() {
        let t = to_table(&sample());
        let r = t.render();
        assert!(r.contains("2.500 ms"), "{r}");
        assert!(r.contains("4.72×"), "{r}");
        assert!(r.contains("61.2"), "{r}");
        assert!(r.contains("power (W)"), "{r}");
        // Embedded units don't repeat in the header.
        assert!(!r.contains("time (s)"), "{r}");
    }

    #[test]
    fn render_text_includes_metrics_and_notes() {
        let text = render_text(&sample());
        assert!(text.contains("## Fig. T — sample"));
        assert!(text.contains("max_speedup: 4.72×"));
        assert!(text.contains("requests: 16"));
        assert!(text.contains("note: paper: 4.72x"));
    }

    #[test]
    fn json_is_schema_versioned_and_quotes_escape() {
        let mut o = sample();
        o.note("a \"quoted\" note\nwith newline");
        let j = to_json(&o);
        assert!(j.starts_with("{\"schema_version\": 1, \"scenario\": \"sweep\""));
        assert!(j.contains("\"p_sub\": 4"));
        assert!(j.contains("\"backend\": \"salpim\""));
        assert!(j.contains("\"seed\": 42"));
        assert!(j.contains("\"truncated\": false"));
        assert!(j.contains("\\\"quoted\\\""));
        assert!(j.contains("\\n"));
        assert!(j.contains("\"rows\": [[32, 0.0025, 4.72, 61.25]]"));
        // Balanced braces/brackets (cheap well-formedness probe).
        assert_eq!(
            j.matches('{').count(),
            j.matches('}').count(),
            "unbalanced braces: {j}"
        );
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn nan_serializes_as_null() {
        let mut o = sample();
        o.metric("bad", f64::NAN, None);
        let j = to_json(&o);
        assert!(j.contains("\"name\": \"bad\", \"value\": null"));
    }

    #[test]
    fn csv_has_raw_values() {
        let csv = to_csv(&sample());
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("in,time,speedup,power (W)"));
        assert_eq!(lines.next(), Some("32,0.0025,4.72,61.25"));
    }

    #[test]
    fn csv_falls_back_to_metrics_without_a_grid() {
        let mut o = sample();
        o.columns.clear();
        o.rows.clear();
        let csv = to_csv(&o);
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("metric,value,unit"));
        assert_eq!(lines.next(), Some("max_speedup,4.72,x"));
        assert_eq!(lines.next(), Some("requests,16,"));
        assert_eq!(lines.next(), None);
    }

    #[test]
    fn bench_files_group_by_tag() {
        let dir = std::env::temp_dir().join("salpim_sink_test");
        let _ = std::fs::remove_dir_all(&dir);
        let a = sample();
        let b = sample();
        let paths =
            write_bench_files(&dir, &[("fig11", &a), ("serve", &b), ("fig11", &b)]).unwrap();
        assert_eq!(paths.len(), 2);
        let fig11 = std::fs::read_to_string(dir.join("BENCH_fig11.json")).unwrap();
        assert!(fig11.contains("\"bench\": \"fig11\""));
        assert_eq!(fig11.matches("\"schema_version\": 1").count(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
