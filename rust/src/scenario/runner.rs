//! Scenario execution.
//!
//! [`Runner`] is the one place experiments are wired to the simulator
//! stack: the CLI, the benches and the examples all hand it a
//! [`Scenario`] and get back a structured [`Outcome`] with the same
//! numbers the hand-wired code paths used to print. Each variant's
//! implementation mirrors the paper experiment it reproduces.

use super::outcome::{Outcome, Provenance};
use super::{CustomParams, EngineKind, Scenario, ScenarioError, ServeParams};
use crate::baseline::GpuModel;
use crate::config::SimConfig;
use crate::coordinator::{Coordinator, PrefillTarget};
use crate::energy::{AreaModel, EnergyParams, PowerReport};
use crate::mapper::GenerationSim;
use crate::serve::sweep::{latency_vs_load, SweepConfig};
use crate::serve::{
    oracle, pct_of_oracle, BackendKind, Cluster, Completion, DeviceEngine, DisaggregatedCluster,
    Fabric, KvPolicy, PhaseSim, PhaseTopology, PrefixCacheMode, SchedPolicy, SchedSpec,
    ServeMetrics, SloClass, WorkloadSpec,
};
use crate::trace::{PhaseProfile, TraceEvent, TraceHandle};
use std::time::{Duration, Instant};

/// Side-channel results a run produces beyond its [`Outcome`]: the
/// lifecycle event stream (when tracing was requested), the engine
/// self-profile, and whether a wall-clock budget cut the run short.
#[derive(Debug, Clone, Default)]
pub struct RunAux {
    /// Lifecycle events, in emission order; empty unless the run was
    /// traceable and `capture_trace` was set.
    pub events: Vec<TraceEvent>,
    /// Wall-clock self-profile, merged across devices; `None` for
    /// scenario kinds that don't exercise the batching engine.
    pub profile: Option<PhaseProfile>,
    /// True when `budget_s` stopped the run before it finished.
    pub truncated: bool,
}

/// Executes scenarios. Stateless — each run resolves its own config.
#[derive(Debug, Clone, Copy, Default)]
pub struct Runner;

impl Runner {
    pub fn new() -> Self {
        Runner
    }

    /// Whether a scenario can emit a lifecycle trace: only serve runs
    /// on the batching engines (the seq coordinator and the load sweep
    /// have no single request timeline to record).
    pub fn traceable(scenario: &Scenario) -> bool {
        match scenario {
            Scenario::Serve(p) => !p.sweep && p.engine != EngineKind::Seq,
            _ => false,
        }
    }

    /// Run one scenario to a structured outcome.
    pub fn run(&self, scenario: &Scenario) -> Result<Outcome, ScenarioError> {
        self.run_with(scenario, false).map(|(out, _)| out)
    }

    /// Run one scenario, also returning the side-channel [`RunAux`]
    /// (trace events when `capture_trace` and the scenario is
    /// [`Runner::traceable`]; self-profile; budget truncation).
    pub fn run_with(
        &self,
        scenario: &Scenario,
        capture_trace: bool,
    ) -> Result<(Outcome, RunAux), ScenarioError> {
        let cfg = scenario.config().resolve()?;
        let deadline = scenario
            .config()
            .budget_s
            .map(|b| Instant::now() + Duration::from_secs_f64(b.max(0.0)));
        let mut aux = RunAux::default();
        let provenance = Provenance {
            scenario: scenario.kind().to_string(),
            preset: scenario.config().preset.clone(),
            p_sub: cfg.parallelism.p_sub,
            backend: match scenario {
                // The resolved schedule, not the raw flag: `--schedule
                // static:<b>` records `<b>` exactly as `--backend <b>`
                // would, and phase runs record the router itself.
                Scenario::Serve(p) => Some(match sched_spec(p).policy {
                    SchedPolicy::Static(b) => b.name().to_string(),
                    SchedPolicy::Phase => "phase".to_string(),
                }),
                _ => None,
            },
            seed: match scenario {
                Scenario::Serve(p) => Some(p.seed),
                _ => None,
            },
            params: scenario.to_kv(),
            truncated: false,
        };
        let capture = capture_trace && Self::traceable(scenario);
        // Single-shot kinds (simulate, breakdown, area) can't be
        // interrupted mid-run; the budget applies between the units of
        // the iterating kinds (grid cells, P_Sub points, sweep loads)
        // and inside the serve engine loop.
        let mut out = match scenario {
            Scenario::Simulate(p) => run_simulate(&cfg, provenance, p),
            Scenario::Sweep(p) => run_sweep(&cfg, provenance, p, deadline, &mut aux),
            Scenario::Breakdown(p) => run_breakdown(&cfg, provenance, p),
            Scenario::Power(p) => run_power(&cfg, provenance, p, deadline, &mut aux)?,
            Scenario::Area(_) => run_area(&cfg, provenance),
            Scenario::Serve(p) => run_serve(&cfg, provenance, p, deadline, capture, &mut aux)?,
            Scenario::Custom(p) => run_custom(provenance, p),
        };
        if aux.truncated {
            out.provenance.truncated = true;
            out.note("wall-clock budget (budget_s) hit — metrics cover a partial workload");
        }
        Ok((out, aux))
    }

    /// Run a whole suite, in order.
    pub fn run_suite(&self, scenarios: &[Scenario]) -> Result<Vec<Outcome>, ScenarioError> {
        scenarios.iter().map(|s| self.run(s)).collect()
    }

    /// Fold per-run self-profiles into the `BENCH_simperf.json` outcome:
    /// the simulator's own speed, gated by bench-diff at a wide
    /// tolerance (wall clock is noisy) so a simulator-side slowdown
    /// fails CI like a model regression would.
    pub fn simperf_outcome(profiles: &[PhaseProfile]) -> Outcome {
        let mut total = PhaseProfile::default();
        for p in profiles {
            total.merge(p);
        }
        let mut out = Outcome::new(
            "simulator self-profile — engine wall clock by phase",
            Provenance {
                scenario: "simperf".to_string(),
                preset: "-".to_string(),
                p_sub: 0,
                backend: None,
                seed: None,
                params: vec![("runs".to_string(), profiles.len().to_string())],
                truncated: false,
            },
        );
        out.metric(
            "sim_tokens_per_wall_s",
            total.sim_tokens_per_wall_s(),
            Some("tok/s"),
        );
        out.metric("sim_wall_s", total.wall_s, Some("s"));
        out.metric("sim_tokens", total.sim_tokens, None);
        out.metric("phase_admission_s", total.admission_s, Some("s"));
        out.metric("phase_growth_s", total.growth_s, Some("s"));
        out.metric("phase_preempt_s", total.preempt_s, Some("s"));
        out.metric("phase_decode_s", total.decode_s, Some("s"));
        out.metric("phase_readmit_s", total.readmit_s, Some("s"));
        out
    }
}

fn past(deadline: Option<Instant>) -> bool {
    deadline.is_some_and(|d| Instant::now() >= d)
}

fn run_simulate(
    cfg: &SimConfig,
    provenance: Provenance,
    p: &super::SimulateParams,
) -> Outcome {
    let mut sim = GenerationSim::new(cfg);
    sim.set_prefetch(p.prefetch);
    let r = sim.generate(p.n_in, p.n_out);
    let tck = cfg.timing.tck_ns;
    let gpu = GpuModel::titan_rtx().generation_time(&cfg.model, p.n_in, p.n_out);
    let total = r.seconds(tck);
    let mut out = Outcome::new(
        &format!(
            "SAL-PIM generation — in={} out={} P_Sub={}",
            p.n_in, p.n_out, cfg.parallelism.p_sub
        ),
        provenance,
    );
    out.metric("prefill", r.prefill.seconds(tck), Some("s"));
    out.metric("decode", r.decode.seconds(tck), Some("s"));
    out.metric("decode_rate", r.decode_tokens_per_sec(tck), Some("tok/s"));
    out.metric("total", total, Some("s"));
    out.metric(
        "avg_internal_bandwidth",
        r.total().avg_internal_bandwidth(tck) * cfg.hbm.pseudo_channels() as f64,
        Some("B/s"),
    );
    out.metric("gpu_baseline", gpu, Some("s"));
    out.metric("speedup_vs_gpu", gpu / total, Some("x"));
    out
}

fn run_sweep(
    cfg: &SimConfig,
    provenance: Provenance,
    p: &super::SweepParams,
    deadline: Option<Instant>,
    aux: &mut RunAux,
) -> Outcome {
    let gpu = GpuModel::titan_rtx();
    let mut sim = GenerationSim::new(cfg);
    let mut out = Outcome::new("Fig. 11 — speedup of SAL-PIM vs GPU", provenance);
    out.columns(&[
        ("in", None),
        ("out", None),
        ("pim", Some("s")),
        ("gpu", Some("s")),
        ("speedup", Some("x")),
    ]);
    let mut speedups = Vec::new();
    'grid: for &n_in in &p.ins {
        for &n_out in &p.outs {
            if past(deadline) {
                aux.truncated = true;
                break 'grid;
            }
            let pim = sim.generate(n_in, n_out).seconds(cfg.timing.tck_ns);
            let g = gpu.generation_time(&cfg.model, n_in, n_out);
            speedups.push(g / pim);
            out.row(vec![
                n_in.into(),
                n_out.into(),
                pim.into(),
                g.into(),
                (g / pim).into(),
            ]);
        }
    }
    let max = speedups.iter().cloned().fold(0.0f64, f64::max);
    let avg = if speedups.is_empty() {
        0.0
    } else {
        speedups.iter().sum::<f64>() / speedups.len() as f64
    };
    out.metric("max_speedup", max, Some("x"));
    out.metric("avg_speedup", avg, Some("x"));
    out.note("paper: max 4.72x / avg 1.83x");
    out
}

fn run_breakdown(
    cfg: &SimConfig,
    provenance: Provenance,
    p: &super::BreakdownParams,
) -> Outcome {
    let mut sim = GenerationSim::new(cfg);
    let st = sim.decode_token(p.kv);
    let mut out = Outcome::new(
        &format!(
            "decode iteration breakdown — kv={} P_Sub={}",
            p.kv, cfg.parallelism.p_sub
        ),
        provenance,
    );
    out.metric("iteration", st.seconds(cfg.timing.tck_ns), Some("s"));
    out.columns(&[("phase", None), ("fraction", Some("frac"))]);
    for (phase, frac) in st.breakdown() {
        out.row(vec![phase.name().into(), frac.into()]);
    }
    out
}

fn run_power(
    cfg: &SimConfig,
    provenance: Provenance,
    p: &super::PowerParams,
    deadline: Option<Instant>,
    aux: &mut RunAux,
) -> Result<Outcome, ScenarioError> {
    let params = EnergyParams::paper();
    let mut out = Outcome::new(
        "Fig. 15 — power by subarray-level parallelism",
        provenance,
    );
    out.columns(&[
        ("p_sub", None),
        ("act", Some("W")),
        ("movement", Some("W")),
        ("logic", Some("W")),
        ("refresh", Some("W")),
        ("total", Some("W")),
        ("budget_fraction", Some("frac")),
    ]);
    for &p_sub in &p.p_subs {
        if past(deadline) {
            aux.truncated = true;
            break;
        }
        if !(1..=cfg.salu.max_p_sub).contains(&p_sub) {
            return Err(ScenarioError::BadPSub {
                p_sub,
                max: cfg.salu.max_p_sub,
            });
        }
        let c = cfg.clone().with_p_sub(p_sub);
        let mut sim = GenerationSim::new(&c);
        let r = sim.generate(p.n_in, p.n_out);
        let rep = PowerReport::from_stats(&c, &params, &r.total());
        let s = rep.seconds;
        out.row(vec![
            p_sub.into(),
            (rep.act_j / s).into(),
            (rep.movement_j / s).into(),
            (rep.logic_j / s).into(),
            (rep.refresh_j / s).into(),
            rep.avg_power_w().into(),
            rep.budget_fraction().into(),
        ]);
    }
    out.note("paper: P_Sub=4 exceeds the 60 W HBM2 budget by 24%");
    Ok(out)
}

fn run_area(cfg: &SimConfig, provenance: Provenance) -> Outcome {
    let a = AreaModel::new(cfg);
    let mut out = Outcome::new("Table 3 — area per channel", provenance);
    out.columns(&[("unit", None), ("count", None), ("area", Some("mm2"))]);
    out.row(vec![
        "S-ALU".into(),
        a.salus_per_channel.into(),
        a.salu_area_mm2().into(),
    ]);
    out.row(vec![
        "Bank-level unit".into(),
        a.bank_units_per_channel.into(),
        a.bank_unit_area_mm2().into(),
    ]);
    out.row(vec![
        "C-ALU".into(),
        a.calus_per_channel.into(),
        a.calu_area_mm2().into(),
    ]);
    out.metric("total_added", a.total_added_mm2(), Some("mm2"));
    out.metric("overhead_vs_channel", a.overhead_fraction(), Some("frac"));
    out.note("paper: 4.81% overhead vs an HBM2 channel (threshold 25%)");
    out
}

/// Push the standard serving metrics onto an outcome.
fn serve_metrics(out: &mut Outcome, m: &ServeMetrics) {
    out.metric("requests", m.requests, None);
    out.metric("total_tokens", m.total_tokens, None);
    out.metric("makespan", m.makespan_s, Some("s"));
    out.metric("throughput", m.throughput_tok_s, Some("tok/s"));
    out.metric("p50_latency", m.p50_latency_s, Some("s"));
    out.metric("p95_latency", m.p95_latency_s, Some("s"));
    out.metric("p50_ttft", m.p50_ttft_s, Some("s"));
    out.metric("p95_ttft", m.p95_ttft_s, Some("s"));
    out.metric("mean_queue", m.mean_queue_s, Some("s"));
    // Swap traffic only exists under `--evict swap`; keep the metric set
    // (and thus the bench-diff gate's watched names) unchanged otherwise.
    if m.swap_outs > 0 || m.swap_ins > 0 {
        out.metric("swap_outs", m.swap_outs, None);
        out.metric("swap_ins", m.swap_ins, None);
        out.metric("swapped_bytes", m.swapped_bytes, Some("B"));
    }
}

/// The effective workload spec: the typed `workload` field when set,
/// else the legacy `at_once`/`rate`/`burst`/`n_sessions` knobs desugared
/// through [`WorkloadSpec::from_legacy`] — same validation errors, same
/// bytes out (pinned by test).
fn workload_spec(p: &ServeParams) -> Result<WorkloadSpec, ScenarioError> {
    match &p.workload {
        Some(spec) => Ok(spec.clone()),
        None => WorkloadSpec::from_legacy(p.at_once, p.rate, p.burst, p.n_sessions)
            .map_err(ScenarioError::Unsupported),
    }
}

/// The effective schedule spec: the typed `schedule` field when set,
/// else the legacy `backend` knob desugared through
/// [`SchedSpec::from_legacy`] — the one place `--backend <b>` becomes
/// `static:<b>`, so the two spellings stay bit-identical (pinned by
/// test).
fn sched_spec(p: &ServeParams) -> SchedSpec {
    match &p.schedule {
        Some(spec) => spec.clone(),
        None => SchedSpec::from_legacy(p.backend),
    }
}

/// Per-SLO-class percentiles and radix prefix-cache stats. Both are
/// conditional — legacy workloads (no interactive traffic, session-mode
/// prefix cache) keep the historical metric set byte-for-byte, so
/// bench-diff baselines stay stable.
fn class_metrics(out: &mut Outcome, done: &[Completion], p: &ServeParams, m: &ServeMetrics) {
    let interactive: Vec<Completion> = done
        .iter()
        .filter(|c| c.slo == SloClass::Interactive)
        .cloned()
        .collect();
    if !interactive.is_empty() {
        let batch: Vec<Completion> = done
            .iter()
            .filter(|c| c.slo == SloClass::Batch)
            .cloned()
            .collect();
        let im = ServeMetrics::from_completions(&interactive);
        out.metric("interactive_requests", interactive.len(), None);
        out.metric("interactive_p50_latency", im.p50_latency_s, Some("s"));
        out.metric("interactive_p95_latency", im.p95_latency_s, Some("s"));
        out.metric("interactive_p50_ttft", im.p50_ttft_s, Some("s"));
        out.metric("interactive_p95_ttft", im.p95_ttft_s, Some("s"));
        out.metric("batch_requests", batch.len(), None);
        if !batch.is_empty() {
            let bm = ServeMetrics::from_completions(&batch);
            out.metric("batch_p95_latency", bm.p95_latency_s, Some("s"));
        }
    }
    if p.prefix_cache == PrefixCacheMode::Radix {
        let prompt_tokens: usize = done.iter().map(|c| c.prompt_len).sum();
        let rate = if prompt_tokens > 0 {
            m.prefix_reused_tokens as f64 / prompt_tokens as f64
        } else {
            0.0
        };
        out.metric("prefix_hits", m.prefix_hits, None);
        out.metric("prefix_reused_tokens", m.prefix_reused_tokens, None);
        out.metric("prefix_cache_hit_rate", rate, Some("frac"));
    }
}

/// The [`Scenario::Custom`] escape hatch: no simulation, just the
/// resolved config validation (done by the caller) plus the free-form
/// parameters — numeric values become informational metrics, every pair
/// rides in provenance.
fn run_custom(provenance: Provenance, p: &CustomParams) -> Outcome {
    let title = if p.label.is_empty() {
        "custom — ad-hoc experiment record".to_string()
    } else {
        format!("custom — {}", p.label)
    };
    let mut out = Outcome::new(&title, provenance);
    out.metric("params", p.params.len(), None);
    for (k, v) in &p.params {
        if let Ok(x) = v.parse::<f64>() {
            out.metric(k, x, None);
        }
    }
    out
}

fn run_serve(
    cfg: &SimConfig,
    provenance: Provenance,
    p: &ServeParams,
    deadline: Option<Instant>,
    capture_trace: bool,
    aux: &mut RunAux,
) -> Result<Outcome, ScenarioError> {
    if let Some(chunk) = p.prefill_chunk {
        if chunk < 1 {
            return Err(ScenarioError::Unsupported(
                "prefill_chunk must be at least 1 token".to_string(),
            ));
        }
    }
    if let Some(b) = p.kv_block {
        if b < 1 {
            return Err(ScenarioError::Unsupported(
                "kv_block must be at least 1 token".to_string(),
            ));
        }
    }
    if p.engine == EngineKind::Seq
        && (p.kv_policy != KvPolicy::Whole || p.kv_block.is_some() || p.kv_units.is_some())
    {
        return Err(ScenarioError::Unsupported(
            "the paged KV policy needs the batching scheduler; pick engine batch|cluster"
                .to_string(),
        ));
    }
    if p.prefix_cache == PrefixCacheMode::Radix && p.kv_policy != KvPolicy::Paged {
        return Err(ScenarioError::Unsupported(
            "prefix_cache radix shares KV blocks; it needs kv_policy paged".to_string(),
        ));
    }
    // One place the schedule surface desugars: `--backend <b>` is
    // `static:<b>`, so every arm below runs off the resolved backend and
    // the two spellings stay bit-identical.
    let sched = sched_spec(p);
    let backend = match sched.policy {
        SchedPolicy::Static(b) => b,
        SchedPolicy::Phase => {
            if p.sweep {
                return Err(ScenarioError::Unsupported(
                    "the load sweep drives static schedules over its own arrivals; \
                     --schedule phase routes one recorded trace (drop --sweep)"
                        .to_string(),
                ));
            }
            return run_serve_phase(cfg, provenance, p, &sched);
        }
    };
    if p.sweep {
        if p.engine == EngineKind::Disagg {
            return Err(ScenarioError::Unsupported(
                "the load sweep drives a homogeneous cluster; engine disagg is not sweepable"
                    .to_string(),
            ));
        }
        if p.workload.is_some() || p.prefix_cache != PrefixCacheMode::Session {
            return Err(ScenarioError::Unsupported(
                "the load sweep drives its own Poisson arrivals; workload specs and the \
                 radix prefix cache apply to single serve runs"
                    .to_string(),
            ));
        }
        return run_serve_sweep(cfg, provenance, p, backend, deadline, aux);
    }
    let spec = workload_spec(p)?;
    let requests = spec.generate(p.seed, p.requests);

    match p.engine {
        EngineKind::Seq => {
            if backend != BackendKind::SalPim {
                return Err(ScenarioError::Unsupported(format!(
                    "engine seq is the paper-faithful PIM coordinator; pick batch|cluster \
                     for backend {} (or offload for GPU prefill)",
                    backend.name()
                )));
            }
            if p.prefill_chunk.is_some() {
                return Err(ScenarioError::Unsupported(
                    "prefill_chunk needs the batching scheduler; pick engine batch|cluster"
                        .to_string(),
                ));
            }
            let mut coord = Coordinator::new(cfg).with_policy(p.policy);
            if p.offload {
                coord = coord.with_prefill_target(PrefillTarget::GpuOffload);
            }
            for r in requests {
                coord.submit_request(r);
            }
            let done = coord.run();
            let m = ServeMetrics::from_completions(&done);
            let mut out = Outcome::new(
                &format!(
                    "serve — engine=seq policy={} offload={} arrivals={}",
                    p.policy.name(),
                    p.offload,
                    spec.arrival_name()
                ),
                provenance,
            );
            serve_metrics(&mut out, &m);
            class_metrics(&mut out, &done, p, &m);
            Ok(out)
        }
        EngineKind::Batch => {
            if p.offload {
                return Err(ScenarioError::Unsupported(
                    "offload applies to engine seq only (use backend hetero for \
                     GPU prefill under batching)"
                        .to_string(),
                ));
            }
            let mut eng = DeviceEngine::with_backend(backend.build(cfg), p.max_batch)
                .with_policy(p.policy)
                .with_core(p.engine_core)
                .with_prefill_chunk(p.prefill_chunk)
                .with_kv_policy(p.kv_policy)
                .with_evict(p.evict)
                .with_prefix_cache(p.prefix_cache);
            if let Some(b) = p.kv_block {
                eng = eng.with_kv_block(b);
            }
            if let Some(u) = p.kv_units {
                eng = eng.with_kv_subarrays(u);
            }
            // Swap-to-host traffic (evict swap) is priced on this link;
            // inert otherwise.
            eng = eng.with_fabric(p.fabric.params());
            let trace = capture_trace.then(TraceHandle::new);
            if let Some(t) = &trace {
                eng.set_trace(t.clone());
            }
            if let Some(d) = deadline {
                eng.set_deadline(d);
            }
            for r in requests {
                eng.submit(r);
            }
            let backend_name = eng.backend_name();
            let done = eng.run();
            let mut m = ServeMetrics::from_completions(&done);
            let rep = eng.report();
            m.absorb_reports(std::slice::from_ref(&rep));
            aux.truncated |= rep.truncated;
            aux.profile = Some(rep.profile);
            if let Some(t) = &trace {
                aux.events = t.take_events();
            }
            let mut out = Outcome::new(
                &format!(
                    "serve — engine=batch backend={} policy={} batch={} chunk={} kv={} arrivals={}",
                    backend_name,
                    p.policy.name(),
                    p.max_batch,
                    match p.prefill_chunk {
                        Some(c) => c.to_string(),
                        None => "inline".to_string(),
                    },
                    p.kv_policy.name(),
                    spec.arrival_name()
                ),
                provenance,
            );
            serve_metrics(&mut out, &m);
            class_metrics(&mut out, &done, p, &m);
            out.metric("kv_policy", p.kv_policy.name(), None);
            out.metric("kv_peak_utilization", rep.kv_peak_utilization, Some("frac"));
            out.metric("max_batch_seen", rep.max_batch_seen, None);
            out.metric("decode_steps", rep.decode_steps, None);
            out.metric("mean_decode_batch", rep.mean_decode_batch, None);
            out.metric("preemptions", rep.preemptions, None);
            out.metric("recompute_tokens", rep.recompute_tokens, None);
            out.metric("reuse_hits", rep.reuse_hits, None);
            out.metric("reuse_tokens", rep.reuse_tokens, None);
            out.metric("rejected", rep.rejected, None);
            Ok(out)
        }
        EngineKind::Cluster => {
            if p.offload {
                return Err(ScenarioError::Unsupported(
                    "offload applies to engine seq only".to_string(),
                ));
            }
            let mut cluster =
                Cluster::homogeneous(cfg, backend, p.devices, p.max_batch, p.route)
                    .with_policy(p.policy)
                    .with_core(p.engine_core)
                    .with_prefill_chunk(p.prefill_chunk)
                    .with_kv(p.kv_policy, p.evict, p.prefix_cache, p.kv_block, p.kv_units);
            // One host link shared by every device's swap traffic.
            cluster.set_fabric(Fabric::shared(p.fabric.params()));
            let trace = capture_trace.then(TraceHandle::new);
            if let Some(t) = &trace {
                cluster.set_trace(t.clone());
            }
            if let Some(d) = deadline {
                cluster.set_deadline(d);
            }
            for r in requests {
                cluster.submit(r);
            }
            let done = cluster.run();
            let reps = cluster.per_device_reports();
            aux.truncated |= cluster.truncated();
            aux.profile = Some(cluster.profile());
            if let Some(t) = &trace {
                aux.events = t.take_events();
            }
            let mut m = ServeMetrics::from_completions(&done);
            m.absorb_reports(&reps);
            let mut out = Outcome::new(
                &format!(
                    "serve — engine=cluster backend={} devices={} batch={} route={} kv={} \
                     arrivals={}",
                    backend.name(),
                    p.devices,
                    p.max_batch,
                    p.route.name(),
                    p.kv_policy.name(),
                    spec.arrival_name()
                ),
                provenance,
            );
            serve_metrics(&mut out, &m);
            class_metrics(&mut out, &done, p, &m);
            out.metric("kv_policy", p.kv_policy.name(), None);
            out.metric("mean_decode_batch", m.mean_decode_batch, None);
            out.metric("preemptions", m.preemptions, None);
            out.metric("recompute_tokens", m.recompute_tokens, None);
            out.metric("reuse_hits", m.reuse_hits, None);
            out.metric("reuse_tokens", m.reuse_tokens, None);
            out.metric("rejected", cluster.rejected(), None);
            out.columns(&[
                ("device", None),
                ("backend", None),
                ("requests", None),
                ("throughput", Some("tok/s")),
                ("p95_latency", Some("s")),
                ("kv_peak_utilization", Some("frac")),
                ("mean_decode_batch", None),
                ("preemptions", None),
                ("reuse_hits", None),
            ]);
            let per = cluster.per_device_metrics(&done);
            let names = cluster.backend_names();
            for (i, (pm, rep)) in per.iter().zip(&reps).enumerate() {
                out.row(vec![
                    i.into(),
                    names[i].clone().into(),
                    pm.requests.into(),
                    pm.throughput_tok_s.into(),
                    pm.p95_latency_s.into(),
                    rep.kv_peak_utilization.into(),
                    rep.mean_decode_batch.into(),
                    rep.preemptions.into(),
                    rep.reuse_hits.into(),
                ]);
            }
            Ok(out)
        }
        EngineKind::Disagg => {
            if p.offload {
                return Err(ScenarioError::Unsupported(
                    "offload applies to engine seq only".to_string(),
                ));
            }
            let (prefill_n, decode_n) = p.pool_sizes();
            let mut cluster = DisaggregatedCluster::new(
                cfg,
                prefill_n,
                decode_n,
                p.max_batch,
                p.fabric.params(),
            )
            .with_policy(p.policy)
            .with_core(p.engine_core)
            .with_prefill_chunk(p.prefill_chunk)
            .with_kv(p.kv_policy, p.evict, p.prefix_cache, p.kv_block, p.kv_units);
            let trace = capture_trace.then(TraceHandle::new);
            if let Some(t) = &trace {
                cluster.set_trace(t.clone());
            }
            if let Some(d) = deadline {
                cluster.set_deadline(d);
            }
            for r in requests {
                cluster.submit(r);
            }
            let done = cluster.run();
            let reps = cluster.per_device_reports();
            aux.truncated |= cluster.truncated();
            aux.profile = Some(cluster.profile());
            if let Some(t) = &trace {
                aux.events = t.take_events();
            }
            let mut m = ServeMetrics::from_completions(&done);
            m.absorb_reports(&reps);
            let (migrated_bytes, fabric_transfers) = cluster.fabric_stats();
            let mut out = Outcome::new(
                &format!(
                    "serve — engine=disagg pools={prefill_n}+{decode_n} batch={} fabric={} \
                     kv={} evict={} arrivals={}",
                    p.max_batch,
                    p.fabric.name(),
                    p.kv_policy.name(),
                    p.evict.name(),
                    spec.arrival_name()
                ),
                provenance,
            );
            serve_metrics(&mut out, &m);
            class_metrics(&mut out, &done, p, &m);
            out.metric("kv_policy", p.kv_policy.name(), None);
            out.metric("migrated_bytes", migrated_bytes, Some("B"));
            out.metric("fabric_transfers", fabric_transfers, None);
            out.metric("mean_decode_batch", m.mean_decode_batch, None);
            out.metric("preemptions", m.preemptions, None);
            out.metric("recompute_tokens", m.recompute_tokens, None);
            out.metric("rejected", cluster.rejected(), None);
            out.columns(&[
                ("device", None),
                ("pool", None),
                ("backend", None),
                ("kv_peak_utilization", Some("frac")),
                ("mean_decode_batch", None),
                ("preemptions", None),
                ("swap_outs", None),
                ("swap_ins", None),
            ]);
            let names = cluster.backend_names();
            for (i, rep) in reps.iter().enumerate() {
                out.row(vec![
                    i.into(),
                    if i < prefill_n { "prefill" } else { "decode" }.into(),
                    names[i].clone().into(),
                    rep.kv_peak_utilization.into(),
                    rep.mean_decode_batch.into(),
                    rep.preemptions.into(),
                    rep.swap_outs.into(),
                    rep.swap_ins.into(),
                ]);
            }
            Ok(out)
        }
    }
}

/// `--schedule phase`: the dynamic phase-aware router over a split
/// GPU-class + PIM-class pool, scored against the offline-optimal
/// [`oracle`] baseline. The pool split reuses the disagg sizing knobs
/// (`--prefill-pool` names the GPU-class side, `--decode-pool` the
/// PIM-class side; unset sides derive from `--devices`).
fn run_serve_phase(
    cfg: &SimConfig,
    provenance: Provenance,
    p: &ServeParams,
    sched: &SchedSpec,
) -> Result<Outcome, ScenarioError> {
    if p.engine != EngineKind::Cluster {
        return Err(ScenarioError::Unsupported(format!(
            "--schedule phase routes phases across a split gpu+pim pool; pick engine \
             cluster (engine {} drives a single homogeneous pool)",
            p.engine.name()
        )));
    }
    if p.offload {
        return Err(ScenarioError::Unsupported(
            "offload applies to engine seq only".to_string(),
        ));
    }
    if p.kv_policy != KvPolicy::Whole || p.kv_block.is_some() || p.kv_units.is_some() {
        return Err(ScenarioError::Unsupported(
            "the phase router models whole-window KV residency; drop kv_policy paged \
             (or run a static schedule for paged KV)"
                .to_string(),
        ));
    }
    let (gpu_n, pim_n) = p.pool_sizes();
    if gpu_n + pim_n > p.devices {
        return Err(ScenarioError::Unsupported(format!(
            "--schedule phase needs a heterogeneous pool split within --devices: \
             gpu {gpu_n} + pim {pim_n} exceeds {} (raise --devices or shrink \
             --prefill-pool/--decode-pool)",
            p.devices
        )));
    }
    let mut topo = PhaseTopology::new(gpu_n, pim_n, p.max_batch);
    topo.fabric = p.fabric.params();
    topo.policy = p.policy;
    topo.prefill_chunk = p.prefill_chunk;
    let spec = workload_spec(p)?;
    let requests = spec.generate(p.seed, p.requests);
    let mut sim = PhaseSim::new(cfg, sched.clone(), topo);
    let outcome = sim.run(&requests);
    let m = ServeMetrics::from_completions(&outcome.completions);
    let rep = oracle(cfg, sched, &topo, &requests, &[outcome.objective]);
    let mut out = Outcome::new(
        &format!(
            "serve — schedule={} pools=gpu:{gpu_n}+pim:{pim_n} batch={} fabric={} arrivals={}",
            sched.render(),
            p.max_batch,
            p.fabric.name(),
            spec.arrival_name()
        ),
        provenance,
    );
    serve_metrics(&mut out, &m);
    class_metrics(&mut out, &outcome.completions, p, &m);
    out.metric("router_migrations", outcome.router_migrations, None);
    out.metric("migrated_bytes", outcome.migrated_bytes, Some("B"));
    out.metric("energy_j", outcome.energy_j, Some("J"));
    out.metric("avg_power_w", outcome.avg_power_w, Some("W"));
    out.metric(
        "pct_of_oracle",
        pct_of_oracle(outcome.objective, rep.objective),
        Some("%"),
    );
    out.metric(
        "best_static_pct_of_oracle",
        pct_of_oracle(rep.best_static_objective, rep.objective),
        Some("%"),
    );
    out.metric("oracle_candidates", rep.candidates, None);
    if !rep.exhaustive {
        out.note(
            "oracle searched the four uniform placements only (trace too long for the \
             exhaustive 4^n per-request search)",
        );
    }
    Ok(out)
}

fn run_serve_sweep(
    cfg: &SimConfig,
    provenance: Provenance,
    p: &ServeParams,
    backend: BackendKind,
    deadline: Option<Instant>,
    aux: &mut RunAux,
) -> Result<Outcome, ScenarioError> {
    if p.loads.is_empty() {
        return Err(ScenarioError::Unsupported(
            "sweep mode needs at least one offered load".to_string(),
        ));
    }
    let sc = SweepConfig {
        devices: p.devices,
        max_batch: p.max_batch,
        routing: p.route,
        policy: p.policy,
        requests: p.requests,
        seed: p.seed,
        n_sessions: p.n_sessions,
        backend,
        prefill_chunk: p.prefill_chunk,
        kv_policy: p.kv_policy,
        evict: p.evict,
        kv_block: p.kv_block,
        kv_units: p.kv_units,
        core: p.engine_core,
    };
    let mut out = Outcome::new(
        &format!(
            "latency vs offered load — {} devices x batch {}, {}, backend {}, {} requests",
            sc.devices,
            sc.max_batch,
            sc.routing.name(),
            sc.backend.name(),
            sc.requests
        ),
        provenance,
    );
    out.columns(&[
        ("offered", Some("req/s")),
        ("throughput", Some("tok/s")),
        ("p50_latency", Some("s")),
        ("p95_latency", Some("s")),
        ("p95_ttft", Some("s")),
        ("rejected", None),
    ]);
    // One load point at a time so a wall-clock budget can stop the
    // sweep cleanly between points (each point is a full serve run).
    for &load in &p.loads {
        if past(deadline) {
            aux.truncated = true;
            break;
        }
        for pt in &latency_vs_load(cfg, &sc, &[load]) {
            out.row(vec![
                pt.offered_rps.into(),
                pt.metrics.throughput_tok_s.into(),
                pt.metrics.p50_latency_s.into(),
                pt.metrics.p95_latency_s.into(),
                pt.metrics.p95_ttft_s.into(),
                pt.rejected.into(),
            ]);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{
        AreaParams, BreakdownParams, ConfigSel, PowerParams, SimulateParams, SweepParams,
    };

    fn mini() -> ConfigSel {
        ConfigSel::preset("mini")
    }

    #[test]
    fn simulate_outcome_matches_the_direct_simulation() {
        let scenario = Scenario::Simulate(
            SimulateParams::default().with_io(8, 4).with_config(mini()),
        );
        let out = Runner::new().run(&scenario).unwrap();
        let cfg = mini().resolve().unwrap();
        let expect = GenerationSim::new(&cfg).generate(8, 4).seconds(cfg.timing.tck_ns);
        assert!((out.metric_f64("total").unwrap() - expect).abs() < 1e-12);
        assert!(out.metric_f64("speedup_vs_gpu").unwrap() > 0.0);
        assert_eq!(out.provenance.scenario, "simulate");
        assert_eq!(out.provenance.preset, "mini");
        assert_eq!(out.provenance.backend, None);
    }

    #[test]
    fn sweep_outcome_has_the_full_grid() {
        let scenario = Scenario::Sweep(
            SweepParams::default()
                .with_grid(vec![8, 16], vec![1, 4, 8])
                .with_config(mini()),
        );
        let out = Runner::new().run(&scenario).unwrap();
        assert_eq!(out.rows.len(), 6);
        let speedups = out.column_f64("speedup");
        let max = out.metric_f64("max_speedup").unwrap();
        assert!((speedups.iter().cloned().fold(0.0f64, f64::max) - max).abs() < 1e-12);
    }

    #[test]
    fn breakdown_fractions_sum_to_one() {
        let scenario =
            Scenario::Breakdown(BreakdownParams::default().with_kv(32).with_config(mini()));
        let out = Runner::new().run(&scenario).unwrap();
        let total: f64 = out.column_f64("fraction").iter().sum();
        assert!((total - 1.0).abs() < 1e-6, "fractions sum to {total}");
        assert!(out.metric_f64("iteration").unwrap() > 0.0);
    }

    #[test]
    fn power_rows_follow_p_sub_order_and_validate() {
        let scenario = Scenario::Power(
            PowerParams::default()
                .with_io(8, 4)
                .with_p_subs(vec![1, 4])
                .with_config(mini()),
        );
        let out = Runner::new().run(&scenario).unwrap();
        let fracs = out.column_f64("budget_fraction");
        assert_eq!(fracs.len(), 2);
        assert!(fracs[0] < fracs[1], "power grows with P_Sub: {fracs:?}");
        let bad = Scenario::Power(PowerParams::default().with_p_subs(vec![9]));
        assert!(matches!(
            Runner::new().run(&bad),
            Err(ScenarioError::BadPSub { .. })
        ));
    }

    #[test]
    fn area_outcome_reports_overhead() {
        let out = Runner::new()
            .run(&Scenario::Area(AreaParams::default()))
            .unwrap();
        let overhead = out.metric_f64("overhead_vs_channel").unwrap();
        assert!(overhead > 0.0 && overhead < 0.25);
        assert_eq!(out.rows.len(), 3);
    }

    #[test]
    fn serve_engines_agree_on_simulated_tokens() {
        let base = ServeParams::default()
            .with_config(mini())
            .with_workload(6, 11)
            .with_at_once(true);
        let seq = Runner::new()
            .run(&Scenario::Serve(base.clone()))
            .unwrap();
        let batch = Runner::new()
            .run(&Scenario::Serve(
                base.clone().with_engine(EngineKind::Batch),
            ))
            .unwrap();
        assert_eq!(
            seq.metric_f64("total_tokens"),
            batch.metric_f64("total_tokens"),
            "token conservation across engines"
        );
        assert!(batch.metric_f64("kv_peak_utilization").is_some());
        assert_eq!(batch.provenance.seed, Some(11));
    }

    #[test]
    fn serve_cluster_outcome_has_per_device_rows() {
        let scenario = Scenario::Serve(
            ServeParams::default()
                .with_config(mini())
                .with_engine(EngineKind::Cluster)
                .with_cluster(2, 4)
                .with_workload(8, 3)
                .with_at_once(true),
        );
        let out = Runner::new().run(&scenario).unwrap();
        assert_eq!(out.rows.len(), 2);
        let per_device: f64 = out.column_f64("requests").iter().sum();
        assert_eq!(per_device as usize, 8);
    }

    #[test]
    fn serve_sweep_outcome_has_one_row_per_load() {
        let scenario = Scenario::Serve(
            ServeParams::default()
                .with_config(mini())
                .with_cluster(1, 4)
                .with_workload(6, 5)
                .with_sweep(vec![50.0, 5000.0]),
        );
        let out = Runner::new().run(&scenario).unwrap();
        assert_eq!(out.rows.len(), 2);
        let p95 = out.column_f64("p95_latency");
        assert!(p95[1] >= p95[0], "load must not improve tails: {p95:?}");
    }

    #[test]
    fn paged_kv_is_sweepable_through_the_scenario_api() {
        use crate::serve::KvPolicy;
        let base = ServeParams::default()
            .with_config(mini())
            .with_engine(EngineKind::Batch)
            .with_workload(8, 11)
            .with_at_once(true);
        let whole = Runner::new().run(&Scenario::Serve(base.clone())).unwrap();
        let paged = Runner::new()
            .run(&Scenario::Serve(base.with_kv_policy(KvPolicy::Paged)))
            .unwrap();
        assert_eq!(
            whole.metric_f64("total_tokens"),
            paged.metric_f64("total_tokens"),
            "token conservation across KV policies"
        );
        assert!(paged.metric_f64("mean_decode_batch").is_some());
        assert!(paged.metric_f64("preemptions").is_some());
        assert!(
            paged.metric_f64("mean_decode_batch").unwrap()
                >= whole.metric_f64("mean_decode_batch").unwrap(),
            "paged must not shrink the decode batch at equal capacity"
        );
    }

    #[test]
    fn engine_cores_agree_through_the_scenario_api() {
        use crate::serve::{EngineCore, KvPolicy};
        let base = ServeParams::default()
            .with_config(mini())
            .with_engine(EngineKind::Batch)
            .with_kv_policy(KvPolicy::Paged)
            .with_workload(8, 11)
            .with_at_once(true);
        let event = Runner::new().run(&Scenario::Serve(base.clone())).unwrap();
        let legacy = Runner::new()
            .run(&Scenario::Serve(base.with_engine_core(EngineCore::Legacy)))
            .unwrap();
        assert_eq!(event.metrics, legacy.metrics, "cores must be bit-identical");
    }

    #[test]
    fn run_with_captures_trace_and_profile_for_batch_serve() {
        let scenario = Scenario::Serve(
            ServeParams::default()
                .with_config(mini())
                .with_engine(EngineKind::Batch)
                .with_workload(6, 7)
                .with_at_once(true),
        );
        assert!(Runner::traceable(&scenario));
        let (out, aux) = Runner::new().run_with(&scenario, true).unwrap();
        assert!(!aux.events.is_empty(), "trace requested but no events");
        let prof = aux.profile.expect("batch serve publishes a profile");
        assert!(prof.sim_tokens > 0);
        assert!(!aux.truncated);
        assert!(!out.provenance.truncated);
        // Tracing must not perturb the simulated numbers.
        let (quiet, quiet_aux) = Runner::new().run_with(&scenario, false).unwrap();
        assert!(quiet_aux.events.is_empty());
        assert_eq!(out.metrics, quiet.metrics);
    }

    #[test]
    fn only_batching_serve_scenarios_are_traceable() {
        assert!(!Runner::traceable(&Scenario::Serve(ServeParams::default())));
        assert!(!Runner::traceable(&Scenario::Serve(
            ServeParams::default()
                .with_cluster(1, 4)
                .with_sweep(vec![10.0]),
        )));
        assert!(Runner::traceable(&Scenario::Serve(
            ServeParams::default().with_engine(EngineKind::Cluster),
        )));
        assert!(!Runner::traceable(&Scenario::Simulate(
            SimulateParams::default(),
        )));
    }

    #[test]
    fn zero_budget_truncates_cleanly() {
        let scenario = Scenario::Serve(
            ServeParams::default()
                .with_config(mini().with_budget_s(0.0))
                .with_engine(EngineKind::Batch)
                .with_workload(6, 7)
                .with_at_once(true),
        );
        let (out, aux) = Runner::new().run_with(&scenario, false).unwrap();
        assert!(aux.truncated);
        assert!(out.provenance.truncated);
        // Iterating kinds stop between units: an exhausted budget means
        // an empty grid, not a hang.
        let sweep = Scenario::Sweep(
            SweepParams::default()
                .with_grid(vec![8], vec![4])
                .with_config(mini().with_budget_s(0.0)),
        );
        let (out, aux) = Runner::new().run_with(&sweep, false).unwrap();
        assert!(aux.truncated && out.provenance.truncated);
        assert_eq!(out.rows.len(), 0);
    }

    #[test]
    fn simperf_outcome_merges_profiles() {
        let a = PhaseProfile {
            wall_s: 1.0,
            sim_tokens: 100,
            decode_s: 0.5,
            ..PhaseProfile::default()
        };
        let b = PhaseProfile {
            wall_s: 1.0,
            sim_tokens: 50,
            ..PhaseProfile::default()
        };
        let out = Runner::simperf_outcome(&[a, b]);
        assert_eq!(out.provenance.scenario, "simperf");
        assert_eq!(out.metric_f64("sim_tokens"), Some(150.0));
        assert_eq!(out.metric_f64("sim_wall_s"), Some(2.0));
        assert_eq!(out.metric_f64("sim_tokens_per_wall_s"), Some(75.0));
        assert_eq!(out.metric_f64("phase_decode_s"), Some(0.5));
    }

    #[test]
    fn serve_disagg_outcome_reports_migration_traffic() {
        let scenario = Scenario::Serve(
            ServeParams::default()
                .with_config(mini())
                .with_engine(EngineKind::Disagg)
                .with_cluster(4, 4)
                .with_workload(8, 3)
                .with_at_once(true),
        );
        let out = Runner::new().run(&scenario).unwrap();
        // Every request crosses the PCIe-class fabric exactly once.
        assert!(out.metric_f64("migrated_bytes").unwrap() > 0.0);
        assert_eq!(out.metric_f64("fabric_transfers"), Some(8.0));
        assert_eq!(out.metric_f64("requests"), Some(8.0));
        assert_eq!(out.rows.len(), 4, "one row per device across both pools");
        // Disagg conserves the workload's token budget vs a single pool.
        let single = Runner::new()
            .run(&Scenario::Serve(
                ServeParams::default()
                    .with_config(mini())
                    .with_engine(EngineKind::Batch)
                    .with_backend(BackendKind::Hetero)
                    .with_workload(8, 3)
                    .with_at_once(true),
            ))
            .unwrap();
        assert_eq!(
            out.metric_f64("total_tokens"),
            single.metric_f64("total_tokens"),
            "token conservation across serving topologies"
        );
    }

    #[test]
    fn unsupported_combinations_are_rejected() {
        let gpu_seq = ServeParams::default().with_backend(BackendKind::Gpu);
        assert!(matches!(
            Runner::new().run(&Scenario::Serve(gpu_seq)),
            Err(ScenarioError::Unsupported(_))
        ));
        let chunk_seq = ServeParams::default().with_prefill_chunk(Some(32));
        assert!(Runner::new().run(&Scenario::Serve(chunk_seq)).is_err());
        let burst_only = ServeParams::default().with_rate(None, Some(4));
        assert!(Runner::new().run(&Scenario::Serve(burst_only)).is_err());
        let zero_rate = ServeParams::default().with_rate(Some(0.0), None);
        assert!(Runner::new().run(&Scenario::Serve(zero_rate)).is_err());
        let offload_batch = ServeParams::default()
            .with_engine(EngineKind::Batch)
            .with_offload(true);
        assert!(Runner::new().run(&Scenario::Serve(offload_batch)).is_err());
        let paged_seq =
            ServeParams::default().with_kv_policy(crate::serve::KvPolicy::Paged);
        assert!(Runner::new().run(&Scenario::Serve(paged_seq)).is_err());
        let offload_disagg = ServeParams::default()
            .with_engine(EngineKind::Disagg)
            .with_offload(true);
        assert!(Runner::new().run(&Scenario::Serve(offload_disagg)).is_err());
        let sweep_disagg = ServeParams::default()
            .with_engine(EngineKind::Disagg)
            .with_sweep(vec![10.0]);
        assert!(Runner::new().run(&Scenario::Serve(sweep_disagg)).is_err());
        let radix_whole = ServeParams::default()
            .with_engine(EngineKind::Batch)
            .with_prefix_cache(PrefixCacheMode::Radix);
        assert!(Runner::new().run(&Scenario::Serve(radix_whole)).is_err());
        let sweep_spec = ServeParams::default()
            .with_cluster(1, 4)
            .with_sweep(vec![10.0])
            .with_workload_spec(WorkloadSpec::parse("at-once").unwrap());
        assert!(Runner::new().run(&Scenario::Serve(sweep_spec)).is_err());
    }

    #[test]
    fn legacy_flags_and_their_spec_desugaring_are_bit_identical() {
        // The deprecated `--rate/--burst` cluster and the equivalent
        // `--workload` string must produce byte-identical outcomes.
        let legacy = ServeParams::default()
            .with_config(mini())
            .with_engine(EngineKind::Batch)
            .with_workload(8, 11)
            .with_rate(Some(200.0), Some(4));
        let typed = legacy
            .clone()
            .with_workload_spec(WorkloadSpec::parse("bursty:200:4,sessions=8").unwrap());
        let a = Runner::new().run(&Scenario::Serve(legacy)).unwrap();
        let b = Runner::new().run(&Scenario::Serve(typed)).unwrap();
        assert_eq!(a.metrics, b.metrics, "desugaring must not change a byte");
    }

    #[test]
    fn static_schedule_specs_are_bit_identical_to_legacy_backend_flags() {
        // `--schedule static:<b>` must desugar onto exactly the code
        // path `--backend <b>` takes — same engine, same numbers, same
        // provenance backend — for every engine that takes a backend.
        for engine in [EngineKind::Batch, EngineKind::Cluster] {
            let legacy = ServeParams::default()
                .with_config(mini())
                .with_engine(engine)
                .with_backend(BackendKind::Gpu)
                .with_workload(6, 11)
                .with_at_once(true);
            // The spec run leaves the legacy `backend` field at its
            // default, so only the schedule can be steering it.
            let spec = ServeParams::default()
                .with_config(mini())
                .with_engine(engine)
                .with_workload(6, 11)
                .with_at_once(true)
                .with_schedule(SchedSpec::parse("static:gpu").unwrap());
            let a = Runner::new().run(&Scenario::Serve(legacy)).unwrap();
            let b = Runner::new().run(&Scenario::Serve(spec)).unwrap();
            assert_eq!(a.metrics, b.metrics, "desugaring must not change a byte");
            assert_eq!(a.provenance.backend.as_deref(), Some("gpu"));
            assert_eq!(b.provenance.backend.as_deref(), Some("gpu"));
        }
    }

    #[test]
    fn phase_schedule_reports_oracle_and_router_metrics() {
        let scenario = Scenario::Serve(
            ServeParams::default()
                .with_config(mini())
                .with_engine(EngineKind::Cluster)
                .with_cluster(2, 4)
                .with_workload(4, 11)
                .with_at_once(true)
                .with_schedule(SchedSpec::parse("phase,hysteresis=1").unwrap()),
        );
        let out = Runner::new().run(&scenario).unwrap();
        assert_eq!(out.provenance.backend.as_deref(), Some("phase"));
        assert_eq!(out.metric_f64("requests"), Some(4.0));
        let pct = out.metric_f64("pct_of_oracle").unwrap();
        assert!(pct > 0.0 && pct <= 100.0 + 1e-9, "pct_of_oracle {pct}");
        let static_pct = out.metric_f64("best_static_pct_of_oracle").unwrap();
        assert!(static_pct > 0.0 && static_pct <= 100.0 + 1e-9);
        // 4 requests brute-force: 4 uniforms + 4^4 placements + this run.
        assert_eq!(out.metric_f64("oracle_candidates"), Some(261.0));
        assert!(out.metric_f64("energy_j").unwrap() > 0.0);
        assert!(out.metric_f64("avg_power_w").unwrap() > 0.0);
        assert!(out.metric_f64("router_migrations").is_some());
        // Token budget must match a static run of the same workload.
        let static_run = Runner::new()
            .run(&Scenario::Serve(
                ServeParams::default()
                    .with_config(mini())
                    .with_engine(EngineKind::Cluster)
                    .with_cluster(2, 4)
                    .with_workload(4, 11)
                    .with_at_once(true),
            ))
            .unwrap();
        assert_eq!(
            out.metric_f64("total_tokens"),
            static_run.metric_f64("total_tokens"),
            "token conservation across schedules"
        );
    }

    #[test]
    fn phase_schedule_rejections_are_actionable() {
        let phase = SchedSpec::parse("phase").unwrap();
        let batch = ServeParams::default()
            .with_config(mini())
            .with_engine(EngineKind::Batch)
            .with_schedule(phase.clone());
        match Runner::new().run(&Scenario::Serve(batch)) {
            Err(ScenarioError::Unsupported(msg)) => {
                assert!(msg.contains("engine cluster"), "{msg}")
            }
            other => panic!("expected Unsupported, got {other:?}"),
        }
        let sweep = ServeParams::default()
            .with_config(mini())
            .with_engine(EngineKind::Cluster)
            .with_sweep(vec![10.0])
            .with_schedule(phase.clone());
        assert!(Runner::new().run(&Scenario::Serve(sweep)).is_err());
        let paged = ServeParams::default()
            .with_config(mini())
            .with_engine(EngineKind::Cluster)
            .with_kv_policy(crate::serve::KvPolicy::Paged)
            .with_schedule(phase.clone());
        match Runner::new().run(&Scenario::Serve(paged)) {
            Err(ScenarioError::Unsupported(msg)) => {
                assert!(msg.contains("whole-window"), "{msg}")
            }
            other => panic!("expected Unsupported, got {other:?}"),
        }
        // One device can't host a two-sided pool split.
        let tiny = ServeParams::default()
            .with_config(mini())
            .with_engine(EngineKind::Cluster)
            .with_cluster(1, 4)
            .with_schedule(phase);
        match Runner::new().run(&Scenario::Serve(tiny)) {
            Err(ScenarioError::Unsupported(msg)) => {
                assert!(msg.contains("--devices"), "{msg}")
            }
            other => panic!("expected Unsupported, got {other:?}"),
        }
    }

    #[test]
    fn radix_prefix_cache_reports_hit_rate_through_the_scenario_api() {
        use crate::serve::KvPolicy;
        let spec =
            WorkloadSpec::parse("poisson:20,multiturn=2:0.1,prefix=64:1:32,interactive=1")
                .unwrap();
        let base = ServeParams::default()
            .with_config(mini())
            .with_engine(EngineKind::Batch)
            .with_kv_policy(KvPolicy::Paged)
            .with_workload(4, 11)
            .with_workload_spec(spec);
        let session = Runner::new().run(&Scenario::Serve(base.clone())).unwrap();
        let radix = Runner::new()
            .run(&Scenario::Serve(
                base.with_prefix_cache(PrefixCacheMode::Radix),
            ))
            .unwrap();
        // Session mode keeps the legacy metric set; radix adds the
        // prefix-cache stats and actually shares the common chain.
        assert_eq!(session.metric_f64("prefix_hits"), None);
        assert!(radix.metric_f64("prefix_hits").unwrap() > 0.0);
        assert!(radix.metric_f64("prefix_cache_hit_rate").unwrap() > 0.0);
        // SLO classes surface per-class percentiles (all-interactive
        // traffic here).
        assert!(radix.metric_f64("interactive_p95_ttft").is_some());
        assert_eq!(
            radix.metric_f64("interactive_requests"),
            radix.metric_f64("requests"),
        );
        // Sharing must not change the simulated token budget.
        assert_eq!(
            session.metric_f64("total_tokens"),
            radix.metric_f64("total_tokens"),
            "token conservation across prefix-cache modes"
        );
    }

    #[test]
    fn custom_scenarios_report_numeric_params_as_metrics() {
        let c = CustomParams::default()
            .with_config(mini())
            .with_label("ablation notes")
            .with_param("alpha", "1.5")
            .with_param("corpus", "wikitext");
        let out = Runner::new().run(&Scenario::Custom(c)).unwrap();
        assert_eq!(out.provenance.scenario, "custom");
        assert_eq!(out.metric_f64("params"), Some(2.0));
        assert_eq!(out.metric_f64("alpha"), Some(1.5));
        assert_eq!(out.metric_f64("corpus"), None, "non-numeric stays provenance-only");
        assert!(!Runner::traceable(&Scenario::Custom(CustomParams::default())));
    }
}
