//! Scenario suite files: a flat TOML subset, round-trippable.
//!
//! The offline build has no serde/toml crates, so suites use the same
//! hand-rolled philosophy as [`crate::config::parse`]: line-based
//! `key = value` with `[[scenario]]` section headers, quoted strings,
//! integer/float/bool literals and one-line `[a, b, c]` lists. Unknown
//! keys are hard errors so a typo'd suite fails loudly.
//!
//! ```text
//! # smoke suite
//! [[scenario]]
//! kind = "simulate"
//! preset = "paper"
//! n_in = 32
//! n_out = 64
//!
//! [[scenario]]
//! kind = "serve"
//! engine = "batch"
//! cfg.model = "gpt2-medium"   # config override vocabulary
//! ```
//!
//! [`Scenario::to_toml`] serializes every field, and
//! [`parse_suite`] parses it back to an equal value — the round-trip the
//! `scenario_roundtrip` test suite exercises property-style.

use super::{
    parse_policy, parse_route, route_token, AreaParams, BreakdownParams, ConfigSel, CustomParams,
    EngineKind, PowerParams, Scenario, ScenarioError, ServeParams, SimulateParams, SweepParams,
};
use crate::serve::{
    BackendKind, EngineCore, EvictPolicy, FabricKind, KvPolicy, PrefixCacheMode, SchedSpec,
    WorkloadSpec,
};
use std::fmt::Write as _;

/// Strip an inline `#` comment, respecting double quotes.
fn strip_comment(line: &str) -> &str {
    let mut in_quote = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_quote = !in_quote,
            '#' if !in_quote => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Remove surrounding double quotes, if any.
fn unquote(s: &str) -> &str {
    s.strip_prefix('"')
        .and_then(|t| t.strip_suffix('"'))
        .unwrap_or(s)
}

fn bad(line: usize, key: &str, value: &str, want: &str) -> ScenarioError {
    ScenarioError::Parse {
        line,
        msg: format!("bad value `{value}` for `{key}` (expected {want})"),
    }
}

fn p_usize(line: usize, key: &str, v: &str) -> Result<usize, ScenarioError> {
    v.parse().map_err(|_| bad(line, key, v, "an integer"))
}

fn p_u64(line: usize, key: &str, v: &str) -> Result<u64, ScenarioError> {
    v.parse().map_err(|_| bad(line, key, v, "an integer"))
}

fn p_f64(line: usize, key: &str, v: &str) -> Result<f64, ScenarioError> {
    v.parse().map_err(|_| bad(line, key, v, "a number"))
}

fn p_bool(line: usize, key: &str, v: &str) -> Result<bool, ScenarioError> {
    match v {
        "true" => Ok(true),
        "false" => Ok(false),
        _ => Err(bad(line, key, v, "true|false")),
    }
}

fn list_items(line: usize, key: &str, v: &str) -> Result<Vec<String>, ScenarioError> {
    let inner = v
        .strip_prefix('[')
        .and_then(|t| t.strip_suffix(']'))
        .ok_or_else(|| bad(line, key, v, "a [a, b, c] list"))?;
    let inner = inner.trim();
    if inner.is_empty() {
        return Ok(Vec::new());
    }
    Ok(inner.split(',').map(|s| s.trim().to_string()).collect())
}

fn p_list_usize(line: usize, key: &str, v: &str) -> Result<Vec<usize>, ScenarioError> {
    list_items(line, key, v)?
        .iter()
        .map(|s| p_usize(line, key, s))
        .collect()
}

fn p_list_f64(line: usize, key: &str, v: &str) -> Result<Vec<f64>, ScenarioError> {
    list_items(line, key, v)?
        .iter()
        .map(|s| p_f64(line, key, s))
        .collect()
}

fn fmt_list<T: std::fmt::Display>(items: &[T]) -> String {
    let body: Vec<String> = items.iter().map(|x| x.to_string()).collect();
    format!("[{}]", body.join(", "))
}

/// Handle keys shared by every scenario kind. Returns `true` if consumed.
fn common_key(
    config: &mut ConfigSel,
    line: usize,
    key: &str,
    value: &str,
) -> Result<bool, ScenarioError> {
    match key {
        "kind" => Ok(true),
        "preset" => {
            config.preset = unquote(value).to_string();
            Ok(true)
        }
        "p_sub" => {
            config.p_sub = Some(p_usize(line, key, value)?);
            Ok(true)
        }
        "budget_s" => {
            config.budget_s = Some(p_f64(line, key, value)?);
            Ok(true)
        }
        _ => {
            if let Some(cfg_key) = key.strip_prefix("cfg.") {
                config
                    .overrides
                    .push((cfg_key.to_string(), unquote(value).to_string()));
                Ok(true)
            } else {
                Ok(false)
            }
        }
    }
}

fn unknown_key(line: usize, kind: &str, key: &str) -> ScenarioError {
    ScenarioError::Parse {
        line,
        msg: format!("unknown key `{key}` for scenario kind `{kind}`"),
    }
}

/// Build one scenario from `(line, key, value)` pairs.
pub fn from_kv(pairs: &[(usize, String, String)]) -> Result<Scenario, ScenarioError> {
    let first_line = pairs.first().map(|p| p.0).unwrap_or(0);
    let (_, _, kind_raw) = pairs
        .iter()
        .find(|(_, k, _)| k == "kind")
        .ok_or_else(|| ScenarioError::Parse {
            line: first_line,
            msg: "scenario is missing `kind`".to_string(),
        })?;
    let kind = unquote(kind_raw).to_string();
    match kind.as_str() {
        "simulate" => {
            let mut p = SimulateParams::default();
            for (line, key, value) in pairs {
                if common_key(&mut p.config, *line, key, value)? {
                    continue;
                }
                match key.as_str() {
                    "n_in" => p.n_in = p_usize(*line, key, value)?,
                    "n_out" => p.n_out = p_usize(*line, key, value)?,
                    "prefetch" => p.prefetch = p_bool(*line, key, value)?,
                    _ => return Err(unknown_key(*line, &kind, key)),
                }
            }
            Ok(Scenario::Simulate(p))
        }
        "sweep" => {
            let mut p = SweepParams::default();
            for (line, key, value) in pairs {
                if common_key(&mut p.config, *line, key, value)? {
                    continue;
                }
                match key.as_str() {
                    "ins" => p.ins = p_list_usize(*line, key, value)?,
                    "outs" => p.outs = p_list_usize(*line, key, value)?,
                    _ => return Err(unknown_key(*line, &kind, key)),
                }
            }
            Ok(Scenario::Sweep(p))
        }
        "breakdown" => {
            let mut p = BreakdownParams::default();
            for (line, key, value) in pairs {
                if common_key(&mut p.config, *line, key, value)? {
                    continue;
                }
                match key.as_str() {
                    "kv" => p.kv = p_usize(*line, key, value)?,
                    _ => return Err(unknown_key(*line, &kind, key)),
                }
            }
            Ok(Scenario::Breakdown(p))
        }
        "power" => {
            let mut p = PowerParams::default();
            for (line, key, value) in pairs {
                if common_key(&mut p.config, *line, key, value)? {
                    continue;
                }
                match key.as_str() {
                    "n_in" => p.n_in = p_usize(*line, key, value)?,
                    "n_out" => p.n_out = p_usize(*line, key, value)?,
                    "p_subs" => p.p_subs = p_list_usize(*line, key, value)?,
                    _ => return Err(unknown_key(*line, &kind, key)),
                }
            }
            Ok(Scenario::Power(p))
        }
        "area" => {
            let mut p = AreaParams::default();
            for (line, key, value) in pairs {
                if common_key(&mut p.config, *line, key, value)? {
                    continue;
                }
                return Err(unknown_key(*line, &kind, key));
            }
            Ok(Scenario::Area(p))
        }
        "serve" => {
            let mut p = ServeParams::default();
            for (line, key, value) in pairs {
                if common_key(&mut p.config, *line, key, value)? {
                    continue;
                }
                let v = unquote(value);
                match key.as_str() {
                    "engine" => {
                        p.engine = EngineKind::parse(v)
                            .ok_or_else(|| bad(*line, key, v, "seq|batch|cluster|disagg"))?
                    }
                    "engine_core" => {
                        p.engine_core = EngineCore::parse(v)
                            .ok_or_else(|| bad(*line, key, v, "event|legacy"))?
                    }
                    "backend" => {
                        // BackendKind::parse's error already names the
                        // vocabulary and suggests a fix; carry it whole.
                        p.backend =
                            BackendKind::parse(v).map_err(|msg| ScenarioError::Parse {
                                line: *line,
                                msg,
                            })?
                    }
                    "policy" => {
                        p.policy = parse_policy(v)
                            .ok_or_else(|| bad(*line, key, v, "fcfs|sjf|spf|priority"))?
                    }
                    "route" => {
                        p.route =
                            parse_route(v).ok_or_else(|| bad(*line, key, v, "rr|ll|affinity"))?
                    }
                    "requests" => p.requests = p_usize(*line, key, value)?,
                    "seed" => p.seed = p_u64(*line, key, value)?,
                    "devices" => p.devices = p_usize(*line, key, value)?,
                    "max_batch" => p.max_batch = p_usize(*line, key, value)?,
                    "n_sessions" => p.n_sessions = p_usize(*line, key, value)?,
                    "prefill_chunk" => p.prefill_chunk = Some(p_usize(*line, key, value)?),
                    "kv_policy" => {
                        p.kv_policy = KvPolicy::parse(v)
                            .ok_or_else(|| bad(*line, key, v, "whole|paged"))?
                    }
                    "evict" => {
                        p.evict = EvictPolicy::parse(v)
                            .ok_or_else(|| bad(*line, key, v, "lru|swap|none"))?
                    }
                    "fabric" => {
                        p.fabric = FabricKind::parse(v)
                            .ok_or_else(|| bad(*line, key, v, "pcie|nvlink|ideal"))?
                    }
                    "prefill_pool" => p.prefill_pool = Some(p_usize(*line, key, value)?),
                    "decode_pool" => p.decode_pool = Some(p_usize(*line, key, value)?),
                    "kv_block" => p.kv_block = Some(p_usize(*line, key, value)?),
                    "kv_units" => p.kv_units = Some(p_usize(*line, key, value)?),
                    "at_once" => p.at_once = p_bool(*line, key, value)?,
                    "rate" => p.rate = Some(p_f64(*line, key, value)?),
                    "burst" => p.burst = Some(p_usize(*line, key, value)?),
                    "workload" => {
                        p.workload =
                            Some(WorkloadSpec::parse(v).map_err(|msg| ScenarioError::Parse {
                                line: *line,
                                msg,
                            })?)
                    }
                    "schedule" => {
                        p.schedule =
                            Some(SchedSpec::parse(v).map_err(|msg| ScenarioError::Parse {
                                line: *line,
                                msg,
                            })?)
                    }
                    "prefix_cache" => {
                        p.prefix_cache = PrefixCacheMode::parse(v)
                            .ok_or_else(|| bad(*line, key, v, "session|radix"))?
                    }
                    "offload" => p.offload = p_bool(*line, key, value)?,
                    "sweep" => p.sweep = p_bool(*line, key, value)?,
                    "loads" => p.loads = p_list_f64(*line, key, value)?,
                    _ => return Err(unknown_key(*line, &kind, key)),
                }
            }
            Ok(Scenario::Serve(p))
        }
        "custom" => {
            let mut p = CustomParams::default();
            for (line, key, value) in pairs {
                if common_key(&mut p.config, *line, key, value)? {
                    continue;
                }
                let v = unquote(value);
                if key == "label" {
                    p.label = v.to_string();
                } else if let Some(k) = key.strip_prefix("param.") {
                    p.params.push((k.to_string(), v.to_string()));
                } else {
                    return Err(unknown_key(*line, &kind, key));
                }
            }
            Ok(Scenario::Custom(p))
        }
        other => Err(ScenarioError::Parse {
            line: first_line,
            msg: format!(
                "unknown scenario kind `{other}` \
                 (simulate|sweep|breakdown|power|area|serve|custom)"
            ),
        }),
    }
}

impl Scenario {
    /// Flatten to the suite-file `key = value` vocabulary (every field,
    /// quoted-string values unquoted). Also used as outcome provenance.
    pub fn to_kv(&self) -> Vec<(String, String)> {
        let mut kv: Vec<(String, String)> = vec![("kind".to_string(), self.kind().to_string())];
        let mut push = |k: &str, v: String| kv.push((k.to_string(), v));
        let config = self.config();
        push("preset", config.preset.clone());
        if let Some(p_sub) = config.p_sub {
            push("p_sub", p_sub.to_string());
        }
        if let Some(b) = config.budget_s {
            push("budget_s", b.to_string());
        }
        for (k, v) in &config.overrides {
            push(&format!("cfg.{k}"), v.clone());
        }
        match self {
            Scenario::Simulate(p) => {
                push("n_in", p.n_in.to_string());
                push("n_out", p.n_out.to_string());
                push("prefetch", p.prefetch.to_string());
            }
            Scenario::Sweep(p) => {
                push("ins", fmt_list(&p.ins));
                push("outs", fmt_list(&p.outs));
            }
            Scenario::Breakdown(p) => push("kv", p.kv.to_string()),
            Scenario::Power(p) => {
                push("n_in", p.n_in.to_string());
                push("n_out", p.n_out.to_string());
                push("p_subs", fmt_list(&p.p_subs));
            }
            Scenario::Area(_) => {}
            Scenario::Serve(p) => {
                push("engine", p.engine.name().to_string());
                push("engine_core", p.engine_core.name().to_string());
                push("backend", p.backend.name().to_string());
                push("policy", p.policy.name().to_string());
                push("route", route_token(p.route).to_string());
                push("requests", p.requests.to_string());
                push("seed", p.seed.to_string());
                push("devices", p.devices.to_string());
                push("max_batch", p.max_batch.to_string());
                push("n_sessions", p.n_sessions.to_string());
                if let Some(c) = p.prefill_chunk {
                    push("prefill_chunk", c.to_string());
                }
                push("kv_policy", p.kv_policy.name().to_string());
                push("evict", p.evict.name().to_string());
                if p.fabric != FabricKind::Pcie {
                    push("fabric", p.fabric.name().to_string());
                }
                if let Some(n) = p.prefill_pool {
                    push("prefill_pool", n.to_string());
                }
                if let Some(n) = p.decode_pool {
                    push("decode_pool", n.to_string());
                }
                if let Some(b) = p.kv_block {
                    push("kv_block", b.to_string());
                }
                if let Some(u) = p.kv_units {
                    push("kv_units", u.to_string());
                }
                push("at_once", p.at_once.to_string());
                if let Some(r) = p.rate {
                    push("rate", r.to_string());
                }
                if let Some(b) = p.burst {
                    push("burst", b.to_string());
                }
                if let Some(w) = &p.workload {
                    push("workload", w.render());
                }
                if let Some(s) = &p.schedule {
                    push("schedule", s.render());
                }
                if p.prefix_cache != PrefixCacheMode::Session {
                    push("prefix_cache", p.prefix_cache.name().to_string());
                }
                push("offload", p.offload.to_string());
                push("sweep", p.sweep.to_string());
                push("loads", fmt_list(&p.loads));
            }
            Scenario::Custom(p) => {
                if !p.label.is_empty() {
                    push("label", p.label.clone());
                }
                for (k, v) in &p.params {
                    push(&format!("param.{k}"), v.clone());
                }
            }
        }
        kv
    }

    /// Serialize as one `[[scenario]]` block.
    pub fn to_toml(&self) -> String {
        // Keys whose values are strings and therefore TOML-quoted.
        fn is_string_key(key: &str) -> bool {
            matches!(
                key,
                "kind" | "preset" | "engine" | "engine_core" | "backend" | "policy" | "route"
                    | "kv_policy" | "evict" | "fabric" | "workload" | "schedule" | "prefix_cache"
                    | "label"
            ) || key.starts_with("cfg.")
                || key.starts_with("param.")
        }
        let mut out = String::from("[[scenario]]\n");
        for (k, v) in self.to_kv() {
            if is_string_key(&k) {
                let _ = writeln!(out, "{k} = \"{v}\"");
            } else {
                let _ = writeln!(out, "{k} = {v}");
            }
        }
        out
    }
}

/// Serialize a whole suite.
pub fn suite_to_toml(scenarios: &[Scenario]) -> String {
    scenarios
        .iter()
        .map(|s| s.to_toml())
        .collect::<Vec<_>>()
        .join("\n")
}

/// Parse a suite file's text into scenarios, in order.
pub fn parse_suite(text: &str) -> Result<Vec<Scenario>, ScenarioError> {
    let mut suites = Vec::new();
    let mut pairs: Vec<(usize, String, String)> = Vec::new();
    let mut seen_header = false;
    for (i, raw) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line == "[[scenario]]" {
            if seen_header || !pairs.is_empty() {
                suites.push(from_kv(&pairs)?);
            }
            pairs.clear();
            seen_header = true;
            continue;
        }
        if line.starts_with('[') {
            return Err(ScenarioError::Parse {
                line: line_no,
                msg: format!("unsupported section header `{line}` (only [[scenario]])"),
            });
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(ScenarioError::Parse {
                line: line_no,
                msg: format!("expected `key = value`, got `{line}`"),
            });
        };
        pairs.push((
            line_no,
            key.trim().to_string(),
            value.trim().to_string(),
        ));
    }
    if seen_header || !pairs.is_empty() {
        suites.push(from_kv(&pairs)?);
    }
    Ok(suites)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{Policy, Routing};

    #[test]
    fn every_kind_round_trips_through_toml() {
        let scenarios = vec![
            Scenario::Simulate(
                SimulateParams::default()
                    .with_io(16, 8)
                    .with_prefetch(true)
                    .with_config(ConfigSel::preset("mini").with_p_sub(2)),
            ),
            Scenario::Sweep(
                SweepParams::default()
                    .with_grid(vec![32], vec![1, 64])
                    .with_config(ConfigSel::default().with_budget_s(90.5)),
            ),
            Scenario::Breakdown(BreakdownParams::default().with_kv(256)),
            Scenario::Power(PowerParams::default().with_p_subs(vec![1, 4])),
            Scenario::Area(AreaParams::default()),
            Scenario::Serve(
                ServeParams::default()
                    .with_engine(EngineKind::Cluster)
                    .with_backend(BackendKind::Hetero)
                    .with_policy(Policy::ShortestJobFirst)
                    .with_route(Routing::SessionAffinity)
                    .with_prefill_chunk(Some(32))
                    .with_rate(Some(212.5), Some(4))
                    .with_config(ConfigSel::default().with_override("model", "gpt2-mini")),
            ),
            Scenario::Serve(
                ServeParams::default()
                    .with_engine(EngineKind::Cluster)
                    .with_kv_policy(KvPolicy::Paged)
                    .with_evict(EvictPolicy::None)
                    .with_kv_block(Some(8))
                    .with_kv_units(Some(48))
                    .with_engine_core(EngineCore::Legacy),
            ),
            Scenario::Serve(
                ServeParams::default()
                    .with_engine(EngineKind::Disagg)
                    .with_fabric(FabricKind::Nvlink)
                    .with_pools(Some(2), Some(2))
                    .with_kv_policy(KvPolicy::Paged)
                    .with_evict(EvictPolicy::Swap),
            ),
            Scenario::Serve(
                ServeParams::default()
                    .with_engine(EngineKind::Batch)
                    .with_policy(Policy::Priority)
                    .with_kv_policy(KvPolicy::Paged)
                    .with_prefix_cache(PrefixCacheMode::Radix)
                    .with_workload_spec(
                        WorkloadSpec::parse(
                            "bursty:150:4,multiturn=3:2.5,prefix=128:4:64,\
                             lengths=heavy:16:8:512,interactive=0.4",
                        )
                        .unwrap(),
                    ),
            ),
            Scenario::Serve(
                ServeParams::default()
                    .with_engine(EngineKind::Cluster)
                    .with_pools(Some(2), Some(2))
                    .with_schedule(
                        SchedSpec::parse("phase,hysteresis=3,objective=energy,power_cap=55")
                            .unwrap(),
                    ),
            ),
            Scenario::Custom(
                CustomParams::default()
                    .with_label("ablation: wider LUT")
                    .with_param("lut_sections", "128")
                    .with_param("note", "hand-run on 2026-08-08"),
            ),
        ];
        let text = suite_to_toml(&scenarios);
        let parsed = parse_suite(&text).unwrap();
        assert_eq!(parsed, scenarios);
    }

    #[test]
    fn workload_specs_round_trip_exactly_through_suite_files() {
        // The canonical render is the serialization form; parse must
        // invert it byte-for-byte (floats use shortest round-trip).
        for s in [
            "at-once,sessions=8",
            "jittered:0.05,sessions=8",
            "poisson:212.5,sessions=3,interactive=0.25",
            "bursty:8:4,multiturn=2:0.1,prefix=64:2:32,lengths=heavy:16:8:512",
        ] {
            let spec = WorkloadSpec::parse(s).unwrap();
            let toml = Scenario::Serve(
                ServeParams::default().with_workload_spec(spec.clone()),
            )
            .to_toml();
            let parsed = parse_suite(&toml).unwrap();
            let Scenario::Serve(p) = &parsed[0] else {
                panic!("serve expected");
            };
            assert_eq!(p.workload.as_ref(), Some(&spec));
            assert_eq!(p.workload.as_ref().unwrap().render(), s);
        }
        // Bad specs carry the workload parser's message with the line.
        let err =
            parse_suite("[[scenario]]\nkind = \"serve\"\nworkload = \"warp:9\"\n").unwrap_err();
        match err {
            ScenarioError::Parse { line, msg } => {
                assert_eq!(line, 3);
                assert!(msg.contains("arrival token"), "{msg}");
            }
            other => panic!("{other:?}"),
        }
        assert!(
            parse_suite("[[scenario]]\nkind = \"serve\"\nprefix_cache = \"tree\"\n").is_err()
        );
    }

    #[test]
    fn schedule_specs_round_trip_exactly_through_suite_files() {
        for s in [
            "static:gpu",
            "static:salpim,hysteresis=4",
            "phase",
            "phase,hysteresis=1,objective=energy,power_cap=60",
        ] {
            let spec = SchedSpec::parse(s).unwrap();
            let toml = Scenario::Serve(ServeParams::default().with_schedule(spec.clone()))
                .to_toml();
            let parsed = parse_suite(&toml).unwrap();
            let Scenario::Serve(p) = &parsed[0] else {
                panic!("serve expected");
            };
            assert_eq!(p.schedule.as_ref(), Some(&spec));
            assert_eq!(p.schedule.as_ref().unwrap().render(), s);
        }
        // Bad specs carry the schedule parser's message with the line.
        let bad_schedule = "[[scenario]]\nkind = \"serve\"\nschedule = \"fase\"\n";
        let err = parse_suite(bad_schedule).unwrap_err();
        match err {
            ScenarioError::Parse { line, msg } => {
                assert_eq!(line, 3);
                assert!(msg.contains("did you mean phase"), "{msg}");
            }
            other => panic!("{other:?}"),
        }
        // Backend typos surface the parser's vocabulary + suggestion.
        let bad_backend = "[[scenario]]\nkind = \"serve\"\nbackend = \"salpin\"\n";
        let err = parse_suite(bad_backend).unwrap_err();
        match err {
            ScenarioError::Parse { line, msg } => {
                assert_eq!(line, 3);
                assert!(msg.contains("salpim|gpu|banklevel|hetero"), "{msg}");
                assert!(msg.contains("did you mean salpim"), "{msg}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn comments_quotes_and_blanks_are_tolerated() {
        let text = "\n# suite\n[[scenario]]\nkind = \"area\"  # trailing\n\n";
        let parsed = parse_suite(text).unwrap();
        assert_eq!(parsed, vec![Scenario::Area(AreaParams::default())]);
        // '#' inside a quoted value is not a comment.
        let text = "[[scenario]]\nkind = \"sweep\"\nins = [32] # grid\n";
        assert!(parse_suite(text).is_ok());
    }

    #[test]
    fn header_is_optional_for_a_single_scenario() {
        let parsed = parse_suite("kind = \"breakdown\"\nkv = 64\n").unwrap();
        assert_eq!(
            parsed,
            vec![Scenario::Breakdown(BreakdownParams::default().with_kv(64))]
        );
    }

    #[test]
    fn unknown_kind_and_key_are_hard_errors() {
        let err = parse_suite("[[scenario]]\nkind = \"frobnicate\"\n").unwrap_err();
        assert!(matches!(err, ScenarioError::Parse { line: 2, .. }));
        let err = parse_suite("[[scenario]]\nkind = \"sweep\"\nkvs = [1]\n").unwrap_err();
        match err {
            ScenarioError::Parse { line, msg } => {
                assert_eq!(line, 3);
                assert!(msg.contains("kvs"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn missing_kind_and_bad_values_are_reported() {
        assert!(parse_suite("[[scenario]]\nkv = 64\n").is_err());
        assert!(parse_suite("[[scenario]]\nkind = \"serve\"\nrequests = many\n").is_err());
        assert!(parse_suite("[[scenario]]\nkind = \"serve\"\nengine = \"warp\"\n").is_err());
        assert!(
            parse_suite("[[scenario]]\nkind = \"serve\"\nengine_core = \"turbo\"\n").is_err()
        );
        assert!(parse_suite("[[scenario]]\nkind = \"serve\"\nkv_policy = \"paging\"\n").is_err());
        assert!(parse_suite("[[scenario]]\nkind = \"serve\"\nevict = \"fifo\"\n").is_err());
        assert!(parse_suite("[[scenario]]\nkind = \"serve\"\nfabric = \"carrier\"\n").is_err());
        assert!(parse_suite("[[scenario]]\nkind = \"sweep\"\nins = 32\n").is_err());
        assert!(parse_suite("not a kv line\n").is_err());
        assert!(parse_suite("[table]\n").is_err());
    }

    #[test]
    fn empty_suite_parses_to_nothing() {
        assert_eq!(parse_suite("# only comments\n").unwrap(), Vec::new());
    }
}
