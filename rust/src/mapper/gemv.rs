//! Fig. 6(b): matrix-vector mapping.
//!
//! Matrix rows → `P_Ch × P_Sub` (channels, then S-ALU groups; a group's
//! 16 register lanes hold 16 output rows), matrix columns → `P_Ba`
//! (partial sums merged by the C-ALU). Weight layout per group: a GBL
//! burst carries 16 consecutive rows' coefficients for one column, so
//! the bank register's broadcast feeding method accumulates 16 outputs
//! per MAC pass.

use crate::config::SimConfig;
use crate::pim::MacroOp;
use crate::stats::Phase;

/// Geometry of a GEMV tile, exposed for tests and the mapping explorer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemvGeometry {
    /// Output rows owned by one pseudo-channel.
    pub rows_per_pch: usize,
    /// S-ALU groups that actually receive work (≤ P_Sub: a 16-row
    /// output chunk is the minimum unit of subarray parallelism).
    pub groups: usize,
    /// 16-row output chunks per active S-ALU group.
    pub chunks_per_group: usize,
    /// Weight columns owned by one bank (+1 burst slot for the bias).
    pub cols_per_bank: usize,
    /// Total weight bursts per active S-ALU group.
    pub bursts_per_group: u64,
}

/// Compute the Fig. 6(b) tile geometry.
pub fn gemv_geometry(cfg: &SimConfig, rows: usize, cols: usize) -> GemvGeometry {
    let p = cfg.parallelism;
    let rows_per_pch = rows.div_ceil(p.p_ch);
    let chunks_total = rows_per_pch.div_ceil(16).max(1);
    let groups = p.p_sub.min(chunks_total);
    let chunks_per_group = chunks_total.div_ceil(groups);
    let cols_per_bank = cols.div_ceil(p.p_ba);
    // +1 column slot per chunk for the bias burst.
    let bursts_per_group = chunks_per_group as u64 * (cols_per_bank as u64 + 1);
    GemvGeometry {
        rows_per_pch,
        groups,
        chunks_per_group,
        cols_per_bank,
        bursts_per_group,
    }
}

/// Lower a GEMV (decode path).
pub fn map_gemv(cfg: &SimConfig, rows: usize, cols: usize, phase: Phase) -> Vec<MacroOp> {
    let p = cfg.parallelism;
    let g = gemv_geometry(cfg, rows, cols);
    let cols_per_row = cfg.hbm.cols_per_row() as u64;
    let rows_per_group = g.bursts_per_group.div_ceil(cols_per_row).max(1);
    let mut ops = vec![MacroOp::WeightStream {
        groups: g.groups,
        rows_per_group,
        cols_per_row: cols_per_row.min(g.bursts_per_group.max(1)),
        // One register lane feeds 16 bursts; the unit reloads every 16.
        reload_every: 16,
        phase,
    }];
    // Merge the per-bank partials: every 16-row output chunk accumulates
    // P_Ba banks in the C-ALU.
    ops.push(MacroOp::CaluAccumulate {
        chunks: g.rows_per_pch.div_ceil(16) as u64,
        banks: p.p_ba,
        phase: Phase::DataMovement,
    });
    // Write the merged output back, replicated into the banks, so it is
    // in place as the next operator's input (Fig. 6(a) seamlessness).
    ops.push(MacroOp::Broadcast {
        bursts_per_bank: (g.rows_per_pch.div_ceil(16)) as u64,
        phase: Phase::DataMovement,
    });
    ops
}

/// Lower a batched GEMV (summarization stage): same weight stream, but
/// the element-wise feeding method services `batch` token vectors per
/// burst, making the stream MAC-rate-bound instead of tCCDL-bound.
pub fn map_gemm(
    cfg: &SimConfig,
    rows: usize,
    cols: usize,
    batch: usize,
    phase: Phase,
) -> Vec<MacroOp> {
    assert!(batch >= 1 && batch <= 16);
    let p = cfg.parallelism;
    let g = gemv_geometry(cfg, rows, cols);
    let cols_per_row = cfg.hbm.cols_per_row() as u64;
    let rows_per_group = g.bursts_per_group.div_ceil(cols_per_row).max(1);
    let stream_cols = cols_per_row.min(g.bursts_per_group.max(1));
    // MAC passes per burst: 16 lanes × batch / (macs × 2 passes/cycle).
    // At batch = 16 this is 16 cycles per burst vs tCCDL = 4: the §6.3
    // "summarization is compute-bound on PIM" effect.
    let macs_per_cycle = 2 * cfg.salu.macs_per_salu as u64;
    let stall = (16 * batch as u64).div_ceil(macs_per_cycle);
    let mut ops = Vec::new();
    // Model the compute-bound stream as a weight stream plus explicit
    // per-burst stalls (Sync) — the engine orders them equivalently in
    // total time because the stream is steady-state.
    ops.push(MacroOp::WeightStream {
        groups: g.groups,
        rows_per_group,
        cols_per_row: stream_cols,
        reload_every: 16,
        phase,
    });
    let bursts = g.groups as u64 * rows_per_group * stream_cols;
    let t_ccdl = cfg.timing.t_ccdl;
    let stream_cycles_per_burst = (t_ccdl / p.p_sub as u64).max(1);
    if stall > stream_cycles_per_burst {
        ops.push(MacroOp::Sync {
            cycles: bursts * (stall - stream_cycles_per_burst),
            phase,
        });
    }
    // Outputs: batch × rows_per_pch values to merge and broadcast.
    ops.push(MacroOp::CaluAccumulate {
        chunks: (batch * g.rows_per_pch).div_ceil(16) as u64,
        banks: p.p_ba,
        phase: Phase::DataMovement,
    });
    ops.push(MacroOp::Broadcast {
        bursts_per_bank: (batch * g.rows_per_pch).div_ceil(16) as u64,
        phase: Phase::DataMovement,
    });
    ops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::PimEngine;

    #[test]
    fn geometry_paper_gemv() {
        // 1024×1024 at (16, 16, 4): 64 rows/pch, 1 chunk/group, 64+1
        // bursts/group.
        let cfg = SimConfig::paper();
        let g = gemv_geometry(&cfg, 1024, 1024);
        assert_eq!(g.rows_per_pch, 64);
        assert_eq!(g.chunks_per_group, 1);
        assert_eq!(g.cols_per_bank, 64);
        assert_eq!(g.bursts_per_group, 65);
    }

    #[test]
    fn geometry_ffn_and_lm_head() {
        let cfg = SimConfig::paper();
        let ffn1 = gemv_geometry(&cfg, 4096, 1024);
        assert_eq!(ffn1.chunks_per_group, 4);
        let lm = gemv_geometry(&cfg, 50257, 1024);
        assert_eq!(lm.rows_per_pch, 3142); // ceil(50257/16)
        assert_eq!(lm.chunks_per_group, 50);
    }

    #[test]
    fn weight_traffic_covers_matrix() {
        // Device-wide bursts × 32 B ≥ rows×cols×2 B.
        let cfg = SimConfig::paper();
        for (r, c) in [(1024, 1024), (4096, 1024), (1024, 4096), (50257, 1024)] {
            let g = gemv_geometry(&cfg, r, c);
            let device_bytes = g.bursts_per_group as usize
                * cfg.parallelism.p_sub
                * cfg.parallelism.p_ba
                * cfg.parallelism.p_ch
                * 32;
            assert!(device_bytes >= r * c * 2, "({r},{c}): {device_bytes}");
            assert!(device_bytes < r * c * 2 * 2, "({r},{c}) over-reads");
        }
    }

    #[test]
    fn gemm_slower_than_gemv_per_weight_pass_but_wins_per_token() {
        let cfg = SimConfig::paper();
        let run = |ops: &[MacroOp]| {
            let mut e = PimEngine::new(&cfg);
            e.execute(ops).unwrap().cycles
        };
        let gemv = run(&map_gemv(&cfg, 1024, 1024, Phase::Mha));
        let gemm16 = run(&map_gemm(&cfg, 1024, 1024, 16, Phase::Mha));
        // One batched pass costs more than one GEMV...
        assert!(gemm16 > gemv, "gemm {gemm16} !> gemv {gemv}");
        // ...but 16 tokens per pass beat 16 GEMV passes.
        assert!(
            gemm16 < gemv * 16,
            "gemm {gemm16} !< 16×gemv {}",
            gemv * 16
        );
    }

    #[test]
    fn gemv_includes_merge_and_writeback() {
        let cfg = SimConfig::paper();
        let ops = map_gemv(&cfg, 1024, 1024, Phase::Ffn);
        assert!(ops
            .iter()
            .any(|o| matches!(o, MacroOp::CaluAccumulate { .. })));
        assert!(ops.iter().any(|o| matches!(o, MacroOp::Broadcast { .. })));
    }

    #[test]
    fn p_sub_1_runs_one_group() {
        let cfg = SimConfig::paper().with_p_sub(1);
        let ops = map_gemv(&cfg, 1024, 1024, Phase::Ffn);
        match ops[0] {
            MacroOp::WeightStream { groups, .. } => assert_eq!(groups, 1),
            _ => panic!("first op must be the weight stream"),
        }
    }
}
