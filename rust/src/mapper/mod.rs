//! The §3.2 data-mapping schemes: compiling GPT operators into PIM
//! macro-op streams.
//!
//! Mapping parameters are `(P_Ch, P_Ba, P_Sub)`:
//! * matrix-vector operations (Fig. 6(b)): matrix **rows** split over
//!   channels and S-ALU groups, **columns** over banks, partial sums
//!   merged by the C-ALU;
//! * multi-head operations: **heads** on channels; K/V tokens
//!   sequentially concatenated across banks (no concat data movement);
//!   the two accumulation directions (Fig. 6(c)/(d)) + the two input
//!   feeding methods eliminate all transposes;
//! * non-linear functions (Fig. 6(a)): tiled to match the producer /
//!   consumer layout so no reshapes are needed.

mod gemv;
mod multihead;
mod nonlinear;
mod sim;

pub use gemv::{gemv_geometry, map_gemm, map_gemv, GemvGeometry};
pub use multihead::{map_kv_append, map_qk, map_sv};
pub use nonlinear::{map_embed, map_gelu, map_layernorm, map_residual, map_sample, map_softmax};
pub use sim::{GenerationResult, GenerationSim};

use crate::config::SimConfig;
use crate::model::GptOp;
use crate::pim::MacroOp;

/// Lower one GPT operator into its macro-op stream.
pub fn map_op(cfg: &SimConfig, op: &GptOp) -> Vec<MacroOp> {
    match *op {
        GptOp::Embed { d } => map_embed(cfg, d),
        GptOp::LayerNorm { d } => map_layernorm(cfg, d),
        GptOp::Gemv { rows, cols, phase } => map_gemv(cfg, rows, cols, phase),
        GptOp::Gemm {
            rows,
            cols,
            batch,
            phase,
        } => map_gemm(cfg, rows, cols, batch, phase),
        GptOp::KvAppend { d } => map_kv_append(cfg, d),
        GptOp::QkMultiHead {
            heads,
            d_head,
            kv_len,
        } => map_qk(cfg, heads, d_head, kv_len),
        GptOp::Softmax { heads, kv_len } => map_softmax(cfg, heads, kv_len),
        GptOp::SvMultiHead {
            heads,
            d_head,
            kv_len,
        } => map_sv(cfg, heads, d_head, kv_len),
        GptOp::Gelu { d } => map_gelu(cfg, d),
        GptOp::Residual { d } => map_residual(cfg, d),
        GptOp::Sample { vocab } => map_sample(cfg, vocab),
    }
}

/// Lower a whole operator sequence.
pub fn map_ops(cfg: &SimConfig, ops: &[GptOp]) -> Vec<MacroOp> {
    ops.iter().flat_map(|op| map_op(cfg, op)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::gpt2;
    use crate::stats::Phase;

    #[test]
    fn every_op_lowers_nonempty() {
        let cfg = SimConfig::paper();
        let ops = gpt2::decode_ops(&cfg.model, 8);
        for op in &ops {
            let mops = map_op(&cfg, op);
            assert!(!mops.is_empty(), "{op:?} lowered to nothing");
        }
    }

    #[test]
    fn decode_stream_reads_all_weight_traffic() {
        // The macro-op read traffic of one decode iteration must cover
        // the model's weight bytes (per pseudo-channel share).
        let cfg = SimConfig::paper();
        let ops = gpt2::decode_ops(&cfg.model, 1);
        let mops = map_ops(&cfg, &ops);
        let bursts_per_bank: u64 = mops.iter().map(|m| m.read_bursts_per_bank()).sum();
        let bytes_device = bursts_per_bank
            * 32
            * (cfg.hbm.banks_per_pch * cfg.hbm.pseudo_channels()) as u64;
        let weight_bytes: usize = ops.iter().map(|o| o.weight_bytes()).sum();
        assert!(
            bytes_device as f64 >= weight_bytes as f64,
            "device reads {bytes_device} < weights {weight_bytes}"
        );
        // ...but not wildly more (≤ 1.5×: overheads from rounding,
        // rereads of intermediates, LUT fetches).
        assert!(
            (bytes_device as f64) < weight_bytes as f64 * 1.5,
            "device reads {bytes_device} ≫ weights {weight_bytes}"
        );
    }

    #[test]
    fn phases_flow_through() {
        let cfg = SimConfig::paper();
        let mops = map_op(
            &cfg,
            &GptOp::Gemv {
                rows: 1024,
                cols: 1024,
                phase: Phase::Ffn,
            },
        );
        assert!(mops.iter().any(|m| m.phase() == Phase::Ffn));
    }
}
