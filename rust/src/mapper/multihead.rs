//! Fig. 6(c)/(d): multi-head operation mappings.
//!
//! Heads map to channels ("the heads are divided on each channel",
//! §3.2.1); K/V token vectors are **sequentially concatenated across
//! banks**, which makes KV-append a plain write (no concat movement).
//! Q×Kᵀ accumulates across banks (Fig. 6(d), element-wise feeding);
//! S×V accumulates in the S-ALU registers over each bank's tokens
//! (Fig. 6(c), broadcast feeding) — the two directions are what remove
//! the transpose.

use crate::config::SimConfig;
use crate::pim::MacroOp;
use crate::stats::Phase;

/// Heads per pseudo-channel.
pub fn heads_per_pch(cfg: &SimConfig, heads: usize) -> usize {
    heads.div_ceil(cfg.parallelism.p_ch)
}

/// KV tokens held by one bank.
pub fn tokens_per_bank(cfg: &SimConfig, kv_len: usize) -> usize {
    kv_len.div_ceil(cfg.parallelism.p_ba)
}

/// Append the current token's K and V head-slices to the banks.
pub fn map_kv_append(cfg: &SimConfig, d: usize) -> Vec<MacroOp> {
    let p = cfg.parallelism;
    // Per pseudo-channel: this channel's heads' K and V slices.
    let values = 2 * d.div_ceil(p.p_ch);
    vec![MacroOp::Broadcast {
        bursts_per_bank: values.div_ceil(16) as u64,
        phase: Phase::Mha,
    }]
}

/// Q×Kᵀ: stream each bank's K tokens past the S-ALUs with Q in the
/// bank register (element-wise feeding), then C-ALU lane-reduce each
/// score (Fig. 6(d) bank-direction accumulation).
pub fn map_qk(cfg: &SimConfig, heads: usize, d_head: usize, kv_len: usize) -> Vec<MacroOp> {
    let p = cfg.parallelism;
    let h_pch = heads_per_pch(cfg, heads);
    let t_bank = tokens_per_bank(cfg, kv_len);
    let bursts_per_token = (d_head * 2).div_ceil(32) as u64;
    // Tokens of a bank are split across the S-ALU groups.
    let bursts_per_group =
        (h_pch as u64 * t_bank as u64 * bursts_per_token).div_ceil(p.p_sub as u64);
    let cols_per_row = cfg.hbm.cols_per_row() as u64;
    let mut ops = vec![MacroOp::WeightStream {
        groups: p.p_sub,
        rows_per_group: bursts_per_group.div_ceil(cols_per_row).max(1),
        cols_per_row: cols_per_row.min(bursts_per_group.max(1)),
        reload_every: 16, // Q register chunk per 16 bursts
        phase: Phase::Mha,
    }];
    // One C-ALU lane-reduce per score (kv_len × heads per channel).
    ops.push(MacroOp::CaluReduce {
        chunks: (h_pch * kv_len) as u64,
        banks: 1,
        phase: Phase::Mha,
    });
    // Scores written back tiled over the banks for softmax (Fig. 6(a)).
    ops.push(MacroOp::Broadcast {
        bursts_per_bank: (h_pch * kv_len).div_ceil(16) as u64,
        phase: Phase::DataMovement,
    });
    ops
}

/// S×V: stream each bank's V tokens with the attention weights broadcast
/// from the bank register (one lane per token), accumulating out[d_head]
/// in the S-ALU registers (Fig. 6(c) subarray-direction accumulation).
pub fn map_sv(cfg: &SimConfig, heads: usize, d_head: usize, kv_len: usize) -> Vec<MacroOp> {
    let p = cfg.parallelism;
    let h_pch = heads_per_pch(cfg, heads);
    let t_bank = tokens_per_bank(cfg, kv_len);
    let bursts_per_token = (d_head * 2).div_ceil(32) as u64;
    let bursts_per_group =
        (h_pch as u64 * t_bank as u64 * bursts_per_token).div_ceil(p.p_sub as u64);
    let cols_per_row = cfg.hbm.cols_per_row() as u64;
    let mut ops = vec![MacroOp::WeightStream {
        groups: p.p_sub,
        rows_per_group: bursts_per_group.div_ceil(cols_per_row).max(1),
        cols_per_row: cols_per_row.min(bursts_per_group.max(1)),
        // One s-lane serves one token (= bursts_per_token bursts); the
        // register holds 16 tokens' weights.
        reload_every: 16 * bursts_per_token,
        phase: Phase::Mha,
    }];
    // Merge per-bank partial outputs: d_head lanes per head.
    ops.push(MacroOp::CaluAccumulate {
        chunks: (h_pch * d_head).div_ceil(16) as u64,
        banks: p.p_ba,
        phase: Phase::DataMovement,
    });
    // Heads live on different channels: reassemble the full attention
    // output and re-broadcast it for the output projection (§3.2.1 "the
    // output of the MHA is reshaped into a single channel ... then
    // broadcasted across all channels").
    ops.push(MacroOp::ChannelReshape {
        bytes: (heads * d_head * 2) as u64,
        phase: Phase::DataMovement,
    });
    ops.push(MacroOp::Broadcast {
        bursts_per_bank: (heads * d_head).div_ceil(16) as u64,
        phase: Phase::DataMovement,
    });
    ops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::PimEngine;

    #[test]
    fn paper_head_alignment() {
        // GPT-2 medium: 16 heads on 16 pseudo-channels → exactly 1 each.
        let cfg = SimConfig::paper();
        assert_eq!(heads_per_pch(&cfg, 16), 1);
        assert_eq!(tokens_per_bank(&cfg, 128), 8);
    }

    #[test]
    fn qk_cost_grows_with_kv() {
        let cfg = SimConfig::paper();
        let run = |kv| {
            let mut e = PimEngine::new(&cfg);
            e.execute(&map_qk(&cfg, 16, 64, kv)).unwrap().cycles
        };
        let short = run(16);
        let long = run(1024);
        assert!(long > short * 4, "long={long} short={short}");
    }

    #[test]
    fn sv_includes_reshape_and_broadcast() {
        let cfg = SimConfig::paper();
        let ops = map_sv(&cfg, 16, 64, 64);
        assert!(ops
            .iter()
            .any(|o| matches!(o, MacroOp::ChannelReshape { .. })));
        assert!(ops.iter().any(|o| matches!(o, MacroOp::Broadcast { .. })));
    }

    #[test]
    fn kv_append_writes_both_k_and_v() {
        let cfg = SimConfig::paper();
        let ops = map_kv_append(&cfg, 1024);
        match ops[0] {
            MacroOp::Broadcast { bursts_per_bank, .. } => {
                // 2 × 1024/16 values / 16 per burst = 8.
                assert_eq!(bursts_per_bank, 8);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn kv_traffic_matches_stored_bytes() {
        // QK must read ≥ the K bytes of this channel's heads.
        let cfg = SimConfig::paper();
        let kv = 256;
        let ops = map_qk(&cfg, 16, 64, kv);
        let read_bursts: u64 = ops.iter().map(|o| o.read_bursts_per_bank()).sum();
        let device_bytes = read_bursts * 32 * (16 * 16) as u64;
        let k_bytes = (16 * 64 * kv * 2) as u64;
        assert!(device_bytes >= k_bytes, "{device_bytes} < {k_bytes}");
    }
}
