//! End-to-end text-generation timing simulation.
//!
//! [`GenerationSim`] composes the op graph ([`crate::model::gpt2`]), the
//! mapper and the PIM engine into whole-workload measurements. Decode
//! iterations are deterministic functions of the KV length, so per-`kv`
//! results are cached — a 256-token generation costs 256 distinct
//! simulations, and sweeps across input sizes share the cache.

use super::map_ops;
use crate::config::SimConfig;
use crate::model::gpt2;
use crate::pim::PimEngine;
use crate::stats::Stats;
use std::collections::HashMap;

/// Result of one simulated generation run.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    /// Summarization-stage statistics.
    pub prefill: Stats,
    /// Generation-stage statistics (all decode iterations merged).
    pub decode: Stats,
    /// Input / output token counts.
    pub n_in: usize,
    pub n_out: usize,
}

impl GenerationResult {
    /// Merged statistics over both stages.
    pub fn total(&self) -> Stats {
        let mut t = self.prefill.clone();
        t.merge(&self.decode);
        t
    }

    /// End-to-end seconds at a tCK.
    pub fn seconds(&self, tck_ns: f64) -> f64 {
        self.total().seconds(tck_ns)
    }

    /// Generation-stage tokens per second.
    pub fn decode_tokens_per_sec(&self, tck_ns: f64) -> f64 {
        if self.n_out == 0 {
            return 0.0;
        }
        self.n_out as f64 / self.decode.seconds(tck_ns)
    }
}

/// Cached whole-workload simulator.
pub struct GenerationSim {
    pub cfg: SimConfig,
    engine: PimEngine,
    decode_cache: HashMap<usize, Stats>,
    prefill_cache: HashMap<usize, Stats>,
}

impl GenerationSim {
    pub fn new(cfg: &SimConfig) -> Self {
        GenerationSim {
            cfg: cfg.clone(),
            engine: PimEngine::new(cfg),
            decode_cache: HashMap::new(),
            prefill_cache: HashMap::new(),
        }
    }

    /// Enable the §Perf prefetch scheduling (invalidates caches).
    pub fn set_prefetch(&mut self, on: bool) {
        if self.engine.opt_prefetch != on {
            self.engine.opt_prefetch = on;
            self.decode_cache.clear();
            self.prefill_cache.clear();
        }
    }

    /// Timing of one decode iteration at a given KV length (cached).
    pub fn decode_token(&mut self, kv_len: usize) -> Stats {
        if let Some(s) = self.decode_cache.get(&kv_len) {
            return s.clone();
        }
        let ops = gpt2::decode_ops(&self.cfg.model, kv_len);
        let mops = map_ops(&self.cfg, &ops);
        self.engine.reset();
        let mut stats = self.engine.execute(&mops).expect("decode stream");
        stats.tokens_generated = 1;
        self.decode_cache.insert(kv_len, stats.clone());
        stats
    }

    /// Timing of the summarization stage over `n_in` tokens (cached).
    pub fn prefill(&mut self, n_in: usize) -> Stats {
        if let Some(s) = self.prefill_cache.get(&n_in) {
            return s.clone();
        }
        let ops = gpt2::prefill_ops(&self.cfg.model, n_in);
        let mops = map_ops(&self.cfg, &ops);
        self.engine.reset();
        let mut stats = self.engine.execute(&mops).expect("prefill stream");
        stats.tokens_generated = 1; // summarization emits the first token
        self.prefill_cache.insert(n_in, stats.clone());
        stats
    }

    /// Full text generation: `n_in` prompt tokens, `n_out` output tokens
    /// (the first comes from the summarization stage, the rest from
    /// decode iterations with growing KV).
    pub fn generate(&mut self, n_in: usize, n_out: usize) -> GenerationResult {
        assert!(n_in >= 1 && n_out >= 1);
        let prefill = self.prefill(n_in);
        let mut decode = Stats::new();
        // Iteration i consumes token n_in+i and produces token i+1.
        for i in 1..n_out {
            let kv_len = n_in + i;
            if kv_len >= self.cfg.model.max_seq {
                break;
            }
            decode.merge(&self.decode_token(kv_len));
        }
        GenerationResult {
            prefill,
            decode,
            n_in,
            n_out,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Phase;

    #[test]
    fn decode_iteration_is_cached() {
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let a = sim.decode_token(64);
        let b = sim.decode_token(64);
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn decode_time_grows_with_kv() {
        let mut sim = GenerationSim::new(&SimConfig::paper());
        assert!(sim.decode_token(512).cycles > sim.decode_token(16).cycles);
    }

    #[test]
    fn decode_token_time_is_plausible() {
        // GPT-2 medium streams ~700 MB/token; at 8 TB/s peak that's
        // ≥84 µs. Anything under that violates physics; anything over
        // ~10× means the mapper is broken.
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let st = sim.decode_token(64);
        let us = st.cycles as f64 / 1000.0;
        assert!(us > 80.0, "decode {us} µs too fast");
        assert!(us < 900.0, "decode {us} µs too slow");
    }

    #[test]
    fn generation_composes_prefill_and_decode() {
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let r = sim.generate(32, 8);
        assert!(r.prefill.cycles > 0);
        assert!(r.decode.cycles > 0);
        assert_eq!(r.total().cycles, r.prefill.cycles + r.decode.cycles);
        assert!(r.decode_tokens_per_sec(1.0) > 0.0);
    }

    #[test]
    fn longer_outputs_cost_more() {
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let short = sim.generate(32, 4).total().cycles;
        let long = sim.generate(32, 32).total().cycles;
        assert!(long > short);
    }

    #[test]
    fn psub_speedup_on_text_generation_matches_fig14() {
        // Fig. 14: P_Sub=4 achieves ≈2.11× over P_Sub=1 on text
        // generation (matrix ops are ~60 % of time). Measured on a
        // generation-dominated workload; accept 1.7–3.2×.
        let mut s4 = GenerationSim::new(&SimConfig::paper());
        let mut s1 = GenerationSim::new(&SimConfig::paper().with_p_sub(1));
        let t4 = s4.generate(32, 64).total().cycles;
        let t1 = s1.generate(32, 64).total().cycles;
        let speedup = t1 as f64 / t4 as f64;
        assert!(
            speedup > 1.7 && speedup < 3.2,
            "P_Sub 4-vs-1 speedup {speedup}"
        );
    }

    #[test]
    fn gemv_phases_dominate_decode() {
        // §6.2: matrix-vector + multi-head ≈ 60 % of execution time.
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let st = sim.decode_token(128);
        let matrix = st.phase_fraction(Phase::Mha)
            + st.phase_fraction(Phase::Ffn)
            + st.phase_fraction(Phase::LmHead);
        assert!(matrix > 0.4, "matrix fraction {matrix}");
    }

    #[test]
    fn prefill_cheaper_than_equivalent_decodes() {
        // Weight reuse must make 32-token prefill ≪ 32 decode steps.
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let prefill = sim.prefill(32).cycles;
        let decode32 = (1..=32).map(|i| sim.decode_token(i).cycles).sum::<u64>();
        assert!(prefill < decode32, "prefill {prefill} !< {decode32}");
    }
}
