//! End-to-end text-generation timing simulation.
//!
//! [`GenerationSim`] composes the op graph ([`crate::model::gpt2`]), the
//! mapper and the PIM engine into whole-workload measurements. Decode
//! iterations are deterministic functions of the KV length, so per-`kv`
//! results are cached — a 256-token generation costs 256 distinct
//! simulations, and sweeps across input sizes share the cache.

use super::map_ops;
use crate::config::SimConfig;
use crate::model::gpt2;
use crate::pim::PimEngine;
use crate::stats::{CmdKind, Phase, Stats};
use std::collections::HashMap;

/// Phases of a decode iteration that stream *model weights*: one batched
/// step pays them once because every request in the batch consumes the
/// same weight rows as they cross the S-ALUs.
const WEIGHT_SHARED_PHASES: [Phase; 5] = [
    Phase::Embedding,
    Phase::Ffn,
    Phase::LmHead,
    Phase::Residual,
    Phase::DataMovement,
];

/// Phases charged per batched request: the KV streams live in different
/// subarray rows per request, and the nonlinear (softmax/LUT) work is
/// per-request state — neither amortizes across a batch.
const PER_REQUEST_PHASES: [Phase; 2] = [Phase::Mha, Phase::NonLinear];

/// Traffic counters pre-scaled by one group's share of an iteration.
#[derive(Debug, Clone, Default)]
struct ScaledTraffic {
    internal_bytes: u64,
    external_bytes: u64,
    activations: u64,
    commands: Vec<(CmdKind, u64)>,
}

impl ScaledTraffic {
    /// Split `src`'s counters into a per-request share (`per_req_frac`
    /// of each counter, rounded) and the *exact residual* as the shared
    /// group. Rounding the two groups independently let them drift from
    /// the unbatched totals by ±1 per counter; assigning the residual
    /// guarantees `shared + per_req == src` exactly, so a batch of one
    /// reproduces the single-iteration traffic bit for bit.
    fn split(src: &Stats, per_req_frac: f64) -> (Self, Self) {
        let per = |v: u64| ((v as f64 * per_req_frac).round() as u64).min(v);
        let per_req = ScaledTraffic {
            internal_bytes: per(src.internal_bytes),
            external_bytes: per(src.external_bytes),
            activations: per(src.activations),
            commands: src.commands.iter().map(|(k, c)| (*k, per(*c))).collect(),
        };
        let shared = ScaledTraffic {
            internal_bytes: src.internal_bytes - per_req.internal_bytes,
            external_bytes: src.external_bytes - per_req.external_bytes,
            activations: src.activations - per_req.activations,
            commands: src
                .commands
                .iter()
                .zip(&per_req.commands)
                .map(|((k, c), (_, p))| (*k, c - p))
                .collect(),
        };
        (shared, per_req)
    }

    fn add_into(&self, dst: &mut Stats) {
        dst.internal_bytes += self.internal_bytes;
        dst.external_bytes += self.external_bytes;
        dst.activations += self.activations;
        for (k, c) in &self.commands {
            *dst.commands.entry(*k).or_insert(0) += c;
        }
    }
}

/// Precomputed batching terms for one KV length: phase cycles split into
/// the weight-shared and per-request groups, traffic counters pre-scaled
/// by each group's share. Cached per kv so the serving engine's hot loop
/// never re-clones full [`Stats`].
#[derive(Debug, Clone)]
struct BatchTerms {
    shared_phases: [(Phase, u64); 5],
    per_req_phases: [(Phase, u64); 2],
    shared_traffic: ScaledTraffic,
    per_req_traffic: ScaledTraffic,
}

impl BatchTerms {
    fn from_stats(st: &Stats) -> Self {
        let grab = |p: Phase| st.phase_cycles.get(&p).copied().unwrap_or(0);
        let shared_phases = WEIGHT_SHARED_PHASES.map(|p| (p, grab(p)));
        let per_req_phases = PER_REQUEST_PHASES.map(|p| (p, grab(p)));
        let per_req: u64 = per_req_phases.iter().map(|(_, c)| *c).sum();
        let per_req_frac = if st.cycles == 0 {
            0.0
        } else {
            per_req as f64 / st.cycles as f64
        };
        let (shared_traffic, per_req_traffic) = ScaledTraffic::split(st, per_req_frac);
        BatchTerms {
            shared_phases,
            per_req_phases,
            shared_traffic,
            per_req_traffic,
        }
    }

    fn shared_cycles(&self) -> u64 {
        self.shared_phases.iter().map(|(_, c)| *c).sum()
    }
}

/// Result of one simulated generation run.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    /// Summarization-stage statistics.
    pub prefill: Stats,
    /// Generation-stage statistics (all decode iterations merged).
    pub decode: Stats,
    /// Input / output token counts.
    pub n_in: usize,
    pub n_out: usize,
}

impl GenerationResult {
    /// Merged statistics over both stages.
    pub fn total(&self) -> Stats {
        let mut t = self.prefill.clone();
        t.merge(&self.decode);
        t
    }

    /// End-to-end seconds at a tCK.
    pub fn seconds(&self, tck_ns: f64) -> f64 {
        self.total().seconds(tck_ns)
    }

    /// Generation-stage tokens per second.
    pub fn decode_tokens_per_sec(&self, tck_ns: f64) -> f64 {
        if self.n_out == 0 {
            return 0.0;
        }
        self.n_out as f64 / self.decode.seconds(tck_ns)
    }
}

/// Cached whole-workload simulator.
pub struct GenerationSim {
    pub cfg: SimConfig,
    engine: PimEngine,
    decode_cache: HashMap<usize, Stats>,
    prefill_cache: HashMap<usize, Stats>,
    batch_cache: HashMap<usize, BatchTerms>,
}

impl GenerationSim {
    pub fn new(cfg: &SimConfig) -> Self {
        GenerationSim {
            cfg: cfg.clone(),
            engine: PimEngine::new(cfg),
            decode_cache: HashMap::new(),
            prefill_cache: HashMap::new(),
            batch_cache: HashMap::new(),
        }
    }

    /// Enable the §Perf prefetch scheduling (invalidates caches).
    pub fn set_prefetch(&mut self, on: bool) {
        if self.engine.opt_prefetch != on {
            self.engine.opt_prefetch = on;
            self.decode_cache.clear();
            self.prefill_cache.clear();
            self.batch_cache.clear();
        }
    }

    /// Timing of one decode iteration at a given KV length (cached).
    pub fn decode_token(&mut self, kv_len: usize) -> Stats {
        if let Some(s) = self.decode_cache.get(&kv_len) {
            return s.clone();
        }
        let ops = gpt2::decode_ops(&self.cfg.model, kv_len);
        let mops = map_ops(&self.cfg, &ops);
        self.engine.reset();
        let mut stats = self.engine.execute(&mops).expect("decode stream");
        stats.tokens_generated = 1;
        self.decode_cache.insert(kv_len, stats.clone());
        stats
    }

    /// Timing of the summarization stage over `n_in` tokens (cached).
    pub fn prefill(&mut self, n_in: usize) -> Stats {
        if let Some(s) = self.prefill_cache.get(&n_in) {
            return s.clone();
        }
        let ops = gpt2::prefill_ops(&self.cfg.model, n_in);
        let mops = map_ops(&self.cfg, &ops);
        self.engine.reset();
        let mut stats = self.engine.execute(&mops).expect("prefill stream");
        stats.tokens_generated = 1; // summarization emits the first token
        self.prefill_cache.insert(n_in, stats.clone());
        stats
    }

    /// Timing of one *batched* decode step: every entry of `kv_lens` is
    /// one in-flight request producing its next token in the same step.
    ///
    /// The weight-streaming phases are charged once at the cost of the
    /// most expensive request (banks broadcast each weight row to all
    /// per-request accumulators), while the KV-bound attention and the
    /// per-request nonlinear work accumulate across the batch — see
    /// [`WEIGHT_SHARED_PHASES`] / [`PER_REQUEST_PHASES`]. A batch of one
    /// degenerates to [`GenerationSim::decode_token`] exactly.
    pub fn decode_batch_step(&mut self, kv_lens: &[usize]) -> Stats {
        assert!(!kv_lens.is_empty(), "empty decode batch");
        for &kv in kv_lens {
            if !self.batch_cache.contains_key(&kv) {
                let st = self.decode_token(kv);
                self.batch_cache.insert(kv, BatchTerms::from_stats(&st));
            }
        }
        let lead = kv_lens
            .iter()
            .map(|kv| &self.batch_cache[kv])
            .max_by_key(|t| t.shared_cycles())
            .unwrap();
        let mut out = Stats::new();
        // Shared weight stream: the lead request's weight-phase cycles.
        for (p, c) in lead.shared_phases.iter().copied() {
            if c > 0 {
                out.add_phase_cycles(p, c);
            }
        }
        lead.shared_traffic.add_into(&mut out);
        // Per-request KV + nonlinear work.
        for kv in kv_lens {
            let t = &self.batch_cache[kv];
            for (p, c) in t.per_req_phases.iter().copied() {
                if c > 0 {
                    out.add_phase_cycles(p, c);
                }
            }
            t.per_req_traffic.add_into(&mut out);
        }
        out.tokens_generated = kv_lens.len() as u64;
        out
    }

    /// Full text generation: `n_in` prompt tokens, `n_out` output tokens
    /// (the first comes from the summarization stage, the rest from
    /// decode iterations with growing KV).
    pub fn generate(&mut self, n_in: usize, n_out: usize) -> GenerationResult {
        assert!(n_in >= 1 && n_out >= 1);
        let prefill = self.prefill(n_in);
        let mut decode = Stats::new();
        // Iteration i consumes token n_in+i and produces token i+1.
        for i in 1..n_out {
            let kv_len = n_in + i;
            if kv_len >= self.cfg.model.max_seq {
                break;
            }
            decode.merge(&self.decode_token(kv_len));
        }
        GenerationResult {
            prefill,
            decode,
            n_in,
            n_out,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Phase;

    #[test]
    fn decode_iteration_is_cached() {
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let a = sim.decode_token(64);
        let b = sim.decode_token(64);
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn decode_time_grows_with_kv() {
        let mut sim = GenerationSim::new(&SimConfig::paper());
        assert!(sim.decode_token(512).cycles > sim.decode_token(16).cycles);
    }

    #[test]
    fn decode_token_time_is_plausible() {
        // GPT-2 medium streams ~700 MB/token; at 8 TB/s peak that's
        // ≥84 µs. Anything under that violates physics; anything over
        // ~10× means the mapper is broken.
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let st = sim.decode_token(64);
        let us = st.cycles as f64 / 1000.0;
        assert!(us > 80.0, "decode {us} µs too fast");
        assert!(us < 900.0, "decode {us} µs too slow");
    }

    #[test]
    fn generation_composes_prefill_and_decode() {
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let r = sim.generate(32, 8);
        assert!(r.prefill.cycles > 0);
        assert!(r.decode.cycles > 0);
        assert_eq!(r.total().cycles, r.prefill.cycles + r.decode.cycles);
        assert!(r.decode_tokens_per_sec(1.0) > 0.0);
    }

    #[test]
    fn longer_outputs_cost_more() {
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let short = sim.generate(32, 4).total().cycles;
        let long = sim.generate(32, 32).total().cycles;
        assert!(long > short);
    }

    #[test]
    fn psub_speedup_on_text_generation_matches_fig14() {
        // Fig. 14: P_Sub=4 achieves ≈2.11× over P_Sub=1 on text
        // generation (matrix ops are ~60 % of time). Measured on a
        // generation-dominated workload; accept 1.7–3.2×.
        let mut s4 = GenerationSim::new(&SimConfig::paper());
        let mut s1 = GenerationSim::new(&SimConfig::paper().with_p_sub(1));
        let t4 = s4.generate(32, 64).total().cycles;
        let t1 = s1.generate(32, 64).total().cycles;
        let speedup = t1 as f64 / t4 as f64;
        assert!(
            speedup > 1.7 && speedup < 3.2,
            "P_Sub 4-vs-1 speedup {speedup}"
        );
    }

    #[test]
    fn gemv_phases_dominate_decode() {
        // §6.2: matrix-vector + multi-head ≈ 60 % of execution time.
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let st = sim.decode_token(128);
        let matrix = st.phase_fraction(Phase::Mha)
            + st.phase_fraction(Phase::Ffn)
            + st.phase_fraction(Phase::LmHead);
        assert!(matrix > 0.4, "matrix fraction {matrix}");
    }

    #[test]
    fn batch_of_one_equals_decode_token() {
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let single = sim.decode_token(64);
        let batch = sim.decode_batch_step(&[64]);
        assert_eq!(batch.cycles, single.cycles);
        assert_eq!(batch.tokens_generated, 1);
    }

    #[test]
    fn batch_of_one_conserves_traffic_counters() {
        // The shared/per-request traffic split assigns the exact
        // residual to the shared group, so a batch of one must
        // reproduce the single-iteration counters exactly — not within
        // a per-counter rounding drift.
        let mut sim = GenerationSim::new(&SimConfig::paper());
        for kv in [17usize, 64, 333] {
            let single = sim.decode_token(kv);
            let batch = sim.decode_batch_step(&[kv]);
            assert_eq!(batch.internal_bytes, single.internal_bytes, "kv={kv}");
            assert_eq!(batch.external_bytes, single.external_bytes, "kv={kv}");
            assert_eq!(batch.activations, single.activations, "kv={kv}");
            assert_eq!(batch.commands, single.commands, "kv={kv}");
        }
    }

    #[test]
    fn split_traffic_sums_back_exactly() {
        // Direct conservation check on the splitter with an awkward
        // fraction (1/3 rounds every counter).
        let mut src = Stats::new();
        src.add_phase_cycles(Phase::Mha, 1);
        src.add_phase_cycles(Phase::Ffn, 2);
        src.internal_bytes = 101;
        src.external_bytes = 7;
        src.count_cmd(crate::stats::CmdKind::Act, 13); // also sets activations

        src.count_cmd(crate::stats::CmdKind::Rd, 999);
        let (shared, per_req) = ScaledTraffic::split(&src, 1.0 / 3.0);
        assert_eq!(shared.internal_bytes + per_req.internal_bytes, 101);
        assert_eq!(shared.external_bytes + per_req.external_bytes, 7);
        assert_eq!(shared.activations + per_req.activations, 13);
        for ((k, s), (k2, p)) in shared.commands.iter().zip(&per_req.commands) {
            assert_eq!(k, k2);
            assert_eq!(s + p, src.commands[k]);
        }
    }

    #[test]
    fn batched_step_amortizes_weight_stream() {
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let kvs = [64usize, 96, 128, 160];
        let batch = sim.decode_batch_step(&kvs);
        let individual: u64 = kvs.iter().map(|&kv| sim.decode_token(kv).cycles).sum();
        let slowest = kvs.iter().map(|&kv| sim.decode_token(kv).cycles).max().unwrap();
        // Cheaper than sequential service, never faster than the
        // slowest member alone.
        assert!(batch.cycles < individual, "{} !< {individual}", batch.cycles);
        assert!(batch.cycles >= slowest, "{} < slowest {slowest}", batch.cycles);
        assert_eq!(batch.tokens_generated, 4);
    }

    #[test]
    fn batched_step_grows_with_batch_size() {
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let b2 = sim.decode_batch_step(&[64, 64]).cycles;
        let b8 = sim.decode_batch_step(&[64; 8]).cycles;
        assert!(b8 > b2, "per-request attention must accumulate");
    }

    #[test]
    fn prefill_cheaper_than_equivalent_decodes() {
        // Weight reuse must make 32-token prefill ≪ 32 decode steps.
        let mut sim = GenerationSim::new(&SimConfig::paper());
        let prefill = sim.prefill(32).cycles;
        let decode32 = (1..=32).map(|i| sim.decode_token(i).cycles).sum::<u64>();
        assert!(prefill < decode32, "prefill {prefill} !< {decode32}");
    }
}
