//! Fig. 6(a): non-linear-function and element-wise mappings.
//!
//! Vectors are tiled across banks in exactly the producer's output
//! layout (and duplicated across channels when the consumer is a
//! matrix-vector operation), so no data movement separates a non-linear
//! function from its neighbors.

use crate::config::SimConfig;
use crate::pim::{LutMethod, MacroOp};
use crate::stats::Phase;

fn elems_per_bank(cfg: &SimConfig, n: usize) -> u64 {
    n.div_ceil(cfg.parallelism.p_ba) as u64
}

/// Token embedding + positional add (one row read + element-wise add).
pub fn map_embed(cfg: &SimConfig, d: usize) -> Vec<MacroOp> {
    vec![
        MacroOp::Elementwise {
            elems_per_bank: elems_per_bank(cfg, d),
            n_operands: 2,
            phase: Phase::Embedding,
        },
        // Replicate the embedded vector into every bank as GEMV input.
        MacroOp::Broadcast {
            bursts_per_bank: d.div_ceil(16) as u64,
            phase: Phase::Embedding,
        },
    ]
}

/// layerNorm: two S-ALU+C-ALU reductions (mean, variance), an rsqrt via
/// LUT, then the affine pass (§3.2.1 dataflow).
pub fn map_layernorm(cfg: &SimConfig, d: usize) -> Vec<MacroOp> {
    let e = elems_per_bank(cfg, d);
    let banks = cfg.parallelism.p_ba;
    let nl = Phase::NonLinear;
    vec![
        // Local Σx in the S-ALUs, merged by the C-ALU tree.
        MacroOp::Elementwise {
            elems_per_bank: e,
            n_operands: 1,
            phase: nl,
        },
        MacroOp::CaluReduce {
            chunks: 1,
            banks,
            phase: nl,
        },
        // Local Σ(x−μ)² then merge.
        MacroOp::Elementwise {
            elems_per_bank: e,
            n_operands: 1,
            phase: nl,
        },
        MacroOp::CaluReduce {
            chunks: 1,
            banks,
            phase: nl,
        },
        // 1/σ via the rsqrt table (scalar — one 16-lane sweep).
        MacroOp::LutSweep {
            elems_per_bank: 16,
            method: LutMethod::Embedded,
            sections: cfg.lut.sections,
            phase: nl,
        },
        // (x−μ)·(1/σ)·γ + β.
        MacroOp::Elementwise {
            elems_per_bank: e,
            n_operands: 3,
            phase: nl,
        },
    ]
}

/// Softmax over per-head score vectors (§3.2.1): max, LUT exp,
/// reduce-sum, LUT reciprocal, scale.
pub fn map_softmax(cfg: &SimConfig, heads: usize, kv_len: usize) -> Vec<MacroOp> {
    let h_pch = heads.div_ceil(cfg.parallelism.p_ch);
    let e = elems_per_bank(cfg, h_pch * kv_len);
    let banks = cfg.parallelism.p_ba;
    let nl = Phase::NonLinear;
    vec![
        // Per-bank max (S-ALU max op), merged per head by the C-ALU.
        MacroOp::Elementwise {
            elems_per_bank: e,
            n_operands: 1,
            phase: nl,
        },
        MacroOp::CaluReduce {
            chunks: h_pch as u64,
            banks,
            phase: nl,
        },
        // exp(x − max) through the LUT-embedded subarray.
        MacroOp::LutSweep {
            elems_per_bank: e,
            method: LutMethod::Embedded,
            sections: cfg.lut.sections,
            phase: nl,
        },
        // Σ exp merged per head.
        MacroOp::Elementwise {
            elems_per_bank: e,
            n_operands: 1,
            phase: nl,
        },
        MacroOp::CaluReduce {
            chunks: h_pch as u64,
            banks,
            phase: nl,
        },
        // Reciprocal of the sum (scalar sweep per head).
        MacroOp::LutSweep {
            elems_per_bank: 16 * h_pch as u64,
            method: LutMethod::Embedded,
            sections: cfg.lut.sections,
            phase: nl,
        },
        // Scale every exponential by 1/Σ.
        MacroOp::Elementwise {
            elems_per_bank: e,
            n_operands: 1,
            phase: nl,
        },
    ]
}

/// GELU over the FFN intermediate vector via the LUT-embedded subarray,
/// with the configured method (Embedded unless an ablation overrides).
pub fn map_gelu(cfg: &SimConfig, d: usize) -> Vec<MacroOp> {
    map_gelu_with(cfg, d, LutMethod::Embedded)
}

/// GELU with an explicit LUT access method (the Fig. 13 ablation).
pub fn map_gelu_with(cfg: &SimConfig, d: usize, method: LutMethod) -> Vec<MacroOp> {
    vec![MacroOp::LutSweep {
        elems_per_bank: elems_per_bank(cfg, d),
        method,
        sections: cfg.lut.sections,
        phase: Phase::NonLinear,
    }]
}

/// Residual addition of two resident vectors.
pub fn map_residual(cfg: &SimConfig, d: usize) -> Vec<MacroOp> {
    vec![MacroOp::Elementwise {
        elems_per_bank: elems_per_bank(cfg, d),
        n_operands: 2,
        phase: Phase::Residual,
    }]
}

/// Greedy sampling: per-bank max over the logit tile, C-ALU merge,
/// cross-channel argmax on the buffer die, next-token sync.
pub fn map_sample(cfg: &SimConfig, vocab: usize) -> Vec<MacroOp> {
    let per_pch = vocab.div_ceil(cfg.parallelism.p_ch);
    vec![
        MacroOp::Elementwise {
            elems_per_bank: elems_per_bank(cfg, per_pch),
            n_operands: 1,
            phase: Phase::LmHead,
        },
        MacroOp::CaluReduce {
            chunks: 1,
            banks: cfg.parallelism.p_ba,
            phase: Phase::LmHead,
        },
        // Per-channel (max, index) pairs to the buffer die + final pick.
        MacroOp::ChannelReshape {
            bytes: (cfg.parallelism.p_ch * 4) as u64,
            phase: Phase::LmHead,
        },
        // Token-id broadcast and PIM command-mode turnaround.
        MacroOp::Sync {
            cycles: 100,
            phase: Phase::LmHead,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::PimEngine;

    #[test]
    fn layernorm_has_two_reductions_and_rsqrt() {
        let cfg = SimConfig::paper();
        let ops = map_layernorm(&cfg, 1024);
        let reduces = ops
            .iter()
            .filter(|o| matches!(o, MacroOp::CaluReduce { .. }))
            .count();
        assert_eq!(reduces, 2);
        assert!(ops.iter().any(|o| matches!(o, MacroOp::LutSweep { .. })));
    }

    #[test]
    fn softmax_cost_scales_with_kv() {
        let cfg = SimConfig::paper();
        let run = |kv| {
            let mut e = PimEngine::new(&cfg);
            e.execute(&map_softmax(&cfg, 16, kv)).unwrap().cycles
        };
        assert!(run(1024) > run(32));
    }

    #[test]
    fn gelu_is_one_lut_sweep() {
        let cfg = SimConfig::paper();
        let ops = map_gelu(&cfg, 4096);
        assert_eq!(ops.len(), 1);
        match ops[0] {
            MacroOp::LutSweep {
                elems_per_bank,
                method,
                ..
            } => {
                assert_eq!(elems_per_bank, 256); // 4096 / 16 banks
                assert_eq!(method, LutMethod::Embedded);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn nonlinear_ops_are_cheap_vs_gemv() {
        // The point of the architecture: LUT-based nonlinears must not
        // dominate a decode layer.
        let cfg = SimConfig::paper();
        let run = |ops: &[MacroOp]| {
            let mut e = PimEngine::new(&cfg);
            e.execute(ops).unwrap().cycles
        };
        let gelu = run(&map_gelu(&cfg, 4096));
        let gemv = run(&crate::mapper::map_gemv(
            &cfg,
            4096,
            1024,
            crate::stats::Phase::Ffn,
        ));
        assert!(gelu < gemv, "gelu {gelu} !< ffn gemv {gemv}");
    }

    #[test]
    fn sample_ends_with_sync() {
        let cfg = SimConfig::paper();
        let ops = map_sample(&cfg, 50257);
        assert!(matches!(ops.last(), Some(MacroOp::Sync { .. })));
    }
}
