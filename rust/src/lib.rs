//! # SAL-PIM
//!
//! A from-scratch reproduction of **SAL-PIM: A Subarray-level
//! Processing-in-Memory Architecture with LUT-based Linear Interpolation for
//! Transformer-based Text Generation** (Han, Cho, Kim & Kim, KAIST 2024).
//!
//! The crate contains the whole evaluated stack:
//!
//! * a command-level cycle-accurate **HBM2 + PIM timing simulator**
//!   ([`dram`], [`pim`]) with subarray-level parallelism (SALP), S-ALUs,
//!   bank-level units, C-ALUs and LUT-embedded subarrays,
//! * the paper's **data-mapping schemes** compiling GPT operators into PIM
//!   command streams ([`mapper`]),
//! * the **GPT-2 operator graph** and a bit-exact 16-bit fixed-point
//!   functional model ([`model`]),
//! * **LUT-based linear interpolation** table generation and accuracy
//!   analysis ([`interp`]),
//! * the **GPU roofline** and **bank-level PIM** baselines ([`baseline`]),
//! * **area / energy / power models** seeded with the paper's published
//!   constants ([`energy`]),
//! * a **PJRT runtime** that loads the AOT-compiled JAX/Pallas artifacts as
//!   the float golden model ([`runtime`]),
//! * a text-generation **serving coordinator** ([`coordinator`]),
//! * a **cluster serving engine** — continuous batching, subarray-aware
//!   KV-cache accounting and multi-device routing ([`serve`]),
//! * the **scenario experiment API** — declarative [`scenario::Scenario`]
//!   descriptions executed by [`scenario::Runner`] into structured
//!   [`scenario::Outcome`]s, rendered as tables / JSON / CSV and
//!   accumulated into `BENCH_*.json` ([`scenario`]),
//! * **request-lifecycle tracing and self-profiling** — typed lifecycle
//!   events, per-request span timelines, Chrome `trace_event` export,
//!   log-bucketed histogram metrics and wall-clock phase profiles
//!   ([`trace`]),
//! * reporting/CLI/test utilities ([`report`], [`cli`], [`testutil`]).
//!
//! See `DESIGN.md` for the architecture and the per-experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod baseline;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dram;
pub mod energy;
pub mod interp;
pub mod mapper;
pub mod model;
pub mod pim;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod stats;
pub mod testutil;
pub mod trace;

pub use config::SimConfig;
