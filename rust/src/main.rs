//! SAL-PIM command-line interface.
//!
//! ```text
//! sal-pim config   [--preset paper|mini] [--file overrides.cfg]
//! sal-pim simulate --in 32 --out 64 [--p-sub 4] [--prefetch]
//! sal-pim sweep    [--p-sub 4]                 # the Fig. 11 grid
//! sal-pim breakdown [--kv 128]                 # decode phase breakdown
//! sal-pim power    [--out 32]                  # Fig. 15 power report
//! sal-pim area                                 # Table 3 arithmetic
//! sal-pim serve    --requests 16 [--policy fcfs|sjf|spf] [--offload]
//! ```

use sal_pim::baseline::GpuModel;
use sal_pim::cli::Args;
use sal_pim::config::{parse::parse_config, SimConfig};
use sal_pim::coordinator::{Coordinator, Policy, PrefillTarget, ServeMetrics};
use sal_pim::energy::{AreaModel, EnergyParams, PowerReport};
use sal_pim::mapper::GenerationSim;
use sal_pim::report::{fmt_bw, fmt_time, fmt_x, Table};
use sal_pim::testutil::SplitMix64;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> anyhow::Result<SimConfig> {
    let mut cfg = match args.flag("preset").unwrap_or("paper") {
        "paper" => SimConfig::paper(),
        "mini" => SimConfig::mini(),
        other => anyhow::bail!("unknown preset `{other}` (paper|mini)"),
    };
    if let Some(path) = args.flag("file") {
        let text = std::fs::read_to_string(path)?;
        cfg = parse_config(cfg, &text)?;
    }
    let p_sub = args.get("p-sub", cfg.parallelism.p_sub)?;
    Ok(cfg.with_p_sub(p_sub))
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("config") => cmd_config(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("breakdown") => cmd_breakdown(&args),
        Some("power") => cmd_power(&args),
        Some("area") => cmd_area(&args),
        Some("serve") => cmd_serve(&args),
        Some(other) => anyhow::bail!("unknown command `{other}` — see --help in the README"),
        None => {
            println!("usage: sal-pim <config|simulate|sweep|breakdown|power|area|serve> [flags]");
            Ok(())
        }
    }
}

fn cmd_config(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    println!("{cfg:#?}");
    println!(
        "peak internal bandwidth: {}",
        fmt_bw(cfg.peak_internal_bandwidth())
    );
    println!(
        "peak external bandwidth: {}",
        fmt_bw(cfg.peak_external_bandwidth())
    );
    let problems = cfg.validate();
    if problems.is_empty() {
        println!("config OK");
    } else {
        for p in problems {
            println!("PROBLEM: {p}");
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let n_in = args.get("in", 32usize)?;
    let n_out = args.get("out", 64usize)?;
    let mut sim = GenerationSim::new(&cfg);
    sim.set_prefetch(args.switch("prefetch"));
    let r = sim.generate(n_in, n_out);
    let tck = cfg.timing.tck_ns;
    let gpu = GpuModel::titan_rtx().generation_time(&cfg.model, n_in, n_out);
    println!(
        "SAL-PIM  in={n_in} out={n_out} P_Sub={}",
        cfg.parallelism.p_sub
    );
    println!("  prefill: {}", fmt_time(r.prefill.seconds(tck)));
    println!(
        "  decode:  {} ({:.1} tok/s)",
        fmt_time(r.decode.seconds(tck)),
        r.decode_tokens_per_sec(tck)
    );
    println!("  total:   {}", fmt_time(r.seconds(tck)));
    println!(
        "  avg internal bandwidth: {}",
        fmt_bw(r.total().avg_internal_bandwidth(tck) * cfg.hbm.pseudo_channels() as f64)
    );
    println!("  GPU baseline: {}", fmt_time(gpu));
    println!("  speedup vs GPU: {}", fmt_x(gpu / r.seconds(tck)));
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let gpu = GpuModel::titan_rtx();
    let mut sim = GenerationSim::new(&cfg);
    let mut t = Table::new(
        "Fig. 11 — speedup of SAL-PIM vs GPU",
        &["in", "out", "pim", "gpu", "speedup"],
    );
    let mut speedups = Vec::new();
    for &n_in in &[32usize, 64, 128] {
        for &n_out in &[1usize, 4, 16, 32, 64, 128, 256] {
            let pim = sim.generate(n_in, n_out).seconds(cfg.timing.tck_ns);
            let g = gpu.generation_time(&cfg.model, n_in, n_out);
            speedups.push(g / pim);
            t.row(&[
                n_in.to_string(),
                n_out.to_string(),
                fmt_time(pim),
                fmt_time(g),
                fmt_x(g / pim),
            ]);
        }
    }
    t.print();
    let max = speedups.iter().cloned().fold(0.0f64, f64::max);
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    println!("max speedup {} | avg speedup {} (paper: 4.72× / 1.83×)", fmt_x(max), fmt_x(avg));
    Ok(())
}

fn cmd_breakdown(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let kv = args.get("kv", 128usize)?;
    let mut sim = GenerationSim::new(&cfg);
    let st = sim.decode_token(kv);
    println!(
        "decode iteration @ kv={kv}, P_Sub={}: {}",
        cfg.parallelism.p_sub,
        fmt_time(st.seconds(cfg.timing.tck_ns))
    );
    for (phase, frac) in st.breakdown() {
        println!("  {:>13}: {:5.2}%", phase.name(), frac * 100.0);
    }
    Ok(())
}

fn cmd_power(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let n_out = args.get("out", 32usize)?;
    let mut t = Table::new(
        "Fig. 15 — power by subarray-level parallelism",
        &["P_Sub", "avg W", "vs 60 W budget"],
    );
    for p_sub in [1usize, 2, 4] {
        let c = cfg.clone().with_p_sub(p_sub);
        let mut sim = GenerationSim::new(&c);
        let r = sim.generate(32, n_out);
        let rep = PowerReport::from_stats(&c, &EnergyParams::paper(), &r.total());
        t.row(&[
            p_sub.to_string(),
            format!("{:.1}", rep.avg_power_w()),
            format!("{:.0}%", rep.budget_fraction() * 100.0),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_area(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let a = AreaModel::new(&cfg);
    let mut t = Table::new(
        "Table 3 — area per channel",
        &["unit", "count", "area (mm²)"],
    );
    t.row(&[
        "S-ALU".into(),
        a.salus_per_channel.to_string(),
        format!("{:.2}", a.salu_area_mm2()),
    ]);
    t.row(&[
        "Bank-level unit".into(),
        a.bank_units_per_channel.to_string(),
        format!("{:.2}", a.bank_unit_area_mm2()),
    ]);
    t.row(&[
        "C-ALU".into(),
        a.calus_per_channel.to_string(),
        format!("{:.2}", a.calu_area_mm2()),
    ]);
    t.print();
    println!(
        "overhead vs HBM2 channel: {:.2}% (paper: 4.81%, threshold 25%)",
        a.overhead_fraction() * 100.0
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let n = args.get("requests", 16usize)?;
    let policy = match args.flag("policy").unwrap_or("fcfs") {
        "fcfs" => Policy::Fcfs,
        "sjf" => Policy::ShortestJobFirst,
        "spf" => Policy::ShortestPromptFirst,
        other => anyhow::bail!("unknown policy `{other}`"),
    };
    let mut coord = Coordinator::new(&cfg).with_policy(policy);
    if args.switch("offload") {
        coord = coord.with_prefill_target(PrefillTarget::GpuOffload);
    }
    // Synthetic arrival process (deterministic seed): prompt 16–128,
    // output 8–128, Poisson-ish arrivals.
    let mut rng = SplitMix64::new(args.get("seed", 42u64)?);
    let mut at = 0.0;
    for _ in 0..n {
        let prompt = 16 + (rng.below(8) * 16) as usize;
        let out = 8 << rng.below(5) as usize;
        at += rng.f64_unit() * 0.05;
        coord.submit(prompt, out, at);
    }
    let done = coord.run();
    let m = ServeMetrics::from_completions(&done);
    println!(
        "policy={} offload={}\n{m}",
        policy.name(),
        args.switch("offload")
    );
    Ok(())
}
