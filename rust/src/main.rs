//! SAL-PIM command-line interface.
//!
//! ```text
//! sal-pim config   [--preset paper|mini] [--file overrides.cfg]
//! sal-pim simulate --in 32 --out 64 [--p-sub 4] [--prefetch]
//! sal-pim sweep    [--p-sub 4]                 # the Fig. 11 grid
//! sal-pim breakdown [--kv 128]                 # decode phase breakdown
//! sal-pim power    [--out 32]                  # Fig. 15 power report
//! sal-pim area                                 # Table 3 arithmetic
//! sal-pim serve    --requests 16 [--policy fcfs|sjf|spf] [--offload]
//!                  [--engine seq|batch|cluster] [--devices 4] [--batch 8]
//!                  [--backend salpim|gpu|banklevel|hetero]
//!                  [--prefill-chunk 32]
//!                  [--route rr|ll|affinity] [--rate 200] [--burst 4]
//!                  [--sweep] [--seed 42]
//! ```
//!
//! `serve` modes:
//! * `--engine seq` (default) — the paper-faithful sequential coordinator;
//! * `--engine batch` — continuous batching on one device (KV-admission
//!   controlled, batched decode steps);
//! * `--engine cluster` — `--devices` N batching devices behind a router
//!   (`--route` round-robin / least-loaded / session-affinity);
//! * `--backend` picks the execution backend batching devices simulate:
//!   the subarray-level PIM (default), the Titan RTX roofline with
//!   batched decode, the Newton-style bank-level PIM, or the
//!   heterogeneous GPU-prefill + PIM-decode device;
//! * `--prefill-chunk` C interleaves summarization in C-token chunks at
//!   token boundaries instead of stalling the decode batch;
//! * `--rate` R switches arrivals to open-loop Poisson at R req/s
//!   (`--burst` B makes them bursts of B); without it the legacy jittered
//!   mix is used;
//! * `--sweep` — the latency-vs-offered-load curve at 3 loads.

use sal_pim::baseline::GpuModel;
use sal_pim::cli::Args;
use sal_pim::config::{parse::parse_config, SimConfig};
use sal_pim::coordinator::{Coordinator, Policy, PrefillTarget, ServeMetrics};
use sal_pim::energy::{AreaModel, EnergyParams, PowerReport};
use sal_pim::mapper::GenerationSim;
use sal_pim::report::{fmt_bw, fmt_pct, fmt_time, fmt_x, Table};
use sal_pim::serve::sweep::{latency_vs_load, SweepConfig};
use sal_pim::serve::workload::{requests_from_items, ArrivalPattern};
use sal_pim::serve::{BackendKind, Cluster, DeviceEngine, Routing};
use sal_pim::testutil::RequestMix;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> anyhow::Result<SimConfig> {
    let mut cfg = match args.flag("preset").unwrap_or("paper") {
        "paper" => SimConfig::paper(),
        "mini" => SimConfig::mini(),
        other => anyhow::bail!("unknown preset `{other}` (paper|mini)"),
    };
    if let Some(path) = args.flag("file") {
        let text = std::fs::read_to_string(path)?;
        cfg = parse_config(cfg, &text)?;
    }
    let p_sub = args.get("p-sub", cfg.parallelism.p_sub)?;
    Ok(cfg.with_p_sub(p_sub))
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("config") => cmd_config(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("breakdown") => cmd_breakdown(&args),
        Some("power") => cmd_power(&args),
        Some("area") => cmd_area(&args),
        Some("serve") => cmd_serve(&args),
        Some(other) => anyhow::bail!("unknown command `{other}` — see --help in the README"),
        None => {
            println!("usage: sal-pim <config|simulate|sweep|breakdown|power|area|serve> [flags]");
            println!();
            println!("serve flags:");
            println!("  --requests N       request count (default 16)");
            println!("  --policy P         fcfs|sjf|spf (default fcfs)");
            println!("  --engine E         seq|batch|cluster (default seq)");
            println!("  --devices N        cluster size (default 4)");
            println!("  --batch M          continuous-batching slots per device (default 8)");
            println!("  --route R          rr|ll|affinity (default rr)");
            println!("  --backend B        salpim|gpu|banklevel|hetero (default salpim;");
            println!("                     batch/cluster/sweep engines)");
            println!("  --prefill-chunk C  interleave prefill in C-token chunks instead of");
            println!("                     stalling the decode batch");
            println!("  --rate R           open-loop Poisson arrivals at R req/s");
            println!("  --burst B          make Poisson arrivals bursts of B");
            println!("  --offload          GPU prefill offload (seq engine only)");
            println!("  --sweep            latency-vs-offered-load curve (3 loads)");
            println!("  --seed S           workload seed (default 42)");
            Ok(())
        }
    }
}

fn cmd_config(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    println!("{cfg:#?}");
    println!(
        "peak internal bandwidth: {}",
        fmt_bw(cfg.peak_internal_bandwidth())
    );
    println!(
        "peak external bandwidth: {}",
        fmt_bw(cfg.peak_external_bandwidth())
    );
    let problems = cfg.validate();
    if problems.is_empty() {
        println!("config OK");
    } else {
        for p in problems {
            println!("PROBLEM: {p}");
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let n_in = args.get("in", 32usize)?;
    let n_out = args.get("out", 64usize)?;
    let mut sim = GenerationSim::new(&cfg);
    sim.set_prefetch(args.switch("prefetch"));
    let r = sim.generate(n_in, n_out);
    let tck = cfg.timing.tck_ns;
    let gpu = GpuModel::titan_rtx().generation_time(&cfg.model, n_in, n_out);
    println!(
        "SAL-PIM  in={n_in} out={n_out} P_Sub={}",
        cfg.parallelism.p_sub
    );
    println!("  prefill: {}", fmt_time(r.prefill.seconds(tck)));
    println!(
        "  decode:  {} ({:.1} tok/s)",
        fmt_time(r.decode.seconds(tck)),
        r.decode_tokens_per_sec(tck)
    );
    println!("  total:   {}", fmt_time(r.seconds(tck)));
    println!(
        "  avg internal bandwidth: {}",
        fmt_bw(r.total().avg_internal_bandwidth(tck) * cfg.hbm.pseudo_channels() as f64)
    );
    println!("  GPU baseline: {}", fmt_time(gpu));
    println!("  speedup vs GPU: {}", fmt_x(gpu / r.seconds(tck)));
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let gpu = GpuModel::titan_rtx();
    let mut sim = GenerationSim::new(&cfg);
    let mut t = Table::new(
        "Fig. 11 — speedup of SAL-PIM vs GPU",
        &["in", "out", "pim", "gpu", "speedup"],
    );
    let mut speedups = Vec::new();
    for &n_in in &[32usize, 64, 128] {
        for &n_out in &[1usize, 4, 16, 32, 64, 128, 256] {
            let pim = sim.generate(n_in, n_out).seconds(cfg.timing.tck_ns);
            let g = gpu.generation_time(&cfg.model, n_in, n_out);
            speedups.push(g / pim);
            t.row(&[
                n_in.to_string(),
                n_out.to_string(),
                fmt_time(pim),
                fmt_time(g),
                fmt_x(g / pim),
            ]);
        }
    }
    t.print();
    let max = speedups.iter().cloned().fold(0.0f64, f64::max);
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    println!("max speedup {} | avg speedup {} (paper: 4.72× / 1.83×)", fmt_x(max), fmt_x(avg));
    Ok(())
}

fn cmd_breakdown(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let kv = args.get("kv", 128usize)?;
    let mut sim = GenerationSim::new(&cfg);
    let st = sim.decode_token(kv);
    println!(
        "decode iteration @ kv={kv}, P_Sub={}: {}",
        cfg.parallelism.p_sub,
        fmt_time(st.seconds(cfg.timing.tck_ns))
    );
    for (phase, frac) in st.breakdown() {
        println!("  {:>13}: {:5.2}%", phase.name(), frac * 100.0);
    }
    Ok(())
}

fn cmd_power(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let n_out = args.get("out", 32usize)?;
    let mut t = Table::new(
        "Fig. 15 — power by subarray-level parallelism",
        &["P_Sub", "avg W", "vs 60 W budget"],
    );
    for p_sub in [1usize, 2, 4] {
        let c = cfg.clone().with_p_sub(p_sub);
        let mut sim = GenerationSim::new(&c);
        let r = sim.generate(32, n_out);
        let rep = PowerReport::from_stats(&c, &EnergyParams::paper(), &r.total());
        t.row(&[
            p_sub.to_string(),
            format!("{:.1}", rep.avg_power_w()),
            format!("{:.0}%", rep.budget_fraction() * 100.0),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_area(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let a = AreaModel::new(&cfg);
    let mut t = Table::new(
        "Table 3 — area per channel",
        &["unit", "count", "area (mm²)"],
    );
    t.row(&[
        "S-ALU".into(),
        a.salus_per_channel.to_string(),
        format!("{:.2}", a.salu_area_mm2()),
    ]);
    t.row(&[
        "Bank-level unit".into(),
        a.bank_units_per_channel.to_string(),
        format!("{:.2}", a.bank_unit_area_mm2()),
    ]);
    t.row(&[
        "C-ALU".into(),
        a.calus_per_channel.to_string(),
        format!("{:.2}", a.calu_area_mm2()),
    ]);
    t.print();
    println!(
        "overhead vs HBM2 channel: {:.2}% (paper: 4.81%, threshold 25%)",
        a.overhead_fraction() * 100.0
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let n = args.get("requests", 16usize)?;
    let seed = args.get("seed", 42u64)?;
    let policy = match args.flag("policy").unwrap_or("fcfs") {
        "fcfs" => Policy::Fcfs,
        "sjf" => Policy::ShortestJobFirst,
        "spf" => Policy::ShortestPromptFirst,
        other => anyhow::bail!("unknown policy `{other}`"),
    };
    let routing = match args.flag("route").unwrap_or("rr") {
        "rr" => Routing::RoundRobin,
        "ll" => Routing::LeastLoaded,
        "affinity" => Routing::SessionAffinity,
        other => anyhow::bail!("unknown route `{other}` (rr|ll|affinity)"),
    };
    let devices = args.get("devices", 4usize)?;
    let max_batch = args.get("batch", 8usize)?;
    let backend_flag = args.flag("backend").unwrap_or("salpim");
    let backend = BackendKind::parse(backend_flag).ok_or_else(|| {
        anyhow::anyhow!("unknown backend `{backend_flag}` (salpim|gpu|banklevel|hetero)")
    })?;
    // switch() also catches a bare `--prefill-chunk` (defaults to 32
    // tokens) that flag() would miss.
    let prefill_chunk = if args.switch("prefill-chunk") {
        let c = args.get("prefill-chunk", 32usize)?;
        anyhow::ensure!(c >= 1, "--prefill-chunk must be at least 1 token");
        Some(c)
    } else {
        None
    };

    if args.switch("sweep") {
        // Honor an explicit --requests; default to a load big enough to
        // actually saturate the cluster.
        let sweep_requests = if args.flag("requests").is_some() { n } else { 64 };
        let sc = SweepConfig {
            devices,
            max_batch,
            routing,
            policy,
            requests: sweep_requests,
            seed,
            backend,
            prefill_chunk,
            ..SweepConfig::default()
        };
        let loads = [50.0, 200.0, 1000.0];
        let pts = latency_vs_load(&cfg, &sc, &loads);
        let mut t = Table::new(
            &format!(
                "latency vs offered load ({} devices × batch {}, {}, backend {}, {} requests)",
                sc.devices,
                sc.max_batch,
                routing.name(),
                backend.name(),
                sc.requests
            ),
            &["offered req/s", "tok/s", "p50 lat", "p95 lat", "p95 TTFT", "rejected"],
        );
        for p in &pts {
            t.row(&[
                format!("{:.0}", p.offered_rps),
                format!("{:.1}", p.metrics.throughput_tok_s),
                fmt_time(p.metrics.p50_latency_s),
                fmt_time(p.metrics.p95_latency_s),
                fmt_time(p.metrics.p95_ttft_s),
                p.rejected.to_string(),
            ]);
        }
        t.print();
        return Ok(());
    }

    // The shared request mix: every engine sees the identical workload.
    let items = RequestMix::paper(seed).take(n);
    let pattern = match args.flag("rate") {
        Some(_) => {
            let rate = args.get("rate", 200.0f64)?;
            anyhow::ensure!(rate > 0.0, "--rate must be positive");
            match args.flag("burst") {
                Some(_) => ArrivalPattern::Bursty {
                    rate_rps: rate,
                    burst: args.get("burst", 4usize)?,
                },
                None => ArrivalPattern::Poisson { rate_rps: rate },
            }
        }
        None => ArrivalPattern::Jittered { scale_s: 0.05 },
    };
    let requests = requests_from_items(&items, pattern, 8);

    match args.flag("engine").unwrap_or("seq") {
        "seq" => {
            anyhow::ensure!(
                backend == BackendKind::SalPim,
                "--engine seq is the paper-faithful PIM coordinator; pick --engine batch|cluster \
                 for --backend {} (or use --offload for GPU prefill)",
                backend.name()
            );
            anyhow::ensure!(
                prefill_chunk.is_none(),
                "--prefill-chunk needs the batching scheduler; pick --engine batch|cluster"
            );
            let mut coord = Coordinator::new(&cfg).with_policy(policy);
            if args.switch("offload") {
                coord = coord.with_prefill_target(PrefillTarget::GpuOffload);
            }
            for r in requests {
                coord.submit_request(r);
            }
            let m = ServeMetrics::from_completions(&coord.run());
            println!(
                "engine=seq policy={} offload={} arrivals={}\n{m}",
                policy.name(),
                args.switch("offload"),
                pattern.name()
            );
        }
        "batch" => {
            let mut eng = DeviceEngine::with_backend(backend.build(&cfg), max_batch)
                .with_policy(policy)
                .with_prefill_chunk(prefill_chunk);
            for r in requests {
                eng.submit(r);
            }
            let backend_name = eng.backend_name();
            let m = ServeMetrics::from_completions(&eng.run());
            let rep = eng.report();
            println!(
                "engine=batch backend={} policy={} batch={} chunk={} arrivals={}\n{m}",
                backend_name,
                policy.name(),
                max_batch,
                match prefill_chunk {
                    Some(c) => c.to_string(),
                    None => "inline".to_string(),
                },
                pattern.name()
            );
            println!(
                "kv peak util:    {} | max batch seen: {} | rejected: {}",
                fmt_pct(rep.kv_peak_utilization),
                rep.max_batch_seen,
                rep.rejected
            );
        }
        "cluster" => {
            let mut cluster = Cluster::homogeneous(&cfg, backend, devices, max_batch, routing)
                .with_policy(policy)
                .with_prefill_chunk(prefill_chunk);
            for r in requests {
                cluster.submit(r);
            }
            let done = cluster.run();
            let m = ServeMetrics::from_completions(&done);
            println!(
                "engine=cluster backend={} devices={} batch={} route={} arrivals={}\n{m}",
                backend.name(),
                devices,
                max_batch,
                routing.name(),
                pattern.name()
            );
            let mut t = Table::new(
                "per-device",
                &["device", "backend", "requests", "tok/s", "p95 lat", "kv peak util"],
            );
            let per = cluster.per_device_metrics(&done);
            let reps = cluster.per_device_reports();
            let names = cluster.backend_names();
            for (i, (pm, rep)) in per.iter().zip(&reps).enumerate() {
                t.row(&[
                    i.to_string(),
                    names[i].clone(),
                    pm.requests.to_string(),
                    format!("{:.1}", pm.throughput_tok_s),
                    fmt_time(pm.p95_latency_s),
                    fmt_pct(rep.kv_peak_utilization),
                ]);
            }
            t.print();
        }
        other => anyhow::bail!("unknown engine `{other}` (seq|batch|cluster)"),
    }
    Ok(())
}
